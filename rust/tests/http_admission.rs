//! HTTP admission-edge contract (ISSUE 8, DESIGN.md §9): client
//! mistakes are 400s, overload refusals are 429s with a computed
//! `retry_after_ms`, and shed-degraded admissions report their capped
//! `max_new`. Runs the whole stack on the modeled executor, so it never
//! skips for missing artifacts.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use blink::eval::overload::overload_manifest;
use blink::frontend::overload::OverloadConfig;
use blink::frontend::token_reader::ReaderConfig;
use blink::frontend::{DpuFrontend, FrontendConfig};
use blink::gpu::{Executor, ModeledCost, PrefixReuse, Scheduler, SchedulerConfig};
use blink::http::HttpServer;
use blink::rdma::{RdmaConfig, RdmaEngine};
use blink::ringbuf::{RingBuffer, RingConfig};
use blink::tokenizer::Vocab;

struct Stack {
    http: HttpServer,
    frontend: Arc<DpuFrontend>,
    sched: Scheduler,
}

impl Stack {
    fn addr(&self) -> std::net::SocketAddr {
        self.http.addr
    }

    fn stop(mut self) {
        self.http.shutdown();
        self.sched.drain_and_stop();
    }
}

/// Full modeled pipeline behind the real HTTP surface: ring → RDMA →
/// scheduler → modeled executor, fronted by a `DpuFrontend` with the
/// given admission-gate config.
fn stack(overload: OverloadConfig) -> Stack {
    let manifest = overload_manifest();
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 64,
        max_prompt: 256,
        max_output: 256,
    }));
    let rdma = RdmaEngine::spawn(ring.clone(), RdmaConfig::zero_cost());
    let executor = Executor::spawn_modeled(
        &manifest,
        ModeledCost { prefill_us_per_token: 1.0, decode_step_us: 200.0, ..ModeledCost::zero() },
    );
    let sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest,
        SchedulerConfig {
            apply_launch_delays: false,
            prefix_reuse: PrefixReuse::Off,
            ..Default::default()
        },
    );
    // Byte-level vocab: every byte is its own token, which is all the
    // tokenizer needs for these admission-contract checks.
    let vocab = Arc::new(Vocab { tokens: (0..=255u8).map(|b| vec![b]).collect(), merges: vec![] });
    let frontend = Arc::new(DpuFrontend::new(
        rdma,
        vocab,
        FrontendConfig {
            num_slots: 64,
            max_prompt: 256,
            max_output: 256,
            reader: ReaderConfig::default(),
            overload,
        },
    ));
    frontend.attach_stats(sched.stats.clone());
    let http = HttpServer::serve("127.0.0.1:0", frontend.clone(), sched.stats.clone())
        .expect("http bind");
    Stack { http, frontend, sched }
}

#[test]
fn client_errors_are_400_never_429() {
    let s = stack(OverloadConfig::default());
    let addr = s.addr();

    // Baseline: a well-formed request completes.
    let ok = http_post(addr, r#"{"prompt": "hello", "max_tokens": 3}"#);
    assert!(ok.starts_with("HTTP/1.1 200"), "resp: {ok}");

    // Out-of-range priority is rejected, not silently clamped to 7.
    let bad = http_post(addr, r#"{"prompt": "x", "max_tokens": 2, "priority": 9}"#);
    assert!(bad.starts_with("HTTP/1.1 400"), "resp: {bad}");
    assert!(bad.contains("priority must be an integer 0-7"), "resp: {bad}");

    // max_tokens 0 would create a max_new == 0 lane (PR 4's fail-fast
    // invariant); it must die at the parse edge.
    let bad = http_post(addr, r#"{"prompt": "x", "max_tokens": 0}"#);
    assert!(bad.starts_with("HTTP/1.1 400"), "resp: {bad}");
    assert!(bad.contains("max_tokens must be an integer in 1..="), "resp: {bad}");

    // 2^32 + 1 used to wrap u64→u32 into max_new == 1; now it's past the
    // documented cap and rejected.
    let bad = http_post(addr, r#"{"prompt": "x", "max_tokens": 4294967297}"#);
    assert!(bad.starts_with("HTTP/1.1 400"), "resp: {bad}");
    assert!(bad.contains("max_tokens must be an integer in 1..="), "resp: {bad}");

    // A prompt over the arena capacity is the client's mistake: 400 (it
    // was a 429 before the Rejected::Client/Overload split), and the
    // body must not carry overload retry advice.
    let long = format!(r#"{{"prompt": "{}", "max_tokens": 2}}"#, "a".repeat(300));
    let bad = http_post(addr, &long);
    assert!(bad.starts_with("HTTP/1.1 400"), "resp: {bad}");
    assert!(bad.contains("exceeds arena capacity"), "resp: {bad}");
    assert!(!bad.contains("retry_after_ms"), "client errors carry no retry hint: {bad}");

    // An empty tenant tag is malformed, not an admission problem.
    let bad = http_post(addr, r#"{"prompt": "x", "max_tokens": 2, "tenant": ""}"#);
    assert!(bad.starts_with("HTTP/1.1 400"), "resp: {bad}");

    s.stop();
}

#[test]
fn rate_limited_requests_get_429_with_retry_after() {
    // One admission per minute; shed thresholds parked at infinity so
    // only the hard window cap speaks.
    let s = stack(OverloadConfig {
        enabled: true,
        window_capacity: 1,
        window_ms: 60_000,
        bucket_capacity: 1e6,
        bucket_refill_per_s: 1e6,
        tenant_slots: 16,
        degrade_threshold: f64::INFINITY,
        drop_threshold: f64::INFINITY,
        degrade_max_new: 4,
        interactive_floor: 4,
    });
    let addr = s.addr();

    let ok = http_post(addr, r#"{"prompt": "first", "max_tokens": 2, "tenant": "acme"}"#);
    assert!(ok.starts_with("HTTP/1.1 200"), "resp: {ok}");

    let limited = http_post(addr, r#"{"prompt": "second", "max_tokens": 2, "tenant": "acme"}"#);
    assert!(limited.starts_with("HTTP/1.1 429"), "resp: {limited}");
    assert!(limited.contains("retry_after_ms"), "429 must carry retry advice: {limited}");
    assert!(limited.contains("rate limit"), "resp: {limited}");

    // The refusal is visible on the metrics surface: the gate is on and
    // the tenant's admission row shows one admit, one reject.
    let m = http_get(addr, "/metrics");
    assert!(m.contains("overload_enabled 1"), "metrics: {m}");
    assert!(m.contains("rate_limited=1"), "metrics: {m}");
    assert!(m.contains("tenant_admission{"), "metrics: {m}");
    assert!(m.contains("admitted=1 rejected=1"), "metrics: {m}");

    s.stop();
}

#[test]
fn shed_degraded_completion_reports_capped_max_new() {
    // degrade_threshold 0 puts every best-effort admission in the
    // degrade band without ever dropping; interactive requests pass
    // untouched.
    let s = stack(OverloadConfig {
        enabled: true,
        window_capacity: 1000,
        window_ms: 1000,
        bucket_capacity: 1e6,
        bucket_refill_per_s: 1e6,
        tenant_slots: 16,
        degrade_threshold: 0.0,
        drop_threshold: f64::INFINITY,
        degrade_max_new: 2,
        interactive_floor: 4,
    });
    let addr = s.addr();

    // Best-effort request asked for 8 tokens, was admitted degraded to 2
    // — and the usage block says so.
    let resp = http_post(addr, r#"{"prompt": "background batch job", "max_tokens": 8}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "resp: {resp}");
    assert!(resp.contains("\"max_new\":2"), "degraded budget must be reported: {resp}");

    // Interactive-class admission is never degraded by the shed policy.
    let resp =
        http_post(addr, r#"{"prompt": "user chat", "max_tokens": 8, "class": "interactive"}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "resp: {resp}");
    assert!(resp.contains("\"max_new\":8"), "interactive budget must hold: {resp}");

    let shed = s
        .frontend
        .gate()
        .shed_degraded
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(shed, 1, "exactly the batch admission was degraded");

    s.stop();
}

fn http_post(addr: std::net::SocketAddr, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}
