//! Tier-1 acceptance test for fixed-k speculative decoding
//! (DESIGN.md §11). Runs entirely on the modeled executor in
//! greedy-chain mode — no artifacts, never skips — through the shared
//! [`blink::eval::spec::run_live_spec`] runner, i.e. the real ring →
//! scheduler → draft → `decode_verify` → longest-prefix-retire path.
//!
//! The contract speculation must honor, verbatim from the issue:
//!
//! 1. **faster**: ≥ 1.5× decode tokens/s at k = 4, acceptance ≥ 0.7,
//!    against the k = 0 run of the *same* trace;
//! 2. **identical**: per-token outputs byte-identical to the
//!    non-speculative greedy decode — rejected drafts must be invisible;
//! 3. **EOS-safe**: an EOS surfacing mid-verify-window retires the lane
//!    without publishing anything past it.

use blink::eval::spec::{run_live_spec, LiveSpecParams};

/// The speedup + identity contract on one four-lane trace. The chain
/// streams at the default prompt base never hit EOS inside the 96-token
/// budget (verified against the chain function), so both runs produce
/// exactly `requests × max_new` tokens and the wall clocks are directly
/// comparable.
#[test]
fn speculation_is_faster_and_byte_identical() {
    let plain = run_live_spec(&LiveSpecParams::base(0, 1.0));
    let spec = run_live_spec(&LiveSpecParams::base(4, 0.7));

    // Identity first — a fast-but-wrong decode is worthless. Greedy
    // chains make each stream a pure function of its prompt, so k must
    // not change a single token.
    assert_eq!(plain.outputs, spec.outputs, "speculation changed the decoded tokens");
    for (slot, out) in plain.outputs.iter().enumerate() {
        assert_eq!(out.len(), 96, "slot {slot} must run its full budget");
    }

    // The speedup criterion: fewer weight sweeps for the same tokens.
    let ratio = spec.tokens_per_s / plain.tokens_per_s;
    assert!(
        ratio >= 1.5,
        "k=4 @ accept 0.7 must clear 1.5x: {:.1} vs {:.1} tok/s ({ratio:.2}x)",
        spec.tokens_per_s,
        plain.tokens_per_s,
    );
    // And the mechanism behind it, independent of wall-clock noise: the
    // speculative run must have launched far fewer decode iterations.
    assert!(
        spec.decode_steps * 4 < plain.decode_steps * 3,
        "speculation must cut launches: {} vs {}",
        spec.decode_steps,
        plain.decode_steps
    );

    // Telemetry surfaces the acceptance economics.
    assert_eq!(plain.spec_drafted, 0, "k=0 must not draft");
    assert!(spec.spec_drafted > 0, "k=4 must draft");
    assert!(
        spec.spec_accepted > 0 && spec.spec_accepted < spec.spec_drafted,
        "acceptance 0.7 must land strictly between 0 and 1: {}/{}",
        spec.spec_accepted,
        spec.spec_drafted
    );
    assert!(
        spec.accepted_per_verify_p50 >= 1.0,
        "median accepted per verify at 0.7 acceptance: {}",
        spec.accepted_per_verify_p50
    );
}

/// Perfect acceptance is the ceiling: every verify emits k + 1 tokens,
/// so launches shrink by ~(k + 1)× and throughput approaches the
/// verify-premium-adjusted bound.
#[test]
fn perfect_acceptance_approaches_k_plus_one() {
    let plain = run_live_spec(&LiveSpecParams::base(0, 1.0));
    let spec = run_live_spec(&LiveSpecParams::base(4, 1.0));
    assert_eq!(plain.outputs, spec.outputs);
    assert_eq!(
        spec.spec_accepted, spec.spec_drafted,
        "acceptance 1.0 must accept every draft"
    );
    assert!(
        spec.decode_steps * 4 <= plain.decode_steps,
        "k=4 @ accept 1.0 must cut launches ~5x: {} vs {}",
        spec.decode_steps,
        plain.decode_steps
    );
}

/// EOS mid-window: prompt base 69 at slot 0 produces the chain
/// `[1672, 606, 1614, 1293, 0]` — EOS (token 0) at generated index 4,
/// inside the first k = 4 verify window. The lane must retire with
/// exactly those five tokens: nothing after the EOS, even though the
/// verify window scored a position past it.
#[test]
fn eos_mid_verify_window_retires_without_trailing_tokens() {
    let mut params = LiveSpecParams::base(4, 1.0);
    params.requests = 1;
    params.prompt_base = 69;
    params.max_new = 64;
    let spec = run_live_spec(&params);

    let expected: Vec<u32> = vec![1672, 606, 1614, 1293, 0];
    assert_eq!(
        spec.outputs[0], expected,
        "the EOS trace must stop exactly at the EOS token"
    );
    assert_eq!(spec.total_tokens, 5, "no tokens may be published past EOS");

    // The plain decode of the same prompt agrees byte-for-byte.
    params.spec_k = 0;
    let plain = run_live_spec(&params);
    assert_eq!(plain.outputs[0], expected, "k=0 must produce the same truncated stream");
}
