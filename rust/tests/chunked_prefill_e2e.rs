//! Chunked-prefill tests on the *modeled* executor (never skip): the
//! full pipeline — ring scan → admission → ChunkedPrefill state machine
//! → planner → offset-graph chunk launches → completion — without
//! artifacts or PJRT. The headline assertion is the PR's acceptance
//! criterion: a prompt longer than the per-iteration budget prefills
//! across ≥ 2 chunk launches with decode steps interleaved between
//! them, its first token appearing only after the final chunk; and the
//! live chunk count equals the DES's ⌈suffix / budget⌉ for the same
//! lengths. Plus the planner property: chunk *k*+1 never launches
//! before chunk *k*, and hit/cold/chunk groups still respect
//! block-dependency order.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use blink::gpu::planner::{BatchPlanner, PrefillSeq};
use blink::gpu::{Executor, ModeledCost, PrefixReuse, Scheduler, SchedulerConfig};
use blink::kvcache::SeqCache;
use blink::ringbuf::{RingBuffer, RingConfig, SlotState};
use blink::runtime::ModelManifest;
use blink::sim::costmodel::LLAMA3_8B;
use blink::sim::des::{simulate, SimConfig};
use blink::sim::systems::System;
use blink::util::prop::run_prop;
use blink::util::rng::Rng;
use blink::workload::LengthModel;

/// A manifest for the modeled executor: full prefill grid up to 256,
/// offset grid up to 128, `max_blocks_per_seq` picked per test via the
/// parameter (it sets max_context = 16 × blocks).
fn manifest(max_blocks_per_seq: usize) -> ModelManifest {
    let mut text = format!(
        "blink-manifest v1\nmodel chunk-test\nvocab_size 2048\nd_model 64\nn_layers 2\n\
         n_heads 4\nn_kv_heads 2\nd_head 16\nd_ff 128\nblock_size 16\nnum_blocks 64\n\
         max_blocks_per_seq {max_blocks_per_seq}\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
         param tok_embed 2048x64 f32\n",
    );
    for b in [1usize, 2, 4, 8] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0\n"));
    }
    for b in [1usize, 2, 4] {
        for s in [16usize, 32, 64, 128, 256] {
            text.push_str(&format!("graph prefill_b{b}_s{s} prefill {b} {s}\n"));
        }
        for s in [16usize, 32, 64, 128] {
            text.push_str(&format!("graph prefill_offset_b{b}_s{s} prefill_offset {b} {s}\n"));
        }
    }
    ModelManifest::parse(&text).expect("chunk test manifest")
}

fn start(
    m: &ModelManifest,
    cost: ModeledCost,
    prefill_chunk_tokens: Option<usize>,
) -> (Arc<RingBuffer>, Scheduler) {
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 64,
        max_prompt: 256,
        max_output: 64,
    }));
    let executor = Executor::spawn_modeled(m, cost);
    let sched = Scheduler::spawn(
        ring.clone(),
        executor,
        m.clone(),
        SchedulerConfig {
            apply_launch_delays: false,
            prefix_reuse: PrefixReuse::Auto,
            prefill_chunk_tokens,
            ..Default::default()
        },
    );
    (ring, sched)
}

fn submit(ring: &RingBuffer, slot: usize, prompt: &[u32], max_new: u32) {
    assert!(ring.claim_for_write(slot));
    ring.write_prompt(slot, prompt);
    ring.submit(slot, slot as u64, prompt.len() as u32, max_new, slot as u32);
}

fn wait_done(ring: &RingBuffer, slots: &[usize]) {
    let t = Instant::now();
    loop {
        let done = slots.iter().all(|&s| {
            matches!(ring.slot(s).state(), SlotState::DecodeCompleted | SlotState::Failed)
        });
        if done {
            return;
        }
        assert!(t.elapsed() < Duration::from_secs(60), "timed out waiting for completion");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn prompt_of(len: usize, tag: u32) -> Vec<u32> {
    (0..len as u32).map(|i| (i * 17 + tag * 131 + 3) % 2048).collect()
}

/// Acceptance criterion, live half: a 192-token prompt under a 16-token
/// budget prefills across 12 chunk launches (= ⌈192/16⌉, the DES
/// formula), with decode steps of a concurrent short request
/// interleaved between the chunks — observed directly: the short lane's
/// token counter advances while the long prompt still has no token.
#[test]
fn long_prompt_chunks_across_iterations_with_decode_interleaved() {
    let m = manifest(16); // max_context 256
    // Visible per-step costs so the chunking window is long enough to
    // observe interleaving from the outside (~5 ms per decode step,
    // ~0.3 ms per 16-token chunk, 12 chunks ⇒ ≳ 60 ms window).
    let cost =
        ModeledCost { prefill_us_per_token: 20.0, decode_step_us: 5000.0, ..ModeledCost::zero() };
    let (ring, mut sched) = start(&m, cost, Some(16));

    // A short request first: it prefills whole (16 ≤ budget) and keeps
    // decoding throughout the long prompt's chunked prefill.
    submit(&ring, 0, &prompt_of(16, 1), 64);
    let t0 = Instant::now();
    while ring.slot(0).generated.load(Ordering::Acquire) < 2 {
        assert!(t0.elapsed() < Duration::from_secs(30), "short lane never started");
        std::thread::sleep(Duration::from_micros(200));
    }

    // The long prompt: 192 tokens, chunked 16 at a time.
    submit(&ring, 1, &prompt_of(192, 2), 4);
    let mut short_at_claim: Option<u32> = None;
    let mut interleaved = false;
    let t1 = Instant::now();
    loop {
        let long_state = ring.slot(1).state();
        let short_tokens = ring.slot(0).generated.load(Ordering::Acquire);
        if long_state == SlotState::PrefillProcessing {
            // The long prompt is admitted and mid-chunked-prefill (its
            // slot leaves this state, with its first token, only after
            // the final chunk). If the short lane's token counter
            // advances *within* this window, decode steps ran between
            // chunk launches.
            match short_at_claim {
                None => short_at_claim = Some(short_tokens),
                Some(base) if short_tokens > base => interleaved = true,
                Some(_) => {}
            }
        }
        if matches!(long_state, SlotState::DecodeCompleted | SlotState::Failed) {
            break;
        }
        assert!(t1.elapsed() < Duration::from_secs(30), "long prompt never completed");
        std::thread::sleep(Duration::from_micros(200));
    }
    wait_done(&ring, &[0, 1]);
    assert_eq!(ring.slot(0).state(), SlotState::DecodeCompleted);
    assert_eq!(ring.slot(1).state(), SlotState::DecodeCompleted);
    sched.drain_and_stop();

    assert!(interleaved, "short lane must decode between the long prompt's chunks");
    let st = &sched.stats;
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 2);
    assert_eq!(st.chunked_prefills.load(Ordering::Relaxed), 1, "only the long prompt chunks");
    let expected_chunks = 192usize.div_ceil(16) as u64; // the DES's ⌈suffix/budget⌉
    assert_eq!(st.chunk_launches.load(Ordering::Relaxed), expected_chunks);
    assert!(
        st.prefill_offset_batches.load(Ordering::Relaxed) >= expected_chunks - 1,
        "every chunk after the first launches a prefill_offset graph"
    );
    // First-token completion only after the final chunk: the long lane
    // then decodes its full budget.
    assert_eq!(ring.slot(1).generated.load(Ordering::Acquire), 4);
    let toks = ring.read_tokens(1, 0, 4);
    assert!(toks.iter().all(|&t| t < 2048));
}

/// DES half of the chunk-count agreement: the same lengths under the
/// same budget produce the same ⌈suffix/budget⌉ chunks per request in
/// the simulator — the live test above pins the identical count.
#[test]
fn des_chunk_counts_agree_with_live_formula() {
    let mut cfg = SimConfig::new(System::Blink, LLAMA3_8B, 1.0, false);
    cfg.window_s = 10.0;
    cfg.lengths = LengthModel::Fixed { input: 192, output: 4 };
    cfg.prefill_chunk_tokens = 16;
    let wm = simulate(&cfg);
    assert!(wm.chunked.chunked_prefills > 0, "every 192-token prompt chunks");
    assert_eq!(
        wm.chunked.chunk_launches,
        192u64.div_ceil(16) * wm.chunked.chunked_prefills,
        "DES chunk count per request must equal the live scheduler's"
    );
}

/// A prefix-cache *hit* whose suffix exceeds the budget keeps the hit
/// and chunks the suffix through offset graphs (no demotion to cold
/// full prefill) — both turns chunk under a 16-token budget, and the
/// second reuses the first's 64 cached tokens.
#[test]
fn hit_with_long_suffix_chunks_instead_of_falling_back() {
    let m = manifest(16);
    let (ring, mut sched) = start(&m, ModeledCost::zero(), Some(16));

    // Turn 1: cold 64 tokens (> budget ⇒ chunked; 4 chunks), indexed
    // progressively as its chunks complete.
    let first = prompt_of(64, 7);
    submit(&ring, 0, &first, 4);
    wait_done(&ring, &[0]);
    assert_eq!(ring.slot(0).state(), SlotState::DecodeCompleted);

    // Turn 2: the same 64 tokens + 64 new ⇒ suffix 64 > budget 16:
    // a chunked *hit* (4 offset chunks at offsets 64, 80, 96, 112).
    let mut second = first.clone();
    second.extend(prompt_of(64, 8).iter().map(|t| (t + 9) % 2048));
    submit(&ring, 1, &second, 4);
    wait_done(&ring, &[1]);
    assert_eq!(ring.slot(1).state(), SlotState::DecodeCompleted);
    sched.drain_and_stop();

    let st = &sched.stats;
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 2);
    assert_eq!(st.chunked_prefills.load(Ordering::Relaxed), 2, "both turns chunk");
    assert_eq!(
        st.chunk_launches.load(Ordering::Relaxed),
        (64u64.div_ceil(16)) * 2,
        "4 chunks per turn"
    );
    assert_eq!(st.prefix_hits.load(Ordering::Relaxed), 1, "turn 2 hits the index");
    assert_eq!(st.prefix_hit_tokens.load(Ordering::Relaxed), 64);
    assert_eq!(
        st.prefix_fallback_full.load(Ordering::Relaxed),
        0,
        "chunking keeps the hit — no demotion to cold"
    );
}

/// Satellite regression: a prompt of exactly `max_context` length has
/// no decode headroom (`max_new` would clamp to 0) — it must fail fast
/// at admission, not occupy a lane that can never produce a token. A
/// prompt one block shorter admits and completes normally.
#[test]
fn max_context_length_prompt_fails_fast() {
    let m = manifest(8); // max_context = 16 × 8 = 128 = largest prefill graph
    let (ring, mut sched) = start(&m, ModeledCost::zero(), None);

    submit(&ring, 0, &prompt_of(128, 3), 4); // == max_context: no headroom
    submit(&ring, 1, &prompt_of(112, 4), 4); // one block of headroom
    wait_done(&ring, &[0, 1]);
    assert_eq!(ring.slot(0).state(), SlotState::Failed, "max_context prompt must fail");
    assert_eq!(ring.slot(1).state(), SlotState::DecodeCompleted);
    assert_eq!(ring.slot(1).generated.load(Ordering::Acquire), 4);
    sched.drain_and_stop();

    let st = &sched.stats;
    assert_eq!(st.failed_requests.load(Ordering::Relaxed), 1);
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 1);
}

/// Regression (sparse offset grid): when the *final* chunk's padding
/// would push the reservation past the per-seq block budget (a
/// 15-token remainder padding to a 64-token graph), admission must
/// rescue the request with a whole-prompt launch — not reject it
/// forever as "backpressure", wedging the queue.
#[test]
fn final_chunk_padding_overshoot_rescues_to_whole_prompt() {
    // Offset grid {64, 128} only; block 16; max_context 256 (16 blocks).
    let mut text = String::from(
        "blink-manifest v1\nmodel sparse-test\nvocab_size 2048\nd_model 64\nn_layers 2\n\
         n_heads 4\nn_kv_heads 2\nd_head 16\nd_ff 128\nblock_size 16\nnum_blocks 64\n\
         max_blocks_per_seq 16\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
         param tok_embed 2048x64 f32\n",
    );
    for b in [1usize, 2, 4] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0\n"));
    }
    for s in [64usize, 128, 256] {
        text.push_str(&format!("graph prefill_b1_s{s} prefill 1 {s}\n"));
    }
    for s in [64usize, 128] {
        text.push_str(&format!("graph prefill_offset_b1_s{s} prefill_offset 1 {s}\n"));
    }
    let m = ModelManifest::parse(&text).expect("sparse manifest");
    // Budget 48 (block-aligned, on no grid seq): a 255-token prompt's
    // final chunk sits at offset 240 with a 15-token remainder, whose
    // 64-token padded window writes through position 304 — 19 blocks,
    // over the 16-block budget. The prompt itself fits prefill_b1_s256.
    let (ring, mut sched) = start(&m, ModeledCost::zero(), Some(48));
    submit(&ring, 0, &prompt_of(255, 11), 1);
    wait_done(&ring, &[0]);
    assert_eq!(ring.slot(0).state(), SlotState::DecodeCompleted, "rescued, not wedged");
    sched.drain_and_stop();
    let st = &sched.stats;
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 1);
    assert_eq!(
        st.chunked_prefills.load(Ordering::Relaxed),
        0,
        "over-budget chunk plan demotes to one whole-prompt launch"
    );
    assert_eq!(st.chunk_launches.load(Ordering::Relaxed), 0);
    assert_eq!(st.failed_requests.load(Ordering::Relaxed), 0);
}

/// Planner property: chunk *k*+1 never launches before chunk *k* (the
/// self-edge ordering chunked prefill adds), and hit/cold/chunk groups
/// still respect shared-block dependency order, with every sequence
/// launching exactly once — under randomized mixes of cold prompts,
/// prefix sharers and chunked lanes, in shuffled admission order.
#[test]
fn prop_chunk_order_and_block_dependencies() {
    run_prop("chunked-planner-topo", 0xC4A, 150, |rng: &mut Rng| {
        let bs = 16usize;
        let chunk = 32usize; // 2 blocks per non-final chunk
        let p = BatchPlanner::new(3, 2, 32, bs);
        let mut next_block = 1u32;
        let mut alloc = |n: usize| -> Vec<u32> {
            let v: Vec<u32> = (next_block..next_block + n as u32).collect();
            next_block += n as u32;
            v
        };
        let mk = |slot: usize, prompt_len: usize, cached: usize, padded: usize,
                  blocks: Vec<u32>, first: bool| PrefillSeq {
            slot,
            cache: SeqCache { blocks, cached_len: 0, prefix_len: 0 },
            prompt: (0..(prompt_len) as i32).collect(),
            max_new: 4,
            cached_prefix: cached,
            padded,
            first_token: first,
        };

        let mut seqs: Vec<PrefillSeq> = vec![];
        // Cold whole prompts.
        for slot in 0..(1 + rng.below(3) as usize) {
            let blocks = 1 + rng.below(3) as usize;
            let len = blocks * bs - rng.below(bs as u64 - 1) as usize;
            let padded = len.next_power_of_two().max(16);
            seqs.push(mk(slot, len, 0, padded, alloc(padded.div_ceil(bs)), true));
        }
        // Chunked lanes: each contributes its full chunk sequence, all
        // sharing one block list (the lane's whole reservation).
        for i in 0..(1 + rng.below(2) as usize) {
            let slot = 100 * (i + 1);
            let len = chunk + 1 + rng.below(3 * chunk as u64) as usize; // > 1 chunk
            let blocks = alloc(len.div_ceil(bs) + 1);
            let mut off = 0usize;
            while off < len {
                let clen = (len - off).min(chunk);
                // Exact padding for non-final chunks (block-aligned);
                // the final chunk pads to a block multiple.
                let padded = clen.div_ceil(bs) * bs;
                seqs.push(mk(slot, off + clen, off, padded, blocks.clone(), off + clen == len));
                off += clen;
            }
        }
        // Sharers: consume a full-block prefix of an earlier seq's
        // *written prompt* span, then write their own tail.
        for i in 0..rng.below(3) as usize {
            let prod = &seqs[rng.below(seqs.len() as u64) as usize];
            let avail = (prod.prompt.len() / bs).min(prod.cache.blocks.len());
            if avail == 0 {
                continue;
            }
            let shared = 1 + rng.below(avail as u64) as usize;
            let suffix = 1 + rng.below(24) as usize;
            let mut blocks = prod.cache.blocks[..shared].to_vec();
            blocks.extend(alloc(1 + suffix / bs));
            let padded = suffix.next_power_of_two().max(16);
            seqs.push(mk(1000 + i, shared * bs + suffix, shared * bs, padded, blocks, true));
        }
        // Shuffle: admission order must not be what saves us.
        rng.shuffle(&mut seqs);

        let expected = seqs.len();
        let groups = p.group_prefills(seqs);

        // Exactly-once launch.
        let launched: usize = groups.iter().map(|g| g.seqs.len()).sum();
        assert_eq!(launched, expected, "no seq dropped or duplicated");

        // Chunk order: within a slot, group index strictly increases
        // with the chunk offset.
        let mut per_slot: std::collections::HashMap<usize, Vec<(usize, usize)>> =
            Default::default();
        for (gi, g) in groups.iter().enumerate() {
            for s in &g.seqs {
                per_slot.entry(s.slot).or_default().push((s.cached_prefix, gi));
            }
        }
        for (slot, mut chunks) in per_slot {
            chunks.sort_unstable();
            for w in chunks.windows(2) {
                assert!(
                    w[0].1 < w[1].1,
                    "slot {slot}: chunk at offset {} (group {}) must launch strictly before \
                     offset {} (group {})",
                    w[0].0,
                    w[0].1,
                    w[1].0,
                    w[1].1
                );
            }
        }

        // Block-dependency order: a block consumed as cached prefix is
        // written by a strictly earlier group (writers credited with
        // their padded launch window, as the planner does).
        let mut writer_group: std::collections::HashMap<u32, usize> = Default::default();
        for (gi, g) in groups.iter().enumerate() {
            for s in &g.seqs {
                let lo = (s.cached_prefix / bs).min(s.cache.blocks.len());
                let hi = (s.cached_prefix + s.padded).div_ceil(bs).min(s.cache.blocks.len());
                for &b in &s.cache.blocks[lo..hi] {
                    writer_group.entry(b).or_insert(gi);
                }
            }
        }
        for (gi, g) in groups.iter().enumerate() {
            for s in &g.seqs {
                for &b in s.cache.blocks.iter().take(s.cached_prefix / bs) {
                    if let Some(&wg) = writer_group.get(&b) {
                        assert!(
                            wg < gi,
                            "group {gi} (slot {}) consumes block {b} whose writer launches in \
                             group {wg}",
                            s.slot
                        );
                    }
                }
            }
        }
    });
}
