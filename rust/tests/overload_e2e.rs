//! Tier-1 overload acceptance (ISSUE 8): on a 2× over-capacity mixed
//! trace through the *live* scheduler (modeled executor — no artifacts
//! needed, so this test never skips), the DPU-side limiter + shed hold
//! interactive-class SLO attainment near its pre-saturation level while
//! the best-effort class absorbs the loss, and the open-loop baseline
//! demonstrably collapses.
//!
//! The runner is `blink::eval::overload::run_live_overload` — the same
//! code path `blink eval overload` exercises — so what CI pins here is
//! exactly what the eval suite reports.

use blink::eval::overload::{run_live_overload, LiveOverloadParams};

#[test]
fn limiter_and_shed_hold_interactive_slo_at_2x_overload() {
    let base = run_live_overload(&LiveOverloadParams::presat());
    let unlimited = run_live_overload(&LiveOverloadParams::overload_unlimited());
    let limited = run_live_overload(&LiveOverloadParams::overload_limited());

    // Sanity: each run produced enough interactive samples to mean
    // anything, and ungated runs refuse nothing.
    assert!(base.interactive_admitted >= 3, "base interactive n = {}", base.interactive_admitted);
    assert!(
        unlimited.interactive_admitted >= 8,
        "unlimited interactive n = {}",
        unlimited.interactive_admitted
    );
    assert!(limited.interactive_admitted >= 5, "limited n = {}", limited.interactive_admitted);
    assert_eq!(base.rejected, 0, "no gate configured pre-saturation");
    assert_eq!(unlimited.rejected, 0, "no gate configured on the open-loop run");

    // Pre-saturation the budget is easy; the acceptance criterion is
    // that the gated overload run stays within 10% of this level.
    assert!(base.interactive_attainment > 0.8, "base attainment {}", base.interactive_attainment);
    assert!(
        limited.interactive_attainment >= base.interactive_attainment - 0.10,
        "limited attainment {} fell more than 10% below pre-saturation {}",
        limited.interactive_attainment,
        base.interactive_attainment
    );

    // The open-loop baseline collapses: queues grow for the whole
    // window, so late interactive arrivals blow their TTFT budget.
    assert!(
        unlimited.interactive_attainment < 0.6,
        "unlimited attainment {} should collapse at 2x capacity",
        unlimited.interactive_attainment
    );
    assert!(
        unlimited.interactive_attainment < limited.interactive_attainment - 0.2,
        "gate must clearly beat open loop: {} vs {}",
        unlimited.interactive_attainment,
        limited.interactive_attainment
    );

    // The gate actually refused work, and the loss landed on the
    // best-effort class: batch admission rate < interactive admission
    // rate, with shed counters explaining the difference.
    assert!(limited.rejected > 0, "limiter must refuse work at 2x capacity");
    assert!(
        limited.rejected_rate + limited.shed_dropped > 0,
        "window and shed rejections must show up in the gate counters"
    );
    let batch_offered = limited.offered - limited.interactive_offered;
    let interactive_rate =
        limited.interactive_admitted as f64 / limited.interactive_offered.max(1) as f64;
    let batch_rate = limited.batch_admitted as f64 / batch_offered.max(1) as f64;
    assert!(
        batch_rate < interactive_rate,
        "best-effort must absorb the loss: batch {batch_rate} vs interactive {interactive_rate}"
    );
    // Every shed-degraded admission surfaced its capped budget on the
    // request handle (what the HTTP usage block reports).
    assert_eq!(limited.degraded as u64, limited.shed_degraded);
}
