//! A dependency-free mini-loom: exhaustive two-thread schedule
//! enumeration over a modeled-atomics shim, used to *prove* (within
//! the model) the two protocol edges that blink-lint's contracts
//! merely assert (DESIGN.md §10):
//!
//! * the launch-arena epoch handoff — staged plane writes published by
//!   a `fetch_add(Release)` on `epoch`, observed by `load(Acquire)`
//!   (`gpu/arena.rs`, and the same contract reversed on
//!   `devsim` CompletionBuffer's `epoch`);
//! * the devsim doorbell — payload written before a release "ring",
//!   ring observed with acquire by `recv`, plus the ring-then-close
//!   sequence where close must not hide an earlier ring.
//!
//! The shim is the standard operational release/acquire model: every
//! store appends a timestamped message to its location's modification
//! history; each thread carries a *view* (per-location minimum
//! timestamp it may still read); a release write attaches the writer's
//! view to the message; an acquire read joins the message's view into
//! the reader's. Relaxed ops move only the accessed location's slot.
//! A load may read ANY message at or above the thread's view — that
//! per-read nondeterminism, DFS-enumerated alongside the interleaving
//! choice, is what makes stale reads representable and the negative
//! tests meaningful: they show the exact torn execution that would be
//! legal if a contract's Release or Acquire were downgraded, i.e. that
//! the orderings the lint pins are load-bearing, not decoration.
//!
//! The model is deliberately *weaker* than C++11 in one respect (a
//! relaxed RMW does not continue a release sequence), so an invariant
//! that holds over all modeled executions holds a fortiori over the
//! real ones our protocols produce.

use std::collections::BTreeSet;

const NLOCS: usize = 4;

/// Per-location timestamp frontier. `view[l] = t` means messages of
/// location `l` with timestamp `< t` are no longer readable by this
/// thread. Timestamp 0 is the initial value.
type View = [usize; NLOCS];

fn join(a: &mut View, b: &View) {
    for l in 0..NLOCS {
        a[l] = a[l].max(b[l]);
    }
}

#[derive(Clone)]
struct Msg {
    val: u64,
    view: View,
}

#[derive(Clone, Copy, Debug)]
enum Op {
    Store { loc: usize, val: u64, rel: bool },
    Load { loc: usize, acq: bool, reg: usize },
    FetchAdd { loc: usize, add: u64, acq: bool, rel: bool, reg: usize },
    /// compare_exchange(expect → new), AcqRel success / Acquire failure;
    /// old value lands in `reg` either way (Ok/Err both carry it).
    Cas { loc: usize, expect: u64, new: u64, reg: usize },
}

#[derive(Clone)]
struct State {
    hist: [Vec<Msg>; NLOCS],
    views: [View; 2],
    regs: Vec<u64>,
    pc: [usize; 2],
}

impl State {
    fn new(nregs: usize) -> State {
        State {
            hist: Default::default(),
            views: [[0; NLOCS]; 2],
            regs: vec![0; nregs],
            pc: [0; 2],
        }
    }

    /// (timestamp, value, attached view) of `loc`'s latest message —
    /// what an RMW must read for atomicity.
    fn latest(&self, loc: usize) -> (usize, u64, View) {
        match self.hist[loc].last() {
            Some(m) => (self.hist[loc].len(), m.val, m.view),
            None => (0, 0, [0; NLOCS]),
        }
    }

    fn write(&mut self, tid: usize, loc: usize, val: u64, rel: bool) {
        let ts = self.hist[loc].len() + 1;
        self.views[tid][loc] = ts;
        let view = if rel {
            self.views[tid]
        } else {
            let mut v = [0; NLOCS];
            v[loc] = ts;
            v
        };
        self.hist[loc].push(Msg { val, view });
    }

    /// Successor states of `tid` executing `op` — one per legal read
    /// choice (writes and RMWs are deterministic given the schedule).
    fn step(&self, tid: usize, op: Op) -> Vec<State> {
        let mut succ = Vec::new();
        match op {
            Op::Store { loc, val, rel } => {
                let mut s = self.clone();
                s.write(tid, loc, val, rel);
                s.pc[tid] += 1;
                succ.push(s);
            }
            Op::Load { loc, acq, reg } => {
                for ts in self.views[tid][loc]..=self.hist[loc].len() {
                    let mut s = self.clone();
                    let (val, mview) = if ts == 0 {
                        (0, [0; NLOCS])
                    } else {
                        let m = &self.hist[loc][ts - 1];
                        (m.val, m.view)
                    };
                    s.views[tid][loc] = ts;
                    if acq {
                        join(&mut s.views[tid], &mview);
                    }
                    s.regs[reg] = val;
                    s.pc[tid] += 1;
                    succ.push(s);
                }
            }
            Op::FetchAdd { loc, add, acq, rel, reg } => {
                let mut s = self.clone();
                let (ts, old, mview) = s.latest(loc);
                s.views[tid][loc] = ts;
                if acq {
                    join(&mut s.views[tid], &mview);
                }
                s.regs[reg] = old;
                s.write(tid, loc, old.wrapping_add(add), rel);
                s.pc[tid] += 1;
                succ.push(s);
            }
            Op::Cas { loc, expect, new, reg } => {
                let mut s = self.clone();
                let (ts, old, mview) = s.latest(loc);
                s.views[tid][loc] = ts;
                join(&mut s.views[tid], &mview); // acquire on both outcomes
                s.regs[reg] = old;
                if old == expect {
                    s.write(tid, loc, new, true);
                }
                s.pc[tid] += 1;
                succ.push(s);
            }
        }
        succ
    }
}

/// DFS over every interleaving × every legal read. Returns the set of
/// reachable terminal register assignments and the number of complete
/// executions explored.
fn explore(progs: [&[Op]; 2], nregs: usize) -> (BTreeSet<Vec<u64>>, usize) {
    let mut outcomes = BTreeSet::new();
    let mut paths = 0usize;
    let mut stack = vec![State::new(nregs)];
    while let Some(s) = stack.pop() {
        let runnable: Vec<usize> = (0..2).filter(|&t| s.pc[t] < progs[t].len()).collect();
        if runnable.is_empty() {
            outcomes.insert(s.regs.clone());
            paths += 1;
            continue;
        }
        for t in runnable {
            stack.extend(s.step(t, progs[t][s.pc[t]]));
        }
    }
    (outcomes, paths)
}

// Locations / registers, named for readability.
const DATA: usize = 0;
const EPOCH: usize = 1;
const BELL: usize = 2;
const CLOSED: usize = 3;
const R0: usize = 0;
const R1: usize = 1;

#[test]
fn enumeration_is_exhaustive() {
    // Two independent 2-op threads: C(4,2) = 6 interleavings, no read
    // nondeterminism — the DFS must visit exactly all of them.
    let t0 = [
        Op::Store { loc: DATA, val: 1, rel: false },
        Op::Store { loc: DATA, val: 2, rel: false },
    ];
    let t1 = [
        Op::Store { loc: EPOCH, val: 1, rel: false },
        Op::Store { loc: EPOCH, val: 2, rel: false },
    ];
    let (_, paths) = explore([&t0, &t1], 0);
    assert_eq!(paths, 6);
}

#[test]
fn arena_epoch_release_handoff_is_watertight() {
    // gpu/arena.rs contract: `atomic(epoch) observe=Acquire rmw=Release`.
    // Writer stages a plane cell (Relaxed, per its `plane` contract),
    // then publishes via fetch_add(Release); reader acquires the epoch
    // and reads the plane. Epoch observed ⇒ staging visible, in EVERY
    // execution.
    let writer = [
        Op::Store { loc: DATA, val: 42, rel: false },
        Op::FetchAdd { loc: EPOCH, add: 1, acq: false, rel: true, reg: R0 },
    ];
    let reader = [
        Op::Load { loc: EPOCH, acq: true, reg: R0 },
        Op::Load { loc: DATA, acq: false, reg: R1 },
    ];
    let (outcomes, _) = explore([&writer, &reader], 2);
    assert!(!outcomes.is_empty());
    for o in &outcomes {
        if o[R0] == 1 {
            assert_eq!(o[R1], 42, "acquired epoch but stale plane data: {o:?}");
        }
    }
    // Both branches of the race are actually reachable.
    assert!(outcomes.iter().any(|o| o[R0] == 1));
    assert!(outcomes.iter().any(|o| o[R0] == 0));
}

#[test]
fn relaxed_epoch_publish_tears() {
    // Downgrade the publish to Relaxed (what the lint would reject
    // against the arena contract): a torn execution exists where the
    // reader sees the new epoch but stale plane data. The Release is
    // load-bearing.
    let writer = [
        Op::Store { loc: DATA, val: 42, rel: false },
        Op::FetchAdd { loc: EPOCH, add: 1, acq: false, rel: false, reg: R0 },
    ];
    let reader = [
        Op::Load { loc: EPOCH, acq: true, reg: R0 },
        Op::Load { loc: DATA, acq: false, reg: R1 },
    ];
    let (outcomes, _) = explore([&writer, &reader], 2);
    assert!(
        outcomes.iter().any(|o| o[R0] == 1 && o[R1] == 0),
        "expected a stale-data execution under a Relaxed publish"
    );
}

#[test]
fn relaxed_epoch_observe_tears() {
    // Same, other side: keep the Release publish but observe with
    // Relaxed — the synchronizes-with edge never forms and the stale
    // execution reappears. The Acquire is load-bearing too.
    let writer = [
        Op::Store { loc: DATA, val: 42, rel: false },
        Op::FetchAdd { loc: EPOCH, add: 1, acq: false, rel: true, reg: R0 },
    ];
    let reader = [
        Op::Load { loc: EPOCH, acq: false, reg: R0 },
        Op::Load { loc: DATA, acq: false, reg: R1 },
    ];
    let (outcomes, _) = explore([&writer, &reader], 2);
    assert!(
        outcomes.iter().any(|o| o[R0] == 1 && o[R1] == 0),
        "expected a stale-data execution under a Relaxed observe"
    );
}

#[test]
fn doorbell_payload_visible_on_recv() {
    // devsim doorbell, ring/recv: payload write (Relaxed plane), then
    // the release ring; recv acquires the bell. Bell observed ⇒
    // payload visible, always.
    let ringer = [
        Op::Store { loc: DATA, val: 7, rel: false },
        Op::Store { loc: BELL, val: 1, rel: true },
    ];
    let receiver = [
        Op::Load { loc: BELL, acq: true, reg: R0 },
        Op::Load { loc: DATA, acq: false, reg: R1 },
    ];
    let (outcomes, _) = explore([&ringer, &receiver], 2);
    for o in &outcomes {
        if o[R0] == 1 {
            assert_eq!(o[R1], 7, "rang bell but payload not visible: {o:?}");
        }
    }
    assert!(outcomes.iter().any(|o| o[R0] == 1));
}

#[test]
fn doorbell_close_cannot_hide_a_ring() {
    // ring then close, both Release: a receiver that observes the
    // close (Acquire) must also observe the earlier ring — shutdown
    // can never swallow a delivered completion.
    let ringer = [
        Op::Store { loc: BELL, val: 1, rel: true },
        Op::Store { loc: CLOSED, val: 1, rel: true },
    ];
    let receiver = [
        Op::Load { loc: CLOSED, acq: true, reg: R0 },
        Op::Load { loc: BELL, acq: true, reg: R1 },
    ];
    let (outcomes, _) = explore([&ringer, &receiver], 2);
    for o in &outcomes {
        if o[R0] == 1 {
            assert_eq!(o[R1], 1, "observed close but lost the ring: {o:?}");
        }
    }
    // With a Relaxed close the ring CAN be lost — the release edge on
    // shutdown is what makes drain-on-close sound.
    let ringer_relaxed = [
        Op::Store { loc: BELL, val: 1, rel: true },
        Op::Store { loc: CLOSED, val: 1, rel: false },
    ];
    let (torn, _) = explore([&ringer_relaxed, &receiver], 2);
    assert!(torn.iter().any(|o| o[R0] == 1 && o[R1] == 0));
}

#[test]
fn slot_claim_is_exclusive() {
    // The ring-slot claim shape (`atomic(state) rmw=AcqRel`): two
    // schedulers CAS the same slot from 0 to their own id. In every
    // execution exactly one CAS reads 0 (wins) and the loser reads the
    // winner's id — RMW atomicity, which the model must not be able to
    // violate under any interleaving.
    let t0 = [Op::Cas { loc: DATA, expect: 0, new: 1, reg: R0 }];
    let t1 = [Op::Cas { loc: DATA, expect: 0, new: 2, reg: R1 }];
    let (outcomes, paths) = explore([&t0, &t1], 2);
    assert_eq!(paths, 2);
    for o in &outcomes {
        let wins = [o[R0], o[R1]].iter().filter(|&&v| v == 0).count();
        assert_eq!(wins, 1, "slot claim must have exactly one winner: {o:?}");
    }
}
