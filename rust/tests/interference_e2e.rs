//! The headline-claim tier-1 test (paper Fig 1 / §6.3), on the *modeled*
//! executor so it never skips: under a max-intensity antagonist the
//! host-driven placement's P99 full-iteration latency inflates ≥3× over
//! its own isolated run, while the device-plane placement inflates <1.5×.
//!
//! Robustness by construction, because CI hosts are shared and noisy:
//!
//! * the antagonist is the *deterministic* channel
//!   (`HostOrchestrator::set_contention`) — it inflates the host
//!   orchestrator's **work** by samples from a seeded
//!   `InterferenceProcess`, so iteration time scales with work and the
//!   contended/isolated comparison is a ratio of like against like on
//!   whatever hardware the test lands on;
//! * assertions are **ratios**, never absolute latencies;
//! * the modeled decode step (800 µs of spin) dominates each device-plane
//!   iteration, so scheduler-thread preemption blips are small relative
//!   to the quantity under test;
//! * percentiles are exact (`SampleRing` raw samples), because the log₂
//!   histogram's bucket resolution (2× per bucket) cannot express a
//!   1.5× bound.

use blink::eval::interference::{run_live_cell, CellSpec, LiveParams};

fn params() -> LiveParams {
    LiveParams {
        requests: 8,
        input_tokens: 32,
        output_tokens: 80,
        // Heavy enough that OS noise is a small fraction of every
        // iteration; light enough that all four cells finish in a few
        // seconds.
        decode_step_us: 800.0,
        prefill_us_per_token: 20.0,
        expert_dispatch_us: 0.0,
        // The host baseline's orchestration: an 8 MB scratch heap walked
        // with a 300k-touch dependent chain is ≥ 1 ms of genuinely
        // memory-bound work per step on any current machine — the
        // antagonist multiplies exactly this.
        scratch_mb: 8,
        touches_per_step: 300_000,
        seed: 42,
    }
}

#[test]
fn host_placement_collapses_under_antagonist_while_gpu_holds() {
    let p = params();
    let cell = |host: bool, intensity: f64| {
        let c = run_live_cell(&CellSpec { moe: false, host, intensity }, &p);
        assert!(c.iter_p99_us > 0.0, "cell host={host} i={intensity} recorded no iterations");
        c
    };

    let gpu_iso = cell(false, 0.0);
    let gpu_hot = cell(false, 1.0);
    let host_iso = cell(true, 0.0);
    let host_hot = cell(true, 1.0);

    let gpu_ratio = gpu_hot.iter_p99_us / gpu_iso.iter_p99_us;
    let host_ratio = host_hot.iter_p99_us / host_iso.iter_p99_us;

    // The paper's Fig 1 shape, as ratios: the host-driven control loop
    // collapses under contention (≥3×; expect ~5–15× here), the
    // device-plane loop has no host work on its critical path and holds
    // (<1.5×; expect ~1.0×).
    assert!(
        host_ratio >= 3.0,
        "host-driven P99 iteration must inflate >=3x under max antagonist intensity: \
         {:.1} -> {:.1} µs ({host_ratio:.2}x)",
        host_iso.iter_p99_us,
        host_hot.iter_p99_us,
    );
    assert!(
        gpu_ratio < 1.5,
        "device-plane P99 iteration must hold <1.5x under max antagonist intensity: \
         {:.1} -> {:.1} µs ({gpu_ratio:.2}x)",
        gpu_iso.iter_p99_us,
        gpu_hot.iter_p99_us,
    );

    // And the cross-placement gap under contention is the product story:
    // the contended host loop is far slower than the contended device
    // loop even though both run the identical executor cost model.
    assert!(
        host_hot.iter_p99_us > 2.0 * gpu_hot.iter_p99_us,
        "contended host loop ({:.1} µs) should dwarf the contended device loop ({:.1} µs)",
        host_hot.iter_p99_us,
        gpu_hot.iter_p99_us,
    );
}

#[test]
fn moe_cells_run_and_pay_the_dispatch_tax() {
    // The sparse path is servable end-to-end: the MoE manifest runs the
    // same pipeline, and its decode iterations carry the expert-dispatch
    // cost (deterministic spin, so the median comparison is stable).
    let mut p = params();
    p.output_tokens = 24;
    p.expert_dispatch_us = 200.0;
    let dense = run_live_cell(&CellSpec { moe: false, host: false, intensity: 0.0 }, &p);
    let moe = run_live_cell(&CellSpec { moe: true, host: false, intensity: 0.0 }, &p);
    assert!(moe.tok_per_s > 0.0, "moe cell must complete its requests");
    // 8 lanes of top-2-of-4 routing activate ~4 experts ⇒ ~800 µs of
    // dispatch on top of the 800 µs step: ≥1.5× the dense median leaves
    // wide noise margin.
    assert!(
        moe.iter_p50_us > 1.5 * dense.iter_p50_us,
        "expert dispatch must show up in MoE iteration cost: moe {:.1} µs vs dense {:.1} µs",
        moe.iter_p50_us,
        dense.iter_p50_us,
    );
}
