//! Integration: ring buffer -> persistent scheduler -> executor -> tokens,
//! under both placements. Requires `make artifacts`.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use blink::gpu::{Executor, Placement, Scheduler, SchedulerConfig};
use blink::ringbuf::{RingBuffer, RingConfig, SlotState};
use blink::runtime::{artifacts_dir, ModelManifest};

fn setup(placement: Placement) -> Option<(Arc<RingBuffer>, Scheduler)> {
    let dir = artifacts_dir();
    if !dir.join("blink-tiny/manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let manifest = ModelManifest::load(&dir.join("blink-tiny/manifest.txt")).unwrap();
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 64,
        max_prompt: 256,
        max_output: 128,
    }));
    let executor = Executor::spawn(dir, "blink-tiny".into()).expect("executor");
    let sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest,
        SchedulerConfig { placement, apply_launch_delays: false, ..Default::default() },
    );
    Some((ring, sched))
}

fn submit(ring: &RingBuffer, slot: usize, prompt: &[u32], max_new: u32) {
    assert!(ring.claim_for_write(slot));
    ring.write_prompt(slot, prompt);
    ring.submit(slot, slot as u64, prompt.len() as u32, max_new, 7);
}

fn wait_done(ring: &RingBuffer, slots: &[usize], timeout: Duration) {
    let t = Instant::now();
    loop {
        let done = slots
            .iter()
            .all(|&s| matches!(ring.slot(s).state(), SlotState::DecodeCompleted | SlotState::Failed));
        if done {
            return;
        }
        assert!(t.elapsed() < timeout, "timed out waiting for completion");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn serves_batch_of_requests_gpu_resident() {
    let Some((ring, mut sched)) = setup(Placement::GpuResident) else { return };
    let slots: Vec<usize> = (0..5).collect();
    for &s in &slots {
        let prompt: Vec<u32> = (0..10 + s as u32).map(|i| (i * 13 + 5) % 2048).collect();
        submit(&ring, s, &prompt, 8);
    }
    wait_done(&ring, &slots, Duration::from_secs(120));
    for &s in &slots {
        assert_eq!(ring.slot(s).state(), SlotState::DecodeCompleted, "slot {s}");
        let n = ring.slot(s).generated.load(Ordering::Acquire);
        assert!(n >= 1 && n <= 8, "slot {s} generated {n}");
        let toks = ring.read_tokens(s, 0, n);
        assert!(toks.iter().all(|&t| t < 2048));
    }
    sched.drain_and_stop();
    let st = &sched.stats;
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 5);
    assert!(st.decode_steps.load(Ordering::Relaxed) >= 1);
    assert!(st.tokens_generated.load(Ordering::Relaxed) >= 5);
    println!("stats: {}", st.summary());
}

#[test]
fn serves_requests_cpu_resident_baseline() {
    let Some((ring, mut sched)) =
        setup(Placement::CpuResident { scratch_mb: 2, touches_per_step: 1000 })
    else {
        return;
    };
    for s in 0..3 {
        let prompt: Vec<u32> = (0..12).map(|i| (i * 7 + s as u32) % 2048).collect();
        submit(&ring, s, &prompt, 4);
    }
    wait_done(&ring, &[0, 1, 2], Duration::from_secs(120));
    for s in 0..3 {
        assert_eq!(ring.slot(s).state(), SlotState::DecodeCompleted);
    }
    sched.drain_and_stop();
    assert_eq!(sched.stats.completed_requests.load(Ordering::Relaxed), 3);
}

#[test]
fn rejects_oversized_prompt() {
    let Some((ring, mut sched)) = setup(Placement::GpuResident) else { return };
    // max prefill seq for blink-tiny is 256; ring arena cap is 256 -> craft
    // a prompt longer than the largest prefill graph via prompt_len spoof:
    // write 256 tokens but submit len 300 is blocked by arena... use 257?
    // Arena cap is 256, so use a 256-token prompt with max grid 256: valid.
    // Instead spoof an empty prompt (len 0) which must fail.
    assert!(ring.claim_for_write(0));
    ring.write_prompt(0, &[]);
    ring.submit(0, 0, 0, 4, 7);
    wait_done(&ring, &[0], Duration::from_secs(60));
    assert_eq!(ring.slot(0).state(), SlotState::Failed);
    sched.drain_and_stop();
    assert_eq!(sched.stats.failed_requests.load(Ordering::Relaxed), 1);
}

#[test]
fn continuous_batching_admits_mid_flight() {
    let Some((ring, mut sched)) = setup(Placement::GpuResident) else { return };
    // Long-running first request, then a burst mid-flight.
    submit(&ring, 0, &[1, 2, 3, 4, 5, 6, 7, 8], 64);
    std::thread::sleep(Duration::from_millis(300));
    for s in 1..4 {
        submit(&ring, s, &[9, 8, 7, 6, 5], 8);
    }
    wait_done(&ring, &[0, 1, 2, 3], Duration::from_secs(180));
    sched.drain_and_stop();
    let st = &sched.stats;
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 4);
    // Mean occupancy > 1 proves the burst shared decode steps with slot 0.
    assert!(
        st.mean_batch_occupancy() > 1.01,
        "no batching observed: occupancy {}",
        st.mean_batch_occupancy()
    );
    println!("stats: {}", st.summary());
}
