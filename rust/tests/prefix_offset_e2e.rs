//! Scheduler-level offset-prefill tests on the *modeled* executor: the
//! full pipeline (ring scan → admission → prefix index → planner →
//! launcher → completion) runs without artifacts or PJRT, so these —
//! unlike `scheduler_e2e.rs` — never skip. The headline assertion is the
//! PR's acceptance criterion: with offset graphs in the manifest, a
//! second-turn request with a ≥50 % block-aligned prefix hit launches a
//! `prefill_offset` graph covering only the uncached suffix.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use blink::gpu::{Executor, ModeledCost, PrefixReuse, Scheduler, SchedulerConfig};
use blink::ringbuf::{RingBuffer, RingConfig, SlotState};
use blink::runtime::ModelManifest;

/// A manifest for the modeled executor. `offset_seqs` controls the
/// offset-prefill grid: empty = artifacts without offset graphs (reuse
/// must auto-disable), partial = fallback coverage.
fn manifest(offset_seqs: &[usize]) -> ModelManifest {
    let mut text = String::from(
        "blink-manifest v1\nmodel modeled-test\nvocab_size 2048\nd_model 64\nn_layers 2\n\
         n_heads 4\nn_kv_heads 2\nd_head 16\nd_ff 128\nblock_size 16\nnum_blocks 64\n\
         max_blocks_per_seq 16\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
         param tok_embed 2048x64 f32\n",
    );
    for b in [1usize, 2, 4, 8] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0\n"));
    }
    for b in [1usize, 2, 4] {
        for s in [16usize, 32, 64, 128] {
            text.push_str(&format!("graph prefill_b{b}_s{s} prefill {b} {s}\n"));
        }
    }
    for b in [1usize, 2, 4] {
        for &s in offset_seqs {
            text.push_str(&format!("graph prefill_offset_b{b}_s{s} prefill_offset {b} {s}\n"));
        }
    }
    ModelManifest::parse(&text).expect("modeled test manifest")
}

fn start(
    m: &ModelManifest,
    prefix_reuse: PrefixReuse,
    prefill_chunk_tokens: Option<usize>,
) -> (Arc<RingBuffer>, Scheduler) {
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 64,
        max_prompt: 256,
        max_output: 64,
    }));
    let executor = Executor::spawn_modeled(m, ModeledCost::zero());
    let sched = Scheduler::spawn(
        ring.clone(),
        executor,
        m.clone(),
        SchedulerConfig {
            apply_launch_delays: false,
            prefix_reuse,
            prefill_chunk_tokens,
            ..Default::default()
        },
    );
    (ring, sched)
}

fn submit(ring: &RingBuffer, slot: usize, prompt: &[u32], max_new: u32) {
    assert!(ring.claim_for_write(slot));
    ring.write_prompt(slot, prompt);
    ring.submit(slot, slot as u64, prompt.len() as u32, max_new, slot as u32);
}

fn wait_done(ring: &RingBuffer, slots: &[usize]) {
    let t = Instant::now();
    loop {
        let done = slots.iter().all(|&s| {
            matches!(ring.slot(s).state(), SlotState::DecodeCompleted | SlotState::Failed)
        });
        if done {
            return;
        }
        assert!(t.elapsed() < Duration::from_secs(60), "timed out waiting for completion");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn prompt_of(len: usize, tag: u32) -> Vec<u32> {
    (0..len as u32).map(|i| (i * 13 + tag * 101 + 5) % 2048).collect()
}

/// Acceptance criterion: offset graphs in the manifest + a second turn
/// whose first 64 of 96 tokens (67 %, block-aligned) are cached ⇒ the
/// scheduler launches a `prefill_offset` graph sized to the 32-token
/// suffix, reusing 64 tokens from the index.
#[test]
fn second_turn_hit_launches_offset_graph_for_suffix_only() {
    let m = manifest(&[16, 32, 64, 128]);
    let (ring, mut sched) = start(&m, PrefixReuse::Auto, None);

    // Turn 1: cold 64-token prompt (4 full blocks indexed on success).
    let first = prompt_of(64, 1);
    submit(&ring, 0, &first, 4);
    wait_done(&ring, &[0]);
    assert_eq!(ring.slot(0).state(), SlotState::DecodeCompleted);

    // Turn 2: the same 64 tokens + 32 new ones.
    let mut second = first.clone();
    second.extend(prompt_of(32, 2).iter().map(|t| t + 1));
    submit(&ring, 1, &second, 4);
    wait_done(&ring, &[1]);
    assert_eq!(ring.slot(1).state(), SlotState::DecodeCompleted);
    sched.drain_and_stop();

    let st = &sched.stats;
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 2);
    assert_eq!(st.prefix_hits.load(Ordering::Relaxed), 1, "turn 2 must hit the index");
    assert_eq!(
        st.prefix_hit_tokens.load(Ordering::Relaxed),
        64,
        "the whole block-aligned shared prefix is served from cache"
    );
    assert_eq!(
        st.prefill_offset_batches.load(Ordering::Relaxed),
        1,
        "turn 2 prefills through an offset graph"
    );
    assert_eq!(
        st.prefill_batches.load(Ordering::Relaxed),
        2,
        "one full prefill (turn 1) + one offset prefill (turn 2)"
    );
    assert_eq!(st.prefix_fallback_full.load(Ordering::Relaxed), 0);
    // Tokens flowed end to end.
    let n = ring.slot(1).generated.load(Ordering::Acquire);
    assert_eq!(n, 4);
    assert!(ring.read_tokens(1, 0, n).iter().all(|&t| t < 2048));
}

/// Without offset graphs in the artifacts, `Auto` reuse must resolve to
/// the paper's cold behavior: identical two-turn traffic produces no
/// hits, no offset launches — and correct results.
#[test]
fn auto_reuse_stays_cold_without_offset_graphs() {
    let m = manifest(&[]);
    let (ring, mut sched) = start(&m, PrefixReuse::Auto, None);
    let first = prompt_of(64, 3);
    submit(&ring, 0, &first, 4);
    wait_done(&ring, &[0]);
    let mut second = first.clone();
    second.extend(prompt_of(32, 4));
    submit(&ring, 1, &second, 4);
    wait_done(&ring, &[1]);
    sched.drain_and_stop();

    let st = &sched.stats;
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 2);
    assert_eq!(st.prefix_hits.load(Ordering::Relaxed), 0, "no offset graphs → no live reuse");
    assert_eq!(st.prefill_offset_batches.load(Ordering::Relaxed), 0);
}

/// Forced-on reuse with a *partial* offset grid: a hit whose suffix is
/// off the grid is demoted to a full cold prefill (counted, correct,
/// no offset launch) — the graceful-fallback path end to end.
#[test]
fn offgrid_suffix_falls_back_to_full_prefill_live() {
    let m = manifest(&[16]); // suffixes ≤ 16 only
    // Chunking off: with the default budget (= the grid's largest
    // offset seq, 16 here) the off-grid 32-token suffix would *chunk*
    // through two offset launches instead of falling back — this test
    // pins the chunking-disabled demotion path.
    let (ring, mut sched) = start(&m, PrefixReuse::On, Some(0));
    let first = prompt_of(64, 5);
    submit(&ring, 0, &first, 4);
    wait_done(&ring, &[0]);
    // Suffix of 32 > the grid's 16: must fall back.
    let mut second = first.clone();
    second.extend(prompt_of(32, 6));
    submit(&ring, 1, &second, 4);
    wait_done(&ring, &[1]);
    assert_eq!(ring.slot(1).state(), SlotState::DecodeCompleted);
    // Suffix of 16 fits: offset path.
    let mut third = first.clone();
    third.extend(prompt_of(16, 7));
    submit(&ring, 2, &third, 4);
    wait_done(&ring, &[2]);
    assert_eq!(ring.slot(2).state(), SlotState::DecodeCompleted);
    sched.drain_and_stop();

    let st = &sched.stats;
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 3);
    assert_eq!(st.prefix_fallback_full.load(Ordering::Relaxed), 1, "turn 2 fell back");
    assert_eq!(st.prefill_offset_batches.load(Ordering::Relaxed), 1, "turn 3 used the grid");
    assert_eq!(st.prefix_hits.load(Ordering::Relaxed), 1, "only the on-grid hit reserves reuse");
}

/// The modeled executor carries ordinary (cold, batched, continuous)
/// traffic through the whole pipeline — scheduler-level coverage that
/// used to exist only when artifacts were built.
#[test]
fn modeled_executor_serves_concurrent_batch() {
    let m = manifest(&[16, 32, 64, 128]);
    let (ring, mut sched) = start(&m, PrefixReuse::Auto, None);
    let slots: Vec<usize> = (0..6).collect();
    for &s in &slots {
        submit(&ring, s, &prompt_of(10 + s, 10 + s as u32), 8);
    }
    wait_done(&ring, &slots);
    sched.drain_and_stop();
    let st = &sched.stats;
    assert_eq!(st.completed_requests.load(Ordering::Relaxed), 6);
    assert!(st.decode_steps.load(Ordering::Relaxed) >= 7, "8 tokens each → ≥7 decode steps");
    for &s in &slots {
        assert_eq!(ring.slot(s).state(), SlotState::DecodeCompleted, "slot {s}");
        let n = ring.slot(s).generated.load(Ordering::Acquire);
        assert_eq!(n, 8, "modeled tokens never hit EOS");
        assert!(ring.read_tokens(s, 0, n).iter().all(|&t| t < 2048));
    }
}
