//! Whole-system integration: DPU frontend -> RDMA -> ring buffer ->
//! persistent scheduler -> executor -> token reader -> SSE-ready events,
//! plus the HTTP/OpenAI surface. Requires `make artifacts`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use blink::http::HttpServer;
use blink::server::{BlinkServer, ServerConfig};

fn server_or_skip() -> Option<BlinkServer> {
    if !blink::runtime::artifacts_dir().join("blink-tiny/manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    Some(BlinkServer::start(ServerConfig::default()).expect("server start"))
}

#[test]
fn full_stack_generate_and_stream() {
    let Some(server) = server_or_skip() else { return };

    // Several concurrent requests through the DPU plane.
    let handles: Vec<_> = (0..4)
        .map(|i| {
            server
                .submit_text(
                    &format!("the quick brown fox {i} jumps over the lazy dog"),
                    12,
                )
                .expect("submit")
        })
        .collect();
    for h in handles {
        let slot = h.slot;
        let toks = h.collect().expect("generation");
        assert!(!toks.is_empty() && toks.len() <= 12, "slot {slot}: {} tokens", toks.len());
        assert!(toks.iter().all(|&t| t < server.manifest.vocab_size as u32));
    }

    // RDMA engine really carried the traffic.
    let (ops, bytes) = server.rdma.stats();
    assert!(ops > 8, "rdma ops {ops}");
    assert!(bytes > 0);
    server.shutdown();
}

#[test]
fn http_api_completion_and_sse() {
    let Some(server) = server_or_skip() else { return };
    let http = HttpServer::serve(
        "127.0.0.1:0",
        server.frontend.clone(),
        server.scheduler.stats.clone(),
    )
    .expect("http bind");
    let addr = http.addr;

    // Non-streaming completion.
    let body = r#"{"prompt": "hello world from the ring buffer", "max_tokens": 8}"#;
    let resp = http_post(addr, "/v1/completions", body);
    assert!(resp.starts_with("HTTP/1.1 200"), "resp: {resp}");
    assert!(resp.contains("text_completion"), "resp: {resp}");
    assert!(resp.contains("completion_tokens"), "resp: {resp}");

    // Streaming (SSE) completion.
    let body = r#"{"prompt": "stream me", "max_tokens": 5, "stream": true}"#;
    let resp = http_post(addr, "/v1/completions", body);
    assert!(resp.contains("text/event-stream"), "resp: {resp}");
    assert!(resp.contains("data: "), "resp: {resp}");
    assert!(resp.trim_end().ends_with("data: [DONE]"), "resp: {resp}");

    // Health + metrics.
    let h = http_get(addr, "/health");
    assert!(h.contains("\"ok\""));
    let m = http_get(addr, "/metrics");
    assert!(m.contains("decode_steps="), "metrics: {m}");

    // Bad request handling.
    let bad = http_post(addr, "/v1/completions", "{not json");
    assert!(bad.starts_with("HTTP/1.1 400"), "resp: {bad}");

    drop(http);
    server.shutdown();
}

#[test]
fn http_session_accumulates_multi_turn_history() {
    let Some(server) = server_or_skip() else { return };
    let http = HttpServer::serve(
        "127.0.0.1:0",
        server.frontend.clone(),
        server.scheduler.stats.clone(),
    )
    .expect("http bind");
    let addr = http.addr;

    // Turn 1 opens the session; turn 2 submits only its new text.
    let body = r#"{"prompt": "the quick brown fox", "max_tokens": 4, "session_id": "conv-1"}"#;
    let resp = http_post(addr, "/v1/completions", body);
    assert!(resp.starts_with("HTTP/1.1 200"), "resp: {resp}");
    let after_turn1 = server.frontend.session_history_len("conv-1");
    assert!(after_turn1 > 0, "turn 1 must seed the session history");

    let body = r#"{"prompt": " jumps over", "max_tokens": 4, "session_id": "conv-1"}"#;
    let resp = http_post(addr, "/v1/completions", body);
    assert!(resp.starts_with("HTTP/1.1 200"), "resp: {resp}");
    // Turn 2's prompt carried the full history: prompt_tokens in the
    // usage block must exceed what " jumps over" alone tokenizes to.
    let after_turn2 = server.frontend.session_history_len("conv-1");
    assert!(
        after_turn2 > after_turn1,
        "history must grow across turns: {after_turn1} -> {after_turn2}"
    );
    // The GPU plane saw the session tag on both admissions.
    let session_reqs = server
        .scheduler
        .stats
        .session_requests
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(session_reqs >= 2, "scheduler must attribute session turns: {session_reqs}");

    // An invalid session_id type is rejected, not silently dropped.
    let bad = http_post(
        addr,
        "/v1/completions",
        r#"{"prompt": "x", "max_tokens": 2, "session_id": 7}"#,
    );
    assert!(bad.starts_with("HTTP/1.1 400"), "resp: {bad}");

    drop(http);
    server.shutdown();
}

fn http_post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}
