//! Tier-1 gate: `blink-lint` must run clean over `rust/src`.
//!
//! This is the enforcement point for DESIGN.md §10 — every atomic in
//! the six protocol modules carries an explicit ordering contract,
//! every contract's use sites conform tree-wide, release/acquire pairs
//! have counterparts, tagged hot paths stay allocation- and
//! panic-free, and every `unsafe` carries a SAFETY comment. A fresh
//! atomic field, a weakened ordering, or a stray `format!` in the
//! decode loop fails this test, not a human reviewer.

#[test]
fn repo_lints_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = blink_lint::run(root).expect("blink-lint over rust/src");
    assert!(
        report.clean(),
        "blink-lint violations (fix them or add a reasoned allow.toml entry):\n{}",
        blink_lint::render_human(&report)
    );
}

#[test]
fn contract_coverage_does_not_shrink() {
    // A clean report is only meaningful if the contracts are actually
    // there — deleting every annotation would also "pass". Pin floors
    // just under the current counts (~88 contracts / ~247 checked use
    // sites / 97 atomic declarations after the speculative-decoding
    // counters landed; the gate before this PR pinned 86/241/95).
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = blink_lint::run(root).expect("blink-lint over rust/src");
    assert!(report.contracts >= 82, "contract registry shrank: {}", report.contracts);
    assert!(report.uses >= 210, "checked atomic use sites shrank: {}", report.uses);
    assert!(report.decls >= 92, "atomic declarations shrank: {}", report.decls);
}
