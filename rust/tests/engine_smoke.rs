//! Integration: load the AOT artifacts, run prefill + decode end to end.
//! Requires `make artifacts` (skipped with a clear message otherwise).

use blink::graphs::GraphKind;
use blink::runtime::{artifacts_dir, Engine};

fn engine_or_skip(model: &str) -> Option<Engine> {
    let dir = artifacts_dir();
    if !dir.join(model).join("manifest.txt").exists() {
        eprintln!("skipping: artifacts for {model} not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&dir, model).expect("engine load"))
}

#[test]
fn prefill_then_decode_roundtrip() {
    let Some(mut eng) = engine_or_skip("blink-tiny") else { return };
    let m = eng.manifest.clone();
    let mbs = m.max_blocks_per_seq;

    // One prompt of 10 tokens padded to 16, blocks [1, 2] reserved.
    let g = eng.cache.select_prefill(1, 16).expect("prefill graph");
    assert_eq!(eng.cache.spec(g).kind, GraphKind::Prefill);
    let mut bt = vec![0i32; mbs];
    bt[0] = 1;
    bt[1] = 2;
    let prompt: Vec<i32> = (0..16).map(|i| (i * 7 + 3) % m.vocab_size as i32).collect();
    let first = eng.execute(g, &bt, &[10], &prompt, &[], 42).expect("prefill exec");
    assert_eq!(first.len(), 1);
    assert!((0..m.vocab_size as i32).contains(&first[0]));

    // Decode a few tokens; seq_lens counts cached tokens.
    let d = eng.cache.select_decode(1).expect("decode graph");
    let mut tok = first[0];
    let mut len = 10i32;
    for step in 0..4u32 {
        let out = eng.execute(d, &bt, &[len], &[tok], &[], 100 + step).expect("decode exec");
        assert_eq!(out.len(), 1);
        assert!((0..m.vocab_size as i32).contains(&out[0]));
        tok = out[0];
        len += 1;
    }
    assert_eq!(eng.steps, 5);
}

#[test]
fn generation_is_deterministic_given_seeds() {
    let Some(mut eng) = engine_or_skip("blink-tiny") else { return };
    let m = eng.manifest.clone();
    let mbs = m.max_blocks_per_seq;
    let g = eng.cache.select_prefill(1, 16).unwrap();
    let d = eng.cache.select_decode(1).unwrap();
    let mut bt = vec![0i32; mbs];
    bt[0] = 3;
    bt[1] = 4;
    let prompt: Vec<i32> = (0..16).map(|i| (i * 5 + 1) % 2048).collect();

    let mut run = |eng: &mut Engine| -> Vec<i32> {
        eng.reset_kv().unwrap();
        let mut toks = eng.execute(g, &bt, &[12], &prompt, &[], 7).unwrap();
        let mut len = 12;
        for s in 0..6u32 {
            let t = eng.execute(d, &bt, &[len], &[*toks.last().unwrap()], &[], 1000 + s).unwrap();
            toks.push(t[0]);
            len += 1;
        }
        toks
    };
    let a = run(&mut eng);
    let b = run(&mut eng);
    assert_eq!(a, b, "same seeds must replay identically");
}

#[test]
fn batched_decode_matches_singleton_lanes() {
    // Lanes are independent: decoding two sequences in one batch must give
    // the same tokens as decoding each alone (same seed convention: the
    // graph derives per-lane uniforms from (seed, lane), so we compare
    // against a batch-of-2 with duplicated lane 0).
    let Some(mut eng) = engine_or_skip("blink-tiny") else { return };
    let m = eng.manifest.clone();
    let mbs = m.max_blocks_per_seq;
    let g = eng.cache.select_prefill(2, 16).expect("prefill b2");
    // Two identical prompts in different blocks.
    let mut bt = vec![0i32; 2 * mbs];
    bt[0] = 5;
    bt[1] = 6;
    bt[mbs] = 7;
    bt[mbs + 1] = 8;
    let prompt: Vec<i32> = (0..16).map(|i| (i * 11 + 2) % 2048).collect();
    let both: Vec<i32> = prompt.iter().chain(prompt.iter()).copied().collect();
    let first = eng.execute(g, &bt, &[10, 10], &both, &[], 9).unwrap();
    assert_eq!(first.len(), 2);
    // Identical inputs at identical positions with per-lane independent
    // uniforms: lanes may differ in sampled token, but both must be valid.
    for t in &first {
        assert!((0..m.vocab_size as i32).contains(t));
    }
}
