//! Integration: ring buffer → candidate snapshot → admission policy,
//! exercising the scheduler pipeline's first three stages against a real
//! ring (no artifacts needed — the executor stages are covered by
//! `scheduler_e2e.rs`). Extends `scan_claims_in_fcfs_ticket_order`: the
//! ring scan stays FCFS no matter what class metadata rides along; the
//! *policy* stage is where reordering happens, and only for the
//! non-FCFS policies.

use std::sync::atomic::Ordering;

use blink::gpu::policy::{
    AdmissionPolicy, Candidate, Fcfs, PriorityAged, ShortestPromptFirst, SloAware,
};
use blink::ringbuf::{RingBuffer, RingConfig, SubmitMeta};
use blink::util::prop::run_prop;
use blink::util::rng::Rng;

fn ring() -> RingBuffer {
    RingBuffer::new(RingConfig { num_slots: 64, max_prompt: 64, max_output: 16 })
}

fn submit(ring: &RingBuffer, slot: usize, prompt_len: u32, priority: u32, budget_us: u64) -> u64 {
    assert!(ring.claim_for_write(slot));
    let prompt: Vec<u32> = (0..prompt_len).collect();
    ring.write_prompt(slot, &prompt);
    ring.submit_with_meta(
        slot,
        &SubmitMeta {
            request_id: slot as u64,
            prompt_len,
            max_new: 4,
            seed: 0,
            priority,
            ttft_budget_us: budget_us,
            session_id: 0,
        },
    )
}

/// Scrambled slot order + adversarial class metadata: FCFS admission
/// must still follow submission tickets exactly.
#[test]
fn fcfs_preserves_ticket_order_under_scrambled_submission() {
    let rb = ring();
    let mut rng = Rng::new(0xF1F0);
    let mut slots: Vec<usize> = (0..32).collect();
    rng.shuffle(&mut slots);
    let mut expected: Vec<(u64, usize)> = vec![];
    for &s in &slots {
        // Priorities and deadlines chosen to *disagree* with ticket order.
        let ticket = submit(&rb, s, 1 + (s as u32 % 17), 7 - (s as u32 % 8).min(7), 1_000);
        expected.push((ticket, s));
    }
    expected.sort_unstable();

    let pending = rb.scan_pending();
    let mut cands = Candidate::collect(&rb, &pending);
    Fcfs.order(&mut cands, blink::util::timer::now_us());
    let got: Vec<usize> = cands.iter().map(|c| c.slot).collect();
    let want: Vec<usize> = expected.iter().map(|(_, s)| *s).collect();
    assert_eq!(got, want, "fcfs must reproduce submission ticket order");

    // And the claim path (scan_and_claim) agrees.
    assert_eq!(rb.scan_and_claim(256, 64), want);
}

#[test]
fn candidates_carry_class_metadata_from_the_ring() {
    let rb = ring();
    submit(&rb, 3, 17, 5, 250_000);
    let cands = Candidate::collect(&rb, &rb.scan_pending());
    assert_eq!(cands.len(), 1);
    let c = cands[0];
    assert_eq!(c.slot, 3);
    assert_eq!(c.priority, 5);
    assert_eq!(c.prompt_len, 17);
    let s = rb.slot(3);
    assert_eq!(c.submit_time_us, s.submit_time_us.load(Ordering::Relaxed));
    assert_eq!(c.ttft_deadline_us, c.submit_time_us + 250_000);
}

#[test]
fn priority_aged_reorders_ring_candidates_by_class() {
    let rb = ring();
    // Submit low-priority first (earlier tickets), then high-priority.
    for s in 0..4 {
        submit(&rb, s, 8, 0, 0);
    }
    for s in 4..6 {
        submit(&rb, s, 8, 6, 0);
    }
    let mut cands = Candidate::collect(&rb, &rb.scan_pending());
    PriorityAged::default().order(&mut cands, blink::util::timer::now_us());
    let order: Vec<usize> = cands.iter().map(|c| c.slot).collect();
    assert_eq!(&order[..2], &[4, 5], "high-priority submissions jump ahead");
    assert_eq!(&order[2..], &[0, 1, 2, 3], "FCFS within the low-priority class");
}

#[test]
fn sjf_and_slo_rank_ring_candidates_as_documented() {
    let rb = ring();
    submit(&rb, 0, 40, 0, 0); // long prompt, no deadline
    submit(&rb, 1, 4, 0, 0); // short prompt, no deadline
    submit(&rb, 2, 20, 0, 10_000); // tight deadline
    let now = blink::util::timer::now_us();

    let mut cands = Candidate::collect(&rb, &rb.scan_pending());
    ShortestPromptFirst.order(&mut cands, now);
    assert_eq!(cands.iter().map(|c| c.slot).collect::<Vec<_>>(), vec![1, 2, 0]);

    let mut cands = Candidate::collect(&rb, &rb.scan_pending());
    SloAware::default().order(&mut cands, now);
    assert_eq!(cands[0].slot, 2, "tight deadline first under slo-aware");
}

/// Pipeline-level anti-starvation property (the policy-unit variant
/// lives in `gpu::policy`): randomized submissions through the *ring*,
/// ranked at a future clock — every candidate older than the starvation
/// cap precedes every younger one.
#[test]
fn prop_ring_candidates_respect_starvation_cap() {
    let p = PriorityAged::default();
    run_prop("ring_starvation_cap", 0x51A7, 40, |rng| {
        let rb = ring();
        let n = 2 + rng.below(20) as usize;
        for s in 0..n {
            submit(
                &rb,
                s,
                1 + rng.below(60) as u32,
                rng.below(8) as u32,
                if rng.below(2) == 0 { 0 } else { 1_000 + rng.below(1 << 20) },
            );
        }
        let mut cands = Candidate::collect(&rb, &rb.scan_pending());
        // Evaluate at a virtual future clock so a random subset of the
        // submissions has crossed the starvation cap.
        let base = blink::util::timer::now_us();
        let now = base + rng.below(2 * p.starvation_cap_us);
        p.order(&mut cands, now);
        let starved = cands.iter().filter(|c| c.age_us(now) >= p.starvation_cap_us).count();
        for (i, c) in cands.iter().enumerate() {
            assert_eq!(
                c.age_us(now) >= p.starvation_cap_us,
                i < starved,
                "starved candidates must form the admission prefix"
            );
        }
    });
}
