//! Zero-allocation regression test for the steady-state control loop
//! (the PR's acceptance criterion, DESIGN.md §5 "Persistent batch
//! state"): with the counting global allocator installed, N consecutive
//! steady-state decode iterations on the modeled executor perform **0**
//! heap allocations — the launch inputs live in the persistent arena and
//! are updated in place, the scan / snapshot / poll paths fill
//! scheduler-owned scratches, and the doorbell launch has no queue to
//! grow. Admission + retirement are measured separately and asserted
//! *bounded* (they allocate — prompt reads, sequence staging — but per
//! request, never per iteration).
//!
//! The allocator counts every thread in the process. During the measured
//! window only three threads run — this test thread (sleeping in a poll
//! loop), the scheduler and the modeled executor — so a nonzero delta
//! can only come from the control loop or the executor's launch path,
//! which is exactly what the test is pinning.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use blink::gpu::{Executor, ModeledCost, PrefixReuse, Scheduler, SchedulerConfig};
use blink::ringbuf::{RingBuffer, RingConfig, SlotState};
use blink::runtime::ModelManifest;
use blink::util::alloc::{alloc_count, CountingAlloc};

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

/// The counting allocator is process-wide, so the two zero-allocation
/// windows in this file must never overlap: a concurrently running test
/// would charge its allocations to the other's window.
static WINDOW: Mutex<()> = Mutex::new(());

/// Decode grid up to batch 8, prefill grid (b ≤ 4, s ≤ 64), no offset
/// graphs (prefix reuse stays off: admission is the cold path here).
/// `max_blocks_per_seq 64` × `block_size 16` bounds the context at 1024
/// tokens, so a 16-token prompt's `max_new` clamps to 1008 — long enough
/// that no lane retires inside the measured window.
fn manifest() -> ModelManifest {
    let mut text = String::from(
        "blink-manifest v1\nmodel hotloop-test\nvocab_size 2048\nd_model 64\nn_layers 2\n\
         n_heads 4\nn_kv_heads 2\nd_head 16\nd_ff 128\nblock_size 16\nnum_blocks 512\n\
         max_blocks_per_seq 64\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
         param tok_embed 2048x64 f32\n",
    );
    for b in [1usize, 2, 4, 8] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0\n"));
    }
    for b in [1usize, 2, 4] {
        for s in [16usize, 32, 64] {
            text.push_str(&format!("graph prefill_b{b}_s{s} prefill {b} {s}\n"));
        }
    }
    ModelManifest::parse(&text).expect("hotloop manifest")
}

fn submit(ring: &RingBuffer, slot: usize, prompt_len: usize, max_new: u32) {
    assert!(ring.claim_for_write(slot));
    let prompt: Vec<u32> = (0..prompt_len as u32).map(|i| (i * 7 + 3) % 2048).collect();
    ring.write_prompt(slot, &prompt);
    ring.submit(slot, slot as u64, prompt_len as u32, max_new, slot as u32);
}

fn wait_until(deadline: Duration, what: &str, mut cond: impl FnMut() -> bool) {
    let t = Instant::now();
    while !cond() {
        assert!(t.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn steady_state_decode_iterations_allocate_nothing() {
    let _window = WINDOW.lock().unwrap_or_else(|e| e.into_inner());
    let m = manifest();
    // A visible per-step cost paces the loop at ~100 µs/iteration:
    // plenty of iterations in the window, but a lane's 1008-token budget
    // (~100 ms of decoding) comfortably outlives it.
    let cost =
        ModeledCost { prefill_us_per_token: 1.0, decode_step_us: 100.0, ..ModeledCost::zero() };
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 16,
        max_prompt: 64,
        max_output: 2048,
    }));
    let executor = Executor::spawn_modeled(&m, cost);
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        m.clone(),
        SchedulerConfig {
            apply_launch_delays: false,
            prefix_reuse: PrefixReuse::Off,
            ..Default::default()
        },
    );
    let stats = sched.stats.clone();
    let steps = || stats.decode_steps.load(Ordering::Relaxed);

    // --- admission phase (bounded-allocation assertion) ---------------
    let before_admission = alloc_count();
    for slot in 0..4 {
        submit(&ring, slot, 16, u32::MAX); // clamps to the 1008 headroom
    }
    wait_until(Duration::from_secs(20), "all four lanes decoding", || {
        (0..4).all(|i| ring.slot(i).generated.load(Ordering::Acquire) >= 2)
    });
    let admission_allocs = alloc_count() - before_admission;
    assert!(
        admission_allocs > 0,
        "sanity: the counting allocator is installed and admission does allocate"
    );
    assert!(
        admission_allocs < 100_000,
        "admission of 4 requests must be bounded, saw {admission_allocs} allocations"
    );

    // --- warmup: let scratch capacities and the arena sync settle -----
    let warm_target = steps() + 100;
    wait_until(Duration::from_secs(20), "warmup decode steps", || steps() >= warm_target);

    // --- the measured steady-state window -----------------------------
    let a0 = alloc_count();
    let s0 = steps();
    wait_until(Duration::from_secs(20), "steady-state window", || steps() >= s0 + 400);
    let a1 = alloc_count();
    let s1 = steps();
    assert!(s1 >= s0 + 400, "window progressed ({s0} → {s1})");
    assert_eq!(
        a1 - a0,
        0,
        "steady-state decode must be allocation-free: {} heap allocations across {} iterations",
        a1 - a0,
        s1 - s0
    );

    // The summary surfaces the same counter for /metrics readers.
    assert!(stats.summary().contains("heap_allocs="), "{}", stats.summary());

    // --- post-window admission + retirement stays bounded --------------
    let a2 = alloc_count();
    submit(&ring, 4, 16, 4);
    wait_until(Duration::from_secs(20), "fifth request completes", || {
        ring.slot(4).state() == SlotState::DecodeCompleted
    });
    let churn_allocs = alloc_count() - a2;
    assert!(
        churn_allocs < 100_000,
        "admission + retirement of one request must be bounded, saw {churn_allocs}"
    );
    assert!(
        stats.batch_membership_changes.load(Ordering::Relaxed) >= 5,
        "4 admissions + 1 admission + 1 retirement were membership changes"
    );
    assert!(
        stats.loop_iter.count() >= (s1 - s0),
        "every decode iteration recorded a control-overhead sample"
    );
    assert!(stats.loop_iter_p50_us() > 0.0);

    // Hard stop: the four long lanes still hold ~900 tokens of budget
    // each; draining would serialize ~90 ms × 4 of modeled decode for no
    // additional coverage.
    sched.stop();
}

/// Same manifest shape plus a k = 4 verify grid, `eos_token` pushed out
/// of the vocab (verify outputs are always chain-scored, so an in-vocab
/// EOS could retire a lane mid-window), and a 4096-token context — at
/// ~3 accepted tokens per iteration the budget burns ~3× faster than
/// plain decode, and no lane may finish inside the measured window.
fn spec_manifest() -> ModelManifest {
    let mut text = String::from(
        "blink-manifest v1\nmodel hotloop-spec-test\nvocab_size 2048\nd_model 64\nn_layers 2\n\
         n_heads 4\nn_kv_heads 2\nd_head 16\nd_ff 128\nblock_size 16\nnum_blocks 1200\n\
         max_blocks_per_seq 256\nn_experts 0\ntop_k 0\neos_token 2048\nmoe 0\n\
         param tok_embed 2048x64 f32\n",
    );
    for b in [1usize, 2, 4, 8] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0\n"));
        text.push_str(&format!("graph decode_verify_b{b}_k4 decode_verify {b} 4\n"));
    }
    for b in [1usize, 2, 4] {
        for s in [16usize, 32, 64] {
            text.push_str(&format!("graph prefill_b{b}_s{s} prefill {b} {s}\n"));
        }
    }
    ModelManifest::parse(&text).expect("hotloop spec manifest")
}

/// The issue's acceptance criterion: speculative steady state is held to
/// the same zero-allocation bar as plain decode. Every per-iteration
/// addition — drafting into the preallocated scratch, the k-wide verify
/// staging, the w-wide poll, the variable-length prefix retire with its
/// KV tail rollback (acceptance 0.7 rejects ~30% of drafts, so
/// `truncate_tail` runs constantly), and the accepted-count sample ring
/// — must touch no heap.
#[test]
fn steady_state_speculative_decode_allocates_nothing() {
    let _window = WINDOW.lock().unwrap_or_else(|e| e.into_inner());
    let m = spec_manifest();
    let cost = ModeledCost {
        prefill_us_per_token: 1.0,
        decode_step_us: 100.0,
        verify_pos_us: 1.0,
        ..ModeledCost::zero()
    };
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 16,
        max_prompt: 64,
        max_output: 4096,
    }));
    let executor = Executor::spawn_modeled(&m, cost);
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        m.clone(),
        SchedulerConfig {
            apply_launch_delays: false,
            prefix_reuse: PrefixReuse::Off,
            spec_k: 4,
            spec_accept: 0.7,
            ..Default::default()
        },
    );
    let stats = sched.stats.clone();
    let steps = || stats.decode_steps.load(Ordering::Relaxed);

    for slot in 0..4 {
        submit(&ring, slot, 16, u32::MAX); // clamps to the 4080 headroom
    }
    wait_until(Duration::from_secs(20), "all four lanes decoding", || {
        (0..4).all(|i| ring.slot(i).generated.load(Ordering::Acquire) >= 2)
    });

    // Warmup covers scratch growth and the plain↔verify arena resync.
    let warm_target = steps() + 100;
    wait_until(Duration::from_secs(20), "warmup verify steps", || steps() >= warm_target);

    let a0 = alloc_count();
    let s0 = steps();
    let d0 = stats.spec_drafted.load(Ordering::Relaxed);
    wait_until(Duration::from_secs(20), "speculative steady-state window", || {
        steps() >= s0 + 400
    });
    let a1 = alloc_count();
    let s1 = steps();
    assert_eq!(
        a1 - a0,
        0,
        "steady-state speculative decode must be allocation-free: {} heap allocations \
         across {} iterations",
        a1 - a0,
        s1 - s0
    );
    // The window really was speculative, not a plain-decode fallback.
    let drafted = stats.spec_drafted.load(Ordering::Relaxed) - d0;
    assert!(drafted > 0, "the measured window must have drafted (saw {drafted})");
    assert!(
        stats.spec_accepted.load(Ordering::Relaxed) > 0,
        "acceptance 0.7 must accept some drafts"
    );

    sched.stop();
}
