//! The host plane and its fragility (paper §2–3).
//!
//! Two pieces:
//!
//! * [`HostOrchestrator`] — the per-step host work a CPU-resident serving
//!   stack performs (batch reassembly, block-table bookkeeping, kernel
//!   dispatch marshalling). Modeled as pointer-chasing updates over a
//!   multi-MB scratch heap: genuinely memory-bound, so *live* colocated
//!   interferers slow it through the same microarchitectural channels the
//!   paper measures (LLC + TLB contention), no parameter tuning needed.
//! * [`Interferer`] — the colocated noisy neighbor: worker threads doing
//!   pbzip2-like block compression (stream reads + rolling-hash writes
//!   over large buffers), evicting shared cache aggressively.
//!
//! The discrete-event simulator uses calibrated inflation factors instead
//! (sim::interference); this module is for *live* end-to-end runs
//! (examples/colocation.rs, Fig 3's baseline placement).

use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Host-side orchestration work, interference-sensitive by construction.
pub struct HostOrchestrator {
    scratch: Vec<u64>,
    cursor: u64,
    /// Scratch touches per orchestration step (calibrates base cost).
    touches_per_step: usize,
}

impl HostOrchestrator {
    /// `scratch_mb` ~ the resident host working set of a serving engine's
    /// scheduler (Python object soup, block tables, request dicts).
    pub fn new(scratch_mb: usize, touches_per_step: usize) -> HostOrchestrator {
        let words = scratch_mb * 1024 * 1024 / 8;
        // Fill with a pseudo-random permutation walk so accesses defeat
        // the prefetcher, like real pointer-heavy scheduler state.
        let mut rng = Rng::new(0xD15EA5E);
        let scratch = (0..words).map(|_| rng.next_u64()).collect();
        HostOrchestrator { scratch, cursor: 1, touches_per_step }
    }

    /// One decode-iteration's worth of host work: dependent loads + RMW
    /// over the scratch heap. Returns a checksum so the work can't be
    /// optimized away.
    pub fn step_work(&mut self) -> u64 {
        let n = self.scratch.len() as u64;
        let mut c = self.cursor;
        let mut acc = 0u64;
        for _ in 0..self.touches_per_step {
            let idx = (c % n) as usize;
            // Dependent chain: next index derives from loaded value.
            let v = self.scratch[idx].wrapping_add(c);
            self.scratch[idx] = v.rotate_left(7);
            acc ^= v;
            c = v | 1;
        }
        self.cursor = c;
        acc
    }

    pub fn scratch_bytes(&self) -> usize {
        self.scratch.len() * 8
    }
}

/// Live CPU interferer: `threads` workers doing compression-like passes
/// over private large buffers (the pbzip2/Ninja stand-in).
pub struct Interferer {
    stop: Arc<AtomicBool>,
    pub work_units: Arc<AtomicU64>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Interferer {
    pub fn spawn(threads: usize, buffer_mb_per_thread: usize) -> Interferer {
        let stop = Arc::new(AtomicBool::new(false));
        let work_units = Arc::new(AtomicU64::new(0));
        let mut handles = vec![];
        for t in 0..threads {
            let stop = stop.clone();
            let work = work_units.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("interferer-{t}"))
                    .spawn(move || {
                        let words = buffer_mb_per_thread * 1024 * 1024 / 8;
                        let mut buf: Vec<u64> =
                            (0..words).map(|i| (i as u64).wrapping_mul(0x9E3779B9)).collect();
                        let mut h = 0xCBF29CE484222325u64; // FNV offset
                        while !stop.load(Ordering::Relaxed) {
                            // "Compress" a block: stream read, hash, write back —
                            // maximal cache-line turnover like bzip2 block sorting.
                            for i in 0..words {
                                h = (h ^ buf[i]).wrapping_mul(0x100000001B3);
                                buf[i] = buf[i].rotate_left(13) ^ h;
                            }
                            work.fetch_add(1, Ordering::Relaxed);
                        }
                        std::hint::black_box(h);
                    })
                    .expect("spawn interferer"),
            );
        }
        Interferer { stop, work_units, handles }
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Release);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Interferer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn orchestrator_work_is_stateful() {
        let mut h = HostOrchestrator::new(1, 100);
        let a = h.step_work();
        let b = h.step_work();
        assert_ne!(a, b, "work must evolve state");
        assert_eq!(h.scratch_bytes(), 1024 * 1024);
    }

    #[test]
    fn interferer_spins_and_stops() {
        let i = Interferer::spawn(2, 1);
        let t = Instant::now();
        while i.work_units.load(Ordering::Relaxed) == 0 && t.elapsed().as_secs() < 10 {
            std::thread::yield_now();
        }
        assert!(i.work_units.load(Ordering::Relaxed) > 0);
        i.stop();
    }

    #[test]
    #[ignore] // timing-sensitive; run with --ignored on a quiet machine
    fn interference_slows_orchestrator() {
        let mut h = HostOrchestrator::new(8, 20_000);
        let t0 = Instant::now();
        for _ in 0..50 {
            std::hint::black_box(h.step_work());
        }
        let baseline = t0.elapsed();
        let inter = Interferer::spawn(std::thread::available_parallelism().unwrap().get(), 8);
        std::thread::sleep(std::time::Duration::from_millis(100));
        let t1 = Instant::now();
        for _ in 0..50 {
            std::hint::black_box(h.step_work());
        }
        let contended = t1.elapsed();
        inter.stop();
        assert!(
            contended.as_nanos() > baseline.as_nanos(),
            "contended {contended:?} <= baseline {baseline:?}"
        );
    }
}
