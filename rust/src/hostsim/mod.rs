//! The host plane and its fragility (paper §2–3).
//!
//! Two pieces:
//!
//! * [`HostOrchestrator`] — the per-step host work a CPU-resident serving
//!   stack performs (batch reassembly, block-table bookkeeping, kernel
//!   dispatch marshalling). Modeled as pointer-chasing updates over a
//!   multi-MB scratch heap: genuinely memory-bound, so *live* colocated
//!   interferers slow it through the same microarchitectural channels the
//!   paper measures (LLC + TLB contention), no parameter tuning needed.
//! * [`Interferer`] — the colocated noisy neighbor: worker threads doing
//!   pbzip2-like block compression (stream reads + rolling-hash writes
//!   over large buffers), evicting shared cache aggressively.
//!
//! The discrete-event simulator uses calibrated inflation factors instead
//! (sim::interference); this module is for *live* end-to-end runs
//! (examples/colocation.rs, Fig 3's baseline placement).

use crate::sim::interference::InterferenceProcess;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Host-side orchestration work, interference-sensitive by construction.
pub struct HostOrchestrator {
    scratch: Vec<u64>,
    cursor: u64,
    /// Scratch touches per orchestration step (calibrates base cost).
    touches_per_step: usize,
    /// Seeded modeled contention: inflates the *work* (touch count) per
    /// step instead of relying on a live antagonist's timing. `None` =
    /// isolated.
    contention: Option<Contention>,
    /// Touch count the most recent `step_work` actually performed —
    /// observable so tests can pin the contention model on work, not
    /// wall clock.
    last_step_touches: usize,
}

/// Deterministic antagonist channel: a seeded [`InterferenceProcess`]
/// sampled once per step. A live `Interferer` slows the orchestrator
/// through real LLC/TLB contention, but its effect depends on the host
/// the test runs on; this channel instead multiplies the *amount* of
/// scratch work per step by the sampled inflation factor, so time scales
/// with work deterministically and CI can assert inflation *ratios*.
struct Contention {
    process: InterferenceProcess,
    rng: Rng,
    step: u64,
}

impl HostOrchestrator {
    /// `scratch_mb` ~ the resident host working set of a serving engine's
    /// scheduler (Python object soup, block tables, request dicts).
    pub fn new(scratch_mb: usize, touches_per_step: usize) -> HostOrchestrator {
        let words = scratch_mb * 1024 * 1024 / 8;
        // Fill with a pseudo-random permutation walk so accesses defeat
        // the prefetcher, like real pointer-heavy scheduler state.
        let mut rng = Rng::new(0xD15EA5E);
        let scratch = (0..words).map(|_| rng.next_u64()).collect();
        HostOrchestrator {
            scratch,
            cursor: 1,
            touches_per_step,
            contention: None,
            last_step_touches: 0,
        }
    }

    /// Enable the deterministic contention channel: each `step_work`
    /// multiplies its touch count by a sample from a seeded
    /// [`InterferenceProcess`] with the given `mean` (≥ 1.0; 1.0 or less
    /// disables inflation). Same `(mean, seed)` ⇒ same per-step work
    /// sequence on every host.
    pub fn set_contention(&mut self, mean: f64, seed: u64) {
        let mut rng = Rng::new(seed);
        let process = InterferenceProcess::new(mean, &mut rng);
        self.contention = Some(Contention { process, rng, step: 0 });
    }

    /// One decode-iteration's worth of host work: dependent loads + RMW
    /// over the scratch heap. Returns a checksum so the work can't be
    /// optimized away.
    pub fn step_work(&mut self) -> u64 {
        let touches = match &mut self.contention {
            Some(c) => {
                // Virtual time drives the process's slow phase wander;
                // 10 ms of virtual time per step sweeps a few phase
                // periods over a thousand-iteration run.
                let t_s = c.step as f64 * 0.01;
                c.step += 1;
                let mult = c.process.sample(t_s, &mut c.rng);
                (self.touches_per_step as f64 * mult).round() as usize
            }
            None => self.touches_per_step,
        };
        self.last_step_touches = touches;
        let n = self.scratch.len() as u64;
        let mut c = self.cursor;
        let mut acc = 0u64;
        for _ in 0..touches {
            let idx = (c % n) as usize;
            // Dependent chain: next index derives from loaded value.
            let v = self.scratch[idx].wrapping_add(c);
            self.scratch[idx] = v.rotate_left(7);
            acc ^= v;
            c = v | 1;
        }
        self.cursor = c;
        acc
    }

    pub fn scratch_bytes(&self) -> usize {
        self.scratch.len() * 8
    }

    /// Touches performed by the most recent [`HostOrchestrator::step_work`]
    /// (equals `touches_per_step` when no contention is set).
    pub fn last_step_touches(&self) -> usize {
        self.last_step_touches
    }
}

/// Live CPU interferer: `threads` workers doing compression-like passes
/// over private large buffers (the pbzip2/Ninja stand-in).
pub struct Interferer {
    // lint: atomic(stop) flag
    stop: Arc<AtomicBool>,
    // lint: atomic(work_units) counter
    pub work_units: Arc<AtomicU64>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Interferer {
    pub fn spawn(threads: usize, buffer_mb_per_thread: usize) -> Interferer {
        let stop = Arc::new(AtomicBool::new(false));
        let work_units = Arc::new(AtomicU64::new(0));
        let mut handles = vec![];
        for t in 0..threads {
            let stop = stop.clone();
            let work = work_units.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("interferer-{t}"))
                    .spawn(move || {
                        let words = buffer_mb_per_thread * 1024 * 1024 / 8;
                        let mut buf: Vec<u64> =
                            (0..words).map(|i| (i as u64).wrapping_mul(0x9E3779B9)).collect();
                        let mut h = 0xCBF29CE484222325u64; // FNV offset
                        while !stop.load(Ordering::Relaxed) {
                            // "Compress" a block: stream read, hash, write back —
                            // maximal cache-line turnover like bzip2 block sorting.
                            for i in 0..words {
                                h = (h ^ buf[i]).wrapping_mul(0x100000001B3);
                                buf[i] = buf[i].rotate_left(13) ^ h;
                            }
                            work.fetch_add(1, Ordering::Relaxed);
                        }
                        std::hint::black_box(h);
                    })
                    .expect("spawn interferer"),
            );
        }
        Interferer { stop, work_units, handles }
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Release);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Interferer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn orchestrator_work_is_stateful() {
        let mut h = HostOrchestrator::new(1, 100);
        let a = h.step_work();
        let b = h.step_work();
        assert_ne!(a, b, "work must evolve state");
        assert_eq!(h.scratch_bytes(), 1024 * 1024);
    }

    #[test]
    fn contention_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut h = HostOrchestrator::new(1, 1_000);
            h.set_contention(8.0, seed);
            (0..20).map(|_| (h.step_work(), h.last_step_touches())).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "same seed ⇒ identical work sequence");
        assert_ne!(run(7), run(8), "different seed ⇒ different sequence");
    }

    #[test]
    fn step_cost_monotone_in_contention_intensity() {
        // The deterministic antagonist channel: mean step *work* (and so
        // step cost — time scales with touches) must grow monotonically
        // with contention intensity at a fixed seed. Work-based, so it
        // cannot flake on a noisy host the way wall-clock comparisons do.
        // 3700 steps × 10 ms virtual = exactly one 37 s phase period, so
        // the sinusoidal phase component averages out and the sample mean
        // calibrates to the requested multiplier.
        let mean_touches = |mean: f64| {
            let mut h = HostOrchestrator::new(1, 100);
            if mean > 1.0 {
                h.set_contention(mean, 42);
            }
            let steps = 3_700;
            let mut total = 0usize;
            for _ in 0..steps {
                std::hint::black_box(h.step_work());
                total += h.last_step_touches();
            }
            total as f64 / steps as f64
        };
        let iso = mean_touches(1.0);
        let mid = mean_touches(4.0);
        let max = mean_touches(8.0);
        assert!((iso - 100.0).abs() < 1e-9, "isolated = base touches, got {iso}");
        // Same seed ⇒ mid and max share phase + jitter draws, so the
        // ordering is structural and the means calibrate within the
        // process's jitter tolerance.
        assert!(mid > 2.0 * iso, "mid contention ≥2× base work: {mid} vs {iso}");
        assert!(max > mid, "work monotone in intensity: {max} vs {mid}");
        assert!(max > 5.0 * iso && max < 12.0 * iso, "max near 8× calibration: {max}");
    }

    #[test]
    fn orchestrator_under_live_interferer_still_makes_progress() {
        // Deterministic-seed companion to the #[ignore]d wall-clock test
        // below: with a live antagonist running, step_work's *results*
        // (checksums, state evolution) are unchanged — interference slows
        // the orchestrator but never corrupts it.
        let mut quiet = HostOrchestrator::new(1, 5_000);
        let quiet_sums: Vec<u64> = (0..10).map(|_| quiet.step_work()).collect();
        let inter = Interferer::spawn(2, 2);
        let mut contended = HostOrchestrator::new(1, 5_000);
        let contended_sums: Vec<u64> = (0..10).map(|_| contended.step_work()).collect();
        inter.stop();
        assert_eq!(quiet_sums, contended_sums, "interference affects timing, not results");
    }

    #[test]
    fn interferer_drop_joins_all_threads() {
        // Clean shutdown: dropping the interferer must join its workers,
        // not leak them. Each worker holds a clone of `work_units`; once
        // the threads have exited, ours is the only strong reference.
        let i = Interferer::spawn(3, 1);
        let wu = i.work_units.clone();
        assert_eq!(Arc::strong_count(&wu), 1 + 1 + 3, "ours + struct's + 3 workers");
        drop(i);
        assert_eq!(Arc::strong_count(&wu), 1, "threads joined and released on drop");
    }

    #[test]
    fn interferer_spins_and_stops() {
        let i = Interferer::spawn(2, 1);
        let t = Instant::now();
        while i.work_units.load(Ordering::Relaxed) == 0 && t.elapsed().as_secs() < 10 {
            std::thread::yield_now();
        }
        assert!(i.work_units.load(Ordering::Relaxed) > 0);
        i.stop();
    }

    #[test]
    #[ignore] // timing-sensitive; run with --ignored on a quiet machine
    fn interference_slows_orchestrator() {
        let mut h = HostOrchestrator::new(8, 20_000);
        let t0 = Instant::now();
        for _ in 0..50 {
            std::hint::black_box(h.step_work());
        }
        let baseline = t0.elapsed();
        let inter = Interferer::spawn(std::thread::available_parallelism().unwrap().get(), 8);
        std::thread::sleep(std::time::Duration::from_millis(100));
        let t1 = Instant::now();
        for _ in 0..50 {
            std::hint::black_box(h.step_work());
        }
        let contended = t1.elapsed();
        inter.stop();
        assert!(
            contended.as_nanos() > baseline.as_nanos(),
            "contended {contended:?} <= baseline {baseline:?}"
        );
    }
}
