//! Overload-control evaluation (DESIGN.md §9): what admission control
//! buys at 2× over-capacity load, modeled and live.
//!
//! The paper's headline tails are *pre-saturation* numbers; past
//! saturation an open-loop stack grows every queue until every deadline
//! dies. This suite shows the DPU-side gate changing that shape:
//!
//! * **modeled rows** (`overload.csv`, golden): the DES with its gate
//!   mirror ([`SimConfig`]'s `rate_limit` / `tenant_buckets` /
//!   `shed_policy`) over the mixed interactive/batch trace at ½× and 2×
//!   the ~12 req/s Blink capacity, plus a hot-tenant fairness pair.
//!   Virtual time, byte-deterministic at a fixed seed.
//! * **live rows** (`overload_live.csv`, never golden-tested): the real
//!   `DpuFrontend` gate in front of the real ring → scheduler →
//!   modeled-executor pipeline, Poisson arrivals paced in wall time.
//!   [`run_live_overload`] is shared with the tier-1 acceptance test in
//!   `tests/overload_e2e.rs`, so the collapse-vs-hold comparison runs
//!   on every machine, artifacts or not.

use std::sync::mpsc::TryRecvError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::frontend::overload::{OverloadConfig, Rejected};
use crate::frontend::token_reader::ReaderConfig;
use crate::frontend::tracker::TokenEvent;
use crate::frontend::{DpuFrontend, FrontendConfig, RequestClass, RequestHandle};
use crate::gpu::{Executor, ModeledCost, PrefixReuse, Scheduler, SchedulerConfig};
use crate::rdma::{RdmaConfig, RdmaEngine};
use crate::ringbuf::{RingBuffer, RingConfig};
use crate::runtime::ModelManifest;
use crate::sim::costmodel::LLAMA3_8B;
use crate::sim::des::{simulate, ShedPolicyCfg, SimConfig, TenantBucketCfg};
use crate::sim::systems::System;
use crate::tokenizer::Vocab;
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;
use crate::workload::ClassMix;

/// Priority at or above which the gate holds admission (matches
/// [`RequestClass::interactive`] and the gate's default floor).
pub const INTERACTIVE_PRIORITY: u32 = 4;

// ---------------------------------------------------------------------------
// Modeled rows: the DES gate mirror in virtual time (golden CSV).
// ---------------------------------------------------------------------------

/// Blink capacity reference for `ClassMix::interactive_batch` on
/// LLAMA3-8B — the policy sweep's knee; the grid's loads are ½× and 2×.
pub const MODELED_CAPACITY: f64 = 12.0;

/// One modeled scenario: a load level plus a gate configuration.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub name: &'static str,
    pub rate: f64,
    /// 0.0 = unlimited (the open-loop baseline).
    pub rate_limit: f64,
    pub shed: bool,
    pub buckets: Option<TenantBucketCfg>,
}

/// The scenario grid, in CSV row order: the ½×/2× limiter story first,
/// then the hot-tenant fairness pair.
pub fn scenario_grid() -> Vec<Scenario> {
    let hot = |capacity: f64, refill_per_s: f64| TenantBucketCfg {
        capacity,
        refill_per_s,
        tenants: 8,
        hot_share: 0.5,
    };
    vec![
        Scenario {
            name: "presat_unlimited",
            rate: MODELED_CAPACITY * 0.5,
            rate_limit: 0.0,
            shed: false,
            buckets: None,
        },
        Scenario {
            name: "overload_unlimited",
            rate: MODELED_CAPACITY * 2.0,
            rate_limit: 0.0,
            shed: false,
            buckets: None,
        },
        Scenario {
            name: "overload_limited",
            rate: MODELED_CAPACITY * 2.0,
            rate_limit: MODELED_CAPACITY,
            shed: false,
            buckets: None,
        },
        Scenario {
            name: "overload_limited_shed",
            rate: MODELED_CAPACITY * 2.0,
            rate_limit: MODELED_CAPACITY,
            shed: true,
            buckets: None,
        },
        Scenario {
            name: "hot_tenant_open",
            rate: 16.0,
            rate_limit: 0.0,
            shed: false,
            buckets: Some(hot(1e9, 1e9)),
        },
        Scenario {
            name: "hot_tenant_buckets",
            rate: 16.0,
            rate_limit: 0.0,
            shed: false,
            buckets: Some(hot(8.0, 2.0)),
        },
    ]
}

/// One modeled result row.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: &'static str,
    pub rate: f64,
    pub offered: u64,
    pub admitted: u64,
    pub rejected_rate: u64,
    pub rejected_bucket: u64,
    pub shed_degraded: u64,
    pub shed_dropped: u64,
    /// Interactive-class SLO attainment over admitted requests.
    pub interactive_slo: f64,
    pub ttft_p99_ms: f64,
    pub max_tenant_share: f64,
}

fn scenario_cfg(s: &Scenario, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(System::Blink, LLAMA3_8B, s.rate, false);
    cfg.window_s = 20.0;
    cfg.classes = Some(ClassMix::interactive_batch());
    cfg.rate_limit = s.rate_limit;
    cfg.tenant_buckets = s.buckets;
    if s.shed {
        cfg.shed_policy = ShedPolicyCfg::degrade_then_drop(16);
    }
    cfg.seed = cfg.seed.wrapping_add(seed.wrapping_mul(0x9E37_79B9));
    cfg
}

/// Run the whole modeled grid at one seed (virtual time; same seed ⇒
/// identical rows on every host).
pub fn modeled_rows(seed: u64) -> Vec<Row> {
    scenario_grid()
        .iter()
        .map(|s| {
            let wm = simulate(&scenario_cfg(s, seed));
            let slo = wm
                .class(INTERACTIVE_PRIORITY)
                .map_or(f64::NAN, |c| c.slo_attainment);
            Row {
                name: s.name,
                rate: s.rate,
                offered: wm.overload.offered,
                admitted: wm.overload.admitted,
                rejected_rate: wm.overload.rejected_rate,
                rejected_bucket: wm.overload.rejected_bucket,
                shed_degraded: wm.overload.shed_degraded,
                shed_dropped: wm.overload.shed_dropped,
                interactive_slo: slo,
                ttft_p99_ms: wm.ttft.p99,
                max_tenant_share: wm.overload.max_tenant_share(),
            }
        })
        .collect()
}

/// Serialize rows to the suite's CSV (stable column order; the golden
/// byte-determinism test pins these bytes at a fixed seed).
pub fn overload_csv(rows: &[Row]) -> String {
    let mut csv = String::from(
        "scenario,rate,offered,admitted,rejected_rate,rejected_bucket,shed_degraded,\
         shed_dropped,interactive_slo,ttft_p99_ms,max_tenant_share\n",
    );
    for r in rows {
        csv.push_str(&format!(
            "{},{:.1},{},{},{},{},{},{},{:.4},{:.2},{:.4}\n",
            r.name,
            r.rate,
            r.offered,
            r.admitted,
            r.rejected_rate,
            r.rejected_bucket,
            r.shed_degraded,
            r.shed_dropped,
            r.interactive_slo,
            r.ttft_p99_ms,
            r.max_tenant_share,
        ));
    }
    csv
}

// ---------------------------------------------------------------------------
// Live rows: the real DpuFrontend gate over the ring → scheduler →
// modeled-executor pipeline. Wall-clock measured; never golden-tested.
// ---------------------------------------------------------------------------

/// Tiny-testbed request shapes. The decode grid below runs at most 4
/// lanes, so with a 20 ms decode step and a ~13.6-step mean output the
/// serving capacity is ≈ [`LIVE_CAPACITY`] req/s — small enough that a
/// 2-second window produces real overload without thousands of requests.
pub const INTERACTIVE_IN: usize = 24;
pub const INTERACTIVE_OUT: u32 = 8;
pub const BATCH_IN: usize = 48;
pub const BATCH_OUT: u32 = 16;

/// Approximate live serving capacity (req/s) of the testbed below:
/// 4 decode lanes / (0.3·8 + 0.7·16 steps × 20 ms).
pub const LIVE_CAPACITY: f64 = 14.7;

/// A modeled manifest whose decode grid tops out at batch 4, so live
/// overload is reachable at tens (not hundreds) of requests per second.
pub fn overload_manifest() -> ModelManifest {
    let mut text = String::from(
        "blink-manifest v1\nmodel modeled-overload\nvocab_size 2048\nd_model 256\nn_layers 4\n\
         n_heads 8\nn_kv_heads 4\nd_head 32\nd_ff 704\nblock_size 16\nnum_blocks 512\n\
         max_blocks_per_seq 32\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
         param tok_embed 2048x256 f32\n",
    );
    for b in [1usize, 2, 4] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0 modeled\n"));
    }
    for b in [1usize, 2, 4] {
        for s in [16usize, 32, 64] {
            text.push_str(&format!("graph prefill_b{b}_s{s} prefill {b} {s} modeled\n"));
        }
    }
    ModelManifest::parse(&text).expect("overload manifest")
}

/// The gate configuration the live suite (and the acceptance test) runs:
/// a ~8 req/s sliding-window limit with degrade-then-drop shedding and
/// effectively-unlimited tenant buckets (fairness is the DES's job; the
/// live cells isolate the limiter+shed story).
pub fn limiter_config() -> OverloadConfig {
    OverloadConfig {
        enabled: true,
        window_capacity: 2,
        window_ms: 250,
        bucket_capacity: 1e6,
        bucket_refill_per_s: 1e6,
        tenant_slots: 64,
        degrade_threshold: 0.5,
        drop_threshold: 0.8,
        degrade_max_new: 4,
        interactive_floor: INTERACTIVE_PRIORITY,
    }
}

/// Knobs for one live run.
#[derive(Debug, Clone, Copy)]
pub struct LiveOverloadParams {
    pub offered_rate: f64,
    /// Submission window (seconds of Poisson arrivals).
    pub window_s: f64,
    pub interactive_share: f64,
    pub ttft_budget: Duration,
    pub decode_step_us: f64,
    pub prefill_us_per_token: f64,
    /// `None` = unlimited (open-loop baseline).
    pub gate: Option<OverloadConfig>,
    pub seed: u64,
}

impl LiveOverloadParams {
    fn base(offered_rate: f64, gate: Option<OverloadConfig>) -> LiveOverloadParams {
        LiveOverloadParams {
            offered_rate,
            window_s: 2.0,
            interactive_share: 0.3,
            ttft_budget: Duration::from_millis(750),
            decode_step_us: 20_000.0,
            prefill_us_per_token: 5.0,
            gate,
            seed: 7,
        }
    }

    /// Pre-saturation baseline: ~½× capacity, no gate.
    pub fn presat() -> LiveOverloadParams {
        LiveOverloadParams::base(8.0, None)
    }

    /// 2× over-capacity, open loop — the collapse case.
    pub fn overload_unlimited() -> LiveOverloadParams {
        LiveOverloadParams::base(2.0 * LIVE_CAPACITY, None)
    }

    /// 2× over-capacity behind the limiter + shed.
    pub fn overload_limited() -> LiveOverloadParams {
        LiveOverloadParams::base(2.0 * LIVE_CAPACITY, Some(limiter_config()))
    }

    /// CI sizing: half the submission window.
    pub fn smoke(mut self) -> LiveOverloadParams {
        self.window_s = 1.0;
        self
    }
}

/// What one live run measured.
#[derive(Debug, Clone)]
pub struct LiveOverloadReport {
    pub offered: usize,
    pub admitted: usize,
    /// 429-class refusals at the submit edge.
    pub rejected: usize,
    /// Admissions whose `max_new` came back capped.
    pub degraded: usize,
    pub interactive_offered: usize,
    pub interactive_admitted: usize,
    pub batch_admitted: usize,
    /// Share of admitted interactive requests whose first token landed
    /// within the TTFT budget.
    pub interactive_attainment: f64,
    pub interactive_ttft_p99_ms: f64,
    /// Gate counters (0 on unlimited runs).
    pub rejected_rate: u64,
    pub rejected_bucket: u64,
    pub shed_degraded: u64,
    pub shed_dropped: u64,
}

struct Pending {
    interactive: bool,
    degraded: bool,
    submitted: Instant,
    first: Option<Instant>,
    done: bool,
    handle: RequestHandle,
}

/// Drain every pending receiver without blocking, stamping first-token
/// times as they appear.
fn poll_pending(pending: &mut [Pending]) {
    for p in pending.iter_mut() {
        if p.done {
            continue;
        }
        loop {
            match p.handle.rx.try_recv() {
                Ok(TokenEvent::Token(_)) => {
                    if p.first.is_none() {
                        p.first = Some(Instant::now());
                    }
                }
                Ok(TokenEvent::Done) | Ok(TokenEvent::Failed) => {
                    p.done = true;
                    break;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    p.done = true;
                    break;
                }
            }
        }
    }
}

/// One live overload run: Poisson arrivals paced in wall time through
/// the real frontend gate into the real scheduler on the modeled
/// executor. Shared between `blink eval overload` and the tier-1
/// acceptance test, so it must run (and drain) on any machine.
pub fn run_live_overload(p: &LiveOverloadParams) -> LiveOverloadReport {
    let manifest = overload_manifest();
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 256,
        max_prompt: 256,
        max_output: 256,
    }));
    let rdma = RdmaEngine::spawn(ring.clone(), RdmaConfig::zero_cost());
    let cost = ModeledCost {
        prefill_us_per_token: p.prefill_us_per_token,
        decode_step_us: p.decode_step_us,
        ..ModeledCost::zero()
    };
    let executor = Executor::spawn_modeled(&manifest, cost);
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest,
        SchedulerConfig {
            apply_launch_delays: false,
            prefix_reuse: PrefixReuse::Off,
            ..Default::default()
        },
    );
    // Byte-level vocab: the live runner submits pre-tokenized ids, so
    // only the frontend's arena shapes matter, not the merge table.
    let vocab = Arc::new(Vocab {
        tokens: (0..=255u8).map(|b| vec![b]).collect(),
        merges: vec![],
    });
    let frontend = DpuFrontend::new(
        rdma,
        vocab,
        FrontendConfig {
            num_slots: 256,
            max_prompt: 256,
            max_output: 256,
            reader: ReaderConfig::default(),
            overload: p.gate.unwrap_or_default(),
        },
    );

    // Deterministic arrival schedule (the pacing is wall-clock, the
    // schedule is not).
    let mut rng = Rng::new(p.seed);
    let mut arrivals: Vec<(f64, bool)> = vec![];
    let mut t = 0.0;
    loop {
        t += rng.exp(p.offered_rate);
        if t >= p.window_s {
            break;
        }
        arrivals.push((t, rng.f64() < p.interactive_share));
    }

    let budget_us = p.ttft_budget.as_micros() as u64;
    let mut pending: Vec<Pending> = vec![];
    let mut rejected = 0usize;
    let mut interactive_offered = 0usize;
    let t0 = Instant::now();
    for &(at, interactive) in &arrivals {
        while t0.elapsed().as_secs_f64() < at {
            poll_pending(&mut pending);
            std::thread::sleep(Duration::from_micros(200));
        }
        interactive_offered += interactive as usize;
        let (len, max_new, class) = if interactive {
            (
                INTERACTIVE_IN,
                INTERACTIVE_OUT,
                RequestClass { priority: INTERACTIVE_PRIORITY, ttft_budget_us: budget_us },
            )
        } else {
            (BATCH_IN, BATCH_OUT, RequestClass::default())
        };
        let tokens: Vec<u32> = (0..len).map(|i| (i % 251) as u32 + 1).collect();
        match frontend.submit_tokens_class(&tokens, max_new, class) {
            Ok(handle) => pending.push(Pending {
                interactive,
                degraded: handle.max_new < max_new,
                submitted: Instant::now(),
                first: None,
                done: false,
                handle,
            }),
            Err(Rejected::Overload { .. }) => rejected += 1,
            Err(Rejected::Client(e)) => panic!("unexpected client rejection: {e}"),
        }
    }

    // Drain: every admitted request must finish (the modeled executor
    // never early-EOSes, so "done" is deterministic).
    let deadline = Instant::now() + Duration::from_secs(60);
    while pending.iter().any(|p| !p.done) {
        assert!(Instant::now() < deadline, "live overload run failed to drain");
        poll_pending(&mut pending);
        std::thread::sleep(Duration::from_micros(500));
    }
    sched.drain_and_stop();

    let gate = frontend.gate();
    let ord = std::sync::atomic::Ordering::Relaxed;
    let mut ttfts_ms: Vec<f64> = pending
        .iter()
        .filter(|q| q.interactive)
        .filter_map(|q| q.first.map(|f| (f - q.submitted).as_secs_f64() * 1e3))
        .collect();
    ttfts_ms.sort_by(f64::total_cmp);
    let interactive_admitted = pending.iter().filter(|q| q.interactive).count();
    let attained = pending
        .iter()
        .filter(|q| q.interactive)
        .filter(|q| q.first.is_some_and(|f| f - q.submitted <= p.ttft_budget))
        .count();
    LiveOverloadReport {
        offered: arrivals.len(),
        admitted: pending.len(),
        rejected,
        degraded: pending.iter().filter(|q| q.degraded).count(),
        interactive_offered,
        interactive_admitted,
        batch_admitted: pending.len() - interactive_admitted,
        interactive_attainment: attained as f64 / interactive_admitted.max(1) as f64,
        interactive_ttft_p99_ms: percentile_sorted(&ttfts_ms, 99.0),
        rejected_rate: gate.rejected_rate.load(ord),
        rejected_bucket: gate.rejected_bucket.load(ord),
        shed_degraded: gate.shed_degraded.load(ord),
        shed_dropped: gate.shed_dropped.load(ord),
    }
}

// ---------------------------------------------------------------------------
// The eval entry point.
// ---------------------------------------------------------------------------

fn print_rows(rows: &[Row]) {
    println!(
        "{:<22} {:>6} {:>8} {:>9} {:>9} {:>9} {:>9} {:>8} {:>12} {:>12} {:>10}",
        "scenario",
        "rate",
        "offered",
        "admitted",
        "rej_rate",
        "rej_bckt",
        "degraded",
        "dropped",
        "inter_slo",
        "ttft_p99_ms",
        "max_share"
    );
    for r in rows {
        println!(
            "{:<22} {:>6.1} {:>8} {:>9} {:>9} {:>9} {:>9} {:>8} {:>12.4} {:>12.2} {:>10.4}",
            r.name,
            r.rate,
            r.offered,
            r.admitted,
            r.rejected_rate,
            r.rejected_bucket,
            r.shed_degraded,
            r.shed_dropped,
            r.interactive_slo,
            r.ttft_p99_ms,
            r.max_tenant_share,
        );
    }
}

/// `blink eval overload [--out DIR] [--smoke]`: the deterministic
/// modeled sweep (golden CSV) followed by live collapse-vs-hold runs.
pub fn overload(out: Option<&std::path::Path>, smoke: bool) {
    println!("\n== Overload control suite (DESIGN.md §9) ==");
    println!("(open-loop admission collapses at 2x capacity; the DPU gate holds interactive SLOs)");

    let rows = modeled_rows(7);
    println!("\n-- modeled scenarios (DES gate mirror, byte-deterministic at fixed seed) --");
    print_rows(&rows);
    super::live::write_out(out, "overload.csv", &overload_csv(&rows));

    let live_specs = [
        ("presat_unlimited", LiveOverloadParams::presat()),
        ("overload_unlimited", LiveOverloadParams::overload_unlimited()),
        ("overload_limited_shed", LiveOverloadParams::overload_limited()),
    ];
    println!("\n-- live runs (real frontend gate + scheduler on the modeled executor) --");
    let mut csv = String::from(
        "scenario,offered_rate,offered,admitted,rejected,degraded,interactive_admitted,\
         interactive_attainment,interactive_ttft_p99_ms\n",
    );
    for (name, params) in live_specs {
        let params = if smoke { params.smoke() } else { params };
        let r = run_live_overload(&params);
        println!(
            "{:<22} offered {:>3} admitted {:>3} rejected {:>3} degraded {:>3} \
             interactive slo {:.3} ttft_p99 {:.1} ms",
            name,
            r.offered,
            r.admitted,
            r.rejected,
            r.degraded,
            r.interactive_attainment,
            r.interactive_ttft_p99_ms,
        );
        csv.push_str(&format!(
            "{},{:.1},{},{},{},{},{},{:.4},{:.2}\n",
            name,
            params.offered_rate,
            r.offered,
            r.admitted,
            r.rejected,
            r.degraded,
            r.interactive_admitted,
            r.interactive_attainment,
            r.interactive_ttft_p99_ms,
        ));
    }
    super::live::write_out(out, "overload_live.csv", &csv);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overload_csv_is_deterministic() {
        // Same seed ⇒ identical bytes (the acceptance criterion; the
        // modeled grid runs the DES in virtual time, so this holds on
        // any machine).
        let a = overload_csv(&modeled_rows(7));
        let b = overload_csv(&modeled_rows(7));
        assert_eq!(a, b, "same seed must produce identical CSV bytes");
        let c = overload_csv(&modeled_rows(8));
        assert_ne!(a, c, "the seed must actually drive the trace");
    }

    #[test]
    fn overload_grid_covers_the_story() {
        let rows = modeled_rows(7);
        assert_eq!(rows.len(), scenario_grid().len());
        let get = |n: &str| rows.iter().find(|r| r.name == n).unwrap();

        // The open-loop rows admit everything; the limited rows refuse.
        assert_eq!(get("overload_unlimited").offered, get("overload_unlimited").admitted);
        let lim = get("overload_limited_shed");
        assert!(lim.admitted < lim.offered, "limiter must refuse work at 2x");
        assert!(lim.rejected_rate + lim.shed_dropped > 0);

        // Admission control buys interactive attainment at 2x load.
        let unl = get("overload_unlimited");
        assert!(unl.interactive_slo.is_finite() && lim.interactive_slo.is_finite());
        assert!(
            lim.interactive_slo >= unl.interactive_slo - 0.05,
            "limited {} vs unlimited {}",
            lim.interactive_slo,
            unl.interactive_slo
        );

        // Tenant buckets shrink the hot tenant's admitted share.
        let open = get("hot_tenant_open");
        let fair = get("hot_tenant_buckets");
        assert!(fair.rejected_bucket > 0, "tight buckets must trip");
        assert!(
            fair.max_tenant_share < open.max_tenant_share,
            "buckets must cap the flooder: {} vs {}",
            fair.max_tenant_share,
            open.max_tenant_share
        );
    }
}
