//! Speculative-decoding evaluation (DESIGN.md §11): what fixed-k
//! self-drafted draft-verify buys across the acceptance range, modeled
//! and live.
//!
//! Decode is HBM-bound — every step reads the full active weight set to
//! emit one token per lane. A k-wide verify launch scores k+1 positions
//! under **one** weight sweep, so accepted drafts are nearly free; the
//! question speculation always comes down to is whether the acceptance
//! rate clears the verify premium (extra KV reads + window FLOPs).
//! This suite answers it twice:
//!
//! * **modeled rows** (`spec.csv`, golden): the DES charging
//!   [`crate::sim::costmodel::CostModel::verify_step_with_chunk_s`]
//!   over a saturated
//!   fixed-length trace, swept over k × acceptance. Virtual time,
//!   byte-deterministic at a fixed seed.
//! * **live rows** (`spec_live.csv`, never golden-tested): the real
//!   scheduler's draft → verify → longest-prefix-retire path on the
//!   modeled executor in greedy-chain mode, where token streams are a
//!   pure function of the prompt — so the k = 0 and k = 4 runs of the
//!   same trace must agree byte-for-byte while their wall clocks
//!   diverge. [`run_live_spec`] is shared with the tier-1 acceptance
//!   test in `tests/spec_decode_e2e.rs`, so the speedup-with-identical-
//!   tokens contract runs on every machine, artifacts or not.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::gpu::{Executor, ModeledCost, PrefixReuse, Scheduler, SchedulerConfig};
use crate::ringbuf::{RingBuffer, RingConfig, SlotState};
use crate::runtime::ModelManifest;
use crate::sim::costmodel::LLAMA3_8B;
use crate::sim::des::{simulate, SimConfig};
use crate::sim::systems::System;
use crate::workload::LengthModel;

// ---------------------------------------------------------------------------
// Modeled rows: the DES verify-cost sweep in virtual time (golden CSV).
// ---------------------------------------------------------------------------

/// The k × acceptance grid, in CSV row order: the plain-decode baseline
/// first, then each k swept across the acceptance range the paper's
/// self-drafting regime spans. 16 lanes keeps the verify window under
/// the weight sweep (the regime where speculation pays, per
/// `CostModel::verify_step_s`).
pub fn scenario_grid() -> Vec<(usize, f64)> {
    vec![
        (0, 1.0),
        (2, 0.7),
        (2, 0.9),
        (4, 0.5),
        (4, 0.7),
        (4, 0.9),
        (4, 1.0),
        (8, 0.7),
    ]
}

/// One modeled result row.
#[derive(Debug, Clone)]
pub struct Row {
    pub k: usize,
    pub accept: f64,
    pub completed: usize,
    pub decode_tok_s: f64,
    pub tpot_mean_ms: f64,
    pub tpot_p99_ms: f64,
    /// Decode-throughput ratio vs the k = 0 row of the same sweep.
    pub speedup: f64,
}

fn sweep_cfg(k: usize, accept: f64, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(System::Blink, LLAMA3_8B, 100.0, false);
    // Saturated fixed-length trace: arrivals far outrun capacity, so
    // throughput measures the launch shape, not the workload.
    cfg.window_s = 10.0;
    cfg.max_num_seqs = 16;
    cfg.lengths = LengthModel::Fixed { input: 64, output: 64 };
    cfg.spec_k = k;
    cfg.spec_accept = accept;
    cfg.seed = cfg.seed.wrapping_add(seed.wrapping_mul(0x9E37_79B9));
    cfg
}

/// Run the whole modeled grid at one seed (virtual time; same seed ⇒
/// identical rows on every host).
pub fn modeled_rows(seed: u64) -> Vec<Row> {
    let grid = scenario_grid();
    let mut rows: Vec<Row> = grid
        .iter()
        .map(|&(k, accept)| {
            let wm = simulate(&sweep_cfg(k, accept, seed));
            Row {
                k,
                accept,
                completed: wm.completed,
                decode_tok_s: wm.decode_tok_s,
                tpot_mean_ms: wm.tpot.mean,
                tpot_p99_ms: wm.tpot.p99,
                speedup: 0.0,
            }
        })
        .collect();
    let base = rows
        .iter()
        .find(|r| r.k == 0)
        .map(|r| r.decode_tok_s)
        .unwrap_or(f64::NAN);
    for r in rows.iter_mut() {
        r.speedup = r.decode_tok_s / base;
    }
    rows
}

/// Serialize rows to the suite's CSV (stable column order; the golden
/// byte-determinism test pins these bytes at a fixed seed).
pub fn spec_csv(rows: &[Row]) -> String {
    let mut csv =
        String::from("k,accept,completed,decode_tok_s,tpot_mean_ms,tpot_p99_ms,speedup\n");
    for r in rows {
        csv.push_str(&format!(
            "{},{:.2},{},{:.2},{:.3},{:.3},{:.3}\n",
            r.k, r.accept, r.completed, r.decode_tok_s, r.tpot_mean_ms, r.tpot_p99_ms, r.speedup,
        ));
    }
    csv
}

// ---------------------------------------------------------------------------
// Live rows: the real scheduler's draft/verify/retire path on the
// modeled executor in greedy-chain mode. Wall-clock; never golden.
// ---------------------------------------------------------------------------

/// A modeled manifest carrying a full verify grid (k ∈ {2, 4} at every
/// decode batch size), so the live path exercises exact-k selection and
/// the tightest-batch fit alongside plain decode.
pub fn spec_manifest() -> ModelManifest {
    let mut text = String::from(
        "blink-manifest v1\nmodel modeled-spec\nvocab_size 2048\nd_model 64\nn_layers 2\n\
         n_heads 4\nn_kv_heads 2\nd_head 16\nd_ff 128\nblock_size 16\nnum_blocks 256\n\
         max_blocks_per_seq 16\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
         param tok_embed 2048x64 f32\n",
    );
    for b in [1usize, 2, 4] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0 modeled\n"));
        for k in [2usize, 4] {
            text.push_str(&format!(
                "graph decode_verify_b{b}_k{k} decode_verify {b} {k} modeled\n"
            ));
        }
        for s in [16usize, 32] {
            text.push_str(&format!("graph prefill_b{b}_s{s} prefill {b} {s} modeled\n"));
        }
    }
    ModelManifest::parse(&text).expect("spec manifest")
}

/// Knobs for one live run.
#[derive(Debug, Clone, Copy)]
pub struct LiveSpecParams {
    pub spec_k: usize,
    pub spec_accept: f64,
    pub requests: usize,
    pub prompt_len: usize,
    pub max_new: u32,
    /// Modeled per-step decode cost — large enough that wall clocks
    /// measure launches, not scheduler overhead.
    pub decode_step_us: f64,
    /// Modeled per-draft-position verify premium (the KV/FLOPs the
    /// window adds on top of the shared weight sweep).
    pub verify_pos_us: f64,
    /// Offset mixed into every prompt token ([`spec_prompt`]) — greedy-
    /// chain streams are a pure function of the prompt, so this seed
    /// picks the whole trace (e.g. one whose chain hits EOS mid-window).
    pub prompt_base: u32,
}

impl LiveSpecParams {
    pub fn base(spec_k: usize, spec_accept: f64) -> LiveSpecParams {
        LiveSpecParams {
            spec_k,
            spec_accept,
            requests: 4,
            prompt_len: 16,
            max_new: 96,
            decode_step_us: 2_000.0,
            verify_pos_us: 25.0,
            prompt_base: 5,
        }
    }

    /// CI sizing: a third of the output budget.
    pub fn smoke(mut self) -> LiveSpecParams {
        self.max_new = 32;
        self
    }
}

/// What one live run measured.
#[derive(Debug, Clone)]
pub struct LiveSpecReport {
    /// Every published token, per slot, in publication order — the
    /// byte-identity surface (greedy-chain streams are a pure function
    /// of the prompt, so k must not change a single token).
    pub outputs: Vec<Vec<u32>>,
    pub total_tokens: u64,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub decode_steps: u64,
    pub spec_drafted: u64,
    pub spec_accepted: u64,
    pub accepted_per_verify_p50: f64,
    pub accepted_per_verify_p99: f64,
}

/// The deterministic per-slot prompt every live run submits: in-vocab,
/// slot-distinct, and fixed — with greedy-chain emission this pins the
/// whole output stream regardless of k, acceptance, or launch timing.
/// The default `base` of 5 yields four streams that never hit the
/// manifest's EOS inside a 96-token budget; `base` 69 at slot 0 hits
/// EOS at generated index 4 (the e2e mid-window-EOS trace).
pub fn spec_prompt(slot: usize, len: usize, base: u32) -> Vec<u32> {
    (0..len as u32).map(|i| (i * 13 + 7 * slot as u32 + base) % 2048).collect()
}

/// One live run: `requests` prompts through the real ring → scheduler →
/// modeled-executor pipeline with the given speculation knobs, drained
/// to completion. Shared between `blink eval spec` and the tier-1
/// acceptance test, so it must run on any machine (no artifacts).
pub fn run_live_spec(p: &LiveSpecParams) -> LiveSpecReport {
    let manifest = spec_manifest();
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 16,
        max_prompt: 32,
        max_output: 256,
    }));
    let cost = ModeledCost {
        prefill_us_per_token: 2.0,
        decode_step_us: p.decode_step_us,
        verify_pos_us: p.verify_pos_us,
        greedy_chain: true,
        ..ModeledCost::zero()
    };
    let executor = Executor::spawn_modeled(&manifest, cost);
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest,
        SchedulerConfig {
            apply_launch_delays: false,
            prefix_reuse: PrefixReuse::Off,
            spec_k: p.spec_k,
            spec_accept: p.spec_accept,
            ..Default::default()
        },
    );

    let t0 = Instant::now();
    for slot in 0..p.requests {
        let prompt = spec_prompt(slot, p.prompt_len, p.prompt_base);
        assert!(ring.claim_for_write(slot));
        ring.write_prompt(slot, &prompt);
        ring.submit(slot, slot as u64, prompt.len() as u32, p.max_new, 7);
    }
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let done = (0..p.requests).all(|s| {
            matches!(ring.slot(s).state(), SlotState::DecodeCompleted | SlotState::Failed)
        });
        if done {
            break;
        }
        assert!(Instant::now() < deadline, "live spec run failed to drain");
        std::thread::sleep(Duration::from_micros(200));
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let mut outputs = Vec::with_capacity(p.requests);
    let mut total_tokens = 0u64;
    for slot in 0..p.requests {
        assert_eq!(ring.slot(slot).state(), SlotState::DecodeCompleted, "slot {slot} failed");
        let n = ring.slot(slot).generated.load(Ordering::Acquire);
        total_tokens += n as u64;
        outputs.push(ring.read_tokens(slot, 0, n));
    }
    let stats = sched.stats.clone();
    sched.drain_and_stop();
    LiveSpecReport {
        outputs,
        total_tokens,
        wall_s,
        tokens_per_s: total_tokens as f64 / wall_s.max(1e-9),
        decode_steps: stats.decode_steps.load(Ordering::Relaxed),
        spec_drafted: stats.spec_drafted.load(Ordering::Relaxed),
        spec_accepted: stats.spec_accepted.load(Ordering::Relaxed),
        accepted_per_verify_p50: stats.accepted_per_verify_p50(),
        accepted_per_verify_p99: stats.accepted_per_verify_p99(),
    }
}

// ---------------------------------------------------------------------------
// The eval entry point.
// ---------------------------------------------------------------------------

fn print_rows(rows: &[Row]) {
    println!(
        "{:>2} {:>7} {:>10} {:>13} {:>13} {:>12} {:>8}",
        "k", "accept", "completed", "decode_tok_s", "tpot_mean_ms", "tpot_p99_ms", "speedup"
    );
    for r in rows {
        println!(
            "{:>2} {:>7.2} {:>10} {:>13.2} {:>13.3} {:>12.3} {:>8.3}",
            r.k, r.accept, r.completed, r.decode_tok_s, r.tpot_mean_ms, r.tpot_p99_ms, r.speedup,
        );
    }
}

/// `blink eval spec [--out DIR] [--smoke]`: the deterministic modeled
/// k × acceptance sweep (golden CSV) followed by live
/// identical-tokens-faster-clock runs.
pub fn spec(out: Option<&std::path::Path>, smoke: bool) {
    println!("\n== Speculative decoding suite (DESIGN.md §11) ==");
    println!("(k+1 tokens per weight sweep; acceptance decides whether the verify premium pays)");

    let rows = modeled_rows(7);
    println!("\n-- modeled k x acceptance sweep (DES, byte-deterministic at fixed seed) --");
    print_rows(&rows);
    super::live::write_out(out, "spec.csv", &spec_csv(&rows));

    let live_specs = [
        ("plain_k0", LiveSpecParams::base(0, 1.0)),
        ("spec_k4_a70", LiveSpecParams::base(4, 0.7)),
        ("spec_k4_a100", LiveSpecParams::base(4, 1.0)),
    ];
    println!("\n-- live runs (real scheduler draft/verify/retire on the modeled executor) --");
    let mut csv = String::from(
        "scenario,spec_k,spec_accept,tokens,wall_s,tokens_per_s,decode_steps,\
         spec_drafted,spec_accepted,accepted_per_verify_p50,accepted_per_verify_p99\n",
    );
    let mut baseline: Option<LiveSpecReport> = None;
    for (name, params) in live_specs {
        let params = if smoke { params.smoke() } else { params };
        let r = run_live_spec(&params);
        if let Some(b) = &baseline {
            assert_eq!(
                b.outputs, r.outputs,
                "greedy-chain streams must be identical across k (scenario {name})"
            );
            println!(
                "{:<14} {:>5} tokens in {:>6.3} s  {:>8.1} tok/s  ({:.2}x vs plain, \
                 accepted/verify p50 {:.1})",
                name,
                r.total_tokens,
                r.wall_s,
                r.tokens_per_s,
                r.tokens_per_s / b.tokens_per_s,
                r.accepted_per_verify_p50,
            );
        } else {
            println!(
                "{:<14} {:>5} tokens in {:>6.3} s  {:>8.1} tok/s  (baseline)",
                name, r.total_tokens, r.wall_s, r.tokens_per_s,
            );
        }
        csv.push_str(&format!(
            "{},{},{:.2},{},{:.4},{:.1},{},{},{},{:.2},{:.2}\n",
            name,
            params.spec_k,
            params.spec_accept,
            r.total_tokens,
            r.wall_s,
            r.tokens_per_s,
            r.decode_steps,
            r.spec_drafted,
            r.spec_accepted,
            r.accepted_per_verify_p50,
            r.accepted_per_verify_p99,
        ));
        if baseline.is_none() {
            baseline = Some(r);
        }
    }
    super::live::write_out(out, "spec_live.csv", &csv);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_csv_is_deterministic() {
        // Same seed ⇒ identical bytes (the acceptance criterion; the
        // modeled grid runs the DES in virtual time, so this holds on
        // any machine).
        let a = spec_csv(&modeled_rows(7));
        let b = spec_csv(&modeled_rows(7));
        assert_eq!(a, b, "same seed must produce identical CSV bytes");
        let c = spec_csv(&modeled_rows(8));
        assert_ne!(a, c, "the seed must actually drive the trace");
    }

    #[test]
    fn modeled_sweep_tells_the_acceptance_story() {
        let rows = modeled_rows(7);
        assert_eq!(rows.len(), scenario_grid().len());
        let base = rows.iter().find(|r| r.k == 0).unwrap();
        assert!((base.speedup - 1.0).abs() < 1e-12);
        assert!(base.completed > 100, "baseline must serve: {}", base.completed);
        // Perfect acceptance at k = 4 clears 2x; realistic 0.7 clears 1.5x.
        let perfect = rows.iter().find(|r| r.k == 4 && r.accept == 1.0).unwrap();
        assert!(perfect.speedup > 2.0, "k=4 @ 1.0: {}", perfect.speedup);
        let realistic = rows.iter().find(|r| r.k == 4 && r.accept == 0.7).unwrap();
        assert!(realistic.speedup > 1.5, "k=4 @ 0.7: {}", realistic.speedup);
        // Speedup is monotone in acceptance at fixed k.
        let k4: Vec<f64> = rows.iter().filter(|r| r.k == 4).map(|r| r.speedup).collect();
        assert!(k4.windows(2).all(|w| w[0] < w[1]), "k=4 sweep must be monotone: {k4:?}");
    }

    #[test]
    fn spec_manifest_covers_the_decode_grid() {
        let m = spec_manifest();
        let cache = crate::gpu::scheduler::cache_from_manifest(&m);
        assert!(cache.has_verify_graphs());
        assert_eq!(cache.verify_ks(), vec![2, 4]);
        for k in [2usize, 4] {
            assert!(
                cache.verify_uncovered_batches(k).is_empty(),
                "full batch coverage at k={k}"
            );
        }
    }
}
