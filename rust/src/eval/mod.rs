//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §6 per-experiment index). Simulator-driven experiments
//! consume one shared sweep; mechanism experiments (Fig 3, Fig 4) run the
//! *live* system and live in [`live`].
//!
//! Each function prints the same rows/series the paper reports and, when
//! `out` is set, writes a CSV next to it. Paper values are included
//! side-by-side where the paper prints a single table, so shape
//! divergence is visible at a glance.

pub mod interference;
pub mod live;
pub mod overload;
pub mod spec;

use std::io::Write;
use std::path::Path;

use crate::gpu::policy::PolicyKind;
use crate::sim::costmodel::{CostModel, PaperModel, LLAMA3_8B, PAPER_MODELS};
use crate::sim::des::{simulate, SimConfig};
use crate::sim::interference::CounterModel;
use crate::sim::sweep::{
    run_chunked_sweep, run_policy_sweep, run_prefix_sweep, run_sweep, SweepResults,
};
use crate::sim::systems::{System, ALL_SYSTEMS};
use crate::util::stats::serviceable_load;

pub struct EvalCtx {
    pub sweep: SweepResults,
    pub out: Option<std::path::PathBuf>,
}

impl EvalCtx {
    /// Run the shared sweep (all four paper models).
    pub fn new(window_s: f64, threads: usize, out: Option<&Path>) -> EvalCtx {
        eprintln!("[eval] running sweep: 4 systems x 4 models x 13 loads x {{iso,interf}} ...");
        let t = std::time::Instant::now();
        let sweep = run_sweep(&PAPER_MODELS, window_s, threads);
        eprintln!("[eval] sweep done in {:.1}s", t.elapsed().as_secs_f64());
        if let Some(o) = out {
            std::fs::create_dir_all(o).ok();
        }
        EvalCtx { sweep, out: out.map(|p| p.to_path_buf()) }
    }

    fn write_csv(&self, name: &str, content: &str) {
        if let Some(dir) = &self.out {
            let path = dir.join(name);
            if let Ok(mut f) = std::fs::File::create(&path) {
                let _ = f.write_all(content.as_bytes());
                eprintln!("[eval] wrote {}", path.display());
            }
        }
    }
}

fn model(name: &str) -> PaperModel {
    PAPER_MODELS.iter().copied().find(|m| m.name == name).unwrap()
}

// ---------------------------------------------------------------------------
// Fig 1 — headline: throughput at 4 req/s on the MoE model, iso vs coloc.
// ---------------------------------------------------------------------------

pub fn fig1(ctx: &EvalCtx) {
    println!("\n== Figure 1: achieved throughput, Qwen-3 30B-A3B @ 4 req/s ==");
    println!("{:<10} {:>12} {:>12} {:>8}   (paper ratio: BLINK ~1.0, baselines 0.28-0.54)",
        "system", "isolated", "colocated", "ratio");
    let mut csv = String::from("system,isolated_rps,colocated_rps,ratio\n");
    let level = ctx.sweep.levels.iter().position(|l| *l == 4.0).unwrap();
    for sys in ALL_SYSTEMS {
        let iso = ctx.sweep.get(sys, "qwen3-30b-a3b", false, level).req_throughput;
        let co = ctx.sweep.get(sys, "qwen3-30b-a3b", true, level).req_throughput;
        println!("{:<10} {:>12.2} {:>12.2} {:>8.2}", sys.name(), iso, co, co / iso);
        csv.push_str(&format!("{},{:.3},{:.3},{:.3}\n", sys.name(), iso, co, co / iso));
    }
    ctx.write_csv("fig1.csv", &csv);
}

// ---------------------------------------------------------------------------
// Table 1 — vLLM under 12× / 24× interference + µarch counters.
// ---------------------------------------------------------------------------

pub fn table1(ctx: &EvalCtx) {
    println!("\n== Table 1: vLLM colocation impact (Llama-3 8B, 7 req/s) ==");
    let mk = |intensity: f64| {
        let mut cfg = SimConfig::new(System::Vllm, model("llama3-8b"), 7.0, intensity > 0.0);
        cfg.window_s = 60.0;
        // Scale the interference process to the requested intensity.
        let wm = if intensity == 0.0 {
            simulate(&cfg)
        } else {
            // sensitivity scaled: 12× interferer ≈ half the 24× pressure.
            scaled_interference_sim(&cfg, intensity)
        };
        let c = CounterModel::interference(intensity).counters();
        (wm, c)
    };
    let (base, cb) = mk(0.0);
    let (mid, cm) = mk(0.5);
    let (full, cf) = mk(1.0);
    let rows: Vec<(&str, [String; 3])> = vec![
        ("Throughput (tok/s)", [f0(base.decode_tok_s + base.prefill_tok_s), f0(mid.decode_tok_s + mid.prefill_tok_s), f0(full.decode_tok_s + full.prefill_tok_s)]),
        ("Mean TTFT (ms)", [f1(base.ttft.mean), f1(mid.ttft.mean), f1(full.ttft.mean)]),
        ("P99 TTFT (ms)", [f0(base.ttft.p99), f0(mid.ttft.p99), f0(full.ttft.p99)]),
        ("Mean TPOT (ms)", [f1(base.tpot.mean), f1(mid.tpot.mean), f1(full.tpot.mean)]),
        ("P99 TPOT (ms)", [f1(base.tpot.p99), f1(mid.tpot.p99), f1(full.tpot.p99)]),
        ("P99 ITL (ms)", [f1(base.itl.p99), f1(mid.itl.p99), f1(full.itl.p99)]),
        ("IPC", [f2(cb.ipc), f2(cm.ipc), f2(cf.ipc)]),
        ("LLC miss rate (%)", [f1(cb.llc_miss_pct), f1(cm.llc_miss_pct), f1(cf.llc_miss_pct)]),
        ("LLC stall cycles (M)", [f0(cb.llc_stall_cycles_m), f0(cm.llc_stall_cycles_m), f0(cf.llc_stall_cycles_m)]),
        ("dTLB load misses (M)", [f0(cb.dtlb_load_misses_m), f0(cm.dtlb_load_misses_m), f0(cf.dtlb_load_misses_m)]),
        ("walk_active (M)", [f0(cb.walk_active_m), f0(cm.walk_active_m), f0(cf.walk_active_m)]),
        ("CPU migrations", [cb.cpu_migrations.to_string(), cm.cpu_migrations.to_string(), cf.cpu_migrations.to_string()]),
    ];
    println!("{:<24} {:>10} {:>12} {:>12}", "", "Baseline", "12x", "24x");
    let mut csv = String::from("metric,baseline,interference_12x,interference_24x\n");
    for (name, vals) in &rows {
        println!("{:<24} {:>10} {:>12} {:>12}", name, vals[0], vals[1], vals[2]);
        csv.push_str(&format!("{},{},{},{}\n", name, vals[0], vals[1], vals[2]));
    }
    println!("(paper: tput 7475->1961 tok/s, P99 TTFT 150->20959 ms, IPC 1.53->0.72)");
    ctx.write_csv("table1.csv", &csv);
}

/// DES run with the interference process scaled to a partial intensity.
fn scaled_interference_sim(cfg: &SimConfig, intensity: f64) -> crate::workload::WindowMetrics {
    // Reuse simulate() but with a scaled sensitivity: mean multiplier
    // interpolates between 1 and the system's full sensitivity.
    let full = cfg.system.interference_sensitivity();
    let scaled = 1.0 + (full - 1.0) * intensity;
    let mut c = cfg.clone();
    c.interference = true;
    // Encode the scale by swapping the system sensitivity via an env-free
    // mechanism: simulate_with_sensitivity is the honest API.
    crate::sim::des::simulate_with_sensitivity(&c, scaled)
}

// ---------------------------------------------------------------------------
// Table 2 — page-size ablation (huge pages do not restore isolation).
// ---------------------------------------------------------------------------

pub fn table2(ctx: &EvalCtx) {
    println!("\n== Table 2: page-size ablation under interference (synthetic 1024/512, 7 req/s) ==");
    let mut cfg = SimConfig::new(System::Vllm, model("llama3-8b"), 7.0, true);
    cfg.lengths = crate::workload::LengthModel::Fixed { input: 1024, output: 512 };
    let wm4k = simulate(&cfg);
    // 2 MB pages: dTLB reach improves ~16 % for the Python-heavy working
    // set (paper), nothing else moves; latency within noise.
    let mut cfg2 = cfg.clone();
    cfg2.seed ^= 0x2B;
    let wm2m = simulate(&cfg2);
    let c4k = CounterModel::interference(1.0).counters();
    let (d4k, w4k) = (c4k.dtlb_load_misses_m * 0.88, c4k.walk_active_m * 0.78);
    let (d2m, w2m) = (d4k * 0.84, w4k * 0.87);
    println!("{:<22} {:>12} {:>12}", "", "4 KB pages", "2 MB pages");
    let rows = vec![
        ("Throughput (tok/s)", f0(wm4k.decode_tok_s + wm4k.prefill_tok_s), f0(wm2m.decode_tok_s + wm2m.prefill_tok_s)),
        ("P50 TTFT (ms)", f0(wm4k.ttft.p50), f0(wm2m.ttft.p50)),
        ("P99 TTFT (ms)", f0(wm4k.ttft.p99), f0(wm2m.ttft.p99)),
        ("P50 TPOT (ms)", f1(wm4k.tpot.p50), f1(wm2m.tpot.p50)),
        ("P99 TPOT (ms)", f1(wm4k.tpot.p99), f1(wm2m.tpot.p99)),
        ("P99 ITL (ms)", f1(wm4k.itl.p99), f1(wm2m.itl.p99)),
        ("LLC miss rate (%)", f1(c4k.llc_miss_pct), f1(c4k.llc_miss_pct - 0.1)),
        ("dTLB load misses (M)", f1(d4k), f1(d2m)),
        ("walk_active (M)", f0(w4k), f0(w2m)),
    ];
    let mut csv = String::from("metric,4kb,2mb\n");
    for (n, a, b) in &rows {
        println!("{:<22} {:>12} {:>12}", n, a, b);
        csv.push_str(&format!("{n},{a},{b}\n"));
    }
    println!("(paper: dTLB drops only 16 %, all latency within noise — pages don't help)");
    ctx.write_csv("table2.csv", &csv);
}

// ---------------------------------------------------------------------------
// Table 3 — core pinning: helps but does not restore isolation.
// ---------------------------------------------------------------------------

pub fn table3(ctx: &EvalCtx) {
    println!("\n== Table 3: core pinning (6 dedicated cores), ShareGPT @ 12 req/s ==");
    let mut cfg = SimConfig::new(System::Vllm, model("llama3-8b"), 12.0, false);
    let iso = simulate(&cfg);
    // Pinning removes preemption/migrations; LLC + membw + interconnect
    // stay shared ⇒ residual ~1.2–1.4× inflation of host work.
    cfg.interference = true;
    let pinned = crate::sim::des::simulate_with_sensitivity(&cfg, 1.45);
    let d = |a: f64, b: f64| format!("{:+.1} %", (b / a - 1.0) * 100.0);
    println!("{:<28} {:>12} {:>14} {:>9}", "", "Isolation", "Interference", "Δ%");
    let rows = vec![
        ("Completed requests", iso.completed as f64, pinned.completed as f64),
        ("Mean throughput (tok/s)", iso.decode_tok_s + iso.prefill_tok_s, pinned.decode_tok_s + pinned.prefill_tok_s),
        ("Mean throughput (req/s)", iso.req_throughput, pinned.req_throughput),
        ("P50 TTFT (ms)", iso.ttft.p50, pinned.ttft.p50),
        ("P99 TTFT (ms)", iso.ttft.p99, pinned.ttft.p99),
        ("P50 TPOT (ms)", iso.tpot.p50, pinned.tpot.p50),
        ("P99 TPOT (ms)", iso.tpot.p99, pinned.tpot.p99),
        ("P50 ITL (ms)", iso.itl.p50, pinned.itl.p50),
        ("P99 ITL (ms)", iso.itl.p99, pinned.itl.p99),
        ("Decode throughput (tok/s)", iso.decode_tok_s, pinned.decode_tok_s),
    ];
    let mut csv = String::from("metric,isolation,interference,delta_pct\n");
    for (n, a, b) in &rows {
        println!("{:<28} {:>12.2} {:>14.2} {:>9}", n, a, b, d(*a, *b));
        csv.push_str(&format!("{n},{a:.3},{b:.3},{}\n", d(*a, *b)));
    }
    println!("(paper: -16..-18 % throughput, +19..+30 % tails — pinning is not enough)");
    ctx.write_csv("table3.csv", &csv);
}

// ---------------------------------------------------------------------------
// Table 4 — CAT way sweep: LLC recovers, tail latency does not.
// ---------------------------------------------------------------------------

pub fn table4(ctx: &EvalCtx) {
    println!("\n== Table 4: CAT cache-way allocation under interference ==");
    let ways = [1.0, 3.0, 5.0, 7.0, 12.0];
    let mut cfg = SimConfig::new(System::Vllm, model("llama3-8b"), 7.0, true);
    cfg.lengths = crate::workload::LengthModel::Fixed { input: 1024, output: 512 };
    // CAT fixes cache occupancy, not host scheduling jitter: residual
    // sensitivity stays ~4x regardless of ways (that's the takeaway).
    let wm: Vec<_> = ways
        .iter()
        .map(|w| {
            let mut c = cfg.clone();
            c.seed ^= (*w as u64) << 4;
            crate::sim::des::simulate_with_sensitivity(&c, 4.0)
        })
        .collect();
    let counters: Vec<_> = ways.iter().map(|w| CounterModel::with_ways(0.55, *w).counters()).collect();
    print!("{:<22}", "Cache ways");
    for w in ways {
        print!(" {:>9}", w as u32);
    }
    println!();
    let mut csv = String::from("metric,w1,w3,w5,w7,w12\n");
    let mut emit = |name: &str, vals: Vec<String>| {
        print!("{name:<22}");
        for v in &vals {
            print!(" {v:>9}");
        }
        println!();
        csv.push_str(&format!("{name},{}\n", vals.join(",")));
    };
    emit("LLC miss rate (%)", counters.iter().map(|c| f1(c.llc_miss_pct)).collect());
    emit("IPC", counters.iter().map(|c| f2(c.ipc)).collect());
    emit("LLC stall cycles (M)", counters.iter().map(|c| f0(c.llc_stall_cycles_m)).collect());
    emit("dTLB load misses (M)", counters.iter().map(|c| f1(c.dtlb_load_misses_m)).collect());
    emit("walk_active (M)", counters.iter().map(|c| f0(c.walk_active_m)).collect());
    emit("P99 TTFT (ms)", wm.iter().map(|m| f0(m.ttft.p99)).collect());
    emit("P99 TPOT (ms)", wm.iter().map(|m| f1(m.tpot.p99)).collect());
    emit("P99 ITL (ms)", wm.iter().map(|m| f1(m.itl.p99)).collect());
    println!("(paper: miss rate 57.6->6.8 %, yet P99 ITL flat 53-56 ms: cache is not the bottleneck)");
    ctx.write_csv("table4.csv", &csv);
}

// ---------------------------------------------------------------------------
// Table 6 / Table 7 — pre-saturation summaries (iso / interference).
// ---------------------------------------------------------------------------

pub fn table6(ctx: &EvalCtx, interference: bool) {
    let name = if interference { "Table 7" } else { "Table 6" };
    println!("\n== {name}: pre-saturation summary over BLINK's operating range{} ==",
        if interference { " (under CPU interference; brackets = vs isolation)" } else { "" });
    let mut csv = String::from("model,system,geo_p99_ttft_ms,geo_p99_tpot_ms,tput_at_sat_rps\n");
    for m in PAPER_MODELS {
        let sat = ctx.sweep.blink_saturation_level(m.name);
        println!("--- {} (operating range: λ ≤ {} req/s) ---", m.name, ctx.sweep.levels[sat]);
        println!("{:<10} {:>14} {:>14} {:>12}", "system", "geoP99 TTFT", "geoP99 TPOT", "tput@sat");
        for sys in ALL_SYSTEMS {
            let ttft = ctx.sweep.geomean_over_range(sys, m.name, interference, "ttft", "p99", sat);
            let tpot = ctx.sweep.geomean_over_range(sys, m.name, interference, "tpot", "p99", sat);
            let tput = ctx.sweep.get(sys, m.name, interference, sat).req_throughput;
            if interference {
                let ttft_i = ctx.sweep.geomean_over_range(sys, m.name, false, "ttft", "p99", sat);
                let tpot_i = ctx.sweep.geomean_over_range(sys, m.name, false, "tpot", "p99", sat);
                let tput_i = ctx.sweep.get(sys, m.name, false, sat).req_throughput;
                println!(
                    "{:<10} {:>8.1} [{:>5.2}] {:>8.1} [{:>5.2}] {:>6.2} [{:>4.2}]",
                    sys.name(), ttft, ttft / ttft_i, tpot, tpot / tpot_i, tput, tput / tput_i
                );
            } else {
                println!("{:<10} {:>14.1} {:>14.1} {:>12.2}", sys.name(), ttft, tpot, tput);
            }
            csv.push_str(&format!("{},{},{:.2},{:.2},{:.3}\n", m.name, sys.name(), ttft, tpot, tput));
        }
    }
    let fname = if interference { "table7.csv" } else { "table6.csv" };
    println!("(paper {}: BLINK best on 3/4 models, near-parity on qwen3-32b{})",
        name, if interference { "; baselines retain 0.28-0.64x" } else { "" });
    ctx.write_csv(fname, &csv);
}

// ---------------------------------------------------------------------------
// Figs 5/6/7, D.*, E.1 — curves across the load sweep.
// ---------------------------------------------------------------------------

pub fn latency_figure(ctx: &EvalCtx, fig: &str, metric: &str, pct: &str, models: &[&str]) {
    println!("\n== {fig}: {pct} {metric} curves (ms) — solid=isolated, dashed=interference ==");
    let mut csv = String::from("model,system,condition,".to_string());
    csv.push_str(&ctx.sweep.levels.iter().map(|l| format!("r{l}")).collect::<Vec<_>>().join(","));
    csv.push('\n');
    for m in models {
        for sys in ALL_SYSTEMS {
            for (cond, interf) in [("iso", false), ("int", true)] {
                let curve = ctx.sweep.latency_curve(sys, m, interf, metric, pct);
                println!(
                    "{:<14} {:<8} {:<4} {}",
                    m,
                    sys.name(),
                    cond,
                    curve.iter().map(|v| format!("{v:>9.1}")).collect::<String>()
                );
                csv.push_str(&format!(
                    "{},{},{},{}\n",
                    m,
                    sys.name(),
                    cond,
                    curve.iter().map(|v| format!("{v:.2}")).collect::<Vec<_>>().join(",")
                ));
            }
        }
    }
    ctx.write_csv(&format!("{}.csv", fig.to_lowercase().replace([' ', '.'], "_")), &csv);
}

pub fn fig7(ctx: &EvalCtx) {
    println!("\n== Figure 7: throughput (req/s) across offered load ==");
    let mut csv = String::from("model,system,condition,".to_string());
    csv.push_str(&ctx.sweep.levels.iter().map(|l| format!("r{l}")).collect::<Vec<_>>().join(","));
    csv.push('\n');
    for m in PAPER_MODELS {
        for sys in ALL_SYSTEMS {
            for (cond, interf) in [("iso", false), ("int", true)] {
                let curve = ctx.sweep.tput_curve(sys, m.name, interf);
                println!(
                    "{:<14} {:<8} {:<4} {}",
                    m.name,
                    sys.name(),
                    cond,
                    curve.iter().map(|v| format!("{v:>7.2}")).collect::<String>()
                );
                csv.push_str(&format!(
                    "{},{},{},{}\n",
                    m.name,
                    sys.name(),
                    cond,
                    curve.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>().join(",")
                ));
            }
        }
    }
    // Plateau retention summary (the paper's headline for Fig 7).
    println!("\nplateau retention (interference/isolated):");
    for m in PAPER_MODELS {
        print!("  {:<14}", m.name);
        for sys in ALL_SYSTEMS {
            let iso = ctx.sweep.tput_curve(sys, m.name, false);
            let int = ctx.sweep.tput_curve(sys, m.name, true);
            let piso = iso.iter().cloned().fold(0.0, f64::max);
            let pint = int.iter().cloned().fold(0.0, f64::max);
            print!(" {}={:.2}", sys.name(), pint / piso);
        }
        println!();
    }
    ctx.write_csv("fig7.csv", &csv);
}

// ---------------------------------------------------------------------------
// Fig 8 — energy per token.
// ---------------------------------------------------------------------------

pub fn fig8(ctx: &EvalCtx) {
    println!("\n== Figure 8: energy per token (mJ/tok) at BLINK's saturation load ==");
    println!("{:<14} {:>4}  {:>10} {:>10}", "model", "", "isolated", "interference");
    let mut csv = String::from("model,system,iso_mj_per_tok,int_mj_per_tok\n");
    for m in PAPER_MODELS {
        let sat = ctx.sweep.blink_saturation_level(m.name);
        for sys in ALL_SYSTEMS {
            let iso = ctx.sweep.get(sys, m.name, false, sat).energy_mj_per_tok;
            let int = ctx.sweep.get(sys, m.name, true, sat).energy_mj_per_tok;
            println!("{:<14} {:<8} {:>10.0} {:>10.0}", m.name, sys.name(), iso, int);
            csv.push_str(&format!("{},{},{:.1},{:.1}\n", m.name, sys.name(), iso, int));
        }
    }
    println!("(paper: BLINK 363-1306 mJ/tok iso, 13.7-48.6 % below best baseline; 41-71 % under interference)");
    ctx.write_csv("fig8.csv", &csv);
}

// ---------------------------------------------------------------------------
// Appendix: Table B.1, Table B.2, Fig C.1.
// ---------------------------------------------------------------------------

pub fn table_b1(ctx: &EvalCtx) {
    println!("\n== Table B.1: geomean P50/mean TTFT & TPOT over operating range (isolated) ==");
    println!("{:<14} {:<8} {:>10} {:>10} {:>10} {:>10}", "model", "system", "P50 TTFT", "mean TTFT", "P50 TPOT", "mean TPOT");
    let mut csv = String::from("model,system,p50_ttft,mean_ttft,p50_tpot,mean_tpot\n");
    for m in PAPER_MODELS {
        let sat = ctx.sweep.blink_saturation_level(m.name);
        for sys in ALL_SYSTEMS {
            let g = |metric: &str, pct: &str| {
                ctx.sweep.geomean_over_range(sys, m.name, false, metric, pct, sat)
            };
            println!(
                "{:<14} {:<8} {:>10.1} {:>10.1} {:>10.1} {:>10.1}",
                m.name, sys.name(), g("ttft", "p50"), g("ttft", "mean"), g("tpot", "p50"), g("tpot", "mean")
            );
            csv.push_str(&format!(
                "{},{},{:.2},{:.2},{:.2},{:.2}\n",
                m.name, sys.name(), g("ttft", "p50"), g("ttft", "mean"), g("tpot", "p50"), g("tpot", "mean")
            ));
        }
    }
    ctx.write_csv("tableB1.csv", &csv);
}

pub fn table_b2(ctx: &EvalCtx) {
    println!("\n== Table B.2: token-level throughput at BLINK's saturation point (isolated) ==");
    println!("{:<14} {:<8} {:>12} {:>12}", "model", "system", "decode tok/s", "prefill tok/s");
    let mut csv = String::from("model,system,decode_tok_s,prefill_tok_s\n");
    for m in PAPER_MODELS {
        let sat = ctx.sweep.blink_saturation_level(m.name);
        for sys in ALL_SYSTEMS {
            let wm = ctx.sweep.get(sys, m.name, false, sat);
            println!("{:<14} {:<8} {:>12.0} {:>12.0}", m.name, sys.name(), wm.decode_tok_s, wm.prefill_tok_s);
            csv.push_str(&format!("{},{},{:.1},{:.1}\n", m.name, sys.name(), wm.decode_tok_s, wm.prefill_tok_s));
        }
    }
    ctx.write_csv("tableB2.csv", &csv);
}

pub fn fig_c1(ctx: &EvalCtx) {
    println!("\n== Fig C.1: maximum serviceable load (goodput ≥ 0.95×offered) ==");
    println!("{:<14} {:<8} {:>10} {:>14}", "model", "system", "isolated", "interference");
    let mut csv = String::from("model,system,iso_rps,int_rps\n");
    for m in PAPER_MODELS {
        for sys in ALL_SYSTEMS {
            let iso = serviceable_load(&ctx.sweep.levels, &ctx.sweep.tput_curve(sys, m.name, false), 0.95);
            let int = serviceable_load(&ctx.sweep.levels, &ctx.sweep.tput_curve(sys, m.name, true), 0.95);
            println!("{:<14} {:<8} {:>10.1} {:>14.1}", m.name, sys.name(), iso, int);
            csv.push_str(&format!("{},{},{:.1},{:.1}\n", m.name, sys.name(), iso, int));
        }
    }
    println!("(paper: BLINK highest everywhere; retains full capacity under interference)");
    ctx.write_csv("figC1.csv", &csv);
}


pub fn fig_e1(ctx: &EvalCtx) {
    println!("\n== Fig E.1: token-level throughput curves (prefill / decode tok/s) ==");
    let mut csv = String::from("model,system,condition,kind,".to_string());
    csv.push_str(&ctx.sweep.levels.iter().map(|l| format!("r{l}")).collect::<Vec<_>>().join(","));
    csv.push('\n');
    for m in PAPER_MODELS {
        for sys in ALL_SYSTEMS {
            for (cond, interf) in [("iso", false), ("int", true)] {
                for kind in ["prefill", "decode"] {
                    let curve: Vec<f64> = (0..ctx.sweep.levels.len())
                        .map(|l| {
                            let wm = ctx.sweep.get(sys, m.name, interf, l);
                            if kind == "prefill" { wm.prefill_tok_s } else { wm.decode_tok_s }
                        })
                        .collect();
                    println!(
                        "{:<14} {:<8} {:<4} {:<8} {}",
                        m.name,
                        sys.name(),
                        cond,
                        kind,
                        curve.iter().map(|v| format!("{v:>8.0}")).collect::<String>()
                    );
                    csv.push_str(&format!(
                        "{},{},{},{},{}\n",
                        m.name,
                        sys.name(),
                        cond,
                        kind,
                        curve.iter().map(|v| format!("{v:.1}")).collect::<Vec<_>>().join(",")
                    ));
                }
            }
        }
    }
    ctx.write_csv("figE1.csv", &csv);
}

// ---------------------------------------------------------------------------
// Policy comparison — per-priority-class P99 TTFT across admission
// policies under the mixed interactive/batch load (not a paper figure:
// the scheduling-dimension extension enabled by the staged pipeline).
// ---------------------------------------------------------------------------

pub fn policy_comparison(
    out: Option<&Path>,
    window_s: f64,
    threads: usize,
    only: Option<PolicyKind>,
) {
    eprintln!("[eval] running policy sweep ({} s windows, {} threads) ...", window_s, threads);
    let t = std::time::Instant::now();
    let r = run_policy_sweep(LLAMA3_8B, window_s, threads, only);
    eprintln!("[eval] policy sweep done in {:.1}s", t.elapsed().as_secs_f64());

    // Report against the mix the sweep actually simulated.
    let total_weight: f64 = r.mix.classes.iter().map(|c| c.weight).sum();
    let mix_desc: Vec<String> = r
        .mix
        .classes
        .iter()
        .map(|c| {
            format!(
                "{:.0}% {} (prio {}{})",
                100.0 * c.weight / total_weight,
                c.name,
                c.priority,
                if c.ttft_budget_ms > 0.0 {
                    format!(", {:.0} ms TTFT SLO", c.ttft_budget_ms)
                } else {
                    String::new()
                }
            )
        })
        .collect();
    println!("\n== Policy comparison: {} on Blink, {} ==", r.model.name, mix_desc.join(" + "));
    let inter_prio =
        r.mix.classes.iter().map(|c| c.priority).max().unwrap_or(0);
    let batch_prio =
        r.mix.classes.iter().map(|c| c.priority).min().unwrap_or(0);

    println!(
        "{:<14} {:>7} {:>16} {:>16} {:>10} {:>10}",
        "policy", "load", "inter P99 TTFT", "batch P99 TTFT", "inter SLO", "completed"
    );
    let mut csv = String::from(
        "policy,load_rps,interactive_p99_ttft_ms,batch_p99_ttft_ms,interactive_slo_attainment,completed\n",
    );
    for &p in &r.policies {
        for (level, rate) in r.levels.iter().enumerate() {
            let wm = r.get(p, level);
            let inter = wm.class(inter_prio);
            let batch = wm.class(batch_prio);
            let it = inter.map(|c| c.ttft.p99).unwrap_or(f64::NAN);
            let bt = batch.map(|c| c.ttft.p99).unwrap_or(f64::NAN);
            let slo = inter.map(|c| c.slo_attainment).unwrap_or(f64::NAN);
            println!(
                "{:<14} {:>7} {:>13.0} ms {:>13.0} ms {:>9.0}% {:>10}",
                p.name(),
                rate,
                it,
                bt,
                slo * 100.0,
                wm.completed
            );
            csv.push_str(&format!(
                "{},{},{:.1},{:.1},{:.3},{}\n",
                p.name(),
                rate,
                it,
                bt,
                slo,
                wm.completed
            ));
        }
    }

    // The headline: at the saturating end of the sweep, FCFS starves the
    // interactive class while the class-aware policies hold its P99 TTFT.
    if only.is_none() {
        let sat = r.levels.len() - 1;
        let p99 = |p: PolicyKind| {
            r.get(p, sat).class(inter_prio).map(|c| c.ttft.p99).unwrap_or(f64::INFINITY)
        };
        let fcfs = p99(PolicyKind::Fcfs);
        let aged = p99(PolicyKind::PriorityAged);
        let slo = p99(PolicyKind::SloAware);
        println!(
            "\nat {} req/s (saturating): interactive P99 TTFT — fcfs {:.0} ms, \
             priority-aged {:.0} ms ({:.1}x better), slo {:.0} ms ({:.1}x better)",
            r.levels[sat],
            fcfs,
            aged,
            fcfs / aged.max(1e-9),
            slo,
            fcfs / slo.max(1e-9),
        );
    }

    if let Some(dir) = out {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("[eval] cannot create {}: {e}", dir.display());
            return;
        }
        let path = dir.join("policy_comparison.csv");
        match std::fs::write(&path, csv) {
            Ok(()) => eprintln!("[eval] wrote {}", path.display()),
            Err(e) => eprintln!("[eval] failed to write {}: {e}", path.display()),
        }
    }
}

// ---------------------------------------------------------------------------
// Prefix comparison — multi-turn chat workload, prefix-aware KV reuse on
// vs off (not a paper figure: the DESIGN.md §7 extension; the paper
// itself runs every system with prefix caching disabled).
// ---------------------------------------------------------------------------

/// The `prefix_comparison.csv` content for a finished prefix sweep —
/// separated from the printing so reproducibility is testable: a fixed
/// seed must yield a *byte-identical* CSV across runs (the DES is
/// deterministic and the sweep's thread sharding only races on point
/// insertion order, never on point values; rows are emitted in level
/// order here). Pinned by `prefix_eval_csv_is_deterministic`, the
/// baseline for comparing live offset-graph numbers against the DES.
pub fn prefix_csv(r: &crate::sim::sweep::PrefixSweepResults) -> String {
    let mut csv = String::from(
        "load_sessions_per_s,condition,mean_ttft_ms,p99_ttft_ms,req_throughput,completed,\
         prefix_hits,prefix_lookups,hit_tokens,input_tokens,hit_ratio,evicted_tokens\n",
    );
    for (level, rate) in r.levels.iter().enumerate() {
        let cold = r.get(false, level);
        let hit = r.get(true, level);
        for (cond, wm) in [("no-reuse", cold), ("reuse", hit)] {
            csv.push_str(&format!(
                "{},{},{:.1},{:.1},{:.3},{},{},{},{},{},{:.3},{}\n",
                rate,
                cond,
                wm.ttft.mean,
                wm.ttft.p99,
                wm.req_throughput,
                wm.completed,
                wm.prefix.hits,
                wm.prefix.lookups,
                wm.prefix.hit_tokens,
                wm.prefix.input_tokens,
                wm.prefix.hit_ratio(),
                wm.prefix.evicted_tokens,
            ));
        }
    }
    csv
}

pub fn prefix_comparison(out: Option<&Path>, window_s: f64, threads: usize) {
    eprintln!("[eval] running prefix sweep ({} s windows, {} threads) ...", window_s, threads);
    let t = std::time::Instant::now();
    let r = run_prefix_sweep(LLAMA3_8B, window_s, threads);
    eprintln!("[eval] prefix sweep done in {:.1}s", t.elapsed().as_secs_f64());

    println!(
        "\n== Prefix reuse: {} on Blink, multi-turn chat ({}-token system prompt, \
         ~{:.0} turns/session, {:.1} s think time) ==",
        r.model.name,
        r.mix.system_prompt_tokens,
        1.0 / (1.0 - r.mix.continue_prob),
        r.mix.think_time_s,
    );
    println!(
        "{:<9} {:>14} {:>14} {:>9} {:>14} {:>14} {:>8} {:>10} {:>10}",
        "sess/s",
        "cold mean TTFT",
        "cold P99 TTFT",
        "cold r/s",
        "hit mean TTFT",
        "hit P99 TTFT",
        "hit r/s",
        "hit ratio",
        "evict tok"
    );
    let csv = prefix_csv(&r);
    for (level, rate) in r.levels.iter().enumerate() {
        let cold = r.get(false, level);
        let hit = r.get(true, level);
        println!(
            "{:<9} {:>11.0} ms {:>11.0} ms {:>9.2} {:>11.0} ms {:>11.0} ms {:>8.2} {:>9.0}% {:>10}",
            rate,
            cold.ttft.mean,
            cold.ttft.p99,
            cold.req_throughput,
            hit.ttft.mean,
            hit.ttft.p99,
            hit.req_throughput,
            hit.prefix.hit_ratio() * 100.0,
            hit.prefix.evicted_tokens,
        );
    }

    // Headline: the mid-sweep improvement (the acceptance criterion —
    // ≥2x mean TTFT at ≥50 % hit ratio — is pinned by a sweep test).
    let mid = r.levels.len() / 2;
    let cold = r.get(false, mid);
    let hit = r.get(true, mid);
    println!(
        "\nat {} sessions/s: mean TTFT {:.0} ms -> {:.0} ms ({:.1}x) at {:.0}% token hit \
         ratio; O(history) prefill becomes O(new tokens)",
        r.levels[mid],
        cold.ttft.mean,
        hit.ttft.mean,
        cold.ttft.mean / hit.ttft.mean.max(1e-9),
        hit.prefix.hit_ratio() * 100.0,
    );
    // Roofline cross-check: the cost model's predicted per-request
    // prefill cut at the observed mean prompt/hit sizes.
    let cm = CostModel::new(r.model);
    let lookups = hit.prefix.lookups.max(1);
    let mean_input = (hit.prefix.input_tokens / lookups) as usize;
    let mean_hit = (hit.prefix.hit_tokens / lookups) as usize;
    println!(
        "roofline: mean per-request prefill {:.1} ms cold -> {:.1} ms with the {}-token \
         mean cached prefix",
        cm.prefill_s(mean_input) * 1e3,
        cm.prefill_with_prefix_s(mean_input, mean_hit) * 1e3,
        mean_hit,
    );

    if let Some(dir) = out {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("[eval] cannot create {}: {e}", dir.display());
            return;
        }
        let path = dir.join("prefix_comparison.csv");
        match std::fs::write(&path, csv) {
            Ok(()) => eprintln!("[eval] wrote {}", path.display()),
            Err(e) => eprintln!("[eval] failed to write {}: {e}", path.display()),
        }
    }
}

// ---------------------------------------------------------------------------
// Chunked-prefill comparison — P99 TPOT/TTFT across per-iteration chunk
// budgets on the heavy-tailed long-prompt workload (not a paper figure:
// the paper serves whole-prompt prefill, which is exactly the §3.1
// head-of-line regime this extension bounds).
// ---------------------------------------------------------------------------

/// The `chunked_comparison.csv` content for a finished chunked sweep —
/// separated from the printing so reproducibility is testable (fixed
/// seed ⇒ byte-identical CSV, like `prefix_csv`). Budget 0 is the
/// whole-prompt baseline row.
pub fn chunked_csv(r: &crate::sim::sweep::ChunkedSweepResults) -> String {
    let mut csv = String::from(
        "chunk_budget_tokens,mean_ttft_ms,p99_ttft_ms,mean_tpot_ms,p99_tpot_ms,p99_itl_ms,\
         req_throughput,completed,chunked_prefills,chunk_launches,hide_point_tokens\n",
    );
    // The recalibrated cost model's hide point at the saturated decode
    // batch: the largest chunk that rides the decode weight sweep for
    // free (`CostModel::hide_point_tokens`). A derived constant, so the
    // same value lands on every row — the column exists so a CSV reader
    // can place each budget relative to the boundary without also
    // loading the cost model.
    let hide = CostModel::new(r.model).hide_point_tokens(16);
    for (level, &budget) in r.budgets.iter().enumerate() {
        let wm = r.get(level);
        csv.push_str(&format!(
            "{},{:.1},{:.1},{:.2},{:.2},{:.2},{:.3},{},{},{},{}\n",
            budget,
            wm.ttft.mean,
            wm.ttft.p99,
            wm.tpot.mean,
            wm.tpot.p99,
            wm.itl.p99,
            wm.req_throughput,
            wm.completed,
            wm.chunked.chunked_prefills,
            wm.chunked.chunk_launches,
            hide,
        ));
    }
    csv
}

pub fn chunked_comparison(out: Option<&Path>, window_s: f64, threads: usize) {
    eprintln!("[eval] running chunked sweep ({} s windows, {} threads) ...", window_s, threads);
    let t = std::time::Instant::now();
    let r = run_chunked_sweep(LLAMA3_8B, window_s, threads);
    eprintln!("[eval] chunked sweep done in {:.1}s", t.elapsed().as_secs_f64());

    println!(
        "\n== Chunked prefill: {} on Blink at {} req/s, {:.0}% document prompts \
         (4–8k tokens) over a chat majority ==",
        r.model.name,
        r.rate,
        r.mix.long_frac * 100.0,
    );
    println!(
        "{:>8} {:>14} {:>13} {:>13} {:>12} {:>10} {:>9} {:>8}",
        "budget", "mean TTFT", "P99 TTFT", "P99 TPOT", "P99 ITL", "req/s", "chunked", "chunks"
    );
    let csv = chunked_csv(&r);
    for (level, &budget) in r.budgets.iter().enumerate() {
        let wm = r.get(level);
        println!(
            "{:>8} {:>11.0} ms {:>10.0} ms {:>10.2} ms {:>9.2} ms {:>10.2} {:>9} {:>8}",
            if budget == 0 { "whole".to_string() } else { budget.to_string() },
            wm.ttft.mean,
            wm.ttft.p99,
            wm.tpot.p99,
            wm.itl.p99,
            wm.req_throughput,
            wm.chunked.chunked_prefills,
            wm.chunked.chunk_launches,
        );
    }

    // Headline: the best budget against the whole-prompt baseline.
    let whole = r.get(0);
    let best = (1..r.budgets.len())
        .min_by(|&a, &b| r.get(a).tpot.p99.total_cmp(&r.get(b).tpot.p99))
        .expect("non-empty budget levels");
    let bw = r.get(best);
    println!(
        "\nbest budget {}: P99 TPOT {:.2} ms vs whole-prompt {:.2} ms ({:.1}x) — a bounded \
         chunk rides the decode weight sweep, a whole document prefill stalls every lane; \
         document TTFT pays the difference ({:.0} ms vs {:.0} ms mean)",
        r.budgets[best],
        bw.tpot.p99,
        whole.tpot.p99,
        whole.tpot.p99 / bw.tpot.p99.max(1e-9),
        bw.ttft.mean,
        whole.ttft.mean,
    );
    println!(
        "hide point: chunks up to {} tokens ride a saturated (b=16) decode step for free \
         on {} (CostModel::hide_point_tokens; larger chunks pay the MXU excess)",
        CostModel::new(r.model).hide_point_tokens(16),
        r.model.name,
    );

    if let Some(dir) = out {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("[eval] cannot create {}: {e}", dir.display());
            return;
        }
        let path = dir.join("chunked_comparison.csv");
        match std::fs::write(&path, csv) {
            Ok(()) => eprintln!("[eval] wrote {}", path.display()),
            Err(e) => eprintln!("[eval] failed to write {}: {e}", path.display()),
        }
    }
}

fn f0(x: f64) -> String {
    format!("{x:.0}")
}
fn f1(x: f64) -> String {
    format!("{x:.1}")
}
fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Table 5 is the hardware configuration — documentation, not measurement.
pub fn table5() {
    println!("\n== Table 5: hardware configuration (paper testbed vs this reproduction) ==");
    println!("{:<12} {:<44} {:<}", "component", "paper", "this repo (simulated/substituted)");
    for (c, p, r) in [
        ("GPU", "NVIDIA H100 (96 GB HBM3)", "CPU PJRT client + roofline cost model (sim)"),
        ("CPU", "2x Xeon Gold 6336Y, DVFS off", "host threads + live interferers (hostsim)"),
        ("DRAM", "256 GB DDR5", "n/a"),
        ("Network", "ConnectX-6 (200 Gbps)", "rdma module: 200 Gbps / 2 µs verb model"),
        ("DPU", "BlueField-3 (16 ARM A78, 32 GB)", "frontend threads + SWAR tokenizer"),
        ("OS", "Linux 5.15 (Ubuntu 22.04)", std::env::consts::OS),
    ] {
        println!("{c:<12} {p:<44} {r}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Determinism golden test: `blink eval prefix` with a fixed seed
    /// must produce a byte-identical CSV across two in-process runs —
    /// the DES and the sharded sweep are fully reproducible, which is
    /// the precondition for comparing live offset-graph numbers against
    /// simulated ones.
    #[test]
    fn prefix_eval_csv_is_deterministic() {
        let a = run_prefix_sweep(LLAMA3_8B, 6.0, 3);
        let b = run_prefix_sweep(LLAMA3_8B, 6.0, 3);
        let (ca, cb) = (prefix_csv(&a), prefix_csv(&b));
        assert!(!ca.is_empty() && ca.lines().count() > a.levels.len());
        assert_eq!(ca, cb, "prefix sweep CSV must be byte-identical across runs");
    }

    /// Same reproducibility bar for `blink eval chunked`: fixed seed ⇒
    /// byte-identical CSV, so budget curves can be compared across runs
    /// and machines.
    #[test]
    fn chunked_eval_csv_is_deterministic() {
        let a = run_chunked_sweep(LLAMA3_8B, 6.0, 3);
        let b = run_chunked_sweep(LLAMA3_8B, 6.0, 3);
        let (ca, cb) = (chunked_csv(&a), chunked_csv(&b));
        assert_eq!(ca.lines().count(), a.budgets.len() + 1, "header + one row per budget");
        assert_eq!(ca, cb, "chunked sweep CSV must be byte-identical across runs");
    }

    /// The CSV's `hide_point_tokens` column, the DES chunk cost, and
    /// the cost model must tell one story: the reported value is the
    /// exact boundary where `decode_step_with_chunk_s` stops equalling
    /// the plain decode step.
    #[test]
    fn chunked_csv_hide_point_agrees_with_cost_model() {
        let r = run_chunked_sweep(LLAMA3_8B, 6.0, 3);
        let csv = chunked_csv(&r);
        let cm = CostModel::new(LLAMA3_8B);
        let h = cm.hide_point_tokens(16);
        assert_eq!(h, 128, "recalibrated llama3-8b hide point at b=16");
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert!(header.ends_with(",hide_point_tokens"), "{header}");
        for row in lines {
            assert!(row.ends_with(&format!(",{h}")), "row must carry the hide point: {row}");
        }
        // The derived value is the true boundary in the DES chunk cost.
        let plain = cm.decode_step_s(16, 1200.0);
        assert_eq!(cm.decode_step_with_chunk_s(16, 1200.0, h), plain);
        assert!(cm.decode_step_with_chunk_s(16, 1200.0, h + 1) > plain);
    }
}
