//! The headline reproduction: stability under CPU interference (paper
//! Fig 1, §6.3), live and modeled.
//!
//! Blink's signature result is that a device-plane control loop does not
//! care what the host CPUs are doing: colocated antagonists degrade
//! CPU-resident baselines by up to two orders of magnitude while Blink
//! holds flat. This module demonstrates that end-to-end as a scenario
//! grid over
//!
//! * **model**: dense (`modeled-tiny`) vs MoE (`modeled-tiny-moe`, 4
//!   experts top-2 — the sparse path pays a per-step expert-dispatch tax
//!   in the modeled executor);
//! * **placement**: `gpu` ([`Placement::GpuResident`], the overlapped
//!   device-plane loop) vs `host` ([`Placement::CpuResident`], the
//!   deliberately host-driven baseline whose every iteration runs
//!   [`HostOrchestrator`](crate::hostsim::HostOrchestrator) work on the
//!   host heap);
//! * **antagonist intensity**: 0, ½, 1 — mapped to a mean host-work
//!   multiplier of `1 + 7·intensity` (8× at full tilt, the shape of the
//!   paper's 24× pbzip2 antagonist scaled to the tiny testbed).
//!
//! Two antagonist channels exist and they serve different purposes
//! (DESIGN.md §8): a *live* [`Interferer`](crate::hostsim::Interferer)
//! produces real LLC/TLB contention but host-dependent timing, while
//! the *deterministic* channel
//! ([`HostOrchestrator::set_contention`](crate::hostsim::HostOrchestrator::set_contention))
//! inflates the orchestrator's work by samples from a seeded
//! [`InterferenceProcess`] so time scales with work and CI can assert
//! inflation *ratios*. The golden-tested `interference.csv` comes from a
//! fully virtual-time model of the control loop (byte-deterministic at a
//! fixed seed — wall clocks never enter it); the live cells run the real
//! ring → scheduler → modeled-executor pipeline and report measured
//! values in `interference_live.csv`, which is *not* golden-tested.
//!
//! Energy per token is wired to both via
//! [`PowerModel::mj_per_token_live`]: wall power decomposed into base +
//! GPU swing + host share + antagonist draw (scaled by intensity) + DPU,
//! divided by measured throughput.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::eval::live::{modeled_manifest, modeled_moe_manifest};
use crate::gpu::executor::expected_active_experts;
use crate::gpu::{
    Executor, HostContention, ModeledCost, Placement, PrefixReuse, Scheduler, SchedulerConfig,
};
use crate::ringbuf::{RingBuffer, RingConfig, SlotState};
use crate::sim::energy::PowerModel;
use crate::sim::interference::InterferenceProcess;
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;
use crate::workload::{RequestMetrics, WindowMetrics};

/// Antagonist intensities the suite sweeps (the acceptance grid needs
/// ≥ 3 so the curve's *shape* — flat vs exploding — is visible).
pub const INTENSITIES: [f64; 3] = [0.0, 0.5, 1.0];

/// Intensity → mean host-work multiplier: 8× at full intensity. The
/// paper's 24× antagonist collapses a host-driven stack outright; 8×
/// keeps the tiny testbed's cells fast while leaving the ≥3×-vs-<1.5×
/// headline margin wide.
pub fn contention_mean(intensity: f64) -> f64 {
    1.0 + 7.0 * intensity.clamp(0.0, 1.0)
}

/// One cell of the scenario grid.
#[derive(Debug, Clone, Copy)]
pub struct CellSpec {
    pub moe: bool,
    /// Host-driven control loop (the baseline) vs device-plane loop.
    pub host: bool,
    pub intensity: f64,
}

impl CellSpec {
    pub fn model(&self) -> &'static str {
        if self.moe {
            "moe"
        } else {
            "dense"
        }
    }

    pub fn placement(&self) -> &'static str {
        if self.host {
            "host"
        } else {
            "gpu"
        }
    }
}

/// The full {dense, moe} × {gpu, host} × intensity grid, in CSV row order.
pub fn cell_grid() -> Vec<CellSpec> {
    let mut cells = vec![];
    for moe in [false, true] {
        for host in [false, true] {
            for intensity in INTENSITIES {
                cells.push(CellSpec { moe, host, intensity });
            }
        }
    }
    cells
}

/// Per-cell results — shared between the modeled sweep and the live
/// runner so both serialize through [`interference_csv`].
#[derive(Debug, Clone)]
pub struct Cell {
    pub spec: CellSpec,
    /// Control-overhead percentiles (loop top → decode launch, µs).
    pub loop_p50_us: f64,
    pub loop_p99_us: f64,
    /// Full-iteration percentiles (control + executor step, µs).
    pub iter_p50_us: f64,
    pub iter_p99_us: f64,
    pub ttft_p99_ms: f64,
    pub tpot_p99_ms: f64,
    pub tok_per_s: f64,
    pub energy_mj_per_tok: f64,
}

// ---------------------------------------------------------------------------
// Modeled cells: a virtual-time model of the control loop. Deterministic
// by construction — no wall clock anywhere — which is what makes the
// golden byte-determinism test possible. The loop structure mirrors the
// live scheduler: control work at the loop top, paused-admission prefill
// in batches, one decode step per iteration over the live lanes.
// ---------------------------------------------------------------------------

/// Modeled per-iteration control work, µs. The device-plane loop's
/// control share (ring scan + staging + launch enqueue) is a few µs and
/// — the design point — contains no host-heap work to inflate.
const GPU_CONTROL_US: f64 = 5.0;
/// The host-driven baseline's per-iteration orchestration (batch
/// reassembly and bookkeeping over the host heap); this is what the
/// antagonist multiplies.
const HOST_ORCH_US: f64 = 400.0;
const DECODE_STEP_US: f64 = 200.0;
const PREFILL_US_PER_TOKEN: f64 = 50.0;
const EXPERT_DISPATCH_US: f64 = 40.0;
/// MoE routing geometry of `modeled-tiny-moe`.
const MOE_EXPERTS: usize = 4;
const MOE_TOP_K: usize = 2;

/// Modeled workload: all requests arrive at t = 0, prefill admits in
/// grid-sized batches, decode runs the batch to completion.
const MODELED_REQUESTS: usize = 16;
const MODELED_INPUT: usize = 64;
const MODELED_OUTPUT: usize = 32;

/// Run one modeled cell in virtual time. Same `(spec, seed)` ⇒ identical
/// results on every host and platform.
pub fn run_modeled_cell(spec: &CellSpec, seed: u64) -> Cell {
    let (max_batch, prefill_batch) = if spec.moe { (8, 2) } else { (16, 4) };
    let mut rng = Rng::new(seed);
    let mean = contention_mean(spec.intensity);
    let process = if spec.host && mean > 1.0 {
        InterferenceProcess::new(mean, &mut rng)
    } else {
        InterferenceProcess::none()
    };

    let n = MODELED_REQUESTS;
    let mut t_us = 0.0f64;
    let mut busy_us = 0.0f64; // device-plane busy (prefill + decode)
    let mut ctrl_us_sum = 0.0f64;
    let mut pending = n;
    let mut lanes: Vec<(usize, usize)> = Vec::new(); // (request, generated)
    let mut first_s = vec![0.0f64; n];
    let mut finish_s = vec![0.0f64; n];
    let mut ctrl_samples: Vec<f64> = Vec::new();
    let mut iter_samples: Vec<f64> = Vec::new();
    let mut iter_idx = 0u64;

    while pending > 0 || !lanes.is_empty() {
        // Control work at the loop top. The host placement's share is
        // inflated by the seeded antagonist process (10 ms of virtual
        // time per iteration drives its phase wander, matching
        // HostOrchestrator::step_work); the device-plane share has no
        // host-heap work for the antagonist to touch.
        let ctrl = if spec.host {
            HOST_ORCH_US * process.sample(iter_idx as f64 * 0.01, &mut rng)
        } else {
            GPU_CONTROL_US
        };
        iter_idx += 1;
        t_us += ctrl;
        ctrl_us_sum += ctrl;
        ctrl_samples.push(ctrl);

        // Paused-admission prefill, one grid batch per iteration. The
        // prefill launch publishes each lane's first token, so TTFT is
        // stamped at prefill completion — same as the live ring.
        if pending > 0 && lanes.len() < max_batch {
            let admit = prefill_batch.min(pending).min(max_batch - lanes.len());
            let pf = PREFILL_US_PER_TOKEN * (admit * MODELED_INPUT) as f64;
            t_us += pf;
            busy_us += pf;
            for _ in 0..admit {
                let id = n - pending;
                first_s[id] = t_us / 1e6;
                lanes.push((id, 0));
                pending -= 1;
            }
        }

        // One decode step over the live batch; MoE pays the dispatch tax
        // for the expected expert union at this batch size.
        if !lanes.is_empty() {
            let b = lanes.len();
            let dispatch = if spec.moe {
                EXPERT_DISPATCH_US * expected_active_experts(MOE_EXPERTS, MOE_TOP_K, b)
            } else {
                0.0
            };
            let step = DECODE_STEP_US + dispatch;
            t_us += step;
            busy_us += step;
            iter_samples.push(ctrl + step);
            let mut i = 0;
            while i < lanes.len() {
                lanes[i].1 += 1;
                if lanes[i].1 >= MODELED_OUTPUT {
                    finish_s[lanes[i].0] = t_us / 1e6;
                    lanes.swap_remove(i);
                } else {
                    i += 1;
                }
            }
        }
    }

    let window_s = t_us / 1e6;
    let reqs: Vec<RequestMetrics> = (0..n)
        .map(|id| RequestMetrics {
            id: id as u64,
            arrival_s: 0.0,
            first_token_s: first_s[id],
            finish_s: finish_s[id],
            input_tokens: MODELED_INPUT,
            output_tokens: MODELED_OUTPUT,
            itl_s: vec![],
            priority: 0,
            ttft_budget_s: 0.0,
        })
        .collect();
    let wm = WindowMetrics::from_requests(n as f64 / window_s, window_s, &reqs);

    ctrl_samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    iter_samples.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // Energy: the device plane's utilization is its busy share of the
    // makespan; the host share is the orchestration's busy fraction
    // (one hot core plus what contention adds) on the host placement
    // and near-idle on the device placement. The device-plane stack
    // fronts through the DPU (Blink's BlueField draw); the host-driven
    // baseline has no DPU. Antagonist draw scales with intensity.
    let gpu_util = busy_us / t_us;
    let host_util = if spec.host { (ctrl_us_sum / t_us).min(1.0) } else { 0.02 };
    let dpu_w = if spec.host { 0.0 } else { 75.0 };
    let tok_per_s = wm.decode_tok_s;
    let energy = PowerModel::default()
        .mj_per_token_live(gpu_util, host_util, dpu_w, spec.intensity, tok_per_s);

    Cell {
        spec: *spec,
        loop_p50_us: percentile_sorted(&ctrl_samples, 50.0),
        loop_p99_us: percentile_sorted(&ctrl_samples, 99.0),
        iter_p50_us: percentile_sorted(&iter_samples, 50.0),
        iter_p99_us: percentile_sorted(&iter_samples, 99.0),
        ttft_p99_ms: wm.ttft.p99,
        tpot_p99_ms: wm.tpot.p99,
        tok_per_s,
        energy_mj_per_tok: energy,
    }
}

/// The full modeled grid at a fixed seed (per-cell sub-seeds are derived
/// by index, so cells are independent but the whole sweep is one seed).
pub fn modeled_cells(seed: u64) -> Vec<Cell> {
    cell_grid()
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            run_modeled_cell(spec, seed.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9)))
        })
        .collect()
}

/// Serialize cells to the suite's CSV (stable column order; the golden
/// test pins these bytes at a fixed seed).
pub fn interference_csv(cells: &[Cell]) -> String {
    let mut csv = String::from(
        "model,placement,intensity,loop_iter_p50_us,loop_iter_p99_us,iter_full_p50_us,\
         iter_full_p99_us,ttft_p99_ms,tpot_p99_ms,tok_per_s,energy_mj_per_tok\n",
    );
    for c in cells {
        csv.push_str(&format!(
            "{},{},{:.2},{:.2},{:.2},{:.2},{:.2},{:.3},{:.4},{:.1},{:.2}\n",
            c.spec.model(),
            c.spec.placement(),
            c.spec.intensity,
            c.loop_p50_us,
            c.loop_p99_us,
            c.iter_p50_us,
            c.iter_p99_us,
            c.ttft_p99_ms,
            c.tpot_p99_ms,
            c.tok_per_s,
            c.energy_mj_per_tok,
        ));
    }
    csv
}

// ---------------------------------------------------------------------------
// Live cells: the real ring → scheduler → modeled-executor pipeline under
// the deterministic antagonist channel. Wall-clock measured — printed and
// written to interference_live.csv, never golden-tested (DESIGN.md §8:
// on shared CI hosts only *ratios* are assertable, and the tier-1 test
// asserts exactly those).
// ---------------------------------------------------------------------------

/// Knobs for one live run. `eval()` is the eval-suite sizing; the tier-1
/// ratio test uses heavier decode/orchestration costs so OS noise is
/// small relative to every iteration.
#[derive(Debug, Clone, Copy)]
pub struct LiveParams {
    pub requests: usize,
    pub input_tokens: usize,
    pub output_tokens: usize,
    pub decode_step_us: f64,
    pub prefill_us_per_token: f64,
    pub expert_dispatch_us: f64,
    /// Host-driven baseline's orchestrator sizing.
    pub scratch_mb: usize,
    pub touches_per_step: usize,
    pub seed: u64,
}

impl LiveParams {
    pub fn eval() -> LiveParams {
        LiveParams {
            requests: 8,
            input_tokens: 64,
            output_tokens: 48,
            decode_step_us: DECODE_STEP_US,
            prefill_us_per_token: PREFILL_US_PER_TOKEN,
            expert_dispatch_us: EXPERT_DISPATCH_US,
            scratch_mb: 4,
            touches_per_step: 60_000,
            seed: 7,
        }
    }

    pub fn smoke() -> LiveParams {
        LiveParams { requests: 4, output_tokens: 16, ..LiveParams::eval() }
    }
}

/// Run one live cell: real scheduler + modeled executor, requests
/// submitted through the ring, per-request TTFT/TPOT read back off the
/// slots' device-plane timestamps.
pub fn run_live_cell(spec: &CellSpec, p: &LiveParams) -> Cell {
    let manifest = if spec.moe { modeled_moe_manifest() } else { modeled_manifest() };
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 64,
        max_prompt: 256,
        max_output: 256,
    }));
    let cost = ModeledCost {
        prefill_us_per_token: p.prefill_us_per_token,
        decode_step_us: p.decode_step_us,
        expert_dispatch_us: p.expert_dispatch_us,
        ..ModeledCost::zero()
    };
    let executor = Executor::spawn_modeled(&manifest, cost);
    let placement = if spec.host {
        Placement::CpuResident { scratch_mb: p.scratch_mb, touches_per_step: p.touches_per_step }
    } else {
        Placement::GpuResident
    };
    let mean = contention_mean(spec.intensity);
    let host_contention = (spec.host && mean > 1.0)
        .then_some(HostContention { mean, seed: p.seed ^ 0xC010_C0DE });
    let n_experts = manifest.n_experts;
    let top_k = manifest.top_k;
    let is_moe = manifest.moe;
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest,
        SchedulerConfig {
            placement,
            apply_launch_delays: false,
            prefix_reuse: PrefixReuse::Off,
            host_contention,
            ..Default::default()
        },
    );

    let mut rng = Rng::new(p.seed);
    let prompts: Vec<Vec<u32>> = (0..p.requests)
        .map(|_| (0..p.input_tokens).map(|_| rng.below(2048) as u32).collect())
        .collect();

    let t0 = Instant::now();
    for (i, prompt) in prompts.iter().enumerate() {
        assert!(ring.claim_for_write(i));
        ring.write_prompt(i, prompt);
        ring.submit(i, i as u64, prompt.len() as u32, p.output_tokens as u32, i as u32);
    }
    loop {
        let done = (0..p.requests).all(|i| {
            matches!(ring.slot(i).state(), SlotState::DecodeCompleted | SlotState::Failed)
        });
        if done {
            break;
        }
        std::thread::sleep(Duration::from_micros(500));
    }
    let makespan = t0.elapsed();
    sched.drain_and_stop();

    // Per-request metrics off the slot timestamps (stamped by the ring
    // at submit / first published token / completion), re-based to the
    // earliest submit. Relaxed loads: the DecodeCompleted state read
    // (Acquire, paired with the scheduler's Release transition) already
    // ordered these timestamp reads after the stores — and the stores
    // are Relaxed anyway, so an Acquire here would pair with nothing.
    let epoch_us = (0..p.requests)
        .map(|i| ring.slot(i).submit_time_us.load(Ordering::Relaxed))
        .min()
        .unwrap_or(0);
    let reqs: Vec<RequestMetrics> = (0..p.requests)
        .filter(|&i| ring.slot(i).state() == SlotState::DecodeCompleted)
        .map(|i| {
            let s = ring.slot(i);
            RequestMetrics::from_slot_times_us(
                i as u64,
                epoch_us,
                s.submit_time_us.load(Ordering::Relaxed),
                s.first_token_time_us.load(Ordering::Relaxed),
                s.finish_time_us.load(Ordering::Relaxed),
                p.input_tokens,
                p.output_tokens,
            )
        })
        .collect();
    let window_s = makespan.as_secs_f64().max(1e-9);
    let wm = WindowMetrics::from_requests(p.requests as f64 / window_s, window_s, &reqs);

    // Device-plane busy estimate for the power decomposition: decode
    // steps at their modeled cost (plus the expert-dispatch tax at the
    // mean live batch) and the submitted prefill tokens.
    let steps = sched.stats.decode_steps.load(Ordering::Relaxed) as f64;
    let mean_batch = sched.stats.mean_batch_occupancy().round().max(1.0) as usize;
    let dispatch = if is_moe {
        p.expert_dispatch_us * expected_active_experts(n_experts, top_k, mean_batch)
    } else {
        0.0
    };
    let busy_us = steps * (p.decode_step_us + dispatch)
        + (p.requests * p.input_tokens) as f64 * p.prefill_us_per_token;
    let gpu_util = (busy_us / (window_s * 1e6)).clamp(0.0, 1.0);
    // The live path has no perf counters; charge the modeled host share
    // (orchestration busy fraction is not separable from the makespan
    // here, so use the same placement constants the modeled cells
    // converge to: a hot host core under the baseline, near-idle host
    // under the device plane).
    let host_util = if spec.host { 0.40 } else { 0.02 };
    let dpu_w = if spec.host { 0.0 } else { 75.0 };
    let tok_per_s = wm.decode_tok_s;
    let energy = PowerModel::default()
        .mj_per_token_live(gpu_util, host_util, dpu_w, spec.intensity, tok_per_s);

    Cell {
        spec: *spec,
        loop_p50_us: sched.stats.loop_iter_p50_us(),
        loop_p99_us: sched.stats.loop_iter_p99_us(),
        iter_p50_us: sched.stats.iter_full_p50_us(),
        iter_p99_us: sched.stats.iter_full_p99_us(),
        ttft_p99_ms: wm.ttft.p99,
        tpot_p99_ms: wm.tpot.p99,
        tok_per_s,
        energy_mj_per_tok: energy,
    }
}

// ---------------------------------------------------------------------------
// The eval entry point.
// ---------------------------------------------------------------------------

fn print_cells(title: &str, cells: &[Cell]) {
    println!("\n{title}");
    println!(
        "{:<7} {:<6} {:>9} {:>12} {:>12} {:>12} {:>12} {:>10} {:>10} {:>10} {:>8}",
        "model",
        "place",
        "intensity",
        "loop_p50_us",
        "loop_p99_us",
        "iter_p50_us",
        "iter_p99_us",
        "ttft_p99",
        "tpot_p99",
        "tok/s",
        "mJ/tok"
    );
    for c in cells {
        println!(
            "{:<7} {:<6} {:>9.2} {:>12.2} {:>12.2} {:>12.2} {:>12.2} {:>10.2} {:>10.3} {:>10.1} {:>8.1}",
            c.spec.model(),
            c.spec.placement(),
            c.spec.intensity,
            c.loop_p50_us,
            c.loop_p99_us,
            c.iter_p50_us,
            c.iter_p99_us,
            c.ttft_p99_ms,
            c.tpot_p99_ms,
            c.tok_per_s,
            c.energy_mj_per_tok,
        );
    }
}

/// The grid cell matching (model, placement, intensity), if present.
fn find_cell(cells: &[Cell], moe: bool, host: bool, i: f64) -> Option<&Cell> {
    cells.iter().find(|c| c.spec.moe == moe && c.spec.host == host && c.spec.intensity == i)
}

/// P99 inflation of max-intensity cells over their isolated siblings,
/// per (model, placement) — the Fig 1 shape in two numbers per row.
fn print_inflation(cells: &[Cell], metric: fn(&Cell) -> f64, what: &str) {
    println!("\n  p99 {what} inflation at max antagonist intensity (vs isolated):");
    for moe in [false, true] {
        for host in [false, true] {
            let pick = |i: f64| find_cell(cells, moe, host, i);
            if let (Some(iso), Some(hot)) = (pick(0.0), pick(1.0)) {
                let ratio = metric(hot) / metric(iso).max(1e-9);
                println!(
                    "    {:<7} {:<6} {:>6.2}x  {}",
                    iso.spec.model(),
                    iso.spec.placement(),
                    ratio,
                    if host { "(host-driven baseline)" } else { "(device-plane loop)" },
                );
            }
        }
    }
}

/// `blink eval interference [--out DIR] [--smoke]`: the deterministic
/// modeled sweep (golden CSV) followed by the live scenario grid.
pub fn interference(out: Option<&std::path::Path>, smoke: bool) {
    println!("\n== Interference & colocation suite (paper Fig 1 / §6.3) ==");
    println!("(host-driven placement collapses under antagonist load; the device-plane loop holds)");

    let seed = 7u64;
    let modeled = modeled_cells(seed);
    print_cells("-- modeled cells (virtual time, byte-deterministic at fixed seed) --", &modeled);
    print_inflation(&modeled, |c| c.loop_p99_us, "control-overhead");
    super::live::write_out(out, "interference.csv", &interference_csv(&modeled));

    let params = if smoke { LiveParams::smoke() } else { LiveParams::eval() };
    println!(
        "\n-- live cells (real scheduler + modeled executor; {} req x {} out per cell) --",
        params.requests, params.output_tokens
    );
    let live: Vec<Cell> = cell_grid().iter().map(|s| run_live_cell(s, &params)).collect();
    print_cells("-- live cells (wall-clock; ratios are the stable signal) --", &live);
    print_inflation(&live, |c| c.iter_p99_us, "full-iteration");
    super::live::write_out(out, "interference_live.csv", &interference_csv(&live));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interference_csv_is_deterministic() {
        // Same seed ⇒ identical bytes (the acceptance criterion, same
        // contract as prefix_eval_csv_is_deterministic). The modeled
        // sweep runs in virtual time, so this holds on any machine.
        let a = interference_csv(&modeled_cells(7));
        let b = interference_csv(&modeled_cells(7));
        assert_eq!(a, b, "same seed must produce identical CSV bytes");
        let c = interference_csv(&modeled_cells(8));
        assert_ne!(a, c, "the seed must actually drive the antagonist");
    }

    #[test]
    fn interference_csv_covers_the_acceptance_grid() {
        let csv = interference_csv(&modeled_cells(7));
        let header = csv.lines().next().unwrap();
        for col in
            ["loop_iter_p99_us", "ttft_p99_ms", "tpot_p99_ms", "energy_mj_per_tok", "tok_per_s"]
        {
            assert!(header.contains(col), "missing column {col}: {header}");
        }
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 2 * 2 * INTENSITIES.len(), "{{dense,moe}} x {{gpu,host}} x 3");
        for model in ["dense", "moe"] {
            for place in ["gpu", "host"] {
                for i in INTENSITIES {
                    let prefix = format!("{model},{place},{i:.2},");
                    assert!(rows.iter().any(|r| r.starts_with(&prefix)), "missing cell {prefix}");
                }
            }
        }
    }

    #[test]
    fn modeled_cells_pin_headline_shape() {
        // The Fig 1 shape, deterministically: under the max-intensity
        // antagonist the host-driven placement's control p99 inflates
        // hard while the device-plane loop does not move at all.
        let cells = modeled_cells(7);
        let pick = |moe: bool, host: bool, i: f64| find_cell(&cells, moe, host, i).unwrap();
        for moe in [false, true] {
            let host_ratio = pick(moe, true, 1.0).loop_p99_us / pick(moe, true, 0.0).loop_p99_us;
            let gpu_ratio = pick(moe, false, 1.0).loop_p99_us / pick(moe, false, 0.0).loop_p99_us;
            assert!(host_ratio >= 3.0, "moe={moe}: host p99 inflation {host_ratio} < 3x");
            assert!(gpu_ratio < 1.5, "moe={moe}: gpu p99 inflation {gpu_ratio} >= 1.5x");
        }
        // The sparse path pays its dispatch tax: MoE decode iterations
        // are strictly slower than dense at the same placement.
        assert!(
            pick(true, false, 0.0).iter_p50_us > pick(false, false, 0.0).iter_p50_us,
            "expert dispatch must show up in MoE iteration cost"
        );
        // Colocation draws antagonist power: at the same placement the
        // device-plane cells pay more energy per token when the
        // antagonist runs (throughput holds, wall power rises).
        assert!(
            pick(false, false, 1.0).energy_mj_per_tok > pick(false, false, 0.0).energy_mj_per_tok,
            "interferer draw must be accounted in colocated energy"
        );
    }

    #[test]
    fn modeled_host_baseline_degrades_monotonically() {
        // Along the intensity sweep the host-driven placement's tail and
        // throughput must degrade monotonically — the curve Fig 1 plots.
        let cells = modeled_cells(7);
        for moe in [false, true] {
            let host: Vec<&Cell> =
                cells.iter().filter(|c| c.spec.moe == moe && c.spec.host).collect();
            for w in host.windows(2) {
                assert!(
                    w[1].loop_p99_us >= w[0].loop_p99_us,
                    "moe={moe}: host p99 not monotone over intensity"
                );
                assert!(
                    w[1].tok_per_s <= w[0].tok_per_s,
                    "moe={moe}: host throughput not monotone over intensity"
                );
            }
        }
    }
}
