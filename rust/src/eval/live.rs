//! Live (really-executed) mechanism experiments: Fig 3 (GPU- vs
//! CPU-resident scheduling makespan) and Fig 4 (tokenizer latency).
//! Unlike the sweep these run the actual stack on the tiny model —
//! the same compiled engine under both scheduler placements, exactly the
//! paper's controlled comparison.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::gpu::{Executor, ModeledCost, Placement, PrefixReuse, Scheduler, SchedulerConfig};
use crate::ringbuf::{RingBuffer, RingConfig, SlotState};
use crate::runtime::{artifacts_dir, ModelManifest};
use crate::tokenizer::baselines::{HeapliteTokenizer, NaiveTokenizer};
use crate::tokenizer::blink::BlinkTokenizer;
use crate::tokenizer::{Tokenizer, Vocab};
use crate::util::rng::Rng;

/// Fig 3 workloads, scaled to the tiny model's 512-token context:
/// N×I→O = N requests, I input tokens, O output tokens (batch ≤ 16).
pub const FIG3_WORKLOADS: [(usize, usize, usize); 4] =
    [(8, 64, 16), (8, 64, 32), (16, 96, 32), (16, 96, 64)];

/// Run one workload through a scheduler placement; returns the makespan
/// plus the control-overhead percentiles (loop top → decode-launch
/// enqueue, µs) — the per-iteration number the zero-allocation loop
/// budget is about: under interference the CPU-resident placement's
/// percentiles inflate while the GPU-resident ones hold.
fn run_makespan(
    model: &str,
    placement: Placement,
    n: usize,
    input: usize,
    output: usize,
) -> (Duration, f64, f64) {
    let dir = artifacts_dir();
    let manifest = ModelManifest::load(&dir.join(model).join("manifest.txt")).expect("manifest");
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 64,
        max_prompt: 256,
        max_output: 256,
    }));
    let executor = Executor::spawn(dir, model.into()).expect("executor");
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest,
        // prefix_reuse off: Fig 3 is the paper's controlled placement
        // comparison, which runs without prefix caching (DESIGN.md §7).
        SchedulerConfig {
            placement,
            apply_launch_delays: true,
            prefix_reuse: PrefixReuse::Off,
            ..Default::default()
        },
    );

    let mut rng = Rng::new(42);
    let prompts: Vec<Vec<u32>> = (0..n)
        .map(|_| (0..input).map(|_| rng.below(2048) as u32).collect())
        .collect();

    let t0 = Instant::now();
    for (i, p) in prompts.iter().enumerate() {
        assert!(ring.claim_for_write(i));
        ring.write_prompt(i, p);
        ring.submit(i, i as u64, p.len() as u32, output as u32, i as u32);
    }
    // Wait for all to complete.
    loop {
        let done = (0..n).all(|i| {
            matches!(ring.slot(i).state(), SlotState::DecodeCompleted | SlotState::Failed)
        });
        if done {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let makespan = t0.elapsed();
    for i in 0..n {
        assert_eq!(ring.slot(i).state(), SlotState::DecodeCompleted, "slot {i} failed");
        assert_eq!(ring.slot(i).generated.load(Ordering::Acquire), output as u32);
    }
    sched.drain_and_stop();
    let (p50, p99) = (sched.stats.loop_iter_p50_us(), sched.stats.loop_iter_p99_us());
    (makespan, p50, p99)
}

/// Fig 3: normalized makespan, CPU-resident vs GPU-resident scheduling on
/// identical compiled engines + identical policy.
pub fn fig3(out: Option<&std::path::Path>) {
    println!("\n== Figure 3: normalized makespan, GPU- vs CPU-resident scheduling (live, blink-tiny) ==");
    println!("(paper: CPU placement inflates makespan 1.16-1.70x on Qwen3-32B/H100; shape, not absolutes)");
    println!(
        "{:<14} {:>12} {:>12} {:>8} {:>22} {:>22}",
        "workload",
        "GPU-res (s)",
        "CPU-res (s)",
        "ratio",
        "gpu iter p50/p99 (µs)",
        "cpu iter p50/p99 (µs)"
    );
    let mut csv = String::from(
        "workload,gpu_s,cpu_s,ratio,gpu_iter_p50_us,gpu_iter_p99_us,cpu_iter_p50_us,cpu_iter_p99_us\n",
    );
    for (n, i, o) in FIG3_WORKLOADS {
        let (gpu, gp50, gp99) = run_makespan("blink-tiny", Placement::GpuResident, n, i, o);
        let (cpu, cp50, cp99) = run_makespan(
            "blink-tiny",
            // Host orchestration sized so its share of step time matches
            // the paper's CPU-resident baseline proportion (~15-30 % of a
            // decode step: per-step D2H token copy, batch reassembly on
            // the host heap, H2D + host launch). See DESIGN.md §2.
            Placement::CpuResident { scratch_mb: 16, touches_per_step: 400_000 },
            n,
            i,
            o,
        );
        let ratio = cpu.as_secs_f64() / gpu.as_secs_f64();
        let name = format!("{n}x{i}->{o}");
        println!(
            "{:<14} {:>12.2} {:>12.2} {:>8.2} {:>12.1}/{:>8.1} {:>12.1}/{:>8.1}",
            name,
            gpu.as_secs_f64(),
            cpu.as_secs_f64(),
            ratio,
            gp50,
            gp99,
            cp50,
            cp99,
        );
        csv.push_str(&format!(
            "{name},{:.4},{:.4},{ratio:.4},{gp50:.2},{gp99:.2},{cp50:.2},{cp99:.2}\n",
            gpu.as_secs_f64(),
            cpu.as_secs_f64()
        ));
    }
    write_out(out, "fig3.csv", &csv);
}

/// Fig 4: tokenizer latency across input sizes, three implementations.
pub fn fig4(out: Option<&std::path::Path>) {
    println!("\n== Figure 4: tokenization latency (live) ==");
    println!("(paper: blink 8-19.7x faster than HF stand-in; consistently above llama.cpp stand-in)");
    let vocab = Arc::new(
        Vocab::load(&artifacts_dir().join("vocab.blink")).expect("vocab (run make artifacts)"),
    );
    let blink = BlinkTokenizer::new(&vocab);
    let naive = NaiveTokenizer::new(&vocab);
    let heap = HeapliteTokenizer::new(&vocab);

    // Build text inputs sized in *tokens* (approximately), from corpus-like
    // words so merges actually fire.
    let words = ["the", "scheduler", "buffer", "request", "token", "memory", "and", "launches"];
    let mut rng = Rng::new(7);
    let text_of = |target_tokens: usize, rng: &mut Rng| -> String {
        let mut s = String::new();
        // ~1.4 tokens per word with this vocab.
        for _ in 0..(target_tokens * 5 / 7).max(1) {
            s.push(' ');
            s.push_str(words[rng.below(words.len() as u64) as usize]);
        }
        s
    };

    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "tokens", "blink (µs)", "naive (µs)", "heap (µs)", "vs naive", "vs heap"
    );
    let mut csv = String::from("tokens,blink_us,naive_us,heaplite_us\n");
    for target in [10usize, 64, 256, 1024, 2048] {
        let text = text_of(target, &mut rng);
        let mut check = vec![];
        blink.encode(&text, &mut check);
        let measure = |t: &dyn Tokenizer| {
            let mut out = Vec::with_capacity(4096);
            // Warmup.
            for _ in 0..3 {
                out.clear();
                t.encode(&text, &mut out);
            }
            let iters = (2000 / target.max(1)).clamp(5, 200);
            let t0 = Instant::now();
            for _ in 0..iters {
                out.clear();
                t.encode(&text, &mut out);
            }
            t0.elapsed().as_secs_f64() * 1e6 / iters as f64
        };
        let b = measure(&blink);
        let n = measure(&naive);
        let h = measure(&heap);
        println!(
            "{:>8} {:>12.1} {:>12.1} {:>12.1} {:>9.1}x {:>9.1}x",
            check.len(),
            b,
            n,
            h,
            n / b,
            h / b
        );
        csv.push_str(&format!("{},{b:.2},{n:.2},{h:.2}\n", check.len()));
    }
    write_out(out, "fig4.csv", &csv);
}

/// A modeled-executor manifest with the full graph grid, including the
/// offset prefill variants (what `make artifacts` now emits for
/// blink-tiny, minus the weights no modeled run needs).
pub fn modeled_manifest() -> ModelManifest {
    let mut text = String::from(
        "blink-manifest v1\nmodel modeled-tiny\nvocab_size 2048\nd_model 256\nn_layers 4\n\
         n_heads 8\nn_kv_heads 4\nd_head 32\nd_ff 704\nblock_size 16\nnum_blocks 512\n\
         max_blocks_per_seq 32\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
         param tok_embed 2048x256 f32\n",
    );
    // Backend token "modeled": no real attention runs here, and the
    // label flows through to `/metrics` so a modeled run never claims
    // to be a pallas (or ref) artifact.
    for b in [1usize, 2, 4, 8, 16] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0 modeled\n"));
    }
    for b in [1usize, 2, 4] {
        for s in [16usize, 32, 64, 128, 256] {
            text.push_str(&format!("graph prefill_b{b}_s{s} prefill {b} {s} modeled\n"));
            text.push_str(&format!(
                "graph prefill_offset_b{b}_s{s} prefill_offset {b} {s} modeled\n"
            ));
        }
    }
    ModelManifest::parse(&text).expect("modeled manifest")
}

/// The MoE sibling of [`modeled_manifest`]: blink-tiny-moe's geometry
/// (4 experts, top-2 routing, d_ff 512) over the AOT MoE graph grid —
/// the narrower batch/seq grid `python/compile/aot.py` exports for MoE
/// models. This is what makes the sparse path *servable* without
/// artifacts: `Executor::spawn_modeled` reads `moe`/`n_experts`/`top_k`
/// off this manifest and charges the expert-dispatch tax per decode
/// step.
pub fn modeled_moe_manifest() -> ModelManifest {
    let mut text = String::from(
        "blink-manifest v1\nmodel modeled-tiny-moe\nvocab_size 2048\nd_model 256\nn_layers 4\n\
         n_heads 8\nn_kv_heads 4\nd_head 32\nd_ff 512\nblock_size 16\nnum_blocks 512\n\
         max_blocks_per_seq 32\nn_experts 4\ntop_k 2\neos_token 0\nmoe 1\n\
         param tok_embed 2048x256 f32\n",
    );
    for b in [1usize, 2, 4, 8] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0 modeled\n"));
    }
    for b in [1usize, 2] {
        for s in [16usize, 32, 64, 128] {
            text.push_str(&format!("graph prefill_b{b}_s{s} prefill {b} {s} modeled\n"));
            text.push_str(&format!(
                "graph prefill_offset_b{b}_s{s} prefill_offset {b} {s} modeled\n"
            ));
        }
    }
    ModelManifest::parse(&text).expect("modeled moe manifest")
}

/// Prefix reuse, live: the real scheduler pipeline (ring scan →
/// admission → prefix index → offset-graph launch → completion) on the
/// *modeled* executor, so it runs without artifacts on any machine.
/// Two-turn sessions: turn 2 replays turn 1's prompt plus new text, so
/// with offset graphs in the grid each second turn should hit the index
/// and launch a `prefill_offset` graph for its suffix only — the counters
/// printed here are the same ones `/metrics` exports.
pub fn prefix_live(out: Option<&std::path::Path>) {
    println!("\n== Prefix reuse, live scheduler on the modeled executor ==");
    println!("(two-turn sessions; turn 2 = turn 1's 64-token prompt + 32 new tokens)");
    let manifest = modeled_manifest();
    let sessions = 8usize;
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 64,
        max_prompt: 256,
        max_output: 64,
    }));
    // Visible per-token prefill cost so the suffix-only win shows up in
    // the turn makespans, not just the counters.
    let cost =
        ModeledCost { prefill_us_per_token: 50.0, decode_step_us: 200.0, ..ModeledCost::zero() };
    let executor = Executor::spawn_modeled(&manifest, cost);
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest.clone(),
        SchedulerConfig {
            apply_launch_delays: false,
            prefix_reuse: PrefixReuse::Auto,
            ..Default::default()
        },
    );

    let mut rng = Rng::new(99);
    let firsts: Vec<Vec<u32>> = (0..sessions)
        .map(|_| (0..64).map(|_| rng.below(2048) as u32).collect())
        .collect();

    let run_turn = |prompts: &[Vec<u32>], base_slot: usize| -> Duration {
        let t0 = Instant::now();
        for (i, p) in prompts.iter().enumerate() {
            let slot = base_slot + i;
            assert!(ring.claim_for_write(slot));
            ring.write_prompt(slot, p);
            // Non-zero session tag: the scheduler attributes both turns
            // of conversation i to `session_requests` (reuse itself is
            // content-addressed and does not read the tag).
            ring.submit_with_meta(
                slot,
                &crate::ringbuf::SubmitMeta {
                    request_id: slot as u64,
                    prompt_len: p.len() as u32,
                    max_new: 4,
                    seed: i as u32,
                    priority: 0,
                    ttft_budget_us: 0,
                    session_id: 1 + i as u64,
                },
            );
        }
        loop {
            let done = (0..prompts.len()).all(|i| {
                matches!(
                    ring.slot(base_slot + i).state(),
                    SlotState::DecodeCompleted | SlotState::Failed
                )
            });
            if done {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        t0.elapsed()
    };

    let t1 = run_turn(&firsts, 0);
    let seconds: Vec<Vec<u32>> = firsts
        .iter()
        .map(|f| {
            let mut p = f.clone();
            p.extend((0..32).map(|_| rng.below(2048) as u32));
            p
        })
        .collect();
    let t2 = run_turn(&seconds, sessions);
    sched.drain_and_stop();

    let st = &sched.stats;
    let ld = |a: &std::sync::atomic::AtomicU64| a.load(Ordering::Relaxed);
    let (hits, hit_tokens) = (ld(&st.prefix_hits), ld(&st.prefix_hit_tokens));
    let offset_batches = ld(&st.prefill_offset_batches);
    println!("{:<22} {:>10} {:>10}", "", "turn 1", "turn 2");
    println!(
        "{:<22} {:>10.2} {:>10.2}",
        "makespan (ms)",
        t1.as_secs_f64() * 1e3,
        t2.as_secs_f64() * 1e3
    );
    println!(
        "offset-graph launches: {offset_batches}   prefix hits: {hits}   hit tokens: {hit_tokens}   \
         fallbacks to full prefill: {}",
        ld(&st.prefix_fallback_full)
    );
    let (ip50, ip99) = (st.loop_iter_p50_us(), st.loop_iter_p99_us());
    println!("control overhead per iteration: p50 {ip50:.1} µs   p99 {ip99:.1} µs");
    println!("stats: {}", st.summary());
    // The iteration-overhead histogram is cumulative over the run, so it
    // rides on the final (turn 2) row only.
    let csv = format!(
        "turn,requests,makespan_ms,prefix_hits,hit_tokens,offset_prefill_batches,\
         loop_iter_p50_us,loop_iter_p99_us\n\
         1,{sessions},{:.3},0,0,0,,\n\
         2,{sessions},{:.3},{hits},{hit_tokens},{offset_batches},{ip50:.2},{ip99:.2}\n",
        t1.as_secs_f64() * 1e3,
        t2.as_secs_f64() * 1e3,
    );
    write_out(out, "prefix_live.csv", &csv);
}

pub(crate) fn write_out(out: Option<&std::path::Path>, name: &str, content: &str) {
    if let Some(dir) = out {
        std::fs::create_dir_all(dir).ok();
        let p = dir.join(name);
        if std::fs::write(&p, content).is_ok() {
            eprintln!("[eval] wrote {}", p.display());
        }
    }
}
