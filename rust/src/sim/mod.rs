//! Discrete-event simulation of the paper's H100 testbed (DESIGN.md §2's
//! substitution for unavailable hardware): cost models, per-system host
//! coupling, interference process + counter model, energy model, the DES
//! core, and the full evaluation sweep.

pub mod costmodel;
pub mod des;
pub mod energy;
pub mod interference;
pub mod sweep;
pub mod systems;
