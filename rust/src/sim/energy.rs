//! Server wall-power + energy-per-token model (paper §6.4).
//!
//! The paper's key observation: all four systems draw comparable wall
//! power (1.1–1.4 kW), so energy/token tracks inversely with throughput;
//! Blink additionally accounts the BlueField-3's own draw. We model wall
//! power as base + GPU·util + host CPU·util (+ interferer draw when
//! colocated — the paper measures at the PSU feed, interferer included),
//! then divide by generated tokens.

use crate::sim::systems::System;

#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    /// Chassis + DRAM + fans + NIC at idle.
    pub base_w: f64,
    /// H100 SXM swing from idle to full tilt.
    pub gpu_max_w: f64,
    pub gpu_idle_w: f64,
    /// Dual Xeon 6336Y swing.
    pub cpu_max_w: f64,
    /// Interferer draw when colocated (90 busy cores).
    pub interferer_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            base_w: 380.0,
            gpu_max_w: 700.0,
            gpu_idle_w: 90.0,
            cpu_max_w: 340.0,
            interferer_w: 260.0,
        }
    }
}

impl PowerModel {
    /// Mean wall power during a window.
    pub fn wall_power_w(
        &self,
        system: System,
        gpu_util: f64,
        interference: bool,
    ) -> f64 {
        let gpu = self.gpu_idle_w + (self.gpu_max_w - self.gpu_idle_w) * gpu_util.clamp(0.0, 1.0);
        let host = self.cpu_max_w * system.host_util();
        let interferer = if interference { self.interferer_w } else { 0.0 };
        self.base_w + gpu + host + interferer + system.dpu_power_w()
    }

    /// Energy per generated token, millijoules.
    pub fn mj_per_token(
        &self,
        system: System,
        gpu_util: f64,
        interference: bool,
        tokens_per_s: f64,
    ) -> f64 {
        if tokens_per_s <= 0.0 {
            return f64::NAN;
        }
        self.wall_power_w(system, gpu_util, interference) / tokens_per_s * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_power_in_paper_band() {
        let p = PowerModel::default();
        for s in crate::sim::systems::ALL_SYSTEMS {
            let iso = p.wall_power_w(s, 0.85, false);
            let co = p.wall_power_w(s, 0.85, true);
            assert!((900.0..1500.0).contains(&iso), "{s:?} iso {iso}");
            assert!((1100.0..1500.0).contains(&co), "{s:?} colocated {co}");
        }
    }

    #[test]
    fn energy_tracks_inverse_throughput() {
        let p = PowerModel::default();
        let fast = p.mj_per_token(System::Blink, 0.9, false, 3880.0);
        let slow = p.mj_per_token(System::Sglang, 0.9, false, 2638.0);
        assert!(fast < slow);
        // Llama-3 8B band: paper reports 363–1306 mJ/tok across models.
        assert!((200.0..600.0).contains(&fast), "fast {fast}");
    }
}
