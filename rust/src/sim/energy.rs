//! Server wall-power + energy-per-token model (paper §6.4).
//!
//! The paper's key observation: all four systems draw comparable wall
//! power (1.1–1.4 kW), so energy/token tracks inversely with throughput;
//! Blink additionally accounts the BlueField-3's own draw. We model wall
//! power as base + GPU·util + host CPU·util (+ interferer draw when
//! colocated — the paper measures at the PSU feed, interferer included),
//! then divide by generated tokens.

use crate::sim::systems::System;

#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    /// Chassis + DRAM + fans + NIC at idle.
    pub base_w: f64,
    /// H100 SXM swing from idle to full tilt.
    pub gpu_max_w: f64,
    pub gpu_idle_w: f64,
    /// Dual Xeon 6336Y swing.
    pub cpu_max_w: f64,
    /// Interferer draw when colocated (90 busy cores).
    pub interferer_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            base_w: 380.0,
            gpu_max_w: 700.0,
            gpu_idle_w: 90.0,
            cpu_max_w: 340.0,
            interferer_w: 260.0,
        }
    }
}

impl PowerModel {
    /// Mean wall power during a window.
    pub fn wall_power_w(&self, system: System, gpu_util: f64, interference: bool) -> f64 {
        self.wall_power_live_w(
            gpu_util,
            system.host_util(),
            system.dpu_power_w(),
            if interference { 1.0 } else { 0.0 },
        )
    }

    /// System-free wall-power decomposition: what a *live* run reports
    /// when there is no `System` enum in play — the interference eval
    /// measures `gpu_util`/`host_util` off its own control loop and
    /// scales the antagonist draw by its intensity (`interferer_frac`,
    /// 0..1: the antagonist runs fewer busy cores at partial intensity).
    /// `wall_power_w` is this with the system's calibrated constants, so
    /// the DES and the live path share one decomposition.
    pub fn wall_power_live_w(
        &self,
        gpu_util: f64,
        host_util: f64,
        dpu_w: f64,
        interferer_frac: f64,
    ) -> f64 {
        let gpu = self.gpu_idle_w + (self.gpu_max_w - self.gpu_idle_w) * gpu_util.clamp(0.0, 1.0);
        let host = self.cpu_max_w * host_util.clamp(0.0, 1.0);
        let interferer = self.interferer_w * interferer_frac.clamp(0.0, 1.0);
        self.base_w + gpu + host + interferer + dpu_w
    }

    /// Energy per generated token, millijoules.
    pub fn mj_per_token(
        &self,
        system: System,
        gpu_util: f64,
        interference: bool,
        tokens_per_s: f64,
    ) -> f64 {
        if tokens_per_s <= 0.0 {
            return f64::NAN;
        }
        self.wall_power_w(system, gpu_util, interference) / tokens_per_s * 1e3
    }

    /// Live-run counterpart of [`PowerModel::mj_per_token`] (same NaN
    /// contract on zero throughput).
    pub fn mj_per_token_live(
        &self,
        gpu_util: f64,
        host_util: f64,
        dpu_w: f64,
        interferer_frac: f64,
        tokens_per_s: f64,
    ) -> f64 {
        if tokens_per_s <= 0.0 {
            return f64::NAN;
        }
        self.wall_power_live_w(gpu_util, host_util, dpu_w, interferer_frac) / tokens_per_s * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_power_in_paper_band() {
        let p = PowerModel::default();
        for s in crate::sim::systems::ALL_SYSTEMS {
            let iso = p.wall_power_w(s, 0.85, false);
            let co = p.wall_power_w(s, 0.85, true);
            assert!((900.0..1500.0).contains(&iso), "{s:?} iso {iso}");
            assert!((1100.0..1500.0).contains(&co), "{s:?} colocated {co}");
        }
    }

    #[test]
    fn energy_tracks_inverse_throughput() {
        let p = PowerModel::default();
        let fast = p.mj_per_token(System::Blink, 0.9, false, 3880.0);
        let slow = p.mj_per_token(System::Sglang, 0.9, false, 2638.0);
        assert!(fast < slow);
        // Llama-3 8B band: paper reports 363–1306 mJ/tok across models.
        assert!((200.0..600.0).contains(&fast), "fast {fast}");
    }

    #[test]
    fn live_decomposition_sums_exactly() {
        let p = PowerModel::default();
        let (gpu_util, host_util, dpu_w) = (0.6, 0.25, 75.0);
        let expect = p.base_w
            + p.gpu_idle_w
            + (p.gpu_max_w - p.gpu_idle_w) * gpu_util
            + p.cpu_max_w * host_util
            + dpu_w;
        let got = p.wall_power_live_w(gpu_util, host_util, dpu_w, 0.0);
        assert!((got - expect).abs() < 1e-9, "decomposition sums: {got} vs {expect}");
        // Utilizations clamp rather than extrapolate.
        assert_eq!(
            p.wall_power_live_w(2.0, 2.0, 0.0, 0.0),
            p.wall_power_live_w(1.0, 1.0, 0.0, 0.0)
        );
        // The DES path is this decomposition with the system constants —
        // one formula, no drift.
        for s in crate::sim::systems::ALL_SYSTEMS {
            let via_sys = p.wall_power_w(s, 0.7, true);
            let via_live = p.wall_power_live_w(0.7, s.host_util(), s.dpu_power_w(), 1.0);
            assert!((via_sys - via_live).abs() < 1e-9, "{s:?}");
        }
    }

    #[test]
    fn colocated_runs_include_interferer_draw() {
        let p = PowerModel::default();
        let iso = p.wall_power_live_w(0.8, 0.1, 0.0, 0.0);
        let co = p.wall_power_live_w(0.8, 0.1, 0.0, 1.0);
        assert!((co - iso - p.interferer_w).abs() < 1e-9, "full-intensity delta = interferer_w");
        // Partial antagonist intensity draws a proportional fraction.
        let half = p.wall_power_live_w(0.8, 0.1, 0.0, 0.5);
        assert!((half - iso - 0.5 * p.interferer_w).abs() < 1e-9);
    }

    #[test]
    fn energy_per_token_falls_as_throughput_rises_at_fixed_power() {
        let p = PowerModel::default();
        let mut prev = f64::INFINITY;
        for tok_s in [500.0, 1000.0, 2000.0, 4000.0] {
            let e = p.mj_per_token_live(0.85, 0.1, 75.0, 0.0, tok_s);
            assert!(e < prev, "energy/token monotone down in throughput: {e} vs {prev}");
            prev = e;
        }
        // Same wall power, double throughput ⇒ exactly half the energy.
        let e1 = p.mj_per_token_live(0.85, 0.1, 75.0, 0.0, 1000.0);
        let e2 = p.mj_per_token_live(0.85, 0.1, 75.0, 0.0, 2000.0);
        assert!((e1 / e2 - 2.0).abs() < 1e-9);
        assert!(p.mj_per_token_live(0.85, 0.1, 75.0, 0.0, 0.0).is_nan());
    }
}
