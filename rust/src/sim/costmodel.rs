//! GPU cost model for the paper's four models on an H100 (Table 5).
//!
//! We cannot run 8–32 B-parameter models here; Tables 6/7 and Figs 5–8
//! depend on the *ratio structure* between GPU step time and per-step
//! host overhead, which a roofline model captures: decode is HBM-bound
//! (read all active weights once per step), prefill is MXU-bound.
//! Constants are H100 SXM: ~3.35 TB/s HBM3 (derated), ~990 TFLOP/s fp16
//! at an achievable MFU. MoE uses active params for compute/bandwidth,
//! total params for capacity.

/// Paper model descriptors (python/compile/model.py PAPER_MODELS mirror).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PaperModel {
    pub name: &'static str,
    pub total_params: f64,
    pub active_params: f64,
    pub layers: usize,
    pub moe: bool,
}

pub const LLAMA3_8B: PaperModel = PaperModel {
    name: "llama3-8b",
    total_params: 8.0e9,
    active_params: 8.0e9,
    layers: 32,
    moe: false,
};
pub const PHI4_15B: PaperModel = PaperModel {
    name: "phi4-15b",
    total_params: 14.7e9,
    active_params: 14.7e9,
    layers: 40,
    moe: false,
};
pub const QWEN3_32B: PaperModel = PaperModel {
    name: "qwen3-32b",
    total_params: 32.0e9,
    active_params: 32.0e9,
    layers: 64,
    moe: false,
};
pub const QWEN3_30B_A3B: PaperModel = PaperModel {
    name: "qwen3-30b-a3b",
    total_params: 30.0e9,
    active_params: 3.0e9,
    layers: 48,
    moe: true,
};

pub const PAPER_MODELS: [PaperModel; 4] = [LLAMA3_8B, PHI4_15B, QWEN3_32B, QWEN3_30B_A3B];

pub fn by_name(name: &str) -> Option<PaperModel> {
    PAPER_MODELS.iter().copied().find(|m| m.name == name)
}

/// H100 testbed constants.
#[derive(Debug, Clone, Copy)]
pub struct Hardware {
    /// Effective HBM bandwidth, bytes/s (derated from the 3.35 TB/s peak).
    pub hbm_bytes_per_s: f64,
    /// Achievable fp16 FLOP/s (peak × realistic MFU for prefill GEMMs).
    pub flops: f64,
    /// GPU memory for KV after weights, bytes (96 GB card).
    pub vram_bytes: f64,
    /// Fixed per-graph-execution overhead on the GPU, seconds (kernel
    /// pipeline drain/fill; independent of batch).
    pub graph_exec_overhead_s: f64,
    /// Fraction of the roofline `flops` a *piggybacked* suffix-prefill
    /// chunk achieves inside a decode iteration. Recalibrated from the
    /// measured chunk-size cost curve (python/compile/bench_kernels.py):
    /// the fused paged suffix-prefill kernel's cost is linear in chunk
    /// tokens with a per-token slope ~2.3x below the jnp gather/einsum
    /// composition it replaced (interpret-mode sweep, S ∈ 32..1024 at a
    /// 512-token context), so the chunk's GEMMs now run near — but not
    /// at — the roofline: launch/epilogue and the page-walk's gather
    /// bandwidth keep it a few percent under peak. The earlier model
    /// charged chunks at a full 1.0, which overstated how many tokens
    /// hide under the decode weight sweep.
    pub chunk_mxu_efficiency: f64,
}

impl Default for Hardware {
    fn default() -> Self {
        Hardware {
            hbm_bytes_per_s: 2.9e12,
            flops: 4.5e14,
            vram_bytes: 96.0e9,
            graph_exec_overhead_s: 150e-6,
            chunk_mxu_efficiency: 0.92,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub model: PaperModel,
    pub hw: Hardware,
}

impl CostModel {
    pub fn new(model: PaperModel) -> CostModel {
        CostModel { model, hw: Hardware::default() }
    }

    /// Weight bytes touched per decode step (fp16) for a batch of `b`.
    ///
    /// Dense models stream all weights once regardless of batch. MoE
    /// models activate `active/total` of their experts per *token*, but
    /// the batch reads the **union** of activated experts — the fraction
    /// 1-(1-a/t)^b — which is why MoE throughput doesn't scale linearly
    /// with batch and why its per-step time stays small only at modest
    /// batches (the regime where the paper's §6.2 analysis applies).
    pub fn active_weight_bytes(&self, b: usize) -> f64 {
        if self.model.moe {
            let frac = self.model.active_params / self.model.total_params;
            let union = 1.0 - (1.0 - frac).powi(b as i32);
            self.model.total_params * 2.0 * union
        } else {
            self.model.active_params * 2.0
        }
    }

    /// One decode iteration for a batch of `b` sequences with mean
    /// context `ctx` tokens: HBM-bound weight sweep + per-sequence KV
    /// reads + fixed graph overhead.
    pub fn decode_step_s(&self, b: usize, mean_ctx: f64) -> f64 {
        self.decode_step_with_chunk_s(b, mean_ctx, 0)
    }

    /// One decode iteration that also carries `chunk_tokens` of prefill
    /// — the chunked-prefill launch pair (decode graph + bounded
    /// `prefill_offset` chunk, back to back, before the next
    /// completion poll). Decode is HBM-bound: the weight sweep is paid
    /// once per iteration either way, so the chunk's GEMM FLOPs hide
    /// beneath it until the pair turns compute-bound, and only the
    /// excess extends the step — the roofline form of prefill/decode
    /// co-scheduling ("piggybacking" in the related-work framing). The
    /// chunk's GEMMs run at `chunk_mxu_efficiency` of the roofline
    /// (the fused-kernel calibration; see [`Hardware`]), which puts
    /// the hide point at [`CostModel::hide_point_tokens`] — 128 tokens
    /// for the dense 8B at the saturated b=16 decode batch: budgets
    /// near it make long-prompt prefill nearly free for decode tails,
    /// while large budgets degenerate toward the whole-prompt stall.
    pub fn decode_step_with_chunk_s(&self, b: usize, mean_ctx: f64, chunk_tokens: usize) -> f64 {
        self.verify_step_with_chunk_s(b, mean_ctx, 0, chunk_tokens)
    }

    /// One speculative draft-verify iteration (DESIGN.md §11): each lane
    /// scores `k + 1` window positions (pending token + k drafts) in a
    /// single launch. Plain decode is the `k = 0` case — this is the
    /// general form [`CostModel::decode_step_with_chunk_s`] delegates
    /// to, so the existing decode pins hold by construction. The
    /// roofline story of why speculation pays: the HBM-bound weight
    /// sweep is charged **once** regardless of k (that is the whole
    /// win — k+1 tokens ride one weight read), while attention's KV
    /// reads and the GEMM FLOPs scale with the window. Costs therefore
    /// grow sublinearly in k until the extra FLOPs lift the step off
    /// the weight sweep, which is exactly the regime where acceptance
    /// decides whether verify launches beat plain decode.
    pub fn verify_step_s(&self, b: usize, mean_ctx: f64, k: usize) -> f64 {
        self.verify_step_with_chunk_s(b, mean_ctx, k, 0)
    }

    /// Verify iteration that also carries a piggybacked prefill chunk
    /// (the chunked-prefill co-scheduling applies unchanged: the
    /// chunk's GEMMs hide under the shared weight sweep).
    pub fn verify_step_with_chunk_s(
        &self,
        b: usize,
        mean_ctx: f64,
        k: usize,
        chunk_tokens: usize,
    ) -> f64 {
        let window = (k + 1) as f64;
        let weights = self.active_weight_bytes(b) / self.hw.hbm_bytes_per_s;
        // KV bytes per token per layer ≈ 2 (K,V) × d_kv × 2 bytes. Use a
        // GQA-typical 1024 bytes/token/layer; every window position
        // attends over the full context, so the KV sweep scales with w.
        let kv_bytes = b as f64 * window * mean_ctx * self.model.layers as f64 * 1024.0;
        let kv = kv_bytes / self.hw.hbm_bytes_per_s;
        // Batched GEMV compute — w tokens per lane (rarely binding below
        // b·w ≈ 64) — plus the piggybacked chunk's prefill GEMMs at the
        // calibrated chunk efficiency.
        let flops = 2.0 * self.model.active_params
            * (b as f64 * window + chunk_tokens as f64 / self.hw.chunk_mxu_efficiency)
            / self.hw.flops;
        weights.max(flops) + kv + self.hw.graph_exec_overhead_s
    }

    /// The hide point: the largest piggybacked chunk (tokens) whose
    /// prefill GEMMs stay entirely under the decode weight sweep for a
    /// batch of `b`, i.e. the largest `c` with
    /// `decode_step_with_chunk_s(b, ctx, c) == decode_step_s(b, ctx)`.
    /// Derived from the same calibrated constants the DES charges, so
    /// the kernel's measured curve, the DES chunk cost, and the eval
    /// report (`blink eval chunked`'s `hide_point_tokens` column) tell
    /// one consistent story.
    pub fn hide_point_tokens(&self, b: usize) -> usize {
        let weights_s = self.active_weight_bytes(b) / self.hw.hbm_bytes_per_s;
        let gemv_s = 2.0 * self.model.active_params * b as f64 / self.hw.flops;
        let headroom_s = (weights_s - gemv_s).max(0.0);
        (headroom_s * self.hw.flops * self.hw.chunk_mxu_efficiency
            / (2.0 * self.model.active_params)) as usize
    }

    /// Prefill `tokens` prompt tokens (possibly batched): MXU-bound.
    pub fn prefill_s(&self, tokens: usize) -> f64 {
        let flops = 2.0 * self.model.active_params * tokens as f64;
        // Short prefills can't saturate the MXU; floor at the weight sweep.
        let min = self.active_weight_bytes(tokens.min(64)) / self.hw.hbm_bytes_per_s;
        (flops / self.hw.flops).max(min) + self.hw.graph_exec_overhead_s
    }

    /// Prefill cost when the leading `cached` prompt tokens are served
    /// from the prefix cache: only the uncached suffix pays the MXU
    /// cost (at least one token always prefills — the suffix launch
    /// produces the first output token's logits).
    pub fn prefill_with_prefix_s(&self, tokens: usize, cached: usize) -> f64 {
        self.prefill_s(tokens - cached.min(tokens.saturating_sub(1)))
    }

    /// KV capacity in *tokens* given weights resident (fp16).
    pub fn kv_capacity_tokens(&self) -> f64 {
        let weights = self.model.total_params * 2.0;
        let per_token = self.model.layers as f64 * 1024.0;
        ((self.hw.vram_bytes * 0.90 - weights) / per_token).max(0.0)
    }

    /// Max concurrent sequences for a given mean footprint.
    pub fn max_batch(&self, mean_tokens_per_seq: f64) -> usize {
        let kv_limit = (self.kv_capacity_tokens() / mean_tokens_per_seq) as usize;
        kv_limit.clamp(1, 256)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_times_ordered_by_active_params() {
        let ctx = 1200.0;
        let t8 = CostModel::new(LLAMA3_8B).decode_step_s(16, ctx);
        let t15 = CostModel::new(PHI4_15B).decode_step_s(16, ctx);
        let t32 = CostModel::new(QWEN3_32B).decode_step_s(16, ctx);
        let tmoe1 = CostModel::new(QWEN3_30B_A3B).decode_step_s(1, ctx);
        let t8_1 = CostModel::new(LLAMA3_8B).decode_step_s(1, ctx);
        assert!(t8 < t15 && t15 < t32);
        // At batch 1 the MoE reads only its 3B active params: fastest.
        assert!(tmoe1 < t8_1, "MoE must be fastest at b=1: {tmoe1} vs {t8_1}");
        // At batch 16 the expert union makes it comparable to a mid dense.
        let tmoe = CostModel::new(QWEN3_30B_A3B).decode_step_s(16, ctx);
        assert!(tmoe > tmoe1 * 2.0, "expert union must grow with batch");
    }

    #[test]
    fn decode_step_magnitudes_sane() {
        // Llama-3 8B fp16: 16 GB weights / 2.9 TB/s ≈ 5.5 ms.
        let t = CostModel::new(LLAMA3_8B).decode_step_s(16, 1200.0);
        assert!((0.004..0.012).contains(&t), "t={t}");
        // Qwen-3 32B: ~64 GB / 2.9 ≈ 22 ms.
        let t = CostModel::new(QWEN3_32B).decode_step_s(16, 1200.0);
        assert!((0.018..0.035).contains(&t), "t={t}");
    }

    #[test]
    fn prefill_scales_with_tokens() {
        let cm = CostModel::new(LLAMA3_8B);
        let t1k = cm.prefill_s(1024);
        let t4k = cm.prefill_s(4096);
        assert!(t4k > 3.0 * t1k && t4k < 5.0 * t1k);
    }

    #[test]
    fn prefix_reuse_cuts_prefill_to_suffix_cost() {
        let cm = CostModel::new(LLAMA3_8B);
        let full = cm.prefill_s(2048);
        let mostly_cached = cm.prefill_with_prefix_s(2048, 1920);
        assert!(mostly_cached < 0.25 * full, "hit {mostly_cached} vs cold {full}");
        // The floor holds: a fully-cached prompt still pays at least the
        // short-prefill weight sweep (never zero).
        assert!(cm.prefill_with_prefix_s(2048, 4096) >= cm.prefill_s(1));
        assert_eq!(cm.prefill_with_prefix_s(2048, 0), full);
    }

    #[test]
    fn piggybacked_chunk_hides_under_decode_sweep() {
        let cm = CostModel::new(LLAMA3_8B);
        let plain = cm.decode_step_s(16, 1200.0);
        // A hide-point chunk rides free: its GEMM FLOPs (at the
        // calibrated chunk efficiency) stay under the 16 GB weight sweep.
        let small = cm.decode_step_with_chunk_s(16, 1200.0, 128);
        assert_eq!(small, plain, "128-token chunk hides under the weight sweep");
        // A large chunk turns the pair compute-bound: the step extends
        // by roughly the chunk's prefill time.
        let big = cm.decode_step_with_chunk_s(16, 1200.0, 2048);
        assert!(big > 10.0 * plain, "2048-token chunk dominates: {big} vs {plain}");
        assert!(big < plain + cm.prefill_s(2048), "but cheaper than a serial stall");
    }

    /// The derived hide point and the DES chunk cost agree by
    /// construction: the hide point is the exact boundary of the
    /// charged `decode_step_with_chunk_s` — one token more extends
    /// the step. Pins the recalibrated constant for the dense 8B.
    #[test]
    fn hide_point_is_the_exact_chunk_cost_boundary() {
        for model in [LLAMA3_8B, PHI4_15B, QWEN3_32B, QWEN3_30B_A3B] {
            let cm = CostModel::new(model);
            for b in [1, 8, 16] {
                let h = cm.hide_point_tokens(b);
                assert!(h > 0, "{}: hide point must be positive", model.name);
                let plain = cm.decode_step_s(b, 1200.0);
                assert_eq!(
                    cm.decode_step_with_chunk_s(b, 1200.0, h),
                    plain,
                    "{}: a hide-point chunk must ride free at b={b}",
                    model.name
                );
                assert!(
                    cm.decode_step_with_chunk_s(b, 1200.0, h + 1) > plain,
                    "{}: one token past the hide point must extend the step at b={b}",
                    model.name
                );
            }
        }
        // The recalibrated dense-8B constant the eval CSV reports: the
        // ideal-efficiency ~139 tokens at b=16, derated by the fused
        // kernel's 0.92 calibrated chunk efficiency.
        assert_eq!(CostModel::new(LLAMA3_8B).hide_point_tokens(16), 128);
    }

    #[test]
    fn standalone_chunk_rounds_cost_bounded_overhead() {
        // The DES's standalone chunk rounds (no decode lanes to
        // piggyback on) charge `prefill_s` per chunk: the total for a
        // split suffix exceeds one whole launch by exactly the extra
        // per-launch overheads (8192 = 4 × 2048, each chunk
        // MXU-bound), while each *iteration stall* shrinks from the
        // whole prompt to one chunk — the quantity chunking bounds.
        let cm = CostModel::new(LLAMA3_8B);
        let whole = cm.prefill_s(8192);
        let chunked = 4.0 * cm.prefill_s(2048);
        assert!(chunked > whole, "chunked {chunked} vs whole {whole}");
        let premium = chunked - whole;
        let overhead = cm.hw.graph_exec_overhead_s;
        assert!(
            (premium - 3.0 * overhead).abs() < 1e-9,
            "premium {premium} vs 3 overheads {}",
            3.0 * overhead
        );
        assert!(cm.prefill_s(2048) < 0.3 * whole);
    }

    /// The verify roofline (DESIGN.md §11): k = 0 *is* plain decode
    /// (the delegation keeps every existing decode pin), cost grows
    /// with k but far slower than running k+1 sequential decode steps —
    /// the weight sweep and the graph overhead are paid once — and the
    /// break-even acceptance (verify cost ÷ per-launch emitted tokens)
    /// sits well below 1, so speculation pays at realistic acceptance.
    #[test]
    fn verify_step_shares_the_weight_sweep() {
        for model in [LLAMA3_8B, QWEN3_32B, QWEN3_30B_A3B] {
            let cm = CostModel::new(model);
            for b in [1usize, 16] {
                let plain = cm.decode_step_s(b, 1200.0);
                assert_eq!(cm.verify_step_s(b, 1200.0, 0), plain, "{}", model.name);
                let v4 = cm.verify_step_s(b, 1200.0, 4);
                assert!(v4 > plain, "{}: k=4 must cost more than k=0 at b={b}", model.name);
                assert!(
                    v4 < 2.5 * plain,
                    "{}: one 5-wide verify must stay far under 5 decode steps \
                     (got {v4} vs {plain} at b={b})",
                    model.name
                );
                // Perfect acceptance emits 5 tokens per launch: ≥2×
                // tokens/s over plain decode on every paper model.
                assert!(
                    v4 / 5.0 < plain / 2.0,
                    "{}: per-token verify cost must beat half the decode cost at b={b}",
                    model.name
                );
            }
        }
        // Monotone in k.
        let cm = CostModel::new(LLAMA3_8B);
        let costs: Vec<f64> = (0..=8).map(|k| cm.verify_step_s(16, 1200.0, k)).collect();
        assert!(costs.windows(2).all(|w| w[0] < w[1]), "{costs:?}");
    }

    #[test]
    fn kv_capacity_positive_and_ordered() {
        let c8 = CostModel::new(LLAMA3_8B).kv_capacity_tokens();
        let c32 = CostModel::new(QWEN3_32B).kv_capacity_tokens();
        assert!(c8 > c32, "bigger weights leave less KV room");
        assert!(c32 > 100_000.0, "32B still holds >100k tokens on 96GB");
    }

    #[test]
    fn moe_capacity_uses_total_params() {
        // 30B total weights resident even though 3B active.
        let cmoe = CostModel::new(QWEN3_30B_A3B).kv_capacity_tokens();
        let c8 = CostModel::new(LLAMA3_8B).kv_capacity_tokens();
        assert!(cmoe < c8);
    }
}
