//! Interference process + microarchitectural counter model (paper §2.2,
//! §3, Appendix A).
//!
//! Live interference (crate::hostsim) perturbs this machine, not an H100
//! testbed; the DES instead applies a *calibrated* inflation process to
//! host-side work:
//!
//! * a slow phase component — the interferers (pbzip2 I/O vs. compress
//!   phases, Ninja preprocess/compile/link cycles) traverse distinct
//!   execution phases over the sweep, which the paper notes produces
//!   non-monotonic baseline curves (Appendix A);
//! * a heavy-tailed per-step lognormal — LLC/TLB contention jitter.
//!
//! The counter model maps an interference intensity (and, for Table 4, a
//! CAT way allocation) to the hardware counters the paper reports,
//! reproducing the two-stage amplification mechanism of §3.1: TLB misses
//! rise mildly, but each miss's page walk lands in a polluted LLC, so
//! walk_active and LLC stalls blow up together.

use crate::util::rng::Rng;

/// Time-varying inflation multiplier applied to host-side costs.
#[derive(Debug, Clone)]
pub struct InterferenceProcess {
    /// Mean multiplier at full intensity (system-specific sensitivity).
    pub mean: f64,
    /// Lognormal shape of per-step jitter (heavier ⇒ fatter P99.9).
    pub sigma: f64,
    /// Phase modulation depth (0..1) and period (s) — Appendix A.
    pub phase_depth: f64,
    pub phase_period_s: f64,
    phase_offset: f64,
}

impl InterferenceProcess {
    pub fn new(mean: f64, rng: &mut Rng) -> InterferenceProcess {
        InterferenceProcess {
            mean,
            sigma: 0.55,
            phase_depth: 0.45,
            phase_period_s: 37.0,
            phase_offset: rng.f64() * std::f64::consts::TAU,
        }
    }

    pub fn none() -> InterferenceProcess {
        InterferenceProcess {
            mean: 1.0,
            sigma: 0.0,
            phase_depth: 0.0,
            phase_period_s: 1.0,
            phase_offset: 0.0,
        }
    }

    /// Multiplier at simulation time `t` (≥ 1.0).
    pub fn sample(&self, t_s: f64, rng: &mut Rng) -> f64 {
        if self.mean <= 1.0 {
            return 1.0;
        }
        let phase = 1.0
            + self.phase_depth
                * (std::f64::consts::TAU * t_s / self.phase_period_s + self.phase_offset).sin();
        let jitter = if self.sigma > 0.0 {
            // Lognormal with unit mean: exp(sigma*z - sigma^2/2).
            (self.sigma * rng.normal() - self.sigma * self.sigma / 2.0).exp()
        } else {
            1.0
        };
        (self.mean * phase * jitter).max(1.0)
    }
}

/// Hardware-counter model: reproduces the §3.1 amplification mechanism.
/// `intensity` 0.0 = isolated, 1.0 = the paper's 24× interferer;
/// `cat_ways` = Some(w) models Intel CAT with `w` LLC ways dedicated to
/// the victim (Table 4); None = no partitioning (Tables 1–2).
#[derive(Debug, Clone, Copy)]
pub struct CounterModel {
    pub intensity: f64,
    pub cat_ways: Option<f64>,
}

#[derive(Debug, Clone, Copy)]
pub struct Counters {
    pub ipc: f64,
    pub llc_miss_pct: f64,
    pub llc_stall_cycles_m: f64,
    pub dtlb_load_misses_m: f64,
    pub walk_active_m: f64,
    pub cpu_migrations: u64,
}

impl CounterModel {
    pub fn isolated() -> CounterModel {
        CounterModel { intensity: 0.0, cat_ways: None }
    }

    pub fn interference(intensity: f64) -> CounterModel {
        CounterModel { intensity, cat_ways: None }
    }

    pub fn with_ways(intensity: f64, ways: f64) -> CounterModel {
        CounterModel { intensity, cat_ways: Some(ways) }
    }

    /// Fraction of the victim's hot working set (incl. page-table entries)
    /// the interferer can evict: 1.0 with no CAT protection, dropping to
    /// ~0 once ≥7 of 12 ways are dedicated (the Table 4 knee).
    fn pollution(&self) -> f64 {
        if self.intensity <= 0.0 {
            return 0.0;
        }
        match self.cat_ways {
            None => self.intensity.min(1.0),
            Some(w) => {
                let knee = 7.0;
                if w >= knee {
                    0.0
                } else {
                    self.intensity.min(1.0) * ((knee - w) / knee).powi(2) / 0.7347
                    // normalized so 1 way ≈ the fitted 0.78 eviction level
                }
            }
        }
    }

    pub fn counters(&self) -> Counters {
        let i = self.intensity;
        let pol = self.pollution();
        // LLC miss: 7 % baseline → ~72 % fully polluted (Table 1, 24×);
        // CAT ways claw it back (Table 4: 7 ways ⇒ 7.0 %).
        let llc_miss_pct = 7.0 + 65.0 * pol;
        // TLB misses rise mildly (1.6× at 24×): unmap churn invalidates
        // entries; CAT does not partition the TLB (constant across ways).
        let dtlb = 6.0 * (1.0 + 0.66 * i);
        // Page walks: each miss costs more when page-table entries fall
        // out of the LLC — the two-stage amplification. Protected ways
        // keep PTEs resident even under full interference.
        let walk = 383.0 * (1.0 + 2.8 * i * pol.max(0.045 * i));
        // LLC stall cycles: 450 M baseline; data misses escalate sharply
        // with pollution (11.2× at 24× with no CAT; Table 4: 3169 M at
        // 1 way → 442 M at 12 ways).
        let stall = 450.0 * (1.0 + 10.2 * pol);
        // IPC collapses as stalls mount: 1.53 → 0.72 at 24× (no CAT);
        // 1.16 → 1.55 across the CAT sweep.
        let ipc = match self.cat_ways {
            None => 1.53 / (1.0 + 1.15 * pol),
            Some(_) => 1.53 / (1.0 + 0.42 * pol),
        };
        Counters {
            ipc,
            llc_miss_pct,
            llc_stall_cycles_m: stall,
            dtlb_load_misses_m: dtlb,
            walk_active_m: walk,
            cpu_migrations: (6.0 + 21.0 * i) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_interference_is_identity() {
        let p = InterferenceProcess::none();
        let mut rng = Rng::new(1);
        for t in 0..100 {
            assert_eq!(p.sample(t as f64, &mut rng), 1.0);
        }
    }

    #[test]
    fn mean_multiplier_near_target() {
        let mut rng = Rng::new(2);
        let p = InterferenceProcess::new(10.0, &mut rng);
        let n = 200_000;
        let mean: f64 =
            (0..n).map(|i| p.sample(i as f64 * 0.01, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean / 10.0 - 1.0).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn heavy_tail_exists() {
        let mut rng = Rng::new(3);
        let p = InterferenceProcess::new(10.0, &mut rng);
        let mut xs: Vec<f64> = (0..100_000).map(|i| p.sample(i as f64 * 0.001, &mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = xs[(xs.len() as f64 * 0.99) as usize];
        let p50 = xs[xs.len() / 2];
        assert!(p99 / p50 > 2.0, "p99/p50 {}", p99 / p50);
    }

    #[test]
    fn inflation_never_below_one() {
        // Property: whatever the mean, seed, or time, the sampled
        // multiplier never deflates host work — interference can only
        // slow the victim down.
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            for mean in [0.0, 0.5, 1.0, 1.5, 4.0, 10.0, 24.0] {
                let p = InterferenceProcess::new(mean, &mut rng);
                for i in 0..2_000 {
                    let s = p.sample(i as f64 * 0.037, &mut rng);
                    assert!(s >= 1.0, "mean {mean} seed {seed} t {i}: sample {s} < 1");
                }
            }
        }
    }

    #[test]
    fn mean_calibrated_at_full_intensity_across_seeds() {
        // Property: at the full-intensity sensitivity of every modeled
        // system (Blink's 1.0 through TRT-LLM's 24×), the long-run mean
        // of the process reproduces that target — phase wander and
        // lognormal jitter are shape, not bias. Multiple seeds so the
        // calibration isn't an artifact of one phase offset.
        for seed in [2u64, 11, 29] {
            for target in [4.0, 10.0, 24.0] {
                let mut rng = Rng::new(seed);
                let p = InterferenceProcess::new(target, &mut rng);
                let n = 100_000;
                let mean: f64 =
                    (0..n).map(|i| p.sample(i as f64 * 0.01, &mut rng)).sum::<f64>() / n as f64;
                assert!(
                    (mean / target - 1.0).abs() < 0.15,
                    "seed {seed} target {target}: mean {mean}"
                );
            }
        }
    }

    #[test]
    fn two_stage_amplification_ordering_holds_across_intensities() {
        // §3.1 mechanism as a property over the whole intensity sweep,
        // not just the endpoints: LLC stalls must grow strictly faster
        // than raw dTLB misses at every nonzero intensity (stage two —
        // each TLB miss's page walk lands in a polluted LLC), and both
        // growth curves must be monotone in intensity.
        let base = CounterModel::isolated().counters();
        let mut prev_stall = 1.0;
        let mut prev_tlb = 1.0;
        for step in 1..=10 {
            let i = step as f64 / 10.0;
            let c = CounterModel::interference(i).counters();
            let stall_growth = c.llc_stall_cycles_m / base.llc_stall_cycles_m;
            let tlb_growth = c.dtlb_load_misses_m / base.dtlb_load_misses_m;
            assert!(
                stall_growth > tlb_growth,
                "intensity {i}: stalls ({stall_growth}×) must outgrow TLB misses ({tlb_growth}×)"
            );
            assert!(stall_growth >= prev_stall, "stall growth monotone at {i}");
            assert!(tlb_growth >= prev_tlb, "tlb growth monotone at {i}");
            prev_stall = stall_growth;
            prev_tlb = tlb_growth;
        }
        // And the endpoint amplification gap is an order of magnitude:
        // mild TLB rise (<2×), explosive stall rise (>10×).
        assert!(prev_tlb < 2.0 && prev_stall > 10.0, "tlb {prev_tlb}× stall {prev_stall}×");
    }

    #[test]
    fn counters_match_table1_shape() {
        // Isolated ≈ Table 1 baseline column.
        let base = CounterModel::isolated().counters();
        assert!((base.ipc - 1.53).abs() < 0.05);
        assert!((base.llc_miss_pct - 7.0).abs() < 0.5);
        // Full interference ≈ the 24× column (no CAT).
        let c = CounterModel::interference(1.0).counters();
        assert!(c.ipc < 0.85, "ipc {}", c.ipc);
        assert!(c.llc_miss_pct > 60.0, "llc {}", c.llc_miss_pct);
        assert!(c.llc_stall_cycles_m > 4000.0, "stall {}", c.llc_stall_cycles_m);
        assert!(c.walk_active_m > 1200.0, "walk {}", c.walk_active_m);
        // Mechanism: TLB misses rise mildly (<2×) while stalls rise >10×.
        assert!(c.dtlb_load_misses_m / base.dtlb_load_misses_m < 2.0);
        assert!(c.llc_stall_cycles_m / base.llc_stall_cycles_m > 10.0);
    }

    #[test]
    fn cat_sweep_matches_table4_shape() {
        let one = CounterModel::with_ways(1.0, 1.0).counters();
        let three = CounterModel::with_ways(1.0, 3.0).counters();
        let seven = CounterModel::with_ways(1.0, 7.0).counters();
        let twelve = CounterModel::with_ways(1.0, 12.0).counters();
        // Table 4 row shapes: 57.6 / 26.6 / 7.0 / 6.8 % miss.
        assert!(one.llc_miss_pct > 45.0, "1 way {}", one.llc_miss_pct);
        assert!(three.llc_miss_pct < one.llc_miss_pct);
        assert!(seven.llc_miss_pct < 10.0, "7 ways {}", seven.llc_miss_pct);
        assert!(twelve.llc_miss_pct <= seven.llc_miss_pct + 1.0);
        // IPC recovers: 1.16 → 1.55.
        assert!(one.ipc < 1.25 && twelve.ipc > 1.45);
        // dTLB count unaffected by CAT (Table 4 row ≈ constant).
        assert!((one.dtlb_load_misses_m - twelve.dtlb_load_misses_m).abs() < 0.5);
        // Stalls collapse 3169 → ~450.
        assert!(one.llc_stall_cycles_m > 2500.0 && twelve.llc_stall_cycles_m < 600.0);
    }
}
