//! The full evaluation sweep (paper §6.1): 13 offered-load levels from 1
//! to 32 req/s × 4 systems × 4 models × {isolated, interference}, 60 s
//! per level — the dataset behind Tables 6/7, Figs 1/5/6/7/8 and every
//! appendix table/figure. Points are independent, so the sweep shards
//! across threads.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::gpu::policy::PolicyKind;
use crate::sim::costmodel::{PaperModel, PAPER_MODELS};
use crate::sim::des::{simulate, SimConfig};
use crate::sim::systems::{System, ALL_SYSTEMS};
use crate::util::stats::{geomean, saturation_index};
use crate::workload::{ClassMix, LongPromptMix, MultiTurnMix, WindowMetrics};

/// guidellm-style sweep levels (13 levels, 1..32 req/s).
pub fn load_levels() -> Vec<f64> {
    vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0, 12.0, 16.0, 20.0, 24.0, 32.0]
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PointKey {
    pub system: System,
    pub model: &'static str,
    pub interference: bool,
    /// Load level index into `load_levels()`.
    pub level: usize,
}

pub struct SweepResults {
    pub levels: Vec<f64>,
    pub points: HashMap<PointKey, WindowMetrics>,
}

impl SweepResults {
    pub fn get(&self, system: System, model: &str, interference: bool, level: usize) -> &WindowMetrics {
        let model = PAPER_MODELS.iter().find(|m| m.name == model).expect("model").name;
        self.points
            .get(&PointKey { system, model, interference, level })
            .expect("sweep point")
    }

    /// Throughput curve (req/s completed) across levels.
    pub fn tput_curve(&self, system: System, model: &str, interference: bool) -> Vec<f64> {
        (0..self.levels.len())
            .map(|l| self.get(system, model, interference, l).req_throughput)
            .collect()
    }

    /// Latency curve for a metric ("ttft"|"tpot"|"itl") at a percentile.
    pub fn latency_curve(
        &self,
        system: System,
        model: &str,
        interference: bool,
        metric: &str,
        pct: &str,
    ) -> Vec<f64> {
        (0..self.levels.len())
            .map(|l| {
                let wm = self.get(system, model, interference, l);
                match metric {
                    "ttft" => wm.ttft.get(pct),
                    "tpot" => wm.tpot.get(pct),
                    _ => wm.itl.get(pct),
                }
            })
            .collect()
    }

    /// Blink's saturation level index for a model (isolated curve, two-
    /// segment fit — §6.2's "operating range" λ ≤ levels[idx]). The fit is
    /// capped at the last level still serving ≥85 % of the offered load,
    /// so the "operating range" never includes deep-queueing levels (the
    /// paper's ranges sit just below the knee as well).
    pub fn blink_saturation_level(&self, model: &str) -> usize {
        let curve = self.tput_curve(System::Blink, model, false);
        let k = saturation_index(&self.levels, &curve);
        let mut served = 0;
        for (i, (l, g)) in self.levels.iter().zip(&curve).enumerate() {
            if *g >= 0.85 * *l {
                served = i;
            }
        }
        k.min(served).max(1)
    }

    /// Geometric mean of a latency metric over Blink's operating range.
    pub fn geomean_over_range(
        &self,
        system: System,
        model: &str,
        interference: bool,
        metric: &str,
        pct: &str,
        sat_level: usize,
    ) -> f64 {
        let curve = self.latency_curve(system, model, interference, metric, pct);
        geomean(&curve[..=sat_level])
    }
}

/// Run the sweep. `models` defaults to all four paper models; sharded
/// across `threads` OS threads (points are independent sims).
pub fn run_sweep(models: &[PaperModel], window_s: f64, threads: usize) -> SweepResults {
    let levels = load_levels();
    let mut work: Vec<(PointKey, SimConfig)> = vec![];
    for model in models {
        for system in ALL_SYSTEMS {
            for interference in [false, true] {
                for (level, rate) in levels.iter().enumerate() {
                    let mut cfg = SimConfig::new(system, *model, *rate, interference);
                    cfg.window_s = window_s;
                    work.push((PointKey { system, model: model.name, interference, level }, cfg));
                }
            }
        }
    }
    let results: Mutex<HashMap<PointKey, WindowMetrics>> = Mutex::new(HashMap::new());
    let next: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                let (key, cfg) = &work[i];
                let wm = simulate(cfg);
                results.lock().unwrap().insert(*key, wm);
            });
        }
    });
    SweepResults { levels, points: results.into_inner().unwrap() }
}

// ---------------------------------------------------------------------------
// Policy-comparison sweep: Blink under the mixed interactive/batch load,
// one curve per admission policy.
// ---------------------------------------------------------------------------

/// Load levels for the policy comparison: from comfortable to clearly
/// saturating for Blink on the dense 8B model (~16 req/s knee under the
/// mixed load).
pub fn policy_load_levels() -> Vec<f64> {
    vec![4.0, 8.0, 12.0, 16.0, 20.0, 24.0]
}

pub struct PolicySweepResults {
    pub model: PaperModel,
    pub levels: Vec<f64>,
    /// Exactly the mix the sweep simulated (threaded into every config).
    pub mix: ClassMix,
    /// Exactly the policies the sweep ran, in run order.
    pub policies: Vec<PolicyKind>,
    pub points: HashMap<(PolicyKind, usize), WindowMetrics>,
}

impl PolicySweepResults {
    pub fn get(&self, policy: PolicyKind, level: usize) -> &WindowMetrics {
        self.points.get(&(policy, level)).expect("policy sweep point")
    }
}

/// Build the SimConfig for one policy-comparison point (shared by the
/// sweep and the targeted regression test below).
pub fn policy_point_config(
    model: PaperModel,
    policy: PolicyKind,
    rate: f64,
    window_s: f64,
    mix: &ClassMix,
) -> SimConfig {
    let mut cfg = SimConfig::new(System::Blink, model, rate, false);
    cfg.window_s = window_s;
    cfg.policy = policy;
    cfg.classes = Some(mix.clone());
    cfg
}

/// Run the policy comparison: Blink × the mixed interactive/batch
/// workload × all four admission policies (or one, via `only`) × the
/// policy load levels. Points are independent sims, sharded across
/// threads like the main sweep.
pub fn run_policy_sweep(
    model: PaperModel,
    window_s: f64,
    threads: usize,
    only: Option<PolicyKind>,
) -> PolicySweepResults {
    let levels = policy_load_levels();
    let mix = ClassMix::interactive_batch();
    let policies: Vec<PolicyKind> = match only {
        Some(p) => vec![p],
        None => PolicyKind::ALL.to_vec(),
    };
    let mut work: Vec<((PolicyKind, usize), SimConfig)> = vec![];
    for &policy in &policies {
        for (level, rate) in levels.iter().enumerate() {
            work.push((
                (policy, level),
                policy_point_config(model, policy, *rate, window_s, &mix),
            ));
        }
    }
    let results: Mutex<HashMap<(PolicyKind, usize), WindowMetrics>> = Mutex::new(HashMap::new());
    let next: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                let (key, cfg) = &work[i];
                let wm = simulate(cfg);
                results.lock().unwrap().insert(*key, wm);
            });
        }
    });
    PolicySweepResults { model, levels, mix, policies, points: results.into_inner().unwrap() }
}

// ---------------------------------------------------------------------------
// Prefix-reuse sweep: Blink on the multi-turn chat workload, prefix
// cache on vs off (the `blink eval prefix` experiment).
// ---------------------------------------------------------------------------

/// Session-arrival levels for the prefix comparison (sessions/s; each
/// session expands into ~3–5 turns, so the request rate is higher).
pub fn prefix_load_levels() -> Vec<f64> {
    vec![1.0, 2.0, 4.0, 6.0, 8.0, 12.0]
}

/// Prefix-cache token budget for the reuse condition — deliberately a
/// small slice of the H100 pool so the high end of the session-rate
/// sweep shows LRU eviction pressure, not just free hits.
pub const PREFIX_CACHE_TOKENS: usize = 600_000;

pub struct PrefixSweepResults {
    pub model: PaperModel,
    pub levels: Vec<f64>,
    pub mix: MultiTurnMix,
    /// (reuse_enabled, level) → window metrics.
    pub points: HashMap<(bool, usize), WindowMetrics>,
}

impl PrefixSweepResults {
    pub fn get(&self, reuse: bool, level: usize) -> &WindowMetrics {
        self.points.get(&(reuse, level)).expect("prefix sweep point")
    }
}

/// Build the SimConfig for one prefix-comparison point (shared by the
/// sweep and the acceptance test below).
pub fn prefix_point_config(
    model: PaperModel,
    reuse: bool,
    session_rate: f64,
    window_s: f64,
    mix: &MultiTurnMix,
) -> SimConfig {
    let mut cfg = SimConfig::new(System::Blink, model, session_rate, false);
    cfg.window_s = window_s;
    cfg.multi_turn = Some(mix.clone());
    cfg.prefix_cache_tokens = if reuse { PREFIX_CACHE_TOKENS } else { 0 };
    cfg
}

/// Run the prefix comparison: Blink × the multi-turn chat workload ×
/// {reuse, no-reuse} × the session-rate levels. Points are independent
/// sims, sharded across threads like the main sweep.
pub fn run_prefix_sweep(model: PaperModel, window_s: f64, threads: usize) -> PrefixSweepResults {
    let levels = prefix_load_levels();
    let mix = MultiTurnMix::chat();
    let mut work: Vec<((bool, usize), SimConfig)> = vec![];
    for reuse in [false, true] {
        for (level, rate) in levels.iter().enumerate() {
            work.push(((reuse, level), prefix_point_config(model, reuse, *rate, window_s, &mix)));
        }
    }
    let results: Mutex<HashMap<(bool, usize), WindowMetrics>> = Mutex::new(HashMap::new());
    let next: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                let (key, cfg) = &work[i];
                let wm = simulate(cfg);
                results.lock().unwrap().insert(*key, wm);
            });
        }
    });
    PrefixSweepResults { model, levels, mix, points: results.into_inner().unwrap() }
}

// ---------------------------------------------------------------------------
// Chunked-prefill sweep: Blink on the heavy-tailed long-prompt workload,
// chunk budgets against P99 TPOT/TTFT (the `blink eval chunked`
// experiment).
// ---------------------------------------------------------------------------

/// Chunk budgets for the chunked-prefill comparison, in tokens (0 = the
/// paper's whole-prompt prefill baseline). The interesting region sits
/// around the cost model's hide point (~150 tokens for the dense 8B):
/// small budgets ride the decode weight sweep nearly free, large ones
/// degenerate toward the whole-prompt stall.
pub fn chunk_budget_levels() -> Vec<usize> {
    vec![0, 128, 256, 512, 1024, 2048, 4096]
}

/// Offered load for the chunked comparison (req/s): enough concurrency
/// that document prefills genuinely stall in-flight decodes, below the
/// dense-8B knee so queueing doesn't swamp the mechanism.
pub const CHUNKED_SWEEP_RATE: f64 = 10.0;

pub struct ChunkedSweepResults {
    pub model: PaperModel,
    pub rate: f64,
    pub budgets: Vec<usize>,
    pub mix: LongPromptMix,
    /// budget-level index → window metrics.
    pub points: HashMap<usize, WindowMetrics>,
}

impl ChunkedSweepResults {
    pub fn get(&self, level: usize) -> &WindowMetrics {
        self.points.get(&level).expect("chunked sweep point")
    }
}

/// Build the SimConfig for one chunked-comparison point.
pub fn chunked_point_config(
    model: PaperModel,
    budget: usize,
    rate: f64,
    window_s: f64,
    mix: &LongPromptMix,
) -> SimConfig {
    let mut cfg = SimConfig::new(System::Blink, model, rate, false);
    cfg.window_s = window_s;
    cfg.long_prompts = Some(mix.clone());
    cfg.prefill_chunk_tokens = budget;
    cfg
}

/// Run the chunked-prefill comparison: Blink × the long-prompt document
/// mix × the chunk-budget levels at one fixed offered load. Every point
/// replays the *same trace* (same seed; the budget is not a trace
/// input), so curves differ only by the scheduling mechanism. Points
/// are independent sims, sharded across threads like the main sweep.
pub fn run_chunked_sweep(model: PaperModel, window_s: f64, threads: usize) -> ChunkedSweepResults {
    let budgets = chunk_budget_levels();
    let mix = LongPromptMix::document_chat();
    let work: Vec<(usize, SimConfig)> = budgets
        .iter()
        .enumerate()
        .map(|(level, &b)| {
            (level, chunked_point_config(model, b, CHUNKED_SWEEP_RATE, window_s, &mix))
        })
        .collect();
    let results: Mutex<HashMap<usize, WindowMetrics>> = Mutex::new(HashMap::new());
    let next: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                let (key, cfg) = &work[i];
                let wm = simulate(cfg);
                results.lock().unwrap().insert(*key, wm);
            });
        }
    });
    ChunkedSweepResults {
        model,
        rate: CHUNKED_SWEEP_RATE,
        budgets,
        mix,
        points: results.into_inner().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costmodel::LLAMA3_8B;

    #[test]
    fn small_sweep_has_expected_structure() {
        let r = run_sweep(&[LLAMA3_8B], 25.0, 4);
        assert_eq!(r.points.len(), 4 * 2 * 13);
        let sat = r.blink_saturation_level("llama3-8b");
        assert!(sat >= 3, "blink should absorb >= 4 req/s, sat level {sat}");
        // Blink throughput curve is monotone-ish up to saturation.
        let curve = r.tput_curve(System::Blink, "llama3-8b", false);
        assert!(curve[3] > curve[0]);
        // Interference: baselines retain less than blink at mid-load.
        let b_ret = r.get(System::Blink, "llama3-8b", true, 5).req_throughput
            / r.get(System::Blink, "llama3-8b", false, 5).req_throughput.max(1e-9);
        let v_ret = r.get(System::Vllm, "llama3-8b", true, 5).req_throughput
            / r.get(System::Vllm, "llama3-8b", false, 5).req_throughput.max(1e-9);
        assert!(b_ret > v_ret, "blink {b_ret} vllm {v_ret}");
    }

    /// The acceptance criterion of the staged-pipeline refactor: under a
    /// saturating mixed workload, the aging-priority policy holds the
    /// interactive class's P99 TTFT far below FCFS (which queues
    /// interactive requests behind the batch backlog indiscriminately).
    #[test]
    fn priority_aged_beats_fcfs_for_interactive_class_under_saturation() {
        let window = 25.0;
        let rate = 24.0; // well past the ~16 req/s knee for llama3-8b
        let mix = ClassMix::interactive_batch();
        let fcfs =
            simulate(&policy_point_config(LLAMA3_8B, PolicyKind::Fcfs, rate, window, &mix));
        let aged = simulate(&policy_point_config(
            LLAMA3_8B,
            PolicyKind::PriorityAged,
            rate,
            window,
            &mix,
        ));
        let fi = fcfs.class(4).expect("interactive completed under fcfs").ttft.p99;
        let ai = aged.class(4).expect("interactive completed under priority-aged").ttft.p99;
        assert!(
            ai < 0.8 * fi,
            "priority-aged interactive P99 TTFT {ai:.0} ms must beat fcfs {fi:.0} ms"
        );
        // FCFS treats the classes identically, so its interactive class
        // must be saturating too (sanity that the load is actually mixed
        // *and* saturating, not that priority-aged won by luck).
        assert!(fi > 1_000.0, "fcfs interactive P99 {fi:.0} ms should show queueing");
    }

    /// The acceptance criterion of the prefix-reuse feature: on the
    /// multi-turn chat workload, enabling the prefix cache improves mean
    /// TTFT by ≥2× at a ≥50 % token hit ratio versus the cold baseline.
    #[test]
    fn prefix_reuse_doubles_multi_turn_ttft_at_high_hit_ratio() {
        let mix = MultiTurnMix::chat();
        let window = 30.0;
        let rate = 4.0; // sessions/s, comfortably inside Blink's range
        let on = simulate(&prefix_point_config(LLAMA3_8B, true, rate, window, &mix));
        let off = simulate(&prefix_point_config(LLAMA3_8B, false, rate, window, &mix));
        assert!(on.completed > 50 && off.completed > 50, "both conditions must complete");
        let ratio = on.prefix.hit_ratio();
        assert!(ratio >= 0.5, "hit ratio {ratio:.2} must reach 0.5");
        assert!(
            off.ttft.mean >= 2.0 * on.ttft.mean,
            "reuse mean TTFT {:.1} ms must be ≥2x better than cold {:.1} ms",
            on.ttft.mean,
            off.ttft.mean
        );
        // The cold condition reports no cache activity at all.
        assert_eq!(off.prefix.lookups, 0);
        assert!(on.prefix.hits > 0 && on.prefix.hit_tokens > 0);
    }

    #[test]
    fn prefix_cache_evicts_under_session_pressure() {
        // Enough sessions that their histories exceed the cache budget:
        // the LRU must evict, and the hit ratio must survive it (recent
        // sessions keep hitting).
        let mix = MultiTurnMix::chat();
        let mut cfg = prefix_point_config(LLAMA3_8B, true, 12.0, 40.0, &mix);
        cfg.prefix_cache_tokens = 60_000; // deliberately tight
        let wm = simulate(&cfg);
        assert!(wm.prefix.evicted_tokens > 0, "tight budget must evict");
        assert!(wm.prefix.hit_tokens > 0, "recent sessions still hit");
    }

    #[test]
    fn chunked_sweep_structure_and_trace_identity() {
        let r = run_chunked_sweep(LLAMA3_8B, 8.0, 4);
        assert_eq!(r.points.len(), chunk_budget_levels().len());
        let whole = r.get(0);
        assert_eq!(whole.chunked.chunk_launches, 0, "budget 0 never chunks");
        // Same trace at every point: completions stay comparable and
        // the chunked points actually chunk.
        for (level, &b) in r.budgets.iter().enumerate().skip(1) {
            let wm = r.get(level);
            assert!(wm.completed > 0);
            assert!(
                wm.chunked.chunked_prefills > 0,
                "budget {b} must chunk the document prompts"
            );
            // Smaller budgets mean more launches per chunked prompt.
            assert!(wm.chunked.chunk_launches > wm.chunked.chunked_prefills);
        }
    }

    #[test]
    fn policy_sweep_structure_and_slo_policy() {
        // One level, two policies, small window: structural smoke test.
        let r = run_policy_sweep(LLAMA3_8B, 10.0, 4, Some(PolicyKind::SloAware));
        assert_eq!(r.points.len(), policy_load_levels().len());
        let wm = r.get(PolicyKind::SloAware, 0);
        assert!(wm.completed > 0);
        assert!(wm.class(4).is_some() && wm.class(0).is_some(), "both classes reported");
    }
}
