//! Per-system host-coupling models (paper §6.1 baselines).
//!
//! All four systems run the *same* FCFS continuous-batching policy in the
//! DES (as in the paper, which disables chunked prefill / prefix caching
//! for controlled comparison); they differ in where control lives:
//!
//! * per-decode-step host overhead (scheduler iteration, batch
//!   reassembly, kernel dispatch) — zero-ish for Blink (GPU-resident scan
//!   + device launch), milliseconds for host-driven stacks;
//! * per-request admission cost (HTTP, tokenization, scheduler enqueue on
//!   the host vs. DPU);
//! * interference sensitivity: how much CPU contention inflates the two
//!   costs above (Blink's costs live on DPU/GPU and do not inflate).
//!
//! Constants are calibrated against the paper's own measurements
//! (Tables 6/7/B.1/B.2); see EXPERIMENTS.md for the per-table comparison.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum System {
    Blink,
    TrtLlm,
    Vllm,
    Sglang,
}

pub const ALL_SYSTEMS: [System; 4] = [System::Blink, System::TrtLlm, System::Vllm, System::Sglang];

impl System {
    pub fn name(&self) -> &'static str {
        match self {
            System::Blink => "BLINK",
            System::TrtLlm => "TRT-LLM",
            System::Vllm => "vLLM",
            System::Sglang => "SGLang",
        }
    }

    pub fn by_name(s: &str) -> Option<System> {
        match s.to_ascii_lowercase().as_str() {
            "blink" => Some(System::Blink),
            "trt" | "trt-llm" | "trtllm" => Some(System::TrtLlm),
            "vllm" => Some(System::Vllm),
            "sglang" => Some(System::Sglang),
            _ => None,
        }
    }

    /// Host (or device) control overhead added to every decode iteration,
    /// seconds, in isolation: a fixed dispatch cost plus a per-sequence
    /// bookkeeping term (batch reassembly, block-table updates, sampler
    /// state — O(batch) on the host for CPU-coupled stacks, parallel
    /// across scheduler threads and therefore ~flat for Blink).
    pub fn step_overhead_s(&self, batch: usize) -> f64 {
        self.step_overhead_moe_s(batch, false)
    }

    /// MoE variant: host-coupled stacks pay a per-step expert-routing
    /// orchestration tax (gather router outputs, marshal expert dispatch,
    /// rebuild expert batches — §6.2: "CPU-mediated baselines still
    /// interpose host-side scheduling on every decode step"). Blink's
    /// device-side graph launch interprets router outputs on-GPU, so its
    /// cost is unchanged. Multipliers calibrated to the paper's MoE
    /// plateau retentions (TRT 3.61 / vLLM 2.91 / SGLang 2.62 vs 5.07).
    pub fn step_overhead_moe_s(&self, batch: usize, moe: bool) -> f64 {
        let moe_mult = if moe {
            match self {
                System::Blink => 1.0,
                System::TrtLlm => 5.5,
                System::Vllm => 6.0,
                System::Sglang => 5.0,
            }
        } else {
            1.0
        };
        let (base, per_seq) = match self {
            // Ring scan (1–5 µs) + device FnF launch (2 µs) + amortized
            // tail launch: all on-device, batch handled by parallel lanes.
            System::Blink => (7e-6, 0.0),
            // TRT-LLM's C++ runtime is the leanest host loop.
            System::TrtLlm => (0.3e-3, 15e-6),
            // vLLM v0.13 engine-core iteration (V1 overlap hides part).
            System::Vllm => (0.6e-3, 45e-6),
            // SGLang's Python scheduler w/ overlapped scheduling.
            System::Sglang => (1.0e-3, 60e-6),
        };
        (base + per_seq * batch as f64) * moe_mult
    }

    /// Per-request admission latency (transport + tokenize + enqueue until
    /// first schedulable), seconds, in isolation.
    pub fn admission_s(&self) -> f64 {
        match self {
            // DPU tokenizer + RDMA write + one ring-scan interval.
            System::Blink => 0.3e-3,
            System::TrtLlm => 28e-3,
            System::Vllm => 65e-3,
            System::Sglang => 190e-3,
        }
    }

    /// Mean multiplier interference applies to the two host costs above
    /// (paper §6.3: TRT-LLM degrades hardest, Blink not at all). The
    /// time-varying process around this mean lives in `interference.rs`.
    pub fn interference_sensitivity(&self) -> f64 {
        match self {
            System::Blink => 1.0,
            System::TrtLlm => 24.0,
            System::Vllm => 10.0,
            System::Sglang => 7.0,
        }
    }

    /// Host CPU active fraction attributable to serving (energy model).
    pub fn host_util(&self) -> f64 {
        match self {
            System::Blink => 0.02,
            System::TrtLlm => 0.25,
            System::Vllm => 0.40,
            System::Sglang => 0.45,
        }
    }

    /// Blink carries a BlueField-3 DPU (+~75 W, §6.4 accounting).
    pub fn dpu_power_w(&self) -> f64 {
        match self {
            System::Blink => 75.0,
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blink_is_orders_cheaper_per_step() {
        for s in [System::TrtLlm, System::Vllm, System::Sglang] {
            assert!(s.step_overhead_s(16) / System::Blink.step_overhead_s(16) > 50.0);
        }
    }

    #[test]
    fn host_overhead_scales_with_batch_except_blink() {
        assert_eq!(System::Blink.step_overhead_s(64), System::Blink.step_overhead_s(1));
        assert!(System::Vllm.step_overhead_s(64) > 2.0 * System::Vllm.step_overhead_s(1));
    }

    #[test]
    fn blink_immune_to_interference() {
        assert_eq!(System::Blink.interference_sensitivity(), 1.0);
        for s in [System::TrtLlm, System::Vllm, System::Sglang] {
            assert!(s.interference_sensitivity() > 1.0);
        }
    }

    #[test]
    fn names_roundtrip() {
        for s in ALL_SYSTEMS {
            assert_eq!(System::by_name(s.name()), Some(s));
        }
    }
}
