//! Discrete-event serving simulator: the paper's evaluation harness for
//! the four (unrunnable-here) testbed models. One `simulate` call = one
//! (system, model, load, interference) point of the sweep: Poisson
//! arrivals, FCFS continuous batching with whole-prompt prefill (chunked
//! prefill disabled, as in the paper's controlled setup), roofline GPU
//! step costs, system-specific host coupling, and the time-varying
//! interference process applied to *host-side* work only.
//!
//! The simulation is step-granular (one event per decode iteration /
//! prefill batch), which preserves exactly the quantities the paper
//! reports: TTFT (admission + queue + prefill), TPOT (steady decode
//! cadence), ITL (per-token gaps incl. prefill pauses — the §3.1 "jitter"
//! gap between ITL and TPOT), throughput and saturation behaviour.

use std::collections::HashMap;

use crate::gpu::policy::{Candidate, PolicyKind};
use crate::sim::costmodel::{CostModel, PaperModel};
use crate::sim::energy::PowerModel;
use crate::sim::interference::InterferenceProcess;
use crate::sim::systems::System;
use crate::util::rng::Rng;
use crate::workload::{
    ChunkStats, ClassMix, LengthModel, LongPromptMix, MultiTurnMix, OverloadStats, PrefixStats,
    RequestMetrics, TraceGen, TraceRequest, WindowMetrics,
};

/// Per-tenant token-bucket quota for the simulated admission gate
/// (mirrors the live `OverloadGate`'s bucket slab at float precision).
#[derive(Debug, Clone, Copy)]
pub struct TenantBucketCfg {
    /// Burst capacity in requests.
    pub capacity: f64,
    /// Sustained refill rate in requests/second.
    pub refill_per_s: f64,
    /// Number of tenants stamped onto the trace
    /// (see [`crate::workload::assign_tenants`]).
    pub tenants: u64,
    /// Share of the trace sent by a single hot tenant (0.0 = uniform).
    pub hot_share: f64,
}

/// Shed policy for the simulated gate: below-floor work is degraded
/// (output capped) above `degrade_threshold` pressure and dropped above
/// `drop_threshold`; interactive-class work is only stopped by the hard
/// window cap. [`ShedPolicyCfg::off`] (infinite thresholds) is the
/// default — the paper's open-loop behavior.
#[derive(Debug, Clone, Copy)]
pub struct ShedPolicyCfg {
    pub degrade_threshold: f64,
    pub drop_threshold: f64,
    /// Output-token cap applied to degraded admissions.
    pub degrade_max_new: usize,
    /// Priority at or above which a request is interactive-class.
    pub interactive_floor: u32,
}

impl ShedPolicyCfg {
    pub fn off() -> ShedPolicyCfg {
        ShedPolicyCfg {
            degrade_threshold: f64::INFINITY,
            drop_threshold: f64::INFINITY,
            degrade_max_new: 16,
            interactive_floor: 4,
        }
    }

    /// The live gate's default thresholds (degrade at 50 % pressure,
    /// drop at 80 %).
    pub fn degrade_then_drop(degrade_max_new: usize) -> ShedPolicyCfg {
        ShedPolicyCfg {
            degrade_threshold: 0.5,
            drop_threshold: 0.8,
            degrade_max_new,
            interactive_floor: 4,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub system: System,
    pub model: PaperModel,
    pub interference: bool,
    pub rate: f64,
    pub window_s: f64,
    pub seed: u64,
    pub lengths: LengthModel,
    /// Upper bound on concurrent sequences (engine max_num_seqs).
    pub max_num_seqs: usize,
    /// Max prompts admitted per prefill batch.
    pub max_prefill_batch: usize,
    /// Admission policy over the schedulable queue — the *same*
    /// `AdmissionPolicy` implementations the live scheduler runs, so the
    /// DES exercises the real ranking code. FCFS reproduces the paper.
    pub policy: PolicyKind,
    /// Mixed-priority workload; `None` = the single-class `lengths` model.
    pub classes: Option<ClassMix>,
    /// Multi-turn conversation workload (`rate` = sessions/s); takes
    /// precedence over `classes`/`lengths` when set.
    pub multi_turn: Option<MultiTurnMix>,
    /// Prefix-cache capacity in tokens; 0 disables reuse (the paper's
    /// configuration). When enabled the DES mirrors the live KvManager's
    /// behavior at token granularity: each admission charges prefill only
    /// for the uncached suffix of its session history, and cached
    /// sessions are evicted LRU under capacity pressure.
    pub prefix_cache_tokens: usize,
    /// Per-iteration prefill token budget, mirroring the live
    /// scheduler's `--prefill-chunk-tokens`: an admitted prompt whose
    /// uncached suffix exceeds the budget prefills in chunks of at most
    /// this many tokens — one budget-bounded round per scheduler
    /// iteration, decode steps interleaved, first token only after the
    /// final chunk. 0 = whole-prompt prefill (the paper's setup, and
    /// the §3.1 head-of-line-blocking regime under long prompts).
    pub prefill_chunk_tokens: usize,
    /// Heavy-tailed long-prompt workload (the chunked-prefill
    /// comparison's trace); takes precedence over `classes`/`lengths`,
    /// but not over `multi_turn`, when set.
    pub long_prompts: Option<LongPromptMix>,
    /// Admission-edge sliding-window rate limit (requests/second over a
    /// 1 s window), mirroring the live `OverloadGate`. 0.0 = unlimited.
    pub rate_limit: f64,
    /// Per-tenant token buckets at the admission edge; `None` = no
    /// per-tenant quota.
    pub tenant_buckets: Option<TenantBucketCfg>,
    /// Shed policy at the admission edge (see [`ShedPolicyCfg`]).
    pub shed_policy: ShedPolicyCfg,
    /// Speculative decoding (DESIGN.md §11): draft tokens verified per
    /// decode iteration, mirroring the live scheduler's `spec_k`. Each
    /// iteration charges [`CostModel::verify_step_with_chunk_s`] (the
    /// weight sweep paid once for the whole k+1 window) and every lane
    /// retires 1 + its seeded run of leading draft accepts, capped at
    /// its output budget. 0 = plain decode (the paper's setup).
    pub spec_k: usize,
    /// Per-position draft-acceptance probability for `spec_k > 0`
    /// (seeded — the sweep is deterministic per config). 1.0 = every
    /// draft accepted.
    pub spec_accept: f64,
}

impl SimConfig {
    pub fn new(system: System, model: PaperModel, rate: f64, interference: bool) -> SimConfig {
        SimConfig {
            system,
            model,
            interference,
            rate,
            window_s: 60.0,
            seed: 0xB11AC << 8 | (rate as u64),
            lengths: LengthModel::sharegpt(),
            max_num_seqs: 64,
            max_prefill_batch: 8,
            policy: PolicyKind::Fcfs,
            classes: None,
            multi_turn: None,
            prefix_cache_tokens: 0,
            prefill_chunk_tokens: 0,
            long_prompts: None,
            rate_limit: 0.0,
            tenant_buckets: None,
            shed_policy: ShedPolicyCfg::off(),
            spec_k: 0,
            spec_accept: 1.0,
        }
    }

    /// Reject degenerate configurations before they can poison the
    /// event loop: a non-finite rate makes every Poisson inter-arrival
    /// gap NaN (`exp(rate)`), which would spin the arrival loop forever
    /// and defeat the arrival-time sorts — caught here, once, with a
    /// clear message instead of a deep-in-the-sweep panic or hang.
    pub fn validate(&self) -> Result<(), String> {
        if !self.rate.is_finite() || self.rate <= 0.0 {
            return Err(format!("offered rate must be finite and > 0, got {}", self.rate));
        }
        if !self.window_s.is_finite() || self.window_s <= 0.0 {
            return Err(format!("window_s must be finite and > 0, got {}", self.window_s));
        }
        Ok(())
    }
}

/// Token-granular stand-in for the live `kvcache` prefix index: cached
/// history per session + a shared system-prompt prefix, LRU-evicted
/// under a token budget. Block alignment mirrors the live manager's
/// full-block-only matching.
struct PrefixCacheSim {
    budget: usize,
    block: usize,
    /// Cross-session shared prefix (the common system prompt), cacheable
    /// once any session has warmed the index.
    shared_base: usize,
    warm: bool,
    total: usize,
    tick: u64,
    /// session → (cached tokens, last-use tick).
    sessions: HashMap<u64, (usize, u64)>,
    stats: PrefixStats,
}

impl PrefixCacheSim {
    fn new(budget: usize, shared_base: usize) -> PrefixCacheSim {
        PrefixCacheSim {
            budget,
            block: 16,
            shared_base,
            warm: false,
            total: 0,
            tick: 0,
            sessions: HashMap::new(),
            stats: PrefixStats::default(),
        }
    }

    /// Cached-prefix tokens available to this request (block-aligned,
    /// capped below the full prompt as the live manager does).
    fn lookup(&mut self, r: &TraceRequest) -> usize {
        self.stats.lookups += 1;
        self.stats.input_tokens += r.input_tokens as u64;
        self.tick += 1;
        let cached = match self.sessions.get_mut(&r.session_id) {
            Some(e) if r.session_id != 0 => {
                e.1 = self.tick;
                e.0
            }
            // Unseen session: only the cross-session shared prefix (the
            // common system prompt) can hit, and only once warmed.
            _ if self.warm => self.shared_base,
            _ => 0,
        };
        let hit = cached.min(r.history_tokens).min(r.input_tokens.saturating_sub(1))
            / self.block
            * self.block;
        if hit > 0 {
            self.stats.hits += 1;
            self.stats.hit_tokens += hit as u64;
        }
        hit
    }

    /// Record a session's cached tokens (monotone per session), evicting
    /// least-recently-used sessions over budget. `tokens` is aligned
    /// *down* to a full block first, mirroring the live manager: only
    /// full prompt blocks are ever indexed — in particular a turn's
    /// generated reply is not matchable until the *next* turn's prompt
    /// (which contains it) commits.
    fn store(&mut self, session: u64, tokens: usize) {
        if session == 0 {
            return;
        }
        let tokens = tokens / self.block * self.block;
        self.warm = true;
        self.tick += 1;
        let e = self.sessions.entry(session).or_insert((0, self.tick));
        self.total += tokens.saturating_sub(e.0);
        e.0 = e.0.max(tokens);
        e.1 = self.tick;
        while self.total > self.budget && self.sessions.len() > 1 {
            let (&victim, &(toks, _)) = self
                .sessions
                .iter()
                .min_by_key(|(_, (_, tick))| *tick)
                .expect("non-empty");
            if victim == session {
                break; // never evict the entry just refreshed
            }
            self.sessions.remove(&victim);
            self.total -= toks;
            self.stats.evicted_tokens += toks as u64;
        }
    }
}

/// The DES mirror of the live `OverloadGate`: same decision order
/// (tenant bucket → sliding window → class-aware shed), simulated in
/// virtual time with exact (timestamp-queue) window accounting instead
/// of the live two-bucket estimate.
struct GateSim {
    rate_limit: f64,
    buckets_cfg: Option<TenantBucketCfg>,
    shed: ShedPolicyCfg,
    /// Admission timestamps within the trailing 1 s window.
    window: std::collections::VecDeque<f64>,
    /// tenant → (bucket level, last refill time).
    buckets: HashMap<u64, (f64, f64)>,
    admitted_by_tenant: HashMap<u64, u64>,
    stats: OverloadStats,
}

enum GateSimDecision {
    Admit,
    Degrade,
    Drop,
}

impl GateSim {
    fn new(cfg: &SimConfig) -> GateSim {
        GateSim {
            rate_limit: cfg.rate_limit,
            buckets_cfg: cfg.tenant_buckets,
            shed: cfg.shed_policy,
            window: std::collections::VecDeque::new(),
            buckets: HashMap::new(),
            admitted_by_tenant: HashMap::new(),
            stats: OverloadStats::default(),
        }
    }

    /// Gate one request at virtual time `t`. `backlog_util` plays the
    /// live gate's ring-occupancy role: schedulable backlog relative to
    /// a few batches' worth of slack.
    fn check(&mut self, r: &TraceRequest, t: f64, backlog_util: f64) -> GateSimDecision {
        self.stats.offered += 1;
        // 1. Tenant bucket (charged to the flooder before the window).
        if let Some(cfg) = self.buckets_cfg {
            let e = self.buckets.entry(r.tenant).or_insert((cfg.capacity, t));
            e.0 = (e.0 + (t - e.1) * cfg.refill_per_s).min(cfg.capacity);
            e.1 = t;
            if e.0 < 1.0 {
                self.stats.rejected_bucket += 1;
                return GateSimDecision::Drop;
            }
        }
        // 2. Global sliding window + class-aware shed.
        let mut window_util = 0.0;
        if self.rate_limit > 0.0 {
            while self.window.front().is_some_and(|&a| a <= t - 1.0) {
                self.window.pop_front();
            }
            let est = self.window.len() as f64;
            if est >= self.rate_limit {
                self.stats.rejected_rate += 1;
                return GateSimDecision::Drop;
            }
            window_util = est / self.rate_limit;
        }
        let pressure = window_util.max(backlog_util);
        let interactive = r.priority >= self.shed.interactive_floor;
        if !interactive {
            if pressure >= self.shed.drop_threshold {
                self.stats.shed_dropped += 1;
                return GateSimDecision::Drop;
            }
            if pressure >= self.shed.degrade_threshold {
                self.commit(r, t);
                self.stats.shed_degraded += 1;
                return GateSimDecision::Degrade;
            }
        }
        self.commit(r, t);
        GateSimDecision::Admit
    }

    fn commit(&mut self, r: &TraceRequest, t: f64) {
        if let Some(e) = self.buckets.get_mut(&r.tenant) {
            e.0 = (e.0 - 1.0).max(0.0);
        }
        if self.rate_limit > 0.0 {
            self.window.push_back(t);
        }
        self.stats.admitted += 1;
        *self.admitted_by_tenant.entry(r.tenant).or_insert(0) += 1;
    }

    fn into_stats(mut self) -> OverloadStats {
        let mut by_tenant: Vec<(u64, u64)> = self.admitted_by_tenant.into_iter().collect();
        by_tenant.sort_unstable();
        self.stats.admitted_by_tenant = by_tenant;
        self.stats
    }
}

struct Run {
    req: TraceRequest,
    produced: usize,
    ctx: usize,
    first_token_s: f64,
    last_token_s: f64,
    itl_s: Vec<f64>,
}

/// One admitted request mid-chunked-prefill (the DES mirror of the live
/// scheduler's `ChunkedPrefill` state machine): `remaining` uncached
/// suffix tokens still to prefill, consumed in budget-bounded rounds;
/// the request produces its first token when the final chunk lands.
struct ChunkRun {
    req: TraceRequest,
    remaining: usize,
}

pub fn simulate(cfg: &SimConfig) -> WindowMetrics {
    let sens =
        if cfg.interference { cfg.system.interference_sensitivity() } else { 1.0 };
    simulate_with_sensitivity(cfg, sens)
}

/// Like [`simulate`] but with an explicit mean inflation multiplier for
/// host-side work — used by the §3 ablations (partial interferers, core
/// pinning, CAT) where the effective pressure differs from the full
/// colocation scenario.
pub fn simulate_with_sensitivity(cfg: &SimConfig, sensitivity: f64) -> WindowMetrics {
    cfg.validate().expect("invalid SimConfig");
    // Interference runs use an independent seed even for immune systems:
    // the paper reports Blink's interference numbers as "within
    // experimental variance" of isolation, i.e. a different run, not a
    // bit-identical replay.
    let iseed = if cfg.interference { cfg.seed.rotate_left(17) ^ 0xC010C } else { cfg.seed };
    let mut rng = Rng::new(iseed ^ sys_tag(cfg.system));
    let cm = CostModel::new(cfg.model);
    let mut trace = if let Some(mt) = &cfg.multi_turn {
        mt.generate(&mut rng.fork(1), cfg.rate, cfg.window_s, 8192, 4096)
    } else if let Some(lp) = &cfg.long_prompts {
        lp.generate(&mut rng.fork(1), cfg.rate, cfg.window_s, 8192, 4096)
    } else {
        match &cfg.classes {
            Some(mix) => mix.generate(&mut rng.fork(1), cfg.rate, cfg.window_s, 8192, 4096),
            None => TraceGen::new(cfg.lengths, 8192, 4096)
                .generate(&mut rng.fork(1), cfg.rate, cfg.window_s),
        }
    };
    if let Some(tb) = cfg.tenant_buckets {
        crate::workload::assign_tenants(&mut trace, tb.tenants, tb.hot_share);
    }
    let policy = cfg.policy.build();
    let mut prefix: Option<PrefixCacheSim> = if cfg.prefix_cache_tokens > 0 {
        let shared = cfg.multi_turn.as_ref().map_or(0, |m| m.system_prompt_tokens);
        Some(PrefixCacheSim::new(cfg.prefix_cache_tokens, shared))
    } else {
        None
    };

    let interference = if sensitivity > 1.0 {
        InterferenceProcess::new(sensitivity, &mut rng)
    } else {
        InterferenceProcess::none()
    };

    // Requests become schedulable after the system's admission path
    // (HTTP + tokenize + enqueue), which inflates under interference for
    // host-coupled systems.
    let mut ready: Vec<(f64, TraceRequest)> = trace
        .iter()
        .map(|r| {
            let adm = cfg.system.admission_s() * interference.sample(r.arrival_s, &mut rng);
            (r.arrival_s + adm, *r)
        })
        .collect();
    // `total_cmp`: no panic even if a degenerate admission model ever
    // produced a non-finite ready time (rates are validated above).
    ready.sort_by(|a, b| a.0.total_cmp(&b.0));

    let mean_footprint = mean_tokens(&trace).max(64.0);
    let max_batch = cm.max_batch(mean_footprint).min(cfg.max_num_seqs);

    let mut gate = GateSim::new(cfg);
    let mut t = 0.0f64;
    let mut next_ready = 0usize;
    // Schedulable queue: (ready_s, request, submission ticket). The
    // admission policy re-ranks it at every admission opportunity, so
    // aging and deadline slack are evaluated against the current clock.
    let mut pending: Vec<(f64, TraceRequest, u64)> = vec![];
    let mut ticket_ctr = 0u64;
    let mut running: Vec<Run> = vec![];
    // Admitted lanes mid-chunked-prefill (FIFO; the same one-round-per-
    // iteration servicing as the live scheduler's chunk_step).
    let mut chunking: Vec<ChunkRun> = vec![];
    let mut chunk_stats = ChunkStats::default();
    let budget = cfg.prefill_chunk_tokens;
    let mut done: Vec<RequestMetrics> = vec![];
    let mut gpu_busy_s = 0.0f64;
    let drain_deadline = cfg.window_s * 4.0 + 120.0;

    while (next_ready < ready.len()
        || !pending.is_empty()
        || !running.is_empty()
        || !chunking.is_empty())
        && t < drain_deadline
    {
        // Requests whose admission path finished become schedulable —
        // after the overload gate (the frontend edge): the gate runs
        // before any queueing, so refused work never joins `pending`.
        while next_ready < ready.len() && ready[next_ready].0 <= t {
            let (ready_s, mut r) = (ready[next_ready].0, ready[next_ready].1);
            next_ready += 1;
            let backlog_util = pending.len() as f64 / (4 * max_batch).max(1) as f64;
            match gate.check(&r, ready_s, backlog_util.min(1.0)) {
                GateSimDecision::Drop => continue,
                GateSimDecision::Degrade => {
                    r.output_tokens = r.output_tokens.min(cfg.shed_policy.degrade_max_new.max(1));
                }
                GateSimDecision::Admit => {}
            }
            pending.push((ready_s, r, ticket_ctr));
            ticket_ctr += 1;
        }

        // Admit in policy order while capacity allows; prefill in
        // batches. Chunking lanes hold batch slots until they finish.
        let free = max_batch
            .saturating_sub(running.len() + chunking.len())
            .min(cfg.max_prefill_batch);
        let mut admitted: Vec<TraceRequest> = vec![];
        if free > 0 && !pending.is_empty() {
            let now_us = (t * 1e6) as u64;
            let mut cands: Vec<Candidate> = pending
                .iter()
                .enumerate()
                .map(|(i, (ready_s, r, ticket))| Candidate {
                    slot: i,
                    ticket: *ticket,
                    priority: r.priority,
                    prompt_len: r.input_tokens as u32,
                    submit_time_us: (ready_s * 1e6) as u64,
                    ttft_deadline_us: if r.ttft_budget_s > 0.0 {
                        ((ready_s + r.ttft_budget_s) * 1e6) as u64
                    } else {
                        0
                    },
                })
                .collect();
            policy.order(&mut cands, now_us);
            let chosen: Vec<usize> = cands.iter().take(free).map(|c| c.slot).collect();
            for &i in &chosen {
                admitted.push(pending[i].1);
            }
            let mut remove_idx = chosen;
            remove_idx.sort_unstable();
            for i in remove_idx.into_iter().rev() {
                pending.remove(i);
            }
        }
        if !admitted.is_empty() {
            // Pause decode, run one prefill batch (paper policy), resume.
            // With prefix reuse, each request charges only its uncached
            // suffix — the cached history's K/V is already resident. A
            // suffix over the chunk budget does *not* prefill inline: it
            // queues for budget-bounded chunk rounds below, exactly like
            // the live scheduler's ChunkedPrefill state machine.
            let mut direct: Vec<TraceRequest> = vec![];
            let mut direct_tokens = 0usize;
            for r in admitted {
                let hit = prefix.as_mut().map_or(0, |p| p.lookup(&r));
                let suffix = r.input_tokens - hit;
                if budget > 0 && suffix > budget {
                    chunk_stats.chunked_prefills += 1;
                    chunking.push(ChunkRun { req: r, remaining: suffix });
                } else {
                    direct_tokens += suffix;
                    direct.push(r);
                }
            }
            // The admitted prompts themselves become cached history
            // (full prompt blocks only — the live path's index_prompt
            // commits exactly this after the prefill; replies become
            // matchable only once a later prompt containing them
            // commits). Chunked prompts commit when their final chunk
            // lands, mirroring the live partial-index invariant.
            if let Some(p) = prefix.as_mut() {
                for r in &direct {
                    p.store(r.session_id, r.input_tokens);
                }
            }
            if !direct.is_empty() {
                let host = cfg
                    .system
                    .step_overhead_moe_s(
                        running.len() + chunking.len() + direct.len(),
                        cfg.model.moe,
                    )
                    * interference.sample(t, &mut rng);
                let dur = cm.prefill_s(direct_tokens) + host;
                gpu_busy_s += cm.prefill_s(direct_tokens);
                t += dur;
                for r in direct {
                    running.push(Run {
                        req: r,
                        produced: 1, // prefill emits the first token
                        ctx: r.input_tokens + 1,
                        first_token_s: t,
                        last_token_s: t,
                        itl_s: vec![],
                    });
                }
                // Single-token requests finish at prefill.
                retire(&mut running, &mut done);
                if chunking.is_empty() {
                    // No chunked lanes in flight: identical cadence to
                    // the pre-chunking loop (re-check arrivals first).
                    continue;
                }
                // Chunked lanes in flight: fall through to the chunk
                // round + decode step below — the live control loop
                // runs chunk_step every iteration, admission ones
                // included, so skipping the round here would starve
                // mid-flight lanes under sustained arrivals.
            }
        }

        // Budget-bounded chunk servicing for this iteration: FIFO from
        // the oldest chunking lane, one chunk per lane, at least one
        // lane when any are queued — the same round the live
        // scheduler's `chunk_step` runs. Returns the lengths taken.
        let chunk_lens: Vec<usize> = if chunking.is_empty() {
            vec![]
        } else {
            let mut serviced = 0usize;
            let mut spent = 0usize;
            while serviced < chunking.len() {
                let len = chunking[serviced].remaining.min(budget);
                if serviced > 0 && spent + len > budget {
                    break;
                }
                spent += len;
                serviced += 1;
            }
            chunking
                .iter_mut()
                .take(serviced)
                .map(|cr| {
                    let len = cr.remaining.min(budget);
                    cr.remaining -= len;
                    chunk_stats.chunk_launches += 1;
                    len
                })
                .collect()
        };

        if running.is_empty() {
            if chunk_lens.is_empty() {
                // Idle: jump to the next ready request.
                if next_ready < ready.len() {
                    t = t.max(ready[next_ready].0);
                }
                continue;
            }
            // No decode lanes to piggyback on: the chunk round runs as
            // standalone prefill launches.
            let round: f64 = chunk_lens.iter().map(|&l| cm.prefill_s(l)).sum();
            let host = cfg
                .system
                .step_overhead_moe_s(chunking.len(), cfg.model.moe)
                * interference.sample(t, &mut rng);
            gpu_busy_s += round;
            t += round + host;
            finish_chunked(&mut chunking, &mut running, &mut prefix, t);
            retire(&mut running, &mut done);
            continue;
        }

        // One decode iteration for the whole batch — carrying this
        // round's chunks as piggybacked launches: the weight sweep is
        // paid once, the bounded chunk's GEMMs largely hide beneath it
        // (`decode_step_with_chunk_s`), and each chunk pays its own
        // launch overhead. This is what turns a long prompt's prefill
        // from an exclusive decode stall into bounded per-iteration
        // work — the quantity the chunk-budget sweep trades against
        // the per-launch overhead.
        let b = running.len();
        let mean_ctx = running.iter().map(|r| r.ctx as f64).sum::<f64>() / b as f64;
        let chunk_tokens: usize = chunk_lens.iter().sum();
        // With spec_k > 0 the iteration is a (k+1)-wide draft-verify
        // launch (DESIGN.md §11): the verify cost charges the weight
        // sweep once for the whole window — the speculative win — while
        // KV reads and GEMM FLOPs scale with k+1. k = 0 is plain decode
        // through the same delegating cost form, so the paper sweeps
        // are untouched byte-for-byte.
        let k = cfg.spec_k;
        let gpu = cm.verify_step_with_chunk_s(b, mean_ctx, k, chunk_tokens)
            + chunk_lens.len() as f64 * cm.hw.graph_exec_overhead_s;
        let host =
            cfg.system.step_overhead_moe_s(b, cfg.model.moe) * interference.sample(t, &mut rng);
        t += gpu + host;
        gpu_busy_s += gpu;
        for r in running.iter_mut() {
            // Tokens retired this launch: the always-valid bonus token
            // plus the lane's seeded run of leading draft accepts,
            // truncated at the first miss (one divergence poisons the
            // rest of the window) and at the output budget — the DES
            // mirror of the live scheduler's longest-prefix retire and
            // budget-edge clamp. All of a launch's tokens land at the
            // same completion instant, so the first carries the full
            // inter-launch gap and the rest are intra-window zeros;
            // TPOT percentiles see exactly that burstiness.
            let remaining = r.req.output_tokens.saturating_sub(r.produced);
            let mut emitted = 1usize;
            while emitted <= k && emitted < remaining && rng.f64() < cfg.spec_accept {
                emitted += 1;
            }
            r.produced += emitted;
            r.ctx += emitted;
            r.itl_s.push(t - r.last_token_s);
            for _ in 1..emitted {
                r.itl_s.push(0.0);
            }
            r.last_token_s = t;
        }
        // Lanes whose final chunk landed open their decode lane now
        // (first token at the end of this iteration, not a decode
        // token — they start producing next iteration).
        finish_chunked(&mut chunking, &mut running, &mut prefix, t);
        retire(&mut running, &mut done);
    }

    let mut wm = WindowMetrics::from_requests(cfg.rate, cfg.window_s, &done);
    if let Some(p) = &prefix {
        wm.prefix = p.stats;
    }
    wm.chunked = chunk_stats;
    wm.overload = gate.into_stats();
    // Energy: GPU utilization over the *active* span.
    let active = t.min(cfg.window_s).max(1e-9);
    let gpu_util = (gpu_busy_s.min(active) / active).clamp(0.0, 1.0);
    let tok_s = wm.decode_tok_s + wm.prefill_tok_s * 0.0; // paper: per generated token
    wm.energy_mj_per_tok = PowerModel::default().mj_per_token(
        cfg.system,
        gpu_util,
        cfg.interference,
        tok_s.max(1e-9),
    );
    wm
}

/// Chunked lanes whose final chunk just landed produce their first
/// token at `t`: the cached history commits (the live partial-index
/// invariant — a prompt becomes matchable only once fully prefilled;
/// intermediate chunks are already committed progressively on the live
/// path, which the session-granular cache sim cannot express, so it
/// commits at completion) and a decode lane opens.
fn finish_chunked(
    chunking: &mut Vec<ChunkRun>,
    running: &mut Vec<Run>,
    prefix: &mut Option<PrefixCacheSim>,
    t: f64,
) {
    let mut i = 0;
    while i < chunking.len() {
        if chunking[i].remaining == 0 {
            let cr = chunking.remove(i);
            if let Some(p) = prefix.as_mut() {
                p.store(cr.req.session_id, cr.req.input_tokens);
            }
            running.push(Run {
                req: cr.req,
                produced: 1,
                ctx: cr.req.input_tokens + 1,
                first_token_s: t,
                last_token_s: t,
                itl_s: vec![],
            });
        } else {
            i += 1;
        }
    }
}

fn retire(running: &mut Vec<Run>, done: &mut Vec<RequestMetrics>) {
    let mut i = 0;
    while i < running.len() {
        if running[i].produced >= running[i].req.output_tokens {
            let r = running.swap_remove(i);
            done.push(RequestMetrics {
                id: r.req.id,
                arrival_s: r.req.arrival_s,
                first_token_s: r.first_token_s,
                finish_s: r.last_token_s,
                input_tokens: r.req.input_tokens,
                output_tokens: r.req.output_tokens,
                itl_s: r.itl_s,
                priority: r.req.priority,
                ttft_budget_s: r.req.ttft_budget_s,
            });
        } else {
            i += 1;
        }
    }
}

fn mean_tokens(trace: &[TraceRequest]) -> f64 {
    if trace.is_empty() {
        return 0.0;
    }
    trace.iter().map(|r| (r.input_tokens + r.output_tokens) as f64).sum::<f64>()
        / trace.len() as f64
}

fn sys_tag(s: System) -> u64 {
    match s {
        System::Blink => 0x11,
        System::TrtLlm => 0x22,
        System::Vllm => 0x33,
        System::Sglang => 0x44,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costmodel::{LLAMA3_8B, QWEN3_30B_A3B};

    #[test]
    fn low_load_all_complete() {
        for sys in crate::sim::systems::ALL_SYSTEMS {
            let cfg = SimConfig::new(sys, LLAMA3_8B, 2.0, false);
            let wm = simulate(&cfg);
            assert!(wm.completed as f64 >= 0.8 * 2.0 * 50.0, "{sys:?}: {}", wm.completed);
            assert!(wm.ttft.p99 > 0.0 && wm.tpot.p99 > 0.0);
        }
    }

    #[test]
    fn blink_beats_baselines_pre_saturation() {
        let b = simulate(&SimConfig::new(System::Blink, LLAMA3_8B, 8.0, false));
        let v = simulate(&SimConfig::new(System::Vllm, LLAMA3_8B, 8.0, false));
        let s = simulate(&SimConfig::new(System::Sglang, LLAMA3_8B, 8.0, false));
        assert!(b.ttft.p99 < v.ttft.p99, "blink {} vs vllm {}", b.ttft.p99, v.ttft.p99);
        assert!(b.tpot.p99 < v.tpot.p99);
        assert!(v.ttft.p99 < s.ttft.p99, "vllm {} vs sglang {}", v.ttft.p99, s.ttft.p99);
    }

    #[test]
    fn interference_collapses_baselines_not_blink() {
        let iso = simulate(&SimConfig::new(System::Blink, LLAMA3_8B, 8.0, false));
        let int = simulate(&SimConfig::new(System::Blink, LLAMA3_8B, 8.0, true));
        let ratio = int.req_throughput / iso.req_throughput;
        assert!(ratio > 0.9, "blink retention {ratio}");

        let viso = simulate(&SimConfig::new(System::Vllm, LLAMA3_8B, 8.0, false));
        let vint = simulate(&SimConfig::new(System::Vllm, LLAMA3_8B, 8.0, true));
        let vratio = vint.req_throughput / viso.req_throughput;
        assert!(vratio < 0.7, "vllm retention {vratio}");
        assert!(vint.tpot.p99 > 2.0 * viso.tpot.p99, "vllm TPOT must inflate");
    }

    #[test]
    fn moe_amplifies_blink_advantage() {
        // §6.2: host expert-routing tax makes the MoE *throughput* gap at
        // saturating load larger than the dense gap (paper: 37 % vs 9 %).
        let bm = simulate(&SimConfig::new(System::Blink, QWEN3_30B_A3B, 8.0, false));
        let vm = simulate(&SimConfig::new(System::Vllm, QWEN3_30B_A3B, 8.0, false));
        let bd = simulate(&SimConfig::new(System::Blink, LLAMA3_8B, 16.0, false));
        let vd = simulate(&SimConfig::new(System::Vllm, LLAMA3_8B, 16.0, false));
        let moe_gap = bm.req_throughput / vm.req_throughput;
        let dense_gap = bd.req_throughput / vd.req_throughput;
        assert!(moe_gap > dense_gap, "moe {moe_gap} dense {dense_gap}");
        assert!(moe_gap > 1.2, "moe gap should be large: {moe_gap}");
    }

    #[test]
    fn determinism() {
        let cfg = SimConfig::new(System::Vllm, LLAMA3_8B, 6.0, true);
        let a = simulate(&cfg);
        let b = simulate(&cfg);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.ttft.p99, b.ttft.p99);
    }

    fn overloaded_cfg(rate: f64) -> SimConfig {
        let mut cfg = SimConfig::new(System::Blink, LLAMA3_8B, rate, false);
        cfg.classes = Some(ClassMix::interactive_batch());
        cfg
    }

    #[test]
    fn gated_sim_is_deterministic() {
        let mut cfg = overloaded_cfg(24.0);
        cfg.rate_limit = 12.0;
        cfg.shed_policy = ShedPolicyCfg::degrade_then_drop(16);
        cfg.tenant_buckets =
            Some(TenantBucketCfg { capacity: 32.0, refill_per_s: 4.0, tenants: 8, hot_share: 0.5 });
        let a = simulate(&cfg);
        let b = simulate(&cfg);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.overload.admitted, b.overload.admitted);
        assert_eq!(a.overload.rejected_rate, b.overload.rejected_rate);
        assert_eq!(a.overload.rejected_bucket, b.overload.rejected_bucket);
        assert_eq!(a.overload.shed_dropped, b.overload.shed_dropped);
        assert_eq!(a.overload.shed_degraded, b.overload.shed_degraded);
        assert_eq!(a.overload.admitted_by_tenant, b.overload.admitted_by_tenant);
    }

    #[test]
    fn limiter_and_shed_protect_interactive_at_2x_overload() {
        // 2× over the ~12 req/s Blink capacity for this mix: unlimited
        // admission lets every queue grow and interactive attainment
        // collapse; the limiter + shed hold admitted load near capacity
        // and push the loss onto the batch class.
        let unlimited = simulate(&overloaded_cfg(24.0));
        let mut cfg = overloaded_cfg(24.0);
        cfg.rate_limit = 12.0;
        cfg.shed_policy = ShedPolicyCfg::degrade_then_drop(16);
        let limited = simulate(&cfg);

        assert_eq!(unlimited.overload.rejected_rate, 0);
        assert!(limited.overload.admitted < limited.overload.offered);
        assert!(
            limited.overload.rejected_rate + limited.overload.shed_dropped > 0,
            "gate must refuse work at 2x overload"
        );
        assert!(limited.overload.shed_degraded + limited.overload.shed_dropped > 0);

        let ua = unlimited.class(4).expect("interactive class").slo_attainment;
        let la = limited.class(4).expect("interactive class").slo_attainment;
        assert!(ua.is_finite() && la.is_finite());
        assert!(
            la > ua,
            "limited interactive attainment {la} must beat unlimited {ua}"
        );
    }

    #[test]
    fn tenant_buckets_cap_the_hot_tenant() {
        // One tenant sends half the trace. Generous buckets admit it
        // all; tight buckets clamp its admitted share toward its fair
        // quota without touching the cold tenants' admissions.
        let mut generous = overloaded_cfg(16.0);
        generous.tenant_buckets = Some(TenantBucketCfg {
            capacity: 1e9,
            refill_per_s: 1e9,
            tenants: 8,
            hot_share: 0.5,
        });
        let g = simulate(&generous);

        let mut tight = overloaded_cfg(16.0);
        tight.tenant_buckets =
            Some(TenantBucketCfg { capacity: 8.0, refill_per_s: 2.0, tenants: 8, hot_share: 0.5 });
        let t = simulate(&tight);

        assert_eq!(g.overload.rejected_bucket, 0);
        assert!(t.overload.rejected_bucket > 0, "tight buckets must reject the flooder");
        let gs = g.overload.max_tenant_share();
        let ts = t.overload.max_tenant_share();
        assert!(gs > 0.4, "hot tenant should dominate unthrottled: {gs}");
        assert!(ts < gs, "buckets must shrink the hot tenant's share: {ts} vs {gs}");
    }

    /// The tentpole's acceptance shape: on the heavy-tailed long-prompt
    /// mix, bounding per-iteration prefill strictly lowers the P99 TPOT
    /// of concurrent decodes versus whole-prompt prefill of the *same
    /// trace* (same seed ⇒ identical arrivals and lengths; only the
    /// budget differs). 256 tokens sits near the 8B model's hide point
    /// (`decode_step_with_chunk_s`), where chunks ride the decode
    /// weight sweep almost free.
    #[test]
    fn chunked_prefill_cuts_p99_tpot_on_long_prompt_mix() {
        let mix = crate::workload::LongPromptMix::document_chat();
        let mut cfg = SimConfig::new(System::Blink, LLAMA3_8B, 10.0, false);
        cfg.window_s = 30.0;
        cfg.long_prompts = Some(mix);
        let whole = simulate(&cfg);
        cfg.prefill_chunk_tokens = 256;
        let chunked = simulate(&cfg);
        assert!(whole.completed > 100 && chunked.completed > 100, "both runs must serve");
        assert_eq!(whole.chunked.chunk_launches, 0, "budget 0 never chunks");
        assert!(chunked.chunked.chunked_prefills > 0, "document prompts must chunk");
        assert!(
            chunked.chunked.chunk_launches >= 2 * chunked.chunked.chunked_prefills,
            "a chunked prompt launches ≥ 2 chunks"
        );
        assert!(
            chunked.tpot.p99 < whole.tpot.p99,
            "chunked P99 TPOT {:.1} ms must beat whole-prompt {:.1} ms",
            chunked.tpot.p99,
            whole.tpot.p99
        );
        // Chunking trades document TTFT for decode tails; it must not
        // cost throughput (the total work is conserved up to per-chunk
        // launch overheads, most of which hide under the sweep).
        assert!(
            chunked.completed as f64 >= 0.9 * whole.completed as f64,
            "chunked {} vs whole {} completions",
            chunked.completed,
            whole.completed
        );
    }

    /// Chunk-count contract shared with the live scheduler: a request
    /// whose uncached suffix spans `s` tokens under budget `c` launches
    /// exactly ⌈s/c⌉ chunks — the quantity the live modeled-executor
    /// e2e test pins against the same formula.
    #[test]
    fn chunk_counts_match_ceil_formula() {
        let mut cfg = SimConfig::new(System::Blink, LLAMA3_8B, 2.0, false);
        cfg.window_s = 20.0;
        cfg.lengths = LengthModel::Fixed { input: 5000, output: 8 };
        cfg.prefill_chunk_tokens = 2048;
        let wm = simulate(&cfg);
        assert!(wm.chunked.chunked_prefills > 0);
        let per_request = 5000usize.div_ceil(2048) as u64; // = 3
        assert_eq!(
            wm.chunked.chunk_launches,
            per_request * wm.chunked.chunked_prefills,
            "every 5000-token prompt takes exactly {per_request} chunks"
        );
    }

    /// The speculative path (DESIGN.md §11): on a saturated fixed-length
    /// workload, k = 4 at 0.9 acceptance lifts decode throughput ≥ 1.5×
    /// over plain decode of the *same trace* (same seed ⇒ identical
    /// arrivals; only the launch shape differs); zero acceptance pays
    /// the verify premium for ~plain throughput (the knob's floor); and
    /// the seeded acceptance stream reproduces exactly.
    #[test]
    fn speculative_decode_lifts_saturated_throughput() {
        let mut cfg = SimConfig::new(System::Blink, LLAMA3_8B, 100.0, false);
        cfg.window_s = 10.0;
        cfg.max_num_seqs = 16;
        cfg.lengths = LengthModel::Fixed { input: 64, output: 64 };
        let plain = simulate(&cfg);
        assert!(plain.completed > 100, "baseline must serve: {}", plain.completed);
        cfg.spec_k = 4;
        cfg.spec_accept = 0.9;
        let spec = simulate(&cfg);
        assert!(
            spec.decode_tok_s > 1.5 * plain.decode_tok_s,
            "k=4 @ 0.9 acceptance must lift throughput ≥1.5×: {} vs {}",
            spec.decode_tok_s,
            plain.decode_tok_s
        );
        assert!(
            spec.tpot.mean < 0.6 * plain.tpot.mean,
            "per-token latency must drop with the shared weight sweep: {} vs {}",
            spec.tpot.mean,
            plain.tpot.mean
        );
        // Every draft rejected: one token per launch at verify cost —
        // bounded below plain-decode throughput, never above it.
        cfg.spec_accept = 0.0;
        let reject = simulate(&cfg);
        assert!(
            reject.decode_tok_s < 1.05 * plain.decode_tok_s,
            "zero acceptance cannot beat plain decode: {} vs {}",
            reject.decode_tok_s,
            plain.decode_tok_s
        );
        // Determinism: the seeded acceptance stream reproduces exactly.
        cfg.spec_accept = 0.9;
        let again = simulate(&cfg);
        assert_eq!(spec.decode_tok_s, again.decode_tok_s);
        assert_eq!(spec.tpot.p99, again.tpot.p99);
        assert_eq!(spec.completed, again.completed);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn non_finite_rate_is_rejected() {
        let cfg = SimConfig::new(System::Blink, LLAMA3_8B, f64::NAN, false);
        let _ = simulate(&cfg);
    }

    #[test]
    fn chunked_run_is_deterministic() {
        let mut cfg = SimConfig::new(System::Blink, LLAMA3_8B, 8.0, false);
        cfg.window_s = 15.0;
        cfg.long_prompts = Some(crate::workload::LongPromptMix::document_chat());
        cfg.prefill_chunk_tokens = 1024;
        let a = simulate(&cfg);
        let b = simulate(&cfg);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.tpot.p99, b.tpot.p99);
        assert_eq!(a.chunked.chunk_launches, b.chunked.chunk_launches);
    }
}
