//! Discrete-event serving simulator: the paper's evaluation harness for
//! the four (unrunnable-here) testbed models. One `simulate` call = one
//! (system, model, load, interference) point of the sweep: Poisson
//! arrivals, FCFS continuous batching with whole-prompt prefill (chunked
//! prefill disabled, as in the paper's controlled setup), roofline GPU
//! step costs, system-specific host coupling, and the time-varying
//! interference process applied to *host-side* work only.
//!
//! The simulation is step-granular (one event per decode iteration /
//! prefill batch), which preserves exactly the quantities the paper
//! reports: TTFT (admission + queue + prefill), TPOT (steady decode
//! cadence), ITL (per-token gaps incl. prefill pauses — the §3.1 "jitter"
//! gap between ITL and TPOT), throughput and saturation behaviour.

use std::collections::HashMap;

use crate::gpu::policy::{Candidate, PolicyKind};
use crate::sim::costmodel::{CostModel, PaperModel};
use crate::sim::energy::PowerModel;
use crate::sim::interference::InterferenceProcess;
use crate::sim::systems::System;
use crate::util::rng::Rng;
use crate::workload::{
    ClassMix, LengthModel, MultiTurnMix, PrefixStats, RequestMetrics, TraceGen, TraceRequest,
    WindowMetrics,
};

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub system: System,
    pub model: PaperModel,
    pub interference: bool,
    pub rate: f64,
    pub window_s: f64,
    pub seed: u64,
    pub lengths: LengthModel,
    /// Upper bound on concurrent sequences (engine max_num_seqs).
    pub max_num_seqs: usize,
    /// Max prompts admitted per prefill batch.
    pub max_prefill_batch: usize,
    /// Admission policy over the schedulable queue — the *same*
    /// `AdmissionPolicy` implementations the live scheduler runs, so the
    /// DES exercises the real ranking code. FCFS reproduces the paper.
    pub policy: PolicyKind,
    /// Mixed-priority workload; `None` = the single-class `lengths` model.
    pub classes: Option<ClassMix>,
    /// Multi-turn conversation workload (`rate` = sessions/s); takes
    /// precedence over `classes`/`lengths` when set.
    pub multi_turn: Option<MultiTurnMix>,
    /// Prefix-cache capacity in tokens; 0 disables reuse (the paper's
    /// configuration). When enabled the DES mirrors the live KvManager's
    /// behavior at token granularity: each admission charges prefill only
    /// for the uncached suffix of its session history, and cached
    /// sessions are evicted LRU under capacity pressure.
    pub prefix_cache_tokens: usize,
}

impl SimConfig {
    pub fn new(system: System, model: PaperModel, rate: f64, interference: bool) -> SimConfig {
        SimConfig {
            system,
            model,
            interference,
            rate,
            window_s: 60.0,
            seed: 0xB11AC << 8 | (rate as u64),
            lengths: LengthModel::sharegpt(),
            max_num_seqs: 64,
            max_prefill_batch: 8,
            policy: PolicyKind::Fcfs,
            classes: None,
            multi_turn: None,
            prefix_cache_tokens: 0,
        }
    }
}

/// Token-granular stand-in for the live `kvcache` prefix index: cached
/// history per session + a shared system-prompt prefix, LRU-evicted
/// under a token budget. Block alignment mirrors the live manager's
/// full-block-only matching.
struct PrefixCacheSim {
    budget: usize,
    block: usize,
    /// Cross-session shared prefix (the common system prompt), cacheable
    /// once any session has warmed the index.
    shared_base: usize,
    warm: bool,
    total: usize,
    tick: u64,
    /// session → (cached tokens, last-use tick).
    sessions: HashMap<u64, (usize, u64)>,
    stats: PrefixStats,
}

impl PrefixCacheSim {
    fn new(budget: usize, shared_base: usize) -> PrefixCacheSim {
        PrefixCacheSim {
            budget,
            block: 16,
            shared_base,
            warm: false,
            total: 0,
            tick: 0,
            sessions: HashMap::new(),
            stats: PrefixStats::default(),
        }
    }

    /// Cached-prefix tokens available to this request (block-aligned,
    /// capped below the full prompt as the live manager does).
    fn lookup(&mut self, r: &TraceRequest) -> usize {
        self.stats.lookups += 1;
        self.stats.input_tokens += r.input_tokens as u64;
        self.tick += 1;
        let cached = match self.sessions.get_mut(&r.session_id) {
            Some(e) if r.session_id != 0 => {
                e.1 = self.tick;
                e.0
            }
            // Unseen session: only the cross-session shared prefix (the
            // common system prompt) can hit, and only once warmed.
            _ if self.warm => self.shared_base,
            _ => 0,
        };
        let hit = cached.min(r.history_tokens).min(r.input_tokens.saturating_sub(1))
            / self.block
            * self.block;
        if hit > 0 {
            self.stats.hits += 1;
            self.stats.hit_tokens += hit as u64;
        }
        hit
    }

    /// Record a session's cached tokens (monotone per session), evicting
    /// least-recently-used sessions over budget. `tokens` is aligned
    /// *down* to a full block first, mirroring the live manager: only
    /// full prompt blocks are ever indexed — in particular a turn's
    /// generated reply is not matchable until the *next* turn's prompt
    /// (which contains it) commits.
    fn store(&mut self, session: u64, tokens: usize) {
        if session == 0 {
            return;
        }
        let tokens = tokens / self.block * self.block;
        self.warm = true;
        self.tick += 1;
        let e = self.sessions.entry(session).or_insert((0, self.tick));
        self.total += tokens.saturating_sub(e.0);
        e.0 = e.0.max(tokens);
        e.1 = self.tick;
        while self.total > self.budget && self.sessions.len() > 1 {
            let (&victim, &(toks, _)) = self
                .sessions
                .iter()
                .min_by_key(|(_, (_, tick))| *tick)
                .expect("non-empty");
            if victim == session {
                break; // never evict the entry just refreshed
            }
            self.sessions.remove(&victim);
            self.total -= toks;
            self.stats.evicted_tokens += toks as u64;
        }
    }
}

struct Run {
    req: TraceRequest,
    produced: usize,
    ctx: usize,
    first_token_s: f64,
    last_token_s: f64,
    itl_s: Vec<f64>,
}

pub fn simulate(cfg: &SimConfig) -> WindowMetrics {
    let sens =
        if cfg.interference { cfg.system.interference_sensitivity() } else { 1.0 };
    simulate_with_sensitivity(cfg, sens)
}

/// Like [`simulate`] but with an explicit mean inflation multiplier for
/// host-side work — used by the §3 ablations (partial interferers, core
/// pinning, CAT) where the effective pressure differs from the full
/// colocation scenario.
pub fn simulate_with_sensitivity(cfg: &SimConfig, sensitivity: f64) -> WindowMetrics {
    // Interference runs use an independent seed even for immune systems:
    // the paper reports Blink's interference numbers as "within
    // experimental variance" of isolation, i.e. a different run, not a
    // bit-identical replay.
    let iseed = if cfg.interference { cfg.seed.rotate_left(17) ^ 0xC010C } else { cfg.seed };
    let mut rng = Rng::new(iseed ^ sys_tag(cfg.system));
    let cm = CostModel::new(cfg.model);
    let trace = if let Some(mt) = &cfg.multi_turn {
        mt.generate(&mut rng.fork(1), cfg.rate, cfg.window_s, 8192, 4096)
    } else {
        match &cfg.classes {
            Some(mix) => mix.generate(&mut rng.fork(1), cfg.rate, cfg.window_s, 8192, 4096),
            None => TraceGen::new(cfg.lengths, 8192, 4096)
                .generate(&mut rng.fork(1), cfg.rate, cfg.window_s),
        }
    };
    let policy = cfg.policy.build();
    let mut prefix: Option<PrefixCacheSim> = if cfg.prefix_cache_tokens > 0 {
        let shared = cfg.multi_turn.as_ref().map_or(0, |m| m.system_prompt_tokens);
        Some(PrefixCacheSim::new(cfg.prefix_cache_tokens, shared))
    } else {
        None
    };

    let interference = if sensitivity > 1.0 {
        InterferenceProcess::new(sensitivity, &mut rng)
    } else {
        InterferenceProcess::none()
    };

    // Requests become schedulable after the system's admission path
    // (HTTP + tokenize + enqueue), which inflates under interference for
    // host-coupled systems.
    let mut ready: Vec<(f64, TraceRequest)> = trace
        .iter()
        .map(|r| {
            let adm = cfg.system.admission_s() * interference.sample(r.arrival_s, &mut rng);
            (r.arrival_s + adm, *r)
        })
        .collect();
    ready.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mean_footprint = mean_tokens(&trace).max(64.0);
    let max_batch = cm.max_batch(mean_footprint).min(cfg.max_num_seqs);

    let mut t = 0.0f64;
    let mut next_ready = 0usize;
    // Schedulable queue: (ready_s, request, submission ticket). The
    // admission policy re-ranks it at every admission opportunity, so
    // aging and deadline slack are evaluated against the current clock.
    let mut pending: Vec<(f64, TraceRequest, u64)> = vec![];
    let mut ticket_ctr = 0u64;
    let mut running: Vec<Run> = vec![];
    let mut done: Vec<RequestMetrics> = vec![];
    let mut gpu_busy_s = 0.0f64;
    let drain_deadline = cfg.window_s * 4.0 + 120.0;

    while (next_ready < ready.len() || !pending.is_empty() || !running.is_empty())
        && t < drain_deadline
    {
        // Requests whose admission path finished become schedulable.
        while next_ready < ready.len() && ready[next_ready].0 <= t {
            pending.push((ready[next_ready].0, ready[next_ready].1, ticket_ctr));
            ticket_ctr += 1;
            next_ready += 1;
        }

        // Admit in policy order while capacity allows; prefill in batches.
        let free = max_batch.saturating_sub(running.len()).min(cfg.max_prefill_batch);
        let mut admitted: Vec<TraceRequest> = vec![];
        if free > 0 && !pending.is_empty() {
            let now_us = (t * 1e6) as u64;
            let mut cands: Vec<Candidate> = pending
                .iter()
                .enumerate()
                .map(|(i, (ready_s, r, ticket))| Candidate {
                    slot: i,
                    ticket: *ticket,
                    priority: r.priority,
                    prompt_len: r.input_tokens as u32,
                    submit_time_us: (ready_s * 1e6) as u64,
                    ttft_deadline_us: if r.ttft_budget_s > 0.0 {
                        ((ready_s + r.ttft_budget_s) * 1e6) as u64
                    } else {
                        0
                    },
                })
                .collect();
            policy.order(&mut cands, now_us);
            let chosen: Vec<usize> = cands.iter().take(free).map(|c| c.slot).collect();
            for &i in &chosen {
                admitted.push(pending[i].1);
            }
            let mut remove_idx = chosen;
            remove_idx.sort_unstable();
            for i in remove_idx.into_iter().rev() {
                pending.remove(i);
            }
        }
        if !admitted.is_empty() {
            // Pause decode, run one prefill batch (paper policy), resume.
            // With prefix reuse, each request charges only its uncached
            // suffix — the cached history's K/V is already resident.
            let prefill_tokens: usize = admitted
                .iter()
                .map(|r| {
                    let hit = prefix.as_mut().map_or(0, |p| p.lookup(r));
                    r.input_tokens - hit
                })
                .sum();
            // The admitted prompts themselves become cached history
            // (full prompt blocks only — the live path's index_prompt
            // commits exactly this after the prefill; replies become
            // matchable only once a later prompt containing them
            // commits).
            if let Some(p) = prefix.as_mut() {
                for r in &admitted {
                    p.store(r.session_id, r.input_tokens);
                }
            }
            let host = cfg.system.step_overhead_moe_s(running.len() + admitted.len(), cfg.model.moe)
                * interference.sample(t, &mut rng);
            let dur = cm.prefill_s(prefill_tokens) + host;
            gpu_busy_s += cm.prefill_s(prefill_tokens);
            t += dur;
            for r in admitted {
                running.push(Run {
                    req: r,
                    produced: 1, // prefill emits the first token
                    ctx: r.input_tokens + 1,
                    first_token_s: t,
                    last_token_s: t,
                    itl_s: vec![],
                });
            }
            // Single-token requests finish at prefill.
            retire(&mut running, &mut done);
            continue;
        }

        if running.is_empty() {
            // Idle: jump to the next ready request.
            if next_ready < ready.len() {
                t = t.max(ready[next_ready].0);
            }
            continue;
        }

        // One decode iteration for the whole batch.
        let b = running.len();
        let mean_ctx = running.iter().map(|r| r.ctx as f64).sum::<f64>() / b as f64;
        let gpu = cm.decode_step_s(b, mean_ctx);
        let host =
            cfg.system.step_overhead_moe_s(b, cfg.model.moe) * interference.sample(t, &mut rng);
        t += gpu + host;
        gpu_busy_s += gpu;
        for r in running.iter_mut() {
            r.produced += 1;
            r.ctx += 1;
            r.itl_s.push(t - r.last_token_s);
            r.last_token_s = t;
        }
        retire(&mut running, &mut done);
    }

    let mut wm = WindowMetrics::from_requests(cfg.rate, cfg.window_s, &done);
    if let Some(p) = &prefix {
        wm.prefix = p.stats;
    }
    // Energy: GPU utilization over the *active* span.
    let active = t.min(cfg.window_s).max(1e-9);
    let gpu_util = (gpu_busy_s.min(active) / active).clamp(0.0, 1.0);
    let tok_s = wm.decode_tok_s + wm.prefill_tok_s * 0.0; // paper: per generated token
    wm.energy_mj_per_tok = PowerModel::default().mj_per_token(
        cfg.system,
        gpu_util,
        cfg.interference,
        tok_s.max(1e-9),
    );
    wm
}

fn retire(running: &mut Vec<Run>, done: &mut Vec<RequestMetrics>) {
    let mut i = 0;
    while i < running.len() {
        if running[i].produced >= running[i].req.output_tokens {
            let r = running.swap_remove(i);
            done.push(RequestMetrics {
                id: r.req.id,
                arrival_s: r.req.arrival_s,
                first_token_s: r.first_token_s,
                finish_s: r.last_token_s,
                input_tokens: r.req.input_tokens,
                output_tokens: r.req.output_tokens,
                itl_s: r.itl_s,
                priority: r.req.priority,
                ttft_budget_s: r.req.ttft_budget_s,
            });
        } else {
            i += 1;
        }
    }
}

fn mean_tokens(trace: &[TraceRequest]) -> f64 {
    if trace.is_empty() {
        return 0.0;
    }
    trace.iter().map(|r| (r.input_tokens + r.output_tokens) as f64).sum::<f64>()
        / trace.len() as f64
}

fn sys_tag(s: System) -> u64 {
    match s {
        System::Blink => 0x11,
        System::TrtLlm => 0x22,
        System::Vllm => 0x33,
        System::Sglang => 0x44,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::costmodel::{LLAMA3_8B, QWEN3_30B_A3B};

    #[test]
    fn low_load_all_complete() {
        for sys in crate::sim::systems::ALL_SYSTEMS {
            let cfg = SimConfig::new(sys, LLAMA3_8B, 2.0, false);
            let wm = simulate(&cfg);
            assert!(wm.completed as f64 >= 0.8 * 2.0 * 50.0, "{sys:?}: {}", wm.completed);
            assert!(wm.ttft.p99 > 0.0 && wm.tpot.p99 > 0.0);
        }
    }

    #[test]
    fn blink_beats_baselines_pre_saturation() {
        let b = simulate(&SimConfig::new(System::Blink, LLAMA3_8B, 8.0, false));
        let v = simulate(&SimConfig::new(System::Vllm, LLAMA3_8B, 8.0, false));
        let s = simulate(&SimConfig::new(System::Sglang, LLAMA3_8B, 8.0, false));
        assert!(b.ttft.p99 < v.ttft.p99, "blink {} vs vllm {}", b.ttft.p99, v.ttft.p99);
        assert!(b.tpot.p99 < v.tpot.p99);
        assert!(v.ttft.p99 < s.ttft.p99, "vllm {} vs sglang {}", v.ttft.p99, s.ttft.p99);
    }

    #[test]
    fn interference_collapses_baselines_not_blink() {
        let iso = simulate(&SimConfig::new(System::Blink, LLAMA3_8B, 8.0, false));
        let int = simulate(&SimConfig::new(System::Blink, LLAMA3_8B, 8.0, true));
        let ratio = int.req_throughput / iso.req_throughput;
        assert!(ratio > 0.9, "blink retention {ratio}");

        let viso = simulate(&SimConfig::new(System::Vllm, LLAMA3_8B, 8.0, false));
        let vint = simulate(&SimConfig::new(System::Vllm, LLAMA3_8B, 8.0, true));
        let vratio = vint.req_throughput / viso.req_throughput;
        assert!(vratio < 0.7, "vllm retention {vratio}");
        assert!(vint.tpot.p99 > 2.0 * viso.tpot.p99, "vllm TPOT must inflate");
    }

    #[test]
    fn moe_amplifies_blink_advantage() {
        // §6.2: host expert-routing tax makes the MoE *throughput* gap at
        // saturating load larger than the dense gap (paper: 37 % vs 9 %).
        let bm = simulate(&SimConfig::new(System::Blink, QWEN3_30B_A3B, 8.0, false));
        let vm = simulate(&SimConfig::new(System::Vllm, QWEN3_30B_A3B, 8.0, false));
        let bd = simulate(&SimConfig::new(System::Blink, LLAMA3_8B, 16.0, false));
        let vd = simulate(&SimConfig::new(System::Vllm, LLAMA3_8B, 16.0, false));
        let moe_gap = bm.req_throughput / vm.req_throughput;
        let dense_gap = bd.req_throughput / vd.req_throughput;
        assert!(moe_gap > dense_gap, "moe {moe_gap} dense {dense_gap}");
        assert!(moe_gap > 1.2, "moe gap should be large: {moe_gap}");
    }

    #[test]
    fn determinism() {
        let cfg = SimConfig::new(System::Vllm, LLAMA3_8B, 6.0, true);
        let a = simulate(&cfg);
        let b = simulate(&cfg);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.ttft.p99, b.ttft.p99);
    }
}
