//! One-sided RDMA simulation (paper §4.4 "RDMA datapath").
//!
//! Blink's frontend never shares an address space with the backend: it
//! reads and writes the GPU-resident ring buffer exclusively through
//! one-sided RDMA verbs (DOCA on BlueField-3, 200 Gbps link). We model
//! that boundary faithfully at the *verb* level:
//!
//! * the frontend posts work requests ([`RdmaOp`]s) on a [`QueuePair`]
//!   (doorbell),
//! * a dedicated engine thread — the "NIC" — executes each op against the
//!   target memory after a modeled wire latency + serialization delay,
//! * completions are delivered through a completion queue the caller
//!   polls ([`QueuePair::poll_cq`]), with payloads for READs,
//! * CAS ops map to RDMA atomics (a real verbs feature), which is how the
//!   frontend claims EMPTY slots without owning backend memory.
//!
//! The frontend module (`crate::frontend`) holds only a `QueuePair` — the
//! type system enforces that no frontend code touches the `RingBuffer`
//! directly, mirroring the paper's hardware isolation boundary.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::ringbuf::{RingBuffer, SlotState, SubmitMeta};

/// Link + verb cost model. Defaults follow the paper's testbed: 200 Gbps
/// link, ~2 µs one-way op latency. `zero_cost()` disables the delays for
/// unit tests.
#[derive(Debug, Clone, Copy)]
pub struct RdmaConfig {
    pub base_latency_us: f64,
    /// Link bandwidth in bytes/µs (200 Gbps = 25 GB/s = 25_000 B/µs).
    pub bytes_per_us: f64,
    /// Per-op NIC processing overhead, µs.
    pub op_overhead_us: f64,
}

impl Default for RdmaConfig {
    fn default() -> Self {
        RdmaConfig { base_latency_us: 2.0, bytes_per_us: 25_000.0, op_overhead_us: 0.3 }
    }
}

impl RdmaConfig {
    pub fn zero_cost() -> Self {
        RdmaConfig { base_latency_us: 0.0, bytes_per_us: f64::INFINITY, op_overhead_us: 0.0 }
    }

    fn delay_for(&self, bytes: usize) -> Duration {
        let us = self.base_latency_us + self.op_overhead_us + bytes as f64 / self.bytes_per_us;
        Duration::from_nanos((us * 1000.0) as u64)
    }
}

/// One-sided ops. Sizes are what a DOCA implementation would move.
#[derive(Debug, Clone)]
pub enum RdmaOp {
    /// RDMA atomic CAS: claim an EMPTY slot for writing.
    ClaimSlot { slot: usize },
    /// RDMA WRITE of prompt tokens into the slot's input-arena region.
    WritePrompt { slot: usize, tokens: Vec<u32> },
    /// RDMA WRITE of slot metadata + state flip to PREFILL_PENDING.
    /// `priority` / `ttft_budget_us` are the request-class fields the
    /// scheduler's admission policy ranks by (0/0 = batch class, FCFS
    /// behavior); `session_id` tags multi-turn conversations for the
    /// prefix-reuse path. All of it rides in the same metadata write, so
    /// neither the class nor the session costs an extra verb.
    Submit {
        slot: usize,
        request_id: u64,
        prompt_len: u32,
        max_new: u32,
        seed: u32,
        priority: u32,
        ttft_budget_us: u64,
        session_id: u64,
    },
    /// Bulk RDMA READ of (state, generated) for a contiguous slot range —
    /// the token reader's per-cycle 64 KB metadata refresh.
    ReadMeta { first_slot: usize, count: usize },
    /// RDMA READ of generated tokens `[from, to)` from the output arena.
    ReadTokens { slot: usize, from: u32, to: u32 },
    /// RDMA atomic CAS: recycle a DECODE_COMPLETED slot.
    ReleaseSlot { slot: usize },
}

impl RdmaOp {
    /// Wire bytes for the bandwidth model.
    fn bytes(&self) -> usize {
        match self {
            RdmaOp::ClaimSlot { .. } | RdmaOp::ReleaseSlot { .. } => 8,
            RdmaOp::WritePrompt { tokens, .. } => tokens.len() * 4,
            RdmaOp::Submit { .. } => 56,
            RdmaOp::ReadMeta { count, .. } => count * 16,
            RdmaOp::ReadTokens { from, to, .. } => ((to - from) as usize) * 4,
        }
    }
}

/// Per-slot metadata snapshot returned by `ReadMeta`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotMeta {
    pub slot: usize,
    pub state: SlotState,
    pub generated: u32,
    pub request_id: u64,
}

#[derive(Debug, Clone)]
pub enum Payload {
    None,
    /// For ClaimSlot / ReleaseSlot: CAS success.
    Cas(bool),
    Meta(Vec<SlotMeta>),
    Tokens(Vec<u32>),
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub wr_id: u64,
    pub payload: Payload,
}

struct Pending {
    deliver_at: Instant,
    seq: u64,
    wr_id: u64,
    op: RdmaOp,
    cq: Sender<Completion>,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.deliver_at == other.deliver_at && self.seq == other.seq
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, tie-break by
        // submission order so same-deadline ops keep FIFO semantics.
        other.deliver_at.cmp(&self.deliver_at).then(other.seq.cmp(&self.seq))
    }
}

/// The shared "NIC": executes ops against the ring buffer.
pub struct RdmaEngine {
    tx: Sender<Pending>,
    // lint: atomic(seq) counter # FIFO tie-break stamp; ordering between
    // ops comes from the channel send, not from this counter.
    seq: AtomicU64,
    config: RdmaConfig,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    // lint: atomic(ops_executed) counter
    ops_executed: Arc<AtomicU64>,
    // lint: atomic(bytes_moved) counter
    bytes_moved: Arc<AtomicU64>,
}

impl RdmaEngine {
    /// Spawn the engine thread bound to the target memory.
    pub fn spawn(ring: Arc<RingBuffer>, config: RdmaConfig) -> Arc<RdmaEngine> {
        let (tx, rx) = channel::<Pending>();
        let ops_executed = Arc::new(AtomicU64::new(0));
        let bytes_moved = Arc::new(AtomicU64::new(0));
        let (ops2, bytes2) = (ops_executed.clone(), bytes_moved.clone());
        let handle = std::thread::Builder::new()
            .name("rdma-nic".into())
            .spawn(move || Self::run(ring, rx, ops2, bytes2))
            .expect("spawn rdma engine");
        Arc::new(RdmaEngine {
            tx,
            seq: AtomicU64::new(0),
            config,
            handle: Mutex::new(Some(handle)),
            ops_executed,
            bytes_moved,
        })
    }

    fn run(
        ring: Arc<RingBuffer>,
        rx: Receiver<Pending>,
        ops: Arc<AtomicU64>,
        bytes: Arc<AtomicU64>,
    ) {
        let mut heap: BinaryHeap<Pending> = BinaryHeap::new();
        loop {
            // Wait for work, bounded by the next deliverable deadline.
            let next_deadline = heap.peek().map(|p| p.deliver_at);
            let recv = match next_deadline {
                None => rx.recv().map_err(|_| ()),
                Some(d) => {
                    let now = Instant::now();
                    if d <= now {
                        Err(()) // deliver first
                    } else {
                        match rx.recv_timeout(d - now) {
                            Ok(p) => Ok(p),
                            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Err(()),
                            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                                if heap.is_empty() {
                                    return;
                                }
                                Err(())
                            }
                        }
                    }
                }
            };
            match recv {
                Ok(p) => {
                    heap.push(p);
                    // Drain whatever else is queued without blocking.
                    while let Ok(p) = rx.try_recv() {
                        heap.push(p);
                    }
                }
                Err(()) => {
                    if heap.is_empty() {
                        // Channel closed and nothing pending.
                        return;
                    }
                }
            }
            let now = Instant::now();
            while heap.peek().is_some_and(|p| p.deliver_at <= now) {
                let p = heap.pop().unwrap();
                bytes.fetch_add(p.op.bytes() as u64, Ordering::Relaxed);
                ops.fetch_add(1, Ordering::Relaxed);
                let payload = Self::execute(&ring, &p.op);
                let _ = p.cq.send(Completion { wr_id: p.wr_id, payload });
            }
        }
    }

    fn execute(ring: &RingBuffer, op: &RdmaOp) -> Payload {
        match op {
            RdmaOp::ClaimSlot { slot } => Payload::Cas(ring.claim_for_write(*slot)),
            RdmaOp::WritePrompt { slot, tokens } => {
                ring.write_prompt(*slot, tokens);
                Payload::None
            }
            RdmaOp::Submit {
                slot,
                request_id,
                prompt_len,
                max_new,
                seed,
                priority,
                ttft_budget_us,
                session_id,
            } => {
                ring.submit_with_meta(
                    *slot,
                    &SubmitMeta {
                        request_id: *request_id,
                        prompt_len: *prompt_len,
                        max_new: *max_new,
                        seed: *seed,
                        priority: *priority,
                        ttft_budget_us: *ttft_budget_us,
                        session_id: *session_id,
                    },
                );
                Payload::None
            }
            RdmaOp::ReadMeta { first_slot, count } => {
                let n = ring.num_slots();
                let metas = (*first_slot..(*first_slot + *count).min(n))
                    .map(|i| {
                        let s = ring.slot(i);
                        SlotMeta {
                            slot: i,
                            state: s.state(),
                            generated: s.generated.load(Ordering::Acquire),
                            request_id: s.request_id.load(Ordering::Relaxed),
                        }
                    })
                    .collect();
                Payload::Meta(metas)
            }
            RdmaOp::ReadTokens { slot, from, to } => {
                Payload::Tokens(ring.read_tokens(*slot, *from, *to))
            }
            RdmaOp::ReleaseSlot { slot } => Payload::Cas(ring.release(*slot)),
        }
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.ops_executed.load(Ordering::Relaxed), self.bytes_moved.load(Ordering::Relaxed))
    }
}

impl Drop for RdmaEngine {
    fn drop(&mut self) {
        // Senders (QueuePairs) may still exist; the engine thread exits
        // when all QPs drop. Detach rather than join to avoid deadlock.
        let _ = self.handle.lock().map(|mut h| h.take());
    }
}

/// A queue pair + its completion queue. Cheap to create; each frontend
/// subsystem (submitter, token reader, slot tracker) owns its own QP, as
/// the paper separates submission from retrieval traffic.
pub struct QueuePair {
    engine: Arc<RdmaEngine>,
    cq_tx: Sender<Completion>,
    cq_rx: Receiver<Completion>,
    next_wr: u64,
}

impl QueuePair {
    pub fn new(engine: Arc<RdmaEngine>) -> QueuePair {
        let (cq_tx, cq_rx) = channel();
        QueuePair { engine, cq_tx, cq_rx, next_wr: 1 }
    }

    /// Post a work request (doorbell). Returns the wr_id.
    pub fn post(&mut self, op: RdmaOp) -> u64 {
        let wr_id = self.next_wr;
        self.next_wr += 1;
        let delay = self.engine.config.delay_for(op.bytes());
        let seq = self.engine.seq.fetch_add(1, Ordering::Relaxed);
        let p = Pending {
            deliver_at: Instant::now() + delay,
            seq,
            wr_id,
            op,
            cq: self.cq_tx.clone(),
        };
        self.engine.tx.send(p).expect("rdma engine alive");
        wr_id
    }

    /// Non-blocking poll of up to `max` completions.
    pub fn poll_cq(&mut self, max: usize) -> Vec<Completion> {
        let mut out = Vec::new();
        while out.len() < max {
            match self.cq_rx.try_recv() {
                Ok(c) => out.push(c),
                Err(_) => break,
            }
        }
        out
    }

    /// Blocking wait for a specific wr_id (simple clients / tests).
    pub fn wait(&mut self, wr_id: u64) -> Completion {
        loop {
            let c = self.cq_rx.recv().expect("rdma engine alive");
            if c.wr_id == wr_id {
                return c;
            }
            // Out-of-order completion for someone else on this QP: stash
            // is unnecessary since wr_ids are QP-local and callers either
            // poll or wait — but preserve FIFO by re-queueing.
            let _ = self.cq_tx.send(c);
        }
    }

    /// Post + wait helper.
    pub fn exec(&mut self, op: RdmaOp) -> Payload {
        let id = self.post(op);
        self.wait(id).payload
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ringbuf::RingConfig;

    fn setup() -> (Arc<RingBuffer>, Arc<RdmaEngine>) {
        let ring = Arc::new(RingBuffer::new(RingConfig {
            num_slots: 16,
            max_prompt: 32,
            max_output: 32,
        }));
        let engine = RdmaEngine::spawn(ring.clone(), RdmaConfig::zero_cost());
        (ring, engine)
    }

    #[test]
    fn claim_write_submit_roundtrip() {
        let (ring, engine) = setup();
        let mut qp = QueuePair::new(engine);
        assert!(matches!(qp.exec(RdmaOp::ClaimSlot { slot: 2 }), Payload::Cas(true)));
        assert!(matches!(qp.exec(RdmaOp::ClaimSlot { slot: 2 }), Payload::Cas(false)));
        qp.exec(RdmaOp::WritePrompt { slot: 2, tokens: vec![5, 6, 7] });
        qp.exec(RdmaOp::Submit {
            slot: 2,
            request_id: 9,
            prompt_len: 3,
            max_new: 4,
            seed: 1,
            priority: 3,
            ttft_budget_us: 100_000,
            session_id: 0,
        });
        assert_eq!(ring.slot(2).state(), SlotState::PrefillPending);
        assert_eq!(ring.read_prompt(2), vec![5, 6, 7]);
        // The request class travels in the same metadata write.
        assert_eq!(ring.slot(2).priority.load(Ordering::Relaxed), 3);
        let s = ring.slot(2);
        assert_eq!(
            s.ttft_deadline_us.load(Ordering::Relaxed),
            s.submit_time_us.load(Ordering::Relaxed) + 100_000
        );
    }

    #[test]
    fn read_meta_snapshot() {
        let (ring, engine) = setup();
        let mut qp = QueuePair::new(engine);
        qp.exec(RdmaOp::ClaimSlot { slot: 0 });
        qp.exec(RdmaOp::WritePrompt { slot: 0, tokens: vec![1] });
        qp.exec(RdmaOp::Submit {
            slot: 0,
            request_id: 4,
            prompt_len: 1,
            max_new: 2,
            seed: 0,
            priority: 0,
            ttft_budget_us: 0,
            session_id: 0,
        });
        ring.claim_pending(0);
        ring.slot(0).set_state(SlotState::DecodeProcessing);
        ring.publish_token(0, 42);
        match qp.exec(RdmaOp::ReadMeta { first_slot: 0, count: 16 }) {
            Payload::Meta(m) => {
                assert_eq!(m.len(), 16);
                assert_eq!(m[0].state, SlotState::DecodeProcessing);
                assert_eq!(m[0].generated, 1);
                assert_eq!(m[0].request_id, 4);
            }
            other => panic!("unexpected payload {other:?}"),
        }
    }

    #[test]
    fn read_tokens_after_publish() {
        let (ring, engine) = setup();
        let mut qp = QueuePair::new(engine);
        qp.exec(RdmaOp::ClaimSlot { slot: 1 });
        qp.exec(RdmaOp::WritePrompt { slot: 1, tokens: vec![1] });
        qp.exec(RdmaOp::Submit {
            slot: 1,
            request_id: 1,
            prompt_len: 1,
            max_new: 8,
            seed: 0,
            priority: 0,
            ttft_budget_us: 0,
            session_id: 0,
        });
        ring.claim_pending(1);
        ring.slot(1).set_state(SlotState::DecodeProcessing);
        for t in 0..5 {
            ring.publish_token(1, 100 + t);
        }
        match qp.exec(RdmaOp::ReadTokens { slot: 1, from: 1, to: 5 }) {
            Payload::Tokens(t) => assert_eq!(t, vec![101, 102, 103, 104]),
            other => panic!("unexpected payload {other:?}"),
        }
    }

    #[test]
    fn latency_model_orders_completions() {
        // With a real (non-zero) cost model, a big write completes after a
        // small one posted at the same time on the same QP.
        let ring = Arc::new(RingBuffer::new(RingConfig {
            num_slots: 4,
            max_prompt: 4096,
            max_output: 8,
        }));
        let engine = RdmaEngine::spawn(
            ring.clone(),
            RdmaConfig { base_latency_us: 10.0, bytes_per_us: 100.0, op_overhead_us: 0.0 },
        );
        let mut qp = QueuePair::new(engine);
        qp.exec(RdmaOp::ClaimSlot { slot: 0 });
        qp.exec(RdmaOp::ClaimSlot { slot: 1 });
        let big = qp.post(RdmaOp::WritePrompt { slot: 0, tokens: vec![0; 4000] }); // 16 kB
        let small = qp.post(RdmaOp::WritePrompt { slot: 1, tokens: vec![1, 2] });
        let first = loop {
            let cs = qp.poll_cq(1);
            if let Some(c) = cs.into_iter().next() {
                break c.wr_id;
            }
        };
        assert_eq!(first, small, "small op should complete before big one");
        let _ = qp.wait(big);
    }

    #[test]
    fn release_via_rdma_atomic() {
        let (ring, engine) = setup();
        let mut qp = QueuePair::new(engine);
        qp.exec(RdmaOp::ClaimSlot { slot: 3 });
        qp.exec(RdmaOp::WritePrompt { slot: 3, tokens: vec![1] });
        qp.exec(RdmaOp::Submit {
            slot: 3,
            request_id: 2,
            prompt_len: 1,
            max_new: 1,
            seed: 0,
            priority: 0,
            ttft_budget_us: 0,
            session_id: 0,
        });
        ring.claim_pending(3);
        ring.slot(3).set_state(SlotState::DecodeProcessing);
        ring.publish_token(3, 7);
        ring.complete(3);
        assert!(matches!(qp.exec(RdmaOp::ReleaseSlot { slot: 3 }), Payload::Cas(true)));
        assert_eq!(ring.slot(3).state(), SlotState::Empty);
    }
}
