//! blink — CLI for the Blink reproduction.
//!
//! Subcommands:
//!
//! ```text
//! serve   [--model M] [--bind ADDR] [--cpu-resident] [--policy P]
//!         [--prefix-reuse | --no-prefix-reuse] [--prefill-chunk-tokens N]
//!         [--rate-limit N] [--spec-k K]
//!         start a live server (P: fcfs|priority|sjf|slo); prefix reuse
//!         defaults to auto (on when the artifacts ship offset graphs);
//!         chunk budget defaults to the largest offset-graph seq (0 =
//!         whole-prompt prefill, the paper's behavior); --spec-k K turns
//!         on fixed-k speculative decoding when the artifacts ship
//!         decode_verify graphs at that k (0 = off, the default)
//! eval    <all|policies|prefix|prefix-live|chunked|interference|overload|spec|fig1|table1..table7|fig3..fig8|tableB1|tableB2|figC1|figD|figE1>
//!         [--out DIR] [--window S] [--threads N] [--smoke (interference/overload/spec: CI-sized live cells)]
//! info    print manifest + graph grid for a model, including verify
//!         k-grid coverage per decode batch size
//! ```

use blink::eval;
use blink::frontend::overload::OverloadConfig;
use blink::gpu::{Placement, PolicyKind, PrefixReuse};
use blink::http::HttpServer;
use blink::server::{BlinkServer, ServerConfig};
use blink::sim::costmodel::PAPER_MODELS;
use blink::util::cli::Args;

fn main() {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args),
        Some("eval") => eval_cmd(&args),
        Some("info") => info(&args),
        _ => {
            eprintln!(
                "usage: blink <serve|eval|info> [...]\n\
                 serve [--model blink-tiny] [--bind 127.0.0.1:8089] [--cpu-resident] \\\n\
                       [--policy fcfs|priority|sjf|slo] [--prefix-reuse|--no-prefix-reuse] \\\n\
                       [--prefill-chunk-tokens N (0 = whole-prompt prefill)] \\\n\
                       [--rate-limit N (req/s admission cap + shed; absent = open loop)] \\\n\
                       [--spec-k K (fixed-k speculative decode; 0 = off)]\n\
                 eval <all|policies|prefix|prefix-live|chunked|interference|overload|spec|fig1|fig3|fig4|fig5|fig6|fig7|fig8|table1..table7|tableB1|tableB2|figC1|figD|figE1> \\\n\
                      [--out results/] [--window 60] [--threads N] [--policy P (policies: single-policy run)] \\\n\
                      [--smoke (interference/overload/spec: CI-sized live cells)]\n\
                 info [--model blink-tiny]"
            );
            std::process::exit(2);
        }
    }
}

fn serve(args: &Args) {
    let model = args.get_or("model", "blink-tiny").to_string();
    let bind = args.get_or("bind", "127.0.0.1:8089").to_string();
    let placement = if args.has_flag("cpu-resident") {
        Placement::CpuResident { scratch_mb: 16, touches_per_step: 400_000 }
    } else {
        Placement::GpuResident
    };
    let policy = parse_policy_flag(args).unwrap_or(PolicyKind::Fcfs);
    // Default-on: prefix reuse engages automatically when the artifacts
    // provide offset prefill graphs (suffix-only prefill at the correct
    // positions — DESIGN.md §7); without them it gracefully stays on the
    // paper's cold path. `--no-prefix-reuse` forces it off,
    // `--prefix-reuse` keeps the index machinery on even without offset
    // graphs (hits are counted but demoted to full prefills).
    let prefix_reuse = if args.has_flag("no-prefix-reuse") {
        PrefixReuse::Off
    } else if args.has_flag("prefix-reuse") {
        PrefixReuse::On
    } else {
        PrefixReuse::Auto
    };
    // Chunked prefill (DESIGN.md §5): absent = the default budget (the
    // largest offset-graph seq in the artifacts); 0 = whole-prompt
    // prefill, the paper's behavior.
    let prefill_chunk_tokens = args.get("prefill-chunk-tokens").map(|raw| {
        raw.parse::<usize>().unwrap_or_else(|_| {
            eprintln!("--prefill-chunk-tokens must be a non-negative integer, got {raw}");
            std::process::exit(2);
        })
    });
    // Overload control (DESIGN.md §9): --rate-limit N caps admission at
    // N requests per 1 s sliding window and turns on the default
    // degrade-then-drop shed policy; absent = the paper's open loop.
    let overload = match args.get("rate-limit") {
        None => OverloadConfig::default(),
        Some(raw) => {
            let n = raw.parse::<u32>().ok().filter(|&n| n > 0).unwrap_or_else(|| {
                eprintln!("--rate-limit must be a positive integer (req/s), got {raw}");
                std::process::exit(2);
            });
            OverloadConfig { enabled: true, window_capacity: n, ..OverloadConfig::default() }
        }
    };
    // Speculative decoding (DESIGN.md §11): --spec-k K drafts K tokens
    // per lane per iteration and verifies them in one decode_verify
    // launch; engages only when the artifacts ship verify graphs at
    // exactly that k. 0 = the paper's one-token-per-launch decode.
    let spec_k = args.get_usize("spec-k", 0);
    eprintln!(
        "[serve] loading {model} (compiling AOT graphs, ~30s), policy={}, prefix_reuse={:?}, \
         prefill_chunk_tokens={}, spec_k={spec_k} ...",
        policy.name(),
        prefix_reuse,
        match prefill_chunk_tokens {
            Some(n) => n.to_string(),
            None => "auto".into(),
        },
    );
    let server = BlinkServer::start(ServerConfig {
        model,
        placement,
        policy,
        prefix_reuse,
        prefill_chunk_tokens,
        overload,
        spec_k,
        ..Default::default()
    })
    .expect("server start");
    let http = HttpServer::serve(&bind, server.frontend.clone(), server.scheduler.stats.clone())
        .expect("bind");
    eprintln!("[serve] listening on http://{}", http.addr);
    eprintln!(
        "[serve] try: curl -s http://{}/v1/completions -d '{{\"prompt\": \"the quick brown\", \"max_tokens\": 16}}'",
        http.addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn eval_cmd(args: &Args) {
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let out = args.get("out").map(std::path::PathBuf::from);
    let out_ref = out.as_deref();
    let window = args.get_f64("window", 60.0);
    let threads = args.get_usize(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8),
    );

    // Live experiments and the policy comparison don't need the sweep.
    match what {
        "fig3" => return eval::live::fig3(out_ref),
        "fig4" => return eval::live::fig4(out_ref),
        "table5" => return eval::table5(),
        "policies" => {
            return eval::policy_comparison(out_ref, window, threads, parse_policy_flag(args));
        }
        "prefix" => return eval::prefix_comparison(out_ref, window, threads),
        "prefix-live" => return eval::live::prefix_live(out_ref),
        "chunked" => return eval::chunked_comparison(out_ref, window, threads),
        "interference" => {
            return eval::interference::interference(out_ref, args.has_flag("smoke"));
        }
        "overload" => {
            return eval::overload::overload(out_ref, args.has_flag("smoke"));
        }
        "spec" => {
            return eval::spec::spec(out_ref, args.has_flag("smoke"));
        }
        _ => {}
    }

    let ctx = eval::EvalCtx::new(window, threads, out_ref);
    let all_models: Vec<&str> = PAPER_MODELS.iter().map(|m| m.name).collect();
    match what {
        "all" => {
            eval::fig1(&ctx);
            eval::table1(&ctx);
            eval::table2(&ctx);
            eval::table3(&ctx);
            eval::table4(&ctx);
            eval::table5();
            eval::table6(&ctx, false);
            eval::table6(&ctx, true);
            eval::latency_figure(&ctx, "Fig 5 TTFT", "ttft", "p999", &["qwen3-32b"]);
            eval::latency_figure(&ctx, "Fig 5 TPOT", "tpot", "p999", &["qwen3-32b"]);
            eval::latency_figure(&ctx, "Fig 6 TTFT", "ttft", "p99", &all_models);
            eval::latency_figure(&ctx, "Fig 6 TPOT", "tpot", "p99", &all_models);
            eval::fig7(&ctx);
            eval::fig8(&ctx);
            eval::table_b1(&ctx);
            eval::table_b2(&ctx);
            eval::fig_c1(&ctx);
            for (fig, pct) in
                [("Fig D.1", "p999"), ("Fig D.2", "p95"), ("Fig D.3", "p50"), ("Fig D.4", "mean")]
            {
                eval::latency_figure(&ctx, &format!("{fig} TTFT"), "ttft", pct, &all_models);
                eval::latency_figure(&ctx, &format!("{fig} TPOT"), "tpot", pct, &all_models);
                if pct == "p50" || pct == "mean" {
                    eval::latency_figure(&ctx, &format!("{fig} ITL"), "itl", pct, &all_models);
                }
            }
            eval::fig_e1(&ctx);
            // Live experiments last (they need artifacts + ~2 min).
            eval::live::fig4(out_ref);
            eval::live::fig3(out_ref);
        }
        "fig1" => eval::fig1(&ctx),
        "table1" => eval::table1(&ctx),
        "table2" => eval::table2(&ctx),
        "table3" => eval::table3(&ctx),
        "table4" => eval::table4(&ctx),
        "table6" => eval::table6(&ctx, false),
        "table7" => eval::table6(&ctx, true),
        "fig5" => {
            eval::latency_figure(&ctx, "Fig 5 TTFT", "ttft", "p999", &["qwen3-32b"]);
            eval::latency_figure(&ctx, "Fig 5 TPOT", "tpot", "p999", &["qwen3-32b"]);
        }
        "fig6" => {
            eval::latency_figure(&ctx, "Fig 6 TTFT", "ttft", "p99", &all_models);
            eval::latency_figure(&ctx, "Fig 6 TPOT", "tpot", "p99", &all_models);
        }
        "fig7" => eval::fig7(&ctx),
        "fig8" => eval::fig8(&ctx),
        "tableB1" => eval::table_b1(&ctx),
        "tableB2" => eval::table_b2(&ctx),
        "figC1" => eval::fig_c1(&ctx),
        "figD" => {
            for (fig, pct) in
                [("Fig D.1", "p999"), ("Fig D.2", "p95"), ("Fig D.3", "p50"), ("Fig D.4", "mean")]
            {
                eval::latency_figure(&ctx, &format!("{fig} TTFT"), "ttft", pct, &all_models);
                eval::latency_figure(&ctx, &format!("{fig} TPOT"), "tpot", pct, &all_models);
            }
        }
        "figE1" => eval::fig_e1(&ctx),
        other => {
            eprintln!("unknown eval target: {other}");
            std::process::exit(2);
        }
    }
}

/// `--policy` if present; exits with a usage error on unknown values.
fn parse_policy_flag(args: &Args) -> Option<PolicyKind> {
    args.get("policy").map(|raw| {
        PolicyKind::parse(raw).unwrap_or_else(|| {
            eprintln!("unknown policy {raw} (fcfs|priority|sjf|slo)");
            std::process::exit(2);
        })
    })
}

fn info(args: &Args) {
    let model = args.get_or("model", "blink-tiny");
    let dir = blink::runtime::artifacts_dir().join(model);
    match blink::runtime::ModelManifest::load(&dir.join("manifest.txt")) {
        Ok(m) => {
            println!("model {} (moe={})", m.model, m.moe);
            println!(
                "geometry: vocab={} d_model={} layers={} heads={}/{} d_ff={}",
                m.vocab_size, m.d_model, m.n_layers, m.n_heads, m.n_kv_heads, m.d_ff
            );
            println!(
                "kv: block_size={} num_blocks={} max_blocks/seq={} (max context {})",
                m.block_size, m.num_blocks, m.max_blocks_per_seq, m.max_context()
            );
            println!("graphs ({}, attention={}):", m.graphs.len(), m.attention_backend());
            for g in &m.graphs {
                println!(
                    "  {} kind={} batch={} seq={} backend={}",
                    g.name, g.kind, g.batch, g.seq, g.backend
                );
            }
            // Verify k-grid coverage (DESIGN.md §11): `serve --spec-k K`
            // only engages at batch sizes whose decode grid entry has a
            // decode_verify twin at that k — uncovered batches silently
            // fall back to plain decode, so surface any gap here.
            let cache = blink::gpu::scheduler::cache_from_manifest(&m);
            if cache.has_verify_graphs() {
                for k in cache.verify_ks() {
                    let uncovered = cache.verify_uncovered_batches(k);
                    if uncovered.is_empty() {
                        println!("spec decode k={k}: covers the full decode batch grid");
                    } else {
                        println!(
                            "spec decode k={k}: WARNING: no verify graph reachable for decode \
                             batch sizes {uncovered:?} — those batches fall back to plain decode \
                             under --spec-k {k}"
                        );
                    }
                }
            } else {
                println!("spec decode: no decode_verify graphs (serve --spec-k will stay off)");
            }
        }
        Err(e) => {
            eprintln!("cannot load manifest: {e:#} (run `make artifacts`)");
            std::process::exit(1);
        }
    }
}
