//! Paged KV-cache management (paper §4.2), GPU-resident: the block pool
//! itself is a device buffer owned by the executor; this module manages
//! its *metadata* — the free list, per-request block tables, refcounts,
//! the prefix index and the admission reservation — all living in
//! "persistent GPU memory" (state owned by the scheduler thread,
//! surviving graph re-instantiation).
//!
//! Admission policy: full reservation. A request is admitted only if its
//! uncached tail of `blocks_needed_with_prefix(..)` blocks is available,
//! so decode can never hit a mid-flight OOM (no preemption-by-OOM path;
//! DECODE_PAUSED is reserved for continuous-batching pauses, as in the
//! paper). The reservation covers padded prefill positions because the
//! prefill graph writes K/V for every padded slot (see
//! python/compile/model.py).
//!
//! # Prefix-aware reuse (paper delta)
//!
//! Blink itself ships with prefix caching *disabled* (§6.1 runs every
//! system without it, for a controlled comparison). This module adds it
//! back as DESIGN.md §7 describes, because multi-turn conversations
//! re-prefill their entire history every turn without it:
//!
//! * every block carries a **refcount**; blocks may back multiple live
//!   sequences that share a common prompt prefix;
//! * a **prefix index** maps chained token-block hashes to cached
//!   blocks. The chain hash of block *i* mixes the chain hash of block
//!   *i−1* with block *i*'s token content, so a lookup walks the prompt
//!   block by block (radix-style over full blocks) and stops at the
//!   first miss. Entries additionally store their parent hash *and*
//!   their token content, and [`KvManager::match_prefix`] verifies both
//!   — a hash collision can never alias differing token content;
//! * [`KvManager::admit_reuse`] matches the longest indexed prefix,
//!   bumps the matched blocks' refcounts and reserves only the uncached
//!   tail; [`KvManager::index_prompt`] publishes a prompt's full blocks
//!   into the index *after* its prefill completed;
//! * [`KvManager::release`] decrements refcounts. An unreferenced block
//!   that holds indexed prefix content is *parked* in an LRU evictable
//!   set instead of being freed — it is reclaimed lazily, oldest first,
//!   only under pool pressure, and never while referenced.
//!
//! Invariants (pinned by the property tests below):
//! 1. a block is never freed or evicted while its refcount is > 0;
//! 2. the evictable set contains exactly the unreferenced indexed
//!    blocks — never a referenced or free one;
//! 3. `free + evictable + referenced == num_blocks − 1` (block 0 is the
//!    shared pad target and never leaves the manager);
//! 4. a prefix match never spans differing token content;
//! 5. the index never refers to K/V that was not written: entries are
//!    committed only after a successful prefill, so a failed launch
//!    releases having published nothing. Speculative verify (DESIGN.md
//!    §11) extends this to *rejected* writes: a verify launch writes
//!    K/V optimistically for every draft position, and
//!    [`KvManager::truncate_tail`] rolls `cached_len` back past the
//!    rejected suffix — those positions sit beyond `cached_len` (the
//!    kernels mask by length, so attention never reads them, and the
//!    lane's next launch overwrites them), and they always live in the
//!    sequence's *partial* tail block region, which is never indexed —
//!    so rejected-draft K/V is unreachable through the prefix index.

use std::collections::{BTreeMap, HashMap};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvConfig {
    pub block_size: usize,
    pub num_blocks: usize,
    pub max_blocks_per_seq: usize,
}

impl KvConfig {
    pub fn blocks_needed(&self, padded_prompt: usize, prompt: usize, max_new: usize) -> usize {
        let span = padded_prompt.max(prompt + max_new);
        span.div_ceil(self.block_size)
    }

    /// Total blocks a sequence needs when its first `cached` prompt
    /// tokens are served from the prefix index and only the suffix is
    /// prefilled (padded to `padded_suffix` grid positions). The span
    /// still covers the whole padded prefill write *and* the decode
    /// budget, exactly like [`KvConfig::blocks_needed`].
    pub fn blocks_needed_with_prefix(
        &self,
        cached: usize,
        padded_suffix: usize,
        prompt: usize,
        max_new: usize,
    ) -> usize {
        let span = (cached + padded_suffix).max(prompt + max_new);
        span.div_ceil(self.block_size)
    }
}

/// Per-request cache state: the ordered blocks backing the sequence.
#[derive(Debug, Clone)]
pub struct SeqCache {
    pub blocks: Vec<u32>,
    /// Tokens currently cached (prompt after prefill, +1 per decode step).
    pub cached_len: usize,
    /// Leading prompt tokens served from the prefix index at admission
    /// (block-aligned; 0 = cold). The prefill launch only has to cover
    /// `prompt_len - prefix_len` suffix tokens.
    pub prefix_len: usize,
}

impl SeqCache {
    /// The fixed-shape block-table row the AOT graphs take: `max_blocks`
    /// entries, reserved blocks first, padded with block 0 (never touched
    /// within the reservation span; the attention kernel masks by length).
    pub fn table_row(&self, max_blocks: usize) -> Vec<i32> {
        let mut row = vec![0i32; max_blocks];
        for (i, b) in self.blocks.iter().take(max_blocks).enumerate() {
            row[i] = *b as i32;
        }
        row
    }
}

/// Longest indexed prefix of a prompt (see [`KvManager::match_prefix`]).
#[derive(Debug, Clone, Default)]
pub struct PrefixMatch {
    /// Matched cached blocks, in sequence order.
    pub blocks: Vec<u32>,
    /// Matched tokens (`blocks.len() * block_size`).
    pub tokens: usize,
}

/// Reuse/eviction counters (monotone over the manager's lifetime).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvStats {
    /// Admissions that reused at least one cached block.
    pub prefix_hits: u64,
    /// Admissions that reused nothing (cold prompts).
    pub prefix_misses: u64,
    /// Prompt tokens served from the prefix index.
    pub reused_tokens: u64,
    /// Block reservations avoided by sharing.
    pub reused_blocks: u64,
    /// Full prompt blocks inserted into the prefix index.
    pub indexed_blocks: u64,
    /// Parked blocks reclaimed under pool pressure.
    pub evicted_blocks: u64,
}

/// One prefix-index entry: a cached full block of prompt tokens.
#[derive(Debug)]
struct PrefixEntry {
    block: u32,
    /// Chain hash of the preceding block (`CHAIN_SEED` for block 0).
    parent: u64,
    /// The block's token content — verified on every match so a hash
    /// collision can never alias differing prompts.
    tokens: Vec<u32>,
    /// LRU tick while parked in the evictable set; `None` while any
    /// sequence references the block.
    evict_tick: Option<u64>,
}

/// Sentinel for "block holds no index entry" in the per-block map.
const NO_ENTRY: u64 = 0;
/// Root of every hash chain (also guards against `NO_ENTRY` aliasing: a
/// chain hash is always the output of `mix`, never 0 in practice; we
/// additionally skip indexing on the astronomically-unlikely 0 hash).
const CHAIN_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Chain hash of one block given its parent's chain hash.
fn chain_hash(parent: u64, tokens: &[u32]) -> u64 {
    let mut h = mix(parent ^ CHAIN_SEED);
    for &t in tokens {
        h = mix(h ^ t as u64);
    }
    h
}

/// Block pool metadata manager.
pub struct KvManager {
    config: KvConfig,
    free: Vec<u32>,
    /// Per-block reference count (index 0 unused: the pad block).
    refcount: Vec<u32>,
    /// Prefix index: chain hash → cached block entry.
    index: HashMap<u64, PrefixEntry>,
    /// Per-block back-pointer into `index` (`NO_ENTRY` = not indexed).
    block_entry: Vec<u64>,
    /// Unreferenced indexed blocks, LRU order: tick → block.
    evictable: BTreeMap<u64, u32>,
    lru_tick: u64,
    /// High-water mark of simultaneously allocated blocks (telemetry).
    pub peak_in_use: usize,
    pub stats: KvStats,
    /// Debug-only O(1) membership mirror of `free`, replacing the old
    /// O(free)-per-block `free.contains` double-free scan.
    #[cfg(debug_assertions)]
    free_bits: Vec<bool>,
}

impl KvManager {
    pub fn new(config: KvConfig) -> KvManager {
        // LIFO free list; block 0 is kept as the shared pad target and
        // never handed out, matching the table_row padding convention.
        let free: Vec<u32> = (1..config.num_blocks as u32).rev().collect();
        #[cfg(debug_assertions)]
        let free_bits = {
            let mut bits = vec![true; config.num_blocks];
            bits[0] = false;
            bits
        };
        KvManager {
            free,
            refcount: vec![0; config.num_blocks],
            index: HashMap::new(),
            block_entry: vec![NO_ENTRY; config.num_blocks],
            evictable: BTreeMap::new(),
            lru_tick: 0,
            config,
            peak_in_use: 0,
            stats: KvStats::default(),
            #[cfg(debug_assertions)]
            free_bits,
        }
    }

    pub fn config(&self) -> KvConfig {
        self.config
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Unreferenced blocks parked in the prefix cache (reclaimable).
    pub fn evictable_blocks(&self) -> usize {
        self.evictable.len()
    }

    /// Blocks the allocator can produce right now (free + reclaimable).
    pub fn available_blocks(&self) -> usize {
        self.free.len() + self.evictable.len()
    }

    /// Blocks referenced by at least one live sequence.
    pub fn in_use(&self) -> usize {
        (self.config.num_blocks - 1) - self.free.len() - self.evictable.len()
    }

    /// Can a request with these dimensions be admitted right now?
    pub fn can_admit(&self, padded_prompt: usize, prompt: usize, max_new: usize) -> bool {
        let need = self.config.blocks_needed(padded_prompt, prompt, max_new);
        need <= self.config.max_blocks_per_seq && need <= self.available_blocks()
    }

    /// Can a request be admitted given this prefix match and a prefill
    /// suffix padded to `padded_suffix`? Matched blocks that are
    /// currently *parked* count against availability too: admitting
    /// unparks them, so they can no longer be evicted to feed the tail
    /// reservation.
    pub fn can_admit_reuse(
        &self,
        m: &PrefixMatch,
        padded_suffix: usize,
        prompt: usize,
        max_new: usize,
    ) -> bool {
        let need = self.config.blocks_needed_with_prefix(m.tokens, padded_suffix, prompt, max_new);
        let tail = need.saturating_sub(m.blocks.len());
        let parked =
            m.blocks.iter().filter(|&&b| self.refcount[b as usize] == 0).count();
        need <= self.config.max_blocks_per_seq && tail + parked <= self.available_blocks()
    }

    /// Longest indexed prefix of `tokens`, walking full blocks through
    /// the hash chain. Verifies parent linkage *and* token content at
    /// every step (invariant 4). Matching is capped so at least one
    /// prompt token is always left to prefill — the suffix launch is
    /// what produces the first output token's logits.
    pub fn match_prefix(&self, tokens: &[u32]) -> PrefixMatch {
        let bs = self.config.block_size;
        let max_blocks = tokens.len().saturating_sub(1) / bs;
        let mut h = CHAIN_SEED;
        let mut blocks = Vec::new();
        for b in 0..max_blocks {
            let content = &tokens[b * bs..(b + 1) * bs];
            let next = chain_hash(h, content);
            match self.index.get(&next) {
                Some(e) if e.parent == h && e.tokens == content => {
                    blocks.push(e.block);
                    h = next;
                }
                _ => break,
            }
        }
        PrefixMatch { tokens: blocks.len() * bs, blocks }
    }

    /// Reserve the full block span for a request without consulting the
    /// prefix index (the paper's behavior). Returns None if the pool
    /// cannot satisfy it (caller applies backpressure).
    pub fn admit(&mut self, padded_prompt: usize, prompt: usize, max_new: usize) -> Option<SeqCache> {
        if !self.can_admit(padded_prompt, prompt, max_new) {
            return None;
        }
        let need = self.config.blocks_needed(padded_prompt, prompt, max_new);
        let blocks: Vec<u32> = (0..need).map(|_| self.alloc_block()).collect();
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Some(SeqCache { blocks, cached_len: 0, prefix_len: 0 })
    }

    /// Prefix-aware admission: match the longest cached prefix of
    /// `tokens`, share those blocks (refcount bump) and reserve only the
    /// uncached tail. `padded_suffix` is the grid-padded length of the
    /// uncached suffix the prefill launch will cover.
    pub fn admit_reuse(
        &mut self,
        tokens: &[u32],
        padded_suffix: usize,
        max_new: usize,
    ) -> Option<SeqCache> {
        let m = self.match_prefix(tokens);
        self.admit_matched(&m, tokens.len(), padded_suffix, max_new)
    }

    /// [`KvManager::admit_reuse`] with a pre-computed match — the
    /// scheduler already ran [`KvManager::match_prefix`] to size the
    /// padded suffix, so this avoids hashing the prompt a second time.
    /// `m` must come from `match_prefix` on the current index state,
    /// with no intervening mutation.
    pub fn admit_matched(
        &mut self,
        m: &PrefixMatch,
        prompt: usize,
        padded_suffix: usize,
        max_new: usize,
    ) -> Option<SeqCache> {
        if !self.can_admit_reuse(m, padded_suffix, prompt, max_new) {
            return None;
        }
        let need =
            self.config.blocks_needed_with_prefix(m.tokens, padded_suffix, prompt, max_new);
        let matched = m.blocks.len();

        // Share the matched prefix.
        let mut blocks = Vec::with_capacity(need);
        for &b in &m.blocks {
            self.ref_block(b);
            blocks.push(b);
        }
        // Reserve the uncached tail (evicting parked blocks LRU-first if
        // the free list alone cannot cover it — capacity checked above).
        for _ in matched..need {
            blocks.push(self.alloc_block());
        }

        if m.tokens > 0 {
            self.stats.prefix_hits += 1;
            self.stats.reused_tokens += m.tokens as u64;
            self.stats.reused_blocks += matched as u64;
        } else {
            self.stats.prefix_misses += 1;
        }
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Some(SeqCache { blocks, cached_len: 0, prefix_len: m.tokens })
    }

    /// Publish a successfully prefilled prompt's *full* blocks into the
    /// prefix index. Deliberately separate from [`KvManager::admit_reuse`]
    /// and called only after the prefill launch completed: the index can
    /// never refer to K/V that was not actually written (invariant 5) —
    /// a failed prefill simply releases, having published nothing, and a
    /// request admitted in the same batch as its twin can never match
    /// the twin's still-unwritten blocks. Partial blocks (prompt tail,
    /// decode region) are never indexed: their content is not a stable
    /// full-block prefix.
    ///
    /// Chunked prefill commits *progressively* through the same entry
    /// point: after chunk *k* completes, the scheduler passes the
    /// prompt prefix up to the chunk's end, so a partially prefilled
    /// prompt's index entries cover exactly its fully prefilled blocks
    /// and nothing beyond — invariant 5 holds mid-flight, and the
    /// already-committed entries stay valid even if a later chunk
    /// fails (their K/V was written by completed launches).
    pub fn index_prompt(&mut self, cache: &SeqCache, tokens: &[u32]) {
        // Rehashing from the seed (rather than resuming from the
        // admission-time match) is deliberate: it runs once per
        // *successful prefill* (sub-µs against a multi-ms launch) and
        // keeps the commit independent of any state captured at
        // admission. Chunked lanes, whose commits repeat per chunk,
        // use [`KvManager::index_prompt_resume`] instead.
        self.index_prompt_resume(cache, tokens, 0, None);
    }

    /// Resume-from-`chain` form of [`KvManager::index_prompt`] for
    /// chunked prefill: commits only the full blocks `from_block..` of
    /// `tokens`, continuing the hash chain from the value the previous
    /// call returned (`None` = start at the chain root, `from_block`
    /// must then be 0). Returns the chain hash after the last full
    /// block, to pass back in — so a lane's successive chunk commits
    /// each pay O(chunk), not O(prefix), and their total equals one
    /// whole-prompt `index_prompt`. Contract: `(from_block, chain)`
    /// must come from the previous call over the same growing prompt.
    pub fn index_prompt_resume(
        &mut self,
        cache: &SeqCache,
        tokens: &[u32],
        from_block: usize,
        chain: Option<u64>,
    ) -> u64 {
        debug_assert!(chain.is_some() || from_block == 0, "rootless resume");
        let bs = self.config.block_size;
        let full = (tokens.len() / bs).min(cache.blocks.len());
        let mut h = chain.unwrap_or(CHAIN_SEED);
        for bi in from_block..full {
            let content = &tokens[bi * bs..(bi + 1) * bs];
            let next = chain_hash(h, content);
            // Existing entries (this sequence's own matched prefix, or a
            // twin committed first) are kept — identical content either
            // way.
            if next != NO_ENTRY && !self.index.contains_key(&next) {
                self.index.insert(
                    next,
                    PrefixEntry {
                        block: cache.blocks[bi],
                        parent: h,
                        tokens: content.to_vec(),
                        evict_tick: None,
                    },
                );
                self.block_entry[cache.blocks[bi] as usize] = next;
                self.stats.indexed_blocks += 1;
            }
            h = next;
        }
        h
    }

    /// Roll a sequence's cached length back to `new_len` after a
    /// speculative verify rejected a draft suffix (DESIGN.md §11). The
    /// verify launch wrote K/V optimistically for all k draft
    /// positions; the rejected tail is logically discarded here and
    /// physically overwritten by the lane's next launch before any
    /// attention reads it (the kernels mask by `cached_len`). Blocks
    /// stay reserved — the admission-time reservation already covers
    /// `prompt + max_new`, so rollback never frees or reshuffles
    /// blocks, and invariant 5 holds: the rejected positions live in
    /// the never-indexed partial tail region, beyond `cached_len`.
    pub fn truncate_tail(&self, cache: &mut SeqCache, new_len: usize) {
        assert!(new_len <= cache.cached_len, "truncate_tail must not extend the cache");
        debug_assert!(
            new_len >= cache.prefix_len,
            "rollback below the shared prefix ({new_len} < {})",
            cache.prefix_len
        );
        debug_assert!(
            new_len.div_ceil(self.config.block_size) <= cache.blocks.len(),
            "cached span exceeds the block reservation"
        );
        cache.cached_len = new_len;
    }

    /// Return a finished request's blocks: decrement refcounts; an
    /// unreferenced block is parked (if indexed) or freed (if not).
    pub fn release(&mut self, cache: SeqCache) {
        for b in cache.blocks {
            let rc = &mut self.refcount[b as usize];
            debug_assert!(*rc > 0, "release of unreferenced block {b}");
            *rc -= 1;
            if *rc > 0 {
                continue; // still shared by another sequence
            }
            let h = self.block_entry[b as usize];
            if h != NO_ENTRY {
                // Park: reusable prefix content, reclaimed only under
                // pool pressure (LRU), never while referenced.
                self.lru_tick += 1;
                if let Some(e) = self.index.get_mut(&h) {
                    e.evict_tick = Some(self.lru_tick);
                }
                self.evictable.insert(self.lru_tick, b);
            } else {
                #[cfg(debug_assertions)]
                {
                    // O(1) double-free membership check (the old
                    // `free.contains(&b)` scan was O(free) per block).
                    debug_assert!(!self.free_bits[b as usize], "double free of block {b}");
                    self.free_bits[b as usize] = true;
                }
                self.free.push(b);
            }
        }
    }

    /// Take a reference on a cached block, unparking it if necessary.
    fn ref_block(&mut self, b: u32) {
        let rc = &mut self.refcount[b as usize];
        if *rc == 0 {
            let h = self.block_entry[b as usize];
            debug_assert_ne!(h, NO_ENTRY, "unreferenced non-indexed block {b} outside free list");
            if let Some(e) = self.index.get_mut(&h) {
                if let Some(tick) = e.evict_tick.take() {
                    let removed = self.evictable.remove(&tick);
                    debug_assert_eq!(removed, Some(b));
                }
            }
        }
        *rc += 1;
    }

    /// Pop a free block, evicting the LRU parked block if the free list
    /// is empty. Caller must have checked `available_blocks()`.
    fn alloc_block(&mut self) -> u32 {
        let b = match self.free.pop() {
            Some(b) => b,
            None => self.evict_lru().expect("available_blocks checked by caller"),
        };
        #[cfg(debug_assertions)]
        {
            self.free_bits[b as usize] = false;
        }
        debug_assert_eq!(self.refcount[b as usize], 0);
        self.refcount[b as usize] = 1;
        b
    }

    /// Drop the least-recently-used parked block from the prefix index.
    fn evict_lru(&mut self) -> Option<u32> {
        let (&tick, &b) = self.evictable.iter().next()?;
        self.evictable.remove(&tick);
        let h = self.block_entry[b as usize];
        self.index.remove(&h);
        self.block_entry[b as usize] = NO_ENTRY;
        self.stats.evicted_blocks += 1;
        Some(b)
    }

    /// Check the module invariants (used by the property tests; cheap
    /// enough to call after every mutation in tests).
    pub fn check_invariants(&self) {
        let referenced = self.refcount.iter().filter(|&&r| r > 0).count();
        assert_eq!(
            self.free.len() + self.evictable.len() + referenced,
            self.config.num_blocks - 1,
            "conservation: free + evictable + referenced == usable pool"
        );
        for &b in self.evictable.values() {
            assert_eq!(self.refcount[b as usize], 0, "evictable block {b} is referenced");
            assert_ne!(self.block_entry[b as usize], NO_ENTRY, "evictable block {b} not indexed");
        }
        for &b in &self.free {
            assert_eq!(self.refcount[b as usize], 0, "free block {b} is referenced");
            assert_eq!(self.block_entry[b as usize], NO_ENTRY, "free block {b} still indexed");
        }
        assert_eq!(self.refcount[0], 0, "pad block 0 must never be referenced");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn cfg() -> KvConfig {
        KvConfig { block_size: 16, num_blocks: 64, max_blocks_per_seq: 8 }
    }

    /// A deterministic prompt of `n` tokens from a stream tag.
    fn prompt(tag: u32, n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| tag.wrapping_mul(1_000_003).wrapping_add(i)).collect()
    }

    #[test]
    fn blocks_needed_covers_padding() {
        let c = cfg();
        // prompt 17 padded to 32, 2 new tokens: span = max(32, 19) = 32 -> 2
        assert_eq!(c.blocks_needed(32, 17, 2), 2);
        // long generation dominates: max(32, 17+100)=117 -> 8
        assert_eq!(c.blocks_needed(32, 17, 100), 8);
        assert_eq!(c.blocks_needed(16, 16, 0), 1);
        assert_eq!(c.blocks_needed(16, 16, 1), 2);
        // 32 cached + 16-padded suffix, decode budget dominates.
        assert_eq!(c.blocks_needed_with_prefix(32, 16, 40, 30), 5);
        assert_eq!(c.blocks_needed_with_prefix(32, 16, 40, 1), 3);
    }

    #[test]
    fn admit_and_release_roundtrip() {
        let mut m = KvManager::new(cfg());
        let before = m.free_blocks();
        let c = m.admit(32, 20, 40).unwrap(); // span 60 -> 4 blocks
        assert_eq!(c.blocks.len(), 4);
        assert_eq!(m.free_blocks(), before - 4);
        m.release(c);
        assert_eq!(m.free_blocks(), before);
    }

    #[test]
    fn rejects_over_long_sequences() {
        let mut m = KvManager::new(cfg());
        // 9 blocks needed > max_blocks_per_seq 8
        assert!(m.admit(16, 16, 128).is_none());
    }

    #[test]
    fn backpressure_when_pool_exhausted() {
        let mut m = KvManager::new(cfg());
        let mut held = vec![];
        // 63 usable blocks; each request takes 8.
        for _ in 0..7 {
            held.push(m.admit(128, 128, 0).unwrap());
        }
        assert_eq!(m.free_blocks(), 63 - 56);
        assert!(m.admit(128, 128, 0).is_none(), "must refuse, 7 < 8 free");
        m.release(held.pop().unwrap());
        assert!(m.admit(128, 128, 0).is_some());
    }

    #[test]
    fn table_row_pads_with_zero() {
        let c = SeqCache { blocks: vec![5, 9], cached_len: 20, prefix_len: 0 };
        assert_eq!(c.table_row(4), vec![5, 9, 0, 0]);
    }

    #[test]
    fn block_zero_never_allocated() {
        // Drain the whole pool; block 0 (the pad target) must never be
        // handed out and no block may be handed out twice.
        let mut m = KvManager::new(cfg());
        let mut seen = std::collections::HashSet::new();
        while let Some(c) = m.admit(16, 16, 0) {
            for b in &c.blocks {
                assert_ne!(*b, 0);
                assert!(seen.insert(*b), "block {b} handed out twice");
            }
        }
        assert_eq!(seen.len(), 63);
    }

    #[test]
    fn prefix_hit_reserves_only_tail() {
        let mut m = KvManager::new(cfg());
        let toks = prompt(7, 64); // 4 full blocks
        let a = m.admit_reuse(&toks, 64, 4).unwrap();
        assert_eq!(a.prefix_len, 0, "cold admission");
        m.index_prompt(&a, &toks); // prefill succeeded: commit
        // All 4 full prompt blocks are indexed; matching is capped at 3
        // so at least one token always prefills.
        assert_eq!(m.stats.indexed_blocks, 4);
        let free_before = m.free_blocks();

        let b = m.admit_reuse(&toks, 16, 4).unwrap();
        assert_eq!(b.prefix_len, 48, "3 blocks * 16 tokens reused");
        assert_eq!(&b.blocks[..3], &a.blocks[..3], "prefix blocks shared");
        // span = max(48+16, 64+4) = 68 -> 5 blocks, 3 shared -> 2 fresh.
        assert_eq!(m.free_blocks(), free_before - 2);
        assert_eq!(m.stats.prefix_hits, 1);
        assert_eq!(m.stats.reused_tokens, 48);
        m.check_invariants();
        m.release(a);
        m.release(b);
        m.check_invariants();
    }

    #[test]
    fn release_parks_indexed_blocks_then_admit_hits_again() {
        let mut m = KvManager::new(cfg());
        let toks = prompt(3, 64);
        let a = m.admit_reuse(&toks, 64, 4).unwrap();
        m.index_prompt(&a, &toks);
        m.release(a);
        // All 4 indexed blocks are parked, not freed: the pool holds.
        assert_eq!(m.evictable_blocks(), 4);
        assert_eq!(m.free_blocks() + m.evictable_blocks(), 63);
        // A re-admission of the same prompt reuses the parked blocks
        // (the 4th indexed block is beyond the match cap and stays
        // parked — only matchable blocks unpark).
        let b = m.admit_reuse(&toks, 16, 4).unwrap();
        assert_eq!(b.prefix_len, 48);
        assert_eq!(m.evictable_blocks(), 1, "hit unparks the 3 matched blocks");
        m.release(b);
        assert_eq!(m.free_blocks() + m.evictable_blocks(), 63);
        m.check_invariants();
    }

    #[test]
    fn eviction_under_pressure_lru_first_never_referenced() {
        let mut m = KvManager::new(cfg());
        // Park two distinct 4-block prefixes (8 evictable), keep a third
        // referenced.
        let a = m.admit_reuse(&prompt(1, 64), 64, 4).unwrap();
        m.index_prompt(&a, &prompt(1, 64));
        m.release(a);
        let b = m.admit_reuse(&prompt(2, 64), 64, 4).unwrap();
        m.index_prompt(&b, &prompt(2, 64));
        m.release(b);
        let held = m.admit_reuse(&prompt(3, 64), 64, 4).unwrap();
        m.index_prompt(&held, &prompt(3, 64));
        assert_eq!(m.evictable_blocks(), 8);
        let evictable_before = m.evictable_blocks();

        // Drain the free list entirely, forcing evictions.
        let mut drained = vec![];
        while m.free_blocks() >= 8 {
            drained.push(m.admit(128, 128, 0).unwrap());
        }
        while m.available_blocks() >= 2 {
            drained.push(m.admit(16, 16, 8).unwrap()); // 2 blocks each
        }
        assert!(m.evictable_blocks() < evictable_before, "pressure evicted parked blocks");
        assert!(m.stats.evicted_blocks > 0);
        m.check_invariants();
        // The referenced prefix survives: release everything and the
        // held prompt must still fully hit.
        for d in drained {
            m.release(d);
        }
        m.release(held);
        let again = m.admit_reuse(&prompt(3, 64), 16, 4).unwrap();
        assert_eq!(again.prefix_len, 48, "referenced prefix was never evicted");
        m.release(again);
        m.check_invariants();
    }

    #[test]
    fn parked_matched_blocks_count_against_tail_availability() {
        // Regression: a hit on *parked* blocks unparks them, shrinking
        // the evictable pool the tail reservation would draw from — the
        // admission check must refuse rather than let alloc_block panic.
        let mut m = KvManager::new(cfg());
        let toks = prompt(4, 64);
        let a = m.admit_reuse(&toks, 64, 4).unwrap(); // 5 blocks
        m.index_prompt(&a, &toks); // 4 indexed
        m.release(a); // 4 parked, 1 freed
        assert_eq!((m.free_blocks(), m.evictable_blocks()), (59, 4));
        // Drain the free list completely with 1-block requests.
        let mut fillers = vec![];
        while m.free_blocks() > 0 {
            fillers.push(m.admit(16, 16, 0).unwrap());
        }
        // Re-admitting the prompt needs 3 (parked) + 2 tail, but only
        // the 3 parked blocks are available: must refuse cleanly.
        assert!(m.admit_reuse(&toks, 16, 4).is_none(), "tail cannot be satisfied");
        m.check_invariants();
        // Two freed blocks later, the same admission succeeds and the
        // parked prefix is reused rather than evicted.
        m.release(fillers.pop().unwrap());
        m.release(fillers.pop().unwrap());
        let b = m.admit_reuse(&toks, 16, 4).expect("2 free + 3 parked now suffice");
        assert_eq!(b.prefix_len, 48);
        assert_eq!(m.stats.evicted_blocks, 0, "reuse must not evict its own match");
        m.release(b);
        for f in fillers {
            m.release(f);
        }
        m.check_invariants();
    }

    #[test]
    fn index_commits_only_after_successful_prefill() {
        let mut m = KvManager::new(cfg());
        let toks = prompt(5, 64);
        // Admission alone publishes nothing: a twin admitted in the same
        // batch (before any commit) matches nothing — it can never share
        // blocks whose K/V is still unwritten.
        let a = m.admit_reuse(&toks, 64, 4).unwrap();
        assert_eq!(m.stats.indexed_blocks, 0);
        assert_eq!(m.match_prefix(&toks).tokens, 0);
        // Failed prefill: plain release; no phantom entries survive.
        m.release(a);
        assert_eq!(m.match_prefix(&toks).tokens, 0);
        assert_eq!(m.free_blocks(), 63);
        assert_eq!(m.evictable_blocks(), 0);
        m.check_invariants();

        // Successful prefill: commit publishes, later prompts hit, and
        // the sharer's commit is a no-op (entries already present).
        let b = m.admit_reuse(&toks, 64, 4).unwrap();
        m.index_prompt(&b, &toks);
        assert_eq!(m.stats.indexed_blocks, 4);
        let c = m.admit_reuse(&toks, 16, 4).unwrap();
        assert_eq!(c.prefix_len, 48);
        m.index_prompt(&c, &toks);
        assert_eq!(m.stats.indexed_blocks, 4, "sharer re-commit inserts nothing");
        m.release(c);
        m.release(b);
        m.check_invariants();
    }

    /// Chunked prefill's partial-index invariant: committing the prompt
    /// prefix up to a completed chunk indexes exactly those full
    /// blocks; a later prompt can hit them while the rest of the
    /// prompt is still unprefilled, and the final commit extends the
    /// chain without duplicating entries.
    #[test]
    fn partial_commit_indexes_only_completed_chunks() {
        let mut m = KvManager::new(cfg());
        let toks = prompt(8, 64); // 4 full blocks
        let a = m.admit_reuse(&toks, 64, 4).unwrap();
        // Chunk 1 of 2 completed: commit the first 32 tokens only,
        // rooting the resumable hash chain.
        let h = m.index_prompt_resume(&a, &toks[..32], 0, None);
        assert_eq!(m.stats.indexed_blocks, 2, "only the chunk's full blocks commit");
        assert_eq!(
            m.match_prefix(&toks).tokens,
            32,
            "a concurrent prompt hits exactly the prefilled prefix"
        );
        // Final chunk: resuming from the stored chain walks only the
        // new blocks, and the result equals one whole-prompt commit —
        // the same prompt indexed whole in a twin manager matches
        // identically.
        m.index_prompt_resume(&a, &toks, 2, Some(h));
        assert_eq!(m.stats.indexed_blocks, 4);
        assert_eq!(m.match_prefix(&toks).tokens, 48, "match capped below the full prompt");
        let mut whole = KvManager::new(cfg());
        let b = whole.admit_reuse(&toks, 64, 4).unwrap();
        whole.index_prompt(&b, &toks);
        assert_eq!(whole.stats.indexed_blocks, m.stats.indexed_blocks);
        assert_eq!(whole.match_prefix(&toks).tokens, m.match_prefix(&toks).tokens);
        whole.release(b);
        m.release(a);
        m.check_invariants();
    }

    #[test]
    fn match_never_crosses_differing_content() {
        let mut m = KvManager::new(cfg());
        let toks = prompt(9, 64);
        let a = m.admit_reuse(&toks, 64, 4).unwrap();
        m.index_prompt(&a, &toks);
        // Same first block, different second block: match stops at 1.
        let mut forked = toks.clone();
        forked[20] ^= 1;
        assert_eq!(m.match_prefix(&forked).tokens, 16);
        // Different first token: no match at all.
        let mut cold = toks.clone();
        cold[0] ^= 1;
        assert_eq!(m.match_prefix(&cold).tokens, 0);
        m.release(a);
    }

    #[test]
    fn truncate_tail_rolls_back_cached_len_only() {
        let mut m = KvManager::new(cfg());
        let mut c = m.admit(32, 20, 40).unwrap(); // span 60 -> 4 blocks
        c.cached_len = 20; // prefill done
        let blocks = c.blocks.clone();
        let free = m.free_blocks();
        // Verify wrote k=4 draft positions optimistically (20..24);
        // 1 accepted + the bonus token survive -> roll back to 22.
        c.cached_len += 4;
        m.truncate_tail(&mut c, 22);
        assert_eq!(c.cached_len, 22);
        assert_eq!(c.blocks, blocks, "blocks stay reserved across rollback");
        assert_eq!(m.free_blocks(), free, "rollback frees nothing");
        // Boundary: new_len == cached_len is a no-op (fully accepted).
        m.truncate_tail(&mut c, 22);
        assert_eq!(c.cached_len, 22);
        m.release(c);
        m.check_invariants();
    }

    #[test]
    #[should_panic(expected = "must not extend")]
    fn truncate_tail_rejects_extension() {
        let m = KvManager::new(cfg());
        let mut c = SeqCache { blocks: vec![1], cached_len: 5, prefix_len: 0 };
        m.truncate_tail(&mut c, 6);
    }

    #[test]
    fn prop_alloc_free_never_double_allocates() {
        run_prop("kv-alloc-unique", 0xBEEF, 200, |rng: &mut Rng| {
            let mut m = KvManager::new(cfg());
            let mut live: Vec<SeqCache> = vec![];
            let mut owned = std::collections::HashSet::new();
            for _ in 0..100 {
                if rng.f64() < 0.6 {
                    let prompt = rng.range(1, 100) as usize;
                    let max_new = rng.range(0, 40) as usize;
                    let padded = prompt.next_power_of_two().min(128);
                    if let Some(c) = m.admit(padded, prompt, max_new) {
                        for b in &c.blocks {
                            assert!(owned.insert(*b), "double allocation of {b}");
                        }
                        live.push(c);
                    }
                } else if !live.is_empty() {
                    let i = rng.below(live.len() as u64) as usize;
                    let c = live.swap_remove(i);
                    for b in &c.blocks {
                        owned.remove(b);
                    }
                    m.release(c);
                }
                // Conservation: free + owned == usable pool (no prefix
                // reuse on this path, so nothing is ever parked).
                assert_eq!(m.free_blocks() + owned.len(), 63);
            }
        });
    }

    /// Randomized admit_reuse/release/pressure interleavings: a block is
    /// never freed or evicted while referenced, the evictable set never
    /// holds a referenced block, and the pool conserves.
    #[test]
    fn prop_refcount_and_eviction_invariants() {
        run_prop("kv-prefix-invariants", 0xCAFE, 150, |rng: &mut Rng| {
            let mut m = KvManager::new(cfg());
            let mut live: Vec<(Vec<u32>, SeqCache)> = vec![];
            // A small universe of prompt streams so shares actually occur.
            let tags: Vec<u32> = (0..4).map(|_| rng.below(1 << 20) as u32).collect();
            for _ in 0..80 {
                if rng.f64() < 0.55 {
                    let tag = tags[rng.below(tags.len() as u64) as usize];
                    let len = 1 + rng.below(120) as usize;
                    let toks = super::tests::prompt(tag, len);
                    let suffix = len - m.match_prefix(&toks).tokens;
                    let padded = suffix.next_power_of_two().min(128);
                    let max_new = rng.below(16) as usize;
                    if let Some(c) = m.admit_reuse(&toks, padded, max_new) {
                        // Every block this sequence holds is referenced.
                        for b in &c.blocks {
                            assert!(m.refcount[*b as usize] > 0);
                        }
                        // Most prefills succeed and commit their blocks
                        // to the index; ~10% fail and publish nothing.
                        if rng.f64() < 0.9 {
                            m.index_prompt(&c, &toks);
                        }
                        live.push((toks, c));
                    }
                } else if !live.is_empty() {
                    let i = rng.below(live.len() as u64) as usize;
                    let (_, c) = live.swap_remove(i);
                    m.release(c);
                }
                m.check_invariants();
                // No live sequence's block is in the free list or the
                // evictable set (invariant 1 and 2, from the outside).
                for (_, c) in &live {
                    for b in &c.blocks {
                        assert!(!m.free.contains(b), "live block {b} on the free list");
                        assert!(
                            !m.evictable.values().any(|e| e == b),
                            "live block {b} in the evictable set"
                        );
                    }
                }
            }
        });
    }

    /// Hash-chain lookups never match across differing token content,
    /// for random shared-prefix/fork-point layouts.
    #[test]
    fn prop_match_respects_content() {
        run_prop("kv-prefix-content", 0xD00D, 200, |rng: &mut Rng| {
            let mut m = KvManager::new(cfg());
            let len = 33 + rng.below(80) as usize; // >= 2 full blocks
            let toks = super::tests::prompt(rng.below(1 << 16) as u32, len);
            let c = m.admit_reuse(&toks, len.next_power_of_two().min(128), 2).unwrap();
            m.index_prompt(&c, &toks);
            // Fork at a random position: the match must stop at (or
            // before) the block containing the first differing token.
            let pos = rng.below(len as u64) as usize;
            let mut forked = toks.clone();
            forked[pos] = forked[pos].wrapping_add(1 + rng.below(1000) as u32);
            let matched = m.match_prefix(&forked).tokens;
            let bs = m.config().block_size;
            assert!(
                matched <= (pos / bs) * bs,
                "match of {matched} tokens crosses the fork at {pos}"
            );
            // And the matched region is genuinely identical content.
            assert_eq!(forked[..matched], toks[..matched]);
            m.release(c);
        });
    }

    /// Admit-with-hit + release roundtrips restore the pool exactly:
    /// no block leaks into limbo, shares fully unwind.
    #[test]
    fn prop_hit_release_roundtrip_restores_pool() {
        run_prop("kv-prefix-roundtrip", 0xF00D, 150, |rng: &mut Rng| {
            let mut m = KvManager::new(cfg());
            let toks = super::tests::prompt(rng.below(1 << 16) as u32, 32 + rng.below(90) as usize);
            let a = m.admit_reuse(&toks, toks.len().next_power_of_two().min(128), 4).unwrap();
            m.index_prompt(&a, &toks);
            let total = |m: &KvManager| m.free_blocks() + m.evictable_blocks();
            let baseline = total(&m); // pool minus a's referenced blocks
            // Layer a random number of sharers on top, then unwind.
            let n = 1 + rng.below(4) as usize;
            let mut sharers = vec![];
            for _ in 0..n {
                let suffix = toks.len() - m.match_prefix(&toks).tokens;
                if let Some(c) = m.admit_reuse(&toks, suffix.next_power_of_two().min(128), 4) {
                    sharers.push(c);
                }
            }
            for c in sharers {
                m.release(c);
            }
            assert_eq!(total(&m), baseline, "sharer roundtrip must restore the pool");
            m.release(a);
            assert_eq!(total(&m), 63, "full release restores the whole pool");
            m.check_invariants();
        });
    }
}
