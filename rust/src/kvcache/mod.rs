//! Paged KV-cache management (paper §4.2), GPU-resident: the block pool
//! itself is a device buffer owned by the executor; this module manages
//! its *metadata* — the free list, per-request block tables, and the
//! admission reservation — all living in "persistent GPU memory" (state
//! owned by the scheduler thread, surviving graph re-instantiation).
//!
//! Admission policy: full reservation. A request is admitted only if
//! `ceil(max(padded_prompt, prompt + max_new) / block_size)` blocks are
//! free, so decode can never hit a mid-flight OOM (no preemption-by-OOM
//! path; DECODE_PAUSED is reserved for continuous-batching pauses, as in
//! the paper). The reservation covers padded prefill positions because
//! the prefill graph writes K/V for every padded slot (see
//! python/compile/model.py).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvConfig {
    pub block_size: usize,
    pub num_blocks: usize,
    pub max_blocks_per_seq: usize,
}

impl KvConfig {
    pub fn blocks_needed(&self, padded_prompt: usize, prompt: usize, max_new: usize) -> usize {
        let span = padded_prompt.max(prompt + max_new);
        span.div_ceil(self.block_size)
    }
}

/// Per-request cache state: the ordered blocks backing the sequence.
#[derive(Debug, Clone)]
pub struct SeqCache {
    pub blocks: Vec<u32>,
    /// Tokens currently cached (prompt after prefill, +1 per decode step).
    pub cached_len: usize,
}

impl SeqCache {
    /// The fixed-shape block-table row the AOT graphs take: `max_blocks`
    /// entries, reserved blocks first, padded with block 0 (never touched
    /// within the reservation span; the attention kernel masks by length).
    pub fn table_row(&self, max_blocks: usize) -> Vec<i32> {
        let mut row = vec![0i32; max_blocks];
        for (i, b) in self.blocks.iter().take(max_blocks).enumerate() {
            row[i] = *b as i32;
        }
        row
    }
}

/// Block pool metadata manager.
pub struct KvManager {
    config: KvConfig,
    free: Vec<u32>,
    /// High-water mark of simultaneously allocated blocks (telemetry).
    pub peak_in_use: usize,
}

impl KvManager {
    pub fn new(config: KvConfig) -> KvManager {
        // LIFO free list; block 0 is kept as the shared pad target and
        // never handed out, matching the table_row padding convention.
        let free: Vec<u32> = (1..config.num_blocks as u32).rev().collect();
        KvManager { config, free, peak_in_use: 0 }
    }

    pub fn config(&self) -> KvConfig {
        self.config
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn in_use(&self) -> usize {
        (self.config.num_blocks - 1) - self.free.len()
    }

    /// Can a request with these dimensions be admitted right now?
    pub fn can_admit(&self, padded_prompt: usize, prompt: usize, max_new: usize) -> bool {
        let need = self.config.blocks_needed(padded_prompt, prompt, max_new);
        need <= self.config.max_blocks_per_seq && need <= self.free.len()
    }

    /// Reserve the full block span for a request. Returns None if the
    /// pool cannot satisfy it (caller applies backpressure).
    pub fn admit(&mut self, padded_prompt: usize, prompt: usize, max_new: usize) -> Option<SeqCache> {
        if !self.can_admit(padded_prompt, prompt, max_new) {
            return None;
        }
        let need = self.config.blocks_needed(padded_prompt, prompt, max_new);
        let blocks: Vec<u32> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Some(SeqCache { blocks, cached_len: 0 })
    }

    /// Return a finished request's blocks to the pool.
    pub fn release(&mut self, cache: SeqCache) {
        for b in cache.blocks {
            debug_assert!(!self.free.contains(&b), "double free of block {b}");
            self.free.push(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn cfg() -> KvConfig {
        KvConfig { block_size: 16, num_blocks: 64, max_blocks_per_seq: 8 }
    }

    #[test]
    fn blocks_needed_covers_padding() {
        let c = cfg();
        // prompt 17 padded to 32, 2 new tokens: span = max(32, 19) = 32 -> 2
        assert_eq!(c.blocks_needed(32, 17, 2), 2);
        // long generation dominates: max(32, 17+100)=117 -> 8
        assert_eq!(c.blocks_needed(32, 17, 100), 8);
        assert_eq!(c.blocks_needed(16, 16, 0), 1);
        assert_eq!(c.blocks_needed(16, 16, 1), 2);
    }

    #[test]
    fn admit_and_release_roundtrip() {
        let mut m = KvManager::new(cfg());
        let before = m.free_blocks();
        let c = m.admit(32, 20, 40).unwrap(); // span 60 -> 4 blocks
        assert_eq!(c.blocks.len(), 4);
        assert_eq!(m.free_blocks(), before - 4);
        m.release(c);
        assert_eq!(m.free_blocks(), before);
    }

    #[test]
    fn rejects_over_long_sequences() {
        let mut m = KvManager::new(cfg());
        // 9 blocks needed > max_blocks_per_seq 8
        assert!(m.admit(16, 16, 128).is_none());
    }

    #[test]
    fn backpressure_when_pool_exhausted() {
        let mut m = KvManager::new(cfg());
        let mut held = vec![];
        // 63 usable blocks; each request takes 8.
        for _ in 0..7 {
            held.push(m.admit(128, 128, 0).unwrap());
        }
        assert_eq!(m.free_blocks(), 63 - 56);
        assert!(m.admit(128, 128, 0).is_none(), "must refuse, 7 < 8 free");
        m.release(held.pop().unwrap());
        assert!(m.admit(128, 128, 0).is_some());
    }

    #[test]
    fn table_row_pads_with_zero() {
        let c = SeqCache { blocks: vec![5, 9], cached_len: 20 };
        assert_eq!(c.table_row(4), vec![5, 9, 0, 0]);
    }

    #[test]
    fn block_zero_never_allocated() {
        // Drain the whole pool; block 0 (the pad target) must never be
        // handed out and no block may be handed out twice.
        let mut m = KvManager::new(cfg());
        let mut seen = std::collections::HashSet::new();
        while let Some(c) = m.admit(16, 16, 0) {
            for b in &c.blocks {
                assert_ne!(*b, 0);
                assert!(seen.insert(*b), "block {b} handed out twice");
            }
        }
        assert_eq!(seen.len(), 63);
    }

    #[test]
    fn prop_alloc_free_never_double_allocates() {
        run_prop("kv-alloc-unique", 0xBEEF, 200, |rng: &mut Rng| {
            let mut m = KvManager::new(cfg());
            let mut live: Vec<SeqCache> = vec![];
            let mut owned = std::collections::HashSet::new();
            for _ in 0..100 {
                if rng.f64() < 0.6 {
                    let prompt = rng.range(1, 100) as usize;
                    let max_new = rng.range(0, 40) as usize;
                    let padded = prompt.next_power_of_two().min(128);
                    if let Some(c) = m.admit(padded, prompt, max_new) {
                        for b in &c.blocks {
                            assert!(owned.insert(*b), "double allocation of {b}");
                        }
                        live.push(c);
                    }
                } else if !live.is_empty() {
                    let i = rng.below(live.len() as u64) as usize;
                    let c = live.swap_remove(i);
                    for b in &c.blocks {
                        owned.remove(b);
                    }
                    m.release(c);
                }
                // Conservation: free + owned == usable pool.
                assert_eq!(m.free_blocks() + owned.len(), 63);
            }
        });
    }
}
