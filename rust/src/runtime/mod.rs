//! PJRT runtime: load AOT artifacts (`artifacts/<model>/`), compile every
//! HLO-text graph once at startup (the paper's host-assisted
//! *initialization* phase), and execute graphs from the device plane with
//! the KV pool held device-resident across steps.
//!
//! Thread model: `Engine` is intentionally `!Send` (PJRT handles are raw
//! pointers). The device plane (`crate::gpu::executor`) owns the one
//! `Engine`; after initialization the host thread never touches it —
//! which is precisely Blink's "host exits the inference path" property,
//! enforced here by the type system.

pub mod manifest;

pub use manifest::{GraphEntry, ModelManifest};

use anyhow::{anyhow, bail, Context, Result};
use xla::FromRawBytes;
use std::path::{Path, PathBuf};

use crate::graphs::{GraphCache, GraphId, GraphKind, GraphSpec};

/// Compiled model: weights on device, one executable per (batch, seq)
/// graph, the graph-cache selection structure, and the device-resident KV
/// pool.
pub struct Engine {
    pub manifest: ModelManifest,
    pub cache: GraphCache,
    client: xla::PjRtClient,
    /// Device-resident weights, in manifest parameter order.
    params: Vec<xla::PjRtBuffer>,
    /// One compiled executable per `GraphId` (same order as cache specs).
    executables: Vec<xla::PjRtLoadedExecutable>,
    /// The KV block pool, replaced by each graph execution's output.
    kv: xla::PjRtBuffer,
    /// Executions since start (telemetry).
    pub steps: u64,
}

impl Engine {
    /// Load manifest + weights + all graphs for `model` under `artifacts`.
    pub fn load(artifacts: &Path, model: &str) -> Result<Engine> {
        let dir = artifacts.join(model);
        let manifest = ModelManifest::load(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest for {model}"))?;
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;

        // Weights: npz straight to device buffers.
        let names: Vec<&str> = manifest.params.iter().map(|p| p.0.as_str()).collect();
        let params = xla::PjRtBuffer::read_npz_by_name(dir.join("params.npz"), &client, &names)
            .map_err(wrap_xla)?;

        // Compile every graph in the manifest grid.
        let mut specs = Vec::new();
        let mut executables = Vec::new();
        for (i, g) in manifest.graphs.iter().enumerate() {
            let path = dir.join(format!("{}.hlo.txt", g.name));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(wrap_xla)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap_xla)?;
            executables.push(exe);
            specs.push(GraphSpec {
                id: GraphId(i),
                name: g.name.clone(),
                kind: GraphKind::from_manifest(&g.kind),
                batch: g.batch,
                seq: g.seq,
            });
        }
        let cache = GraphCache::new(specs);

        // Zero-initialized KV pool on device.
        let kv = Self::fresh_kv(&client, &manifest)?;
        Ok(Engine { manifest, cache, client, params, executables, kv, steps: 0 })
    }

    fn fresh_kv(client: &xla::PjRtClient, m: &ModelManifest) -> Result<xla::PjRtBuffer> {
        let dims = [
            m.n_layers,
            m.num_blocks,
            2,
            m.n_kv_heads,
            m.block_size,
            m.d_head,
        ];
        let n: usize = dims.iter().product();
        let zeros = vec![0f32; n];
        let dims_u: Vec<usize> = dims.to_vec();
        client
            .buffer_from_host_buffer(&zeros, &dims_u, None)
            .map_err(wrap_xla)
    }

    /// Drop all KV state (between benchmark phases).
    pub fn reset_kv(&mut self) -> Result<()> {
        self.kv = Self::fresh_kv(&self.client, &self.manifest)?;
        Ok(())
    }

    /// Execute one graph. `tokens` is `[B]` for decode, `[B*S]`
    /// row-major for prefill, or `[B*(k+1)]` row-major for decode
    /// verify; `block_tables` is `[B * max_blocks_per_seq]` row-major;
    /// `seq_lens` is `[B]`. `offsets` is `[B]` for offset prefill
    /// graphs (per-lane cached-prefix lengths) and must be empty for
    /// every other kind. Returns the sampled tokens — `[B]`, or
    /// `[B*(k+1)]` row-major for decode verify (one successor per
    /// window position).
    ///
    /// The KV pool is passed as a device buffer and swapped for the
    /// output's pool element — no host copy of cache state, the analogue
    /// of the paper's persistent GPU memory surviving each graph launch.
    pub fn execute(
        &mut self,
        id: GraphId,
        block_tables: &[i32],
        seq_lens: &[i32],
        tokens: &[i32],
        offsets: &[i32],
        seed: u32,
    ) -> Result<Vec<i32>> {
        let spec = self.cache.spec(id).clone();
        let b = spec.batch;
        let m = self.manifest.max_blocks_per_seq;
        if let Err(e) = spec.validate_launch_shapes(
            m,
            block_tables.len(),
            seq_lens.len(),
            tokens.len(),
            offsets.len(),
        ) {
            bail!("{e}");
        }

        let c = &self.client;
        let bt = c
            .buffer_from_host_buffer(block_tables, &[b, m], None)
            .map_err(wrap_xla)?;
        let sl = c.buffer_from_host_buffer(seq_lens, &[b], None).map_err(wrap_xla)?;
        let tok = match spec.kind {
            GraphKind::Decode => c.buffer_from_host_buffer(tokens, &[b], None),
            GraphKind::Prefill | GraphKind::PrefillOffset => {
                c.buffer_from_host_buffer(tokens, &[b, spec.seq], None)
            }
            // Verify graphs take the [B, k+1] draft window; spec.seq
            // records k.
            GraphKind::DecodeVerify => {
                c.buffer_from_host_buffer(tokens, &[b, spec.seq + 1], None)
            }
        }
        .map_err(wrap_xla)?;
        let off_b = if spec.kind == GraphKind::PrefillOffset {
            Some(c.buffer_from_host_buffer(offsets, &[b], None).map_err(wrap_xla)?)
        } else {
            None
        };
        let seed_b = c
            .buffer_from_host_buffer(&[seed], &[] as &[usize], None)
            .map_err(wrap_xla)?;

        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&self.kv);
        args.push(&bt);
        args.push(&sl);
        args.push(&tok);
        if let Some(off) = off_b.as_ref() {
            args.push(off);
        }
        args.push(&seed_b);

        let mut out = self.executables[id.0].execute_b_untupled(&args).map_err(wrap_xla)?;
        let replica = out.pop().ok_or_else(|| anyhow!("no replica outputs"))?;
        let mut it = replica.into_iter();
        let (next_tokens_buf, kv_out) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("expected 2 outputs (tokens, kv)"),
        };
        // Swap in the new pool; the old buffer drops (freed on device).
        self.kv = kv_out;
        self.steps += 1;

        let lit = next_tokens_buf.to_literal_sync().map_err(wrap_xla)?;
        let toks: Vec<i32> = lit.to_vec().map_err(wrap_xla)?;
        Ok(toks)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// Locate the artifacts directory: $BLINK_ARTIFACTS, ./artifacts, or the
/// crate-root artifacts dir (tests run from the workspace root).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("BLINK_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need artifacts live in rust/tests/ (integration);
    // here we only test pure helpers.
    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(!d.as_os_str().is_empty());
    }
}
