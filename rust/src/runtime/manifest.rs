//! Parser for `artifacts/<model>/manifest.txt` (written by
//! python/compile/aot.py). Line-based format; see aot.py for the schema.
//! The manifest is the single source of truth for model geometry shared
//! between the AOT graphs and the rust coordinator.

use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct GraphEntry {
    pub name: String,
    pub kind: String, // "decode" | "prefill" | "prefill_offset" | "decode_verify"
    pub batch: usize,
    pub seq: usize,
    /// Attention build the graph was lowered against, recorded by
    /// aot.py as a trailing token ("pallas" kernels vs the jnp "ref"
    /// oracles); "unspecified" for manifests written before the token
    /// existed. Surfaced through `/metrics` and the eval CSVs so a
    /// serving process states which attention implementation it runs.
    pub backend: String,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub model: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub block_size: usize,
    pub num_blocks: usize,
    pub max_blocks_per_seq: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub eos_token: u32,
    pub moe: bool,
    pub temperature: f64,
    pub top_p: f64,
    pub rope_theta: f64,
    /// (name, dims) in graph-argument order.
    pub params: Vec<(String, Vec<usize>)>,
    pub graphs: Vec<GraphEntry>,
}

impl ModelManifest {
    pub fn load(path: &Path) -> Result<ModelManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ModelManifest> {
        let mut lines = text.lines();
        match lines.next() {
            Some("blink-manifest v1") => {}
            other => bail!("bad manifest header: {other:?}"),
        }
        let mut m = ModelManifest {
            model: String::new(),
            vocab_size: 0,
            d_model: 0,
            n_layers: 0,
            n_heads: 0,
            n_kv_heads: 0,
            d_head: 0,
            d_ff: 0,
            block_size: 0,
            num_blocks: 0,
            max_blocks_per_seq: 0,
            n_experts: 0,
            top_k: 0,
            eos_token: 0,
            moe: false,
            temperature: 0.8,
            top_p: 0.95,
            rope_theta: 10000.0,
            params: vec![],
            graphs: vec![],
        };
        for line in lines {
            let mut it = line.split_whitespace();
            let Some(key) = it.next() else { continue };
            let mut val = || -> Result<&str> {
                it.next().context("missing value").with_context(|| format!("line: {line}"))
            };
            match key {
                "model" => m.model = val()?.to_string(),
                "vocab_size" => m.vocab_size = val()?.parse()?,
                "d_model" => m.d_model = val()?.parse()?,
                "n_layers" => m.n_layers = val()?.parse()?,
                "n_heads" => m.n_heads = val()?.parse()?,
                "n_kv_heads" => m.n_kv_heads = val()?.parse()?,
                "d_head" => m.d_head = val()?.parse()?,
                "d_ff" => m.d_ff = val()?.parse()?,
                "block_size" => m.block_size = val()?.parse()?,
                "num_blocks" => m.num_blocks = val()?.parse()?,
                "max_blocks_per_seq" => m.max_blocks_per_seq = val()?.parse()?,
                "n_experts" => m.n_experts = val()?.parse()?,
                "top_k" => m.top_k = val()?.parse()?,
                "eos_token" => m.eos_token = val()?.parse()?,
                "moe" => m.moe = val()? == "1",
                "temperature" => m.temperature = val()?.parse()?,
                "top_p" => m.top_p = val()?.parse()?,
                "rope_theta" => m.rope_theta = val()?.parse()?,
                "param" => {
                    let name = val()?.to_string();
                    let dims: Vec<usize> = val()?
                        .split('x')
                        .map(|d| d.parse::<usize>())
                        .collect::<std::result::Result<_, _>>()?;
                    m.params.push((name, dims));
                }
                "graph" => {
                    let name = val()?.to_string();
                    let kind = val()?.to_string();
                    // Reject unknown kinds here, at load time: the four
                    // kinds have different launch signatures (offset
                    // prefill takes an extra [B] offsets argument, verify
                    // tokens are [B, k+1]), so a typo'd kind silently
                    // defaulting to "prefill" would surface only as
                    // runtime arg-count failures.
                    if !matches!(
                        kind.as_str(),
                        "decode" | "prefill" | "prefill_offset" | "decode_verify"
                    ) {
                        bail!("unknown graph kind {kind:?} for graph {name}");
                    }
                    let batch = val()?.parse()?;
                    let seq = val()?.parse()?;
                    // Optional trailing backend token (newer aot.py);
                    // absent in older artifacts.
                    let backend = val()
                        .map(|s| s.to_string())
                        .unwrap_or_else(|_| "unspecified".to_string());
                    m.graphs.push(GraphEntry { name, kind, batch, seq, backend });
                }
                _ => {} // forward-compatible: ignore unknown keys
            }
        }
        if m.model.is_empty() || m.params.is_empty() || m.graphs.is_empty() {
            bail!("incomplete manifest");
        }
        if m.vocab_size == 0 || m.block_size == 0 || m.num_blocks == 0 {
            bail!("missing geometry in manifest");
        }
        Ok(m)
    }

    /// Max context = block span of one sequence.
    pub fn max_context(&self) -> usize {
        self.block_size * self.max_blocks_per_seq
    }

    /// The attention backend the artifacts were lowered against:
    /// "pallas" / "ref" when every graph agrees (the normal export),
    /// "mixed" when graphs disagree (hand-assembled artifacts), and
    /// "unspecified" for manifests predating the per-graph token.
    pub fn attention_backend(&self) -> &str {
        let first = match self.graphs.first() {
            Some(g) => g.backend.as_str(),
            None => return "unspecified",
        };
        if self.graphs.iter().all(|g| g.backend == first) {
            first
        } else {
            "mixed"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
blink-manifest v1
model blink-tiny
vocab_size 2048
d_model 256
n_layers 4
n_heads 8
n_kv_heads 4
d_head 32
d_ff 704
block_size 16
num_blocks 512
max_blocks_per_seq 32
n_experts 4
top_k 2
eos_token 0
moe 0
temperature 0.8
top_p 0.95
rope_theta 10000.0
param tok_embed 2048x256 f32
param final_norm 256 f32
graph decode_b1 decode 1 0 pallas
graph prefill_b2_s32 prefill 2 32 pallas
graph prefill_offset_b2_s32 prefill_offset 2 32 pallas
graph decode_verify_b1_k4 decode_verify 1 4 pallas
";

    #[test]
    fn parses_sample() {
        let m = ModelManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model, "blink-tiny");
        assert_eq!(m.vocab_size, 2048);
        assert!(!m.moe);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0], ("tok_embed".to_string(), vec![2048, 256]));
        assert_eq!(m.graphs.len(), 4);
        assert_eq!(
            m.graphs[1],
            GraphEntry {
                name: "prefill_b2_s32".into(),
                kind: "prefill".into(),
                batch: 2,
                seq: 32,
                backend: "pallas".into()
            }
        );
        // Offset prefill graphs ride the same schema with their own kind.
        assert_eq!(
            m.graphs[2],
            GraphEntry {
                name: "prefill_offset_b2_s32".into(),
                kind: "prefill_offset".into(),
                batch: 2,
                seq: 32,
                backend: "pallas".into()
            }
        );
        // Verify graphs record k (the draft count) in the seq slot.
        assert_eq!(
            m.graphs[3],
            GraphEntry {
                name: "decode_verify_b1_k4".into(),
                kind: "decode_verify".into(),
                batch: 1,
                seq: 4,
                backend: "pallas".into()
            }
        );
        assert_eq!(m.max_context(), 512);
        assert_eq!(m.attention_backend(), "pallas");
    }

    #[test]
    fn backend_token_is_optional_and_summarized() {
        // Pre-backend manifests (no trailing token) parse and report
        // "unspecified"; a ref export reports "ref"; disagreeing
        // graphs report "mixed".
        let legacy = SAMPLE.replace(" pallas", "");
        let m = ModelManifest::parse(&legacy).unwrap();
        assert_eq!(m.graphs[0].backend, "unspecified");
        assert_eq!(m.attention_backend(), "unspecified");

        let refs = SAMPLE.replace(" pallas", " ref");
        assert_eq!(ModelManifest::parse(&refs).unwrap().attention_backend(), "ref");

        let mixed = SAMPLE.replace("decode 1 0 pallas", "decode 1 0 ref");
        assert_eq!(ModelManifest::parse(&mixed).unwrap().attention_backend(), "mixed");
    }

    #[test]
    fn rejects_bad_header() {
        assert!(ModelManifest::parse("nope\n").is_err());
    }

    #[test]
    fn rejects_unknown_graph_kind() {
        let bad = SAMPLE.replace("prefill_offset 2 32", "prefil_offset 2 32");
        let err = ModelManifest::parse(&bad).unwrap_err();
        assert!(format!("{err}").contains("unknown graph kind"), "{err}");
    }

    #[test]
    fn rejects_incomplete() {
        assert!(ModelManifest::parse("blink-manifest v1\nmodel x\n").is_err());
    }
}
