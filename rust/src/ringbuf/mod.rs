//! The GPU-resident ring buffer (paper §4.2 "Ring buffer").
//!
//! The *only* shared data structure between the DPU frontend and the GPU
//! backend, and the sole rendezvous point of the two planes. It lives in
//! "GPU memory" (one allocation owned by the backend process image) and is
//! accessed by the frontend exclusively through one-sided RDMA ops
//! (`crate::rdma`), never through host-mediated coordination.
//!
//! Layout: a fixed set of [`Slot`]s (default 4096) plus shared token
//! arenas for prompt and generated tokens. Each slot records per-request
//! metadata and offsets into the arenas. The scheduler advances slots
//! through the lifecycle FSM
//!
//! ```text
//! EMPTY → FRONTEND_WRITING → PREFILL_PENDING → PREFILL_PROCESSING
//!       → DECODE_PROCESSING (⇄ DECODE_PAUSED) → DECODE_COMPLETED → EMPTY
//! ```
//!
//! Ownership and state transitions use atomic compare-and-swap; token
//! publication uses release stores on the generation counter so that
//! RDMA-visible updates become visible in the intended order. Benign
//! races (e.g. the token reader observing a count before the final state
//! flip) are tolerated by construction, exactly as the paper describes.

pub mod slot;

pub use slot::{Slot, SlotState};

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Geometry defaults mirror the paper: 4096 slots, scanned in full in
/// 1–5 µs by the persistent scheduler.
pub const DEFAULT_SLOTS: usize = 4096;

#[derive(Debug, Clone, Copy)]
pub struct RingConfig {
    pub num_slots: usize,
    /// Per-slot capacity of the input (prompt) arena region, tokens.
    pub max_prompt: usize,
    /// Per-slot capacity of the output arena region, tokens.
    pub max_output: usize,
}

impl Default for RingConfig {
    fn default() -> Self {
        RingConfig { num_slots: DEFAULT_SLOTS, max_prompt: 512, max_output: 512 }
    }
}

/// Everything the frontend publishes alongside a prompt. `priority` and
/// `ttft_budget_us` are the request-class fields threaded end-to-end
/// (HTTP body → frontend → RDMA `Submit` → slot → admission policy →
/// per-class eval percentiles); `session_id` tags multi-turn
/// conversations for the prefix-reuse path (DESIGN.md §7).
#[derive(Debug, Clone, Copy)]
pub struct SubmitMeta {
    pub request_id: u64,
    pub prompt_len: u32,
    pub max_new: u32,
    pub seed: u32,
    /// Higher = more important; 0 = batch/default.
    pub priority: u32,
    /// Relative TTFT budget in µs; 0 = no deadline.
    pub ttft_budget_us: u64,
    /// Conversation-session tag; 0 = no session.
    pub session_id: u64,
}

/// The shared ring buffer. `Sync`: every field is atomic; the access
/// protocol (FSM above) provides the logical exclusion.
pub struct RingBuffer {
    pub config: RingConfig,
    slots: Vec<Slot>,
    /// Input-token arena: slot i owns `[i*max_prompt, (i+1)*max_prompt)`.
    // lint: atomic(input_arena) plane # token cells; the write_prompt
    // release fence / read-side acquire edge orders them, not the cells.
    input_arena: Vec<AtomicU32>,
    /// Output-token arena: slot i owns `[i*max_output, (i+1)*max_output)`.
    // lint: atomic(output_arena) plane # token cells published by the
    // `generated` Release store, observed through its Acquire load.
    output_arena: Vec<AtomicU32>,
    /// Approximate count of PREFILL_PENDING slots — a doorbell the
    /// scheduler checks before paying for a full scan.
    // lint: atomic(pending_hint) observe=Acquire rmw=AcqRel # the doorbell
    // is a hint, but its AcqRel edges keep it from drifting ahead of the
    // state words it summarizes.
    pending_hint: AtomicU32,
    /// Monotone submission ticket used for FCFS ordering across slots.
    // lint: atomic(ticket) publish=Relaxed observe=Relaxed rmw=AcqRel
    // # the global ticket counter (AcqRel fetch_add in RingBuffer) and the
    // per-slot stamp share this contract; the stamp itself rides the
    // state-word release edge like the rest of the metadata plane.
    ticket: AtomicU64,
}

impl RingBuffer {
    pub fn new(config: RingConfig) -> Self {
        let slots = (0..config.num_slots).map(|_| Slot::new()).collect();
        let input_arena =
            (0..config.num_slots * config.max_prompt).map(|_| AtomicU32::new(0)).collect();
        let output_arena =
            (0..config.num_slots * config.max_output).map(|_| AtomicU32::new(0)).collect();
        RingBuffer {
            config,
            slots,
            input_arena,
            output_arena,
            pending_hint: AtomicU32::new(0),
            ticket: AtomicU64::new(0),
        }
    }

    pub fn num_slots(&self) -> usize {
        self.config.num_slots
    }

    pub fn slot(&self, i: usize) -> &Slot {
        &self.slots[i]
    }

    /// Frontend half: claim an EMPTY slot for writing (CAS EMPTY →
    /// FRONTEND_WRITING). Returns false if the slot was not empty.
    pub fn claim_for_write(&self, i: usize) -> bool {
        self.slots[i].cas_state(SlotState::Empty, SlotState::FrontendWriting)
    }

    /// Frontend half: publish a fully written prompt, arming the slot for
    /// the scheduler (FRONTEND_WRITING → PREFILL_PENDING, release).
    /// Returns the FCFS ticket assigned to the request. Default class:
    /// priority 0, no TTFT deadline (see [`RingBuffer::submit_with_meta`]).
    pub fn submit(&self, i: usize, request_id: u64, prompt_len: u32, max_new: u32, seed: u32) -> u64 {
        self.submit_with_meta(
            i,
            &SubmitMeta {
                request_id,
                prompt_len,
                max_new,
                seed,
                priority: 0,
                ttft_budget_us: 0,
                session_id: 0,
            },
        )
    }

    /// Full submission path: metadata including the request class the
    /// admission policies rank by. The relative TTFT budget becomes an
    /// absolute deadline stamped against the same clock as
    /// `submit_time_us`, so policy slack math needs no clock exchange
    /// with the frontend.
    // lint: no_alloc no_panic
    pub fn submit_with_meta(&self, i: usize, meta: &SubmitMeta) -> u64 {
        let s = &self.slots[i];
        debug_assert_eq!(s.state(), SlotState::FrontendWriting);
        let ticket = self.ticket.fetch_add(1, Ordering::AcqRel);
        let now = crate::util::timer::now_us();
        s.request_id.store(meta.request_id, Ordering::Relaxed);
        s.prompt_len.store(meta.prompt_len, Ordering::Relaxed);
        s.max_new_tokens.store(meta.max_new, Ordering::Relaxed);
        s.seed.store(meta.seed, Ordering::Relaxed);
        s.priority.store(meta.priority, Ordering::Relaxed);
        s.session_id.store(meta.session_id, Ordering::Relaxed);
        // Saturating: the budget is client-controlled (HTTP body) and a
        // huge value must mean "far future", not a wrapped-tiny deadline.
        let deadline =
            if meta.ttft_budget_us > 0 { now.saturating_add(meta.ttft_budget_us) } else { 0 };
        s.ttft_deadline_us.store(deadline, Ordering::Relaxed);
        s.generated.store(0, Ordering::Relaxed);
        s.read_cursor.store(0, Ordering::Relaxed);
        s.ticket.store(ticket, Ordering::Relaxed);
        s.submit_time_us.store(now, Ordering::Relaxed);
        s.set_state(SlotState::PrefillPending); // release: metadata above is visible
        self.pending_hint.fetch_add(1, Ordering::AcqRel);
        ticket
    }

    /// Scheduler half: claim a pending prompt (CAS PREFILL_PENDING →
    /// PREFILL_PROCESSING).
    // lint: no_alloc no_panic
    pub fn claim_pending(&self, i: usize) -> bool {
        if self.slots[i].cas_state(SlotState::PrefillPending, SlotState::PrefillProcessing) {
            self.pending_hint.fetch_sub(1, Ordering::AcqRel);
            true
        } else {
            false
        }
    }

    /// Cheap doorbell: non-zero if *some* slot is probably pending.
    pub fn pending_hint(&self) -> u32 {
        self.pending_hint.load(Ordering::Acquire)
    }

    /// Scheduler half: overlapped scan *without* claiming — returns
    /// PREFILL_PENDING slots in FCFS ticket order. The scheduler inspects
    /// candidates' metadata (prompt length → KV admission) before deciding
    /// which to claim, so backpressure never needs an un-claim transition.
    ///
    /// Convenience wrapper over [`RingBuffer::scan_pending_into`] for
    /// tests and benches; the scheduler's hot loop uses the scratch
    /// variant. (This signature used to take a `lanes` parameter it
    /// ignored — the lane decomposition of the GPU scan is contiguous
    /// ranges, which on a CPU is exactly the linear sweep below, so the
    /// parameter promised a decomposition the code never performed and
    /// has been dropped. [`RingBuffer::scan_and_claim`] still takes
    /// `lanes` and really walks the ranges.)
    pub fn scan_pending(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.scan_pending_into(&mut out);
        out
    }

    /// Allocation-free overlapped scan: fill the caller's scratch with
    /// the PREFILL_PENDING slot indices in FCFS ticket order (cleared
    /// first; sorted in place, no temporaries). §Perf: this path went
    /// from ~5 µs p50 (acquire loads, tuple collect + sort) to the paper
    /// envelope by scanning relaxed, sorting only when more than one
    /// candidate is found — and it stops heap-allocating entirely now
    /// that the scratch persists across iterations. The sort re-reads
    /// each candidate's ticket (relaxed load) instead of materializing
    /// (ticket, slot) pairs; the single scheduler thread is the only
    /// claimer, so tickets are stable for the duration.
    // lint: no_alloc no_panic # `out.push` reuses persistent scratch
    // capacity; the hotloop_alloc runtime pin covers the reallocation case
    // this syntactic pass cannot see.
    pub fn scan_pending_into(&self, out: &mut Vec<usize>) {
        out.clear();
        for (i, slot) in self.slots.iter().enumerate() {
            if slot.state_relaxed() == SlotState::PrefillPending {
                out.push(i);
            }
        }
        if out.len() > 1 {
            out.sort_unstable_by_key(|&i| self.slots[i].ticket.load(Ordering::Relaxed));
        }
    }

    /// Scheduler half: full parallel-style scan. Walks all slots in
    /// `lanes` disjoint contiguous ranges (the paper's 256 scheduler
    /// threads), claiming up to `max_claim` pending slots. Returns claimed
    /// indices in FCFS ticket order.
    pub fn scan_and_claim(&self, lanes: usize, max_claim: usize) -> Vec<usize> {
        let n = self.num_slots();
        let mut found: Vec<(u64, usize)> = Vec::new();
        let chunk = n.div_ceil(lanes.max(1));
        // Single execution context emulating the lane sweep: disjoint
        // contiguous ranges, identical claim protocol (atomic CAS).
        for lane in 0..lanes.max(1) {
            let lo = lane * chunk;
            let hi = (lo + chunk).min(n);
            for i in lo..hi {
                if self.slots[i].state() == SlotState::PrefillPending {
                    found.push((self.slots[i].ticket.load(Ordering::Relaxed), i));
                }
            }
        }
        found.sort_unstable();
        let mut claimed = Vec::new();
        for (_, i) in found {
            if claimed.len() >= max_claim {
                break;
            }
            if self.claim_pending(i) {
                claimed.push(i);
            }
        }
        claimed
    }

    // --- token arenas -----------------------------------------------------

    /// Byte offset of slot `i`'s input region (recorded in metadata to
    /// mirror the paper's arena-offset scheme; the RDMA engine targets it).
    pub fn input_region(&self, i: usize) -> (usize, usize) {
        (i * self.config.max_prompt, self.config.max_prompt)
    }

    pub fn output_region(&self, i: usize) -> (usize, usize) {
        (i * self.config.max_output, self.config.max_output)
    }

    /// Frontend half (via RDMA WRITE): stage prompt tokens.
    pub fn write_prompt(&self, i: usize, tokens: &[u32]) {
        let (base, cap) = self.input_region(i);
        assert!(tokens.len() <= cap, "prompt longer than arena region");
        for (j, t) in tokens.iter().enumerate() {
            self.input_arena[base + j].store(*t, Ordering::Relaxed);
        }
        // Release fence: arena contents happen-before the PREFILL_PENDING
        // flip in `submit` (which is itself a release store).
        std::sync::atomic::fence(Ordering::Release);
    }

    /// Scheduler half: read a claimed prompt.
    pub fn read_prompt(&self, i: usize) -> Vec<u32> {
        // Relaxed: the claim CAS (AcqRel) already ordered this read after
        // the frontend's release publish. The Acquire this load used to
        // carry paired with nothing — `prompt_len` is stored Relaxed, so
        // it created no edge, just the appearance of one.
        let len = self.slots[i].prompt_len.load(Ordering::Relaxed) as usize;
        let (base, cap) = self.input_region(i);
        (0..len.min(cap)).map(|j| self.input_arena[base + j].load(Ordering::Relaxed)).collect()
    }

    /// Scheduler half: publish one generated token (token store happens
    /// before the release bump of `generated`, so any reader that observes
    /// the new count also observes the token — the paper's fence rule).
    // lint: no_alloc no_panic # `assert!` stays: invariant checks are
    // allowed in no_panic regions, unwinding escape hatches are not.
    pub fn publish_token(&self, i: usize, token: u32) -> u32 {
        let s = &self.slots[i];
        let g = s.generated.load(Ordering::Relaxed);
        let (base, cap) = self.output_region(i);
        assert!((g as usize) < cap, "output arena overflow");
        self.output_arena[base + g as usize].store(token, Ordering::Relaxed);
        s.generated.store(g + 1, Ordering::Release);
        if g == 0 {
            s.first_token_time_us.store(crate::util::timer::now_us(), Ordering::Relaxed);
        }
        g + 1
    }

    /// Frontend half (via RDMA READ): read tokens `[from, to)`.
    pub fn read_tokens(&self, i: usize, from: u32, to: u32) -> Vec<u32> {
        let (base, cap) = self.output_region(i);
        let to = (to as usize).min(cap);
        // Acquire on the counter was done by the caller (token reader);
        // pair with the release in publish_token.
        std::sync::atomic::fence(Ordering::Acquire);
        (from as usize..to).map(|j| self.output_arena[base + j].load(Ordering::Relaxed)).collect()
    }

    /// Scheduler half: mark generation finished.
    pub fn complete(&self, i: usize) {
        let s = &self.slots[i];
        s.finish_time_us.store(crate::util::timer::now_us(), Ordering::Relaxed);
        s.set_state(SlotState::DecodeCompleted);
    }

    /// Frontend half: after draining all tokens, recycle the slot.
    pub fn release(&self, i: usize) -> bool {
        self.slots[i].cas_state(SlotState::DecodeCompleted, SlotState::Empty)
    }

    /// Count slots currently in `state` (diagnostics / tests).
    pub fn count_state(&self, state: SlotState) -> usize {
        self.slots.iter().filter(|s| s.state() == state).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn small() -> RingBuffer {
        RingBuffer::new(RingConfig { num_slots: 8, max_prompt: 16, max_output: 16 })
    }

    #[test]
    fn lifecycle_roundtrip() {
        let rb = small();
        assert!(rb.claim_for_write(3));
        assert!(!rb.claim_for_write(3), "double claim must fail");
        rb.write_prompt(3, &[10, 11, 12]);
        rb.submit(3, 77, 3, 8, 42);
        assert_eq!(rb.slot(3).state(), SlotState::PrefillPending);
        assert_eq!(rb.pending_hint(), 1);
        assert!(rb.claim_pending(3));
        assert_eq!(rb.pending_hint(), 0);
        assert_eq!(rb.read_prompt(3), vec![10, 11, 12]);
        rb.slot(3).set_state(SlotState::DecodeProcessing);
        assert_eq!(rb.publish_token(3, 100), 1);
        assert_eq!(rb.publish_token(3, 101), 2);
        assert_eq!(rb.read_tokens(3, 0, 2), vec![100, 101]);
        rb.complete(3);
        assert!(rb.release(3));
        assert_eq!(rb.slot(3).state(), SlotState::Empty);
    }

    #[test]
    fn scan_claims_in_fcfs_ticket_order() {
        let rb = small();
        // Submit to slots in a scrambled order; tickets define FCFS.
        for &i in &[5usize, 1, 7] {
            assert!(rb.claim_for_write(i));
            rb.write_prompt(i, &[1]);
            rb.submit(i, i as u64, 1, 4, 0);
        }
        let claimed = rb.scan_and_claim(4, 10);
        assert_eq!(claimed, vec![5, 1, 7], "ticket order, not slot order");
    }

    #[test]
    fn scan_ignores_priority_metadata_ticket_order_holds() {
        // The ring itself stays FCFS: class metadata rides along for the
        // scheduler's admission policy but never reorders the scan.
        let rb = small();
        for (n, &i) in [6usize, 0, 4, 2].iter().enumerate() {
            assert!(rb.claim_for_write(i));
            rb.write_prompt(i, &[1]);
            let ticket = rb.submit_with_meta(
                i,
                &SubmitMeta {
                    request_id: i as u64,
                    prompt_len: 1,
                    max_new: 4,
                    seed: 0,
                    priority: (3 - n as u32) * 2, // descending, disagrees with tickets
                    ttft_budget_us: if n % 2 == 0 { 50_000 } else { 0 },
                    session_id: n as u64 + 10,
                },
            );
            assert_eq!(ticket, n as u64);
            assert_eq!(rb.slot(i).priority.load(Ordering::Relaxed), (3 - n as u32) * 2);
            assert_eq!(rb.slot(i).session_id.load(Ordering::Relaxed), n as u64 + 10);
        }
        assert_eq!(rb.scan_pending(), vec![6, 0, 4, 2], "ticket order, not priority order");
        assert_eq!(rb.scan_and_claim(4, 10), vec![6, 0, 4, 2]);
    }

    #[test]
    fn submit_meta_stamps_deadline_from_budget() {
        let rb = small();
        assert!(rb.claim_for_write(1));
        rb.write_prompt(1, &[9]);
        rb.submit_with_meta(
            1,
            &SubmitMeta {
                request_id: 7,
                prompt_len: 1,
                max_new: 2,
                seed: 0,
                priority: 5,
                ttft_budget_us: 250_000,
                session_id: 0,
            },
        );
        let s = rb.slot(1);
        let submit = s.submit_time_us.load(Ordering::Relaxed);
        let deadline = s.ttft_deadline_us.load(Ordering::Relaxed);
        assert_eq!(deadline, submit + 250_000);
        // Budget 0 ⇒ deadline 0 (no deadline), via the plain submit path.
        assert!(rb.claim_for_write(2));
        rb.write_prompt(2, &[9]);
        rb.submit(2, 8, 1, 2, 0);
        assert_eq!(rb.slot(2).ttft_deadline_us.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn scan_pending_into_reuses_scratch_and_sorts_by_ticket() {
        let rb = small();
        for &i in &[7usize, 2, 5] {
            assert!(rb.claim_for_write(i));
            rb.write_prompt(i, &[1]);
            rb.submit(i, i as u64, 1, 4, 0);
        }
        let mut scratch = Vec::with_capacity(8);
        rb.scan_pending_into(&mut scratch);
        assert_eq!(scratch, vec![7, 2, 5], "ticket order");
        let cap = scratch.capacity();
        // A second sweep clears, refills, and never reallocates.
        rb.scan_pending_into(&mut scratch);
        assert_eq!(scratch, vec![7, 2, 5]);
        assert_eq!(scratch.capacity(), cap);
        // Claiming drains the scan.
        for &i in &[7usize, 2, 5] {
            assert!(rb.claim_pending(i));
        }
        rb.scan_pending_into(&mut scratch);
        assert!(scratch.is_empty());
    }

    #[test]
    fn scan_respects_max_claim() {
        let rb = small();
        for i in 0..6 {
            assert!(rb.claim_for_write(i));
            rb.write_prompt(i, &[1]);
            rb.submit(i, i as u64, 1, 4, 0);
        }
        let claimed = rb.scan_and_claim(256, 2);
        assert_eq!(claimed.len(), 2);
        assert_eq!(rb.pending_hint(), 4);
    }

    #[test]
    fn concurrent_claim_is_exclusive() {
        let rb = Arc::new(small());
        for i in 0..8 {
            assert!(rb.claim_for_write(i));
            rb.write_prompt(i, &[1]);
            rb.submit(i, i as u64, 1, 4, 0);
        }
        let mut handles = vec![];
        for _ in 0..4 {
            let rb = rb.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = vec![];
                for i in 0..8 {
                    if rb.claim_pending(i) {
                        got.push(i);
                    }
                }
                got
            }));
        }
        let mut all: Vec<usize> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>(), "each slot claimed exactly once");
    }

    #[test]
    fn publish_read_consistency_across_threads() {
        let rb = Arc::new(small());
        assert!(rb.claim_for_write(0));
        rb.write_prompt(0, &[1]);
        rb.submit(0, 1, 1, 16, 0);
        rb.claim_pending(0);
        rb.slot(0).set_state(SlotState::DecodeProcessing);
        let writer = {
            let rb = rb.clone();
            std::thread::spawn(move || {
                for t in 0..16u32 {
                    rb.publish_token(0, 1000 + t);
                }
            })
        };
        // Reader polls like the DPU token reader: count (acquire) then data.
        let mut seen = 0u32;
        let mut toks = vec![];
        while seen < 16 {
            let g = rb.slot(0).generated.load(Ordering::Acquire);
            if g > seen {
                toks.extend(rb.read_tokens(0, seen, g));
                seen = g;
            }
        }
        writer.join().unwrap();
        assert_eq!(toks, (0..16).map(|t| 1000 + t).collect::<Vec<u32>>());
    }
}
