//! Ring-buffer slot: per-request metadata + the lifecycle state machine.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Slot lifecycle states (paper §4.2). `FrontendWriting` is the transient
/// ownership state between the frontend's claim of an EMPTY slot and its
/// PREFILL_PENDING publish (the paper folds this into the RDMA write; we
/// make it explicit so the claim race is CAS-clean).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u32)]
pub enum SlotState {
    Empty = 0,
    FrontendWriting = 1,
    PrefillPending = 2,
    PrefillProcessing = 3,
    DecodeProcessing = 4,
    DecodePaused = 5,
    DecodeCompleted = 6,
    /// Terminal error (bad request, OOM); frontend reports and releases.
    Failed = 7,
}

impl SlotState {
    pub fn from_u32(v: u32) -> SlotState {
        match v {
            0 => SlotState::Empty,
            1 => SlotState::FrontendWriting,
            2 => SlotState::PrefillPending,
            3 => SlotState::PrefillProcessing,
            4 => SlotState::DecodeProcessing,
            5 => SlotState::DecodePaused,
            6 => SlotState::DecodeCompleted,
            _ => SlotState::Failed,
        }
    }

    /// Legal FSM successors (used by debug assertions + property tests).
    pub fn can_transition_to(self, next: SlotState) -> bool {
        use SlotState::*;
        matches!(
            (self, next),
            (Empty, FrontendWriting)
                | (FrontendWriting, PrefillPending)
                | (FrontendWriting, Empty) // frontend abort
                | (PrefillPending, PrefillProcessing)
                | (PrefillProcessing, DecodeProcessing)
                | (PrefillProcessing, Failed)
                | (DecodeProcessing, DecodePaused)
                | (DecodePaused, DecodeProcessing)
                | (DecodeProcessing, DecodeCompleted)
                | (DecodePaused, DecodeCompleted) // early exit while paused
                | (DecodeProcessing, Failed)
                | (DecodeCompleted, Empty)
                | (Failed, Empty)
        )
    }
}

/// One slot. All fields atomic: the slot is concurrently visible to the
/// DPU plane (RDMA) and the GPU plane (persistent scheduler).
#[derive(Debug)]
pub struct Slot {
    // lint: atomic(state) publish=Release observe=Acquire|Relaxed rmw=AcqRel
    // # the slot's ownership word. Stores publish the metadata written
    // before the transition; Relaxed loads are scan-only peeks whose
    // winner re-synchronizes through the AcqRel claim CAS.
    state: AtomicU32,
    // lint: atomic(request_id) plane
    pub request_id: AtomicU64,
    // lint: atomic(ticket) publish=Relaxed observe=Relaxed rmw=AcqRel
    // # the global ticket counter (AcqRel fetch_add in RingBuffer) and the
    // per-slot stamp share this contract; the stamp itself rides the
    // state-word release edge like the rest of the metadata plane.
    pub ticket: AtomicU64,
    // lint: atomic(prompt_len) plane
    pub prompt_len: AtomicU32,
    // lint: atomic(max_new_tokens) plane
    pub max_new_tokens: AtomicU32,
    // lint: atomic(seed) plane
    pub seed: AtomicU32,
    /// Request class: higher = more important; 0 = batch/default. Read by
    /// the scheduler's admission policy (paper's scheduler is FCFS-only;
    /// this field is what the pluggable policies rank by).
    // lint: atomic(priority) plane
    pub priority: AtomicU32,
    /// Absolute TTFT deadline (µs since process epoch); 0 = no deadline.
    /// Derived from the submitted TTFT budget at publish time.
    // lint: atomic(ttft_deadline_us) plane
    pub ttft_deadline_us: AtomicU64,
    /// Conversation-session tag (hash of the client session id); 0 = no
    /// session. Rides the same metadata write so the GPU plane can
    /// attribute multi-turn traffic (`SchedulerStats::session_requests`)
    /// without any host coordination.
    // lint: atomic(session_id) plane
    pub session_id: AtomicU64,
    /// Number of generated tokens published to the output arena.
    // lint: atomic(generated) publish=Release|Relaxed observe=Acquire|Relaxed
    // # Release stores publish freshly written output-arena tokens to the
    // token reader's Acquire load; Relaxed stores/loads are same-plane
    // resets and progress peeks that carry no data.
    pub generated: AtomicU32,
    /// Frontend-local progress (tokens already streamed to the client).
    // lint: atomic(read_cursor) plane
    pub read_cursor: AtomicU32,
    // lint: atomic(submit_time_us) plane
    pub submit_time_us: AtomicU64,
    // lint: atomic(first_token_time_us) plane
    pub first_token_time_us: AtomicU64,
    // lint: atomic(finish_time_us) plane
    pub finish_time_us: AtomicU64,
}

impl Slot {
    pub fn new() -> Slot {
        Slot {
            state: AtomicU32::new(SlotState::Empty as u32),
            request_id: AtomicU64::new(0),
            ticket: AtomicU64::new(0),
            prompt_len: AtomicU32::new(0),
            max_new_tokens: AtomicU32::new(0),
            seed: AtomicU32::new(0),
            priority: AtomicU32::new(0),
            ttft_deadline_us: AtomicU64::new(0),
            session_id: AtomicU64::new(0),
            generated: AtomicU32::new(0),
            read_cursor: AtomicU32::new(0),
            submit_time_us: AtomicU64::new(0),
            first_token_time_us: AtomicU64::new(0),
            finish_time_us: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn state(&self) -> SlotState {
        SlotState::from_u32(self.state.load(Ordering::Acquire))
    }

    /// Relaxed state peek for bulk scans: the scan only *selects*
    /// candidates — the subsequent claim CAS (AcqRel) provides the
    /// synchronization, so the scan itself needs no ordering. This is
    /// what the 256-thread GPU scan does with plain loads + a fence at
    /// the claim.
    #[inline]
    pub fn state_relaxed(&self) -> SlotState {
        SlotState::from_u32(self.state.load(Ordering::Relaxed))
    }

    #[inline]
    pub fn set_state(&self, next: SlotState) {
        debug_assert!(
            self.state().can_transition_to(next),
            "illegal transition {:?} -> {:?}",
            self.state(),
            next
        );
        self.state.store(next as u32, Ordering::Release);
    }

    /// CAS transition; returns true on success. Legality is checked in
    /// debug builds only (the release hot path is a bare CAS, as on GPU).
    #[inline]
    pub fn cas_state(&self, from: SlotState, to: SlotState) -> bool {
        debug_assert!(from.can_transition_to(to), "illegal transition {from:?} -> {to:?}");
        self.state
            .compare_exchange(from as u32, to as u32, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }
}

impl Default for Slot {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsm_legality() {
        use SlotState::*;
        assert!(Empty.can_transition_to(FrontendWriting));
        assert!(!Empty.can_transition_to(DecodeProcessing));
        assert!(DecodeProcessing.can_transition_to(DecodePaused));
        assert!(DecodePaused.can_transition_to(DecodeProcessing));
        assert!(!DecodeCompleted.can_transition_to(DecodeProcessing));
        assert!(Failed.can_transition_to(Empty));
    }

    #[test]
    fn cas_only_from_expected() {
        let s = Slot::new();
        assert!(s.cas_state(SlotState::Empty, SlotState::FrontendWriting));
        assert!(!s.cas_state(SlotState::Empty, SlotState::FrontendWriting));
        assert_eq!(s.state(), SlotState::FrontendWriting);
    }

    #[test]
    fn roundtrip_u32() {
        for v in 0..8u32 {
            assert_eq!(SlotState::from_u32(v) as u32, v);
        }
    }
}
