//! Latency/throughput statistics used across the evaluation: percentiles,
//! geometric means (the paper aggregates pre-saturation curves by geomean,
//! §6.2), and the two-segment saturation fit of Fig 7.

/// Percentile by linear interpolation on a *sorted* slice. `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; ignores non-positive entries (latencies are positive).
pub fn geomean(xs: &[f64]) -> f64 {
    let v: Vec<f64> = xs.iter().copied().filter(|x| *x > 0.0).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

/// Summary of a latency sample.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub count: usize,
}

impl LatencySummary {
    pub fn from_samples(samples: &[f64]) -> Self {
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySummary {
            mean: mean(&s),
            p50: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
            p99: percentile_sorted(&s, 99.0),
            p999: percentile_sorted(&s, 99.9),
            count: s.len(),
        }
    }

    pub fn get(&self, which: &str) -> f64 {
        match which {
            "mean" => self.mean,
            "p50" => self.p50,
            "p95" => self.p95,
            "p99" => self.p99,
            "p999" => self.p999,
            _ => f64::NAN,
        }
    }
}

/// Saturation-point detection via the paper's two-segment fit (§6.2):
/// throughput grows ~linearly with offered load then plateaus. Returns the
/// index of the last offered-load level in the linear (pre-saturation)
/// regime. `loads` and `tputs` are parallel, sorted by load.
pub fn saturation_index(loads: &[f64], tputs: &[f64]) -> usize {
    assert_eq!(loads.len(), tputs.len());
    let n = loads.len();
    if n < 3 {
        return n.saturating_sub(1);
    }
    // Try every breakpoint k: segment A = linear through origin fit on
    // [0..=k], segment B = constant (plateau) on [k..n]. Pick min SSE.
    let mut best_k = n - 1;
    let mut best_sse = f64::INFINITY;
    for k in 1..n - 1 {
        // slope via least squares through origin on the first segment
        let (mut num, mut den) = (0.0, 0.0);
        for i in 0..=k {
            num += loads[i] * tputs[i];
            den += loads[i] * loads[i];
        }
        let slope = if den > 0.0 { num / den } else { 0.0 };
        let plateau = mean(&tputs[k..]);
        let mut sse = 0.0;
        for i in 0..=k {
            let e = tputs[i] - slope * loads[i];
            sse += e * e;
        }
        for i in k..n {
            let e = tputs[i] - plateau;
            sse += e * e;
        }
        if sse < best_sse {
            best_sse = sse;
            best_k = k;
        }
    }
    best_k
}

/// Highest offered load with goodput >= retention * offered (Fig C.1's
/// "serviceable load", retention = 0.95).
pub fn serviceable_load(loads: &[f64], goodputs: &[f64], retention: f64) -> f64 {
    let mut best = 0.0;
    for (l, g) in loads.iter().zip(goodputs) {
        if *g >= retention * *l && *l > best {
            best = *l;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile_sorted(&s, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile_sorted(&[5.0], 99.0), 5.0);
    }

    #[test]
    fn geomean_matches_hand() {
        let g = geomean(&[1.0, 100.0]);
        assert!((g - 10.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_ignores_nonpositive() {
        let g = geomean(&[0.0, -1.0, 4.0, 9.0]);
        assert!((g - 6.0).abs() < 1e-9);
    }

    #[test]
    fn summary_ordering() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let s = LatencySummary::from_samples(&xs);
        assert!(s.p50 < s.p95 && s.p95 < s.p99 && s.p99 < s.p999);
        assert_eq!(s.count, 1000);
    }

    #[test]
    fn saturation_detects_knee() {
        // linear to 8, plateau after
        let loads: Vec<f64> = (1..=13).map(|i| i as f64).collect();
        let tputs: Vec<f64> =
            loads.iter().map(|l| if *l <= 8.0 { *l } else { 8.0 }).collect();
        let k = saturation_index(&loads, &tputs);
        assert!((7..=8).contains(&k), "k={k}");
    }

    #[test]
    fn serviceable_load_threshold() {
        let loads = [1.0, 2.0, 4.0, 8.0];
        let good = [1.0, 2.0, 3.9, 5.0];
        let s = serviceable_load(&loads, &good, 0.95);
        assert_eq!(s, 4.0);
    }
}
