//! Tiny CLI argument helper: `subcommand [positional...] --key value --flag`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(raw: impl Iterator<Item = String>) -> Args {
        let mut args = Args::default();
        let raw: Vec<String> = raw.collect();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    args.options.insert(key.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed() {
        let a = parse("eval fig3 --model blink-tiny --rate=4.5 --verbose");
        assert_eq!(a.positional, vec!["eval", "fig3"]);
        assert_eq!(a.get("model"), Some("blink-tiny"));
        assert_eq!(a.get_f64("rate", 0.0), 4.5);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.get_usize("port", 8000), 8000);
    }
}
