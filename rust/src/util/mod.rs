//! Small self-contained utilities.
//!
//! The offline registry only carries the `xla` crate's dependency closure,
//! so JSON, CLI parsing, RNG, statistics and the property-testing harness
//! are implemented here instead of pulling serde/clap/criterion/proptest
//! (see DESIGN.md §4). Per submodule:
//!
//! * [`alloc`] — test-only counting global allocator behind the
//!   zero-allocation hot-loop regression test and the `heap_allocs`
//!   metric;
//! * [`cli`] — `subcommand [positional...] --key value --flag` argument
//!   parsing for the `blink` binary (clap stand-in);
//! * [`json`] — the minimal JSON parser/serializer behind the
//!   OpenAI-compatible HTTP surface (serde stand-in);
//! * [`prop`] — seeded property-testing harness with reproducible
//!   per-case RNGs (proptest stand-in);
//! * [`rng`] — deterministic SplitMix64 PRNG plus the exponential /
//!   lognormal draws the workload generators need (rand stand-in);
//! * [`stats`] — percentile/geomean/saturation-knee helpers shared by
//!   the eval tables;
//! * [`timer`] — monotonic µs clock + the warmup/percentile bench
//!   harness every `rust/benches/*` target uses (criterion stand-in).

pub mod alloc;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
