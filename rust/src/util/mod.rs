//! Small self-contained utilities.
//!
//! The offline registry only carries the `xla` crate's dependency closure,
//! so JSON, CLI parsing, RNG, statistics and the property-testing harness
//! are implemented here instead of pulling serde/clap/criterion/proptest
//! (see DESIGN.md §4).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
