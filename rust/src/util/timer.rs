//! Wall-clock helpers + the hand-rolled bench harness used by
//! `rust/benches/*` (criterion is unavailable offline). The harness does
//! warmup, then timed iterations, and reports mean/p50/p99 per iteration.

use std::time::{Duration, Instant};

/// Monotonic microseconds since an arbitrary epoch (process start).
pub fn now_us() -> u64 {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = *EPOCH.get_or_init(Instant::now);
    Instant::now().duration_since(epoch).as_micros() as u64
}

#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self, name: &str) {
        println!(
            "{name:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p99 {:>12}   min {:>12}",
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.2} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark a closure: `warmup` untimed runs, then timed runs until
/// `budget` elapses (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    let min_iters = 10;
    while samples.len() < min_iters || start.elapsed() < budget {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let res = BenchResult {
        iters: samples.len(),
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_ns: crate::util::stats::percentile_sorted(&samples, 50.0),
        p99_ns: crate::util::stats::percentile_sorted(&samples, 99.0),
        min_ns: samples[0],
    };
    res.report(name);
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_us_monotonic() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }

    #[test]
    fn bench_runs() {
        let r = bench("noop", 2, Duration::from_millis(5), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 10);
        assert!(r.mean_ns >= 0.0);
    }
}
