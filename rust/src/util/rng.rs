//! Deterministic PRNG (SplitMix64) + distributions used by the workload
//! generator and the simulator. No external `rand` crate offline; this is
//! the standard SplitMix64 mixer (Steele et al.), plenty for simulation.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        // Rejection-free multiply-shift; bias negligible for simulation.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Exponential with the given rate (mean 1/rate). Used for Poisson
    /// inter-arrival gaps in the workload generator.
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -u.ln() / rate
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal parameterized by the *target* mean and coefficient of
    /// variation of the resulting distribution (how trace lengths are
    /// specified in DESIGN.md §2).
    pub fn lognormal_mean_cv(&mut self, mean: f64, cv: f64) -> f64 {
        let sigma2 = (1.0 + cv * cv).ln();
        let mu = mean.ln() - sigma2 / 2.0;
        (mu + sigma2.sqrt() * self.normal()).exp()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child stream (stable across reorderings of other draws).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for n in [1u64, 2, 7, 1000] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn lognormal_mean_close() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.lognormal_mean_cv(1019.0, 0.9)).sum::<f64>() / n as f64;
        assert!((mean / 1019.0 - 1.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
