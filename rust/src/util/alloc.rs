//! Test-only counting global allocator: proves the steady-state control
//! loop is allocation-free instead of asserting it rhetorically.
//!
//! The library never installs this allocator — in normal builds every
//! counter below stays 0 and the `heap_allocs` field in `gpu::stats`
//! reads as 0. A test binary opts in with
//!
//! ```ignore
//! #[global_allocator]
//! static A: blink::util::alloc::CountingAlloc = blink::util::alloc::CountingAlloc;
//! ```
//!
//! after which [`alloc_count`] reports the process-wide number of heap
//! allocations (allocs + reallocs, across *all* threads — which is the
//! point: the zero-alloc regression test windows a period where only the
//! scheduler and executor threads run, so any count it observes belongs
//! to the control loop). See `rust/tests/hotloop_alloc.rs`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

// lint: atomic(ALLOCS) counter
static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Process-wide allocation events observed so far (0 unless a test
/// binary installed [`CountingAlloc`] as its global allocator).
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// A [`System`]-backed allocator that counts every `alloc` /
/// `alloc_zeroed` / `realloc`. Deallocations are not counted: the
/// hot-loop invariant is "no new heap traffic per iteration", and frees
/// of admission-time buffers are part of bounded retirement, not steady
/// state.
pub struct CountingAlloc;

// SAFETY: every method below upholds the `GlobalAlloc` contract by
// delegating verbatim to `System`, which satisfies it; the only added
// behavior is a relaxed counter bump, which cannot itself allocate (it
// would recurse) and touches no allocator state.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller guarantees `layout` has non-zero size (GlobalAlloc
    // precondition); forwarded unchanged to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: same precondition as `alloc`, forwarded unchanged; System
    // returns zeroed memory or null exactly as the contract requires.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: caller guarantees `ptr` came from this allocator with
    // `layout` and `new_size` is non-zero; since alloc/dealloc delegate
    // to `System`, `ptr` is a valid `System` allocation to forward.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: caller guarantees `ptr` was returned by this allocator for
    // `layout`; every allocation path above is a `System` allocation, so
    // handing it back to `System.dealloc` is the matching free.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The allocator is not installed in lib tests, so the counter is
    // inert here; installation + counting behavior is exercised by the
    // dedicated integration test (`rust/tests/hotloop_alloc.rs`), which
    // is the only place a `#[global_allocator]` can be swapped in.
    #[test]
    fn counter_reads_without_installation() {
        let a = alloc_count();
        let _v: Vec<u8> = Vec::with_capacity(64);
        assert_eq!(alloc_count(), a, "not installed: allocations are invisible");
    }
}
