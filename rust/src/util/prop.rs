//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `run_prop(seed, cases, |rng| ...)` runs `cases` randomized cases; on
//! panic it re-raises with the failing case index + per-case seed so the
//! case is reproducible with `case_rng(seed, i)`. Shrinking is replaced by
//! printing the deterministic case seed — adequate for the coordinator
//! invariants we check (routing, batching, slot state machine, allocator).

use crate::util::rng::Rng;

pub fn case_rng(seed: u64, case: u64) -> Rng {
    Rng::new(seed ^ case.wrapping_mul(0xA076_1D64_78BD_642F))
}

pub fn run_prop<F: FnMut(&mut Rng)>(name: &str, seed: u64, cases: u64, mut f: F) {
    for i in 0..cases {
        let mut rng = case_rng(seed, i);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {i}/{cases} (reproduce with case_rng({seed}, {i}))"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Random vector helper.
pub fn vec_u32(rng: &mut Rng, max_len: usize, max_val: u32) -> Vec<u32> {
    let len = rng.below(max_len as u64 + 1) as usize;
    (0..len).map(|_| rng.below(max_val as u64) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn props_run_and_are_deterministic() {
        let mut seen = vec![];
        run_prop("collect", 9, 5, |rng| seen.push(rng.next_u64()));
        let mut seen2 = vec![];
        run_prop("collect", 9, 5, |rng| seen2.push(rng.next_u64()));
        assert_eq!(seen, seen2);
        assert_eq!(seen.len(), 5);
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        run_prop("fail", 1, 10, |rng| {
            assert!(rng.f64() < 0.5, "intentional");
        });
    }
}
