//! Minimal JSON parser/serializer for the OpenAI-compatible HTTP API and
//! result files (serde is unavailable offline — DESIGN.md §4).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // Surrogate pairs: combine if a low surrogate follows.
                            if (0xD800..0xDC00).contains(&cp)
                                && self.bytes[self.pos + 5..].starts_with(b"\\u")
                            {
                                let hex2 = std::str::from_utf8(
                                    &self.bytes[self.pos + 7..self.pos + 11],
                                )
                                .map_err(|_| "bad surrogate".to_string())?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| "bad surrogate".to_string())?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                self.pos += 10;
                            } else {
                                out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                                self.pos += 4;
                            }
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = &self.bytes[self.pos..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| "bad utf8".to_string())?;
                    out.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\u{1}".into());
        let back = parse(&s.to_string()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("3.5").unwrap().as_f64(), Some(3.5));
        assert_eq!(parse("-17").unwrap().as_f64(), Some(-17.0));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
    }
}
