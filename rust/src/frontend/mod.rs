//! The DPU frontend (paper §4.4): request lifecycle from arrival to token
//! delivery, running on "BlueField ARM cores" (its own threads), touching
//! backend state *only* through one-sided RDMA work requests.
//!
//! Subsystems, as in the paper:
//! * request tracker — per-request state: slot assignment, token counts,
//!   completion status ([`tracker`]);
//! * slot tracker — local availability cache + hint-based circular scan,
//!   so submission does not scan the remote ring ([`slot_tracker`]);
//! * token reader — background thread: one bulk RDMA metadata read per
//!   cycle, urgent-slot prioritization for TTFT, adaptive polling
//!   ([`token_reader`]);
//! * tokenizer — `crate::tokenizer::blink` (shared, zero-alloc request
//!   path);
//! * session store — per-conversation token history kept on the DPU so a
//!   multi-turn client resubmits only its *new* text each turn: the
//!   frontend reuses the already-tokenized history (no re-tokenization)
//!   and the GPU-side prefix index (DESIGN.md §7) turns the shared
//!   history into a KV-cache hit.

pub mod overload;
pub mod slot_tracker;
pub mod token_reader;
pub mod tracker;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex, OnceLock};

use crate::gpu::stats::SchedulerStats;
use crate::rdma::{Payload, QueuePair, RdmaEngine, RdmaOp};
use crate::tokenizer::blink::BlinkTokenizer;
use crate::tokenizer::{Tokenizer, Vocab};
use overload::{Decision, OverloadConfig, OverloadGate, Rejected};
use slot_tracker::SlotTracker;
use token_reader::ReaderConfig;
use tracker::{ReqState, TokenEvent, Tracker};

#[derive(Debug, Clone)]
pub struct FrontendConfig {
    pub num_slots: usize,
    pub max_prompt: usize,
    pub max_output: usize,
    pub reader: ReaderConfig,
    /// Admission-gate knobs (DESIGN.md §9); default = disabled.
    pub overload: OverloadConfig,
}

/// Request class carried from the HTTP body to the scheduler's admission
/// policy: a base priority (higher = more important) and an optional
/// TTFT budget. The default — priority 0, no budget — reproduces the
/// paper's single-class FCFS behavior exactly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequestClass {
    /// Higher = more important; 0 = batch/default.
    pub priority: u32,
    /// Relative TTFT budget in µs; 0 = no deadline.
    pub ttft_budget_us: u64,
}

impl RequestClass {
    pub fn interactive(ttft_budget_us: u64) -> RequestClass {
        RequestClass { priority: 4, ttft_budget_us }
    }
}

/// Stable non-zero tag for a client session id (FNV-1a; 0 is reserved
/// for "no session" end-to-end, so a hash of 0 is nudged to 1).
pub fn session_key(id: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in id.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    if h == 0 {
        1
    } else {
        h
    }
}

/// A submitted request: stream of token events + ids for bookkeeping.
/// `max_new` is the *effective* output budget — a shed-degraded
/// admission carries the capped value so the HTTP layer can report it.
#[derive(Debug)]
pub struct RequestHandle {
    pub request_id: u64,
    pub slot: usize,
    pub prompt_tokens: usize,
    pub max_new: u32,
    pub rx: Receiver<TokenEvent>,
}

impl RequestHandle {
    /// Drain to completion, returning all generated tokens (blocking).
    pub fn collect(self) -> Result<Vec<u32>, String> {
        let mut toks = vec![];
        loop {
            match self.rx.recv() {
                Ok(TokenEvent::Token(t)) => toks.push(t),
                Ok(TokenEvent::Done) => return Ok(toks),
                Ok(TokenEvent::Failed) => return Err("request failed".into()),
                Err(_) => return Err("frontend dropped".into()),
            }
        }
    }
}

pub struct DpuFrontend {
    submit_qp: Mutex<QueuePair>,
    tracker: Arc<Mutex<Tracker>>,
    slots: Arc<Mutex<SlotTracker>>,
    // lint: atomic(urgent) observe=Acquire rmw=AcqRel # wake hint for the
    // token reader; the AcqRel bumps keep it ordered with the slot
    // registrations it advertises.
    urgent: Arc<AtomicU32>,
    // lint: atomic(stop) flag
    stop: Arc<AtomicBool>,
    reader_handle: Option<std::thread::JoinHandle<()>>,
    pub tokenizer: Arc<BlinkTokenizer>,
    pub vocab: Arc<Vocab>,
    // lint: atomic(next_id) counter
    next_id: AtomicU64,
    config: FrontendConfig,
    // lint: atomic(seed_ctr) counter
    seed_ctr: AtomicU32,
    /// Per-session token history (prompt + generated tokens of previous
    /// turns), keyed by the *client's session-id string* — not its hash,
    /// so colliding ids can never merge (or leak) two conversations; the
    /// [`session_key`] hash is only the GPU-plane telemetry tag. Lives
    /// on the DPU plane, like the tokenizer: the backend only ever sees
    /// full token sequences. Each entry carries a last-use tick; the
    /// store is capped at [`MAX_SESSIONS`], reclaiming only idle
    /// sessions.
    sessions: Mutex<HashMap<String, SessionEntry>>,
    // lint: atomic(session_tick) counter
    session_tick: AtomicU64,
    /// Overload-control admission gate (DESIGN.md §9), checked before a
    /// ring slot is claimed so refused work never touches the GPU plane.
    gate: OverloadGate,
    /// Scheduler stats sink: gate decisions are mirrored here (once
    /// attached by the server) so `/metrics` and `summary()` carry shed
    /// counts without the stats plane reaching back into the frontend.
    stats: OnceLock<Arc<SchedulerStats>>,
}

/// One conversation's DPU-side state.
#[derive(Debug)]
struct SessionEntry {
    tokens: Vec<u32>,
    /// Last-use tick for LRU ordering.
    tick: u64,
    /// Wall-clock last use, for the idle-eviction threshold.
    last_use: std::time::Instant,
    /// Set when the stored history stopped matching the real
    /// conversation — a reply no longer fit the prompt arena, or a turn
    /// failed after its text was recorded ([`DpuFrontend::poison_session`]).
    /// Further turns are refused rather than served against a silently
    /// wrong history.
    overflowed: bool,
}

impl SessionEntry {
    /// Append `tokens`, or mark the entry overflowed when they no
    /// longer fit `max` (the prompt arena capacity).
    fn append(&mut self, tokens: &[u32], max: usize) {
        if self.tokens.len() + tokens.len() <= max {
            self.tokens.extend_from_slice(tokens);
        } else {
            self.overflowed = true;
        }
    }
}

/// Upper bound on retained session histories. Worst case is
/// `MAX_SESSIONS × max_prompt × 4` bytes of DPU memory — 8 MB for the
/// tiny live model (512-token arena), 128 MB at the paper models'
/// 8192-token contexts; BlueField-3 carries 32 GB. At capacity, only
/// sessions idle for [`SESSION_IDLE_EVICT`] are reclaimed (LRU); new
/// sessions are refused when nothing is idle, so an active
/// conversation's context is never silently dropped.
pub const MAX_SESSIONS: usize = 4096;

/// Idle threshold before a session at capacity may be evicted.
pub const SESSION_IDLE_EVICT: std::time::Duration = std::time::Duration::from_secs(600);

impl DpuFrontend {
    pub fn new(
        engine: Arc<RdmaEngine>,
        vocab: Arc<Vocab>,
        config: FrontendConfig,
    ) -> DpuFrontend {
        let tokenizer = Arc::new(BlinkTokenizer::new(&vocab));
        let tracker = Arc::new(Mutex::new(Tracker::new()));
        let slots = Arc::new(Mutex::new(SlotTracker::new(config.num_slots)));
        let urgent = Arc::new(AtomicU32::new(0));
        let stop = Arc::new(AtomicBool::new(false));

        let reader_qp = QueuePair::new(engine.clone());
        let reader_handle = token_reader::spawn(
            reader_qp,
            tracker.clone(),
            slots.clone(),
            urgent.clone(),
            stop.clone(),
            config.num_slots,
            config.reader.clone(),
        );

        let gate = OverloadGate::new(config.overload);
        DpuFrontend {
            submit_qp: Mutex::new(QueuePair::new(engine)),
            tracker,
            slots,
            urgent,
            stop,
            reader_handle: Some(reader_handle),
            tokenizer,
            vocab,
            next_id: AtomicU64::new(1),
            config,
            seed_ctr: AtomicU32::new(0x5EED),
            sessions: Mutex::new(HashMap::new()),
            session_tick: AtomicU64::new(1),
            gate,
            stats: OnceLock::new(),
        }
    }

    /// Attach the scheduler's stats block so gate decisions show up in
    /// `/metrics` and `summary()`. Idempotent; the first sink wins.
    pub fn attach_stats(&self, stats: Arc<SchedulerStats>) {
        let _ = self.stats.set(stats);
    }

    /// The admission gate (metrics / tests).
    pub fn gate(&self) -> &OverloadGate {
        &self.gate
    }

    /// Tokenize on the DPU and submit (the paper's step ②③④⑤),
    /// default (batch) request class.
    pub fn submit_text(&self, text: &str, max_new: u32) -> Result<RequestHandle, Rejected> {
        self.submit_text_class(text, max_new, RequestClass::default())
    }

    /// Tokenize and submit with an explicit request class.
    pub fn submit_text_class(
        &self,
        text: &str,
        max_new: u32,
        class: RequestClass,
    ) -> Result<RequestHandle, Rejected> {
        let mut toks = Vec::with_capacity(text.len() / 3 + 4);
        self.tokenizer.encode(text, &mut toks);
        self.submit_tokens_class(&toks, max_new, class)
    }

    /// Submit pre-tokenized input (workload generators / benches),
    /// default (batch) request class.
    pub fn submit_tokens(&self, tokens: &[u32], max_new: u32) -> Result<RequestHandle, Rejected> {
        self.submit_tokens_class(tokens, max_new, RequestClass::default())
    }

    /// Tokenize and submit one turn of a multi-turn conversation. With a
    /// session id, the stored token history of previous turns is
    /// *prepended* (already tokenized — the DPU never re-tokenizes
    /// history) and the new turn's tokens are appended to the store on
    /// successful submission. Generated tokens are added by the caller
    /// via [`DpuFrontend::record_session_reply`] once the turn finishes,
    /// so the next turn's prompt covers the full conversation.
    pub fn submit_text_session(
        &self,
        session: Option<&str>,
        text: &str,
        max_new: u32,
        class: RequestClass,
    ) -> Result<RequestHandle, Rejected> {
        self.submit_text_tenant(session, None, text, max_new, class)
    }

    /// [`submit_text_session`](Self::submit_text_session) with an
    /// explicit tenant tag for the admission gate's per-tenant quotas.
    /// The tenant key is the `tenant` field when given, falling back to
    /// the session id, falling back to the shared anonymous pool (0).
    pub fn submit_text_tenant(
        &self,
        session: Option<&str>,
        tenant: Option<&str>,
        text: &str,
        max_new: u32,
        class: RequestClass,
    ) -> Result<RequestHandle, Rejected> {
        let tenant_key = tenant
            .map(session_key)
            .or_else(|| session.map(session_key))
            .unwrap_or(0);
        let mut new_toks = Vec::with_capacity(text.len() / 3 + 4);
        self.tokenizer.encode(text, &mut new_toks);
        let Some(sid) = session else {
            return self.submit_tokens_gated(0, tenant_key, &new_toks, max_new, class);
        };
        let key = session_key(sid);
        let full: Vec<u32> = {
            let mut sessions = self.sessions.lock().unwrap();
            let tick = self.session_tick.fetch_add(1, Ordering::Relaxed);
            if !sessions.contains_key(sid) && sessions.len() >= MAX_SESSIONS {
                // New conversation at capacity: make room *before*
                // submitting. Only idle sessions are reclaimed — an
                // active conversation's context is never silently
                // dropped; with nothing idle, the new session is
                // refused instead.
                let victim = sessions
                    .iter()
                    .filter(|(_, e)| e.last_use.elapsed() >= SESSION_IDLE_EVICT)
                    .min_by_key(|(_, e)| e.tick)
                    .map(|(k, _)| k.clone());
                match victim {
                    Some(v) => {
                        sessions.remove(&v);
                    }
                    None => {
                        return Err(Rejected::Overload {
                            reason: format!(
                                "session store full ({MAX_SESSIONS} active conversations); \
                                 retry later or omit session_id"
                            ),
                            retry_after_ms: 1000,
                        });
                    }
                }
            }
            let hist: &[u32] = match sessions.get_mut(sid) {
                Some(e) if e.overflowed => {
                    return Err(Rejected::Client(
                        "session history is no longer consistent (overflow or a \
                         failed turn); start a new session"
                            .into(),
                    ));
                }
                Some(e) => {
                    e.tick = tick;
                    e.last_use = std::time::Instant::now();
                    e.tokens.as_slice()
                }
                None => &[],
            };
            let mut full = Vec::with_capacity(hist.len() + new_toks.len());
            full.extend_from_slice(hist);
            full.extend_from_slice(&new_toks);
            full
        };
        let snapshot_len = full.len() - new_toks.len();
        let handle = self.submit_tokens_gated(key, tenant_key, &full, max_new, class)?;
        // Only a successfully submitted turn becomes history. Turns of a
        // session must be serialized by the client: if the stored
        // history changed between our snapshot and this commit (a racing
        // second turn, or a reply the client had not yet received), the
        // submitted prompt no longer matches the conversation — poison
        // rather than record a transcript the model never saw. An absent
        // entry (first turn, or reclaimed mid-flight) stores the exact
        // submitted prompt.
        {
            let tick = self.session_tick.fetch_add(1, Ordering::Relaxed);
            let mut sessions = self.sessions.lock().unwrap();
            match sessions.entry(sid.to_string()) {
                std::collections::hash_map::Entry::Occupied(mut o) => {
                    let e = o.get_mut();
                    if e.tokens.len() == snapshot_len {
                        e.append(&new_toks, self.config.max_prompt);
                    } else {
                        e.overflowed = true;
                    }
                    e.tick = tick;
                    e.last_use = std::time::Instant::now();
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(SessionEntry {
                        tokens: full,
                        tick,
                        last_use: std::time::Instant::now(),
                        overflowed: false,
                    });
                }
            }
        }
        Ok(handle)
    }

    /// Append a finished turn's generated tokens to the session history.
    /// A reply that no longer fits the prompt arena marks the session
    /// *overflowed*: its next turn is refused with an error instead of
    /// being served against a silently-truncated conversation. Replies
    /// never create an entry.
    pub fn record_session_reply(&self, session: &str, tokens: &[u32]) {
        let tick = self.session_tick.fetch_add(1, Ordering::Relaxed);
        let mut sessions = self.sessions.lock().unwrap();
        if let Some(e) = sessions.get_mut(session) {
            e.append(tokens, self.config.max_prompt);
            e.tick = tick;
            e.last_use = std::time::Instant::now();
        }
    }

    /// Mark a session inconsistent after a *failed* turn: the submitted
    /// user text is already part of the stored history but the model
    /// never answered it, so subsequent turns would replay a
    /// conversation that did not happen. The next turn is refused with
    /// an error instead.
    pub fn poison_session(&self, session: &str) {
        let mut sessions = self.sessions.lock().unwrap();
        if let Some(e) = sessions.get_mut(session) {
            e.overflowed = true;
        }
    }

    /// Stored token-history length for a session (diagnostics / tests).
    pub fn session_history_len(&self, session: &str) -> usize {
        self.sessions.lock().unwrap().get(session).map_or(0, |e| e.tokens.len())
    }

    /// Submit pre-tokenized input with an explicit request class.
    pub fn submit_tokens_class(
        &self,
        tokens: &[u32],
        max_new: u32,
        class: RequestClass,
    ) -> Result<RequestHandle, Rejected> {
        self.submit_tokens_full(0, tokens, max_new, class)
    }

    /// Full submission path: pre-tokenized input, explicit class and
    /// session tag (0 = no session). The session tag doubles as the
    /// tenant key for the admission gate.
    pub fn submit_tokens_full(
        &self,
        session_id: u64,
        tokens: &[u32],
        max_new: u32,
        class: RequestClass,
    ) -> Result<RequestHandle, Rejected> {
        self.submit_tokens_gated(session_id, session_id, tokens, max_new, class)
    }

    /// Full submission path with an explicit gate tenant key (which may
    /// differ from the session tag when the client sent a `tenant`
    /// field). Validation order matters for the error contract:
    /// client-side mistakes (400-class) are checked *before* the gate so
    /// a malformed request never consumes quota, and the gate runs
    /// *before* the slot claim so refused work costs the ring nothing.
    pub fn submit_tokens_gated(
        &self,
        session_id: u64,
        tenant: u64,
        tokens: &[u32],
        max_new: u32,
        class: RequestClass,
    ) -> Result<RequestHandle, Rejected> {
        if tokens.is_empty() {
            return Err(Rejected::Client("empty prompt".into()));
        }
        if tokens.len() > self.config.max_prompt {
            return Err(Rejected::Client(format!(
                "prompt of {} tokens exceeds arena capacity {}",
                tokens.len(),
                self.config.max_prompt
            )));
        }
        let mut max_new = max_new.clamp(1, self.config.max_output as u32);

        if self.gate.enabled() {
            let occupancy =
                1.0 - self.approx_free_slots() as f64 / self.config.num_slots.max(1) as f64;
            let decision =
                self.gate.check(tenant, class.priority, occupancy, self.gate.now_ms());
            if let Some(stats) = self.stats.get() {
                stats.mirror_gate_decision(&decision);
            }
            match decision {
                Decision::Admit => {}
                Decision::Degrade { max_new_cap } => {
                    max_new = max_new.min(max_new_cap.max(1));
                }
                Decision::Reject { kind: _, reason, retry_after_ms } => {
                    // The gate hands back a static reason; the String
                    // conversion happens here, off the admission fast path.
                    return Err(Rejected::Overload {
                        reason: reason.to_string(),
                        retry_after_ms,
                    });
                }
            }
        }

        let request_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let seed = self.seed_ctr.fetch_add(0x9E37, Ordering::Relaxed);

        // Claim a slot: hint-based local scan, RDMA CAS to actually own it.
        let mut qp = self.submit_qp.lock().unwrap();
        let slot = {
            let mut tries = 0;
            loop {
                let candidate = {
                    let mut s = self.slots.lock().unwrap();
                    s.acquire_hint()
                };
                let Some(candidate) = candidate else {
                    return Err(Rejected::Overload {
                        reason: "ring buffer full (backpressure)".into(),
                        retry_after_ms: 50,
                    });
                };
                match qp.exec(RdmaOp::ClaimSlot { slot: candidate }) {
                    Payload::Cas(true) => break candidate,
                    _ => {
                        // Stale availability cache: mark used, try the next.
                        self.slots.lock().unwrap().mark_used(candidate);
                        tries += 1;
                        if tries > self.config.num_slots {
                            return Err(Rejected::Overload {
                                reason: "no free slot after full sweep".into(),
                                retry_after_ms: 50,
                            });
                        }
                    }
                }
            }
        };

        // Register with the tracker *before* arming the slot so the token
        // reader can never observe an untracked active slot.
        let (tx, rx) = std::sync::mpsc::channel();
        self.tracker.lock().unwrap().insert(
            slot,
            ReqState::new(request_id, tx),
        );
        self.urgent.fetch_add(1, Ordering::AcqRel);

        // One-sided writes: prompt into the input arena, then metadata +
        // state flip (coalesced by the RDMA engine if bursty).
        qp.post(RdmaOp::WritePrompt { slot, tokens: tokens.to_vec() });
        let wr = qp.post(RdmaOp::Submit {
            slot,
            request_id,
            prompt_len: tokens.len() as u32,
            max_new,
            seed,
            priority: class.priority,
            ttft_budget_us: class.ttft_budget_us,
            session_id,
        });
        qp.wait(wr);

        Ok(RequestHandle { request_id, slot, prompt_tokens: tokens.len(), max_new, rx })
    }

    /// Snapshot of free-slot availability (diagnostics).
    pub fn approx_free_slots(&self) -> usize {
        self.slots.lock().unwrap().approx_free()
    }
}

impl Drop for DpuFrontend {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.reader_handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rdma::RdmaConfig;
    use crate::ringbuf::{RingBuffer, RingConfig};

    fn frontend() -> (Arc<crate::ringbuf::RingBuffer>, DpuFrontend) {
        let ring = Arc::new(RingBuffer::new(RingConfig {
            num_slots: 16,
            max_prompt: 64,
            max_output: 16,
        }));
        let engine = RdmaEngine::spawn(ring.clone(), RdmaConfig::zero_cost());
        let vocab = Arc::new(crate::tokenizer::tests::tiny_vocab());
        let fe = DpuFrontend::new(
            engine,
            vocab,
            FrontendConfig {
                num_slots: 16,
                max_prompt: 64,
                max_output: 16,
                reader: token_reader::ReaderConfig::default(),
                overload: OverloadConfig::default(),
            },
        );
        (ring, fe)
    }

    fn gated_frontend(overload: OverloadConfig) -> DpuFrontend {
        let ring = Arc::new(RingBuffer::new(RingConfig {
            num_slots: 16,
            max_prompt: 64,
            max_output: 16,
        }));
        let engine = RdmaEngine::spawn(ring, RdmaConfig::zero_cost());
        let vocab = Arc::new(crate::tokenizer::tests::tiny_vocab());
        DpuFrontend::new(
            engine,
            vocab,
            FrontendConfig {
                num_slots: 16,
                max_prompt: 64,
                max_output: 16,
                reader: token_reader::ReaderConfig::default(),
                overload,
            },
        )
    }

    #[test]
    fn gate_rejects_and_degrades_at_the_submit_edge() {
        let fe = gated_frontend(OverloadConfig {
            enabled: true,
            window_capacity: 2,
            window_ms: 60_000,
            degrade_threshold: 0.5,
            drop_threshold: 2.0, // degrade-only in this test
            degrade_max_new: 3,
            interactive_floor: 4,
            ..OverloadConfig::default()
        });
        // First admission is clean and keeps its full budget.
        let h = fe.submit_text("the quick", 8, RequestClass::default()).expect("admit");
        assert_eq!(h.max_new, 8);
        // Window half full: the next batch-class submit is degraded and
        // the handle reports the capped budget.
        let h2 = fe.submit_text("brown fox", 8, RequestClass::default()).expect("degraded");
        assert_eq!(h2.max_new, 3, "degraded admission caps max_new");
        // Window full: even interactive work is refused, as Overload
        // (not Client) with a retry hint.
        match fe.submit_text_class("jumps", 4, RequestClass::interactive(300_000)) {
            Err(Rejected::Overload { retry_after_ms, .. }) => assert!(retry_after_ms > 0),
            other => panic!("expected overload rejection, got {other:?}"),
        }
        // Client errors still classify as Client, and never touch quota.
        match fe.submit_text("", 4, RequestClass::default()) {
            Err(Rejected::Client(m)) => assert!(m.contains("empty prompt")),
            other => panic!("expected client rejection, got {other:?}"),
        }
        assert_eq!(fe.gate().admitted.load(Ordering::Relaxed), 2);
        assert_eq!(fe.gate().shed_degraded.load(Ordering::Relaxed), 1);
        assert_eq!(fe.gate().rejected_rate.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tenant_field_beats_session_fallback_for_quota() {
        let fe = gated_frontend(OverloadConfig {
            enabled: true,
            window_capacity: 10_000,
            bucket_capacity: 1.0,
            bucket_refill_per_s: 0.001,
            ..OverloadConfig::default()
        });
        // Two sessions under one tenant share one bucket of 1.
        fe.submit_text_tenant(Some("s1"), Some("acme"), "one", 2, RequestClass::default())
            .expect("first request fits the acme bucket");
        match fe.submit_text_tenant(Some("s2"), Some("acme"), "two", 2, RequestClass::default()) {
            Err(Rejected::Overload { reason, .. }) => assert!(reason.contains("quota")),
            other => panic!("expected tenant-quota rejection, got {other:?}"),
        }
        // A different tenant is untouched.
        fe.submit_text_tenant(Some("s3"), Some("zen"), "three", 2, RequestClass::default())
            .expect("other tenant admitted");
        // No tenant field: the session id is the quota key.
        fe.submit_text_tenant(Some("solo"), None, "four", 2, RequestClass::default())
            .expect("session-keyed bucket");
        assert!(fe
            .submit_text_tenant(Some("solo"), None, "five", 2, RequestClass::default())
            .is_err());
    }

    #[test]
    fn session_key_stable_and_nonzero() {
        assert_eq!(session_key("conv-1"), session_key("conv-1"));
        assert_ne!(session_key("conv-1"), session_key("conv-2"));
        assert_ne!(session_key(""), 0, "0 is reserved for no-session");
    }

    #[test]
    fn session_history_prepends_and_grows() {
        let (ring, fe) = frontend();
        // Turn 1: seeds the history with its own tokens.
        let h1 = fe.submit_text_session(Some("c"), "the quick", 4, RequestClass::default())
            .expect("turn 1");
        let hist1 = fe.session_history_len("c");
        assert_eq!(hist1, h1.prompt_tokens, "history = turn 1 prompt");

        // A generated reply joins the history.
        fe.record_session_reply("c", &[1, 2, 3]);
        assert_eq!(fe.session_history_len("c"), hist1 + 3);

        // Turn 2 prepends the stored history to its new text.
        let h2 = fe.submit_text_session(Some("c"), " the end", 4, RequestClass::default())
            .expect("turn 2");
        assert!(
            h2.prompt_tokens > hist1 + 3,
            "turn 2 prompt ({}) must carry the history ({})",
            h2.prompt_tokens,
            hist1 + 3
        );
        // The session tag rides the slot metadata for the GPU plane.
        let s = ring.slot(h2.slot);
        assert_eq!(
            s.session_id.load(Ordering::Relaxed),
            session_key("c"),
            "slot carries the session tag"
        );
        // Sessionless submissions stamp the reserved 0 tag.
        let h3 = fe.submit_text_session(None, "solo", 2, RequestClass::default()).unwrap();
        assert_eq!(ring.slot(h3.slot).session_id.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn overlong_session_turn_is_rejected_and_history_preserved() {
        let (_ring, fe) = frontend();
        fe.submit_text_session(Some("s"), "the quick brown fox", 4, RequestClass::default())
            .expect("turn 1");
        let before = fe.session_history_len("s");
        // A turn that would blow the 64-token arena: rejected, history
        // unchanged (the failed turn must not poison the session).
        let long = "word ".repeat(80);
        assert!(fe
            .submit_text_session(Some("s"), &long, 4, RequestClass::default())
            .is_err());
        assert_eq!(fe.session_history_len("s"), before);

        // A reply that overflows the arena poisons the session: the
        // history is not silently truncated — the next turn is refused.
        let big_reply: Vec<u32> = vec![7; 64];
        fe.record_session_reply("s", &big_reply);
        assert_eq!(fe.session_history_len("s"), before, "overflowing reply not appended");
        assert!(
            fe.submit_text_session(Some("s"), "next", 2, RequestClass::default()).is_err(),
            "poisoned session must refuse further turns"
        );
        // Other sessions are unaffected.
        assert!(fe.submit_text_session(Some("s2"), "hi", 2, RequestClass::default()).is_ok());

        // A failed turn poisons its session the same way: the stored
        // history contains an unanswered user turn.
        fe.poison_session("s2");
        assert!(
            fe.submit_text_session(Some("s2"), "more", 2, RequestClass::default()).is_err(),
            "poisoned (failed-turn) session must refuse further turns"
        );
    }
}
