//! The DPU frontend (paper §4.4): request lifecycle from arrival to token
//! delivery, running on "BlueField ARM cores" (its own threads), touching
//! backend state *only* through one-sided RDMA work requests.
//!
//! Subsystems, as in the paper:
//! * request tracker — per-request state: slot assignment, token counts,
//!   completion status ([`tracker`]);
//! * slot tracker — local availability cache + hint-based circular scan,
//!   so submission does not scan the remote ring ([`slot_tracker`]);
//! * token reader — background thread: one bulk RDMA metadata read per
//!   cycle, urgent-slot prioritization for TTFT, adaptive polling
//!   ([`token_reader`]);
//! * tokenizer — `crate::tokenizer::blink` (shared, zero-alloc request
//!   path).

pub mod slot_tracker;
pub mod token_reader;
pub mod tracker;

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};

use crate::rdma::{Payload, QueuePair, RdmaEngine, RdmaOp};
use crate::tokenizer::blink::BlinkTokenizer;
use crate::tokenizer::{Tokenizer, Vocab};
use slot_tracker::SlotTracker;
use token_reader::ReaderConfig;
use tracker::{ReqState, TokenEvent, Tracker};

#[derive(Debug, Clone)]
pub struct FrontendConfig {
    pub num_slots: usize,
    pub max_prompt: usize,
    pub max_output: usize,
    pub reader: ReaderConfig,
}

/// Request class carried from the HTTP body to the scheduler's admission
/// policy: a base priority (higher = more important) and an optional
/// TTFT budget. The default — priority 0, no budget — reproduces the
/// paper's single-class FCFS behavior exactly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequestClass {
    /// Higher = more important; 0 = batch/default.
    pub priority: u32,
    /// Relative TTFT budget in µs; 0 = no deadline.
    pub ttft_budget_us: u64,
}

impl RequestClass {
    pub fn interactive(ttft_budget_us: u64) -> RequestClass {
        RequestClass { priority: 4, ttft_budget_us }
    }
}

/// A submitted request: stream of token events + ids for bookkeeping.
pub struct RequestHandle {
    pub request_id: u64,
    pub slot: usize,
    pub prompt_tokens: usize,
    pub rx: Receiver<TokenEvent>,
}

impl RequestHandle {
    /// Drain to completion, returning all generated tokens (blocking).
    pub fn collect(self) -> Result<Vec<u32>, String> {
        let mut toks = vec![];
        loop {
            match self.rx.recv() {
                Ok(TokenEvent::Token(t)) => toks.push(t),
                Ok(TokenEvent::Done) => return Ok(toks),
                Ok(TokenEvent::Failed) => return Err("request failed".into()),
                Err(_) => return Err("frontend dropped".into()),
            }
        }
    }
}

pub struct DpuFrontend {
    submit_qp: Mutex<QueuePair>,
    tracker: Arc<Mutex<Tracker>>,
    slots: Arc<Mutex<SlotTracker>>,
    urgent: Arc<AtomicU32>,
    stop: Arc<AtomicBool>,
    reader_handle: Option<std::thread::JoinHandle<()>>,
    pub tokenizer: Arc<BlinkTokenizer>,
    pub vocab: Arc<Vocab>,
    next_id: AtomicU64,
    config: FrontendConfig,
    seed_ctr: AtomicU32,
}

impl DpuFrontend {
    pub fn new(
        engine: Arc<RdmaEngine>,
        vocab: Arc<Vocab>,
        config: FrontendConfig,
    ) -> DpuFrontend {
        let tokenizer = Arc::new(BlinkTokenizer::new(&vocab));
        let tracker = Arc::new(Mutex::new(Tracker::new()));
        let slots = Arc::new(Mutex::new(SlotTracker::new(config.num_slots)));
        let urgent = Arc::new(AtomicU32::new(0));
        let stop = Arc::new(AtomicBool::new(false));

        let reader_qp = QueuePair::new(engine.clone());
        let reader_handle = token_reader::spawn(
            reader_qp,
            tracker.clone(),
            slots.clone(),
            urgent.clone(),
            stop.clone(),
            config.num_slots,
            config.reader.clone(),
        );

        DpuFrontend {
            submit_qp: Mutex::new(QueuePair::new(engine)),
            tracker,
            slots,
            urgent,
            stop,
            reader_handle: Some(reader_handle),
            tokenizer,
            vocab,
            next_id: AtomicU64::new(1),
            config,
            seed_ctr: AtomicU32::new(0x5EED),
        }
    }

    /// Tokenize on the DPU and submit (the paper's step ②③④⑤),
    /// default (batch) request class.
    pub fn submit_text(&self, text: &str, max_new: u32) -> Result<RequestHandle, String> {
        self.submit_text_class(text, max_new, RequestClass::default())
    }

    /// Tokenize and submit with an explicit request class.
    pub fn submit_text_class(
        &self,
        text: &str,
        max_new: u32,
        class: RequestClass,
    ) -> Result<RequestHandle, String> {
        let mut toks = Vec::with_capacity(text.len() / 3 + 4);
        self.tokenizer.encode(text, &mut toks);
        self.submit_tokens_class(&toks, max_new, class)
    }

    /// Submit pre-tokenized input (workload generators / benches),
    /// default (batch) request class.
    pub fn submit_tokens(&self, tokens: &[u32], max_new: u32) -> Result<RequestHandle, String> {
        self.submit_tokens_class(tokens, max_new, RequestClass::default())
    }

    /// Submit pre-tokenized input with an explicit request class.
    pub fn submit_tokens_class(
        &self,
        tokens: &[u32],
        max_new: u32,
        class: RequestClass,
    ) -> Result<RequestHandle, String> {
        if tokens.is_empty() {
            return Err("empty prompt".into());
        }
        if tokens.len() > self.config.max_prompt {
            return Err(format!(
                "prompt of {} tokens exceeds arena capacity {}",
                tokens.len(),
                self.config.max_prompt
            ));
        }
        let max_new = max_new.clamp(1, self.config.max_output as u32);
        let request_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let seed = self.seed_ctr.fetch_add(0x9E37, Ordering::Relaxed);

        // Claim a slot: hint-based local scan, RDMA CAS to actually own it.
        let mut qp = self.submit_qp.lock().unwrap();
        let slot = {
            let mut tries = 0;
            loop {
                let candidate = {
                    let mut s = self.slots.lock().unwrap();
                    s.acquire_hint()
                };
                let Some(candidate) = candidate else {
                    return Err("ring buffer full (backpressure)".into());
                };
                match qp.exec(RdmaOp::ClaimSlot { slot: candidate }) {
                    Payload::Cas(true) => break candidate,
                    _ => {
                        // Stale availability cache: mark used, try the next.
                        self.slots.lock().unwrap().mark_used(candidate);
                        tries += 1;
                        if tries > self.config.num_slots {
                            return Err("no free slot after full sweep".into());
                        }
                    }
                }
            }
        };

        // Register with the tracker *before* arming the slot so the token
        // reader can never observe an untracked active slot.
        let (tx, rx) = std::sync::mpsc::channel();
        self.tracker.lock().unwrap().insert(
            slot,
            ReqState::new(request_id, tx),
        );
        self.urgent.fetch_add(1, Ordering::AcqRel);

        // One-sided writes: prompt into the input arena, then metadata +
        // state flip (coalesced by the RDMA engine if bursty).
        qp.post(RdmaOp::WritePrompt { slot, tokens: tokens.to_vec() });
        let wr = qp.post(RdmaOp::Submit {
            slot,
            request_id,
            prompt_len: tokens.len() as u32,
            max_new,
            seed,
            priority: class.priority,
            ttft_budget_us: class.ttft_budget_us,
        });
        qp.wait(wr);

        Ok(RequestHandle { request_id, slot, prompt_tokens: tokens.len(), rx })
    }

    /// Snapshot of free-slot availability (diagnostics).
    pub fn approx_free_slots(&self) -> usize {
        self.slots.lock().unwrap().approx_free()
    }
}

impl Drop for DpuFrontend {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.reader_handle.take() {
            let _ = h.join();
        }
    }
}
