//! Slot tracker (paper §4.4): a DPU-local availability cache over the
//! remote ring buffer's slots, found via a hint-based circular scan in
//! O(1) amortized, refreshed from the token reader's bulk metadata reads —
//! so submission never scans all slots over RDMA.

pub struct SlotTracker {
    free: Vec<bool>,
    hint: usize,
    n: usize,
}

impl SlotTracker {
    pub fn new(n: usize) -> SlotTracker {
        SlotTracker { free: vec![true; n], hint: 0, n }
    }

    /// Next probably-free slot, starting at the hint (spatial locality:
    /// consecutive submissions land in consecutive slots, which also makes
    /// the scheduler's lane-chunked scan touch fewer cache lines).
    pub fn acquire_hint(&mut self) -> Option<usize> {
        for off in 0..self.n {
            let i = (self.hint + off) % self.n;
            if self.free[i] {
                self.free[i] = false;
                self.hint = (i + 1) % self.n;
                return Some(i);
            }
        }
        None
    }

    pub fn mark_used(&mut self, slot: usize) {
        self.free[slot] = false;
    }

    pub fn mark_free(&mut self, slot: usize) {
        self.free[slot] = true;
    }

    /// Bulk refresh from a metadata snapshot (EMPTY == free).
    pub fn refresh(&mut self, metas: &[crate::rdma::SlotMeta]) {
        for m in metas {
            if m.slot < self.n {
                // Only *freeing* transitions are taken from the snapshot;
                // locally claimed slots stay used until observed EMPTY so a
                // stale snapshot can't hand a slot to two requests.
                if m.state == crate::ringbuf::SlotState::Empty {
                    self.free[m.slot] = true;
                }
            }
        }
    }

    pub fn approx_free(&self) -> usize {
        self.free.iter().filter(|f| **f).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rdma::SlotMeta;
    use crate::ringbuf::SlotState;

    #[test]
    fn circular_hint_scan() {
        let mut t = SlotTracker::new(4);
        assert_eq!(t.acquire_hint(), Some(0));
        assert_eq!(t.acquire_hint(), Some(1));
        t.mark_free(0);
        // Hint is at 2: scan gives 2, 3, then wraps to 0.
        assert_eq!(t.acquire_hint(), Some(2));
        assert_eq!(t.acquire_hint(), Some(3));
        assert_eq!(t.acquire_hint(), Some(0));
        assert_eq!(t.acquire_hint(), None);
    }

    #[test]
    fn refresh_only_frees() {
        let mut t = SlotTracker::new(2);
        t.acquire_hint();
        t.acquire_hint();
        let metas = vec![
            SlotMeta { slot: 0, state: SlotState::Empty, generated: 0, request_id: 0 },
            SlotMeta { slot: 1, state: SlotState::DecodeProcessing, generated: 3, request_id: 9 },
        ];
        t.refresh(&metas);
        assert_eq!(t.approx_free(), 1);
        assert_eq!(t.acquire_hint(), Some(0));
    }
}
