//! Request tracker (paper §4.4): per-request state — slot assignment,
//! token counts, completion status — keyed by slot while in flight.

use std::collections::HashMap;
use std::sync::mpsc::Sender;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokenEvent {
    Token(u32),
    Done,
    Failed,
}

pub struct ReqState {
    pub request_id: u64,
    pub tx: Sender<TokenEvent>,
    /// Tokens already read from the output arena and delivered.
    pub seen: u32,
    pub got_first: bool,
}

impl ReqState {
    pub fn new(request_id: u64, tx: Sender<TokenEvent>) -> ReqState {
        ReqState { request_id, tx, seen: 0, got_first: false }
    }
}

#[derive(Default)]
pub struct Tracker {
    by_slot: HashMap<usize, ReqState>,
}

impl Tracker {
    pub fn new() -> Tracker {
        Tracker { by_slot: HashMap::new() }
    }

    pub fn insert(&mut self, slot: usize, st: ReqState) {
        self.by_slot.insert(slot, st);
    }

    pub fn get_mut(&mut self, slot: usize) -> Option<&mut ReqState> {
        self.by_slot.get_mut(&slot)
    }

    pub fn remove(&mut self, slot: usize) -> Option<ReqState> {
        self.by_slot.remove(&slot)
    }

    pub fn active_slots(&self) -> Vec<usize> {
        self.by_slot.keys().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.by_slot.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_slot.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let mut t = Tracker::new();
        t.insert(3, ReqState::new(42, tx));
        assert_eq!(t.len(), 1);
        t.get_mut(3).unwrap().seen = 5;
        let st = t.remove(3).unwrap();
        assert_eq!(st.request_id, 42);
        assert_eq!(st.seen, 5);
        assert!(t.is_empty());
    }
}
