//! Token reader (paper §4.4): a background DPU thread that polls the
//! ring buffer for generated tokens.
//!
//! Per cycle: one bulk RDMA read refreshes cached slot metadata (the
//! paper's 64 KB read), each active slot's generation count is compared
//! with local state, and new tokens are fetched with targeted RDMA reads.
//! Newly submitted requests are *urgent*: while any request still awaits
//! its first token the reader polls at the minimum interval, bounding
//! TTFT to one poll interval; otherwise the interval adapts (decay up,
//! shrink on activity) to bound per-token latency while limiting RDMA
//! traffic. Completion-queue saturation is avoided by capping per-poll
//! token reads (`max_reads_per_poll`), mirroring the paper's task pools.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::rdma::{Payload, QueuePair, RdmaOp};
use crate::ringbuf::SlotState;

use super::slot_tracker::SlotTracker;
use super::tracker::{TokenEvent, Tracker};

#[derive(Debug, Clone)]
pub struct ReaderConfig {
    pub min_interval_us: u64,
    pub max_interval_us: u64,
    /// Cap on per-cycle ReadTokens ops (CQ saturation guard).
    pub max_reads_per_poll: usize,
}

impl Default for ReaderConfig {
    fn default() -> Self {
        ReaderConfig { min_interval_us: 20, max_interval_us: 2000, max_reads_per_poll: 64 }
    }
}

#[allow(clippy::too_many_arguments)]
pub fn spawn(
    mut qp: QueuePair,
    tracker: Arc<Mutex<Tracker>>,
    slots: Arc<Mutex<SlotTracker>>,
    urgent: Arc<AtomicU32>,
    stop: Arc<AtomicBool>,
    num_slots: usize,
    config: ReaderConfig,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("dpu-token-reader".into())
        .spawn(move || {
            let mut interval_us = config.min_interval_us;
            while !stop.load(Ordering::Acquire) {
                let metas = match qp.exec(RdmaOp::ReadMeta { first_slot: 0, count: num_slots }) {
                    Payload::Meta(m) => m,
                    _ => break,
                };
                // Refresh the submitter's availability cache for free.
                slots.lock().unwrap().refresh(&metas);

                let mut activity = false;
                let mut reads = 0usize;
                for m in &metas {
                    if reads >= config.max_reads_per_poll {
                        break;
                    }
                    // Cheap pre-check before taking the tracker lock.
                    let interesting = matches!(
                        m.state,
                        SlotState::PrefillProcessing
                            | SlotState::DecodeProcessing
                            | SlotState::DecodePaused
                            | SlotState::DecodeCompleted
                            | SlotState::Failed
                    );
                    if !interesting {
                        continue;
                    }
                    let (seen, done, failed) = {
                        let mut t = tracker.lock().unwrap();
                        let Some(st) = t.get_mut(m.slot) else { continue };
                        (st.seen, m.state == SlotState::DecodeCompleted, m.state == SlotState::Failed)
                    };
                    if failed {
                        if let Some(st) = tracker.lock().unwrap().remove(m.slot) {
                            let _ = st.tx.send(TokenEvent::Failed);
                            if !st.got_first {
                                urgent.fetch_sub(1, Ordering::AcqRel);
                            }
                        }
                        qp.post(RdmaOp::ReleaseSlot { slot: m.slot });
                        activity = true;
                        continue;
                    }
                    if m.generated > seen {
                        // Targeted read of just the new tokens.
                        let toks = match qp.exec(RdmaOp::ReadTokens {
                            slot: m.slot,
                            from: seen,
                            to: m.generated,
                        }) {
                            Payload::Tokens(t) => t,
                            _ => continue,
                        };
                        reads += 1;
                        activity = true;
                        let mut t = tracker.lock().unwrap();
                        if let Some(st) = t.get_mut(m.slot) {
                            if !st.got_first {
                                st.got_first = true;
                                urgent.fetch_sub(1, Ordering::AcqRel);
                            }
                            for tok in toks {
                                let _ = st.tx.send(TokenEvent::Token(tok));
                            }
                            st.seen = m.generated;
                        }
                    }
                    if done {
                        // Deliver any straggler tokens then finish. The
                        // completed count is final once DECODE_COMPLETED is
                        // visible (publish precedes the state flip).
                        let final_seen =
                            tracker.lock().unwrap().get_mut(m.slot).map(|s| s.seen);
                        if final_seen == Some(m.generated) {
                            if let Some(st) = tracker.lock().unwrap().remove(m.slot) {
                                let _ = st.tx.send(TokenEvent::Done);
                                if !st.got_first {
                                    urgent.fetch_sub(1, Ordering::AcqRel);
                                }
                            }
                            qp.post(RdmaOp::ReleaseSlot { slot: m.slot });
                            activity = true;
                        }
                        // else: next cycle reads the stragglers first.
                    }
                }
                // Drain release completions (fire-and-forget bookkeeping).
                let _ = qp.poll_cq(usize::MAX);

                // Adaptive interval; urgent submissions pin it to the floor.
                if urgent.load(Ordering::Acquire) > 0 {
                    interval_us = config.min_interval_us;
                } else if activity {
                    interval_us = (interval_us / 2).max(config.min_interval_us);
                } else {
                    interval_us = (interval_us * 3 / 2).min(config.max_interval_us);
                }
                if interval_us >= 200 {
                    std::thread::sleep(Duration::from_micros(interval_us));
                } else {
                    crate::devsim::spin_us(interval_us as f64);
                }
            }
        })
        .expect("spawn token reader")
}
