//! DPU-side overload control (ROADMAP "million-user regime" item): the
//! first layer of the stack that *refuses* work instead of merely
//! ordering it.
//!
//! Three mechanisms compose into one admission gate, checked on every
//! submission before a ring slot is claimed (so rejected work costs the
//! GPU plane nothing):
//!
//! * a **global sliding-window rate limiter** — the classic two-bucket
//!   sliding-window counter: the previous window's count is weighted by
//!   its remaining overlap, so admission is smooth across window edges
//!   without keeping a per-request timestamp log;
//! * **per-tenant token buckets** in a pre-sized slab (no per-request
//!   allocation — the `hotloop_alloc` pin from PR 5 stays intact), so a
//!   single flooding tenant exhausts its own quota instead of the whole
//!   window;
//! * a **shed policy** driven by measured pressure (window utilization
//!   and ring occupancy): under sustained pressure, lowest-class work is
//!   first *degraded* (its `max_new` capped — it still gets an answer,
//!   just a shorter one) and then *dropped*, while interactive-class
//!   admission holds until the hard window cap.
//!
//! Everything is atomics; the gate is lock-free and allocation-free on
//! the admission path. All decisions are computed from a caller-supplied
//! `now_ms` so unit tests and the DES mirror (`sim/des.rs`) are exactly
//! deterministic.

use std::sync::atomic::{AtomicU64, Ordering};

/// Why a submission was refused. The HTTP layer maps `Client` → 400 and
/// `Overload` → 429 + `retry_after_ms`; conflating the two (the pre-PR-8
/// bug) makes retry-after semantics meaningless because a malformed
/// request would also look retryable.
#[derive(Debug, Clone, PartialEq)]
pub enum Rejected {
    /// The request itself is invalid (empty/overlong prompt, inconsistent
    /// session history). Retrying the same request can never succeed.
    Client(String),
    /// The system refused valid work to protect itself (rate limit,
    /// tenant quota, shed, ring backpressure). `retry_after_ms` is a
    /// computed hint: when the window rolls or the bucket refills enough
    /// for one request.
    Overload { reason: String, retry_after_ms: u64 },
}

impl Rejected {
    pub fn message(&self) -> &str {
        match self {
            Rejected::Client(m) => m,
            Rejected::Overload { reason, .. } => reason,
        }
    }
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::Client(m) => write!(f, "{m}"),
            Rejected::Overload { reason, retry_after_ms } => {
                write!(f, "{reason} (retry after {retry_after_ms} ms)")
            }
        }
    }
}

impl From<Rejected> for String {
    fn from(r: Rejected) -> String {
        r.to_string()
    }
}

/// Admission-gate configuration. `Default` is **disabled** (admit
/// everything): overload control is opt-in per server, and every
/// pre-existing test path keeps its exact behavior.
#[derive(Debug, Clone, Copy)]
pub struct OverloadConfig {
    pub enabled: bool,
    /// Global cap: at most this many admissions per sliding window.
    pub window_capacity: u32,
    /// Sliding-window length in milliseconds.
    pub window_ms: u64,
    /// Per-tenant token-bucket burst capacity (requests).
    pub bucket_capacity: f64,
    /// Per-tenant sustained refill rate (requests/second).
    pub bucket_refill_per_s: f64,
    /// Pre-sized tenant slab length (hash-indexed, bounded linear probe).
    pub tenant_slots: usize,
    /// Pressure (max of window utilization and queue occupancy) at which
    /// below-floor work is *degraded*: admitted with `max_new` capped.
    pub degrade_threshold: f64,
    /// Pressure at which below-floor work is *dropped* (429).
    pub drop_threshold: f64,
    /// The `max_new` cap applied to degraded admissions.
    pub degrade_max_new: u32,
    /// Priority at or above which a request is interactive-class: never
    /// shed, only stopped by the hard window cap or its tenant bucket.
    pub interactive_floor: u32,
}

impl Default for OverloadConfig {
    fn default() -> OverloadConfig {
        OverloadConfig {
            enabled: false,
            window_capacity: 256,
            window_ms: 1000,
            bucket_capacity: 64.0,
            bucket_refill_per_s: 128.0,
            tenant_slots: 512,
            degrade_threshold: 0.5,
            drop_threshold: 0.8,
            degrade_max_new: 16,
            interactive_floor: 4,
        }
    }
}

/// Which mechanism refused the request — kept machine-readable so the
/// stats mirror can count window, bucket and shed rejections apart.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectKind {
    /// The global sliding window is at capacity.
    Window,
    /// The tenant's token bucket is empty.
    Bucket,
    /// Best-effort work dropped by the shed policy under pressure.
    Shed,
}

/// Outcome of the gate check for one submission.
///
/// `reason` is `&'static str`, not `String`: the gate is called on every
/// submission and must stay allocation-free under overload — precisely
/// when it runs most often. Dynamic context (tenant id, counters) belongs
/// to the metrics path, not the reject message.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    Admit,
    /// Admitted, but `max_new` must be capped to this value (shed by
    /// degradation: the tenant still gets an answer, just a shorter one).
    Degrade { max_new_cap: u32 },
    Reject { kind: RejectKind, reason: &'static str, retry_after_ms: u64 },
}

/// Token-bucket level is kept in milli-tokens so it fits an atomic u64
/// without floating-point CAS loops.
const MILLI: u64 = 1000;

/// One slab entry: a tenant's token bucket plus admission counters.
/// `key == 0` means unclaimed; [`claim_or_find`](OverloadGate) CASes the
/// key in on first use. All fields are independently atomic — under
/// contention a tenant can very slightly overshoot its bucket (two
/// threads observing the same level), which is acceptable for a limiter
/// whose job is shaping, not accounting.
#[derive(Debug)]
struct TenantBucket {
    // lint: atomic(key) observe=Relaxed rmw=Relaxed # claim arbiter only: the
    // 0->key CAS decides slab ownership, and every other bucket field is
    // pre-initialized in `OverloadGate::new` before the gate is shared, so
    // no release/acquire edge hangs off the key.
    key: AtomicU64,
    // lint: atomic(level_milli) observe=Relaxed rmw=Relaxed # milli-token
    // level; refill/debit race can overshoot by one request, accepted for a
    // limiter that shapes rather than accounts.
    level_milli: AtomicU64,
    // lint: atomic(last_refill_ms) publish=Relaxed observe=Relaxed rmw=Relaxed
    // # refill stamp; a smeared read only smears the next refill amount.
    last_refill_ms: AtomicU64,
    // lint: atomic(admitted) counter
    admitted: AtomicU64,
    // lint: atomic(rejected) counter
    rejected: AtomicU64,
}

impl TenantBucket {
    /// Buckets start *full* (`level_milli == cap_milli`): initializing the
    /// level here, before the gate is ever shared across threads, is what
    /// lets the claim CAS in [`OverloadGate::tenant_slot`] stay `Relaxed` —
    /// there is no post-claim publish of bucket state to order.
    fn fresh(cap_milli: u64) -> TenantBucket {
        TenantBucket {
            key: AtomicU64::new(0),
            level_milli: AtomicU64::new(cap_milli),
            last_refill_ms: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }
}

/// How many slab entries a tenant key may probe before falling back to
/// sharing the home slot (documented collision behavior: two tenants
/// hashing into the same saturated neighborhood share fate, which only
/// matters past `tenant_slots` concurrently active tenants).
const PROBE_LIMIT: usize = 8;

/// Snapshot of one tenant's admission counters (for `/metrics`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantStat {
    pub key: u64,
    pub admitted: u64,
    pub rejected: u64,
}

/// The admission gate. One per [`DpuFrontend`](super::DpuFrontend);
/// shared-reference callable from any submission thread.
#[derive(Debug)]
pub struct OverloadGate {
    cfg: OverloadConfig,
    epoch: std::time::Instant,
    /// Index of the window `cur_count` belongs to (now_ms / window_ms).
    // lint: atomic(cur_window) observe=Relaxed rmw=Relaxed # rotate arbiter:
    // the CAS picks a single rotator per edge; counters it guards tolerate
    // one-window smear by design, so no release edge is required.
    cur_window: AtomicU64,
    // lint: atomic(cur_count) observe=Relaxed rmw=Relaxed # in-window
    // admission count; swap(0) on rotate, estimate reads tolerate smear.
    cur_count: AtomicU64,
    // lint: atomic(prev_count) publish=Relaxed observe=Relaxed # previous
    // window's carried count; staleness is bounded by one window edge.
    prev_count: AtomicU64,
    /// Aggregate counters, mirrored into `SchedulerStats` by the caller.
    // lint: atomic(admitted) counter
    pub admitted: AtomicU64,
    // lint: atomic(rejected_rate) counter
    pub rejected_rate: AtomicU64,
    // lint: atomic(rejected_bucket) counter
    pub rejected_bucket: AtomicU64,
    // lint: atomic(shed_dropped) counter
    pub shed_dropped: AtomicU64,
    // lint: atomic(shed_degraded) counter
    pub shed_degraded: AtomicU64,
    buckets: Box<[TenantBucket]>,
}

impl OverloadGate {
    pub fn new(cfg: OverloadConfig) -> OverloadGate {
        let slots = cfg.tenant_slots.max(1);
        let cap_milli = (cfg.bucket_capacity * MILLI as f64) as u64;
        let buckets: Vec<TenantBucket> = (0..slots).map(|_| TenantBucket::fresh(cap_milli)).collect();
        OverloadGate {
            cfg,
            epoch: std::time::Instant::now(),
            cur_window: AtomicU64::new(0),
            cur_count: AtomicU64::new(0),
            prev_count: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected_rate: AtomicU64::new(0),
            rejected_bucket: AtomicU64::new(0),
            shed_dropped: AtomicU64::new(0),
            shed_degraded: AtomicU64::new(0),
            buckets: buckets.into_boxed_slice(),
        }
    }

    pub fn config(&self) -> &OverloadConfig {
        &self.cfg
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Milliseconds since the gate was built (the wall-clock entry point;
    /// the decision logic itself is pure in `now_ms`).
    pub fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Gate one submission. `queue_occupancy` is the ring's fill fraction
    /// (0..=1), folded into shed pressure so a backlog the window cannot
    /// see (slow drains) still sheds best-effort work.
    // lint: no_alloc no_panic
    pub fn check(
        &self,
        tenant: u64,
        priority: u32,
        queue_occupancy: f64,
        now_ms: u64,
    ) -> Decision {
        if !self.cfg.enabled {
            return Decision::Admit;
        }

        // 1. Tenant bucket: refill by elapsed time, then require one
        //    whole token. Checked first so a flooding tenant is charged
        //    to its own quota before it can load the global window.
        let slot = self.tenant_slot(tenant);
        if let Some(retry) = self.bucket_deficit_ms(slot, now_ms) {
            self.rejected_bucket.fetch_add(1, Ordering::Relaxed);
            self.buckets[slot].rejected.fetch_add(1, Ordering::Relaxed);
            return Decision::Reject {
                kind: RejectKind::Bucket,
                reason: "tenant over per-tenant quota",
                retry_after_ms: retry,
            };
        }

        // 2. Global sliding window + class-aware shed.
        self.roll_window(now_ms);
        let est = self.window_estimate(now_ms);
        let cap = self.cfg.window_capacity as f64;
        let pressure = (est / cap).max(queue_occupancy);
        let retry_window = (self.cfg.window_ms - now_ms % self.cfg.window_ms).max(1);

        let interactive = priority >= self.cfg.interactive_floor;
        if est >= cap {
            // Hard cap: nothing more fits this window, any class.
            self.rejected_rate.fetch_add(1, Ordering::Relaxed);
            self.buckets[slot].rejected.fetch_add(1, Ordering::Relaxed);
            return Decision::Reject {
                kind: RejectKind::Window,
                reason: "rate limit: admission window full",
                retry_after_ms: retry_window,
            };
        }
        if !interactive {
            if pressure >= self.cfg.drop_threshold {
                self.shed_dropped.fetch_add(1, Ordering::Relaxed);
                self.buckets[slot].rejected.fetch_add(1, Ordering::Relaxed);
                return Decision::Reject {
                    kind: RejectKind::Shed,
                    reason: "shedding best-effort work under overload",
                    retry_after_ms: retry_window,
                };
            }
            if pressure >= self.cfg.degrade_threshold {
                self.commit(slot, now_ms);
                self.shed_degraded.fetch_add(1, Ordering::Relaxed);
                return Decision::Degrade { max_new_cap: self.cfg.degrade_max_new };
            }
        }
        self.commit(slot, now_ms);
        Decision::Admit
    }

    /// Record an admission: debit the tenant bucket, count it in the
    /// current window.
    // lint: no_alloc no_panic
    fn commit(&self, slot: usize, now_ms: u64) {
        let b = &self.buckets[slot];
        // Saturating debit: refill already guaranteed >= 1 token at
        // check time; a concurrent racer can at worst drive this to 0.
        let _ = b
            .level_milli
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(MILLI))
            });
        b.admitted.fetch_add(1, Ordering::Relaxed);
        b.last_refill_ms.fetch_max(now_ms, Ordering::Relaxed);
        self.cur_count.fetch_add(1, Ordering::Relaxed);
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Rotate the two-bucket window if `now_ms` crossed an edge.
    // lint: no_alloc no_panic
    fn roll_window(&self, now_ms: u64) {
        let w = now_ms / self.cfg.window_ms;
        let cur = self.cur_window.load(Ordering::Relaxed);
        if w == cur {
            return;
        }
        if self
            .cur_window
            .compare_exchange(cur, w, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            let old = self.cur_count.swap(0, Ordering::Relaxed);
            // Adjacent windows overlap; a gap means both are stale.
            let carried = if w == cur + 1 { old } else { 0 };
            self.prev_count.store(carried, Ordering::Relaxed);
        }
    }

    /// Sliding-window admission estimate: current count plus the
    /// previous window weighted by its remaining overlap.
    // lint: no_alloc no_panic
    fn window_estimate(&self, now_ms: u64) -> f64 {
        let frac = (now_ms % self.cfg.window_ms) as f64 / self.cfg.window_ms as f64;
        let cur = self.cur_count.load(Ordering::Relaxed) as f64;
        let prev = self.prev_count.load(Ordering::Relaxed) as f64;
        cur + prev * (1.0 - frac)
    }

    /// Refill the tenant's bucket to `now_ms`; `None` if it now holds at
    /// least one whole token, else the milliseconds until it will.
    // lint: no_alloc no_panic
    fn bucket_deficit_ms(&self, slot: usize, now_ms: u64) -> Option<u64> {
        let b = &self.buckets[slot];
        let last = b.last_refill_ms.load(Ordering::Relaxed);
        let elapsed_ms = now_ms.saturating_sub(last);
        let cap_milli = (self.cfg.bucket_capacity * MILLI as f64) as u64;
        let refill_milli = (self.cfg.bucket_refill_per_s * elapsed_ms as f64) as u64;
        if refill_milli > 0 {
            b.last_refill_ms.store(now_ms, Ordering::Relaxed);
            let _ = b
                .level_milli
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    Some((v + refill_milli).min(cap_milli))
                });
        }
        let level = b.level_milli.load(Ordering::Relaxed);
        if level >= MILLI {
            None
        } else {
            let deficit = MILLI - level;
            let ms = (deficit as f64 / self.cfg.bucket_refill_per_s).ceil() as u64;
            Some(ms.max(1))
        }
    }

    /// Find (or claim) the slab entry for `tenant`. Buckets are built
    /// full in [`OverloadGate::new`], so claiming is *only* the key CAS:
    /// there is no bucket state to publish afterwards, and a racing
    /// prober that wins the `k == key` fast path can never observe a
    /// half-initialized bucket. (The previous scheme stored the level
    /// *after* the CAS, which let a concurrent checker read level 0 and
    /// spuriously reject a fresh tenant.)
    // lint: no_alloc no_panic
    fn tenant_slot(&self, tenant: u64) -> usize {
        // Key 0 is the anonymous/no-tenant pool; it lives in slot 0's
        // neighborhood like any other key but is nudged to 1 so "empty"
        // stays unambiguous in the slab.
        let key = if tenant == 0 { 1 } else { tenant };
        let n = self.buckets.len();
        let home = (key % n as u64) as usize;
        for i in 0..PROBE_LIMIT.min(n) {
            let idx = (home + i) % n;
            let b = &self.buckets[idx];
            let k = b.key.load(Ordering::Relaxed);
            if k == key {
                return idx;
            }
            if k == 0 {
                match b.key.compare_exchange(0, key, Ordering::Relaxed, Ordering::Relaxed) {
                    Ok(_) => return idx,
                    Err(actual) if actual == key => return idx,
                    Err(_) => continue,
                }
            }
        }
        // Probe exhausted: share the home slot (documented fate-sharing
        // past `tenant_slots` active tenants — quota shaping degrades
        // gracefully instead of allocating).
        home
    }

    /// Per-tenant admission counters for `/metrics`, in slab order.
    /// Allocates (it's the metrics path, not the admission path).
    pub fn tenant_stats(&self) -> Vec<TenantStat> {
        self.buckets
            .iter()
            .filter(|b| b.key.load(Ordering::Relaxed) != 0)
            .map(|b| TenantStat {
                key: b.key.load(Ordering::Relaxed),
                admitted: b.admitted.load(Ordering::Relaxed),
                rejected: b.rejected.load(Ordering::Relaxed),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> OverloadConfig {
        OverloadConfig {
            enabled: true,
            window_capacity: 8,
            window_ms: 1000,
            bucket_capacity: 100.0,
            bucket_refill_per_s: 1000.0,
            tenant_slots: 16,
            degrade_threshold: 0.5,
            drop_threshold: 0.75,
            degrade_max_new: 4,
            interactive_floor: 4,
        }
    }

    #[test]
    fn disabled_gate_admits_everything() {
        let g = OverloadGate::new(OverloadConfig::default());
        for i in 0..10_000 {
            assert_eq!(g.check(i, 0, 1.0, 0), Decision::Admit);
        }
        assert_eq!(g.admitted.load(Ordering::Relaxed), 0, "disabled gate counts nothing");
    }

    #[test]
    fn window_caps_interactive_and_reports_retry_after() {
        let g = OverloadGate::new(cfg());
        for i in 0..8 {
            assert_eq!(g.check(1, 7, 0.0, 100 + i), Decision::Admit, "under cap");
        }
        match g.check(1, 7, 0.0, 200) {
            Decision::Reject { retry_after_ms, .. } => {
                assert_eq!(retry_after_ms, 800, "time to the window edge");
            }
            d => panic!("expected hard-cap reject, got {d:?}"),
        }
        assert_eq!(g.rejected_rate.load(Ordering::Relaxed), 1);
        // The window rolls: admission resumes, with the previous
        // window's weight decaying across the new one.
        assert_eq!(g.check(1, 7, 0.0, 1999), Decision::Admit, "old window nearly decayed");
    }

    #[test]
    fn shed_degrades_then_drops_batch_while_interactive_holds() {
        let g = OverloadGate::new(cfg());
        // Fill to 50% (4 of 8): batch now degrades, interactive admits.
        for i in 0..4 {
            assert_eq!(g.check(1, 4, 0.0, i), Decision::Admit);
        }
        assert_eq!(
            g.check(2, 0, 0.0, 10),
            Decision::Degrade { max_new_cap: 4 },
            "batch degrades at 50% pressure"
        );
        assert_eq!(g.check(1, 4, 0.0, 11), Decision::Admit, "interactive holds");
        // Fill to 75%: batch drops outright.
        g.check(1, 4, 0.0, 12);
        match g.check(2, 0, 0.0, 13) {
            Decision::Reject { reason, .. } => assert!(reason.contains("shed"), "{reason}"),
            d => panic!("expected shed drop, got {d:?}"),
        }
        assert_eq!(g.check(1, 7, 0.0, 14), Decision::Admit, "interactive still admitted");
        assert_eq!(g.shed_degraded.load(Ordering::Relaxed), 1);
        assert_eq!(g.shed_dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn queue_occupancy_alone_triggers_shedding() {
        let g = OverloadGate::new(cfg());
        // Empty window but a nearly-full ring: batch is degraded/dropped,
        // interactive admitted.
        assert_eq!(g.check(1, 0, 0.6, 0), Decision::Degrade { max_new_cap: 4 });
        match g.check(1, 0, 0.9, 1) {
            Decision::Reject { .. } => {}
            d => panic!("expected drop at 0.9 occupancy, got {d:?}"),
        }
        assert_eq!(g.check(2, 5, 0.9, 2), Decision::Admit);
    }

    #[test]
    fn tenant_bucket_isolates_a_flooding_tenant() {
        let mut c = cfg();
        c.window_capacity = 10_000; // window never binds in this test
        c.bucket_capacity = 3.0;
        c.bucket_refill_per_s = 1.0;
        let g = OverloadGate::new(c);
        // Tenant 7 burns its burst of 3, then is refused with a refill
        // hint; tenant 9 is untouched.
        for _ in 0..3 {
            assert_eq!(g.check(7, 6, 0.0, 0), Decision::Admit);
        }
        match g.check(7, 6, 0.0, 0) {
            Decision::Reject { kind: RejectKind::Bucket, reason, retry_after_ms } => {
                assert!(reason.contains("quota"), "{reason}");
                assert_eq!(retry_after_ms, 1000, "1 token / (1 token/s) = 1000 ms");
            }
            d => panic!("expected bucket reject, got {d:?}"),
        }
        assert_eq!(g.check(9, 6, 0.0, 0), Decision::Admit, "other tenants unaffected");
        // After one second the bucket holds a token again.
        assert_eq!(g.check(7, 6, 0.0, 1001), Decision::Admit);
        assert_eq!(g.rejected_bucket.load(Ordering::Relaxed), 1);
        let stats = g.tenant_stats();
        let t7 = stats.iter().find(|t| t.key == 7).expect("tenant 7 tracked");
        assert_eq!((t7.admitted, t7.rejected), (4, 1));
    }

    #[test]
    fn colliding_tenants_probe_to_distinct_slots() {
        let mut c = cfg();
        c.tenant_slots = 16;
        c.window_capacity = 10_000;
        let g = OverloadGate::new(c);
        // Keys 3, 19, 35 all hash to home slot 3; each must claim its
        // own slab entry so their quotas stay independent.
        for k in [3u64, 19, 35] {
            assert_eq!(g.check(k, 6, 0.0, 0), Decision::Admit);
        }
        let stats = g.tenant_stats();
        for k in [3u64, 19, 35] {
            assert!(stats.iter().any(|t| t.key == k && t.admitted == 1), "tenant {k} tracked");
        }
    }

    #[test]
    fn decisions_are_deterministic_in_now_ms() {
        let run = || {
            let g = OverloadGate::new(cfg());
            (0..200)
                .map(|i| {
                    let d = g.check(i % 5, (i % 8) as u32, 0.0, i * 17);
                    format!("{d:?}")
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
