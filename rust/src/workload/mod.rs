//! Workload generation + SLO metrics (paper §6.1).
//!
//! ShareGPT v3 is not redistributable here; [`TraceGen`] draws
//! input/output lengths from lognormals matched to the paper's reported
//! trace moments (mean input 1019, mean output 463 tokens) — the only
//! properties the scheduler reacts to — plus the synthetic fixed-length
//! workload of §3.2 (1024/512) and a scaled-down variant for the live
//! tiny-model system. Arrivals are Poisson, as in guidellm.

use crate::util::rng::Rng;
use crate::util::stats::LatencySummary;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthModel {
    /// Lognormal(in_mean, out_mean) with the given CVs (ShareGPT-like).
    ShareGpt { in_mean: f64, out_mean: f64, cv: f64 },
    /// Fixed lengths (§3.2's synthetic stress workload).
    Fixed { input: usize, output: usize },
    /// Uniform random in the given ranges (§3.2 variant).
    Uniform { in_lo: usize, in_hi: usize, out_lo: usize, out_hi: usize },
}

impl LengthModel {
    /// The paper's ShareGPT v3 moments.
    pub fn sharegpt() -> LengthModel {
        LengthModel::ShareGpt { in_mean: 1019.0, out_mean: 463.0, cv: 1.1 }
    }

    /// Scaled for the live tiny model (max context 512).
    pub fn sharegpt_tiny() -> LengthModel {
        LengthModel::ShareGpt { in_mean: 60.0, out_mean: 28.0, cv: 0.8 }
    }

    pub fn sample(&self, rng: &mut Rng, max_in: usize, max_out: usize) -> (usize, usize) {
        let (i, o) = match self {
            LengthModel::ShareGpt { in_mean, out_mean, cv } => (
                rng.lognormal_mean_cv(*in_mean, *cv).round() as usize,
                rng.lognormal_mean_cv(*out_mean, *cv).round() as usize,
            ),
            LengthModel::Fixed { input, output } => (*input, *output),
            LengthModel::Uniform { in_lo, in_hi, out_lo, out_hi } => (
                rng.range(*in_lo as u64, *in_hi as u64) as usize,
                rng.range(*out_lo as u64, *out_hi as u64) as usize,
            ),
        };
        (i.clamp(1, max_in), o.clamp(1, max_out))
    }
}

/// One request of a generated trace. Times in seconds.
#[derive(Debug, Clone, Copy)]
pub struct TraceRequest {
    pub id: u64,
    pub arrival_s: f64,
    pub input_tokens: usize,
    pub output_tokens: usize,
}

pub struct TraceGen {
    pub lengths: LengthModel,
    pub max_in: usize,
    pub max_out: usize,
}

impl TraceGen {
    pub fn new(lengths: LengthModel, max_in: usize, max_out: usize) -> TraceGen {
        TraceGen { lengths, max_in, max_out }
    }

    /// Poisson arrivals at `rate` req/s over `window_s` seconds.
    pub fn generate(&self, rng: &mut Rng, rate: f64, window_s: f64) -> Vec<TraceRequest> {
        let mut out = vec![];
        let mut t = 0.0;
        let mut id = 0;
        loop {
            t += rng.exp(rate);
            if t >= window_s {
                break;
            }
            let (i, o) = self.lengths.sample(rng, self.max_in, self.max_out);
            out.push(TraceRequest { id, arrival_s: t, input_tokens: i, output_tokens: o });
            id += 1;
        }
        out
    }
}

/// Per-request measurements (seconds), aggregated into the paper's
/// metrics: TTFT, TPOT = (last - first)/(out - 1), ITL samples.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub id: u64,
    pub arrival_s: f64,
    pub first_token_s: f64,
    pub finish_s: f64,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// Inter-token gaps (seconds); empty for single-token outputs.
    pub itl_s: Vec<f64>,
}

impl RequestMetrics {
    pub fn ttft_ms(&self) -> f64 {
        (self.first_token_s - self.arrival_s) * 1e3
    }

    pub fn tpot_ms(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finish_s - self.first_token_s) / (self.output_tokens - 1) as f64 * 1e3
    }
}

/// Aggregate over one measurement window.
#[derive(Debug, Clone, Default)]
pub struct WindowMetrics {
    pub offered_rate: f64,
    pub window_s: f64,
    pub completed: usize,
    pub ttft: LatencySummary,
    pub tpot: LatencySummary,
    pub itl: LatencySummary,
    pub req_throughput: f64,
    pub decode_tok_s: f64,
    pub prefill_tok_s: f64,
    /// Wall energy per generated token, mJ (filled by the energy model).
    pub energy_mj_per_tok: f64,
}

impl WindowMetrics {
    pub fn from_requests(
        offered_rate: f64,
        window_s: f64,
        reqs: &[RequestMetrics],
    ) -> WindowMetrics {
        // Completion accounting includes a 25 % grace period past the
        // window edge so requests that *arrived* late in the window still
        // count when the system is keeping up (guidellm-style); under
        // saturation, queueing delays far exceed the grace and completions
        // are correctly excluded.
        let done: Vec<&RequestMetrics> =
            reqs.iter().filter(|r| r.finish_s <= window_s * 1.25).collect();
        let ttft: Vec<f64> = done.iter().map(|r| r.ttft_ms()).collect();
        let tpot: Vec<f64> =
            done.iter().filter(|r| r.output_tokens > 1).map(|r| r.tpot_ms()).collect();
        let itl: Vec<f64> =
            done.iter().flat_map(|r| r.itl_s.iter().map(|s| s * 1e3)).collect();
        let out_tokens: usize = done.iter().map(|r| r.output_tokens).sum();
        let in_tokens: usize = done.iter().map(|r| r.input_tokens).sum();
        WindowMetrics {
            offered_rate,
            window_s,
            completed: done.len(),
            ttft: LatencySummary::from_samples(&ttft),
            tpot: LatencySummary::from_samples(&tpot),
            itl: LatencySummary::from_samples(&itl),
            req_throughput: done.len() as f64 / window_s,
            decode_tok_s: out_tokens as f64 / window_s,
            prefill_tok_s: in_tokens as f64 / window_s,
            energy_mj_per_tok: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_close() {
        let g = TraceGen::new(LengthModel::sharegpt(), 8192, 8192);
        let mut rng = Rng::new(1);
        let reqs = g.generate(&mut rng, 10.0, 1000.0);
        let rate = reqs.len() as f64 / 1000.0;
        assert!((rate - 10.0).abs() < 0.5, "rate {rate}");
        // Arrivals strictly increasing.
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn sharegpt_means_close() {
        let g = TraceGen::new(LengthModel::sharegpt(), 100_000, 100_000);
        let mut rng = Rng::new(2);
        let reqs = g.generate(&mut rng, 50.0, 2000.0);
        let mi: f64 =
            reqs.iter().map(|r| r.input_tokens as f64).sum::<f64>() / reqs.len() as f64;
        let mo: f64 =
            reqs.iter().map(|r| r.output_tokens as f64).sum::<f64>() / reqs.len() as f64;
        assert!((mi / 1019.0 - 1.0).abs() < 0.1, "input mean {mi}");
        assert!((mo / 463.0 - 1.0).abs() < 0.1, "output mean {mo}");
    }

    #[test]
    fn lengths_clamped() {
        let g = TraceGen::new(LengthModel::Fixed { input: 9999, output: 9999 }, 512, 128);
        let mut rng = Rng::new(3);
        let reqs = g.generate(&mut rng, 5.0, 10.0);
        assert!(reqs.iter().all(|r| r.input_tokens == 512 && r.output_tokens == 128));
    }

    #[test]
    fn metrics_math() {
        let r = RequestMetrics {
            id: 0,
            arrival_s: 1.0,
            first_token_s: 1.5,
            finish_s: 2.5,
            input_tokens: 10,
            output_tokens: 11,
            itl_s: vec![0.1; 10],
        };
        assert!((r.ttft_ms() - 500.0).abs() < 1e-9);
        assert!((r.tpot_ms() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn window_excludes_unfinished() {
        let mk = |finish| RequestMetrics {
            id: 0,
            arrival_s: 0.0,
            first_token_s: 0.5,
            finish_s: finish,
            input_tokens: 5,
            output_tokens: 2,
            itl_s: vec![0.01],
        };
        let w = WindowMetrics::from_requests(1.0, 10.0, &[mk(5.0), mk(20.0)]);
        assert_eq!(w.completed, 1);
        assert!((w.req_throughput - 0.1).abs() < 1e-12);
    }
}
