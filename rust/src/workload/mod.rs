//! Workload generation + SLO metrics (paper §6.1).
//!
//! ShareGPT v3 is not redistributable here; [`TraceGen`] draws
//! input/output lengths from lognormals matched to the paper's reported
//! trace moments (mean input 1019, mean output 463 tokens) — the only
//! properties the scheduler reacts to — plus the synthetic fixed-length
//! workload of §3.2 (1024/512) and a scaled-down variant for the live
//! tiny-model system. Arrivals are Poisson, as in guidellm.

use crate::util::rng::Rng;
use crate::util::stats::LatencySummary;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthModel {
    /// Lognormal(in_mean, out_mean) with the given CVs (ShareGPT-like).
    ShareGpt { in_mean: f64, out_mean: f64, cv: f64 },
    /// Fixed lengths (§3.2's synthetic stress workload).
    Fixed { input: usize, output: usize },
    /// Uniform random in the given ranges (§3.2 variant).
    Uniform { in_lo: usize, in_hi: usize, out_lo: usize, out_hi: usize },
}

impl LengthModel {
    /// The paper's ShareGPT v3 moments.
    pub fn sharegpt() -> LengthModel {
        LengthModel::ShareGpt { in_mean: 1019.0, out_mean: 463.0, cv: 1.1 }
    }

    /// Scaled for the live tiny model (max context 512).
    pub fn sharegpt_tiny() -> LengthModel {
        LengthModel::ShareGpt { in_mean: 60.0, out_mean: 28.0, cv: 0.8 }
    }

    /// Sampled (input, output) token lengths, clamped to `[1, max]` at
    /// the sampler itself: a lognormal draw rounds to 0 for small
    /// means, `Fixed`/`Uniform` accept 0 bounds, and a 0-token length
    /// downstream lands in the scheduler's invalid-request fail path —
    /// skewing exactly the policy-comparison metrics the traces feed.
    /// The caps are floored at 1 too, so a degenerate `max_in`/`max_out`
    /// of 0 cannot panic the clamp.
    pub fn sample(&self, rng: &mut Rng, max_in: usize, max_out: usize) -> (usize, usize) {
        let (i, o) = match self {
            LengthModel::ShareGpt { in_mean, out_mean, cv } => (
                rng.lognormal_mean_cv(*in_mean, *cv).round() as usize,
                rng.lognormal_mean_cv(*out_mean, *cv).round() as usize,
            ),
            LengthModel::Fixed { input, output } => (*input, *output),
            LengthModel::Uniform { in_lo, in_hi, out_lo, out_hi } => (
                rng.range(*in_lo as u64, *in_hi as u64) as usize,
                rng.range(*out_lo as u64, *out_hi as u64) as usize,
            ),
        };
        (i.clamp(1, max_in.max(1)), o.clamp(1, max_out.max(1)))
    }
}

/// One request of a generated trace. Times in seconds.
#[derive(Debug, Clone, Copy)]
pub struct TraceRequest {
    pub id: u64,
    pub arrival_s: f64,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// Request class: higher = more important; 0 = batch/default.
    pub priority: u32,
    /// Relative TTFT budget in seconds; 0 = no deadline.
    pub ttft_budget_s: f64,
    /// Conversation-session tag; 0 = single-turn request (no session).
    pub session_id: u64,
    /// Leading prompt tokens that repeat the session's earlier turns
    /// (system prompt + prior user/assistant exchanges) — the part a
    /// prefix cache can serve. Always < `input_tokens`; 0 for turn 1.
    pub history_tokens: usize,
    /// Tenant tag for per-tenant admission quotas (DESIGN.md §9);
    /// 0 = the shared anonymous pool. Generators emit 0; overload
    /// scenarios stamp tenants post-generation ([`assign_tenants`]).
    pub tenant: u64,
}

pub struct TraceGen {
    pub lengths: LengthModel,
    pub max_in: usize,
    pub max_out: usize,
}

impl TraceGen {
    pub fn new(lengths: LengthModel, max_in: usize, max_out: usize) -> TraceGen {
        TraceGen { lengths, max_in, max_out }
    }

    /// Poisson arrivals at `rate` req/s over `window_s` seconds.
    pub fn generate(&self, rng: &mut Rng, rate: f64, window_s: f64) -> Vec<TraceRequest> {
        poisson_trace(rng, rate, window_s, |rng| {
            let (i, o) = self.lengths.sample(rng, self.max_in, self.max_out);
            (i, o, 0, 0.0)
        })
    }
}

/// The one Poisson arrival loop shared by the single-class and mixed
/// generators: `sample` draws `(input, output, priority, ttft_budget_s)`
/// per arrival.
fn poisson_trace<F>(rng: &mut Rng, rate: f64, window_s: f64, mut sample: F) -> Vec<TraceRequest>
where
    F: FnMut(&mut Rng) -> (usize, usize, u32, f64),
{
    let mut out = vec![];
    let mut t = 0.0;
    let mut id = 0;
    loop {
        t += rng.exp(rate);
        if t >= window_s {
            break;
        }
        let (i, o, priority, ttft_budget_s) = sample(rng);
        out.push(TraceRequest {
            id,
            arrival_s: t,
            input_tokens: i,
            output_tokens: o,
            priority,
            ttft_budget_s,
            session_id: 0,
            history_tokens: 0,
            tenant: 0,
        });
        id += 1;
    }
    out
}

/// Stamp tenant tags onto a generated trace for per-tenant quota
/// scenarios: request `i` gets tenant `1 + (i mod tenants)`. With
/// `hot_share > 0`, that fraction of requests (every ⌈1/hot_share⌉-th,
/// deterministically) is instead assigned to tenant 1, modeling one
/// tenant flooding a mostly-uniform population. Tenant ids start at 1 —
/// 0 is the shared anonymous pool.
pub fn assign_tenants(trace: &mut [TraceRequest], tenants: u64, hot_share: f64) {
    let tenants = tenants.max(1);
    let stride = if hot_share > 0.0 { (1.0 / hot_share).ceil().max(1.0) as usize } else { 0 };
    for (i, r) in trace.iter_mut().enumerate() {
        if stride > 0 && i % stride == 0 {
            r.tenant = 1;
        } else {
            r.tenant = 1 + (i as u64 % tenants);
        }
    }
}

/// Admission-gate counters mirrored out of the DES (all-zero when the
/// simulated gate is disabled). `admitted_by_tenant` is sorted by tenant
/// id so downstream CSVs are deterministic.
#[derive(Debug, Clone, Default)]
pub struct OverloadStats {
    /// Requests offered to the gate (= trace length when enabled).
    pub offered: u64,
    pub admitted: u64,
    pub rejected_rate: u64,
    pub rejected_bucket: u64,
    pub shed_dropped: u64,
    pub shed_degraded: u64,
    pub admitted_by_tenant: Vec<(u64, u64)>,
}

impl OverloadStats {
    /// The largest single tenant's share of admissions (1.0 when no
    /// per-tenant accounting ran) — the fairness headline: without
    /// buckets a flooding tenant's share approaches its offered share,
    /// with buckets it is pinned near 1/N.
    pub fn max_tenant_share(&self) -> f64 {
        let total: u64 = self.admitted_by_tenant.iter().map(|(_, n)| n).sum();
        if total == 0 {
            return 1.0;
        }
        let max = self.admitted_by_tenant.iter().map(|(_, n)| *n).max().unwrap_or(0);
        max as f64 / total as f64
    }
}

/// One priority class of a mixed workload.
#[derive(Debug, Clone)]
pub struct ClassSpec {
    pub name: &'static str,
    /// Base priority (higher = more important).
    pub priority: u32,
    /// TTFT budget in ms; 0 = no deadline.
    pub ttft_budget_ms: f64,
    /// Relative arrival weight (normalized over the mix).
    pub weight: f64,
    pub lengths: LengthModel,
}

/// Mixed-priority workload generator: Poisson arrivals whose class is
/// drawn per request by weight — the interactive-vs-batch colocation
/// scenario the policy comparison sweep runs (the scheduling dimension
/// "Serving Hybrid LLM Loads with SLO Guarantees" shows dominates tail
/// latency under mixed loads).
#[derive(Debug, Clone)]
pub struct ClassMix {
    pub classes: Vec<ClassSpec>,
}

impl ClassMix {
    /// The canonical hybrid load: 30 % interactive (short chat-style
    /// prompts, priority 4, 300 ms TTFT budget) + 70 % batch (full
    /// ShareGPT lengths, priority 0, no deadline).
    pub fn interactive_batch() -> ClassMix {
        ClassMix {
            classes: vec![
                ClassSpec {
                    name: "interactive",
                    priority: 4,
                    ttft_budget_ms: 300.0,
                    weight: 0.3,
                    lengths: LengthModel::ShareGpt { in_mean: 128.0, out_mean: 96.0, cv: 0.8 },
                },
                ClassSpec {
                    name: "batch",
                    priority: 0,
                    ttft_budget_ms: 0.0,
                    weight: 0.7,
                    lengths: LengthModel::sharegpt(),
                },
            ],
        }
    }

    fn sample_class(&self, rng: &mut Rng) -> &ClassSpec {
        let total: f64 = self.classes.iter().map(|c| c.weight).sum();
        let mut x = rng.f64() * total;
        for c in &self.classes {
            if x < c.weight {
                return c;
            }
            x -= c.weight;
        }
        self.classes.last().expect("non-empty mix")
    }

    /// Poisson arrivals at `rate` req/s over `window_s`, class drawn per
    /// request.
    pub fn generate(
        &self,
        rng: &mut Rng,
        rate: f64,
        window_s: f64,
        max_in: usize,
        max_out: usize,
    ) -> Vec<TraceRequest> {
        assert!(!self.classes.is_empty(), "empty class mix");
        poisson_trace(rng, rate, window_s, |rng| {
            let class = self.sample_class(rng);
            let (i, o) = class.lengths.sample(rng, max_in, max_out);
            (i, o, class.priority, class.ttft_budget_ms / 1e3)
        })
    }
}

/// Multi-turn conversation workload ([`ClassMix`]-compatible: every turn
/// carries the same priority/TTFT-budget class fields the policy sweep
/// ranks by): sessions arrive Poisson at the offered rate; each session
/// opens with a shared system prompt and then alternates user turns and
/// assistant replies, every turn's prompt repeating the *entire* session
/// history — the workload class where prefix caching dominates serving
/// cost, because without it turn k re-prefills turns 1..k−1 verbatim.
#[derive(Debug, Clone)]
pub struct MultiTurnMix {
    /// Tokens of the shared system prompt opening every session.
    pub system_prompt_tokens: usize,
    /// Probability a session continues after each turn (geometric length;
    /// mean turns ≈ 1/(1−p), capped at `max_turns`).
    pub continue_prob: f64,
    pub max_turns: usize,
    /// Per-turn lengths: `sample()`'s input is the user turn, its output
    /// the assistant reply.
    pub turn_lengths: LengthModel,
    /// Mean client think time between turns, seconds (exponential).
    pub think_time_s: f64,
    /// Class fields stamped on every turn (ClassMix-compatible).
    pub priority: u32,
    pub ttft_budget_ms: f64,
}

impl MultiTurnMix {
    /// The canonical chat workload: 512-token system prompt, ~4 turns per
    /// session of ~96-token user turns and ~96-token replies, 1.5 s think
    /// time. Turn-k prompts reach a few thousand tokens, ~70–80 % of
    /// which is replayed history.
    pub fn chat() -> MultiTurnMix {
        MultiTurnMix {
            system_prompt_tokens: 512,
            continue_prob: 0.75,
            max_turns: 6,
            turn_lengths: LengthModel::ShareGpt { in_mean: 96.0, out_mean: 96.0, cv: 0.6 },
            think_time_s: 1.5,
            priority: 0,
            ttft_budget_ms: 0.0,
        }
    }

    /// Poisson *session* arrivals at `session_rate`/s over `window_s`;
    /// turn k+1 arrives after turn k plus think time and a nominal
    /// service estimate (the DES resolves actual completion times — a
    /// turn arriving before its predecessor finished simply sees less
    /// cached history, as a real impatient client would).
    pub fn generate(
        &self,
        rng: &mut Rng,
        session_rate: f64,
        window_s: f64,
        max_in: usize,
        max_out: usize,
    ) -> Vec<TraceRequest> {
        let mut out: Vec<TraceRequest> = vec![];
        let mut t = 0.0f64;
        let mut id = 0u64;
        let mut session = 1u64;
        loop {
            t += rng.exp(session_rate);
            if t >= window_s {
                break;
            }
            let mut arrival = t;
            let mut history = self.system_prompt_tokens;
            for turn in 0..self.max_turns {
                let (user, reply) = self.turn_lengths.sample(rng, max_in, max_out);
                let input = history + user;
                if input > max_in {
                    break; // context exhausted: the session ends
                }
                out.push(TraceRequest {
                    id,
                    arrival_s: arrival,
                    input_tokens: input,
                    output_tokens: reply,
                    priority: self.priority,
                    ttft_budget_s: self.ttft_budget_ms / 1e3,
                    session_id: session,
                    history_tokens: history,
                    tenant: 0,
                });
                id += 1;
                history = input + reply;
                if turn + 1 >= self.max_turns || rng.f64() >= self.continue_prob {
                    break;
                }
                // Nominal pacing: think time + a rough service estimate
                // (TTFT + decode at ~30 ms/token).
                arrival += rng.exp(1.0 / self.think_time_s) + 0.2 + reply as f64 * 0.03;
                if arrival >= window_s {
                    break;
                }
            }
            session += 1;
        }
        // `total_cmp`, not `partial_cmp(..).unwrap()`: a NaN arrival
        // (degenerate rate config) must not panic the sweep —
        // `SimConfig::validate` rejects such configs up front, and the
        // sort stays total-ordered regardless.
        out.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        out
    }
}

/// Long-prompt colocation workload: a majority of chat-sized prompts
/// plus a heavy tail of document-length prompts — the head-of-line
/// blocking regime chunked prefill targets, where one multi-thousand-
/// token prefill launched whole stalls every in-flight decode lane for
/// its full duration and P99 TPOT collapses.
#[derive(Debug, Clone)]
pub struct LongPromptMix {
    /// Probability a request draws from the long-document model.
    pub long_frac: f64,
    /// The common case: chat-sized prompts.
    pub base: LengthModel,
    /// The heavy tail: document-length prompts, modest outputs.
    pub long: LengthModel,
}

impl LongPromptMix {
    /// The canonical mix: 8 % document-length prompts (4–8k tokens in,
    /// short answers out) over a short-prompt chat majority.
    pub fn document_chat() -> LongPromptMix {
        LongPromptMix {
            long_frac: 0.08,
            base: LengthModel::ShareGpt { in_mean: 160.0, out_mean: 128.0, cv: 0.8 },
            long: LengthModel::Uniform { in_lo: 4096, in_hi: 8192, out_lo: 64, out_hi: 256 },
        }
    }

    /// Poisson arrivals at `rate` req/s over `window_s`, each request's
    /// length model drawn by `long_frac`.
    pub fn generate(
        &self,
        rng: &mut Rng,
        rate: f64,
        window_s: f64,
        max_in: usize,
        max_out: usize,
    ) -> Vec<TraceRequest> {
        poisson_trace(rng, rate, window_s, |rng| {
            let model = if rng.f64() < self.long_frac { &self.long } else { &self.base };
            let (i, o) = model.sample(rng, max_in, max_out);
            (i, o, 0, 0.0)
        })
    }
}

/// Prefix-cache counters for one simulated window (filled by the DES
/// when `SimConfig::prefix_cache_tokens` > 0; all-zero otherwise).
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefixStats {
    /// Admissions that consulted the cache.
    pub lookups: u64,
    /// Admissions that reused at least one token.
    pub hits: u64,
    /// Prompt tokens served from the cache (prefill work avoided).
    pub hit_tokens: u64,
    /// Total prompt tokens of all admitted requests.
    pub input_tokens: u64,
    /// Cached tokens dropped under capacity pressure (LRU).
    pub evicted_tokens: u64,
}

impl PrefixStats {
    /// Fraction of admitted prompt tokens served from the cache.
    pub fn hit_ratio(&self) -> f64 {
        if self.input_tokens == 0 {
            0.0
        } else {
            self.hit_tokens as f64 / self.input_tokens as f64
        }
    }
}

/// Chunked-prefill counters for one simulated window (filled by the DES
/// when `SimConfig::prefill_chunk_tokens` > 0; all-zero otherwise).
/// Mirrors the live scheduler's `chunked_prefills` / `chunk_launches`
/// stats: a request whose uncached suffix spans `s` tokens under a
/// budget of `c` launches ⌈s/c⌉ chunks. Note the live scheduler first
/// normalizes its budget (block-aligned, clamped to the offset grid)
/// while the DES — which has no graph grid — uses the configured value
/// as-is, so counts are directly comparable only when the budget is
/// already block-aligned and on-grid (as the e2e agreement test uses).
#[derive(Debug, Clone, Copy, Default)]
pub struct ChunkStats {
    /// Admissions whose suffix exceeded the budget and went chunked.
    pub chunked_prefills: u64,
    /// Individual chunk launches (per request per chunk, final included).
    pub chunk_launches: u64,
}

/// Per-request measurements (seconds), aggregated into the paper's
/// metrics: TTFT, TPOT = (last - first)/(out - 1), ITL samples.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub id: u64,
    pub arrival_s: f64,
    pub first_token_s: f64,
    pub finish_s: f64,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// Inter-token gaps (seconds); empty for single-token outputs.
    pub itl_s: Vec<f64>,
    /// Request class (mirrors [`TraceRequest`]).
    pub priority: u32,
    pub ttft_budget_s: f64,
}

impl RequestMetrics {
    /// Build metrics from the ring slot's device-plane timestamps
    /// (microseconds since the process epoch, `util::timer::now_us` —
    /// stamped at submit, first published token, and completion),
    /// re-based to `epoch_us` (normally the earliest submit in the run)
    /// so live batch runs aggregate through [`WindowMetrics`] exactly
    /// like simulated traces. The slot plane keeps no per-token stamps,
    /// so `itl_s` is empty — TPOT still follows from first/finish.
    pub fn from_slot_times_us(
        id: u64,
        epoch_us: u64,
        submit_us: u64,
        first_token_us: u64,
        finish_us: u64,
        input_tokens: usize,
        output_tokens: usize,
    ) -> RequestMetrics {
        let rebase = |us: u64| us.saturating_sub(epoch_us) as f64 / 1e6;
        RequestMetrics {
            id,
            arrival_s: rebase(submit_us),
            first_token_s: rebase(first_token_us),
            finish_s: rebase(finish_us),
            input_tokens,
            output_tokens,
            itl_s: vec![],
            priority: 0,
            ttft_budget_s: 0.0,
        }
    }

    pub fn ttft_ms(&self) -> f64 {
        (self.first_token_s - self.arrival_s) * 1e3
    }

    pub fn tpot_ms(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finish_s - self.first_token_s) / (self.output_tokens - 1) as f64 * 1e3
    }
}

/// Per-priority-class TTFT summary (the policy comparison's unit of
/// report: which class pays the queueing under each admission policy).
#[derive(Debug, Clone, Default)]
pub struct ClassTtft {
    pub priority: u32,
    /// Requests measured for this class — the full drained population,
    /// not just window-completed ones (see `from_requests`).
    pub measured: usize,
    pub ttft: LatencySummary,
    /// Fraction of completed requests carrying a TTFT budget that met
    /// it; NaN when no request in the class has a budget.
    pub slo_attainment: f64,
}

/// Aggregate over one measurement window.
#[derive(Debug, Clone, Default)]
pub struct WindowMetrics {
    pub offered_rate: f64,
    pub window_s: f64,
    pub completed: usize,
    pub ttft: LatencySummary,
    pub tpot: LatencySummary,
    pub itl: LatencySummary,
    pub req_throughput: f64,
    pub decode_tok_s: f64,
    pub prefill_tok_s: f64,
    /// Wall energy per generated token, mJ (filled by the energy model).
    pub energy_mj_per_tok: f64,
    /// Prefix-cache hit/evict counters (filled by the DES when reuse is
    /// enabled; all-zero otherwise).
    pub prefix: PrefixStats,
    /// Chunked-prefill counters (filled by the DES when a chunk budget
    /// is set; all-zero otherwise).
    pub chunked: ChunkStats,
    /// Admission-gate counters (filled by the DES when overload control
    /// is configured; all-zero otherwise).
    pub overload: OverloadStats,
    /// Per-priority-class TTFT, highest priority first (single-class
    /// workloads produce one entry with priority 0).
    pub ttft_by_class: Vec<ClassTtft>,
}

impl WindowMetrics {
    pub fn from_requests(
        offered_rate: f64,
        window_s: f64,
        reqs: &[RequestMetrics],
    ) -> WindowMetrics {
        // Completion accounting includes a 25 % grace period past the
        // window edge so requests that *arrived* late in the window still
        // count when the system is keeping up (guidellm-style); under
        // saturation, queueing delays far exceed the grace and completions
        // are correctly excluded.
        let done: Vec<&RequestMetrics> =
            reqs.iter().filter(|r| r.finish_s <= window_s * 1.25).collect();
        let ttft: Vec<f64> = done.iter().map(|r| r.ttft_ms()).collect();
        let tpot: Vec<f64> =
            done.iter().filter(|r| r.output_tokens > 1).map(|r| r.tpot_ms()).collect();
        let itl: Vec<f64> =
            done.iter().flat_map(|r| r.itl_s.iter().map(|s| s * 1e3)).collect();
        let out_tokens: usize = done.iter().map(|r| r.output_tokens).sum();
        let in_tokens: usize = done.iter().map(|r| r.input_tokens).sum();

        // Per-priority-class TTFT, highest priority first. Unlike the
        // throughput accounting above, class summaries cover *every*
        // measured request (including ones finishing in the drain past
        // the window): restricting to the window would censor exactly
        // the starved requests the policy comparison is about, and
        // overstate the starving policy's tail and SLO attainment.
        let mut prios: Vec<u32> = reqs.iter().map(|r| r.priority).collect();
        prios.sort_unstable();
        prios.dedup();
        let ttft_by_class: Vec<ClassTtft> = prios
            .iter()
            .rev()
            .map(|&p| {
                let samples: Vec<f64> =
                    reqs.iter().filter(|r| r.priority == p).map(|r| r.ttft_ms()).collect();
                let with_budget = reqs
                    .iter()
                    .filter(|r| r.priority == p && r.ttft_budget_s > 0.0)
                    .count();
                let met = reqs
                    .iter()
                    .filter(|r| {
                        r.priority == p
                            && r.ttft_budget_s > 0.0
                            && r.ttft_ms() <= r.ttft_budget_s * 1e3
                    })
                    .count();
                ClassTtft {
                    priority: p,
                    measured: samples.len(),
                    ttft: LatencySummary::from_samples(&samples),
                    slo_attainment: if with_budget == 0 {
                        f64::NAN
                    } else {
                        met as f64 / with_budget as f64
                    },
                }
            })
            .collect();

        WindowMetrics {
            offered_rate,
            window_s,
            completed: done.len(),
            ttft: LatencySummary::from_samples(&ttft),
            tpot: LatencySummary::from_samples(&tpot),
            itl: LatencySummary::from_samples(&itl),
            req_throughput: done.len() as f64 / window_s,
            decode_tok_s: out_tokens as f64 / window_s,
            prefill_tok_s: in_tokens as f64 / window_s,
            energy_mj_per_tok: 0.0,
            prefix: PrefixStats::default(),
            chunked: ChunkStats::default(),
            overload: OverloadStats::default(),
            ttft_by_class,
        }
    }

    /// The class summary for `priority`, if any request of that class
    /// completed in the window.
    pub fn class(&self, priority: u32) -> Option<&ClassTtft> {
        self.ttft_by_class.iter().find(|c| c.priority == priority)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_times_rebase_to_epoch() {
        let (epoch, submit, first, finish) = (1_000_000u64, 1_200_000, 1_500_000, 2_700_000);
        let r = RequestMetrics::from_slot_times_us(3, epoch, submit, first, finish, 64, 13);
        assert!((r.arrival_s - 0.2).abs() < 1e-9);
        assert!((r.ttft_ms() - 300.0).abs() < 1e-6);
        // TPOT = (finish - first) / (out - 1) = 1.2 s / 12.
        assert!((r.tpot_ms() - 100.0).abs() < 1e-6);
        // Timestamps before the epoch clamp to 0 rather than go negative.
        let t = 1_000_000u64;
        let c = RequestMetrics::from_slot_times_us(0, 5_000_000, t, t, t, 1, 1);
        assert_eq!(c.arrival_s, 0.0);
    }

    #[test]
    fn poisson_rate_close() {
        let g = TraceGen::new(LengthModel::sharegpt(), 8192, 8192);
        let mut rng = Rng::new(1);
        let reqs = g.generate(&mut rng, 10.0, 1000.0);
        let rate = reqs.len() as f64 / 1000.0;
        assert!((rate - 10.0).abs() < 0.5, "rate {rate}");
        // Arrivals strictly increasing.
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn sharegpt_means_close() {
        let g = TraceGen::new(LengthModel::sharegpt(), 100_000, 100_000);
        let mut rng = Rng::new(2);
        let reqs = g.generate(&mut rng, 50.0, 2000.0);
        let mi: f64 =
            reqs.iter().map(|r| r.input_tokens as f64).sum::<f64>() / reqs.len() as f64;
        let mo: f64 =
            reqs.iter().map(|r| r.output_tokens as f64).sum::<f64>() / reqs.len() as f64;
        assert!((mi / 1019.0 - 1.0).abs() < 0.1, "input mean {mi}");
        assert!((mo / 463.0 - 1.0).abs() < 0.1, "output mean {mo}");
    }

    #[test]
    fn lengths_clamped() {
        let g = TraceGen::new(LengthModel::Fixed { input: 9999, output: 9999 }, 512, 128);
        let mut rng = Rng::new(3);
        let reqs = g.generate(&mut rng, 5.0, 10.0);
        assert!(reqs.iter().all(|r| r.input_tokens == 512 && r.output_tokens == 128));
    }

    /// Regression (sampler clamp): tiny lognormal means round to 0 and
    /// `Fixed`/`Uniform` accept 0 bounds — the sampler itself must
    /// never emit a 0-token prompt or output (0-length requests land in
    /// the scheduler's invalid-request fail path and skew comparison
    /// metrics), and a degenerate 0 cap must not panic the clamp.
    #[test]
    fn sampler_never_emits_zero_lengths() {
        let mut rng = Rng::new(17);
        let tiny = LengthModel::ShareGpt { in_mean: 0.1, out_mean: 0.1, cv: 0.5 };
        for _ in 0..500 {
            let (i, o) = tiny.sample(&mut rng, 8192, 4096);
            assert!(i >= 1 && o >= 1, "lognormal sample clamped to ≥1");
        }
        let (i, o) = LengthModel::Fixed { input: 0, output: 0 }.sample(&mut rng, 512, 128);
        assert_eq!((i, o), (1, 1));
        let zero_ranges =
            LengthModel::Uniform { in_lo: 0, in_hi: 1, out_lo: 0, out_hi: 1 };
        for _ in 0..50 {
            let (i, o) = zero_ranges.sample(&mut rng, 512, 128);
            assert!(i >= 1 && o >= 1);
        }
        // 0-token caps: clamp floors at 1 instead of panicking.
        let (i, o) = LengthModel::Fixed { input: 5, output: 5 }.sample(&mut rng, 0, 0);
        assert_eq!((i, o), (1, 1));
    }

    #[test]
    fn long_prompt_mix_has_heavy_tail() {
        let mix = LongPromptMix::document_chat();
        let mut rng = Rng::new(23);
        let reqs = mix.generate(&mut rng, 40.0, 500.0, 8192, 4096);
        assert!(!reqs.is_empty());
        let long: Vec<&TraceRequest> =
            reqs.iter().filter(|r| r.input_tokens >= 4096).collect();
        let frac = long.len() as f64 / reqs.len() as f64;
        assert!(
            (frac - mix.long_frac).abs() < 0.03,
            "long fraction {frac:.3} vs configured {}",
            mix.long_frac
        );
        // The tail dominates offered prefill work despite its rarity —
        // the property that makes whole-prompt prefill a decode-stall
        // problem.
        let long_tokens: usize = long.iter().map(|r| r.input_tokens).sum();
        let all_tokens: usize = reqs.iter().map(|r| r.input_tokens).sum();
        assert!(
            long_tokens * 2 > all_tokens,
            "document prompts should carry most prefill tokens: {long_tokens}/{all_tokens}"
        );
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s, "Poisson arrivals strictly increase");
        }
    }

    #[test]
    fn metrics_math() {
        let r = RequestMetrics {
            id: 0,
            arrival_s: 1.0,
            first_token_s: 1.5,
            finish_s: 2.5,
            input_tokens: 10,
            output_tokens: 11,
            itl_s: vec![0.1; 10],
            priority: 0,
            ttft_budget_s: 0.0,
        };
        assert!((r.ttft_ms() - 500.0).abs() < 1e-9);
        assert!((r.tpot_ms() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn window_excludes_unfinished() {
        let mk = |finish| RequestMetrics {
            id: 0,
            arrival_s: 0.0,
            first_token_s: 0.5,
            finish_s: finish,
            input_tokens: 5,
            output_tokens: 2,
            itl_s: vec![0.01],
            priority: 0,
            ttft_budget_s: 0.0,
        };
        let w = WindowMetrics::from_requests(1.0, 10.0, &[mk(5.0), mk(20.0)]);
        assert_eq!(w.completed, 1);
        assert!((w.req_throughput - 0.1).abs() < 1e-12);
    }

    #[test]
    fn class_mix_weights_and_fields() {
        let mix = ClassMix::interactive_batch();
        let mut rng = Rng::new(7);
        let reqs = mix.generate(&mut rng, 40.0, 500.0, 8192, 4096);
        let inter: Vec<&TraceRequest> = reqs.iter().filter(|r| r.priority == 4).collect();
        let batch: Vec<&TraceRequest> = reqs.iter().filter(|r| r.priority == 0).collect();
        assert_eq!(inter.len() + batch.len(), reqs.len());
        let frac = inter.len() as f64 / reqs.len() as f64;
        assert!((frac - 0.3).abs() < 0.05, "interactive fraction {frac}");
        assert!(inter.iter().all(|r| (r.ttft_budget_s - 0.3).abs() < 1e-12));
        assert!(batch.iter().all(|r| r.ttft_budget_s == 0.0));
        // Interactive prompts are much shorter on average.
        let mi = inter.iter().map(|r| r.input_tokens as f64).sum::<f64>() / inter.len() as f64;
        let mb = batch.iter().map(|r| r.input_tokens as f64).sum::<f64>() / batch.len() as f64;
        assert!(mi * 3.0 < mb, "interactive mean {mi} vs batch mean {mb}");
    }

    #[test]
    fn multi_turn_histories_grow_and_stay_cacheable() {
        let mix = MultiTurnMix::chat();
        let mut rng = Rng::new(11);
        let reqs = mix.generate(&mut rng, 6.0, 300.0, 8192, 4096);
        assert!(!reqs.is_empty());
        // Arrivals sorted; histories strictly below inputs.
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let mut by_session: std::collections::HashMap<u64, Vec<&TraceRequest>> =
            std::collections::HashMap::new();
        for r in &reqs {
            assert!(r.session_id != 0);
            assert!(r.history_tokens < r.input_tokens, "history must leave a fresh suffix");
            by_session.entry(r.session_id).or_default().push(r);
        }
        let mut multi = 0usize;
        for turns in by_session.values_mut() {
            turns.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            assert_eq!(
                turns[0].history_tokens,
                mix.system_prompt_tokens,
                "turn 1 history is exactly the shared system prompt"
            );
            for w in turns.windows(2) {
                // Turn k+1 replays turn k's prompt *and* its reply.
                assert_eq!(
                    w[1].history_tokens,
                    w[0].input_tokens + w[0].output_tokens,
                    "history grows by the previous turn's input + reply"
                );
            }
            if turns.len() > 1 {
                multi += 1;
            }
        }
        // Geometric continuation at 0.75 → most sessions are multi-turn.
        assert!(
            multi * 2 > by_session.len(),
            "most sessions should have >1 turn: {multi}/{}",
            by_session.len()
        );
        // The cacheable fraction of the offered prompt tokens is large —
        // this is the property the prefix cache exploits.
        let input: usize = reqs.iter().map(|r| r.input_tokens).sum();
        let hist: usize = reqs.iter().map(|r| r.history_tokens).sum();
        assert!(
            hist as f64 > 0.5 * input as f64,
            "history fraction {:.2} should exceed 0.5",
            hist as f64 / input as f64
        );
    }

    #[test]
    fn prefix_stats_hit_ratio() {
        let mut p = PrefixStats::default();
        assert_eq!(p.hit_ratio(), 0.0);
        p.input_tokens = 1000;
        p.hit_tokens = 650;
        assert!((p.hit_ratio() - 0.65).abs() < 1e-12);
    }

    #[test]
    fn per_class_window_metrics() {
        let mk = |prio: u32, ttft_s: f64, budget: f64| RequestMetrics {
            id: 0,
            arrival_s: 0.0,
            first_token_s: ttft_s,
            finish_s: ttft_s + 1.0,
            input_tokens: 5,
            output_tokens: 2,
            itl_s: vec![0.01],
            priority: prio,
            ttft_budget_s: budget,
        };
        let w = WindowMetrics::from_requests(
            1.0,
            10.0,
            &[mk(4, 0.1, 0.3), mk(4, 0.5, 0.3), mk(0, 2.0, 0.0)],
        );
        assert_eq!(w.ttft_by_class.len(), 2);
        assert_eq!(w.ttft_by_class[0].priority, 4, "highest priority first");
        let inter = w.class(4).unwrap();
        assert_eq!(inter.measured, 2);
        assert!((inter.slo_attainment - 0.5).abs() < 1e-12, "one of two met 300ms");
        let batch = w.class(0).unwrap();
        assert_eq!(batch.measured, 1);
        assert!(batch.slo_attainment.is_nan(), "no budgets in batch class");
        assert!((batch.ttft.p50 - 2000.0).abs() < 1e-9);
    }
}
