//! The launch arena: persistent, device-shaped staging planes for graph
//! launch inputs (paper §4.2 — batch state lives in GPU memory and is
//! updated *in place*; the host never re-marshals it).
//!
//! Before this arena, every control-loop iteration rebuilt four owned
//! `Vec`s (`block_tables` / `seq_lens` / `tokens` / `offsets`) and moved
//! them through `LaunchCmd` — per-iteration host-heap churn, exactly the
//! interference-amplifying orchestration surface the CPU-resident
//! baseline is supposed to demonstrate and the GPU-resident path is
//! supposed to avoid. Now the planes are allocated once at spawn, sized
//! to the widest graph grid, and mutated in place: a steady-state decode
//! step touches one `seq_lens` slot and one `tokens` slot per lane and
//! nothing else.
//!
//! Two independent *regions* keep interleaved launches from clobbering
//! each other's persistent state: the **decode** region holds the live
//! batch (incrementally updated across steps — its `block_tables` rows
//! are rewritten only when batch membership changes), while the
//! **prefill** region is fully restaged per prefill launch (prefill
//! groups are transient by nature). An inline-prefill pause therefore
//! never invalidates the decode region's incremental state.
//!
//! # The epoch / ownership rule (the executor boundary)
//!
//! The scheduler thread is the only writer; the executor thread is the
//! only reader. Each launch follows a strict protocol:
//!
//! 1. scheduler stages a region's planes (relaxed stores, in place),
//! 2. scheduler calls [`LaunchArena::publish`] — a release epoch bump —
//!    and puts the returned epoch into the `LaunchCmd`,
//! 3. executor acquire-loads the epoch; a mismatch with the command's
//!    epoch means the protocol was violated (a second stage before the
//!    completion poll) and the launch must fail rather than read torn
//!    inputs,
//! 4. executor copies the staged extents out of the planes — the one
//!    copy in the whole launch path, at the device boundary where host
//!    memory becomes device buffers — and publishes the completion the
//!    scheduler is polling,
//! 5. only after that poll returns does the scheduler write again.
//!
//! The release/acquire pair on the epoch makes every relaxed plane store
//! (including untouched rows staged under *earlier* epochs — the whole
//! point of incremental update) visible to the executor.

use std::sync::atomic::{AtomicI32, AtomicU64, AtomicUsize, Ordering};

/// Which staging region a launch reads. Decode graphs read the decode
/// region; (offset) prefill graphs read the prefill region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    Decode,
    Prefill,
}

/// Plane capacities, fixed at spawn from the graph grid.
#[derive(Debug, Clone, Copy)]
pub struct ArenaDims {
    /// Widest decode-graph batch.
    pub decode_lanes: usize,
    /// Decode-region token plane width: `decode_lanes` for plain decode
    /// (one token per lane), widened to the largest `batch × (k+1)`
    /// verify window when the grid ships `decode_verify` graphs — the
    /// draft-token plane rides the decode region under the same epoch
    /// protocol, so speculative staging stays zero-allocation.
    pub decode_tokens: usize,
    /// Widest (offset-)prefill-graph batch.
    pub prefill_lanes: usize,
    /// Largest `batch × seq` token plane over all prefill graphs.
    pub prefill_tokens: usize,
    /// Block-table row width (manifest `max_blocks_per_seq`).
    pub max_blocks_per_seq: usize,
}

/// One region's staging planes plus the extents staged for the current
/// launch (what the executor snapshots and validates against the graph).
struct RegionPlanes {
    // lint: atomic(block_tables) plane # staged cells; the epoch
    // release/acquire pair (module docs) publishes them, not the cells.
    block_tables: Vec<AtomicI32>,
    // lint: atomic(seq_lens) plane
    seq_lens: Vec<AtomicI32>,
    // lint: atomic(tokens) plane
    tokens: Vec<AtomicI32>,
    // lint: atomic(offsets) plane
    offsets: Vec<AtomicI32>,
    // lint: atomic(staged_bt) plane
    staged_bt: AtomicUsize,
    // lint: atomic(staged_sl) plane
    staged_sl: AtomicUsize,
    // lint: atomic(staged_tok) plane
    staged_tok: AtomicUsize,
    // lint: atomic(staged_off) plane
    staged_off: AtomicUsize,
}

fn plane(n: usize) -> Vec<AtomicI32> {
    (0..n).map(|_| AtomicI32::new(0)).collect()
}

impl RegionPlanes {
    fn new(lanes: usize, tokens: usize, mbs: usize, with_offsets: bool) -> RegionPlanes {
        RegionPlanes {
            block_tables: plane(lanes * mbs),
            seq_lens: plane(lanes),
            tokens: plane(tokens),
            offsets: plane(if with_offsets { lanes } else { 0 }),
            staged_bt: AtomicUsize::new(0),
            staged_sl: AtomicUsize::new(0),
            staged_tok: AtomicUsize::new(0),
            staged_off: AtomicUsize::new(0),
        }
    }
}

/// The arena itself. See the module docs for the ownership protocol.
pub struct LaunchArena {
    dims: ArenaDims,
    decode: RegionPlanes,
    prefill: RegionPlanes,
    // lint: atomic(epoch) observe=Acquire rmw=Release # the one ordering
    // edge of the arena: the Release bump publishes every relaxed plane
    // store staged before it; the executor's Acquire load receives them.
    epoch: AtomicU64,
}

impl LaunchArena {
    pub fn new(dims: ArenaDims) -> LaunchArena {
        let mbs = dims.max_blocks_per_seq;
        LaunchArena {
            dims,
            // Decode reads one token per lane — or a (k+1)-wide draft
            // window per lane under speculation; offsets never apply.
            decode: RegionPlanes::new(
                dims.decode_lanes,
                dims.decode_tokens.max(dims.decode_lanes),
                mbs,
                false,
            ),
            prefill: RegionPlanes::new(dims.prefill_lanes, dims.prefill_tokens, mbs, true),
            epoch: AtomicU64::new(0),
        }
    }

    pub fn dims(&self) -> ArenaDims {
        self.dims
    }

    fn region(&self, r: Region) -> &RegionPlanes {
        match r {
            Region::Decode => &self.decode,
            Region::Prefill => &self.prefill,
        }
    }

    // --- writer (scheduler thread) ------------------------------------

    /// Write one block-table row: the lane's block list, zero-padded to
    /// the `max_blocks_per_seq` row width (block 0 is never handed out,
    /// matching `SeqCache::table_row`'s padding convention).
    // lint: no_alloc no_panic
    pub fn write_block_row(&self, r: Region, row: usize, blocks: &[u32]) {
        let mbs = self.dims.max_blocks_per_seq;
        let p = &self.region(r).block_tables[row * mbs..(row + 1) * mbs];
        for (j, cell) in p.iter().enumerate() {
            let v = blocks.get(j).map_or(0, |&b| b as i32);
            cell.store(v, Ordering::Relaxed);
        }
    }

    // lint: no_alloc no_panic
    pub fn write_seq_len(&self, r: Region, row: usize, v: i32) {
        self.region(r).seq_lens[row].store(v, Ordering::Relaxed);
    }

    /// Write one token at a flat plane index (decode: index = lane;
    /// decode verify: index = lane × (k+1) + window position; prefill:
    /// index = lane × grid_seq + position — the row-major layouts the
    /// graphs expect).
    // lint: no_alloc no_panic
    pub fn write_token(&self, r: Region, idx: usize, v: i32) {
        self.region(r).tokens[idx].store(v, Ordering::Relaxed);
    }

    /// Per-lane runtime offset (prefill region only).
    // lint: no_alloc no_panic
    pub fn write_offset(&self, row: usize, v: i32) {
        self.prefill.offsets[row].store(v, Ordering::Relaxed);
    }

    /// Record the extents staged for the next launch. Deliberately set
    /// by the *planner* from the shape it marshaled — the executor
    /// validates them against the launched graph's spec, preserving the
    /// planner-vs-graph cross-check the owned-`Vec` path had.
    // lint: no_alloc no_panic
    pub fn stage_extents(&self, r: Region, bt: usize, sl: usize, tok: usize, off: usize) {
        let p = self.region(r);
        debug_assert!(
            bt <= p.block_tables.len()
                && sl <= p.seq_lens.len()
                && tok <= p.tokens.len()
                && off <= p.offsets.len(),
            "staged extents exceed the arena planes"
        );
        p.staged_bt.store(bt, Ordering::Relaxed);
        p.staged_sl.store(sl, Ordering::Relaxed);
        p.staged_tok.store(tok, Ordering::Relaxed);
        p.staged_off.store(off, Ordering::Relaxed);
    }

    /// Release-publish the staged state; the returned epoch goes into
    /// the `LaunchCmd` (protocol step 2 in the module docs).
    // lint: no_alloc no_panic
    pub fn publish(&self) -> u64 {
        self.epoch.fetch_add(1, Ordering::Release) + 1
    }

    // --- reader (executor thread) -------------------------------------

    /// Acquire-load the current epoch (protocol step 3).
    // lint: no_alloc no_panic
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Copy the staged extents into the executor's reusable scratch
    /// buffers (cleared first; no reallocation once grown to the widest
    /// grid) — the single copy at the device boundary. The staged
    /// lengths become the scratch `len()`s, which is what the executors
    /// feed into `validate_launch_shapes`.
    pub fn snapshot_into(
        &self,
        r: Region,
        bt: &mut Vec<i32>,
        sl: &mut Vec<i32>,
        tok: &mut Vec<i32>,
        off: &mut Vec<i32>,
    ) {
        let p = self.region(r);
        let copy = |dst: &mut Vec<i32>, src: &[AtomicI32], staged: &AtomicUsize| {
            dst.clear();
            let n = staged.load(Ordering::Relaxed);
            dst.extend(src[..n].iter().map(|c| c.load(Ordering::Relaxed)));
        };
        copy(bt, &p.block_tables, &p.staged_bt);
        copy(sl, &p.seq_lens, &p.staged_sl);
        copy(tok, &p.tokens, &p.staged_tok);
        copy(off, &p.offsets, &p.staged_off);
    }

    /// Worst-case scratch capacities over both regions, for executors to
    /// pre-reserve their boundary buffers.
    pub fn scratch_capacities(&self) -> (usize, usize, usize, usize) {
        (
            self.decode.block_tables.len().max(self.prefill.block_tables.len()),
            self.decode.seq_lens.len().max(self.prefill.seq_lens.len()),
            self.decode.tokens.len().max(self.prefill.tokens.len()),
            self.prefill.offsets.len(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena() -> LaunchArena {
        LaunchArena::new(ArenaDims {
            decode_lanes: 4,
            decode_tokens: 4 * 3, // k=2 verify windows over every lane
            prefill_lanes: 2,
            prefill_tokens: 2 * 32,
            max_blocks_per_seq: 3,
        })
    }

    #[test]
    fn decode_token_plane_carries_verify_windows() {
        // A k=2 verify launch stages (k+1)-wide windows row-major in the
        // decode token plane; plain decode keeps using index = lane.
        let a = arena();
        for lane in 0..2 {
            a.write_seq_len(Region::Decode, lane, 10 + lane as i32);
            for j in 0..3 {
                a.write_token(Region::Decode, lane * 3 + j, (100 * lane + j) as i32);
            }
        }
        a.stage_extents(Region::Decode, 2 * 3, 2, 6, 0);
        a.publish();
        let (mut bt, mut sl, mut tok, mut off) = (vec![], vec![], vec![], vec![]);
        a.snapshot_into(Region::Decode, &mut bt, &mut sl, &mut tok, &mut off);
        assert_eq!(tok, vec![0, 1, 2, 100, 101, 102]);
        assert_eq!(sl, vec![10, 11]);
    }

    #[test]
    fn staged_rows_round_trip() {
        let a = arena();
        a.write_block_row(Region::Decode, 0, &[7, 8]);
        a.write_block_row(Region::Decode, 1, &[9, 10, 11]);
        a.write_seq_len(Region::Decode, 0, 17);
        a.write_seq_len(Region::Decode, 1, 33);
        a.write_token(Region::Decode, 0, 42);
        a.write_token(Region::Decode, 1, 43);
        a.stage_extents(Region::Decode, 2 * 3, 2, 2, 0);
        let e = a.publish();
        assert_eq!(e, 1);
        assert_eq!(a.epoch(), 1);

        let (mut bt, mut sl, mut tok, mut off) = (vec![], vec![], vec![], vec![]);
        a.snapshot_into(Region::Decode, &mut bt, &mut sl, &mut tok, &mut off);
        assert_eq!(
            (bt.len(), sl.len(), tok.len(), off.len()),
            (6, 2, 2, 0),
            "scratch lengths are the staged extents"
        );
        assert_eq!(bt, vec![7, 8, 0, 9, 10, 11], "rows zero-padded to the table width");
        assert_eq!(sl, vec![17, 33]);
        assert_eq!(tok, vec![42, 43]);
        assert!(off.is_empty());
    }

    #[test]
    fn regions_are_independent() {
        let a = arena();
        a.write_seq_len(Region::Decode, 0, 5);
        a.write_token(Region::Decode, 0, 1);
        a.stage_extents(Region::Decode, 3, 1, 1, 0);
        a.publish();

        // A prefill launch staged in between must not disturb the decode
        // region's persistent rows.
        a.write_seq_len(Region::Prefill, 0, 64);
        for i in 0..32 {
            a.write_token(Region::Prefill, i, i as i32);
        }
        a.write_offset(0, 16);
        a.stage_extents(Region::Prefill, 3, 1, 32, 1);
        a.publish();

        let (mut bt, mut sl, mut tok, mut off) = (vec![], vec![], vec![], vec![]);
        a.snapshot_into(Region::Prefill, &mut bt, &mut sl, &mut tok, &mut off);
        assert_eq!(sl, vec![64]);
        assert_eq!(tok.len(), 32);
        assert_eq!(off, vec![16]);
        a.snapshot_into(Region::Decode, &mut bt, &mut sl, &mut tok, &mut off);
        assert_eq!(sl, vec![5], "decode region untouched by the prefill stage");
        assert_eq!(tok, vec![1]);
    }

    #[test]
    fn epoch_increments_per_publish() {
        let a = arena();
        assert_eq!(a.epoch(), 0);
        assert_eq!(a.publish(), 1);
        assert_eq!(a.publish(), 2);
        assert_eq!(a.epoch(), 2);
    }

    #[test]
    fn snapshot_reuses_scratch_capacity() {
        let a = arena();
        let (cb, cs, ct, co) = a.scratch_capacities();
        let mut bt = Vec::with_capacity(cb);
        let mut sl = Vec::with_capacity(cs);
        let mut tok = Vec::with_capacity(ct);
        let mut off = Vec::with_capacity(co);
        a.stage_extents(Region::Prefill, 2 * 3, 2, 2 * 32, 2);
        a.publish();
        a.snapshot_into(Region::Prefill, &mut bt, &mut sl, &mut tok, &mut off);
        let caps = (bt.capacity(), sl.capacity(), tok.capacity(), off.capacity());
        a.stage_extents(Region::Decode, 4 * 3, 4, 4, 0);
        a.publish();
        a.snapshot_into(Region::Decode, &mut bt, &mut sl, &mut tok, &mut off);
        assert_eq!(
            caps,
            (bt.capacity(), sl.capacity(), tok.capacity(), off.capacity()),
            "boundary copies never grow the scratch past the widest grid"
        );
    }
}
