//! Pluggable admission policies for the persistent scheduler.
//!
//! The staged pipeline (see DESIGN.md §5) separates *detecting* pending
//! work (ring scan) from *choosing* which pending requests to admit. The
//! scan produces [`Candidate`] snapshots; an [`AdmissionPolicy`] orders
//! them; the batch planner then admits in that order until capacity or
//! KV backpressure stops it. The paper's scheduler is pure FCFS (§4.2);
//! the other three policies explore the scheduling dimension that
//! dominates tail latency under mixed interactive/batch loads:
//!
//! * [`Fcfs`] — ticket order; the paper's behavior, and the default.
//! * [`PriorityAged`] — base priority plus an age boost, with a hard
//!   starvation cap: any request waiting longer than the cap jumps the
//!   queue regardless of priority (the loopr/taskdaemon model).
//! * [`ShortestPromptFirst`] — SJF on prompt length, minimizing mean
//!   TTFT at the cost of long-prompt fairness.
//! * [`SloAware`] — earliest-deadline-first on each request's TTFT
//!   budget; requests without a budget get a default, which reduces to
//!   FCFS among budget-less requests.
//!
//! Policies are consulted with *relaxed* snapshots (same rationale as the
//! relaxed ring scan): ordering is a heuristic, the claim CAS is the
//! synchronization point.

use std::sync::atomic::Ordering;

use crate::ringbuf::{RingBuffer, Slot};

/// Snapshot of one PREFILL_PENDING slot, taken at scan time and ranked by
/// an [`AdmissionPolicy`]. In the sim (`crate::sim::des`) `slot` indexes
/// the pending queue instead of the ring; everything else is identical,
/// which is what lets the live scheduler and the DES share policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    pub slot: usize,
    /// Monotone submission ticket (FCFS order).
    pub ticket: u64,
    /// Base priority class; higher = more important. 0 = batch/default.
    pub priority: u32,
    pub prompt_len: u32,
    /// Submission timestamp, µs since process epoch.
    pub submit_time_us: u64,
    /// Absolute TTFT deadline, µs since process epoch; 0 = no deadline.
    pub ttft_deadline_us: u64,
}

impl Candidate {
    /// Snapshot a ring slot (relaxed loads; see module docs).
    pub fn from_slot(slot_idx: usize, s: &Slot) -> Candidate {
        Candidate {
            slot: slot_idx,
            ticket: s.ticket.load(Ordering::Relaxed),
            priority: s.priority.load(Ordering::Relaxed),
            prompt_len: s.prompt_len.load(Ordering::Relaxed),
            submit_time_us: s.submit_time_us.load(Ordering::Relaxed),
            ttft_deadline_us: s.ttft_deadline_us.load(Ordering::Relaxed),
        }
    }

    /// Snapshot every slot in `indices` from the ring.
    pub fn collect(ring: &RingBuffer, indices: &[usize]) -> Vec<Candidate> {
        let mut out = Vec::with_capacity(indices.len());
        Candidate::collect_into(ring, indices, &mut out);
        out
    }

    /// Allocation-free snapshot into a scheduler-owned scratch (cleared
    /// first) — the hot-loop variant of [`Candidate::collect`].
    pub fn collect_into(ring: &RingBuffer, indices: &[usize], out: &mut Vec<Candidate>) {
        out.clear();
        out.extend(indices.iter().map(|&i| Candidate::from_slot(i, ring.slot(i))));
    }

    pub fn age_us(&self, now_us: u64) -> u64 {
        now_us.saturating_sub(self.submit_time_us)
    }
}

/// An admission-ordering policy. `key` maps a candidate to a sort key —
/// lower keys are admitted first; the second component breaks ties in
/// ticket (FCFS) order so every policy is deterministic and total.
pub trait AdmissionPolicy: Send {
    fn name(&self) -> &'static str;

    fn key(&self, c: &Candidate, now_us: u64) -> (i64, u64);

    /// Order candidates for admission (first = admitted first).
    fn order(&self, candidates: &mut [Candidate], now_us: u64) {
        if candidates.len() > 1 {
            candidates.sort_by_key(|c| self.key(c, now_us));
        }
    }
}

/// Ticket order — the paper's policy and the default.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fcfs;

impl AdmissionPolicy for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn key(&self, c: &Candidate, _now_us: u64) -> (i64, u64) {
        (0, c.ticket)
    }
}

/// Base priority + age boost with a hard starvation cap.
///
/// Effective priority is `base * PRIORITY_SCALE + age_boost`, where the
/// boost grows by one per `age_boost_interval_us` of queueing, capped at
/// `max_age_boost` (so aging can overtake at most
/// `max_age_boost / PRIORITY_SCALE` priority levels). Independently, any
/// candidate older than `starvation_cap_us` is hoisted ahead of every
/// non-starved candidate — the anti-starvation guarantee the property
/// test in this module pins down.
#[derive(Debug, Clone, Copy)]
pub struct PriorityAged {
    pub age_boost_interval_us: u64,
    pub max_age_boost: i64,
    pub starvation_cap_us: u64,
}

/// One priority level in effective-priority units.
pub const PRIORITY_SCALE: i64 = 1_000;

impl Default for PriorityAged {
    fn default() -> Self {
        PriorityAged {
            // +1 per ms of queueing, capped at two priority levels — so
            // aging can overtake nearby classes but interactive traffic
            // keeps outranking fresh batch work even under pressure.
            age_boost_interval_us: 1_000,
            max_age_boost: 2 * PRIORITY_SCALE,
            // After 10 s in the queue, jump it regardless of class. Kept
            // well above interactive TTFT budgets: a tight cap would
            // hoist the entire batch backlog under saturation and
            // degenerate the policy to FCFS exactly when class
            // separation matters most.
            starvation_cap_us: 10_000_000,
        }
    }
}

impl AdmissionPolicy for PriorityAged {
    fn name(&self) -> &'static str {
        "priority-aged"
    }

    fn key(&self, c: &Candidate, now_us: u64) -> (i64, u64) {
        let age = c.age_us(now_us);
        if age >= self.starvation_cap_us {
            // Starved: ahead of everything, FCFS among the starved.
            return (i64::MIN, c.ticket);
        }
        let boost = ((age / self.age_boost_interval_us.max(1)) as i64).min(self.max_age_boost);
        let effective = c.priority as i64 * PRIORITY_SCALE + boost;
        // Higher effective priority sorts first.
        (-effective, c.ticket)
    }
}

/// Shortest-prompt-first (SJF on the only job-size signal the slot
/// metadata carries). Minimizes mean TTFT; unfair to long prompts.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShortestPromptFirst;

impl AdmissionPolicy for ShortestPromptFirst {
    fn name(&self) -> &'static str {
        "sjf"
    }

    fn key(&self, c: &Candidate, _now_us: u64) -> (i64, u64) {
        (c.prompt_len as i64, c.ticket)
    }
}

/// Earliest-deadline-first on the TTFT budget. Requests without a
/// deadline are treated as `submit + default_ttft_budget_us`, so they
/// degrade to FCFS among themselves and never block an urgent deadline.
#[derive(Debug, Clone, Copy)]
pub struct SloAware {
    pub default_ttft_budget_us: u64,
}

impl Default for SloAware {
    fn default() -> Self {
        SloAware { default_ttft_budget_us: 10_000_000 }
    }
}

impl AdmissionPolicy for SloAware {
    fn name(&self) -> &'static str {
        "slo"
    }

    fn key(&self, c: &Candidate, now_us: u64) -> (i64, u64) {
        let deadline = if c.ttft_deadline_us != 0 {
            c.ttft_deadline_us as i64
        } else {
            c.submit_time_us as i64 + self.default_ttft_budget_us as i64
        };
        (deadline - now_us as i64, c.ticket)
    }
}

/// Selector threaded through `SchedulerConfig`, `ServerConfig`,
/// `SimConfig` and the `--policy` CLI flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    Fcfs,
    PriorityAged,
    ShortestPromptFirst,
    SloAware,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 4] = [
        PolicyKind::Fcfs,
        PolicyKind::PriorityAged,
        PolicyKind::ShortestPromptFirst,
        PolicyKind::SloAware,
    ];

    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Some(PolicyKind::Fcfs),
            "priority" | "priority-aged" | "aged" => Some(PolicyKind::PriorityAged),
            "sjf" | "shortest" | "shortest-prompt-first" => Some(PolicyKind::ShortestPromptFirst),
            "slo" | "slo-aware" | "edf" => Some(PolicyKind::SloAware),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Fcfs => "fcfs",
            PolicyKind::PriorityAged => "priority-aged",
            PolicyKind::ShortestPromptFirst => "sjf",
            PolicyKind::SloAware => "slo",
        }
    }

    pub fn build(self) -> Box<dyn AdmissionPolicy> {
        match self {
            PolicyKind::Fcfs => Box::new(Fcfs),
            PolicyKind::PriorityAged => Box::new(PriorityAged::default()),
            PolicyKind::ShortestPromptFirst => Box::new(ShortestPromptFirst),
            PolicyKind::SloAware => Box::new(SloAware::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    fn cand(
        slot: usize,
        ticket: u64,
        priority: u32,
        prompt_len: u32,
        submit_time_us: u64,
        ttft_deadline_us: u64,
    ) -> Candidate {
        Candidate { slot, ticket, priority, prompt_len, submit_time_us, ttft_deadline_us }
    }

    #[test]
    fn fcfs_orders_by_ticket() {
        let mut cs = vec![
            cand(0, 9, 7, 1, 0, 0),
            cand(1, 2, 0, 500, 0, 0),
            cand(2, 5, 3, 10, 0, 0),
        ];
        Fcfs.order(&mut cs, 1_000_000);
        let tickets: Vec<u64> = cs.iter().map(|c| c.ticket).collect();
        assert_eq!(tickets, vec![2, 5, 9]);
    }

    #[test]
    fn priority_beats_ticket_before_aging() {
        let mut cs = vec![
            cand(0, 1, 0, 10, 1_000, 0), // older, low priority
            cand(1, 2, 4, 10, 1_500, 0), // newer, high priority
        ];
        PriorityAged::default().order(&mut cs, 2_000);
        assert_eq!(cs[0].slot, 1, "high priority admitted first");
    }

    #[test]
    fn age_boost_overtakes_one_priority_level() {
        let p = PriorityAged::default();
        // Priority 0 aged past one level's worth of boost (but well short
        // of the starvation cap) beats a brand-new priority-1 request.
        let now = 2_000_000u64;
        let old = cand(0, 1, 0, 10, 0, 0); // age 2 s → boost maxed at 2000
        let fresh = cand(1, 2, 1, 10, now, 0); // effective 1000
        assert!(old.age_us(now) < p.starvation_cap_us, "boost, not starvation, decides");
        let mut cs = vec![fresh, old];
        p.order(&mut cs, now);
        assert_eq!(cs[0].slot, 0);
        // But the boost cap holds: a fresh priority-4 request still wins
        // against the same aged batch request.
        let urgent = cand(2, 3, 4, 10, now, 0);
        let mut cs = vec![old, urgent];
        p.order(&mut cs, now);
        assert_eq!(cs[0].slot, 2, "boost is capped below high-priority classes");
    }

    #[test]
    fn sjf_orders_by_prompt_len() {
        let mut cs = vec![
            cand(0, 1, 0, 300, 0, 0),
            cand(1, 2, 0, 12, 0, 0),
            cand(2, 3, 0, 12, 0, 0),
        ];
        ShortestPromptFirst.order(&mut cs, 0);
        assert_eq!(cs[0].slot, 1, "shortest first, ticket tie-break");
        assert_eq!(cs[1].slot, 2);
        assert_eq!(cs[2].slot, 0);
    }

    #[test]
    fn slo_orders_by_slack_and_defaults_to_fcfs() {
        let p = SloAware::default();
        let mut cs = vec![
            cand(0, 1, 0, 10, 100, 0),         // no deadline (default budget)
            cand(1, 2, 0, 10, 200, 900_000),   // tight deadline
            cand(2, 3, 0, 10, 300, 5_000_000), // loose deadline
        ];
        p.order(&mut cs, 800_000);
        assert_eq!(cs[0].slot, 1, "tightest slack first");
        assert_eq!(cs[1].slot, 2);
        assert_eq!(cs[2].slot, 0);
    }

    #[test]
    fn policy_kind_parse_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(k.name()), Some(k));
            assert_eq!(k.build().name(), k.name());
        }
        assert_eq!(PolicyKind::parse("priority"), Some(PolicyKind::PriorityAged));
        assert_eq!(PolicyKind::parse("edf"), Some(PolicyKind::SloAware));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    /// The anti-starvation guarantee: under PriorityAged, every candidate
    /// older than the starvation cap precedes every younger candidate, no
    /// matter how the priorities, prompt lengths and deadlines fall; and
    /// the starved prefix is FCFS (ticket-ordered) among itself.
    #[test]
    fn prop_priority_aged_never_starves_past_cap() {
        let p = PriorityAged::default();
        run_prop("priority_aged_starvation_cap", 0xA6E, 500, |rng| {
            let now_us: u64 = 100_000_000 + rng.below(1 << 30);
            let n = 2 + rng.below(30) as usize;
            let mut cs: Vec<Candidate> = (0..n)
                .map(|i| {
                    // Ages straddle the cap: 0..2× starvation_cap.
                    let age = rng.below(2 * PriorityAged::default().starvation_cap_us);
                    let submit = now_us - age;
                    let deadline =
                        if rng.below(2) == 0 { 0 } else { submit + 1_000 + rng.below(1 << 20) };
                    cand(
                        i,
                        rng.below(1 << 20),
                        rng.below(8) as u32,
                        1 + rng.below(512) as u32,
                        submit,
                        deadline,
                    )
                })
                .collect();
            p.order(&mut cs, now_us);
            let starved: Vec<&Candidate> =
                cs.iter().filter(|c| c.age_us(now_us) >= p.starvation_cap_us).collect();
            // (a) starved candidates form a prefix of the ordering;
            for (i, c) in cs.iter().enumerate() {
                let is_starved = c.age_us(now_us) >= p.starvation_cap_us;
                assert_eq!(
                    is_starved,
                    i < starved.len(),
                    "starved candidate not in prefix at position {i}"
                );
            }
            // (b) the starved prefix is ticket-ordered (FCFS).
            for w in cs[..starved.len()].windows(2) {
                assert!(w[0].ticket <= w[1].ticket, "starved prefix must be FCFS");
            }
        });
    }

    /// Aged queue simulation: with a continuous stream of high-priority
    /// arrivals and one admission per round, a low-priority request is
    /// still admitted within the rounds implied by the starvation cap.
    #[test]
    fn aged_queue_drains_low_priority_within_cap() {
        // Small cap so the simulated queue trips it within a few rounds.
        let p = PriorityAged {
            age_boost_interval_us: 1_000,
            max_age_boost: 2 * PRIORITY_SCALE,
            starvation_cap_us: 500_000,
        };
        let round_us = 50_000; // 50 ms between admission opportunities
        let mut queue: Vec<Candidate> = vec![cand(0, 0, 0, 64, 0, 0)];
        let mut next_ticket = 1u64;
        let mut now = 0u64;
        let mut admitted_old_at = None;
        for round in 0..64u64 {
            now += round_us;
            // Two fresh high-priority arrivals per round: offered load
            // exceeds the single admission slot, so pure priority order
            // would starve the old request forever.
            for _ in 0..2 {
                queue.push(cand(next_ticket as usize, next_ticket, 7, 64, now, 0));
                next_ticket += 1;
            }
            p.order(&mut queue, now);
            let head = queue.remove(0);
            if head.ticket == 0 {
                admitted_old_at = Some(round);
                break;
            }
        }
        let round = admitted_old_at.expect("low-priority request starved");
        let cap_rounds = p.starvation_cap_us / round_us;
        assert!(
            round <= cap_rounds + 1,
            "admitted at round {round}, cap implies <= {cap_rounds}"
        );
    }
}
