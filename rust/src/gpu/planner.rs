//! Batch planning: the pipeline stage between policy-ordered admission
//! and the launcher. Turns admitted sequences into prefill groups that
//! fit the AOT graph grid, and live decode lanes into decode launch
//! inputs — the pure data-marshalling logic that used to be inlined in
//! `SchedulerCore::admit_and_prefill` / `decode_step`. Pure functions of
//! their inputs: no ring, no executor, no clock — which is what makes
//! this stage unit-testable without artifacts.

use crate::kvcache::SeqCache;

/// One decode lane: a request that finished prefill and is generating.
pub struct Lane {
    pub slot: usize,
    pub cache: SeqCache,
    pub generated: u32,
    pub max_new: u32,
    pub last_token: i32,
}

/// One admitted sequence awaiting prefill.
pub struct PrefillSeq {
    pub slot: usize,
    pub cache: SeqCache,
    pub prompt: Vec<i32>,
    pub max_new: u32,
    /// Leading prompt tokens already cached via prefix reuse (block-
    /// aligned; 0 = cold). The prefill launch covers only the suffix.
    pub cached_prefix: usize,
    /// *Suffix* length (prompt − cached_prefix) padded up to the graph
    /// grid — with no prefix hit this is the padded prompt length,
    /// exactly as before.
    pub padded: usize,
}

/// A group of same-padded-length sequences forming one prefill launch.
pub struct PrefillGroup {
    pub padded: usize,
    pub seqs: Vec<PrefillSeq>,
}

/// Device-shaped launch inputs (what `LaunchCmd` carries).
pub struct LaunchInputs {
    pub block_tables: Vec<i32>,
    pub seq_lens: Vec<i32>,
    pub tokens: Vec<i32>,
}

pub struct BatchPlanner {
    /// Widest prefill graph in the grid.
    pub max_prefill_batch: usize,
    /// Manifest `max_blocks_per_seq` (block-table row width).
    pub max_blocks_per_seq: usize,
}

impl BatchPlanner {
    pub fn new(max_prefill_batch: usize, max_blocks_per_seq: usize) -> BatchPlanner {
        BatchPlanner { max_prefill_batch, max_blocks_per_seq }
    }

    /// Group admitted sequences by padded length, chunked to the prefill
    /// batch grid. Admission order is preserved within each group.
    pub fn group_prefills(&self, mut admitted: Vec<PrefillSeq>) -> Vec<PrefillGroup> {
        admitted.sort_by_key(|a| a.padded);
        let mut groups = Vec::new();
        let mut i = 0;
        while i < admitted.len() {
            let pad = admitted[i].padded;
            let mut j = i + 1;
            while j < admitted.len() && admitted[j].padded == pad && j - i < self.max_prefill_batch
            {
                j += 1;
            }
            let seqs: Vec<PrefillSeq> = admitted.drain(i..j).collect();
            groups.push(PrefillGroup { padded: pad, seqs });
            // drain() shifts the tail down; keep i in place.
        }
        groups
    }

    /// Marshal one prefill group for a `(grid_batch, grid_seq)` graph.
    /// Ghost lanes (grid wider than the group) replicate lane 0 —
    /// identical writes are benign, outputs ignored.
    pub fn prefill_inputs(
        &self,
        group: &PrefillGroup,
        grid_batch: usize,
        grid_seq: usize,
    ) -> LaunchInputs {
        let mbs = self.max_blocks_per_seq;
        let b_actual = group.seqs.len();
        debug_assert!(b_actual > 0 && b_actual <= grid_batch);
        let mut block_tables = Vec::with_capacity(grid_batch * mbs);
        let mut seq_lens = Vec::with_capacity(grid_batch);
        let mut tokens = Vec::with_capacity(grid_batch * grid_seq);
        for s in &group.seqs {
            // Prefix reuse: the launch carries only the uncached suffix;
            // seq_lens stays the *full* length so attention masks and KV
            // write offsets see the whole sequence.
            let suffix = &s.prompt[s.cached_prefix.min(s.prompt.len())..];
            debug_assert!(suffix.len() <= grid_seq, "suffix exceeds prefill grid");
            block_tables.extend(s.cache.table_row(mbs));
            seq_lens.push(s.prompt.len() as i32);
            tokens.extend(suffix);
            tokens.extend(std::iter::repeat(0).take(grid_seq - suffix.len()));
        }
        for _ in b_actual..grid_batch {
            block_tables.extend_from_slice(&group.seqs[0].cache.table_row(mbs));
            seq_lens.push(group.seqs[0].prompt.len() as i32);
            let row0: Vec<i32> = tokens[..grid_seq].to_vec();
            tokens.extend(row0);
        }
        LaunchInputs { block_tables, seq_lens, tokens }
    }

    /// Marshal the live decode lanes for a `grid_batch`-wide decode
    /// graph, ghost lanes replicating lane 0.
    pub fn decode_inputs(&self, lanes: &[Lane], grid_batch: usize) -> LaunchInputs {
        let mbs = self.max_blocks_per_seq;
        debug_assert!(!lanes.is_empty() && lanes.len() <= grid_batch);
        let mut block_tables = Vec::with_capacity(grid_batch * mbs);
        let mut seq_lens = Vec::with_capacity(grid_batch);
        let mut tokens = Vec::with_capacity(grid_batch);
        for l in lanes {
            block_tables.extend(l.cache.table_row(mbs));
            seq_lens.push(l.cache.cached_len as i32);
            tokens.push(l.last_token);
        }
        for _ in lanes.len()..grid_batch {
            block_tables.extend(lanes[0].cache.table_row(mbs));
            seq_lens.push(lanes[0].cache.cached_len as i32);
            tokens.push(lanes[0].last_token);
        }
        LaunchInputs { block_tables, seq_lens, tokens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(slot: usize, prompt_len: usize, padded: usize) -> PrefillSeq {
        PrefillSeq {
            slot,
            cache: SeqCache { blocks: vec![1, 2], cached_len: 0, prefix_len: 0 },
            prompt: (0..prompt_len as i32).collect(),
            max_new: 4,
            cached_prefix: 0,
            padded,
        }
    }

    #[test]
    fn groups_by_padded_len_and_chunks_to_grid() {
        let p = BatchPlanner::new(2, 4);
        let groups = p.group_prefills(vec![
            seq(0, 10, 16),
            seq(1, 30, 32),
            seq(2, 12, 16),
            seq(3, 15, 16),
        ]);
        // 16-padded: [0, 2] then [3] (max batch 2); 32-padded: [1].
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].padded, 16);
        assert_eq!(groups[0].seqs.iter().map(|s| s.slot).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(groups[1].padded, 16);
        assert_eq!(groups[1].seqs[0].slot, 3);
        assert_eq!(groups[2].padded, 32);
        assert_eq!(groups[2].seqs[0].slot, 1);
    }

    #[test]
    fn prefill_inputs_pad_ghost_lanes() {
        let p = BatchPlanner::new(4, 4);
        let group = PrefillGroup { padded: 16, seqs: vec![seq(5, 10, 16)] };
        let li = p.prefill_inputs(&group, 2, 16);
        assert_eq!(li.seq_lens, vec![10, 10], "ghost lane replicates lane 0");
        assert_eq!(li.block_tables.len(), 2 * 4);
        assert_eq!(li.tokens.len(), 2 * 16);
        assert_eq!(&li.tokens[..10], &li.tokens[16..26], "ghost row replicated");
        assert_eq!(&li.tokens[10..16], &[0i32; 6][..], "prompt padded with zeros");
    }

    #[test]
    fn prefill_inputs_carry_only_uncached_suffix() {
        let p = BatchPlanner::new(4, 4);
        let mut s = seq(2, 40, 16);
        s.cached_prefix = 32; // two 16-token blocks served from the index
        let group = PrefillGroup { padded: 16, seqs: vec![s] };
        let li = p.prefill_inputs(&group, 1, 16);
        assert_eq!(li.seq_lens, vec![40], "seq_lens stays the full length");
        assert_eq!(&li.tokens[..8], &(32..40).collect::<Vec<i32>>()[..], "suffix tokens only");
        assert_eq!(&li.tokens[8..], &[0i32; 8][..], "suffix padded to the grid");
    }

    #[test]
    fn decode_inputs_shapes() {
        let p = BatchPlanner::new(4, 4);
        let lanes = vec![
            Lane {
                slot: 0,
                cache: SeqCache { blocks: vec![1], cached_len: 7, prefix_len: 0 },
                generated: 1,
                max_new: 8,
                last_token: 42,
            },
            Lane {
                slot: 1,
                cache: SeqCache { blocks: vec![2], cached_len: 9, prefix_len: 0 },
                generated: 1,
                max_new: 8,
                last_token: 43,
            },
        ];
        let li = p.decode_inputs(&lanes, 4);
        assert_eq!(li.tokens, vec![42, 43, 42, 42]);
        assert_eq!(li.seq_lens, vec![7, 9, 7, 7]);
        assert_eq!(li.block_tables.len(), 4 * 4);
    }
}
