//! Batch planning: the pipeline stage between policy-ordered admission
//! and the launcher. Turns admitted sequences into prefill groups that
//! fit the AOT graph grid (full or *offset* prefill — see
//! [`PrefillGroup::offset`]), orders those groups so a prefix-sharing
//! group never launches before the group that prefills its shared blocks
//! (stage 3b's dependency order), and marshals live decode lanes into
//! decode launch inputs — the pure data-marshalling logic that used to be
//! inlined in `SchedulerCore::admit_and_prefill` / `decode_step`. Pure
//! functions of their inputs: no ring, no executor, no clock — which is
//! what makes this stage unit-testable without artifacts.

use crate::kvcache::SeqCache;

/// One decode lane: a request that finished prefill and is generating.
pub struct Lane {
    pub slot: usize,
    pub cache: SeqCache,
    pub generated: u32,
    pub max_new: u32,
    pub last_token: i32,
}

/// One admitted sequence awaiting prefill — or one *chunk* of a
/// chunked prefill (see `gpu::scheduler`'s `ChunkedPrefill`): a chunk
/// carries the prompt prefix up to the chunk's end, with
/// `cached_prefix` marking the already-written tokens before it.
pub struct PrefillSeq {
    pub slot: usize,
    pub cache: SeqCache,
    pub prompt: Vec<i32>,
    pub max_new: u32,
    /// Leading prompt tokens whose K/V is already written — a prefix-
    /// reuse hit, or the completed chunks of a chunked prefill (block-
    /// aligned; 0 = cold). The prefill launch covers only the suffix.
    pub cached_prefix: usize,
    /// *Suffix* length (prompt − cached_prefix) padded up to the graph
    /// grid — with no prefix hit this is the padded prompt length,
    /// exactly as before.
    pub padded: usize,
    /// True when this launch completes the prompt's prefill and its
    /// sampled token is the request's first output token. False only
    /// for intermediate chunks of a chunked prefill, whose completion
    /// merely advances the lane's high-water mark.
    pub first_token: bool,
}

/// A group of same-padded-length sequences forming one prefill launch.
pub struct PrefillGroup {
    pub padded: usize,
    /// True when this group must launch an offset prefill graph: every
    /// member carries a cached prefix and its tokens are a suffix at a
    /// per-lane runtime offset. Cold sequences are never mixed in — they
    /// run the ordinary prefill graphs, whose grid may differ from the
    /// offset grid.
    pub offset: bool,
    pub seqs: Vec<PrefillSeq>,
}

/// Device-shaped launch inputs (what `LaunchCmd` carries). `offsets` is
/// populated only for offset groups (empty otherwise).
pub struct LaunchInputs {
    pub block_tables: Vec<i32>,
    pub seq_lens: Vec<i32>,
    pub tokens: Vec<i32>,
    pub offsets: Vec<i32>,
}

pub struct BatchPlanner {
    /// Widest full-prefill graph in the grid.
    pub max_prefill_batch: usize,
    /// Widest *offset* prefill graph (0 when the artifacts ship none —
    /// admission never produces offset sequences in that case).
    pub max_prefill_offset_batch: usize,
    /// Manifest `max_blocks_per_seq` (block-table row width).
    pub max_blocks_per_seq: usize,
    /// Manifest `block_size` (maps a cached-prefix token count to the
    /// shared block span for dependency ordering).
    pub block_size: usize,
}

impl BatchPlanner {
    pub fn new(
        max_prefill_batch: usize,
        max_prefill_offset_batch: usize,
        max_blocks_per_seq: usize,
        block_size: usize,
    ) -> BatchPlanner {
        BatchPlanner {
            max_prefill_batch,
            max_prefill_offset_batch,
            max_blocks_per_seq,
            block_size,
        }
    }

    /// Group admitted sequences into prefill launches, in shared-block
    /// dependency order (the stage-3b contract): a sequence never lands
    /// in a group positioned at or before the group that prefills blocks
    /// it consumes as a shared prefix.
    ///
    /// Sequences are first topologically ordered at *sequence*
    /// granularity (consumer after the writer of its shared blocks —
    /// Kahn, stable in admission order), then greedily packed into
    /// groups keyed by (padded length, offset-ness) up to the matching
    /// graph grid's batch width, with the constraint that a sequence may
    /// only join a group positioned strictly after every group holding
    /// one of its producers. Ordering at sequence rather than group
    /// granularity matters: merging same-shape sequences first could
    /// weld two mutually-dependent chains into a group-level cycle that
    /// no launch order resolves.
    ///
    /// Hit sequences (cached_prefix > 0) form *offset* groups; cold
    /// sequences form full-prefill groups — the two kinds never share a
    /// launch, because their graph grids differ.
    ///
    /// Chunks of a chunked prefill are ordinary sequences here: chunk
    /// *k*+1 consumes (as `cached_prefix`) exactly the blocks chunk *k*
    /// writes, so the same consumer→writer edges that order sharers
    /// after producers also order a lane's own chunks — self-edges in
    /// the slot sense, regular edges in the sequence sense. For that to
    /// hold, a sequence's *write span* must be its padded launch window
    /// `[cached_prefix, cached_prefix + padded)`, not its whole
    /// reservation: chunks of one lane share a block list, and crediting
    /// every chunk with the full tail would let an earlier-listed chunk
    /// absorb a later chunk's writes and drop the k→k+1 edge.
    ///
    /// Today the prefix index only ever matches blocks whose prefill
    /// already *completed* (kvcache invariant 5), so intra-admission
    /// edges cannot arise through the index — the order is enforced
    /// unconditionally so the invariant is structural, not incidental:
    /// any future source of intra-admission sharing (speculative
    /// matches, async launch pipelining) inherits a correct launch order
    /// instead of a latent use-before-write.
    pub fn group_prefills(&self, admitted: Vec<PrefillSeq>) -> Vec<PrefillGroup> {
        let n = admitted.len();
        if n == 0 {
            return vec![];
        }
        let bs = self.block_size.max(1);
        // writer[block] = admitted index whose prefill launch writes it:
        // the blocks under the padded launch window. (The decode region
        // past the window is written by decode steps, which no admitted
        // prefill can consume as a shared prefix — the index only ever
        // holds full *prompt* blocks.)
        let mut writer: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
        for (i, s) in admitted.iter().enumerate() {
            let lo = (s.cached_prefix / bs).min(s.cache.blocks.len());
            let hi = (s.cached_prefix + s.padded).div_ceil(bs).min(s.cache.blocks.len());
            for &b in &s.cache.blocks[lo..hi] {
                writer.entry(b).or_insert(i);
            }
        }
        // Edges: consumer -> producer for every shared-prefix block
        // written by a *different* admitted sequence.
        let mut deps: Vec<Vec<usize>> = vec![vec![]; n];
        let mut rdeps: Vec<Vec<usize>> = vec![vec![]; n];
        for (i, s) in admitted.iter().enumerate() {
            for &b in s.cache.blocks.iter().take(s.cached_prefix / bs) {
                if let Some(&w) = writer.get(&b) {
                    if w != i && !deps[i].contains(&w) {
                        deps[i].push(w);
                        rdeps[w].push(i);
                    }
                }
            }
        }
        // Stable topological order (Kahn): among ready sequences, the
        // admission (policy) order is kept.
        let mut indegree: Vec<usize> = deps.iter().map(|d| d.len()).collect();
        let mut topo: Vec<usize> = Vec::with_capacity(n);
        let mut placed = vec![false; n];
        while topo.len() < n {
            match (0..n).find(|&i| !placed[i] && indegree[i] == 0) {
                Some(i) => {
                    placed[i] = true;
                    topo.push(i);
                    for &c in &rdeps[i] {
                        indegree[c] -= 1;
                    }
                }
                None => {
                    // Defensive: cyclic input. Unreachable through the
                    // prefix index (a consumed block's writer committed
                    // strictly earlier); launch the rest in admission
                    // order rather than dropping work.
                    debug_assert!(false, "cycle in prefill dependencies");
                    for i in 0..n {
                        if !placed[i] {
                            placed[i] = true;
                            topo.push(i);
                        }
                    }
                }
            }
        }
        // Greedy packing in topo order: join the first compatible group
        // positioned after all producers, else open a new one at the end.
        let mut groups: Vec<PrefillGroup> = vec![];
        let mut group_of: Vec<usize> = vec![usize::MAX; n];
        let mut slots: Vec<Option<PrefillSeq>> = admitted.into_iter().map(Some).collect();
        for i in topo {
            let s = slots[i].take().expect("topo visits each seq once");
            let offset = s.cached_prefix > 0;
            let max_batch =
                if offset { self.max_prefill_offset_batch } else { self.max_prefill_batch }.max(1);
            let min_pos = deps[i]
                .iter()
                .filter_map(|&w| (group_of[w] != usize::MAX).then(|| group_of[w] + 1))
                .max()
                .unwrap_or(0);
            let found = (min_pos..groups.len()).find(|&gi| {
                let g = &groups[gi];
                g.offset == offset && g.padded == s.padded && g.seqs.len() < max_batch
            });
            match found {
                Some(gi) => {
                    groups[gi].seqs.push(s);
                    group_of[i] = gi;
                }
                None => {
                    groups.push(PrefillGroup { padded: s.padded, offset, seqs: vec![s] });
                    group_of[i] = groups.len() - 1;
                }
            }
        }
        groups
    }

    /// Marshal one prefill group for a `(grid_batch, grid_seq)` graph.
    /// Ghost lanes (grid wider than the group) replicate lane 0 —
    /// identical writes are benign, outputs ignored. Offset groups also
    /// carry per-lane runtime offsets (the block-aligned cached-prefix
    /// lengths the graph shifts rope/masking/KV-writes by).
    pub fn prefill_inputs(
        &self,
        group: &PrefillGroup,
        grid_batch: usize,
        grid_seq: usize,
    ) -> LaunchInputs {
        let mbs = self.max_blocks_per_seq;
        let b_actual = group.seqs.len();
        debug_assert!(b_actual > 0 && b_actual <= grid_batch);
        let mut block_tables = Vec::with_capacity(grid_batch * mbs);
        let mut seq_lens = Vec::with_capacity(grid_batch);
        let mut tokens = Vec::with_capacity(grid_batch * grid_seq);
        let mut offsets = Vec::with_capacity(if group.offset { grid_batch } else { 0 });
        for s in &group.seqs {
            // Prefix reuse: the launch carries only the uncached suffix;
            // seq_lens stays the *full* length so attention masks and KV
            // write offsets see the whole sequence.
            let suffix = &s.prompt[s.cached_prefix.min(s.prompt.len())..];
            debug_assert!(suffix.len() <= grid_seq, "suffix exceeds prefill grid");
            block_tables.extend(s.cache.table_row(mbs));
            seq_lens.push(s.prompt.len() as i32);
            tokens.extend(suffix);
            tokens.extend(std::iter::repeat(0).take(grid_seq - suffix.len()));
            if group.offset {
                offsets.push(s.cached_prefix as i32);
            }
        }
        for _ in b_actual..grid_batch {
            block_tables.extend_from_slice(&group.seqs[0].cache.table_row(mbs));
            seq_lens.push(group.seqs[0].prompt.len() as i32);
            let row0: Vec<i32> = tokens[..grid_seq].to_vec();
            tokens.extend(row0);
            if group.offset {
                offsets.push(group.seqs[0].cached_prefix as i32);
            }
        }
        LaunchInputs { block_tables, seq_lens, tokens, offsets }
    }

    /// Marshal the live decode lanes for a `grid_batch`-wide decode
    /// graph, ghost lanes replicating lane 0.
    pub fn decode_inputs(&self, lanes: &[Lane], grid_batch: usize) -> LaunchInputs {
        let mbs = self.max_blocks_per_seq;
        debug_assert!(!lanes.is_empty() && lanes.len() <= grid_batch);
        let mut block_tables = Vec::with_capacity(grid_batch * mbs);
        let mut seq_lens = Vec::with_capacity(grid_batch);
        let mut tokens = Vec::with_capacity(grid_batch);
        for l in lanes {
            block_tables.extend(l.cache.table_row(mbs));
            seq_lens.push(l.cache.cached_len as i32);
            tokens.push(l.last_token);
        }
        for _ in lanes.len()..grid_batch {
            block_tables.extend(lanes[0].cache.table_row(mbs));
            seq_lens.push(lanes[0].cache.cached_len as i32);
            tokens.push(lanes[0].last_token);
        }
        LaunchInputs { block_tables, seq_lens, tokens, offsets: vec![] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn planner() -> BatchPlanner {
        BatchPlanner::new(2, 2, 4, 16)
    }

    fn seq(slot: usize, prompt_len: usize, padded: usize) -> PrefillSeq {
        PrefillSeq {
            slot,
            cache: SeqCache { blocks: vec![1, 2], cached_len: 0, prefix_len: 0 },
            prompt: (0..prompt_len as i32).collect(),
            max_new: 4,
            cached_prefix: 0,
            padded,
            first_token: true,
        }
    }

    #[test]
    fn groups_by_padded_len_and_chunks_to_grid() {
        let p = planner();
        let groups = p.group_prefills(vec![
            seq(0, 10, 16),
            seq(1, 30, 32),
            seq(2, 12, 16),
            seq(3, 15, 16),
        ]);
        // Admission order preserved: 16-padded [0, 2] (max batch 2),
        // 32-padded [1], then the overflow 16-padded [3].
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].padded, 16);
        assert_eq!(groups[0].seqs.iter().map(|s| s.slot).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(groups[1].padded, 32);
        assert_eq!(groups[1].seqs[0].slot, 1);
        assert_eq!(groups[2].padded, 16);
        assert_eq!(groups[2].seqs[0].slot, 3);
        assert!(groups.iter().all(|g| !g.offset));
    }

    #[test]
    fn hit_and_cold_seqs_never_share_a_launch() {
        let p = planner();
        let mut hit = seq(7, 48, 16);
        hit.cached_prefix = 32;
        hit.cache.blocks = vec![5, 6, 7, 8];
        let groups = p.group_prefills(vec![seq(0, 10, 16), hit, seq(2, 12, 16)]);
        // Same padded length, but the hit runs its own offset-graph
        // launch: [0, 2] (cold, full prefill) + [7] (offset).
        assert_eq!(groups.len(), 2);
        assert!(!groups[0].offset);
        assert_eq!(groups[0].seqs.iter().map(|s| s.slot).collect::<Vec<_>>(), vec![0, 2]);
        assert!(groups[1].offset);
        assert_eq!(groups[1].seqs[0].slot, 7);
    }

    #[test]
    fn offset_groups_chunk_to_the_offset_grid() {
        // Offset grid narrower than the full-prefill grid: 3 hits with
        // the same padded suffix split 2 + 1.
        let p = BatchPlanner::new(4, 2, 4, 16);
        let mk = |slot| {
            let mut s = seq(slot, 40, 16);
            s.cached_prefix = 32;
            s
        };
        let groups = p.group_prefills(vec![mk(0), mk(1), mk(2)]);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].seqs.len(), 2);
        assert_eq!(groups[1].seqs.len(), 1);
        assert!(groups.iter().all(|g| g.offset));
    }

    #[test]
    fn prefill_inputs_pad_ghost_lanes() {
        let p = BatchPlanner::new(4, 4, 4, 16);
        let group = PrefillGroup { padded: 16, offset: false, seqs: vec![seq(5, 10, 16)] };
        let li = p.prefill_inputs(&group, 2, 16);
        assert_eq!(li.seq_lens, vec![10, 10], "ghost lane replicates lane 0");
        assert_eq!(li.block_tables.len(), 2 * 4);
        assert_eq!(li.tokens.len(), 2 * 16);
        assert_eq!(&li.tokens[..10], &li.tokens[16..26], "ghost row replicated");
        assert_eq!(&li.tokens[10..16], &[0i32; 6][..], "prompt padded with zeros");
        assert!(li.offsets.is_empty(), "full prefill carries no offsets");
    }

    #[test]
    fn prefill_inputs_carry_only_uncached_suffix() {
        let p = BatchPlanner::new(4, 4, 4, 16);
        let mut s = seq(2, 40, 16);
        s.cached_prefix = 32; // two 16-token blocks served from the index
        let group = PrefillGroup { padded: 16, offset: true, seqs: vec![s] };
        let li = p.prefill_inputs(&group, 1, 16);
        assert_eq!(li.seq_lens, vec![40], "seq_lens stays the full length");
        assert_eq!(&li.tokens[..8], &(32..40).collect::<Vec<i32>>()[..], "suffix tokens only");
        assert_eq!(&li.tokens[8..], &[0i32; 8][..], "suffix padded to the grid");
        assert_eq!(li.offsets, vec![32], "per-lane runtime offset");
    }

    #[test]
    fn offset_inputs_ghost_lanes_replicate_offset() {
        let p = BatchPlanner::new(4, 4, 4, 16);
        let mut a = seq(0, 40, 16);
        a.cached_prefix = 32;
        let mut b = seq(1, 24, 16);
        b.cached_prefix = 16;
        let group = PrefillGroup { padded: 16, offset: true, seqs: vec![a, b] };
        let li = p.prefill_inputs(&group, 4, 16);
        assert_eq!(li.offsets, vec![32, 16, 32, 32], "ghosts replicate lane 0's offset");
        assert_eq!(li.seq_lens, vec![40, 24, 40, 40]);
    }

    #[test]
    fn decode_inputs_shapes() {
        let p = BatchPlanner::new(4, 4, 4, 16);
        let lanes = vec![
            Lane {
                slot: 0,
                cache: SeqCache { blocks: vec![1], cached_len: 7, prefix_len: 0 },
                generated: 1,
                max_new: 8,
                last_token: 42,
            },
            Lane {
                slot: 1,
                cache: SeqCache { blocks: vec![2], cached_len: 9, prefix_len: 0 },
                generated: 1,
                max_new: 8,
                last_token: 43,
            },
        ];
        let li = p.decode_inputs(&lanes, 4);
        assert_eq!(li.tokens, vec![42, 43, 42, 42]);
        assert_eq!(li.seq_lens, vec![7, 9, 7, 7]);
        assert_eq!(li.block_tables.len(), 4 * 4);
        assert!(li.offsets.is_empty());
    }

    /// A sharer whose prefix blocks are written by a cold seq in the
    /// same admission must launch after it, whatever the padded-length
    /// sort would otherwise do.
    #[test]
    fn sharer_group_launches_after_its_producer() {
        let p = BatchPlanner::new(2, 2, 8, 16);
        // Producer: cold 64-token prompt over blocks 10..14 (padded 64 —
        // sorts *after* 16 by padded length).
        let mut producer = seq(0, 64, 64);
        producer.cache.blocks = vec![10, 11, 12, 13];
        // Sharer: 80-token prompt, 64 cached (blocks 10..14 shared),
        // 16-token suffix (padded 16 — would sort *first*).
        let mut sharer = seq(1, 80, 16);
        sharer.cached_prefix = 64;
        sharer.cache.blocks = vec![10, 11, 12, 13, 14, 15];
        let groups = p.group_prefills(vec![sharer, producer]);
        assert_eq!(groups.len(), 2);
        assert!(!groups[0].offset, "producer launches first");
        assert_eq!(groups[0].seqs[0].slot, 0);
        assert!(groups[1].offset);
        assert_eq!(groups[1].seqs[0].slot, 1);
    }

    /// Randomized sharer-group DAGs (the stage-3b property): the launch
    /// order never schedules a group before the group that prefills its
    /// shared prefix blocks, and every admitted sequence launches exactly
    /// once.
    #[test]
    fn prop_group_order_respects_block_dependencies() {
        run_prop("planner-group-topo", 0x3B, 200, |rng: &mut Rng| {
            let bs = 16usize;
            let p = BatchPlanner::new(3, 2, 16, bs);
            let mut next_block = 1u32;
            let mut alloc = |n: usize| -> Vec<u32> {
                let v: Vec<u32> = (next_block..next_block + n as u32).collect();
                next_block += n as u32;
                v
            };
            // Producers: cold seqs with random block spans.
            let n_prod = 1 + rng.below(4) as usize;
            let mut seqs: Vec<PrefillSeq> = vec![];
            for slot in 0..n_prod {
                let blocks = 1 + rng.below(4) as usize;
                let prompt_len = blocks * bs - rng.below(bs as u64 - 1) as usize;
                let mut s = seq(slot, prompt_len, prompt_len.next_power_of_two().max(16));
                s.cache.blocks = alloc(blocks);
                seqs.push(s);
            }
            // Sharers: consume a random full-block prefix of any earlier
            // seq's *written prompt* span — including another *sharer*'s
            // written tail, so hit→hit edges occur and genuinely force
            // reordering (hits with short padded suffixes would
            // otherwise sort first) — then write their own tail. Only
            // full prompt blocks are ever shareable (the index never
            // holds the decode region past a launch window), so `avail`
            // is capped there. Creation order guarantees a DAG.
            let n_share = rng.below(5) as usize;
            for i in 0..n_share {
                let prod = &seqs[rng.below(seqs.len() as u64) as usize];
                let avail = (prod.prompt.len() / bs).min(prod.cache.blocks.len());
                if avail == 0 {
                    continue;
                }
                let shared = 1 + rng.below(avail as u64) as usize;
                let suffix = 1 + rng.below(32) as usize;
                let prompt_len = shared * bs + suffix;
                let mut s = seq(100 + i, prompt_len, suffix.next_power_of_two().max(16));
                s.cached_prefix = shared * bs;
                let mut blocks = prod.cache.blocks[..shared].to_vec();
                blocks.extend(alloc(1 + suffix / bs));
                s.cache.blocks = blocks;
                seqs.push(s);
            }
            let expected: std::collections::HashSet<usize> =
                seqs.iter().map(|s| s.slot).collect();
            let groups = p.group_prefills(seqs);

            // Exactly-once launch.
            let launched: Vec<usize> =
                groups.iter().flat_map(|g| g.seqs.iter().map(|s| s.slot)).collect();
            assert_eq!(launched.len(), expected.len(), "no seq dropped or duplicated");
            assert_eq!(
                launched.iter().copied().collect::<std::collections::HashSet<_>>(),
                expected
            );

            // Dependency order: a block consumed as shared prefix is
            // never consumed before the group that writes it launches.
            // Writers are determined by the padded launch window, the
            // same span the implementation credits (a launch writes
            // `[cached_prefix, cached_prefix + padded)`, nothing more).
            let mut group_of_writer: std::collections::HashMap<u32, usize> = Default::default();
            for (gi, g) in groups.iter().enumerate() {
                for s in &g.seqs {
                    let lo = (s.cached_prefix / bs).min(s.cache.blocks.len());
                    let hi =
                        (s.cached_prefix + s.padded).div_ceil(bs).min(s.cache.blocks.len());
                    for &b in &s.cache.blocks[lo..hi] {
                        group_of_writer.entry(b).or_insert(gi);
                    }
                }
            }
            for (gi, g) in groups.iter().enumerate() {
                for s in &g.seqs {
                    for &b in s.cache.blocks.iter().take(s.cached_prefix / bs) {
                        if let Some(&wg) = group_of_writer.get(&b) {
                            // Strictly before: sharing a launch with the
                            // producer is an intra-graph use-before-write.
                            assert!(
                                wg < gi,
                                "group {gi} (slot {}) consumes block {b} not written before it \
                                 (writer group {wg})",
                                s.slot
                            );
                        }
                    }
                }
            }
        });
    }
}
