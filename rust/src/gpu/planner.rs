//! Batch planning: the pipeline stage between policy-ordered admission
//! and the launcher. Turns admitted sequences into prefill groups that
//! fit the AOT graph grid (full or *offset* prefill — see
//! [`PrefillGroup::offset`]), orders those groups so a prefix-sharing
//! group never launches before the group that prefills its shared blocks
//! (stage 3b's dependency order), and marshals launch inputs.
//!
//! Marshalling has two implementations:
//!
//! * the **arena path** ([`BatchPlanner::stage_decode`] /
//!   [`BatchPlanner::stage_prefill`]) — the production path: inputs are
//!   staged in place into the planner's persistent
//!   [`LaunchArena`](crate::gpu::arena::LaunchArena), allocation-free in
//!   steady state. A decode step bumps each live lane's `seq_len` and
//!   rewrites its `last_token`; `block_tables` rows are rewritten only
//!   when batch membership changed since the previous step
//!   ([`BatchPlanner::mark_decode_dirty`]) — the whole block span is
//!   reserved at admission, so a lane's table row is immutable for its
//!   lifetime and crossing a block boundary needs no row update.
//! * the **rebuild path** ([`BatchPlanner::decode_inputs`] /
//!   [`BatchPlanner::prefill_inputs`]) — the pre-arena behavior, one
//!   fresh `Vec` quartet per launch. Kept as the baseline the
//!   `decode_hotloop` bench compares against and as the
//!   reference implementation the arena-equivalence property pins the
//!   arena path to.
//!
//! Grouping and the rebuild path are pure functions of their inputs: no
//! ring, no executor, no clock — which is what makes this stage
//! unit-testable without artifacts.

use std::sync::Arc;

use crate::gpu::arena::{ArenaDims, LaunchArena, Region};
use crate::graphs::GraphCache;
use crate::kvcache::SeqCache;

/// One decode lane: a request that finished prefill and is generating.
pub struct Lane {
    pub slot: usize,
    pub cache: SeqCache,
    pub generated: u32,
    pub max_new: u32,
    pub last_token: i32,
}

/// One admitted sequence awaiting prefill — or one *chunk* of a
/// chunked prefill (see `gpu::scheduler`'s `ChunkedPrefill`): a chunk
/// carries the prompt prefix up to the chunk's end, with
/// `cached_prefix` marking the already-written tokens before it.
pub struct PrefillSeq {
    pub slot: usize,
    pub cache: SeqCache,
    pub prompt: Vec<i32>,
    pub max_new: u32,
    /// Leading prompt tokens whose K/V is already written — a prefix-
    /// reuse hit, or the completed chunks of a chunked prefill (block-
    /// aligned; 0 = cold). The prefill launch covers only the suffix.
    pub cached_prefix: usize,
    /// *Suffix* length (prompt − cached_prefix) padded up to the graph
    /// grid — with no prefix hit this is the padded prompt length,
    /// exactly as before.
    pub padded: usize,
    /// True when this launch completes the prompt's prefill and its
    /// sampled token is the request's first output token. False only
    /// for intermediate chunks of a chunked prefill, whose completion
    /// merely advances the lane's high-water mark.
    pub first_token: bool,
}

/// A group of same-padded-length sequences forming one prefill launch.
pub struct PrefillGroup {
    pub padded: usize,
    /// True when this group must launch an offset prefill graph: every
    /// member carries a cached prefix and its tokens are a suffix at a
    /// per-lane runtime offset. Cold sequences are never mixed in — they
    /// run the ordinary prefill graphs, whose grid may differ from the
    /// offset grid.
    pub offset: bool,
    pub seqs: Vec<PrefillSeq>,
}

/// Device-shaped launch inputs as owned `Vec`s — the rebuild path's
/// output (what `LaunchCmd` carried before the arena; today the bench
/// baseline and the reference the arena path is property-tested
/// against). `offsets` is populated only for offset groups (empty
/// otherwise).
pub struct LaunchInputs {
    pub block_tables: Vec<i32>,
    pub seq_lens: Vec<i32>,
    pub tokens: Vec<i32>,
    pub offsets: Vec<i32>,
}

pub struct BatchPlanner {
    /// Widest full-prefill graph in the grid.
    pub max_prefill_batch: usize,
    /// Widest *offset* prefill graph (0 when the artifacts ship none —
    /// admission never produces offset sequences in that case).
    pub max_prefill_offset_batch: usize,
    /// Manifest `max_blocks_per_seq` (block-table row width).
    pub max_blocks_per_seq: usize,
    /// Manifest `block_size` (maps a cached-prefix token count to the
    /// shared block span for dependency ordering).
    pub block_size: usize,
    /// The persistent staging planes the production marshal path writes
    /// in place (shared with the executor, which snapshots them at the
    /// device boundary).
    arena: Arc<LaunchArena>,
    /// Decode grid width the arena's decode region was last *fully*
    /// synced for — 0 ("dirty") whenever batch membership changed, which
    /// forces the next [`BatchPlanner::stage_decode`] to rewrite every
    /// row (block tables included) instead of the incremental per-step
    /// touch.
    decode_synced_grid: usize,
    /// Token-plane extent the decode region was last staged with —
    /// `grid` for plain decode, `grid × (k+1)` for a verify launch. A
    /// mismatch (the batch switched between plain and verify decode, or
    /// changed k) forces a full resync so the staged extents and ghost
    /// windows match the new layout.
    decode_synced_tok: usize,
}

impl BatchPlanner {
    /// Grouping/rebuild-path constructor (unit tests and benches): the
    /// staging arena is minimally sized, so only [`Self::group_prefills`]
    /// / [`Self::decode_inputs`] / [`Self::prefill_inputs`] may be used.
    /// The scheduler builds planners with [`Self::for_cache`], which
    /// sizes the arena to the graph grid.
    pub fn new(
        max_prefill_batch: usize,
        max_prefill_offset_batch: usize,
        max_blocks_per_seq: usize,
        block_size: usize,
    ) -> BatchPlanner {
        BatchPlanner {
            max_prefill_batch,
            max_prefill_offset_batch,
            max_blocks_per_seq,
            block_size,
            arena: Arc::new(LaunchArena::new(ArenaDims {
                decode_lanes: 1,
                decode_tokens: 1,
                prefill_lanes: max_prefill_batch.max(max_prefill_offset_batch).max(1),
                prefill_tokens: 1,
                max_blocks_per_seq,
            })),
            decode_synced_grid: 0,
            decode_synced_tok: 0,
        }
    }

    /// Production constructor: plane capacities are the widest shapes in
    /// the graph grid, allocated once here and mutated in place for the
    /// scheduler's lifetime.
    pub fn for_cache(cache: &GraphCache, max_blocks_per_seq: usize, block_size: usize) -> Self {
        BatchPlanner {
            max_prefill_batch: cache.max_prefill_batch(),
            max_prefill_offset_batch: cache.max_prefill_offset_batch(),
            max_blocks_per_seq,
            block_size,
            arena: Arc::new(LaunchArena::new(ArenaDims {
                decode_lanes: cache.max_decode_batch().max(1),
                // Widened for the draft-verify windows when the grid
                // ships decode_verify graphs (max batch × (k+1)).
                decode_tokens: cache
                    .max_verify_launch_tokens()
                    .max(cache.max_decode_batch())
                    .max(1),
                prefill_lanes: cache
                    .max_prefill_batch()
                    .max(cache.max_prefill_offset_batch())
                    .max(1),
                prefill_tokens: cache.max_launch_tokens().max(1),
                max_blocks_per_seq,
            })),
            decode_synced_grid: 0,
            decode_synced_tok: 0,
        }
    }

    /// The shared staging planes (an `Arc` clone — no allocation), for
    /// embedding into each `LaunchCmd`.
    pub fn arena(&self) -> Arc<LaunchArena> {
        self.arena.clone()
    }

    /// Batch membership changed (admit / retire / failure teardown):
    /// the next [`Self::stage_decode`] must rewrite every decode row —
    /// `swap_remove` moved a tail lane into a retired lane's row, new
    /// lanes appended rows, and ghost rows must re-replicate lane 0.
    pub fn mark_decode_dirty(&mut self) {
        self.decode_synced_grid = 0;
    }

    /// Stage the live decode batch into the arena's decode region and
    /// publish it; returns the launch epoch for the `LaunchCmd`.
    ///
    /// Steady state (same membership, same grid as the previous step)
    /// touches exactly `grid_batch` `seq_lens` slots and `grid_batch`
    /// `tokens` slots — the in-place "bump `seq_len`, write
    /// `last_token`" update of the paper's GPU-resident batch state.
    /// Block-table rows are written only on a full sync: a lane's
    /// reservation is fixed at admission, so its row never changes while
    /// it lives, and ghost rows (grid wider than the batch) replicate
    /// lane 0, whose identity is stable between membership changes.
    pub fn stage_decode(&mut self, lanes: &[Lane], grid_batch: usize) -> u64 {
        debug_assert!(!lanes.is_empty() && lanes.len() <= grid_batch);
        assert!(
            grid_batch <= self.arena.dims().decode_lanes,
            "staging a {grid_batch}-wide decode batch on an arena sized for {} lanes — \
             planners built with BatchPlanner::new are rebuild-path only; use for_cache",
            self.arena.dims().decode_lanes
        );
        let a = &self.arena;
        if self.decode_synced_grid != grid_batch || self.decode_synced_tok != grid_batch {
            for (i, l) in lanes.iter().enumerate() {
                a.write_block_row(Region::Decode, i, &l.cache.blocks);
            }
            for g in lanes.len()..grid_batch {
                a.write_block_row(Region::Decode, g, &lanes[0].cache.blocks);
            }
            a.stage_extents(
                Region::Decode,
                grid_batch * self.max_blocks_per_seq,
                grid_batch,
                grid_batch,
                0,
            );
            self.decode_synced_grid = grid_batch;
            self.decode_synced_tok = grid_batch;
        }
        for (i, l) in lanes.iter().enumerate() {
            a.write_seq_len(Region::Decode, i, l.cache.cached_len as i32);
            a.write_token(Region::Decode, i, l.last_token);
        }
        // Ghost lanes perform the same (benign, identical) KV write as
        // lane 0, so their position must track lane 0's every step.
        for g in lanes.len()..grid_batch {
            a.write_seq_len(Region::Decode, g, lanes[0].cache.cached_len as i32);
            a.write_token(Region::Decode, g, lanes[0].last_token);
        }
        a.publish()
    }

    /// Stage the live decode batch as a draft-verify launch: each lane's
    /// `(k+1)`-wide window — its pending last token followed by its `k`
    /// drafts from `drafts[lane*k .. lane*k + k]` — lands row-major in
    /// the decode token plane. Same incremental contract as
    /// [`Self::stage_decode`]: block-table rows persist across steps;
    /// switching between plain and verify layouts (or changing k)
    /// triggers one full resync because the staged token extent changes.
    /// Steady-state speculative decode touches `grid_batch` seq_lens
    /// slots and `grid_batch × (k+1)` token slots, nothing else — still
    /// zero-allocation.
    pub fn stage_decode_verify(
        &mut self,
        lanes: &[Lane],
        grid_batch: usize,
        k: usize,
        drafts: &[i32],
    ) -> u64 {
        debug_assert!(!lanes.is_empty() && lanes.len() <= grid_batch && k > 0);
        debug_assert_eq!(drafts.len(), lanes.len() * k, "k drafts per live lane");
        let w = k + 1;
        let dims = self.arena.dims();
        assert!(
            grid_batch <= dims.decode_lanes && grid_batch * w <= dims.decode_tokens,
            "staging a ({grid_batch}, k={k}) verify launch on an arena sized for {} lanes / {} \
             decode tokens — planners built with BatchPlanner::new are rebuild-path only; \
             use for_cache",
            dims.decode_lanes,
            dims.decode_tokens
        );
        let a = &self.arena;
        if self.decode_synced_grid != grid_batch || self.decode_synced_tok != grid_batch * w {
            for (i, l) in lanes.iter().enumerate() {
                a.write_block_row(Region::Decode, i, &l.cache.blocks);
            }
            for g in lanes.len()..grid_batch {
                a.write_block_row(Region::Decode, g, &lanes[0].cache.blocks);
            }
            a.stage_extents(
                Region::Decode,
                grid_batch * self.max_blocks_per_seq,
                grid_batch,
                grid_batch * w,
                0,
            );
            self.decode_synced_grid = grid_batch;
            self.decode_synced_tok = grid_batch * w;
        }
        for (i, l) in lanes.iter().enumerate() {
            a.write_seq_len(Region::Decode, i, l.cache.cached_len as i32);
            a.write_token(Region::Decode, i * w, l.last_token);
            for j in 0..k {
                a.write_token(Region::Decode, i * w + 1 + j, drafts[i * k + j]);
            }
        }
        // Ghost lanes replicate lane 0's whole window: their KV writes
        // must be byte-identical to lane 0's so they stay benign.
        for g in lanes.len()..grid_batch {
            a.write_seq_len(Region::Decode, g, lanes[0].cache.cached_len as i32);
            a.write_token(Region::Decode, g * w, lanes[0].last_token);
            for j in 0..k {
                a.write_token(Region::Decode, g * w + 1 + j, drafts[j]);
            }
        }
        a.publish()
    }

    /// Stage one prefill group into the arena's prefill region for a
    /// `(grid_batch, grid_seq)` graph and publish it; returns the launch
    /// epoch. Prefill groups are transient, so the whole region is
    /// restaged per launch (still allocation-free: the planes persist).
    /// Semantics mirror [`Self::prefill_inputs`]: suffix-only tokens,
    /// full-length `seq_lens`, ghost lanes replicating lane 0, per-lane
    /// runtime offsets for offset groups.
    pub fn stage_prefill(&self, group: &PrefillGroup, grid_batch: usize, grid_seq: usize) -> u64 {
        let b_actual = group.seqs.len();
        debug_assert!(b_actual > 0 && b_actual <= grid_batch);
        let dims = self.arena.dims();
        assert!(
            grid_batch <= dims.prefill_lanes && grid_batch * grid_seq <= dims.prefill_tokens,
            "staging a ({grid_batch}, {grid_seq}) prefill on an arena sized for {} lanes / {} \
             tokens — planners built with BatchPlanner::new are rebuild-path only; use for_cache",
            dims.prefill_lanes,
            dims.prefill_tokens
        );
        let a = &self.arena;
        let stage_row = |row: usize, s: &PrefillSeq| {
            let suffix = &s.prompt[s.cached_prefix.min(s.prompt.len())..];
            debug_assert!(suffix.len() <= grid_seq, "suffix exceeds prefill grid");
            a.write_block_row(Region::Prefill, row, &s.cache.blocks);
            a.write_seq_len(Region::Prefill, row, s.prompt.len() as i32);
            let base = row * grid_seq;
            for (j, &t) in suffix.iter().enumerate() {
                a.write_token(Region::Prefill, base + j, t);
            }
            for j in suffix.len()..grid_seq {
                a.write_token(Region::Prefill, base + j, 0);
            }
            if group.offset {
                a.write_offset(row, s.cached_prefix as i32);
            }
        };
        for (i, s) in group.seqs.iter().enumerate() {
            stage_row(i, s);
        }
        for g in b_actual..grid_batch {
            stage_row(g, &group.seqs[0]);
        }
        a.stage_extents(
            Region::Prefill,
            grid_batch * self.max_blocks_per_seq,
            grid_batch,
            grid_batch * grid_seq,
            if group.offset { grid_batch } else { 0 },
        );
        a.publish()
    }

    /// Group admitted sequences into prefill launches, in shared-block
    /// dependency order (the stage-3b contract): a sequence never lands
    /// in a group positioned at or before the group that prefills blocks
    /// it consumes as a shared prefix.
    ///
    /// Sequences are first topologically ordered at *sequence*
    /// granularity (consumer after the writer of its shared blocks —
    /// Kahn, stable in admission order), then greedily packed into
    /// groups keyed by (padded length, offset-ness) up to the matching
    /// graph grid's batch width, with the constraint that a sequence may
    /// only join a group positioned strictly after every group holding
    /// one of its producers. Ordering at sequence rather than group
    /// granularity matters: merging same-shape sequences first could
    /// weld two mutually-dependent chains into a group-level cycle that
    /// no launch order resolves.
    ///
    /// Hit sequences (cached_prefix > 0) form *offset* groups; cold
    /// sequences form full-prefill groups — the two kinds never share a
    /// launch, because their graph grids differ.
    ///
    /// Chunks of a chunked prefill are ordinary sequences here: chunk
    /// *k*+1 consumes (as `cached_prefix`) exactly the blocks chunk *k*
    /// writes, so the same consumer→writer edges that order sharers
    /// after producers also order a lane's own chunks — self-edges in
    /// the slot sense, regular edges in the sequence sense. For that to
    /// hold, a sequence's *write span* must be its padded launch window
    /// `[cached_prefix, cached_prefix + padded)`, not its whole
    /// reservation: chunks of one lane share a block list, and crediting
    /// every chunk with the full tail would let an earlier-listed chunk
    /// absorb a later chunk's writes and drop the k→k+1 edge.
    ///
    /// Today the prefix index only ever matches blocks whose prefill
    /// already *completed* (kvcache invariant 5), so intra-admission
    /// edges cannot arise through the index — the order is enforced
    /// unconditionally so the invariant is structural, not incidental:
    /// any future source of intra-admission sharing (speculative
    /// matches, async launch pipelining) inherits a correct launch order
    /// instead of a latent use-before-write.
    pub fn group_prefills(&self, admitted: Vec<PrefillSeq>) -> Vec<PrefillGroup> {
        let n = admitted.len();
        if n == 0 {
            return vec![];
        }
        let bs = self.block_size.max(1);
        // writer[block] = admitted index whose prefill launch writes it:
        // the blocks under the padded launch window. (The decode region
        // past the window is written by decode steps, which no admitted
        // prefill can consume as a shared prefix — the index only ever
        // holds full *prompt* blocks.)
        let mut writer: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
        for (i, s) in admitted.iter().enumerate() {
            let lo = (s.cached_prefix / bs).min(s.cache.blocks.len());
            let hi = (s.cached_prefix + s.padded).div_ceil(bs).min(s.cache.blocks.len());
            for &b in &s.cache.blocks[lo..hi] {
                writer.entry(b).or_insert(i);
            }
        }
        // Edges: consumer -> producer for every shared-prefix block
        // written by a *different* admitted sequence.
        let mut deps: Vec<Vec<usize>> = vec![vec![]; n];
        let mut rdeps: Vec<Vec<usize>> = vec![vec![]; n];
        for (i, s) in admitted.iter().enumerate() {
            for &b in s.cache.blocks.iter().take(s.cached_prefix / bs) {
                if let Some(&w) = writer.get(&b) {
                    if w != i && !deps[i].contains(&w) {
                        deps[i].push(w);
                        rdeps[w].push(i);
                    }
                }
            }
        }
        // Stable topological order (Kahn): among ready sequences, the
        // admission (policy) order is kept.
        let mut indegree: Vec<usize> = deps.iter().map(|d| d.len()).collect();
        let mut topo: Vec<usize> = Vec::with_capacity(n);
        let mut placed = vec![false; n];
        while topo.len() < n {
            match (0..n).find(|&i| !placed[i] && indegree[i] == 0) {
                Some(i) => {
                    placed[i] = true;
                    topo.push(i);
                    for &c in &rdeps[i] {
                        indegree[c] -= 1;
                    }
                }
                None => {
                    // Defensive: cyclic input. Unreachable through the
                    // prefix index (a consumed block's writer committed
                    // strictly earlier); launch the rest in admission
                    // order rather than dropping work.
                    debug_assert!(false, "cycle in prefill dependencies");
                    for i in 0..n {
                        if !placed[i] {
                            placed[i] = true;
                            topo.push(i);
                        }
                    }
                }
            }
        }
        // Greedy packing in topo order: join the first compatible group
        // positioned after all producers, else open a new one at the end.
        let mut groups: Vec<PrefillGroup> = vec![];
        let mut group_of: Vec<usize> = vec![usize::MAX; n];
        let mut slots: Vec<Option<PrefillSeq>> = admitted.into_iter().map(Some).collect();
        for i in topo {
            let s = slots[i].take().expect("topo visits each seq once");
            let offset = s.cached_prefix > 0;
            let max_batch =
                if offset { self.max_prefill_offset_batch } else { self.max_prefill_batch }.max(1);
            let min_pos = deps[i]
                .iter()
                .filter_map(|&w| (group_of[w] != usize::MAX).then(|| group_of[w] + 1))
                .max()
                .unwrap_or(0);
            let found = (min_pos..groups.len()).find(|&gi| {
                let g = &groups[gi];
                g.offset == offset && g.padded == s.padded && g.seqs.len() < max_batch
            });
            match found {
                Some(gi) => {
                    groups[gi].seqs.push(s);
                    group_of[i] = gi;
                }
                None => {
                    groups.push(PrefillGroup { padded: s.padded, offset, seqs: vec![s] });
                    group_of[i] = groups.len() - 1;
                }
            }
        }
        groups
    }

    /// Rebuild-path marshal (see module docs; the scheduler uses
    /// [`Self::stage_prefill`]): one prefill group for a
    /// `(grid_batch, grid_seq)` graph, as freshly allocated `Vec`s.
    /// Ghost lanes (grid wider than the group) replicate lane 0 —
    /// identical writes are benign, outputs ignored. Offset groups also
    /// carry per-lane runtime offsets (the block-aligned cached-prefix
    /// lengths the graph shifts rope/masking/KV-writes by).
    pub fn prefill_inputs(
        &self,
        group: &PrefillGroup,
        grid_batch: usize,
        grid_seq: usize,
    ) -> LaunchInputs {
        let mbs = self.max_blocks_per_seq;
        let b_actual = group.seqs.len();
        debug_assert!(b_actual > 0 && b_actual <= grid_batch);
        let mut block_tables = Vec::with_capacity(grid_batch * mbs);
        let mut seq_lens = Vec::with_capacity(grid_batch);
        let mut tokens = Vec::with_capacity(grid_batch * grid_seq);
        let mut offsets = Vec::with_capacity(if group.offset { grid_batch } else { 0 });
        for s in &group.seqs {
            // Prefix reuse: the launch carries only the uncached suffix;
            // seq_lens stays the *full* length so attention masks and KV
            // write offsets see the whole sequence.
            let suffix = &s.prompt[s.cached_prefix.min(s.prompt.len())..];
            debug_assert!(suffix.len() <= grid_seq, "suffix exceeds prefill grid");
            block_tables.extend(s.cache.table_row(mbs));
            seq_lens.push(s.prompt.len() as i32);
            tokens.extend(suffix);
            tokens.extend(std::iter::repeat(0).take(grid_seq - suffix.len()));
            if group.offset {
                offsets.push(s.cached_prefix as i32);
            }
        }
        for _ in b_actual..grid_batch {
            block_tables.extend_from_slice(&group.seqs[0].cache.table_row(mbs));
            seq_lens.push(group.seqs[0].prompt.len() as i32);
            let row0: Vec<i32> = tokens[..grid_seq].to_vec();
            tokens.extend(row0);
            if group.offset {
                offsets.push(group.seqs[0].cached_prefix as i32);
            }
        }
        LaunchInputs { block_tables, seq_lens, tokens, offsets }
    }

    /// Rebuild-path marshal (see module docs; the scheduler uses
    /// [`Self::stage_decode`]): the live decode lanes for a
    /// `grid_batch`-wide decode graph, ghost lanes replicating lane 0.
    pub fn decode_inputs(&self, lanes: &[Lane], grid_batch: usize) -> LaunchInputs {
        let mbs = self.max_blocks_per_seq;
        debug_assert!(!lanes.is_empty() && lanes.len() <= grid_batch);
        let mut block_tables = Vec::with_capacity(grid_batch * mbs);
        let mut seq_lens = Vec::with_capacity(grid_batch);
        let mut tokens = Vec::with_capacity(grid_batch);
        for l in lanes {
            block_tables.extend(l.cache.table_row(mbs));
            seq_lens.push(l.cache.cached_len as i32);
            tokens.push(l.last_token);
        }
        for _ in lanes.len()..grid_batch {
            block_tables.extend(lanes[0].cache.table_row(mbs));
            seq_lens.push(lanes[0].cache.cached_len as i32);
            tokens.push(lanes[0].last_token);
        }
        LaunchInputs { block_tables, seq_lens, tokens, offsets: vec![] }
    }

    /// Rebuild-path marshal for a draft-verify launch (the reference
    /// [`Self::stage_decode_verify`] is property-tested against): each
    /// lane contributes a `(k+1)`-wide token window — last token + its
    /// `k` drafts — with ghost lanes replicating lane 0's window.
    pub fn decode_verify_inputs(
        &self,
        lanes: &[Lane],
        grid_batch: usize,
        k: usize,
        drafts: &[i32],
    ) -> LaunchInputs {
        let mbs = self.max_blocks_per_seq;
        debug_assert!(!lanes.is_empty() && lanes.len() <= grid_batch && k > 0);
        debug_assert_eq!(drafts.len(), lanes.len() * k);
        let w = k + 1;
        let mut block_tables = Vec::with_capacity(grid_batch * mbs);
        let mut seq_lens = Vec::with_capacity(grid_batch);
        let mut tokens = Vec::with_capacity(grid_batch * w);
        for (i, l) in lanes.iter().enumerate() {
            block_tables.extend(l.cache.table_row(mbs));
            seq_lens.push(l.cache.cached_len as i32);
            tokens.push(l.last_token);
            tokens.extend_from_slice(&drafts[i * k..(i + 1) * k]);
        }
        for _ in lanes.len()..grid_batch {
            block_tables.extend(lanes[0].cache.table_row(mbs));
            seq_lens.push(lanes[0].cache.cached_len as i32);
            tokens.push(lanes[0].last_token);
            tokens.extend_from_slice(&drafts[..k]);
        }
        LaunchInputs { block_tables, seq_lens, tokens, offsets: vec![] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn planner() -> BatchPlanner {
        BatchPlanner::new(2, 2, 4, 16)
    }

    fn seq(slot: usize, prompt_len: usize, padded: usize) -> PrefillSeq {
        PrefillSeq {
            slot,
            cache: SeqCache { blocks: vec![1, 2], cached_len: 0, prefix_len: 0 },
            prompt: (0..prompt_len as i32).collect(),
            max_new: 4,
            cached_prefix: 0,
            padded,
            first_token: true,
        }
    }

    #[test]
    fn groups_by_padded_len_and_chunks_to_grid() {
        let p = planner();
        let groups = p.group_prefills(vec![
            seq(0, 10, 16),
            seq(1, 30, 32),
            seq(2, 12, 16),
            seq(3, 15, 16),
        ]);
        // Admission order preserved: 16-padded [0, 2] (max batch 2),
        // 32-padded [1], then the overflow 16-padded [3].
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].padded, 16);
        assert_eq!(groups[0].seqs.iter().map(|s| s.slot).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(groups[1].padded, 32);
        assert_eq!(groups[1].seqs[0].slot, 1);
        assert_eq!(groups[2].padded, 16);
        assert_eq!(groups[2].seqs[0].slot, 3);
        assert!(groups.iter().all(|g| !g.offset));
    }

    #[test]
    fn hit_and_cold_seqs_never_share_a_launch() {
        let p = planner();
        let mut hit = seq(7, 48, 16);
        hit.cached_prefix = 32;
        hit.cache.blocks = vec![5, 6, 7, 8];
        let groups = p.group_prefills(vec![seq(0, 10, 16), hit, seq(2, 12, 16)]);
        // Same padded length, but the hit runs its own offset-graph
        // launch: [0, 2] (cold, full prefill) + [7] (offset).
        assert_eq!(groups.len(), 2);
        assert!(!groups[0].offset);
        assert_eq!(groups[0].seqs.iter().map(|s| s.slot).collect::<Vec<_>>(), vec![0, 2]);
        assert!(groups[1].offset);
        assert_eq!(groups[1].seqs[0].slot, 7);
    }

    #[test]
    fn offset_groups_chunk_to_the_offset_grid() {
        // Offset grid narrower than the full-prefill grid: 3 hits with
        // the same padded suffix split 2 + 1.
        let p = BatchPlanner::new(4, 2, 4, 16);
        let mk = |slot| {
            let mut s = seq(slot, 40, 16);
            s.cached_prefix = 32;
            s
        };
        let groups = p.group_prefills(vec![mk(0), mk(1), mk(2)]);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].seqs.len(), 2);
        assert_eq!(groups[1].seqs.len(), 1);
        assert!(groups.iter().all(|g| g.offset));
    }

    #[test]
    fn prefill_inputs_pad_ghost_lanes() {
        let p = BatchPlanner::new(4, 4, 4, 16);
        let group = PrefillGroup { padded: 16, offset: false, seqs: vec![seq(5, 10, 16)] };
        let li = p.prefill_inputs(&group, 2, 16);
        assert_eq!(li.seq_lens, vec![10, 10], "ghost lane replicates lane 0");
        assert_eq!(li.block_tables.len(), 2 * 4);
        assert_eq!(li.tokens.len(), 2 * 16);
        assert_eq!(&li.tokens[..10], &li.tokens[16..26], "ghost row replicated");
        assert_eq!(&li.tokens[10..16], &[0i32; 6][..], "prompt padded with zeros");
        assert!(li.offsets.is_empty(), "full prefill carries no offsets");
    }

    #[test]
    fn prefill_inputs_carry_only_uncached_suffix() {
        let p = BatchPlanner::new(4, 4, 4, 16);
        let mut s = seq(2, 40, 16);
        s.cached_prefix = 32; // two 16-token blocks served from the index
        let group = PrefillGroup { padded: 16, offset: true, seqs: vec![s] };
        let li = p.prefill_inputs(&group, 1, 16);
        assert_eq!(li.seq_lens, vec![40], "seq_lens stays the full length");
        assert_eq!(&li.tokens[..8], &(32..40).collect::<Vec<i32>>()[..], "suffix tokens only");
        assert_eq!(&li.tokens[8..], &[0i32; 8][..], "suffix padded to the grid");
        assert_eq!(li.offsets, vec![32], "per-lane runtime offset");
    }

    #[test]
    fn offset_inputs_ghost_lanes_replicate_offset() {
        let p = BatchPlanner::new(4, 4, 4, 16);
        let mut a = seq(0, 40, 16);
        a.cached_prefix = 32;
        let mut b = seq(1, 24, 16);
        b.cached_prefix = 16;
        let group = PrefillGroup { padded: 16, offset: true, seqs: vec![a, b] };
        let li = p.prefill_inputs(&group, 4, 16);
        assert_eq!(li.offsets, vec![32, 16, 32, 32], "ghosts replicate lane 0's offset");
        assert_eq!(li.seq_lens, vec![40, 24, 40, 40]);
    }

    #[test]
    fn decode_inputs_shapes() {
        let p = BatchPlanner::new(4, 4, 4, 16);
        let lanes = vec![
            Lane {
                slot: 0,
                cache: SeqCache { blocks: vec![1], cached_len: 7, prefix_len: 0 },
                generated: 1,
                max_new: 8,
                last_token: 42,
            },
            Lane {
                slot: 1,
                cache: SeqCache { blocks: vec![2], cached_len: 9, prefix_len: 0 },
                generated: 1,
                max_new: 8,
                last_token: 43,
            },
        ];
        let li = p.decode_inputs(&lanes, 4);
        assert_eq!(li.tokens, vec![42, 43, 42, 42]);
        assert_eq!(li.seq_lens, vec![7, 9, 7, 7]);
        assert_eq!(li.block_tables.len(), 4 * 4);
        assert!(li.offsets.is_empty());
    }

    /// Toy grid for the staging-path tests: decode up to 4 lanes,
    /// prefill/offset up to (2, 32).
    fn staged_planner() -> BatchPlanner {
        use crate::graphs::{GraphId, GraphKind, GraphSpec};
        let mut specs = vec![];
        let mut id = 0;
        for b in [1usize, 2, 4] {
            specs.push(GraphSpec {
                id: GraphId(id),
                name: format!("decode_b{b}"),
                kind: GraphKind::Decode,
                batch: b,
                seq: 0,
            });
            id += 1;
        }
        for b in [1usize, 2] {
            for s in [16usize, 32] {
                for (kind, tag) in
                    [(GraphKind::Prefill, "prefill"), (GraphKind::PrefillOffset, "prefill_offset")]
                {
                    specs.push(GraphSpec {
                        id: GraphId(id),
                        name: format!("{tag}_b{b}_s{s}"),
                        kind,
                        batch: b,
                        seq: s,
                    });
                    id += 1;
                }
            }
        }
        // Verify grid k ∈ {2, 4} over every decode batch (sizes the
        // decode token plane for the verify staging tests).
        for b in [1usize, 2, 4] {
            for k in [2usize, 4] {
                specs.push(GraphSpec {
                    id: GraphId(id),
                    name: format!("decode_verify_b{b}_k{k}"),
                    kind: GraphKind::DecodeVerify,
                    batch: b,
                    seq: k,
                });
                id += 1;
            }
        }
        BatchPlanner::for_cache(&GraphCache::new(specs), 4, 16)
    }

    fn snapshot(p: &BatchPlanner, region: Region) -> LaunchInputs {
        let (mut bt, mut sl, mut tok, mut off) = (vec![], vec![], vec![], vec![]);
        p.arena().snapshot_into(region, &mut bt, &mut sl, &mut tok, &mut off);
        LaunchInputs { block_tables: bt, seq_lens: sl, tokens: tok, offsets: off }
    }

    fn mk_lane(slot: usize, blocks: Vec<u32>, cached_len: usize, last_token: i32) -> Lane {
        Lane {
            slot,
            cache: SeqCache { blocks, cached_len, prefix_len: 0 },
            generated: 1,
            max_new: 64,
            last_token,
        }
    }

    /// The arena path must marshal byte-identically to the rebuild path
    /// — full sync, then incremental steps, then a membership change —
    /// across decode and (offset) prefill launches. This is the
    /// equivalence that lets the scheduler switch marshal paths without
    /// changing which graphs launch with which logical inputs.
    #[test]
    fn prop_arena_staging_matches_rebuild_path() {
        run_prop("arena-vs-rebuild", 0xA2E, 100, |rng: &mut Rng| {
            let mut p = staged_planner();
            let mut next_block = 1u32;
            let mut lanes: Vec<Lane> = (0..1 + rng.below(4) as usize)
                .map(|i| {
                    let nb = 1 + rng.below(4) as usize;
                    let blocks: Vec<u32> = (next_block..next_block + nb as u32).collect();
                    next_block += nb as u32;
                    mk_lane(i, blocks, 1 + rng.below(60) as usize, rng.below(2048) as i32)
                })
                .collect();
            let grid = lanes.len().next_power_of_two();

            // Full sync (first step after a membership change).
            p.mark_decode_dirty();
            let e1 = p.stage_decode(&lanes, grid);
            let want = p.decode_inputs(&lanes, grid);
            let got = snapshot(&p, Region::Decode);
            assert_eq!(got.block_tables, want.block_tables);
            assert_eq!(got.seq_lens, want.seq_lens);
            assert_eq!(got.tokens, want.tokens);
            assert!(got.offsets.is_empty());

            // Incremental steps: bump state in place, stage again — the
            // arena must track without a block-table rewrite.
            for _ in 0..3 {
                for l in lanes.iter_mut() {
                    l.cache.cached_len += 1;
                    l.last_token = rng.below(2048) as i32;
                }
                let e = p.stage_decode(&lanes, grid);
                assert!(e > e1, "every step publishes a fresh epoch");
                let want = p.decode_inputs(&lanes, grid);
                let got = snapshot(&p, Region::Decode);
                assert_eq!(got.seq_lens, want.seq_lens, "incremental seq_len bump");
                assert_eq!(got.tokens, want.tokens, "incremental last_token write");
                assert_eq!(got.block_tables, want.block_tables, "rows persist untouched");
            }

            // Membership change: swap_remove a lane, mark dirty, restage.
            if lanes.len() > 1 {
                let victim = rng.below(lanes.len() as u64) as usize;
                lanes.swap_remove(victim);
                p.mark_decode_dirty();
                let grid = lanes.len().next_power_of_two();
                p.stage_decode(&lanes, grid);
                let want = p.decode_inputs(&lanes, grid);
                let got = snapshot(&p, Region::Decode);
                assert_eq!(got.block_tables, want.block_tables, "full resync after retire");
                assert_eq!(got.seq_lens, want.seq_lens);
                assert_eq!(got.tokens, want.tokens);
            }

            // Prefill group (randomly offset or cold) through both paths.
            let offset = rng.below(2) == 0;
            let cached = if offset { 16 } else { 0 };
            let s_len = cached + 1 + rng.below(16) as usize;
            let mut s = seq(9, s_len, 16);
            s.cached_prefix = cached;
            s.cache.blocks = vec![30, 31, 32];
            let group = PrefillGroup { padded: 16, offset, seqs: vec![s] };
            p.stage_prefill(&group, 2, 16);
            let want = p.prefill_inputs(&group, 2, 16);
            let got = snapshot(&p, Region::Prefill);
            assert_eq!(got.block_tables, want.block_tables);
            assert_eq!(got.seq_lens, want.seq_lens);
            assert_eq!(got.tokens, want.tokens);
            assert_eq!(got.offsets, want.offsets);
        });
    }

    /// The verify staging path must marshal byte-identically to its
    /// rebuild reference — full sync, incremental same-k steps, then a
    /// plain↔verify layout switch (which must resync extents without an
    /// explicit mark_decode_dirty).
    #[test]
    fn prop_verify_staging_matches_rebuild_path() {
        run_prop("verify-arena-vs-rebuild", 0x5EC, 100, |rng: &mut Rng| {
            let mut p = staged_planner();
            let k = if rng.below(2) == 0 { 2usize } else { 4 };
            let mut next_block = 1u32;
            let mut lanes: Vec<Lane> = (0..1 + rng.below(4) as usize)
                .map(|i| {
                    let nb = 1 + rng.below(4) as usize;
                    let blocks: Vec<u32> = (next_block..next_block + nb as u32).collect();
                    next_block += nb as u32;
                    mk_lane(i, blocks, 1 + rng.below(60) as usize, rng.below(2048) as i32)
                })
                .collect();
            let grid = lanes.len().next_power_of_two();
            let mut drafts: Vec<i32> =
                (0..lanes.len() * k).map(|_| rng.below(2048) as i32).collect();

            p.mark_decode_dirty();
            p.stage_decode_verify(&lanes, grid, k, &drafts);
            let want = p.decode_verify_inputs(&lanes, grid, k, &drafts);
            let got = snapshot(&p, Region::Decode);
            assert_eq!(got.block_tables, want.block_tables);
            assert_eq!(got.seq_lens, want.seq_lens);
            assert_eq!(got.tokens, want.tokens, "verify windows row-major");
            assert_eq!(got.tokens.len(), grid * (k + 1));

            // Incremental same-k steps: fresh drafts, bumped state.
            for _ in 0..2 {
                for l in lanes.iter_mut() {
                    l.cache.cached_len += 1;
                    l.last_token = rng.below(2048) as i32;
                }
                for d in drafts.iter_mut() {
                    *d = rng.below(2048) as i32;
                }
                p.stage_decode_verify(&lanes, grid, k, &drafts);
                let want = p.decode_verify_inputs(&lanes, grid, k, &drafts);
                let got = snapshot(&p, Region::Decode);
                assert_eq!(got.tokens, want.tokens);
                assert_eq!(got.seq_lens, want.seq_lens);
                assert_eq!(got.block_tables, want.block_tables, "rows persist across steps");
            }

            // Drop to plain decode (no membership change): the staged
            // token extent must shrink to `grid` without mark_decode_dirty.
            p.stage_decode(&lanes, grid);
            let want = p.decode_inputs(&lanes, grid);
            let got = snapshot(&p, Region::Decode);
            assert_eq!(got.tokens, want.tokens, "plain layout after verify");
            assert_eq!(got.tokens.len(), grid);

            // And back to verify.
            p.stage_decode_verify(&lanes, grid, k, &drafts);
            let want = p.decode_verify_inputs(&lanes, grid, k, &drafts);
            let got = snapshot(&p, Region::Decode);
            assert_eq!(got.tokens, want.tokens, "verify layout after plain");
        });
    }

    /// Steady-state staging leaves block-table rows alone: overwrite the
    /// arena's decode rows out-of-band, stage incrementally (rows must
    /// keep the sentinel), then mark dirty (rows must be rewritten).
    #[test]
    fn stage_decode_touches_block_tables_only_when_dirty() {
        let mut p = staged_planner();
        let lanes = vec![mk_lane(0, vec![5, 6], 10, 41), mk_lane(1, vec![7], 11, 42)];
        p.stage_decode(&lanes, 2); // initial full sync
        let arena = p.arena();
        arena.write_block_row(Region::Decode, 0, &[999]); // sentinel
        p.stage_decode(&lanes, 2); // incremental: must not rewrite rows
        let got = snapshot(&p, Region::Decode);
        assert_eq!(&got.block_tables[..4], &[999, 0, 0, 0], "row untouched in steady state");
        p.mark_decode_dirty();
        p.stage_decode(&lanes, 2);
        let got = snapshot(&p, Region::Decode);
        assert_eq!(&got.block_tables[..4], &[5, 6, 0, 0], "dirty forces the full rewrite");
    }

    /// A grid-width change (batch crossed a decode-graph boundary) also
    /// forces a full resync, so freshly exposed ghost rows never carry a
    /// previous launch's stale tables.
    #[test]
    fn stage_decode_resyncs_on_grid_change() {
        let mut p = staged_planner();
        let mut lanes = vec![mk_lane(0, vec![5], 10, 41)];
        p.stage_decode(&lanes, 1);
        lanes.push(mk_lane(1, vec![7], 3, 43));
        p.mark_decode_dirty();
        p.stage_decode(&lanes, 2);
        let got = snapshot(&p, Region::Decode);
        assert_eq!(got.seq_lens, vec![10, 3]);
        assert_eq!(got.tokens, vec![41, 43]);
        assert_eq!(got.block_tables, vec![5, 0, 0, 0, 7, 0, 0, 0]);
    }

    /// A sharer whose prefix blocks are written by a cold seq in the
    /// same admission must launch after it, whatever the padded-length
    /// sort would otherwise do.
    #[test]
    fn sharer_group_launches_after_its_producer() {
        let p = BatchPlanner::new(2, 2, 8, 16);
        // Producer: cold 64-token prompt over blocks 10..14 (padded 64 —
        // sorts *after* 16 by padded length).
        let mut producer = seq(0, 64, 64);
        producer.cache.blocks = vec![10, 11, 12, 13];
        // Sharer: 80-token prompt, 64 cached (blocks 10..14 shared),
        // 16-token suffix (padded 16 — would sort *first*).
        let mut sharer = seq(1, 80, 16);
        sharer.cached_prefix = 64;
        sharer.cache.blocks = vec![10, 11, 12, 13, 14, 15];
        let groups = p.group_prefills(vec![sharer, producer]);
        assert_eq!(groups.len(), 2);
        assert!(!groups[0].offset, "producer launches first");
        assert_eq!(groups[0].seqs[0].slot, 0);
        assert!(groups[1].offset);
        assert_eq!(groups[1].seqs[0].slot, 1);
    }

    /// Randomized sharer-group DAGs (the stage-3b property): the launch
    /// order never schedules a group before the group that prefills its
    /// shared prefix blocks, and every admitted sequence launches exactly
    /// once.
    #[test]
    fn prop_group_order_respects_block_dependencies() {
        run_prop("planner-group-topo", 0x3B, 200, |rng: &mut Rng| {
            let bs = 16usize;
            let p = BatchPlanner::new(3, 2, 16, bs);
            let mut next_block = 1u32;
            let mut alloc = |n: usize| -> Vec<u32> {
                let v: Vec<u32> = (next_block..next_block + n as u32).collect();
                next_block += n as u32;
                v
            };
            // Producers: cold seqs with random block spans.
            let n_prod = 1 + rng.below(4) as usize;
            let mut seqs: Vec<PrefillSeq> = vec![];
            for slot in 0..n_prod {
                let blocks = 1 + rng.below(4) as usize;
                let prompt_len = blocks * bs - rng.below(bs as u64 - 1) as usize;
                let mut s = seq(slot, prompt_len, prompt_len.next_power_of_two().max(16));
                s.cache.blocks = alloc(blocks);
                seqs.push(s);
            }
            // Sharers: consume a random full-block prefix of any earlier
            // seq's *written prompt* span — including another *sharer*'s
            // written tail, so hit→hit edges occur and genuinely force
            // reordering (hits with short padded suffixes would
            // otherwise sort first) — then write their own tail. Only
            // full prompt blocks are ever shareable (the index never
            // holds the decode region past a launch window), so `avail`
            // is capped there. Creation order guarantees a DAG.
            let n_share = rng.below(5) as usize;
            for i in 0..n_share {
                let prod = &seqs[rng.below(seqs.len() as u64) as usize];
                let avail = (prod.prompt.len() / bs).min(prod.cache.blocks.len());
                if avail == 0 {
                    continue;
                }
                let shared = 1 + rng.below(avail as u64) as usize;
                let suffix = 1 + rng.below(32) as usize;
                let prompt_len = shared * bs + suffix;
                let mut s = seq(100 + i, prompt_len, suffix.next_power_of_two().max(16));
                s.cached_prefix = shared * bs;
                let mut blocks = prod.cache.blocks[..shared].to_vec();
                blocks.extend(alloc(1 + suffix / bs));
                s.cache.blocks = blocks;
                seqs.push(s);
            }
            let expected: std::collections::HashSet<usize> =
                seqs.iter().map(|s| s.slot).collect();
            let groups = p.group_prefills(seqs);

            // Exactly-once launch.
            let launched: Vec<usize> =
                groups.iter().flat_map(|g| g.seqs.iter().map(|s| s.slot)).collect();
            assert_eq!(launched.len(), expected.len(), "no seq dropped or duplicated");
            assert_eq!(
                launched.iter().copied().collect::<std::collections::HashSet<_>>(),
                expected
            );

            // Dependency order: a block consumed as shared prefix is
            // never consumed before the group that writes it launches.
            // Writers are determined by the padded launch window, the
            // same span the implementation credits (a launch writes
            // `[cached_prefix, cached_prefix + padded)`, nothing more).
            let mut group_of_writer: std::collections::HashMap<u32, usize> = Default::default();
            for (gi, g) in groups.iter().enumerate() {
                for s in &g.seqs {
                    let lo = (s.cached_prefix / bs).min(s.cache.blocks.len());
                    let hi =
                        (s.cached_prefix + s.padded).div_ceil(bs).min(s.cache.blocks.len());
                    for &b in &s.cache.blocks[lo..hi] {
                        group_of_writer.entry(b).or_insert(gi);
                    }
                }
            }
            for (gi, g) in groups.iter().enumerate() {
                for s in &g.seqs {
                    for &b in s.cache.blocks.iter().take(s.cached_prefix / bs) {
                        if let Some(&wg) = group_of_writer.get(&b) {
                            // Strictly before: sharing a launch with the
                            // producer is an intra-graph use-before-write.
                            assert!(
                                wg < gi,
                                "group {gi} (slot {}) consumes block {b} not written before it \
                                 (writer group {wg})",
                                s.slot
                            );
                        }
                    }
                }
            }
        });
    }
}
