//! Launch + completion: the last two pipeline stages. [`Launcher`] wraps
//! the executor doorbell with placement-appropriate cost accounting (the
//! fire-and-forget launch-window protocol for GPU-resident placement,
//! host-launch latency for the CPU-resident baseline); [`Completions`]
//! wraps the polled completion buffer with epoch bookkeeping.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::devsim::{CompletionBuffer, LaunchLatencies, LaunchWindow};
use crate::gpu::executor::{Executor, LaunchCmd};
use crate::gpu::stats::SchedulerStats;

pub struct Launcher {
    executor: Executor,
    window: LaunchWindow,
    gpu_resident: bool,
    apply_delays: bool,
    stats: Arc<SchedulerStats>,
}

impl Launcher {
    pub fn new(
        executor: Executor,
        gpu_resident: bool,
        apply_delays: bool,
        stats: Arc<SchedulerStats>,
    ) -> Launcher {
        Launcher {
            executor,
            window: LaunchWindow::new(LaunchLatencies::default(), false),
            gpu_resident,
            apply_delays,
            stats,
        }
    }

    /// Remaining fire-and-forget launches before a tail relaunch is due.
    pub fn headroom(&self) -> u32 {
        self.window.headroom()
    }

    /// Replenish the launch window (the tail-relaunch half of the
    /// fire-and-forget protocol).
    pub fn tail_relaunch(&mut self) {
        self.window.tail_relaunch();
    }

    /// Launch a graph with placement-appropriate cost accounting.
    pub fn launch(&mut self, cmd: LaunchCmd) {
        if self.gpu_resident {
            if self.window.fnf_launch().is_err() {
                self.window.tail_relaunch();
                self.window.fnf_launch().expect("fresh window");
            }
            if self.apply_delays {
                crate::devsim::spin_us(LaunchLatencies::default().fnf_us);
            }
            self.stats.fnf_launches.store(self.window.fnf_launches, Ordering::Relaxed);
            self.stats.tail_relaunches.store(self.window.tail_relaunches, Ordering::Relaxed);
        } else if self.apply_delays {
            // Host-side launch: 11–17 µs (paper §4.2).
            crate::devsim::spin_us(LaunchLatencies::default().host_us);
        }
        self.executor.launch(cmd);
    }
}

/// Completion polling with epoch tracking (one consumer: the scheduler).
pub struct Completions {
    buffer: Arc<CompletionBuffer>,
    epoch: u64,
}

impl Completions {
    pub fn new(buffer: Arc<CompletionBuffer>) -> Completions {
        Completions { buffer, epoch: 0 }
    }

    /// The buffer handle to pass inside each `LaunchCmd`.
    pub fn buffer(&self) -> Arc<CompletionBuffer> {
        self.buffer.clone()
    }

    /// Block until the next epoch's `n` tokens arrive (None = failed).
    pub fn poll(&mut self, n: usize) -> Option<Vec<u32>> {
        let res = self.buffer.poll_wait(self.epoch, n);
        self.epoch = self.buffer.epoch();
        res
    }

    /// Allocation-free poll: block until the next epoch's `n` tokens
    /// arrive and write them into the caller's scratch (cleared first).
    /// Returns false when the executor failed the launch.
    pub fn poll_into(&mut self, n: usize, out: &mut Vec<u32>) -> bool {
        let ok = self.buffer.poll_wait_into(self.epoch, n, out);
        self.epoch = self.buffer.epoch();
        ok
    }
}
