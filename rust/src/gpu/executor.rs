//! The graph executor: models the GPU's SMs running pre-captured
//! inference graphs. Owns the (!Send) PJRT [`Engine`] on a dedicated
//! thread; receives fire-and-forget launch commands from the persistent
//! scheduler through a single-slot [`Doorbell`] and publishes sampled
//! tokens into a polled [`CompletionBuffer`] — never a callback, matching
//! the paper's completion-detection design.
//!
//! A [`LaunchCmd`] carries no input data. Inputs live in the scheduler's
//! persistent [`LaunchArena`] (staged in place, see `gpu::arena`); the
//! command names the graph plus the arena epoch its inputs were published
//! under. This boundary is where the one copy in the launch path happens:
//! the executor snapshots the staged planes into its reusable boundary
//! scratch (and, on the real engine, from there into device buffers) —
//! once per launch, not once per pipeline hop, and allocation-free after
//! the scratch has grown to the widest grid.
//!
//! Two backends behind one doorbell:
//!
//! * [`Executor::spawn`] — the real PJRT engine (needs AOT artifacts and
//!   the native bindings).
//! * [`Executor::spawn_modeled`] — no PJRT, no artifacts: validates every
//!   launch against the manifest graph grid exactly as the engine would,
//!   charges a modeled per-launch cost (suffix-only for offset prefill
//!   graphs — the graph's shape *is* the padded suffix, mirroring the
//!   DES's `CostModel::prefill_with_prefix_s`), and publishes
//!   deterministic non-EOS tokens. This is what lets scheduler-level
//!   tests and `blink eval prefix-live` run the full pipeline on any
//!   machine.

use std::sync::mpsc::channel;
use std::sync::Arc;

use crate::devsim::{CompletionBuffer, Doorbell};
use crate::gpu::arena::{LaunchArena, Region};
use crate::graphs::{GraphCache, GraphId, GraphKind};
use crate::runtime::{Engine, ModelManifest};

/// One launch: the graph to run, the arena holding its staged inputs,
/// the epoch those inputs were published under (the executor refuses a
/// stale epoch rather than read torn inputs — see `gpu::arena`'s
/// ownership rule), and the completion buffer the scheduler will poll.
pub struct LaunchCmd {
    pub graph: GraphId,
    pub arena: Arc<LaunchArena>,
    pub epoch: u64,
    pub seed: u32,
    pub completion: Arc<CompletionBuffer>,
}

impl LaunchCmd {
    /// Which arena region this launch reads, from the graph kind.
    /// Verify launches read the decode region: they are decode steps
    /// with a (k+1)-wide token window per lane (see `gpu::planner`'s
    /// `stage_decode_verify`).
    pub fn region(kind: GraphKind) -> Region {
        match kind {
            GraphKind::Decode | GraphKind::DecodeVerify => Region::Decode,
            GraphKind::Prefill | GraphKind::PrefillOffset => Region::Prefill,
        }
    }
}

/// Cost profile for the modeled executor, in microseconds (charged by
/// spinning, like the device plane's launch delays). The defaults keep
/// tests fast while preserving the shape the DES models: prefill cost
/// scales with *launched* tokens — so an offset graph covering only the
/// uncached suffix is proportionally cheaper than a full prefill — and
/// decode pays a flat per-step cost.
#[derive(Debug, Clone, Copy)]
pub struct ModeledCost {
    pub prefill_us_per_token: f64,
    pub decode_step_us: f64,
    /// MoE models only: extra decode cost per *activated* expert per
    /// step. A decode step over batch `b` activates on expectation
    /// `E·(1 − (1 − k/E)^b)` of `E` experts (the union of `b`
    /// independent top-`k` draws — the same expert-union math
    /// `CostModel::active_weight_bytes` uses), so sparse decode gets
    /// cheaper per token as the batch grows but pays a dispatch tax a
    /// dense model never sees. Ignored for dense manifests.
    pub expert_dispatch_us: f64,
    /// Speculative verify only: extra cost per *draft* position per
    /// lane, on top of the flat decode step — a verify launch over
    /// batch `b` with `k` drafts charges
    /// `decode_step_us + verify_pos_us·b·k` (plus the MoE dispatch
    /// tax), so at `k = 0` it degenerates to a plain decode step,
    /// mirroring `CostModel::verify_step_s`.
    pub verify_pos_us: f64,
    /// When set, decode/prefill emission follows the deterministic
    /// *greedy chain* ([`greedy_chain_token`]): each lane's next token
    /// is a pure function of (previous token, absolute position), not
    /// of the launch seed. This makes a lane's token stream invariant
    /// to how many launches produced it — the k-step verify window and
    /// k sequential decode steps yield byte-identical streams, which
    /// is what pins speculative decode's correctness tests. Chain mode
    /// does *not* skip EOS, so mid-window EOS truncation occurs
    /// naturally. Verify graphs are always chain-scored, regardless of
    /// this flag.
    pub greedy_chain: bool,
}

impl Default for ModeledCost {
    fn default() -> Self {
        ModeledCost {
            prefill_us_per_token: 0.2,
            decode_step_us: 2.0,
            expert_dispatch_us: 0.0,
            verify_pos_us: 0.4,
            greedy_chain: false,
        }
    }
}

impl ModeledCost {
    pub fn zero() -> Self {
        ModeledCost {
            prefill_us_per_token: 0.0,
            decode_step_us: 0.0,
            expert_dispatch_us: 0.0,
            verify_pos_us: 0.0,
            greedy_chain: false,
        }
    }
}

/// Deterministic greedy-chain successor: the token the modeled model
/// "greedily decodes" after seeing `prev` at absolute sequence position
/// `pos`. Pure in `(prev, pos)` — replaying a lane token by token and
/// scoring a whole verify window in one launch produce byte-identical
/// streams (the property speculative decode's acceptance rule relies
/// on). EOS is deliberately *not* skipped: with a small test vocab the
/// chain hits EOS naturally, exercising mid-window truncation.
pub fn greedy_chain_token(vocab: u32, prev: u32, pos: u64) -> u32 {
    let h = mix64(((prev as u64) << 32) ^ pos.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (h % (vocab.max(1) as u64)) as u32
}

/// Expected number of distinct experts activated by a decode step over
/// `batch` lanes with top-`k`-of-`n` routing: `n·(1 − (1 − k/n)^batch)`.
/// `top_k` at batch 1, saturating toward `n` as lanes stack up.
pub fn expected_active_experts(n_experts: usize, top_k: usize, batch: usize) -> f64 {
    if n_experts == 0 || top_k == 0 || batch == 0 {
        return 0.0;
    }
    let n = n_experts as f64;
    let k = top_k.min(n_experts) as f64;
    n * (1.0 - (1.0 - k / n).powi(batch as i32))
}

/// Reusable boundary buffers: the staged planes are copied here once per
/// launch. Capacities grow to the widest grid during warmup and then
/// never change — the executor thread is allocation-free in steady state.
#[derive(Default)]
struct BoundaryScratch {
    block_tables: Vec<i32>,
    seq_lens: Vec<i32>,
    tokens: Vec<i32>,
    offsets: Vec<i32>,
    /// Sampled-token staging for the completion publish.
    out: Vec<u32>,
}

impl BoundaryScratch {
    fn with_capacity(bt: usize, sl: usize, tok: usize, off: usize) -> BoundaryScratch {
        BoundaryScratch {
            block_tables: Vec::with_capacity(bt),
            seq_lens: Vec::with_capacity(sl),
            tokens: Vec::with_capacity(tok),
            offsets: Vec::with_capacity(off),
            // Verify launches publish one token per *window position*
            // (b·(k+1) = the token-plane extent), not one per lane.
            out: Vec::with_capacity(tok.max(sl)),
        }
    }

    /// Protocol steps 3+4 (see `gpu::arena`): check the epoch, then copy
    /// the staged extents out of the arena.
    fn snapshot(&mut self, cmd: &LaunchCmd, kind: GraphKind) -> Result<(), String> {
        let seen = cmd.arena.epoch();
        if seen != cmd.epoch {
            return Err(format!(
                "stale launch epoch: command {} vs arena {seen} (staged before poll?)",
                cmd.epoch
            ));
        }
        cmd.arena.snapshot_into(
            LaunchCmd::region(kind),
            &mut self.block_tables,
            &mut self.seq_lens,
            &mut self.tokens,
            &mut self.offsets,
        );
        Ok(())
    }
}

/// Handle to the executor thread.
pub struct Executor {
    bell: Arc<Doorbell<LaunchCmd>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn the executor thread; it loads the engine itself (PJRT handles
    /// are thread-bound). Blocks until the engine is ready so callers see
    /// load errors synchronously — this is host-assisted initialization,
    /// the one phase where the host is allowed on the path.
    pub fn spawn(artifacts: std::path::PathBuf, model: String) -> anyhow::Result<Executor> {
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let bell = Arc::new(Doorbell::<LaunchCmd>::new());
        let bell2 = bell.clone();
        let handle = std::thread::Builder::new()
            .name("gpu-executor".into())
            .spawn(move || {
                let mut engine = match Engine::load(&artifacts, &model) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                let mut scratch = BoundaryScratch::default();
                while let Some(cmd) = bell2.recv() {
                    let kind = engine.cache.spec(cmd.graph).kind;
                    if let Err(e) = scratch.snapshot(&cmd, kind) {
                        eprintln!("executor: {e}");
                        cmd.completion.fail();
                        continue;
                    }
                    match engine.execute(
                        cmd.graph,
                        &scratch.block_tables,
                        &scratch.seq_lens,
                        &scratch.tokens,
                        &scratch.offsets,
                        cmd.seed,
                    ) {
                        Ok(tokens) => {
                            scratch.out.clear();
                            scratch.out.extend(tokens.iter().map(|t| *t as u32));
                            cmd.completion.publish(&scratch.out);
                        }
                        Err(e) => {
                            eprintln!("executor: graph execution failed: {e:#}");
                            cmd.completion.fail();
                        }
                    }
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Executor { bell, handle: Some(handle) }),
            Ok(Err(e)) => anyhow::bail!("engine load failed: {e}"),
            Err(_) => anyhow::bail!("executor thread died during load"),
        }
    }

    /// Spawn a *modeled* executor over the manifest's graph grid: the
    /// same doorbell/poll protocol, the same arena-boundary snapshot and
    /// the same shape validation as the real engine, with deterministic
    /// token generation instead of PJRT execution. In the default
    /// (seed-based) mode tokens never equal the manifest's EOS, so a
    /// lane always runs to its `max_new` budget — which is what makes
    /// scheduler-level assertions (batch counts, offset-graph launches)
    /// reproducible. With [`ModeledCost::greedy_chain`] set, emission
    /// follows the greedy chain instead (EOS included), the mode the
    /// speculative-decode correctness tests run under.
    pub fn spawn_modeled(manifest: &ModelManifest, cost: ModeledCost) -> Executor {
        let cache = crate::gpu::scheduler::cache_from_manifest(manifest);
        let max_blocks = manifest.max_blocks_per_seq;
        let vocab = manifest.vocab_size.max(2) as u32;
        let eos = manifest.eos_token;
        // MoE manifests pay the expert-dispatch tax on decode steps.
        let moe = if manifest.moe { Some((manifest.n_experts, manifest.top_k)) } else { None };
        let bell = Arc::new(Doorbell::<LaunchCmd>::new());
        let bell2 = bell.clone();
        // Pre-reserve the boundary scratch to the grid's widest shapes so
        // even the first launches never grow it mid-run.
        let max_b = cache.specs().iter().map(|s| s.batch).max().unwrap_or(1).max(1);
        let max_tok =
            cache.max_launch_tokens().max(cache.max_verify_launch_tokens()).max(max_b);
        let handle = std::thread::Builder::new()
            .name("gpu-executor-modeled".into())
            .spawn(move || {
                let mut scratch =
                    BoundaryScratch::with_capacity(max_b * max_blocks, max_b, max_tok, max_b);
                while let Some(cmd) = bell2.recv() {
                    let res =
                        modeled_step(&cache, max_blocks, vocab, eos, cost, moe, &cmd, &mut scratch);
                    match res {
                        Ok(()) => cmd.completion.publish(&scratch.out),
                        Err(e) => {
                            eprintln!("modeled executor: {e}");
                            cmd.completion.fail();
                        }
                    }
                }
            })
            .expect("spawn modeled executor");
        Executor { bell, handle: Some(handle) }
    }

    /// Fire-and-forget launch: ring the doorbell and return immediately;
    /// the caller polls the completion buffer it passed in. Allocation-
    /// free — the single-slot doorbell has no queue to grow.
    pub fn launch(&self, cmd: LaunchCmd) {
        // After shutdown the ring is a dropped no-op; nothing launches
        // and nothing is polled, so ignoring the result is safe.
        let _ = self.bell.ring(cmd);
    }

    pub fn shutdown(&mut self) {
        self.bell.close();
    }
}

/// One modeled launch: validate the staged shapes with the *same* checker
/// `Engine::execute` applies (`GraphSpec::validate_launch_shapes` — one
/// implementation, no drift), charge the modeled cost, emit one
/// deterministic non-EOS token per lane into `scratch.out`.
#[allow(clippy::too_many_arguments)]
fn modeled_step(
    cache: &GraphCache,
    max_blocks: usize,
    vocab: u32,
    eos: u32,
    cost: ModeledCost,
    moe: Option<(usize, usize)>,
    cmd: &LaunchCmd,
    scratch: &mut BoundaryScratch,
) -> Result<(), String> {
    let spec = cache.spec(cmd.graph);
    let b = spec.batch;
    scratch.snapshot(cmd, spec.kind)?;
    spec.validate_launch_shapes(
        max_blocks,
        scratch.block_tables.len(),
        scratch.seq_lens.len(),
        scratch.tokens.len(),
        scratch.offsets.len(),
    )?;
    if spec.kind == GraphKind::PrefillOffset {
        // An offset beyond its lane's length would put the KV write
        // window outside the sequence — a marshalling bug upstream.
        for (i, (&off, &len)) in scratch.offsets.iter().zip(&scratch.seq_lens).enumerate() {
            if off < 0 || off >= len {
                return Err(format!("{}: lane {i} offset {off} not in 0..{len}", spec.name));
            }
        }
    }

    // Cost: suffix-only for offset graphs by construction — the launched
    // token count *is* batch × padded-suffix. Verify pays a flat decode
    // step plus a per-draft-position surcharge (k = 0 would degenerate
    // to plain decode, matching `CostModel::verify_step_s`).
    let us = match spec.kind {
        GraphKind::Decode | GraphKind::DecodeVerify => {
            let dispatch =
                moe.map_or(0.0, |(e, k)| cost.expert_dispatch_us * expected_active_experts(e, k, b));
            let verify = if spec.kind == GraphKind::DecodeVerify {
                cost.verify_pos_us * (b * spec.seq) as f64
            } else {
                0.0
            };
            cost.decode_step_us + verify + dispatch
        }
        GraphKind::Prefill | GraphKind::PrefillOffset => {
            cost.prefill_us_per_token * (b * spec.seq) as f64
        }
    };
    crate::devsim::spin_us(us);

    scratch.out.clear();
    match spec.kind {
        // Verify windows are always chain-scored: out[lane·w + j] is the
        // greedy successor of window position j at absolute position
        // seq_len + j — byte-identical to j chain-mode decode steps,
        // which is exactly what the retire pass's prefix-acceptance
        // rule compares against.
        GraphKind::DecodeVerify => {
            let w = spec.seq + 1;
            for lane in 0..b {
                let base = scratch.seq_lens[lane] as u64;
                for j in 0..w {
                    let prev = scratch.tokens[lane * w + j] as u32;
                    scratch.out.push(greedy_chain_token(vocab, prev, base + j as u64));
                }
            }
        }
        GraphKind::Decode if cost.greedy_chain => {
            // The staged token is the lane's last sampled token, sitting
            // at absolute position seq_len (its K/V write slot).
            for lane in 0..b {
                let prev = scratch.tokens[lane] as u32;
                scratch.out.push(greedy_chain_token(vocab, prev, scratch.seq_lens[lane] as u64));
            }
        }
        GraphKind::Prefill | GraphKind::PrefillOffset if cost.greedy_chain => {
            // Root the chain in the prompt itself: the first generated
            // token follows the last *real* prompt token at position
            // len − 1, independent of the launch seed — so the whole
            // stream is a pure function of the prompt and chain-mode
            // runs with different launch interleavings stay comparable.
            for lane in 0..b {
                let len = (scratch.seq_lens[lane].max(1)) as usize;
                let off = if spec.kind == GraphKind::PrefillOffset {
                    scratch.offsets[lane] as usize
                } else {
                    0
                };
                let idx = lane * spec.seq + (len - off - 1).min(spec.seq - 1);
                let prev = scratch.tokens[idx] as u32;
                scratch.out.push(greedy_chain_token(vocab, prev, (len - 1) as u64));
            }
        }
        _ => {
            scratch.out.extend((0..b).map(|lane| {
                let h =
                    mix64((cmd.seed as u64) ^ (lane as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let r = (h % (vocab as u64 - 1)) as u32;
                // Skip EOS so modeled lanes always run their full budget.
                if r >= eos { r + 1 } else { r }
            }));
        }
    }
    Ok(())
}

fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.bell.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expert_union_matches_routing_math() {
        // batch 1 activates exactly top_k experts.
        assert!((expected_active_experts(4, 2, 1) - 2.0).abs() < 1e-12);
        // Monotone in batch, saturating below n_experts.
        let mut prev = 0.0;
        for b in 1..=32 {
            let e = expected_active_experts(4, 2, b);
            assert!(e > prev, "monotone in batch: {e} vs {prev}");
            assert!(e <= 4.0 + 1e-12);
            prev = e;
        }
        assert!(prev > 3.9, "large batches activate nearly all experts: {prev}");
        // Degenerate configs dispatch nothing.
        assert_eq!(expected_active_experts(0, 2, 8), 0.0);
        assert_eq!(expected_active_experts(4, 0, 8), 0.0);
        assert_eq!(expected_active_experts(4, 2, 0), 0.0);
        // top_k clamped to n_experts: dense-equivalent routing.
        assert!((expected_active_experts(4, 9, 3) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn greedy_chain_is_pure_and_in_range() {
        // Pure in (prev, pos), bounded by vocab, and position-sensitive
        // (the verify acceptance rule leans on all three).
        let a = greedy_chain_token(17, 5, 42);
        assert_eq!(a, greedy_chain_token(17, 5, 42));
        assert!(a < 17);
        assert_ne!(greedy_chain_token(1 << 20, 5, 42), greedy_chain_token(1 << 20, 5, 43));
        assert_ne!(greedy_chain_token(1 << 20, 5, 42), greedy_chain_token(1 << 20, 6, 42));
        // Degenerate vocab never divides by zero.
        assert_eq!(greedy_chain_token(0, 1, 1), 0);
    }
}
