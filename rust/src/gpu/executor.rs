//! The graph executor: models the GPU's SMs running pre-captured
//! inference graphs. Owns the (!Send) PJRT [`Engine`] on a dedicated
//! thread; receives fire-and-forget launch commands from the persistent
//! scheduler and publishes sampled tokens into a polled
//! [`CompletionBuffer`] — never a callback, matching the paper's
//! completion-detection design.
//!
//! Two backends behind one doorbell:
//!
//! * [`Executor::spawn`] — the real PJRT engine (needs AOT artifacts and
//!   the native bindings).
//! * [`Executor::spawn_modeled`] — no PJRT, no artifacts: validates every
//!   launch against the manifest graph grid exactly as the engine would,
//!   charges a modeled per-launch cost (suffix-only for offset prefill
//!   graphs — the graph's shape *is* the padded suffix, mirroring the
//!   DES's `CostModel::prefill_with_prefix_s`), and publishes
//!   deterministic non-EOS tokens. This is what lets scheduler-level
//!   tests and `blink eval prefix-live` run the full pipeline on any
//!   machine.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use crate::devsim::CompletionBuffer;
use crate::graphs::{GraphCache, GraphId, GraphKind};
use crate::runtime::{Engine, ModelManifest};

/// One launch: everything the graph needs, plus the completion buffer the
/// scheduler will poll. `offsets` is per-lane cached-prefix lengths for
/// offset prefill graphs (empty for every other kind); `reset_kv`
/// supports benchmark phase boundaries.
pub struct LaunchCmd {
    pub graph: GraphId,
    pub block_tables: Vec<i32>,
    pub seq_lens: Vec<i32>,
    pub tokens: Vec<i32>,
    pub offsets: Vec<i32>,
    pub seed: u32,
    pub completion: Arc<CompletionBuffer>,
    pub reset_kv: bool,
}

/// Cost profile for the modeled executor, in microseconds (charged by
/// spinning, like the device plane's launch delays). The defaults keep
/// tests fast while preserving the shape the DES models: prefill cost
/// scales with *launched* tokens — so an offset graph covering only the
/// uncached suffix is proportionally cheaper than a full prefill — and
/// decode pays a flat per-step cost.
#[derive(Debug, Clone, Copy)]
pub struct ModeledCost {
    pub prefill_us_per_token: f64,
    pub decode_step_us: f64,
}

impl Default for ModeledCost {
    fn default() -> Self {
        ModeledCost { prefill_us_per_token: 0.2, decode_step_us: 2.0 }
    }
}

impl ModeledCost {
    pub fn zero() -> Self {
        ModeledCost { prefill_us_per_token: 0.0, decode_step_us: 0.0 }
    }
}

/// Handle to the executor thread.
pub struct Executor {
    tx: Sender<LaunchCmd>,
    alive: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn the executor thread; it loads the engine itself (PJRT handles
    /// are thread-bound). Blocks until the engine is ready so callers see
    /// load errors synchronously — this is host-assisted initialization,
    /// the one phase where the host is allowed on the path.
    pub fn spawn(artifacts: std::path::PathBuf, model: String) -> anyhow::Result<Executor> {
        let (tx, rx) = channel::<LaunchCmd>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let alive = Arc::new(AtomicBool::new(true));
        let alive2 = alive.clone();
        let handle = std::thread::Builder::new()
            .name("gpu-executor".into())
            .spawn(move || {
                let mut engine = match Engine::load(&artifacts, &model) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok(cmd) = rx.recv() {
                    if !alive2.load(Ordering::Acquire) {
                        break;
                    }
                    if cmd.reset_kv {
                        if engine.reset_kv().is_err() {
                            cmd.completion.fail();
                            continue;
                        }
                        if cmd.tokens.is_empty() {
                            cmd.completion.publish(&[]);
                            continue;
                        }
                    }
                    match engine.execute(
                        cmd.graph,
                        &cmd.block_tables,
                        &cmd.seq_lens,
                        &cmd.tokens,
                        &cmd.offsets,
                        cmd.seed,
                    ) {
                        Ok(tokens) => {
                            let toks: Vec<u32> = tokens.iter().map(|t| *t as u32).collect();
                            cmd.completion.publish(&toks);
                        }
                        Err(e) => {
                            eprintln!("executor: graph execution failed: {e:#}");
                            cmd.completion.fail();
                        }
                    }
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Executor { tx, alive, handle: Some(handle) }),
            Ok(Err(e)) => anyhow::bail!("engine load failed: {e}"),
            Err(_) => anyhow::bail!("executor thread died during load"),
        }
    }

    /// Spawn a *modeled* executor over the manifest's graph grid: the
    /// same launch/poll protocol and the same shape validation as the
    /// real engine, with deterministic token generation instead of PJRT
    /// execution. Tokens never equal the manifest's EOS, so a lane always
    /// runs to its `max_new` budget — which is what makes scheduler-level
    /// assertions (batch counts, offset-graph launches) reproducible.
    pub fn spawn_modeled(manifest: &ModelManifest, cost: ModeledCost) -> Executor {
        let cache = crate::gpu::scheduler::cache_from_manifest(manifest);
        let max_blocks = manifest.max_blocks_per_seq;
        let vocab = manifest.vocab_size.max(2) as u32;
        let eos = manifest.eos_token;
        let (tx, rx) = channel::<LaunchCmd>();
        let alive = Arc::new(AtomicBool::new(true));
        let alive2 = alive.clone();
        let handle = std::thread::Builder::new()
            .name("gpu-executor-modeled".into())
            .spawn(move || {
                while let Ok(cmd) = rx.recv() {
                    if !alive2.load(Ordering::Acquire) {
                        break;
                    }
                    if cmd.reset_kv && cmd.tokens.is_empty() {
                        cmd.completion.publish(&[]);
                        continue;
                    }
                    match modeled_step(&cache, max_blocks, vocab, eos, cost, &cmd) {
                        Ok(toks) => cmd.completion.publish(&toks),
                        Err(e) => {
                            eprintln!("modeled executor: {e}");
                            cmd.completion.fail();
                        }
                    }
                }
            })
            .expect("spawn modeled executor");
        Executor { tx, alive, handle: Some(handle) }
    }

    /// Fire-and-forget launch: returns immediately; the caller polls the
    /// completion buffer it passed in.
    pub fn launch(&self, cmd: LaunchCmd) {
        let _ = self.tx.send(cmd);
    }

    pub fn shutdown(&mut self) {
        self.alive.store(false, Ordering::Release);
        // Unblock recv with a no-op command if needed: dropping tx suffices
        // when Executor drops; explicit shutdown just marks the flag.
    }
}

/// One modeled launch: validate shapes with the *same* checker
/// `Engine::execute` applies (`GraphSpec::validate_launch_shapes` — one
/// implementation, no drift), charge the modeled cost, emit one
/// deterministic non-EOS token per lane.
fn modeled_step(
    cache: &GraphCache,
    max_blocks: usize,
    vocab: u32,
    eos: u32,
    cost: ModeledCost,
    cmd: &LaunchCmd,
) -> Result<Vec<u32>, String> {
    let spec = cache.spec(cmd.graph);
    let b = spec.batch;
    spec.validate_launch_shapes(
        max_blocks,
        cmd.block_tables.len(),
        cmd.seq_lens.len(),
        cmd.tokens.len(),
        cmd.offsets.len(),
    )?;
    if spec.kind == GraphKind::PrefillOffset {
        // An offset beyond its lane's length would put the KV write
        // window outside the sequence — a marshalling bug upstream.
        for (i, (&off, &len)) in cmd.offsets.iter().zip(&cmd.seq_lens).enumerate() {
            if off < 0 || off >= len {
                return Err(format!("{}: lane {i} offset {off} not in 0..{len}", spec.name));
            }
        }
    }

    // Cost: suffix-only for offset graphs by construction — the launched
    // token count *is* batch × padded-suffix.
    let us = match spec.kind {
        GraphKind::Decode => cost.decode_step_us,
        GraphKind::Prefill | GraphKind::PrefillOffset => {
            cost.prefill_us_per_token * (b * spec.seq) as f64
        }
    };
    crate::devsim::spin_us(us);

    let toks = (0..b)
        .map(|lane| {
            let h = mix64((cmd.seed as u64) ^ (lane as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let r = (h % (vocab as u64 - 1)) as u32;
            // Skip EOS so modeled lanes always run their full budget.
            if r >= eos { r + 1 } else { r }
        })
        .collect();
    Ok(toks)
}

fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.alive.store(false, Ordering::Release);
        // Close the channel, then join.
        let (dead_tx, _) = channel();
        let tx = std::mem::replace(&mut self.tx, dead_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
