//! The graph executor: models the GPU's SMs running pre-captured
//! inference graphs. Owns the (!Send) PJRT [`Engine`] on a dedicated
//! thread; receives fire-and-forget launch commands from the persistent
//! scheduler and publishes sampled tokens into a polled
//! [`CompletionBuffer`] — never a callback, matching the paper's
//! completion-detection design.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use crate::devsim::CompletionBuffer;
use crate::graphs::GraphId;
use crate::runtime::Engine;

/// One launch: everything the graph needs, plus the completion buffer the
/// scheduler will poll. `reset_kv` supports benchmark phase boundaries.
pub struct LaunchCmd {
    pub graph: GraphId,
    pub block_tables: Vec<i32>,
    pub seq_lens: Vec<i32>,
    pub tokens: Vec<i32>,
    pub seed: u32,
    pub completion: Arc<CompletionBuffer>,
    pub reset_kv: bool,
}

/// Handle to the executor thread.
pub struct Executor {
    tx: Sender<LaunchCmd>,
    alive: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn the executor thread; it loads the engine itself (PJRT handles
    /// are thread-bound). Blocks until the engine is ready so callers see
    /// load errors synchronously — this is host-assisted initialization,
    /// the one phase where the host is allowed on the path.
    pub fn spawn(artifacts: std::path::PathBuf, model: String) -> anyhow::Result<Executor> {
        let (tx, rx) = channel::<LaunchCmd>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let alive = Arc::new(AtomicBool::new(true));
        let alive2 = alive.clone();
        let handle = std::thread::Builder::new()
            .name("gpu-executor".into())
            .spawn(move || {
                let mut engine = match Engine::load(&artifacts, &model) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok(cmd) = rx.recv() {
                    if !alive2.load(Ordering::Acquire) {
                        break;
                    }
                    if cmd.reset_kv {
                        if engine.reset_kv().is_err() {
                            cmd.completion.fail();
                            continue;
                        }
                        if cmd.tokens.is_empty() {
                            cmd.completion.publish(&[]);
                            continue;
                        }
                    }
                    match engine.execute(
                        cmd.graph,
                        &cmd.block_tables,
                        &cmd.seq_lens,
                        &cmd.tokens,
                        cmd.seed,
                    ) {
                        Ok(tokens) => {
                            let toks: Vec<u32> = tokens.iter().map(|t| *t as u32).collect();
                            cmd.completion.publish(&toks);
                        }
                        Err(e) => {
                            eprintln!("executor: graph execution failed: {e:#}");
                            cmd.completion.fail();
                        }
                    }
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Executor { tx, alive, handle: Some(handle) }),
            Ok(Err(e)) => anyhow::bail!("engine load failed: {e}"),
            Err(_) => anyhow::bail!("executor thread died during load"),
        }
    }

    /// Fire-and-forget launch: returns immediately; the caller polls the
    /// completion buffer it passed in.
    pub fn launch(&self, cmd: LaunchCmd) {
        let _ = self.tx.send(cmd);
    }

    pub fn shutdown(&mut self) {
        self.alive.store(false, Ordering::Release);
        // Unblock recv with a no-op command if needed: dropping tx suffices
        // when Executor drops; explicit shutdown just marks the flag.
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.alive.store(false, Ordering::Release);
        // Close the channel, then join.
        let (dead_tx, _) = channel();
        let tx = std::mem::replace(&mut self.tx, dead_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
