//! The GPU backend (paper §4.2): a persistent scheduler that performs
//! continuous batching, paged-KV management, device-side graph launch and
//! completion polling without ever yielding to the host plane, plus the
//! executor that models the GPU's SMs running the launched graphs.
//!
//! Thread topology (mirrors the hardware topology of the paper):
//!
//! ```text
//! host thread      — initialization only: spawns the planes, then idles.
//! scheduler thread — the persistent scheduler kernel (one thread block).
//! executor thread  — the SMs executing launched inference graphs; owns
//!                    the PJRT Engine (weights + KV pool device state).
//! rdma-nic thread  — crate::rdma engine.
//! DPU threads      — crate::frontend.
//! ```
//!
//! Scheduler ⇄ executor communicate only through the launch channel (a
//! fire-and-forget doorbell) and the polled
//! [`CompletionBuffer`](crate::devsim::CompletionBuffer) — no
//! locks, no host involvement, exactly the paper's device-side launch +
//! poll protocol. The same scheduler code also runs in *CPU-resident*
//! placement (the Fig 3 baseline): identical policy, but each step pays a
//! host round-trip through `crate::hostsim`'s interference-sensitive
//! orchestrator.

pub mod arena;
pub mod executor;
pub mod launcher;
pub mod planner;
pub mod policy;
pub mod scheduler;
pub mod stats;

pub use arena::{ArenaDims, LaunchArena};
pub use executor::{greedy_chain_token, Executor, LaunchCmd, ModeledCost};
pub use policy::{AdmissionPolicy, Candidate, PolicyKind};
pub use scheduler::{HostContention, Placement, PrefixReuse, Scheduler, SchedulerConfig};
pub use stats::SchedulerStats;
