//! The persistent scheduler (paper §4.2): an infinite control loop that
//! (1) scans the ring buffer for newly submitted prompts, (2) claims them
//! via CAS, (3) selects and launches the tightest-fitting pre-compiled
//! graph for prefill or decode, (4) polls device-resident completion
//! buffers, and (5) publishes generated tokens and status updates back to
//! the ring buffer — with continuous batching via pause-and-resume inline
//! prefill and the fire-and-forget launch window protocol.
//!
//! The same policy runs under two *placements* (Fig 3's controlled
//! comparison): `GpuResident` — the Blink design, overlapped ring scan
//! hidden behind decode compute, 2 µs device launches, zero host work —
//! and `CpuResident` — each step pays a host round trip: orchestration
//! work on the interference-sensitive host heap plus host-launch latency,
//! with the ring scan serialized after completion instead of overlapped.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::devsim::{CompletionBuffer, LaunchLatencies, LaunchWindow};
use crate::gpu::executor::{Executor, LaunchCmd};
use crate::gpu::stats::SchedulerStats;
use crate::graphs::{GraphCache, GraphId, GraphKind, GraphSpec};
use crate::hostsim::HostOrchestrator;
use crate::kvcache::{KvConfig, KvManager, SeqCache};
use crate::ringbuf::{RingBuffer, SlotState};
use crate::runtime::ModelManifest;

#[derive(Debug, Clone)]
pub enum Placement {
    GpuResident,
    /// The host-driven baseline: per-step orchestration over a scratch
    /// heap of `scratch_mb` with `touches_per_step` dependent accesses.
    CpuResident { scratch_mb: usize, touches_per_step: usize },
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub placement: Placement,
    /// Parallel scan lanes (paper: the 256-thread scheduler block).
    pub scan_lanes: usize,
    /// Apply the paper's launch-latency constants as spin delays.
    pub apply_launch_delays: bool,
    /// Stop automatically once idle (used by batch benchmarks).
    pub exit_when_idle: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            placement: Placement::GpuResident,
            scan_lanes: 256,
            apply_launch_delays: true,
            exit_when_idle: false,
        }
    }
}

struct Lane {
    slot: usize,
    cache: SeqCache,
    generated: u32,
    max_new: u32,
    last_token: i32,
}

/// Handle to the running scheduler thread.
pub struct Scheduler {
    pub stats: Arc<SchedulerStats>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Scheduler {
    /// Spawn the persistent scheduler. Takes ownership of the executor
    /// handle (the doorbell into the device) and shares the ring buffer
    /// with the RDMA plane.
    pub fn spawn(
        ring: Arc<RingBuffer>,
        executor: Executor,
        manifest: ModelManifest,
        config: SchedulerConfig,
    ) -> Scheduler {
        let stats = Arc::new(SchedulerStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let drain = Arc::new(AtomicBool::new(false));
        let (stats2, stop2, drain2) = (stats.clone(), stop.clone(), drain.clone());
        let handle = std::thread::Builder::new()
            .name("persistent-scheduler".into())
            .spawn(move || {
                let mut core = SchedulerCore::new(ring, executor, manifest, config, stats2);
                core.run(&stop2, &drain2);
            })
            .expect("spawn scheduler");
        Scheduler { stats, stop, drain, handle: Some(handle) }
    }

    /// Stop accepting new work, finish in-flight requests, then exit.
    pub fn drain_and_stop(&mut self) {
        self.drain.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Hard stop (in-flight requests abandoned).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Build the graph-cache metadata straight from the manifest (the
/// scheduler's copy; the engine holds its own alongside the executables).
pub fn cache_from_manifest(m: &ModelManifest) -> GraphCache {
    let specs = m
        .graphs
        .iter()
        .enumerate()
        .map(|(i, g)| GraphSpec {
            id: GraphId(i),
            name: g.name.clone(),
            kind: if g.kind == "decode" { GraphKind::Decode } else { GraphKind::Prefill },
            batch: g.batch,
            seq: g.seq,
        })
        .collect();
    GraphCache::new(specs)
}

struct SchedulerCore {
    ring: Arc<RingBuffer>,
    executor: Executor,
    manifest: ModelManifest,
    cache: GraphCache,
    config: SchedulerConfig,
    stats: Arc<SchedulerStats>,
    window: LaunchWindow,
    kv: KvManager,
    lanes: Vec<Lane>,
    orchestrator: Option<HostOrchestrator>,
    completion: Arc<CompletionBuffer>,
    completion_epoch: u64,
    seed_ctr: u32,
    max_batch: usize,
}

impl SchedulerCore {
    fn new(
        ring: Arc<RingBuffer>,
        executor: Executor,
        manifest: ModelManifest,
        config: SchedulerConfig,
        stats: Arc<SchedulerStats>,
    ) -> SchedulerCore {
        let cache = cache_from_manifest(&manifest);
        let kv = KvManager::new(KvConfig {
            block_size: manifest.block_size,
            num_blocks: manifest.num_blocks,
            max_blocks_per_seq: manifest.max_blocks_per_seq,
        });
        let orchestrator = match &config.placement {
            Placement::GpuResident => None,
            Placement::CpuResident { scratch_mb, touches_per_step } => {
                Some(HostOrchestrator::new(*scratch_mb, *touches_per_step))
            }
        };
        let max_batch = cache.max_decode_batch();
        let max_lanes = max_batch.max(cache.max_prefill_batch());
        SchedulerCore {
            ring,
            executor,
            manifest,
            cache,
            config,
            stats,
            window: LaunchWindow::new(LaunchLatencies::default(), false),
            kv,
            lanes: Vec::with_capacity(max_batch),
            orchestrator,
            completion: Arc::new(CompletionBuffer::new(max_lanes.max(16))),
            completion_epoch: 0,
            seed_ctr: 1,
            max_batch,
        }
    }

    fn is_gpu_resident(&self) -> bool {
        matches!(self.config.placement, Placement::GpuResident)
    }

    fn run(&mut self, stop: &AtomicBool, drain: &AtomicBool) {
        let mut idle_spins = 0u64;
        loop {
            if stop.load(Ordering::Acquire) {
                break;
            }
            let draining = drain.load(Ordering::Acquire);
            if draining && self.lanes.is_empty() && self.ring.pending_hint() == 0 {
                break;
            }

            // Admission (when not draining): scan + claim + inline prefill.
            if !draining && self.lanes.len() < self.max_batch {
                let candidates = self.scan(true);
                if !candidates.is_empty() {
                    if !self.lanes.is_empty() {
                        // Continuous batching: pausing in-flight decode to
                        // run an inline prefill (the decode loop resumes on
                        // the next iteration — state is in `self.lanes`).
                        self.stats.pauses.fetch_add(1, Ordering::Relaxed);
                        self.pause_lanes();
                    }
                    self.admit_and_prefill(candidates);
                    self.resume_lanes();
                }
            }

            if self.lanes.is_empty() {
                idle_spins += 1;
                if idle_spins > 64 {
                    // Persistent kernels spin; on a shared test machine we
                    // yield so idle schedulers don't starve the world.
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
                if self.config.exit_when_idle && idle_spins > 10_000 {
                    break;
                }
                continue;
            }
            idle_spins = 0;

            self.decode_step(draining);
        }
    }

    /// Timed ring scan (the paper's 1–5 µs full-ring sweep).
    fn scan(&self, only_if_hinted: bool) -> Vec<usize> {
        if only_if_hinted && self.ring.pending_hint() == 0 {
            return vec![];
        }
        let t = Instant::now();
        let pending = self.ring.scan_pending(self.config.scan_lanes);
        self.stats.record_scan(t.elapsed().as_nanos() as u64);
        pending
    }

    fn pause_lanes(&self) {
        for l in &self.lanes {
            self.ring.slot(l.slot).set_state(SlotState::DecodePaused);
        }
    }

    fn resume_lanes(&self) {
        for l in &self.lanes {
            let s = self.ring.slot(l.slot);
            // Lanes admitted during the pause are already DECODE_PROCESSING.
            if s.state() == SlotState::DecodePaused {
                s.set_state(SlotState::DecodeProcessing);
            }
        }
    }

    /// The three admission conditions (paper §4.2 "Continuous batching"):
    /// (i) pending prefills detected, (ii) free batch-slot capacity,
    /// (iii) launch-window headroom for prefill + resumed decode.
    fn admit_and_prefill(&mut self, candidates: Vec<usize>) {
        let mut admitted: Vec<(usize, SeqCache, Vec<i32>, u32, usize)> = vec![]; // slot, cache, prompt, max_new, padded
        for slot_idx in candidates {
            if self.lanes.len() + admitted.len() >= self.max_batch {
                self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                break; // leave pending in the ring: backpressure
            }
            let slot = self.ring.slot(slot_idx);
            if slot.state() != SlotState::PrefillPending {
                continue; // raced with... nothing today, but benign
            }
            let prompt_len = slot.prompt_len.load(Ordering::Acquire) as usize;
            let max_new = slot.max_new_tokens.load(Ordering::Relaxed).max(1);
            let max_seq = self.cache.max_prefill_seq();
            if prompt_len == 0 || prompt_len > max_seq {
                // Invalid request: claim it and fail it.
                if self.ring.claim_pending(slot_idx) {
                    self.fail_slot(slot_idx);
                }
                continue;
            }
            let padded = padded_seq(&self.cache, prompt_len);
            let max_new = max_new.min((self.manifest.max_context() - prompt_len) as u32);
            if !self.kv.can_admit(padded, prompt_len, max_new as usize) {
                // Condition (ii)/KV backpressure: leave it pending.
                self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                break;
            }
            // Condition (iii): headroom for this prefill + one decode.
            if self.window.headroom() < 2 {
                self.window.tail_relaunch();
            }
            if !self.ring.claim_pending(slot_idx) {
                continue;
            }
            let cache = self
                .kv
                .admit(padded, prompt_len, max_new as usize)
                .expect("can_admit checked above");
            let prompt: Vec<i32> =
                self.ring.read_prompt(slot_idx).into_iter().map(|t| t as i32).collect();
            admitted.push((slot_idx, cache, prompt, max_new, padded));
        }
        if admitted.is_empty() {
            return;
        }

        // Group by padded length, chunk to the prefill batch grid.
        admitted.sort_by_key(|a| a.4);
        let max_pb = self.cache.max_prefill_batch();
        let mut i = 0;
        while i < admitted.len() {
            let pad = admitted[i].4;
            let mut j = i + 1;
            while j < admitted.len() && admitted[j].4 == pad && j - i < max_pb {
                j += 1;
            }
            let group: Vec<_> = admitted.drain(i..j).collect();
            self.launch_prefill(group, pad);
            // drain() shifts the tail down; keep i in place.
        }
    }

    fn launch_prefill(&mut self, group: Vec<(usize, SeqCache, Vec<i32>, u32, usize)>, pad: usize) {
        let b_actual = group.len();
        let gid = self
            .cache
            .select_prefill(b_actual, pad)
            .expect("grid covers all padded sizes");
        let spec = self.cache.spec(gid).clone();
        let (gb, gs) = (spec.batch, spec.seq);
        let mbs = self.manifest.max_blocks_per_seq;

        let mut block_tables = Vec::with_capacity(gb * mbs);
        let mut seq_lens = Vec::with_capacity(gb);
        let mut tokens = Vec::with_capacity(gb * gs);
        for (_, cache, prompt, _, _) in &group {
            block_tables.extend(cache.table_row(mbs));
            seq_lens.push(prompt.len() as i32);
            tokens.extend(prompt);
            tokens.extend(std::iter::repeat(0).take(gs - prompt.len()));
        }
        // Pad ghost lanes by replicating lane 0 (identical writes are
        // benign; outputs ignored).
        for _ in b_actual..gb {
            block_tables.extend_from_slice(&group[0].1.table_row(mbs));
            seq_lens.push(group[0].2.len() as i32);
            let row0: Vec<i32> = tokens[..gs].to_vec();
            tokens.extend(row0);
        }

        let seed = self.next_seed();
        self.launch(LaunchCmd {
            graph: gid,
            block_tables,
            seq_lens,
            tokens,
            seed,
            completion: self.completion.clone(),
            reset_kv: false,
        });
        let Some(first_tokens) = self.poll_completion(gb) else {
            for (slot, cache, _, _, _) in group {
                self.kv.release(cache);
                self.fail_slot(slot);
            }
            return;
        };

        self.stats.prefill_batches.fetch_add(1, Ordering::Relaxed);
        for (lane_idx, (slot, mut cache, prompt, max_new, _)) in group.into_iter().enumerate() {
            cache.cached_len = prompt.len();
            let tok = first_tokens[lane_idx] as i32;
            self.ring.slot(slot).set_state(SlotState::DecodeProcessing);
            self.ring.publish_token(slot, tok as u32);
            self.stats.tokens_generated.fetch_add(1, Ordering::Relaxed);
            self.stats.prefilled_requests.fetch_add(1, Ordering::Relaxed);
            let done = max_new <= 1 || tok as u32 == self.manifest.eos_token;
            if done {
                self.finish_lane(Lane { slot, cache, generated: 1, max_new, last_token: tok });
            } else {
                self.lanes.push(Lane { slot, cache, generated: 1, max_new, last_token: tok });
            }
        }
    }

    fn decode_step(&mut self, draining: bool) {
        let live = self.lanes.len();
        debug_assert!(live > 0);
        let gid = self.cache.select_decode(live).expect("decode grid covers batch sizes");
        let spec = self.cache.spec(gid).clone();
        let gb = spec.batch;
        let mbs = self.manifest.max_blocks_per_seq;

        // CPU-resident placement: the host reassembles the batch before
        // every launch — interference-sensitive work on the host heap.
        if let Some(orch) = self.orchestrator.as_mut() {
            std::hint::black_box(orch.step_work());
        }

        let mut block_tables = Vec::with_capacity(gb * mbs);
        let mut seq_lens = Vec::with_capacity(gb);
        let mut tokens = Vec::with_capacity(gb);
        for l in &self.lanes {
            block_tables.extend(l.cache.table_row(mbs));
            seq_lens.push(l.cache.cached_len as i32);
            tokens.push(l.last_token);
        }
        for _ in live..gb {
            block_tables.extend(self.lanes[0].cache.table_row(mbs));
            seq_lens.push(self.lanes[0].cache.cached_len as i32);
            tokens.push(self.lanes[0].last_token);
        }

        let seed = self.next_seed();
        self.launch(LaunchCmd {
            graph: gid,
            block_tables,
            seq_lens,
            tokens,
            seed,
            completion: self.completion.clone(),
            reset_kv: false,
        });

        // GPU-resident: the ring scan overlaps decode compute (its latency
        // hides behind the graph execution). CPU-resident: no overlap —
        // the host waits for the step, then scans on the critical path.
        let overlapped_pending = if self.is_gpu_resident() && !draining {
            self.scan(true)
        } else {
            vec![]
        };

        let Some(step_tokens) = self.poll_completion(gb) else {
            let lanes = std::mem::take(&mut self.lanes);
            for l in lanes {
                self.kv.release(l.cache);
                self.fail_slot(l.slot);
            }
            return;
        };

        self.stats.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.stats.batch_occupancy_sum.fetch_add(live as u64, Ordering::Relaxed);

        // Apply results, retire finished lanes.
        let mut finished: Vec<usize> = vec![];
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let tok = step_tokens[i] as i32;
            lane.cache.cached_len += 1;
            lane.generated += 1;
            lane.last_token = tok;
            self.ring.publish_token(lane.slot, tok as u32);
            self.stats.tokens_generated.fetch_add(1, Ordering::Relaxed);
            if lane.generated >= lane.max_new || tok as u32 == self.manifest.eos_token {
                finished.push(i);
            }
        }
        for i in finished.into_iter().rev() {
            let lane = self.lanes.swap_remove(i);
            self.finish_lane(lane);
        }

        // Pause-and-resume admission using the overlapped scan results.
        if !overlapped_pending.is_empty() && self.lanes.len() < self.max_batch && !draining {
            self.stats.pauses.fetch_add(1, Ordering::Relaxed);
            self.pause_lanes();
            self.admit_and_prefill(overlapped_pending);
            self.resume_lanes();
        }
    }

    fn finish_lane(&mut self, lane: Lane) {
        self.ring.complete(lane.slot);
        self.kv.release(lane.cache);
        self.stats.completed_requests.fetch_add(1, Ordering::Relaxed);
    }

    fn fail_slot(&mut self, slot: usize) {
        self.ring.slot(slot).set_state(SlotState::Failed);
        self.stats.failed_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Launch a graph with placement-appropriate cost accounting.
    fn launch(&mut self, cmd: LaunchCmd) {
        if self.is_gpu_resident() {
            if self.window.fnf_launch().is_err() {
                self.window.tail_relaunch();
                self.window.fnf_launch().expect("fresh window");
            }
            if self.config.apply_launch_delays {
                crate::devsim::spin_us(LaunchLatencies::default().fnf_us);
            }
            self.stats.fnf_launches.store(self.window.fnf_launches, Ordering::Relaxed);
            self.stats.tail_relaunches.store(self.window.tail_relaunches, Ordering::Relaxed);
        } else if self.config.apply_launch_delays {
            // Host-side launch: 11–17 µs (paper §4.2).
            crate::devsim::spin_us(LaunchLatencies::default().host_us);
        }
        self.executor.launch(cmd);
    }

    fn poll_completion(&mut self, n: usize) -> Option<Vec<u32>> {
        let res = self.completion.poll_wait(self.completion_epoch, n);
        self.completion_epoch = self.completion.epoch();
        res
    }

    fn next_seed(&mut self) -> u32 {
        self.seed_ctr = self.seed_ctr.wrapping_mul(747796405).wrapping_add(2891336453);
        self.seed_ctr
    }
}

/// Smallest grid sequence length >= prompt_len.
fn padded_seq(cache: &GraphCache, prompt_len: usize) -> usize {
    let mut best = usize::MAX;
    for s in cache.specs() {
        if s.kind == GraphKind::Prefill && s.seq >= prompt_len && s.seq < best {
            best = s.seq;
        }
    }
    if best == usize::MAX {
        prompt_len
    } else {
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_cache() -> GraphCache {
        cache_from_manifest(
            &ModelManifest::parse(
                "blink-manifest v1\nmodel t\nvocab_size 8\nd_model 4\nn_layers 1\nn_heads 1\n\
                 n_kv_heads 1\nd_head 4\nd_ff 8\nblock_size 16\nnum_blocks 8\n\
                 max_blocks_per_seq 4\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
                 param p 4 f32\ngraph decode_b1 decode 1 0\ngraph prefill_b1_s16 prefill 1 16\n\
                 graph prefill_b1_s32 prefill 1 32\ngraph prefill_b2_s64 prefill 2 64\n",
            )
            .unwrap(),
        )
    }

    #[test]
    fn padded_seq_picks_grid() {
        let c = toy_cache();
        assert_eq!(padded_seq(&c, 10), 16);
        assert_eq!(padded_seq(&c, 16), 16);
        assert_eq!(padded_seq(&c, 17), 32);
        assert_eq!(padded_seq(&c, 40), 64);
    }
}
