//! The persistent scheduler (paper §4.2), structured as a staged
//! pipeline run by an infinite control loop:
//!
//! ```text
//! ring scan → admission policy → batch planner → launcher → completion
//!   (scan)      (policy.rs)       (planner.rs)   (launcher.rs)  (poll)
//! ```
//!
//! * **ring scan** — detect PREFILL_PENDING slots, snapshot them as
//!   [`Candidate`]s (overlapped behind decode compute when GPU-resident);
//! * **admission policy** — a pluggable [`AdmissionPolicy`] orders the
//!   candidates (FCFS by default; see `SchedulerConfig::policy`);
//! * **batch planner** — admit in policy order under the three admission
//!   conditions (pending work, batch-slot capacity, launch-window
//!   headroom) plus KV backpressure, claim via CAS, group prefills to the
//!   graph grid and marshal decode batches ([`BatchPlanner`]);
//! * **launcher** — fire-and-forget device launches with the launch
//!   window protocol, or host-latency launches for the CPU baseline;
//! * **completion** — poll device-resident completion buffers, publish
//!   generated tokens and status updates back to the ring.
//!
//! Continuous batching is pause-and-resume inline prefill, exactly as
//! before the decomposition. The same pipeline runs under two
//! *placements* (Fig 3's controlled comparison): `GpuResident` — the
//! Blink design, overlapped ring scan hidden behind decode compute, 2 µs
//! device launches, zero host work — and `CpuResident` — each step pays
//! a host round trip on the interference-sensitive host heap, with the
//! ring scan serialized after completion instead of overlapped.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::devsim::CompletionBuffer;
use crate::gpu::executor::{Executor, LaunchCmd};
use crate::gpu::launcher::{Completions, Launcher};
use crate::gpu::planner::{BatchPlanner, Lane, PrefillGroup, PrefillSeq};
use crate::gpu::policy::{AdmissionPolicy, Candidate, PolicyKind};
use crate::gpu::stats::SchedulerStats;
use crate::graphs::{GraphCache, GraphId, GraphKind, GraphSpec};
use crate::hostsim::HostOrchestrator;
use crate::kvcache::{KvConfig, KvManager};
use crate::ringbuf::{RingBuffer, SlotState};
use crate::runtime::ModelManifest;

#[derive(Debug, Clone)]
pub enum Placement {
    GpuResident,
    /// The host-driven baseline: per-step orchestration over a scratch
    /// heap of `scratch_mb` with `touches_per_step` dependent accesses.
    CpuResident { scratch_mb: usize, touches_per_step: usize },
}

/// Prefix-aware KV reuse mode (DESIGN.md §7). A live hit prefills only
/// the uncached suffix through an *offset* prefill graph
/// (`prefill_offset_b{B}_s{S}` in the AOT grid), so reuse is only as
/// real as the artifacts: `Auto` turns it on exactly when the manifest
/// provides offset graphs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixReuse {
    /// Default: reuse on when the artifacts ship offset prefill graphs,
    /// off (the paper's cold-admission behavior) otherwise.
    Auto,
    /// Force the index machinery on even without offset graphs: hits are
    /// still *detected* (counters, observability) but every one falls
    /// back to a full cold prefill, so numerics stay correct — no suffix
    /// is ever prefilled at the wrong positions.
    On,
    /// The paper's behavior: every admission reserves its full span,
    /// cold. The DES models reuse independently
    /// (`SimConfig::prefix_cache_tokens`), so `blink eval prefix` does
    /// not depend on this mode.
    Off,
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub placement: Placement,
    /// Parallel scan lanes (paper: the 256-thread scheduler block).
    pub scan_lanes: usize,
    /// Apply the paper's launch-latency constants as spin delays.
    pub apply_launch_delays: bool,
    /// Stop automatically once idle (used by batch benchmarks).
    pub exit_when_idle: bool,
    /// Admission policy (pipeline stage 2). FCFS reproduces the paper.
    pub policy: PolicyKind,
    /// Prefix-aware KV reuse (DESIGN.md §7): match each prompt against
    /// the block-hash prefix index and prefill only the uncached suffix
    /// through an offset prefill graph. Default [`PrefixReuse::Auto`].
    pub prefix_reuse: PrefixReuse,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            placement: Placement::GpuResident,
            scan_lanes: 256,
            apply_launch_delays: true,
            exit_when_idle: false,
            policy: PolicyKind::Fcfs,
            prefix_reuse: PrefixReuse::Auto,
        }
    }
}

/// Handle to the running scheduler thread.
pub struct Scheduler {
    pub stats: Arc<SchedulerStats>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Scheduler {
    /// Spawn the persistent scheduler. Takes ownership of the executor
    /// handle (the doorbell into the device) and shares the ring buffer
    /// with the RDMA plane.
    pub fn spawn(
        ring: Arc<RingBuffer>,
        executor: Executor,
        manifest: ModelManifest,
        config: SchedulerConfig,
    ) -> Scheduler {
        let stats = Arc::new(SchedulerStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let drain = Arc::new(AtomicBool::new(false));
        let (stats2, stop2, drain2) = (stats.clone(), stop.clone(), drain.clone());
        let handle = std::thread::Builder::new()
            .name("persistent-scheduler".into())
            .spawn(move || {
                let mut core = SchedulerCore::new(ring, executor, manifest, config, stats2);
                core.run(&stop2, &drain2);
            })
            .expect("spawn scheduler");
        Scheduler { stats, stop, drain, handle: Some(handle) }
    }

    /// Stop accepting new work, finish in-flight requests, then exit.
    pub fn drain_and_stop(&mut self) {
        self.drain.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Hard stop (in-flight requests abandoned).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Build the graph-cache metadata straight from the manifest (the
/// scheduler's copy; the engine holds its own alongside the executables).
pub fn cache_from_manifest(m: &ModelManifest) -> GraphCache {
    let specs = m
        .graphs
        .iter()
        .enumerate()
        .map(|(i, g)| GraphSpec {
            id: GraphId(i),
            name: g.name.clone(),
            kind: GraphKind::from_manifest(&g.kind),
            batch: g.batch,
            seq: g.seq,
        })
        .collect();
    GraphCache::new(specs)
}

struct SchedulerCore {
    ring: Arc<RingBuffer>,
    manifest: ModelManifest,
    cache: GraphCache,
    config: SchedulerConfig,
    stats: Arc<SchedulerStats>,
    kv: KvManager,
    lanes: Vec<Lane>,
    orchestrator: Option<HostOrchestrator>,
    // Pipeline stages (see module docs).
    policy: Box<dyn AdmissionPolicy>,
    planner: BatchPlanner,
    launcher: Launcher,
    completions: Completions,
    seed_ctr: u32,
    max_batch: usize,
    /// Resolved reuse switch: `config.prefix_reuse` crossed with the
    /// artifacts (`Auto` requires offset graphs in the manifest).
    reuse: bool,
    /// Ticket of the most recently admitted request (out-of-order stat).
    last_admitted_ticket: Option<u64>,
}

impl SchedulerCore {
    fn new(
        ring: Arc<RingBuffer>,
        executor: Executor,
        manifest: ModelManifest,
        config: SchedulerConfig,
        stats: Arc<SchedulerStats>,
    ) -> SchedulerCore {
        let cache = cache_from_manifest(&manifest);
        let kv = KvManager::new(KvConfig {
            block_size: manifest.block_size,
            num_blocks: manifest.num_blocks,
            max_blocks_per_seq: manifest.max_blocks_per_seq,
        });
        let orchestrator = match &config.placement {
            Placement::GpuResident => None,
            Placement::CpuResident { scratch_mb, touches_per_step } => {
                Some(HostOrchestrator::new(*scratch_mb, *touches_per_step))
            }
        };
        let gpu_resident = matches!(config.placement, Placement::GpuResident);
        let max_batch = cache.max_decode_batch();
        let max_lanes =
            max_batch.max(cache.max_prefill_batch()).max(cache.max_prefill_offset_batch());
        let policy = config.policy.build();
        let planner = BatchPlanner::new(
            cache.max_prefill_batch(),
            cache.max_prefill_offset_batch(),
            manifest.max_blocks_per_seq,
            manifest.block_size,
        );
        let launcher =
            Launcher::new(executor, gpu_resident, config.apply_launch_delays, stats.clone());
        let completions = Completions::new(Arc::new(CompletionBuffer::new(max_lanes.max(16))));
        // Live reuse is only as real as the artifacts: `Auto` flips on
        // exactly when the manifest provides offset prefill graphs
        // (graceful fallback to the paper's cold behavior otherwise).
        let reuse = match config.prefix_reuse {
            PrefixReuse::Off => false,
            PrefixReuse::On => true,
            PrefixReuse::Auto => cache.has_offset_graphs(),
        };
        SchedulerCore {
            ring,
            manifest,
            cache,
            config,
            stats,
            kv,
            lanes: Vec::with_capacity(max_batch),
            orchestrator,
            policy,
            planner,
            launcher,
            completions,
            seed_ctr: 1,
            max_batch,
            reuse,
            last_admitted_ticket: None,
        }
    }

    fn is_gpu_resident(&self) -> bool {
        matches!(self.config.placement, Placement::GpuResident)
    }

    fn run(&mut self, stop: &AtomicBool, drain: &AtomicBool) {
        let mut idle_spins = 0u64;
        loop {
            if stop.load(Ordering::Acquire) {
                break;
            }
            let draining = drain.load(Ordering::Acquire);
            if draining && self.lanes.is_empty() && self.ring.pending_hint() == 0 {
                break;
            }

            // Admission (when not draining): scan + policy + claim +
            // inline prefill.
            if !draining && self.lanes.len() < self.max_batch {
                let candidates = self.scan(true);
                if !candidates.is_empty() {
                    if !self.lanes.is_empty() {
                        // Continuous batching: pausing in-flight decode to
                        // run an inline prefill (the decode loop resumes on
                        // the next iteration — state is in `self.lanes`).
                        self.stats.pauses.fetch_add(1, Ordering::Relaxed);
                        self.pause_lanes();
                    }
                    self.admit_and_prefill(candidates);
                    self.resume_lanes();
                }
            }

            if self.lanes.is_empty() {
                idle_spins += 1;
                if idle_spins > 64 {
                    // Persistent kernels spin; on a shared test machine we
                    // yield so idle schedulers don't starve the world.
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
                if self.config.exit_when_idle && idle_spins > 10_000 {
                    break;
                }
                continue;
            }
            idle_spins = 0;

            self.decode_step(draining);
        }
    }

    /// Pipeline stage 1 — timed ring scan (the paper's 1–5 µs full-ring
    /// sweep), snapshotting pending slots as policy candidates.
    fn scan(&self, only_if_hinted: bool) -> Vec<Candidate> {
        if only_if_hinted && self.ring.pending_hint() == 0 {
            return vec![];
        }
        let t = Instant::now();
        let pending = self.ring.scan_pending(self.config.scan_lanes);
        // The timed region covers only the sweep itself, so scan_mean/max
        // stay comparable to the paper envelope; the candidate snapshot
        // is policy-stage work.
        self.stats.record_scan(t.elapsed().as_nanos() as u64);
        Candidate::collect(&self.ring, &pending)
    }

    fn pause_lanes(&self) {
        for l in &self.lanes {
            self.ring.slot(l.slot).set_state(SlotState::DecodePaused);
        }
    }

    fn resume_lanes(&self) {
        for l in &self.lanes {
            let s = self.ring.slot(l.slot);
            // Lanes admitted during the pause are already DECODE_PROCESSING.
            if s.state() == SlotState::DecodePaused {
                s.set_state(SlotState::DecodeProcessing);
            }
        }
    }

    /// Pipeline stages 2+3 — order candidates by the admission policy,
    /// admit under the three admission conditions (paper §4.2
    /// "Continuous batching": (i) pending prefills detected, (ii) free
    /// batch-slot capacity, (iii) launch-window headroom) plus KV
    /// backpressure, then group and launch the prefills.
    fn admit_and_prefill(&mut self, mut candidates: Vec<Candidate>) {
        // Stage 2: policy ordering (FCFS = ticket order, the paper).
        let now_us = crate::util::timer::now_us();
        self.policy.order(&mut candidates, now_us);

        // Stage 3a: admission checks + CAS claims, in policy order.
        let mut admitted: Vec<PrefillSeq> = vec![];
        for cand in candidates {
            if self.lanes.len() + admitted.len() >= self.max_batch {
                self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                break; // leave pending in the ring: backpressure
            }
            let slot_idx = cand.slot;
            let slot = self.ring.slot(slot_idx);
            if slot.state() != SlotState::PrefillPending {
                continue; // raced with... nothing today, but benign
            }
            let prompt_len = slot.prompt_len.load(Ordering::Acquire) as usize;
            let max_new = slot.max_new_tokens.load(Ordering::Relaxed).max(1);
            let max_seq = self.cache.max_prefill_seq();
            if prompt_len == 0 || prompt_len > max_seq {
                // Invalid request: claim it and fail it.
                if self.ring.claim_pending(slot_idx) {
                    self.fail_slot(slot_idx);
                }
                continue;
            }
            let max_new = max_new.min((self.manifest.max_context() - prompt_len) as u32);
            // Condition (ii)/KV admission. Cold path: the exact check is
            // pure slot-metadata math, so a backpressured scan cycle
            // costs nothing. Reuse path: first a metadata-only lower
            // bound — the *best case* is a maximal prefix hit (every
            // full block short of one token cached, none of it parked)
            // whose suffix the offset grid covers; if even that
            // best-case tail cannot be reserved, reject before the
            // O(prompt) arena read + hash. Only then read the prompt
            // (side-effect free, pre-claim) and run the exact
            // match-aware check. A hit whose suffix fits no offset
            // graph is demoted to a cold full prefill *before* any
            // reservation, so nothing is ever double-charged. On
            // rejection, stop admitting so a later (lower-ranked)
            // candidate cannot leapfrog the policy's head-of-queue
            // choice.
            let bs = self.kv.config().block_size;
            let prompt_u32: Option<Vec<u32>>;
            let pm: Option<crate::kvcache::PrefixMatch>;
            let padded;
            if self.reuse {
                // Floor = the cheapest possible outcome: a maximal hit
                // whose suffix the offset grid covers, or a cold full
                // prefill — whichever needs fewer fresh blocks (on a
                // sparse offset grid the smallest offset graph can be
                // *larger* than the cold padding, so the hit is not
                // automatically the best case).
                let cold_padded = padded_seq(&self.cache, prompt_len);
                let cold_need =
                    self.kv.config().blocks_needed(cold_padded, prompt_len, max_new as usize);
                let best_match = (prompt_len - 1) / bs * bs;
                let floor = match self.cache.padded_offset_seq(prompt_len - best_match) {
                    Some(p) => {
                        let hit_need = self.kv.config().blocks_needed_with_prefix(
                            best_match,
                            p,
                            prompt_len,
                            max_new as usize,
                        );
                        (hit_need - best_match / bs).min(cold_need)
                    }
                    None => cold_need,
                };
                if floor > self.kv.available_blocks() {
                    self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                    break;
                }
                let p = self.ring.read_prompt(slot_idx);
                let mut m = self.kv.match_prefix(&p);
                padded = if m.tokens == 0 {
                    cold_padded
                } else if let Some(p_off) = self.cache.padded_offset_seq(prompt_len - m.tokens) {
                    p_off
                } else {
                    // Graceful fallback: the suffix is off the offset
                    // grid (or the artifacts ship none — PrefixReuse::On
                    // without offset graphs). Abandon the match before
                    // reserving anything: the request admits cold with a
                    // full prefill, sharing no blocks.
                    self.stats.prefix_fallback_full.fetch_add(1, Ordering::Relaxed);
                    m = crate::kvcache::PrefixMatch::default();
                    cold_padded
                };
                if !self.kv.can_admit_reuse(&m, padded, prompt_len, max_new as usize) {
                    self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                    break;
                }
                prompt_u32 = Some(p);
                pm = Some(m);
            } else {
                padded = padded_seq(&self.cache, prompt_len);
                if !self.kv.can_admit(padded, prompt_len, max_new as usize) {
                    self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                    break;
                }
                prompt_u32 = None;
                pm = None;
            }
            // Condition (iii): headroom for this prefill + one decode.
            if self.launcher.headroom() < 2 {
                self.launcher.tail_relaunch();
            }
            if !self.ring.claim_pending(slot_idx) {
                continue;
            }
            self.note_admission_order(cand.ticket);
            // Session attribution: the tag rides along the RDMA metadata
            // write; non-zero means a multi-turn conversation turn.
            if self.ring.slot(slot_idx).session_id.load(Ordering::Relaxed) != 0 {
                self.stats.session_requests.fetch_add(1, Ordering::Relaxed);
            }
            // Cold path reads the prompt only now, after the claim.
            let prompt_u32 =
                prompt_u32.unwrap_or_else(|| self.ring.read_prompt(slot_idx));
            let cache = match &pm {
                // Reuse the match computed above — no second hash pass.
                Some(m) => self
                    .kv
                    .admit_matched(m, prompt_len, padded, max_new as usize)
                    .expect("can_admit_reuse checked above"),
                None => self
                    .kv
                    .admit(padded, prompt_len, max_new as usize)
                    .expect("can_admit checked above"),
            };
            let cached_prefix = cache.prefix_len;
            let prompt: Vec<i32> = prompt_u32.into_iter().map(|t| t as i32).collect();
            admitted
                .push(PrefillSeq { slot: slot_idx, cache, prompt, max_new, cached_prefix, padded });
        }
        if admitted.is_empty() {
            self.publish_kv_stats();
            return;
        }

        // Stage 3b: group to the prefill graph grid (full vs offset
        // launches, see planner) and launch each group in shared-block
        // dependency order — `group_prefills` topologically orders
        // sharer groups after their prefix producers, so a hit can never
        // launch before the prefill that writes its shared blocks. Index
        // entries additionally commit only after a group's prefill
        // completed (each launch below is polled synchronously), so a
        // match can only ever land on K/V that is already written.
        for group in self.planner.group_prefills(admitted) {
            self.launch_prefill(group);
        }
        self.publish_kv_stats();
    }

    /// Mirror the KV manager's reuse counters into the shared atomics —
    /// `kvcache::KvStats` is the single source of truth; the scheduler
    /// only publishes it for `/metrics` readers.
    fn publish_kv_stats(&self) {
        let kv_stats = self.kv.stats;
        self.stats.prefix_hits.store(kv_stats.prefix_hits, Ordering::Relaxed);
        self.stats.prefix_hit_tokens.store(kv_stats.reused_tokens, Ordering::Relaxed);
        self.stats.prefix_evicted_blocks.store(kv_stats.evicted_blocks, Ordering::Relaxed);
        self.stats.prefix_indexed_blocks.store(
            kv_stats.indexed_blocks.saturating_sub(kv_stats.evicted_blocks),
            Ordering::Relaxed,
        );
    }

    /// Out-of-ticket-order admissions (non-FCFS policies at work); FCFS
    /// keeps this at zero, which the integration tests pin down.
    fn note_admission_order(&mut self, ticket: u64) {
        match self.last_admitted_ticket {
            Some(last) if ticket < last => {
                self.stats.admitted_out_of_order.fetch_add(1, Ordering::Relaxed);
            }
            _ => self.last_admitted_ticket = Some(ticket),
        }
    }

    /// Pipeline stages 4+5 for one prefill group: marshal, launch, poll,
    /// publish first tokens. Offset groups launch a `prefill_offset`
    /// graph whose seq equals the padded *suffix* the admission stage
    /// reserved — never a longer one, whose K/V writes would land past
    /// the reservation (hits whose suffix is off-grid were demoted to
    /// cold full prefills before reserving anything). A sparse or
    /// non-rectangular offset grid that cannot cover the whole group at
    /// that exact seq in one launch is handled by splitting on the batch
    /// axis.
    fn launch_prefill(&mut self, mut group: PrefillGroup) {
        let b_actual = group.seqs.len();
        let gid = if group.offset {
            // aot.py emits dense rectangular grids, so the first probe
            // succeeds at full width; hand-built manifests may not be
            // rectangular, in which case the widest exactly-sized prefix
            // of the group launches now and the tail recurses. Batch 1
            // always fits: `padded` came from `padded_offset_seq`, so a
            // graph with that exact seq exists and the (seq, batch)
            // tie-break selects it.
            let exact_fit = |cache: &GraphCache, b: usize, padded: usize| {
                cache
                    .select_prefill_offset(b, padded)
                    .filter(|&g| cache.spec(g).seq == padded)
            };
            let fit = (1..=b_actual)
                .rev()
                .find(|&b| exact_fit(&self.cache, b, group.padded).is_some())
                .expect("admission verified an exact-seq offset graph at batch 1");
            if fit < b_actual {
                let rest = group.seqs.split_off(fit);
                let padded = group.padded;
                self.launch_prefill(group);
                self.launch_prefill(PrefillGroup { padded, offset: true, seqs: rest });
                return;
            }
            exact_fit(&self.cache, b_actual, group.padded).expect("probed above")
        } else {
            self.cache
                .select_prefill(b_actual, group.padded)
                .expect("grid covers all padded sizes")
        };
        let spec = self.cache.spec(gid).clone();
        let inputs = self.planner.prefill_inputs(&group, spec.batch, spec.seq);
        if group.offset {
            self.stats.prefill_offset_batches.fetch_add(1, Ordering::Relaxed);
        }

        let seed = self.next_seed();
        self.launcher.launch(LaunchCmd {
            graph: gid,
            block_tables: inputs.block_tables,
            seq_lens: inputs.seq_lens,
            tokens: inputs.tokens,
            offsets: inputs.offsets,
            seed,
            completion: self.completions.buffer(),
            reset_kv: false,
        });
        let Some(first_tokens) = self.completions.poll(spec.batch) else {
            // Failed prefill: plain release. Nothing was published to
            // the prefix index (entries commit only on success below),
            // so no later prompt can "hit" the unwritten K/V.
            for s in group.seqs {
                self.kv.release(s.cache);
                self.fail_slot(s.slot);
            }
            return;
        };

        self.stats.prefill_batches.fetch_add(1, Ordering::Relaxed);
        let group_offset = group.offset;
        for (lane_idx, seq) in group.seqs.into_iter().enumerate() {
            let PrefillSeq { slot, mut cache, prompt, max_new, cached_prefix, .. } = seq;
            debug_assert!(cached_prefix == 0 || group_offset, "hit seq in a full-prefill group");
            cache.cached_len = prompt.len();
            // The prefill wrote this prompt's K/V: commit its full
            // blocks to the prefix index so later turns can share them.
            if self.reuse {
                let toks: Vec<u32> = prompt.iter().map(|&t| t as u32).collect();
                self.kv.index_prompt(&cache, &toks);
            }
            let tok = first_tokens[lane_idx] as i32;
            self.ring.slot(slot).set_state(SlotState::DecodeProcessing);
            self.ring.publish_token(slot, tok as u32);
            self.note_ttft_deadline(slot);
            self.stats.tokens_generated.fetch_add(1, Ordering::Relaxed);
            self.stats.prefilled_requests.fetch_add(1, Ordering::Relaxed);
            let done = max_new <= 1 || tok as u32 == self.manifest.eos_token;
            if done {
                self.finish_lane(Lane { slot, cache, generated: 1, max_new, last_token: tok });
            } else {
                self.lanes.push(Lane { slot, cache, generated: 1, max_new, last_token: tok });
            }
        }
    }

    /// TTFT-deadline attainment accounting (SLO-aware observability).
    fn note_ttft_deadline(&self, slot: usize) {
        let s = self.ring.slot(slot);
        let deadline = s.ttft_deadline_us.load(Ordering::Relaxed);
        if deadline != 0 && s.first_token_time_us.load(Ordering::Relaxed) > deadline {
            self.stats.ttft_deadline_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn decode_step(&mut self, draining: bool) {
        let live = self.lanes.len();
        debug_assert!(live > 0);
        let gid = self.cache.select_decode(live).expect("decode grid covers batch sizes");
        let spec = self.cache.spec(gid).clone();

        // CPU-resident placement: the host reassembles the batch before
        // every launch — interference-sensitive work on the host heap.
        if let Some(orch) = self.orchestrator.as_mut() {
            std::hint::black_box(orch.step_work());
        }

        let inputs = self.planner.decode_inputs(&self.lanes, spec.batch);
        let seed = self.next_seed();
        self.launcher.launch(LaunchCmd {
            graph: gid,
            block_tables: inputs.block_tables,
            seq_lens: inputs.seq_lens,
            tokens: inputs.tokens,
            offsets: inputs.offsets,
            seed,
            completion: self.completions.buffer(),
            reset_kv: false,
        });

        // GPU-resident: the ring scan overlaps decode compute (its latency
        // hides behind the graph execution). CPU-resident: no overlap —
        // the host waits for the step, then scans on the critical path.
        let overlapped_pending = if self.is_gpu_resident() && !draining {
            self.scan(true)
        } else {
            vec![]
        };

        let Some(step_tokens) = self.completions.poll(spec.batch) else {
            let lanes = std::mem::take(&mut self.lanes);
            for l in lanes {
                self.kv.release(l.cache);
                self.fail_slot(l.slot);
            }
            return;
        };

        self.stats.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.stats.batch_occupancy_sum.fetch_add(live as u64, Ordering::Relaxed);

        // Apply results, retire finished lanes.
        let mut finished: Vec<usize> = vec![];
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let tok = step_tokens[i] as i32;
            lane.cache.cached_len += 1;
            lane.generated += 1;
            lane.last_token = tok;
            self.ring.publish_token(lane.slot, tok as u32);
            self.stats.tokens_generated.fetch_add(1, Ordering::Relaxed);
            if lane.generated >= lane.max_new || tok as u32 == self.manifest.eos_token {
                finished.push(i);
            }
        }
        for i in finished.into_iter().rev() {
            let lane = self.lanes.swap_remove(i);
            self.finish_lane(lane);
        }

        // Pause-and-resume admission using the overlapped scan results.
        if !overlapped_pending.is_empty() && self.lanes.len() < self.max_batch && !draining {
            self.stats.pauses.fetch_add(1, Ordering::Relaxed);
            self.pause_lanes();
            self.admit_and_prefill(overlapped_pending);
            self.resume_lanes();
        }
    }

    fn finish_lane(&mut self, lane: Lane) {
        self.ring.complete(lane.slot);
        self.kv.release(lane.cache);
        self.stats.completed_requests.fetch_add(1, Ordering::Relaxed);
    }

    fn fail_slot(&mut self, slot: usize) {
        self.ring.slot(slot).set_state(SlotState::Failed);
        self.stats.failed_requests.fetch_add(1, Ordering::Relaxed);
    }

    fn next_seed(&mut self) -> u32 {
        self.seed_ctr = self.seed_ctr.wrapping_mul(747796405).wrapping_add(2891336453);
        self.seed_ctr
    }
}

/// Smallest grid sequence length >= prompt_len.
fn padded_seq(cache: &GraphCache, prompt_len: usize) -> usize {
    let mut best = usize::MAX;
    for s in cache.specs() {
        if s.kind == GraphKind::Prefill && s.seq >= prompt_len && s.seq < best {
            best = s.seq;
        }
    }
    if best == usize::MAX {
        prompt_len
    } else {
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_cache() -> GraphCache {
        cache_from_manifest(
            &ModelManifest::parse(
                "blink-manifest v1\nmodel t\nvocab_size 8\nd_model 4\nn_layers 1\nn_heads 1\n\
                 n_kv_heads 1\nd_head 4\nd_ff 8\nblock_size 16\nnum_blocks 8\n\
                 max_blocks_per_seq 4\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
                 param p 4 f32\ngraph decode_b1 decode 1 0\ngraph prefill_b1_s16 prefill 1 16\n\
                 graph prefill_b1_s32 prefill 1 32\ngraph prefill_b2_s64 prefill 2 64\n\
                 graph prefill_offset_b1_s16 prefill_offset 1 16\n",
            )
            .unwrap(),
        )
    }

    #[test]
    fn padded_seq_picks_grid() {
        let c = toy_cache();
        assert_eq!(padded_seq(&c, 10), 16);
        assert_eq!(padded_seq(&c, 16), 16);
        assert_eq!(padded_seq(&c, 17), 32);
        assert_eq!(padded_seq(&c, 40), 64);
    }

    #[test]
    fn default_config_is_paper_fcfs() {
        assert_eq!(SchedulerConfig::default().policy, PolicyKind::Fcfs);
        assert_eq!(SchedulerConfig::default().prefix_reuse, PrefixReuse::Auto);
    }

    #[test]
    fn manifest_offset_graphs_parsed_into_cache() {
        let c = toy_cache();
        assert!(c.has_offset_graphs());
        assert_eq!(c.padded_offset_seq(9), Some(16));
        assert_eq!(c.padded_offset_seq(17), None, "off the partial offset grid");
    }

    /// Satellite: a hit whose suffix is off the offset grid is demoted
    /// to a cold full prefill *before* reserving anything — the cold
    /// admission charges exactly the cold block count (no leaked
    /// refcounts, no shared blocks), and a release restores the pool.
    #[test]
    fn offgrid_suffix_falls_back_cold_without_double_charge() {
        use crate::kvcache::{KvConfig, KvManager, PrefixMatch};
        let cache = toy_cache(); // offset grid covers suffixes ≤ 16 only
        let mut kv = KvManager::new(KvConfig {
            block_size: 16,
            num_blocks: 32,
            max_blocks_per_seq: 8,
        });
        // Turn 1: a 40-token prompt indexes its 2 full blocks (32 tokens).
        let prefix: Vec<u32> = (0..40).collect();
        let a = kv.admit_reuse(&prefix, 64, 4).unwrap();
        kv.index_prompt(&a, &prefix);
        kv.release(a);
        let baseline = kv.free_blocks() + kv.evictable_blocks();

        // Turn 2: 64-token prompt hitting 32 cached tokens → suffix 32,
        // which the offset grid does NOT cover. The admission sequence
        // (mirroring SchedulerCore::admit_and_prefill's reuse branch):
        let prompt: Vec<u32> = (0..64).collect();
        let mut m = kv.match_prefix(&prompt);
        assert_eq!(m.tokens, 32, "the index does hit");
        if cache.padded_offset_seq(prompt.len() - m.tokens).is_none() {
            m = PrefixMatch::default(); // demote before reserving
        }
        assert_eq!(m.tokens, 0, "suffix 32 > offset grid max 16 → cold");
        let padded = padded_seq(&cache, prompt.len());
        assert!(kv.can_admit_reuse(&m, padded, prompt.len(), 4));
        let c = kv.admit_matched(&m, prompt.len(), padded, 4).unwrap();
        assert_eq!(c.prefix_len, 0, "no reuse reserved on the fallback path");
        // Cold cost: span = max(64, 64+4) = 68 → 5 fresh blocks, none
        // shared with the parked prefix (which stays parked).
        assert_eq!(c.blocks.len(), 5);
        assert_eq!(baseline - (kv.free_blocks() + kv.evictable_blocks()), 5);
        kv.release(c);
        assert_eq!(kv.free_blocks() + kv.evictable_blocks(), baseline, "no double-charge");
        kv.check_invariants();

        // A short second turn (suffix ≤ 16) does use the offset path.
        let short: Vec<u32> = (0..48).collect();
        let m2 = kv.match_prefix(&short);
        assert_eq!(m2.tokens, 32);
        assert_eq!(cache.padded_offset_seq(short.len() - m2.tokens), Some(16));
    }
}
