//! The persistent scheduler (paper §4.2), structured as a staged
//! pipeline run by an infinite control loop:
//!
//! ```text
//! ring scan → admission policy → batch planner → launcher → completion
//!   (scan)      (policy.rs)       (planner.rs)   (launcher.rs)  (poll)
//! ```
//!
//! * **ring scan** — detect PREFILL_PENDING slots, snapshot them as
//!   [`Candidate`]s (overlapped behind decode compute when GPU-resident);
//! * **admission policy** — a pluggable [`AdmissionPolicy`] orders the
//!   candidates (FCFS by default; see `SchedulerConfig::policy`);
//! * **batch planner** — admit in policy order under the three admission
//!   conditions (pending work, batch-slot capacity, launch-window
//!   headroom) plus KV backpressure, claim via CAS, group prefills to the
//!   graph grid and marshal decode batches ([`BatchPlanner`]);
//! * **launcher** — fire-and-forget device launches with the launch
//!   window protocol, or host-latency launches for the CPU baseline;
//! * **completion** — poll device-resident completion buffers, publish
//!   generated tokens and status updates back to the ring.
//!
//! Continuous batching is pause-and-resume inline prefill, exactly as
//! before the decomposition — with one bound (DESIGN.md §5): a prompt
//! whose uncached suffix exceeds the per-iteration prefill budget
//! ([`SchedulerConfig::prefill_chunk_tokens`]) does *not* prefill in
//! the iteration it is admitted. It enters a [`ChunkedPrefill`] state
//! machine that reserves all blocks up front and launches one
//! block-aligned chunk per control-loop iteration — chunk 0 through an
//! ordinary prefill graph, chunk *k* > 0 through a `prefill_offset`
//! graph at its true positions — so every in-flight decode lane pays at
//! most one bounded chunk of stall per token instead of the whole
//! prompt's prefill. First-token completion is deferred to the final
//! chunk. The same pipeline runs under two *placements* (Fig 3's
//! controlled comparison): `GpuResident` — the Blink design, overlapped
//! ring scan hidden behind decode compute, 2 µs device launches, zero
//! host work — and `CpuResident` — each step pays a host round trip on
//! the interference-sensitive host heap, with the ring scan serialized
//! after completion instead of overlapped.
//!
//! The steady-state loop is **allocation-free** (DESIGN.md §5
//! "Persistent batch state", pinned by `rust/tests/hotloop_alloc.rs`):
//! launch inputs live in the planner's persistent
//! [`LaunchArena`](crate::gpu::arena::LaunchArena) and are updated in
//! place (a decode step bumps each live lane's `seq_len` and rewrites
//! its `last_token`; block-table rows are rewritten only on batch
//! membership changes), the ring scan / candidate snapshot / completion
//! poll fill scheduler-owned scratch buffers, launches ride an
//! allocation-free doorbell, and retirement is one reverse in-place
//! `swap_remove` pass. Per-iteration control overhead (loop top →
//! decode-launch enqueue) is histogrammed into
//! `SchedulerStats::loop_iter` and exported as `loop_iter_p50_us` /
//! `loop_iter_p99_us`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::devsim::CompletionBuffer;
use crate::gpu::executor::{greedy_chain_token, Executor, LaunchCmd};
use crate::gpu::launcher::{Completions, Launcher};
use crate::gpu::planner::{BatchPlanner, Lane, PrefillGroup, PrefillSeq};
use crate::gpu::policy::{AdmissionPolicy, Candidate, PolicyKind};
use crate::gpu::stats::SchedulerStats;
use crate::graphs::{GraphCache, GraphId, GraphKind, GraphSpec};
use crate::hostsim::HostOrchestrator;
use crate::kvcache::{KvConfig, KvManager, SeqCache};
use crate::ringbuf::{RingBuffer, SlotState};
use crate::runtime::ModelManifest;

#[derive(Debug, Clone)]
pub enum Placement {
    GpuResident,
    /// The host-driven baseline: per-step orchestration over a scratch
    /// heap of `scratch_mb` with `touches_per_step` dependent accesses.
    CpuResident { scratch_mb: usize, touches_per_step: usize },
}

/// Prefix-aware KV reuse mode (DESIGN.md §7). A live hit prefills only
/// the uncached suffix through an *offset* prefill graph
/// (`prefill_offset_b{B}_s{S}` in the AOT grid), so reuse is only as
/// real as the artifacts: `Auto` turns it on exactly when the manifest
/// provides offset graphs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixReuse {
    /// Default: reuse on when the artifacts ship offset prefill graphs,
    /// off (the paper's cold-admission behavior) otherwise.
    Auto,
    /// Force the index machinery on even without offset graphs: hits are
    /// still *detected* (counters, observability) but every one falls
    /// back to a full cold prefill, so numerics stay correct — no suffix
    /// is ever prefilled at the wrong positions.
    On,
    /// The paper's behavior: every admission reserves its full span,
    /// cold. The DES models reuse independently
    /// (`SimConfig::prefix_cache_tokens`), so `blink eval prefix` does
    /// not depend on this mode.
    Off,
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub placement: Placement,
    /// Apply the paper's launch-latency constants as spin delays.
    pub apply_launch_delays: bool,
    /// Stop automatically once idle (used by batch benchmarks).
    pub exit_when_idle: bool,
    /// Admission policy (pipeline stage 2). FCFS reproduces the paper.
    pub policy: PolicyKind,
    /// Prefix-aware KV reuse (DESIGN.md §7): match each prompt against
    /// the block-hash prefix index and prefill only the uncached suffix
    /// through an offset prefill graph. Default [`PrefixReuse::Auto`].
    pub prefix_reuse: PrefixReuse,
    /// Per-iteration prefill token budget (chunked prefill, DESIGN.md
    /// §5): an admitted prompt whose uncached suffix exceeds the budget
    /// is split into block-aligned chunks, one launched per scheduler
    /// iteration and interleaved with decode steps, so a long prompt
    /// can no longer stall every decode lane for its whole prefill.
    /// `None` = the default budget, the largest offset-graph sequence
    /// length (the biggest chunk the grid can express); `Some(0)`
    /// disables chunking (whole-prompt prefill, the paper's behavior).
    /// Chunk *k* > 0 runs a `prefill_offset` graph, so without offset
    /// graphs in the artifacts the budget resolves to 0 either way.
    pub prefill_chunk_tokens: Option<usize>,
    /// Seeded modeled CPU contention applied to the host orchestrator
    /// (CpuResident placement only — the device-plane loop has no
    /// host-heap work to inflate, which is exactly Blink's design
    /// point). `None` = isolated host. See
    /// [`HostOrchestrator::set_contention`].
    pub host_contention: Option<HostContention>,
    /// Speculative decoding (DESIGN.md §11): number of self-drafted
    /// tokens verified per decode launch through a `decode_verify`
    /// graph, 0 = off (the paper's one-token decode). Honored only when
    /// the artifacts ship verify graphs at exactly this k (`blink info`
    /// reports the grid); per-step, batch sizes the verify grid misses
    /// and lanes within k tokens of their budget fall back to plain
    /// decode, so enabling speculation never changes *which* tokens a
    /// request gets — only how many launches produce them.
    pub spec_k: usize,
    /// Target draft-acceptance probability of the deterministic
    /// self-drafter (clamped to [0, 1] at spawn). Each draft position
    /// is deliberately corrupted with probability `1 − spec_accept` by
    /// a seeded position hash, so on the modeled executor speculative
    /// throughput is measurable at any acceptance level while emitted
    /// tokens stay exactly the greedy sequence. 1.0 = perfect drafts.
    pub spec_accept: f64,
}

/// Intensity of the deterministic antagonist channel: the host
/// orchestrator's per-step work is multiplied by samples from a seeded
/// `InterferenceProcess` with this mean. Deterministic work (rather than
/// a live interferer's timing) is what lets CI assert inflation ratios.
#[derive(Debug, Clone, Copy)]
pub struct HostContention {
    /// Mean work multiplier (≥ 1.0; the interference eval maps antagonist
    /// intensity `i` to `1 + 7i`, so full intensity means 8× host work).
    pub mean: f64,
    pub seed: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            placement: Placement::GpuResident,
            apply_launch_delays: true,
            exit_when_idle: false,
            policy: PolicyKind::Fcfs,
            prefix_reuse: PrefixReuse::Auto,
            prefill_chunk_tokens: None,
            host_contention: None,
            spec_k: 0,
            spec_accept: 1.0,
        }
    }
}

/// Handle to the running scheduler thread.
pub struct Scheduler {
    pub stats: Arc<SchedulerStats>,
    // lint: atomic(stop) flag
    stop: Arc<AtomicBool>,
    // lint: atomic(drain) flag
    drain: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Scheduler {
    /// Spawn the persistent scheduler. Takes ownership of the executor
    /// handle (the doorbell into the device) and shares the ring buffer
    /// with the RDMA plane.
    pub fn spawn(
        ring: Arc<RingBuffer>,
        executor: Executor,
        manifest: ModelManifest,
        config: SchedulerConfig,
    ) -> Scheduler {
        let stats = Arc::new(SchedulerStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let drain = Arc::new(AtomicBool::new(false));
        let (stats2, stop2, drain2) = (stats.clone(), stop.clone(), drain.clone());
        let handle = std::thread::Builder::new()
            .name("persistent-scheduler".into())
            .spawn(move || {
                let mut core = SchedulerCore::new(ring, executor, manifest, config, stats2);
                core.run(&stop2, &drain2);
            })
            .expect("spawn scheduler");
        Scheduler { stats, stop, drain, handle: Some(handle) }
    }

    /// Stop accepting new work, finish in-flight requests, then exit.
    pub fn drain_and_stop(&mut self) {
        self.drain.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Hard stop (in-flight requests abandoned).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Build the graph-cache metadata straight from the manifest (the
/// scheduler's copy; the engine holds its own alongside the executables).
pub fn cache_from_manifest(m: &ModelManifest) -> GraphCache {
    let specs = m
        .graphs
        .iter()
        .enumerate()
        .map(|(i, g)| GraphSpec {
            id: GraphId(i),
            name: g.name.clone(),
            kind: GraphKind::from_manifest(&g.kind),
            batch: g.batch,
            seq: g.seq,
        })
        .collect();
    GraphCache::new(specs)
}

/// In-flight chunked prefill (one long-prompt lane mid-prefill): the
/// whole block span is reserved at admission; `done` advances by one
/// block-aligned chunk per scheduler iteration until the final chunk
/// publishes the first token and the lane joins the decode batch. The
/// lane holds its slot in `PrefillProcessing` the entire time — the
/// revised §5 invariant is "an admitted prefill completes within
/// ⌈suffix / budget⌉ iterations", not "in the iteration it is admitted".
struct ChunkedPrefill {
    slot: usize,
    /// The full reservation (release obligation: exactly once, either
    /// on chunk failure here or by the decode lane it becomes).
    cache: SeqCache,
    prompt: Vec<i32>,
    max_new: u32,
    /// Prompt tokens whose K/V is already written: the admission-time
    /// cached prefix plus every completed chunk. Block-aligned until
    /// the final chunk lands.
    done: usize,
    /// Rolling prefix-index commit state: full blocks already walked by
    /// `index_prompt_resume` and the chain hash to resume from (`None`
    /// until the first chunk commits), so each chunk's commit costs
    /// O(chunk), not O(prefix).
    indexed_blocks: usize,
    index_chain: Option<u64>,
    /// Consecutive iterations this lane waited while the per-iteration
    /// budget serviced lanes ahead of it (telemetry: the scheduler
    /// publishes the maximum as `max_chunk_wait_iters`).
    wait_iters: u64,
}

struct SchedulerCore {
    ring: Arc<RingBuffer>,
    manifest: ModelManifest,
    cache: GraphCache,
    config: SchedulerConfig,
    stats: Arc<SchedulerStats>,
    kv: KvManager,
    lanes: Vec<Lane>,
    /// Chunked-prefill state machines (lanes mid-prefill), serviced
    /// FIFO by [`SchedulerCore::chunk_step`] once per iteration.
    chunked: Vec<ChunkedPrefill>,
    orchestrator: Option<HostOrchestrator>,
    // Pipeline stages (see module docs).
    policy: Box<dyn AdmissionPolicy>,
    planner: BatchPlanner,
    launcher: Launcher,
    completions: Completions,
    seed_ctr: u32,
    max_batch: usize,
    /// Hot-loop scratch buffers (DESIGN.md §5): the ring sweep, the
    /// candidate snapshot and the completion poll all fill these
    /// persistent vectors instead of allocating per iteration.
    scan_scratch: Vec<usize>,
    cand_scratch: Vec<Candidate>,
    token_scratch: Vec<u32>,
    /// Resolved reuse switch: `config.prefix_reuse` crossed with the
    /// artifacts (`Auto` requires offset graphs in the manifest).
    reuse: bool,
    /// Resolved per-iteration prefill budget in tokens: block-aligned,
    /// clamped to the graph grids (0 = chunking off). See
    /// [`SchedulerConfig::prefill_chunk_tokens`].
    chunk_tokens: usize,
    /// Ticket of the most recently admitted request (out-of-order stat).
    last_admitted_ticket: Option<u64>,
    /// Resolved speculation width: `config.spec_k` crossed with the
    /// artifacts (0 unless the manifest ships `decode_verify` graphs at
    /// exactly that k). See [`SchedulerConfig::spec_k`].
    spec_k: usize,
    /// Drafter acceptance knob, clamped to [0, 1] at spawn.
    spec_accept: f64,
    /// Per-iteration draft-token scratch, row-major `[lane][spec_k]` —
    /// filled by `draft_lanes`, consumed by `stage_decode_verify`, and
    /// read again by the retire pass for prefix matching. Preallocated
    /// to `max_batch × spec_k` so the verify hot loop never grows it.
    draft_scratch: Vec<i32>,
}

impl SchedulerCore {
    fn new(
        ring: Arc<RingBuffer>,
        executor: Executor,
        manifest: ModelManifest,
        config: SchedulerConfig,
        stats: Arc<SchedulerStats>,
    ) -> SchedulerCore {
        // Stamp which attention build the artifacts carry (pallas
        // kernels vs jnp ref oracles) so /metrics states it; first
        // writer wins, matching "set once at startup".
        let _ = stats.attention_backend.set(manifest.attention_backend().to_string());
        let cache = cache_from_manifest(&manifest);
        let kv = KvManager::new(KvConfig {
            block_size: manifest.block_size,
            num_blocks: manifest.num_blocks,
            max_blocks_per_seq: manifest.max_blocks_per_seq,
        });
        let orchestrator = match &config.placement {
            Placement::GpuResident => None,
            Placement::CpuResident { scratch_mb, touches_per_step } => {
                let mut orch = HostOrchestrator::new(*scratch_mb, *touches_per_step);
                if let Some(c) = config.host_contention {
                    orch.set_contention(c.mean, c.seed);
                }
                Some(orch)
            }
        };
        let gpu_resident = matches!(config.placement, Placement::GpuResident);
        let max_batch = cache.max_decode_batch();
        let max_lanes =
            max_batch.max(cache.max_prefill_batch()).max(cache.max_prefill_offset_batch());
        let policy = config.policy.build();
        let planner =
            BatchPlanner::for_cache(&cache, manifest.max_blocks_per_seq, manifest.block_size);
        let launcher =
            Launcher::new(executor, gpu_resident, config.apply_launch_delays, stats.clone());
        // A verify launch retires up to batch × (k+1) tokens, so the
        // completion buffer and the poll scratch must cover the widest
        // verify grid, not just the lane count.
        let max_poll = max_lanes.max(cache.max_verify_launch_tokens()).max(16);
        let completions = Completions::new(Arc::new(CompletionBuffer::new(max_poll)));
        // Speculation is only as real as the artifacts: a configured k
        // with no decode_verify graphs at that exact k resolves to 0
        // (plain decode — the graceful-fallback convention reuse and
        // chunking follow). Partial *batch* coverage at the right k
        // stays enabled and falls back per step (`blink info` warns).
        let spec_k = if config.spec_k > 0 && cache.verify_ks().contains(&config.spec_k) {
            config.spec_k
        } else {
            0
        };
        let spec_accept = config.spec_accept.clamp(0.0, 1.0);
        // Live reuse is only as real as the artifacts: `Auto` flips on
        // exactly when the manifest provides offset prefill graphs
        // (graceful fallback to the paper's cold behavior otherwise).
        let reuse = match config.prefix_reuse {
            PrefixReuse::Off => false,
            PrefixReuse::On => true,
            PrefixReuse::Auto => cache.has_offset_graphs(),
        };
        // Chunk k > 0 prefills through an offset graph at its true
        // positions, so chunking is only as real as the offset grid:
        // the budget is block-aligned (chunk boundaries are the offsets
        // the graphs take) and clamped so every non-final chunk fits
        // both grids (chunk 0 of a cold prompt runs an ordinary prefill
        // graph). Without offset graphs it resolves to 0 — whole-prompt
        // prefill, exactly the paper's behavior.
        let bs = manifest.block_size.max(1);
        let chunk_cap = cache.max_prefill_offset_seq().min(cache.max_prefill_seq()) / bs * bs;
        let chunk_tokens = match config.prefill_chunk_tokens {
            _ if chunk_cap == 0 => 0,
            Some(0) => 0,
            Some(n) => n.clamp(bs, chunk_cap) / bs * bs,
            None => chunk_cap,
        };
        // Scratch capacities cover the worst case up front (every ring
        // slot pending; the widest grid's completion), so the hot loop
        // never grows them.
        let num_slots = ring.num_slots();
        SchedulerCore {
            ring,
            manifest,
            cache,
            config,
            stats,
            kv,
            lanes: Vec::with_capacity(max_batch),
            chunked: Vec::new(),
            orchestrator,
            policy,
            planner,
            launcher,
            completions,
            seed_ctr: 1,
            max_batch,
            scan_scratch: Vec::with_capacity(num_slots),
            cand_scratch: Vec::with_capacity(num_slots),
            token_scratch: Vec::with_capacity(max_poll),
            reuse,
            chunk_tokens,
            last_admitted_ticket: None,
            spec_k,
            spec_accept,
            draft_scratch: Vec::with_capacity(max_batch * spec_k.max(1)),
        }
    }

    fn is_gpu_resident(&self) -> bool {
        matches!(self.config.placement, Placement::GpuResident)
    }

    fn run(&mut self, stop: &AtomicBool, drain: &AtomicBool) {
        let mut idle_spins = 0u64;
        loop {
            // Control-overhead clock: everything from here to the decode
            // launch enqueue is host-side orchestration the paper's
            // GPU-resident design claims is (near) free — measured per
            // iteration into `stats.loop_iter`.
            let iter_t0 = Instant::now();
            if stop.load(Ordering::Acquire) {
                break;
            }
            let draining = drain.load(Ordering::Acquire);
            if draining
                && self.lanes.is_empty()
                && self.chunked.is_empty()
                && self.ring.pending_hint() == 0
            {
                break;
            }

            // Admission (when not draining): scan + policy + claim +
            // inline prefill. Chunked lanes occupy batch slots too.
            if !draining
                && self.lanes.len() + self.chunked.len() < self.max_batch
                && self.scan_into(true)
            {
                if !self.lanes.is_empty() {
                    // Continuous batching: pausing in-flight decode to
                    // run an inline prefill (the decode loop resumes on
                    // the next iteration — state is in `self.lanes`).
                    self.stats.pauses.fetch_add(1, Ordering::Relaxed);
                    self.pause_lanes();
                }
                self.admit_and_prefill();
                self.resume_lanes();
            }

            // Chunked-prefill progress: one budget-bounded chunk round,
            // then the decode step it interleaves with.
            self.chunk_step();

            if self.lanes.is_empty() {
                if !self.chunked.is_empty() {
                    // No decode lanes yet, but chunked prefills are
                    // advancing — not idle.
                    idle_spins = 0;
                    continue;
                }
                idle_spins += 1;
                if idle_spins > 64 {
                    // Persistent kernels spin; on a shared test machine we
                    // yield so idle schedulers don't starve the world.
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
                if self.config.exit_when_idle && idle_spins > 10_000 {
                    break;
                }
                continue;
            }
            idle_spins = 0;

            self.decode_step(draining, iter_t0);
        }
    }

    /// Pipeline stage 1 — timed ring scan (the paper's 1–5 µs full-ring
    /// sweep) into the scheduler-owned scratches, snapshotting pending
    /// slots as policy candidates in `self.cand_scratch`. Returns true
    /// when at least one candidate was found. Allocation-free: both
    /// scratches persist across iterations, and the cheap doorbell check
    /// skips even the sweep when nothing is pending.
    // lint: no_alloc no_panic # scratches persist; hotloop_alloc pins this at runtime
    fn scan_into(&mut self, only_if_hinted: bool) -> bool {
        self.cand_scratch.clear();
        if only_if_hinted && self.ring.pending_hint() == 0 {
            return false;
        }
        let t = Instant::now();
        self.ring.scan_pending_into(&mut self.scan_scratch);
        // The timed region covers only the sweep itself, so scan_mean/max
        // stay comparable to the paper envelope; the candidate snapshot
        // is policy-stage work.
        self.stats.record_scan(t.elapsed().as_nanos() as u64);
        Candidate::collect_into(&self.ring, &self.scan_scratch, &mut self.cand_scratch);
        // Queue-depth gauge for /metrics and the overload layer: how many
        // submitted slots are waiting at this admission pass (one relaxed
        // store — the scratch stays allocation-free).
        self.stats.record_queue_depth(self.cand_scratch.len() as u64);
        !self.cand_scratch.is_empty()
    }

    fn pause_lanes(&self) {
        for l in &self.lanes {
            self.ring.slot(l.slot).set_state(SlotState::DecodePaused);
        }
    }

    fn resume_lanes(&self) {
        for l in &self.lanes {
            let s = self.ring.slot(l.slot);
            // Lanes admitted during the pause are already DECODE_PROCESSING.
            if s.state() == SlotState::DecodePaused {
                s.set_state(SlotState::DecodeProcessing);
            }
        }
    }

    /// Pipeline stages 2+3 — order the candidates scanned into
    /// `self.cand_scratch` by the admission policy, admit under the
    /// three admission conditions (paper §4.2 "Continuous batching":
    /// (i) pending prefills detected, (ii) free batch-slot capacity,
    /// (iii) launch-window headroom) plus KV backpressure, then group
    /// and launch the prefills.
    ///
    /// Admission is the loop's *bounded* allocating phase (prompt reads,
    /// admitted-sequence staging, group planning); the steady-state
    /// decode path allocates nothing (see `hotloop_alloc.rs`, which
    /// asserts both halves). The candidate scratch itself is borrowed
    /// via `mem::take` and handed back cleared, capacity intact.
    fn admit_and_prefill(&mut self) {
        let mut candidates = std::mem::take(&mut self.cand_scratch);
        // Stage 2: policy ordering (FCFS = ticket order, the paper).
        let now_us = crate::util::timer::now_us();
        self.policy.order(&mut candidates, now_us);
        self.admit_ordered(&candidates);
        candidates.clear();
        self.cand_scratch = candidates;
    }

    /// Stage 3a body: admission checks + CAS claims, in policy order,
    /// then stage 3b grouping + launches.
    fn admit_ordered(&mut self, candidates: &[Candidate]) {
        let mut admitted: Vec<PrefillSeq> = vec![];
        let mut new_chunked: Vec<ChunkedPrefill> = vec![];
        for &cand in candidates {
            let occupied =
                self.lanes.len() + self.chunked.len() + admitted.len() + new_chunked.len();
            if occupied >= self.max_batch {
                self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                break; // leave pending in the ring: backpressure
            }
            let slot_idx = cand.slot;
            let slot = self.ring.slot(slot_idx);
            if slot.state() != SlotState::PrefillPending {
                continue; // raced with... nothing today, but benign
            }
            // Relaxed: the PrefillPending read above came through the
            // state word's edge; `prompt_len` itself is stored Relaxed,
            // so Acquire here would pair with nothing.
            let prompt_len = slot.prompt_len.load(Ordering::Relaxed) as usize;
            let max_new = slot.max_new_tokens.load(Ordering::Relaxed).max(1);
            // With chunking off, a prompt must fit one full-prefill
            // graph; chunked prefill lifts that single-launch cap (each
            // chunk fits its grid), leaving the block budget — enforced
            // by the KV admission below — as the only length bound.
            let over_grid =
                prompt_len > self.cache.max_prefill_seq() && self.chunk_tokens == 0;
            // A prompt that already fills the whole context has no
            // decode headroom: `max_new` would clamp to 0 below and the
            // sequence could never produce a token — fail it like any
            // other invalid request instead of admitting a dead lane.
            let headroom = self.manifest.max_context().saturating_sub(prompt_len);
            if prompt_len == 0 || over_grid || headroom == 0 {
                // Invalid request: claim it and fail it.
                if self.ring.claim_pending(slot_idx) {
                    self.fail_slot(slot_idx);
                }
                continue;
            }
            let max_new = max_new.min(headroom as u32);
            // Condition (ii)/KV admission. Cold path: the exact check is
            // pure slot-metadata math, so a backpressured scan cycle
            // costs nothing. Reuse path: first a metadata-only lower
            // bound; if even the best case cannot be reserved, reject
            // before the O(prompt) arena read + hash. Only then read
            // the prompt (side-effect free, pre-claim) and run the
            // exact match-aware check. With chunking off, a hit whose
            // suffix fits no offset graph is demoted to a cold full
            // prefill *before* any reservation, so nothing is ever
            // double-charged; with chunking on such a suffix chunks
            // through the offset grid instead, keeping the hit. On
            // rejection, stop admitting so a later (lower-ranked)
            // candidate cannot leapfrog the policy's head-of-queue
            // choice.
            let bs = self.kv.config().block_size;
            let prompt_u32: Option<Vec<u32>>;
            let pm: Option<crate::kvcache::PrefixMatch>;
            // Padded prefill span to reserve beyond the cached prefix:
            // one launch window, or the furthest chunk write bound.
            let mut padded;
            // Chunked admission: the uncached suffix exceeds the
            // per-iteration budget, so the prompt enters the chunked
            // state machine instead of prefilling inline.
            let mut chunk_this;
            if self.reuse {
                // Floor: a uniform fresh-block lower bound across every
                // admission shape (cold, hit, chunked): the reserved
                // span always covers prompt + max_new, and sharing can
                // save at most the maximal block-aligned prefix. Exact
                // per-shape needs are only higher (padding, parked
                // matches), so a floor over available blocks is a sound
                // early reject.
                let best_match = (prompt_len - 1) / bs * bs;
                let floor =
                    (prompt_len + max_new as usize).div_ceil(bs) - best_match / bs;
                if floor > self.kv.available_blocks() {
                    self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                    break;
                }
                let p = self.ring.read_prompt(slot_idx);
                let mut m = self.kv.match_prefix(&p);
                let suffix = prompt_len - m.tokens;
                if self.chunk_tokens > 0 && suffix > self.chunk_tokens {
                    // Chunked: reserve the whole span up front, sized
                    // by the furthest padded chunk write. A hit's long
                    // suffix stays a hit — every chunk k > 0 fits the
                    // offset grid by the budget clamp, so no demotion.
                    chunk_this = true;
                    padded =
                        chunk_write_end(&self.cache, m.tokens, prompt_len, self.chunk_tokens)
                            - m.tokens;
                } else if m.tokens == 0 {
                    chunk_this = false;
                    padded = padded_seq(&self.cache, prompt_len);
                } else if let Some(p_off) = self.cache.padded_offset_seq(suffix) {
                    chunk_this = false;
                    padded = p_off;
                } else {
                    // Graceful fallback — reachable only with chunking
                    // off (on, any suffix ≤ budget fits the grid): the
                    // suffix is off the offset grid (or the artifacts
                    // ship none — PrefixReuse::On without offset
                    // graphs). Abandon the match before reserving
                    // anything: the request admits cold with a full
                    // prefill, sharing no blocks.
                    self.stats.prefix_fallback_full.fetch_add(1, Ordering::Relaxed);
                    m = crate::kvcache::PrefixMatch::default();
                    chunk_this = false;
                    padded = padded_seq(&self.cache, prompt_len);
                }
                // On a sparse offset grid the *final* chunk's padding
                // can push the chunked write bound past the per-seq
                // block budget even though the prompt itself fits
                // (e.g. a 15-token remainder padding to a 64-token
                // graph). A shape over that cap can never admit no
                // matter how many blocks free up, so routing it to the
                // backpressure break would wedge the queue forever.
                // Rescue ladder instead — unchunked hit, then cold
                // whole prompt, each rung re-checked against the cap —
                // and fail fast when no rung fits.
                let cap = self.kv.config().max_blocks_per_seq;
                if chunk_this
                    && self.kv.config().blocks_needed_with_prefix(
                        m.tokens,
                        padded,
                        prompt_len,
                        max_new as usize,
                    ) > cap
                {
                    let hit_shape = self.cache.padded_offset_seq(suffix).filter(|&p_off| {
                        m.tokens > 0
                            && self.kv.config().blocks_needed_with_prefix(
                                m.tokens,
                                p_off,
                                prompt_len,
                                max_new as usize,
                            ) <= cap
                    });
                    if let Some(p_off) = hit_shape {
                        chunk_this = false;
                        padded = p_off;
                    } else if let Some(cold_padded) =
                        self.cold_rescue_shape(prompt_len, max_new)
                    {
                        if m.tokens > 0 {
                            self.stats.prefix_fallback_full.fetch_add(1, Ordering::Relaxed);
                            m = crate::kvcache::PrefixMatch::default();
                        }
                        chunk_this = false;
                        padded = cold_padded;
                    } else {
                        // No admissible shape at any size: unservable.
                        if self.ring.claim_pending(slot_idx) {
                            self.fail_slot(slot_idx);
                        }
                        continue;
                    }
                }
                if !self.kv.can_admit_reuse(&m, padded, prompt_len, max_new as usize) {
                    self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                    break;
                }
                prompt_u32 = Some(p);
                pm = Some(m);
            } else {
                chunk_this = self.chunk_tokens > 0 && prompt_len > self.chunk_tokens;
                padded = if chunk_this {
                    chunk_write_end(&self.cache, 0, prompt_len, self.chunk_tokens)
                } else {
                    padded_seq(&self.cache, prompt_len)
                };
                // Same final-chunk-padding rescue as the reuse path:
                // demote to a whole-prompt launch only when that shape
                // actually fits the per-seq cap; fail fast otherwise.
                if chunk_this
                    && self.kv.config().blocks_needed(padded, prompt_len, max_new as usize)
                        > self.kv.config().max_blocks_per_seq
                {
                    if let Some(cold_padded) = self.cold_rescue_shape(prompt_len, max_new) {
                        chunk_this = false;
                        padded = cold_padded;
                    } else {
                        if self.ring.claim_pending(slot_idx) {
                            self.fail_slot(slot_idx);
                        }
                        continue;
                    }
                }
                if !self.kv.can_admit(padded, prompt_len, max_new as usize) {
                    self.stats.backpressure_events.fetch_add(1, Ordering::Relaxed);
                    break;
                }
                prompt_u32 = None;
                pm = None;
            }
            // Condition (iii): headroom for this prefill + one decode.
            if self.launcher.headroom() < 2 {
                self.launcher.tail_relaunch();
            }
            if !self.ring.claim_pending(slot_idx) {
                continue;
            }
            self.note_admission_order(cand.ticket);
            // Session attribution: the tag rides along the RDMA metadata
            // write; non-zero means a multi-turn conversation turn.
            if self.ring.slot(slot_idx).session_id.load(Ordering::Relaxed) != 0 {
                self.stats.session_requests.fetch_add(1, Ordering::Relaxed);
            }
            // Cold path reads the prompt only now, after the claim.
            let prompt_u32 =
                prompt_u32.unwrap_or_else(|| self.ring.read_prompt(slot_idx));
            let cache = match &pm {
                // Reuse the match computed above — no second hash pass.
                Some(m) => self
                    .kv
                    .admit_matched(m, prompt_len, padded, max_new as usize)
                    .expect("can_admit_reuse checked above"),
                None => self
                    .kv
                    .admit(padded, prompt_len, max_new as usize)
                    .expect("can_admit checked above"),
            };
            let cached_prefix = cache.prefix_len;
            let prompt: Vec<i32> = prompt_u32.into_iter().map(|t| t as i32).collect();
            if chunk_this {
                self.stats.chunked_prefills.fetch_add(1, Ordering::Relaxed);
                new_chunked.push(ChunkedPrefill {
                    slot: slot_idx,
                    cache,
                    prompt,
                    max_new,
                    done: cached_prefix,
                    indexed_blocks: 0,
                    index_chain: None,
                    wait_iters: 0,
                });
            } else {
                admitted.push(PrefillSeq {
                    slot: slot_idx,
                    cache,
                    prompt,
                    max_new,
                    cached_prefix,
                    padded,
                    first_token: true,
                });
            }
        }
        // Chunked admissions launch nothing here: their chunks are
        // emitted by `chunk_step`, one budget-bounded round per
        // iteration, starting this same control-loop pass.
        self.chunked.extend(new_chunked);
        if admitted.is_empty() {
            self.publish_kv_stats();
            return;
        }

        // Stage 3b: group to the prefill graph grid (full vs offset
        // launches, see planner) and launch each group in shared-block
        // dependency order — `group_prefills` topologically orders
        // sharer groups after their prefix producers, so a hit can never
        // launch before the prefill that writes its shared blocks. Index
        // entries additionally commit only after a group's prefill
        // completed (each launch below is polled synchronously), so a
        // match can only ever land on K/V that is already written.
        for group in self.planner.group_prefills(admitted) {
            self.launch_prefill(group);
        }
        self.publish_kv_stats();
    }

    /// Mirror the KV manager's reuse counters into the shared atomics —
    /// `kvcache::KvStats` is the single source of truth; the scheduler
    /// only publishes it for `/metrics` readers.
    fn publish_kv_stats(&self) {
        let kv_stats = self.kv.stats;
        self.stats.prefix_hits.store(kv_stats.prefix_hits, Ordering::Relaxed);
        self.stats.prefix_hit_tokens.store(kv_stats.reused_tokens, Ordering::Relaxed);
        self.stats.prefix_evicted_blocks.store(kv_stats.evicted_blocks, Ordering::Relaxed);
        self.stats.prefix_indexed_blocks.store(
            kv_stats.indexed_blocks.saturating_sub(kv_stats.evicted_blocks),
            Ordering::Relaxed,
        );
    }

    /// The cold rung of the chunk-plan rescue ladder (both admission
    /// paths): the whole-prompt launch shape, iff the prompt fits a
    /// single prefill graph *and* that shape's block need fits the
    /// per-seq cap. `None` means the request is unservable at any size
    /// — callers fail it fast instead of wedging the queue on a shape
    /// `can_admit` would reject forever.
    fn cold_rescue_shape(&self, prompt_len: usize, max_new: u32) -> Option<usize> {
        if prompt_len > self.cache.max_prefill_seq() {
            return None;
        }
        let cold_padded = padded_seq(&self.cache, prompt_len);
        let need = self.kv.config().blocks_needed(cold_padded, prompt_len, max_new as usize);
        (need <= self.kv.config().max_blocks_per_seq).then_some(cold_padded)
    }

    /// Out-of-ticket-order admissions (non-FCFS policies at work); FCFS
    /// keeps this at zero, which the integration tests pin down.
    fn note_admission_order(&mut self, ticket: u64) {
        match self.last_admitted_ticket {
            Some(last) if ticket < last => {
                self.stats.admitted_out_of_order.fetch_add(1, Ordering::Relaxed);
            }
            _ => self.last_admitted_ticket = Some(ticket),
        }
    }

    /// Resolve one prefill group to concrete graph launches. Offset
    /// groups launch a `prefill_offset` graph whose seq equals the
    /// padded *suffix* the admission stage reserved — never a longer
    /// one, whose K/V writes would land past the reservation (hits
    /// whose suffix is off-grid were demoted to cold full prefills
    /// before reserving anything). A sparse or non-rectangular offset
    /// grid that cannot cover the whole group at that exact seq in one
    /// launch is handled by splitting on the batch axis: aot.py emits
    /// dense rectangular grids, so the first probe succeeds at full
    /// width; hand-built manifests may not be rectangular, in which
    /// case the widest exactly-sized prefix of the group launches first
    /// and the tail follows. Batch 1 always fits: `padded` came from
    /// `padded_offset_seq`, so a graph with that exact seq exists and
    /// the (seq, batch) tie-break selects it.
    fn plan_group_launches(&self, mut group: PrefillGroup) -> Vec<(GraphId, PrefillGroup)> {
        let mut out = vec![];
        loop {
            let b_actual = group.seqs.len();
            if group.offset {
                let exact_fit = |cache: &GraphCache, b: usize, padded: usize| {
                    cache
                        .select_prefill_offset(b, padded)
                        .filter(|&g| cache.spec(g).seq == padded)
                };
                let fit = (1..=b_actual)
                    .rev()
                    .find(|&b| exact_fit(&self.cache, b, group.padded).is_some())
                    .expect("admission verified an exact-seq offset graph at batch 1");
                let gid = exact_fit(&self.cache, fit, group.padded).expect("probed above");
                if fit < b_actual {
                    let rest = group.seqs.split_off(fit);
                    let padded = group.padded;
                    out.push((gid, group));
                    group = PrefillGroup { padded, offset: true, seqs: rest };
                    continue;
                }
                out.push((gid, group));
            } else {
                let gid = self
                    .cache
                    .select_prefill(b_actual, group.padded)
                    .expect("grid covers all padded sizes");
                out.push((gid, group));
            }
            return out;
        }
    }

    /// Stage + launch + poll one resolved prefill launch; on success the
    /// per-lane sampled tokens are left in `self.token_scratch`. Inputs
    /// are staged into the arena's prefill region (one epoch publish)
    /// rather than marshaled into owned `Vec`s.
    fn fire_prefill(&mut self, gid: GraphId, group: &PrefillGroup) -> bool {
        let (grid_batch, grid_seq) = {
            let spec = self.cache.spec(gid);
            (spec.batch, spec.seq)
        };
        if group.offset {
            self.stats.prefill_offset_batches.fetch_add(1, Ordering::Relaxed);
        }
        let epoch = self.planner.stage_prefill(group, grid_batch, grid_seq);
        let seed = self.next_seed();
        self.launcher.launch(LaunchCmd {
            graph: gid,
            arena: self.planner.arena(),
            epoch,
            seed,
            completion: self.completions.buffer(),
        });
        let mut tokens = std::mem::take(&mut self.token_scratch);
        let ok = self.completions.poll_into(grid_batch, &mut tokens);
        self.token_scratch = tokens;
        ok
    }

    /// Pipeline stages 4+5 for one prefill group — whole prompts and
    /// chunks alike: resolve graphs, launch, poll, then publish first
    /// tokens (or advance chunked lanes).
    fn launch_prefill(&mut self, group: PrefillGroup) {
        for (gid, g) in self.plan_group_launches(group) {
            if self.fire_prefill(gid, &g) {
                self.stats.prefill_batches.fetch_add(1, Ordering::Relaxed);
                let tokens = std::mem::take(&mut self.token_scratch);
                self.complete_prefill_seqs(g, &tokens);
                self.token_scratch = tokens;
            } else {
                self.fail_prefill_seqs(g);
            }
        }
    }

    /// Failed prefill launch: plain release, once per lane. Nothing was
    /// published to the prefix index for the failed span (entries
    /// commit only on success), so no later prompt can "hit" unwritten
    /// K/V — blocks of a chunked lane's *earlier* chunks may stay
    /// indexed: their prefill completed and their K/V is real. A chunk
    /// seq's cache clone names the same blocks as its lane's
    /// reservation, so releasing the clone settles the lane's whole
    /// obligation; the state-machine entry is dropped without a second
    /// release.
    fn fail_prefill_seqs(&mut self, group: PrefillGroup) {
        for s in group.seqs {
            if let Some(pos) = self.chunked.iter().position(|c| c.slot == s.slot) {
                self.chunked.remove(pos);
            }
            self.kv.release(s.cache);
            self.fail_slot(s.slot);
        }
    }

    /// Successful prefill launch: commit the written blocks to the
    /// prefix index, then either publish the first token and open a
    /// decode lane (whole prompts and final chunks) or advance the
    /// chunked lane's high-water mark (intermediate chunks).
    fn complete_prefill_seqs(&mut self, group: PrefillGroup, first_tokens: &[u32]) {
        let group_offset = group.offset;
        for (lane_idx, seq) in group.seqs.into_iter().enumerate() {
            let PrefillSeq { slot, mut cache, prompt, max_new, cached_prefix, first_token, .. } =
                seq;
            debug_assert!(cached_prefix == 0 || group_offset, "hit seq in a full-prefill group");
            // The launch wrote K/V for `prompt` — the whole prompt, or
            // the prefix up to this chunk's end. Commit its *full*
            // blocks to the prefix index so later turns (and concurrent
            // sessions, even mid-chunking) can share them. Partial-index
            // invariant: only fully prefilled blocks ever commit, so a
            // partially prefilled prompt exposes exactly its completed
            // chunks and nothing beyond. Chunked lanes resume the hash
            // chain where the previous chunk's commit left it, so the
            // per-iteration commit work is O(chunk) (the prefix copy
            // into `toks` remains — it is a bounded memcpy, not hash +
            // index-probe work).
            if self.reuse {
                let toks: Vec<u32> = prompt.iter().map(|&t| t as u32).collect();
                if let Some(cp) = self.chunked.iter_mut().find(|c| c.slot == slot) {
                    let bs = self.kv.config().block_size;
                    let full = (toks.len() / bs).min(cache.blocks.len());
                    let h = self.kv.index_prompt_resume(
                        &cache,
                        &toks,
                        cp.indexed_blocks,
                        cp.index_chain,
                    );
                    cp.indexed_blocks = full;
                    cp.index_chain = Some(h);
                } else {
                    self.kv.index_prompt(&cache, &toks);
                }
            }
            if !first_token {
                // Intermediate chunk: no token exists yet — first-token
                // completion is deferred to the final chunk.
                let cp = self
                    .chunked
                    .iter_mut()
                    .find(|c| c.slot == slot)
                    .expect("intermediate chunk has an in-flight lane");
                cp.done = prompt.len();
                continue;
            }
            // Final chunk of a chunked lane: retire the state-machine
            // entry. Its release obligation moves to the decode lane
            // below (the chunk seq's cache names the same blocks).
            if let Some(pos) = self.chunked.iter().position(|c| c.slot == slot) {
                self.chunked.remove(pos);
            }
            cache.cached_len = prompt.len();
            let tok = first_tokens[lane_idx] as i32;
            self.ring.slot(slot).set_state(SlotState::DecodeProcessing);
            self.ring.publish_token(slot, tok as u32);
            self.note_ttft_deadline(slot);
            self.stats.tokens_generated.fetch_add(1, Ordering::Relaxed);
            self.stats.prefilled_requests.fetch_add(1, Ordering::Relaxed);
            let done = max_new <= 1 || tok as u32 == self.manifest.eos_token;
            if done {
                // Finished at its first token: never joined the decode
                // batch, so membership (and the arena) is untouched.
                self.finish_lane(Lane { slot, cache, generated: 1, max_new, last_token: tok });
            } else {
                self.lanes.push(Lane { slot, cache, generated: 1, max_new, last_token: tok });
                self.note_membership_change(1);
            }
        }
    }

    /// Chunked-prefill state machine step (one per control-loop
    /// iteration): launch the next block-aligned chunk for as many
    /// in-flight lanes as the per-iteration token budget covers — FIFO
    /// from the oldest lane, always at least one so progress is
    /// guaranteed — grouped so same-shape chunks share a launch. The
    /// decode step the main loop runs right after is what the budget
    /// protects: every in-flight decode lane waits for at most
    /// `chunk_tokens` of prefill per iteration, not a whole prompt.
    fn chunk_step(&mut self) {
        if self.chunked.is_empty() {
            return;
        }
        let paused = !self.lanes.is_empty();
        if paused {
            // Chunk launches are inline prefills: same pause-and-resume
            // protocol as admission.
            self.stats.pauses.fetch_add(1, Ordering::Relaxed);
            self.pause_lanes();
        }
        // How many lanes fit this round's budget (≥ 1).
        let mut spent = 0usize;
        let mut serviced = 0usize;
        while serviced < self.chunked.len() {
            let cp = &self.chunked[serviced];
            let len = (cp.prompt.len() - cp.done).min(self.chunk_tokens);
            if serviced > 0 && spent + len > self.chunk_tokens {
                break;
            }
            spent += len;
            serviced += 1;
        }
        let mut seqs: Vec<PrefillSeq> = Vec::with_capacity(serviced);
        for cp in self.chunked.iter_mut().take(serviced) {
            let len = (cp.prompt.len() - cp.done).min(self.chunk_tokens);
            let end = cp.done + len;
            let padded = if cp.done == 0 {
                // Chunk 0 of a cold prompt is a plain prefix prefill.
                padded_seq(&self.cache, len)
            } else {
                self.cache
                    .padded_offset_seq(len)
                    .expect("budget clamped to the offset grid")
            };
            seqs.push(PrefillSeq {
                slot: cp.slot,
                cache: cp.cache.clone(),
                prompt: cp.prompt[..end].to_vec(),
                max_new: cp.max_new,
                cached_prefix: cp.done,
                padded,
                first_token: end == cp.prompt.len(),
            });
            cp.wait_iters = 0;
        }
        for cp in self.chunked.iter_mut().skip(serviced) {
            cp.wait_iters += 1;
            self.stats.max_chunk_wait_iters.fetch_max(cp.wait_iters, Ordering::Relaxed);
        }
        self.stats.chunk_launches.fetch_add(seqs.len() as u64, Ordering::Relaxed);
        for group in self.planner.group_prefills(seqs) {
            self.launch_prefill(group);
        }
        if paused {
            self.resume_lanes();
        }
        self.publish_kv_stats();
    }

    /// TTFT-deadline attainment accounting (SLO-aware observability).
    fn note_ttft_deadline(&self, slot: usize) {
        let s = self.ring.slot(slot);
        let deadline = s.ttft_deadline_us.load(Ordering::Relaxed);
        if deadline != 0 && s.first_token_time_us.load(Ordering::Relaxed) > deadline {
            self.stats.ttft_deadline_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One steady-state decode iteration — the allocation-free path the
    /// zero-alloc regression test pins: incremental arena staging, an
    /// epoch-tagged doorbell launch, overlapped scratch scan, scratch
    /// completion poll, and a single reverse in-place retire pass.
    // lint: no_alloc no_panic # steady-state decode: the zero-alloc contract, statically
    fn decode_step(&mut self, draining: bool, iter_t0: Instant) {
        let live = self.lanes.len();
        debug_assert!(live > 0);
        // Speculative verify eligibility (DESIGN.md §11): speculation is
        // resolved on, the verify grid covers this batch size, and every
        // lane has strictly more than k tokens of budget left — the
        // budget-edge clamp. A verify launch writes K/V optimistically at
        // `cached_len .. cached_len + k`, which stays inside the
        // admission reservation exactly when `generated + k < max_new`;
        // tail-of-budget iterations run plain decode instead.
        let mut verify_gid = None;
        if self.spec_k > 0
            && self
                .lanes
                .iter()
                .all(|l| (l.max_new.saturating_sub(l.generated) as usize) > self.spec_k)
        {
            verify_gid = self.cache.select_decode_verify(live, self.spec_k);
        }
        let k = if verify_gid.is_some() { self.spec_k } else { 0 };
        // Tokens staged and retired per lane this launch: the pending
        // token plus k drafts. Plain decode is the w = 1 case, so one
        // retire pass below serves both shapes.
        let w = k + 1;
        let gid = verify_gid
            .unwrap_or_else(|| self.cache.select_decode(live).expect("decode grid covers batch sizes"));
        let grid_batch = self.cache.spec(gid).batch;

        // CPU-resident placement: the host reassembles the batch before
        // every launch — interference-sensitive work on the host heap.
        if let Some(orch) = self.orchestrator.as_mut() {
            std::hint::black_box(orch.step_work());
        }

        // Stage the batch in place: per-lane seq_len bump + last_token
        // write (plain decode), or the (k+1)-wide window of pending
        // token + self-drafted tokens (verify); block-table rows only
        // after a membership change. The scratch swap keeps the borrow
        // checker happy without cloning: `draft_lanes` filled it, the
        // planner reads it, and the retire pass reads it again below.
        let epoch = if k > 0 {
            self.draft_lanes(k);
            let drafts = std::mem::take(&mut self.draft_scratch);
            let e = self.planner.stage_decode_verify(&self.lanes, grid_batch, k, &drafts);
            self.draft_scratch = drafts;
            e
        } else {
            self.planner.stage_decode(&self.lanes, grid_batch)
        };
        let seed = self.next_seed();
        self.launcher.launch(LaunchCmd {
            graph: gid,
            arena: self.planner.arena(),
            epoch,
            seed,
            completion: self.completions.buffer(),
        });
        // Control-overhead sample: loop top → decode-launch enqueue.
        self.stats.loop_iter.record_ns(iter_t0.elapsed().as_nanos() as u64);

        // GPU-resident: the ring scan overlaps decode compute (its latency
        // hides behind the graph execution). CPU-resident: no overlap —
        // the host waits for the step, then scans on the critical path.
        let overlapped = if self.is_gpu_resident() && !draining {
            self.scan_into(true)
        } else {
            false
        };

        let mut tokens = std::mem::take(&mut self.token_scratch);
        let ok = self.completions.poll_into(grid_batch * w, &mut tokens);
        self.token_scratch = tokens;
        if !ok {
            let lanes = std::mem::take(&mut self.lanes);
            let torn_down = lanes.len() as u64;
            for l in lanes {
                self.kv.release(l.cache);
                self.fail_slot(l.slot);
            }
            self.note_membership_change(torn_down);
            return;
        }

        self.stats.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.stats.batch_occupancy_sum.fetch_add(live as u64, Ordering::Relaxed);
        if k > 0 {
            self.stats.spec_drafted.fetch_add((live * k) as u64, Ordering::Relaxed);
        }

        // Apply results and retire finished lanes in one reverse
        // in-place pass — `swap_remove` only disturbs indices above the
        // cursor, which this pass has already visited, so no scratch
        // list of finished indices is needed. Each lane's completion
        // window is `w` sampled successors `o_0..o_{k}` (o_j answers
        // window position j); plain decode is the w = 1 window.
        let eos = self.manifest.eos_token;
        let mut retired = 0u64;
        let mut i = self.lanes.len();
        while i > 0 {
            i -= 1;
            let outs = &self.token_scratch[i * w..(i + 1) * w];
            let lane = &mut self.lanes[i];
            // Longest accepted prefix: o_j is the true successor of
            // window position j, so o_j is emittable only once drafts
            // d_1..d_j all matched o_0..o_{j-1}. Stop at EOS (nothing
            // may follow the end of sequence) and at the budget edge;
            // o_0 (the bonus/plain token) is always valid.
            let budget = lane.max_new.saturating_sub(lane.generated) as usize;
            let mut emitted = 1usize;
            while emitted <= k
                && emitted < budget
                && outs[emitted - 1] != eos
                && self.draft_scratch[i * k + emitted - 1] == outs[emitted - 1] as i32
            {
                emitted += 1;
            }
            let accepted = emitted - 1;
            // The launch optimistically wrote K/V for all w window
            // positions; keep the accepted span and roll the rejected
            // tail back (kvcache invariant 5's speculative extension —
            // blocks stay reserved, only `cached_len` moves).
            let base = lane.cache.cached_len;
            lane.cache.cached_len = base + w;
            self.kv.truncate_tail(&mut lane.cache, base + 1 + accepted);
            lane.generated += emitted as u32;
            lane.last_token = outs[emitted - 1] as i32;
            let slot = lane.slot;
            let done = lane.generated >= lane.max_new || outs[emitted - 1] == eos;
            for &tok in &outs[..emitted] {
                self.ring.publish_token(slot, tok);
            }
            self.stats.tokens_generated.fetch_add(emitted as u64, Ordering::Relaxed);
            if k > 0 {
                self.stats.spec_accepted.fetch_add(accepted as u64, Ordering::Relaxed);
                // Counts ride the ring ×1000 — see SchedulerStats.
                self.stats.accepted_per_verify.record_ns(accepted as u64 * 1000);
            }
            if done {
                let lane = self.lanes.swap_remove(i);
                self.finish_lane(lane);
                retired += 1;
            }
        }
        self.note_membership_change(retired);
        // Full-iteration sample (loop top → tokens retired): control
        // overhead *plus* the executor step, raw ns for exact
        // percentiles. This is the number the interference eval pins
        // its inflation ratios on — on the host-driven placement it
        // contains the (possibly contended) orchestration work; on the
        // device plane it is dominated by the executor step.
        self.stats.iter_full.record_ns(iter_t0.elapsed().as_nanos() as u64);

        // Pause-and-resume admission using the overlapped scan results.
        if overlapped && self.lanes.len() + self.chunked.len() < self.max_batch && !draining {
            self.stats.pauses.fetch_add(1, Ordering::Relaxed);
            self.pause_lanes();
            self.admit_and_prefill();
            self.resume_lanes();
        }
    }

    /// Decode-batch membership changed by `n` lanes (admit / retire /
    /// teardown): dirty the arena's decode region so the next staging
    /// pass rewrites every row, and count it — membership churn is the
    /// only thing standing between the steady loop and pure in-place
    /// updates, so `/metrics` reports it alongside the iteration
    /// percentiles.
    // lint: no_alloc no_panic
    fn note_membership_change(&mut self, n: u64) {
        if n > 0 {
            self.planner.mark_decode_dirty();
            self.stats.batch_membership_changes.fetch_add(n, Ordering::Relaxed);
        }
    }

    fn finish_lane(&mut self, lane: Lane) {
        self.ring.complete(lane.slot);
        self.kv.release(lane.cache);
        self.stats.completed_requests.fetch_add(1, Ordering::Relaxed);
    }

    fn fail_slot(&mut self, slot: usize) {
        self.ring.slot(slot).set_state(SlotState::Failed);
        self.stats.failed_requests.fetch_add(1, Ordering::Relaxed);
    }

    fn next_seed(&mut self) -> u32 {
        self.seed_ctr = self.seed_ctr.wrapping_mul(747796405).wrapping_add(2891336453);
        self.seed_ctr
    }

    /// Fill `draft_scratch` with k self-drafted tokens per live lane,
    /// row-major `[lane][k]`. The drafter runs the modeled executor's
    /// greedy chain ([`greedy_chain_token`]) forward from each lane's
    /// pending token, deliberately corrupting each position with
    /// probability `1 − spec_accept` via a deterministic position hash.
    /// On the modeled executor in chain mode this makes acceptance a
    /// tunable knob with correctness untouched — emitted tokens are
    /// always the verify graph's own outputs, drafts only gate how many
    /// of them retire per launch; on real artifacts mismatched drafts
    /// simply degrade throughput toward plain decode. After a corrupted
    /// position the chain continues from the corrupted token, so one
    /// miss poisons the rest of the window — matching how a real
    /// draft-model divergence truncates the accepted prefix.
    // lint: no_alloc no_panic # scratch preallocated to max_batch × spec_k
    fn draft_lanes(&mut self, k: usize) {
        let vocab = (self.manifest.vocab_size as u32).max(1);
        self.draft_scratch.clear();
        for lane in &self.lanes {
            let mut prev = lane.last_token as u32;
            let mut pos = lane.cache.cached_len as u64;
            for _ in 0..k {
                let mut d = greedy_chain_token(vocab, prev, pos);
                if corrupt_unit(prev, pos) >= self.spec_accept {
                    d = (d + 1) % vocab;
                }
                self.draft_scratch.push(d as i32);
                prev = d;
                pos += 1;
            }
        }
    }
}

/// Deterministic unit-interval hash behind the drafter's acceptance
/// knob: a draft position is corrupted exactly when this value lands at
/// or above `spec_accept`, so acceptance converges to the configured
/// rate while staying reproducible run-to-run. A distinct stream
/// constant decouples it from `greedy_chain_token`'s mix, so *which*
/// positions get corrupted is independent of the chain values.
// lint: no_alloc no_panic
fn corrupt_unit(prev: u32, pos: u64) -> f64 {
    let mut x = ((prev as u64) << 32) ^ pos ^ 0xD6E8_FEB8_6659_FD93;
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x >> 11) as f64 / (1u64 << 53) as f64
}

/// Furthest K/V position any chunk launch writes when prefilling the
/// `prompt_len − cached` suffix in chunks of `chunk` tokens: non-final
/// chunks cover exactly `chunk` tokens (a block multiple by the budget
/// clamp, so every later chunk starts block-aligned — the offset form
/// the `prefill_offset` graphs take); each chunk pads to its grid, and
/// padded writes land past the chunk like padded full prefills do, so
/// the reservation must cover this bound, not just the prompt.
fn chunk_write_end(cache: &GraphCache, cached: usize, prompt_len: usize, chunk: usize) -> usize {
    debug_assert!(chunk > 0 && prompt_len > cached);
    let mut end = prompt_len;
    let mut off = cached;
    while off < prompt_len {
        let len = (prompt_len - off).min(chunk);
        let padded = if off == 0 {
            padded_seq(cache, len)
        } else {
            // The budget is clamped to the offset grid's largest seq,
            // so every offset chunk fits; `unwrap_or` only guards
            // hand-built caches mutated after the clamp.
            cache.padded_offset_seq(len).unwrap_or(len)
        };
        end = end.max(off + padded);
        off += len;
    }
    end
}

/// Smallest grid sequence length >= prompt_len.
fn padded_seq(cache: &GraphCache, prompt_len: usize) -> usize {
    let mut best = usize::MAX;
    for s in cache.specs() {
        if s.kind == GraphKind::Prefill && s.seq >= prompt_len && s.seq < best {
            best = s.seq;
        }
    }
    if best == usize::MAX {
        prompt_len
    } else {
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_cache() -> GraphCache {
        cache_from_manifest(
            &ModelManifest::parse(
                "blink-manifest v1\nmodel t\nvocab_size 8\nd_model 4\nn_layers 1\nn_heads 1\n\
                 n_kv_heads 1\nd_head 4\nd_ff 8\nblock_size 16\nnum_blocks 8\n\
                 max_blocks_per_seq 4\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
                 param p 4 f32\ngraph decode_b1 decode 1 0\ngraph prefill_b1_s16 prefill 1 16\n\
                 graph prefill_b1_s32 prefill 1 32\ngraph prefill_b2_s64 prefill 2 64\n\
                 graph prefill_offset_b1_s16 prefill_offset 1 16\n",
            )
            .unwrap(),
        )
    }

    #[test]
    fn padded_seq_picks_grid() {
        let c = toy_cache();
        assert_eq!(padded_seq(&c, 10), 16);
        assert_eq!(padded_seq(&c, 16), 16);
        assert_eq!(padded_seq(&c, 17), 32);
        assert_eq!(padded_seq(&c, 40), 64);
    }

    #[test]
    fn default_config_is_paper_fcfs() {
        assert_eq!(SchedulerConfig::default().policy, PolicyKind::Fcfs);
        assert_eq!(SchedulerConfig::default().prefix_reuse, PrefixReuse::Auto);
        assert_eq!(
            SchedulerConfig::default().prefill_chunk_tokens,
            None,
            "default budget resolves from the offset grid at spawn"
        );
    }

    /// The chunk plan's write bound: block-aligned chunk starts, padded
    /// final chunk, and the reservation covering the furthest padded
    /// write of *any* chunk.
    #[test]
    fn chunk_write_end_covers_padded_chunks() {
        let c = toy_cache(); // full grid {16,32,64}, offset grid {16}
        // 40 tokens in 16-token chunks: [0,16) (full graph, padded 16),
        // [16,32) (offset, padded 16), [32,40) (offset, padded 16 →
        // writes through 48).
        assert_eq!(chunk_write_end(&c, 0, 40, 16), 48);
        // Exactly block-aligned prompt: no padding overhang.
        assert_eq!(chunk_write_end(&c, 0, 32, 16), 32);
        // Cached prefix: chunks start at the block-aligned hit.
        assert_eq!(chunk_write_end(&c, 16, 40, 16), 48);
        // The bound never undershoots the prompt itself.
        assert!(chunk_write_end(&c, 0, 33, 16) >= 33);
    }

    #[test]
    fn manifest_offset_graphs_parsed_into_cache() {
        let c = toy_cache();
        assert!(c.has_offset_graphs());
        assert_eq!(c.padded_offset_seq(9), Some(16));
        assert_eq!(c.padded_offset_seq(17), None, "off the partial offset grid");
    }

    /// Satellite: a hit whose suffix is off the offset grid is demoted
    /// to a cold full prefill *before* reserving anything — the cold
    /// admission charges exactly the cold block count (no leaked
    /// refcounts, no shared blocks), and a release restores the pool.
    #[test]
    fn offgrid_suffix_falls_back_cold_without_double_charge() {
        use crate::kvcache::{KvConfig, KvManager, PrefixMatch};
        let cache = toy_cache(); // offset grid covers suffixes ≤ 16 only
        let mut kv = KvManager::new(KvConfig {
            block_size: 16,
            num_blocks: 32,
            max_blocks_per_seq: 8,
        });
        // Turn 1: a 40-token prompt indexes its 2 full blocks (32 tokens).
        let prefix: Vec<u32> = (0..40).collect();
        let a = kv.admit_reuse(&prefix, 64, 4).unwrap();
        kv.index_prompt(&a, &prefix);
        kv.release(a);
        let baseline = kv.free_blocks() + kv.evictable_blocks();

        // Turn 2: 64-token prompt hitting 32 cached tokens → suffix 32,
        // which the offset grid does NOT cover. The admission sequence
        // (mirroring SchedulerCore::admit_and_prefill's reuse branch):
        let prompt: Vec<u32> = (0..64).collect();
        let mut m = kv.match_prefix(&prompt);
        assert_eq!(m.tokens, 32, "the index does hit");
        if cache.padded_offset_seq(prompt.len() - m.tokens).is_none() {
            m = PrefixMatch::default(); // demote before reserving
        }
        assert_eq!(m.tokens, 0, "suffix 32 > offset grid max 16 → cold");
        let padded = padded_seq(&cache, prompt.len());
        assert!(kv.can_admit_reuse(&m, padded, prompt.len(), 4));
        let c = kv.admit_matched(&m, prompt.len(), padded, 4).unwrap();
        assert_eq!(c.prefix_len, 0, "no reuse reserved on the fallback path");
        // Cold cost: span = max(64, 64+4) = 68 → 5 fresh blocks, none
        // shared with the parked prefix (which stays parked).
        assert_eq!(c.blocks.len(), 5);
        assert_eq!(baseline - (kv.free_blocks() + kv.evictable_blocks()), 5);
        kv.release(c);
        assert_eq!(kv.free_blocks() + kv.evictable_blocks(), baseline, "no double-charge");
        kv.check_invariants();

        // A short second turn (suffix ≤ 16) does use the offset path.
        let short: Vec<u32> = (0..48).collect();
        let m2 = kv.match_prefix(&short);
        assert_eq!(m2.tokens, 32);
        assert_eq!(cache.padded_offset_seq(short.len() - m2.tokens), Some(16));
    }
}
