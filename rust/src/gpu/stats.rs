//! Scheduler telemetry, shared as atomics so the host plane can *observe*
//! the device plane without participating in it.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct SchedulerStats {
    pub decode_steps: AtomicU64,
    pub prefill_batches: AtomicU64,
    pub prefilled_requests: AtomicU64,
    pub completed_requests: AtomicU64,
    pub failed_requests: AtomicU64,
    pub tokens_generated: AtomicU64,
    /// Sum of live-lane counts over decode steps (occupancy = sum/steps).
    pub batch_occupancy_sum: AtomicU64,
    /// Continuous-batching pauses taken for inline prefill.
    pub pauses: AtomicU64,
    /// Ring-scan latency accounting, nanoseconds.
    pub scan_count: AtomicU64,
    pub scan_ns_sum: AtomicU64,
    pub scan_ns_max: AtomicU64,
    /// Launch-window telemetry mirrored out of the scheduler loop.
    pub fnf_launches: AtomicU64,
    pub tail_relaunches: AtomicU64,
    /// Admission backpressure events (no KV blocks / no batch slot).
    pub backpressure_events: AtomicU64,
    /// Admissions whose ticket was lower than an earlier admission's —
    /// zero under FCFS, positive when a policy reorders the queue.
    pub admitted_out_of_order: AtomicU64,
    /// Requests whose first token was published after their TTFT
    /// deadline (only counted for requests that carry a deadline).
    pub ttft_deadline_misses: AtomicU64,
    /// Prefix-reuse telemetry (mirrors `kvcache::KvStats`): admissions
    /// that reused at least one cached block, prompt tokens served from
    /// the prefix index, and parked blocks reclaimed under pool pressure.
    pub prefix_hits: AtomicU64,
    pub prefix_hit_tokens: AtomicU64,
    pub prefix_evicted_blocks: AtomicU64,
    /// Blocks currently shared or parked in the prefix index (gauge).
    pub prefix_indexed_blocks: AtomicU64,
    /// Offset-prefill graph launches (suffix-only prefills of live
    /// prefix-cache hits) — the counter `eval prefix-live` and
    /// `/metrics` report.
    pub prefill_offset_batches: AtomicU64,
    /// Prefix hits demoted to a full cold prefill because their suffix
    /// fit no offset graph (partial or absent offset grid).
    pub prefix_fallback_full: AtomicU64,
    /// Admissions carrying a session tag (multi-turn traffic) — read off
    /// the slot's RDMA-written `session_id` by the GPU plane, so
    /// `/metrics` distinguishes conversation turns from one-shot load.
    pub session_requests: AtomicU64,
    /// Chunked-prefill telemetry (DESIGN.md §5): admissions whose
    /// uncached suffix exceeded the per-iteration budget and entered
    /// the chunked state machine, ...
    pub chunked_prefills: AtomicU64,
    /// ... individual chunk launches (one per lane per chunk, the final
    /// chunk included), ...
    pub chunk_launches: AtomicU64,
    /// ... and the worst backlog a chunked lane saw: the maximum number
    /// of consecutive scheduler iterations a lane spent waiting for the
    /// per-iteration token budget to reach it.
    pub max_chunk_wait_iters: AtomicU64,
}

impl SchedulerStats {
    pub fn record_scan(&self, ns: u64) {
        self.scan_count.fetch_add(1, Ordering::Relaxed);
        self.scan_ns_sum.fetch_add(ns, Ordering::Relaxed);
        self.scan_ns_max.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn mean_scan_us(&self) -> f64 {
        let n = self.scan_count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.scan_ns_sum.load(Ordering::Relaxed) as f64 / n as f64 / 1000.0
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        let n = self.decode_steps.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "decode_steps={} prefills={} offset_prefills={} completed={} failed={} tokens={} \
             occupancy={:.2} pauses={} scan_mean={:.2}µs scan_max={:.2}µs fnf={} tail={} \
             backpressure={} reordered={} ttft_misses={} prefix_hits={} prefix_hit_tokens={} \
             prefix_fallback_full={} prefix_evicted={} prefix_indexed={} session_requests={} \
             chunked_prefills={} chunk_launches={} max_chunk_wait_iters={}",
            self.decode_steps.load(Ordering::Relaxed),
            self.prefill_batches.load(Ordering::Relaxed),
            self.prefill_offset_batches.load(Ordering::Relaxed),
            self.completed_requests.load(Ordering::Relaxed),
            self.failed_requests.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.pauses.load(Ordering::Relaxed),
            self.mean_scan_us(),
            self.scan_ns_max.load(Ordering::Relaxed) as f64 / 1000.0,
            self.fnf_launches.load(Ordering::Relaxed),
            self.tail_relaunches.load(Ordering::Relaxed),
            self.backpressure_events.load(Ordering::Relaxed),
            self.admitted_out_of_order.load(Ordering::Relaxed),
            self.ttft_deadline_misses.load(Ordering::Relaxed),
            self.prefix_hits.load(Ordering::Relaxed),
            self.prefix_hit_tokens.load(Ordering::Relaxed),
            self.prefix_fallback_full.load(Ordering::Relaxed),
            self.prefix_evicted_blocks.load(Ordering::Relaxed),
            self.prefix_indexed_blocks.load(Ordering::Relaxed),
            self.session_requests.load(Ordering::Relaxed),
            self.chunked_prefills.load(Ordering::Relaxed),
            self.chunk_launches.load(Ordering::Relaxed),
            self.max_chunk_wait_iters.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_accounting() {
        let s = SchedulerStats::default();
        s.record_scan(1000);
        s.record_scan(3000);
        assert!((s.mean_scan_us() - 2.0).abs() < 1e-9);
        assert_eq!(s.scan_ns_max.load(Ordering::Relaxed), 3000);
    }

    #[test]
    fn occupancy_mean() {
        let s = SchedulerStats::default();
        s.decode_steps.store(4, Ordering::Relaxed);
        s.batch_occupancy_sum.store(10, Ordering::Relaxed);
        assert!((s.mean_batch_occupancy() - 2.5).abs() < 1e-9);
    }
}
