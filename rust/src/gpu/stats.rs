//! Scheduler telemetry, shared as atomics so the host plane can *observe*
//! the device plane without participating in it.

use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free log₂-bucketed latency histogram (nanosecond samples). 40
/// buckets cover 1 ns … ~18 min; recording is one `fetch_add`, so the
/// control loop can histogram itself without allocating or locking, and
/// readers compute percentiles from a relaxed snapshot. Percentiles are
/// bucket-resolution (≤ 2× error — the geometric bucket midpoint is
/// reported), which is exactly enough to tell a 5 µs control path from a
/// 50 µs one.
#[derive(Debug)]
pub struct LatencyHistogram {
    // lint: atomic(buckets) counter
    buckets: [AtomicU64; HIST_BUCKETS],
}

/// Bucket count: ⌊log₂ ns⌋ buckets covering 1 ns … 2⁴⁰ ns (~18 min).
const HIST_BUCKETS: usize = 40;

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl LatencyHistogram {
    /// Record one sample. Bucket = ⌊log₂ ns⌋, clamped to the top bucket.
    // lint: no_alloc no_panic
    pub fn record_ns(&self, ns: u64) {
        let idx = (ns.max(1).ilog2() as usize).min(HIST_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// The `p`-th percentile in microseconds (0.0 when empty), reported
    /// as the geometric midpoint of the bucket holding that rank.
    pub fn percentile_us(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                // Geometric midpoint of [2^i, 2^(i+1)) ns, in µs.
                return 1.5 * (1u64 << i) as f64 / 1000.0;
            }
        }
        1.5 * (1u64 << (HIST_BUCKETS - 1)) as f64 / 1000.0
    }
}

/// Fixed-capacity ring of raw nanosecond samples for *exact* percentiles.
///
/// The log₂ histogram above quantizes to powers of two, which is fine for
/// telling 5 µs from 50 µs but useless for asserting a "<1.5×" inflation
/// ratio: adjacent buckets are already 2× apart. The interference eval
/// pins its headline ratios on exact samples instead. Recording is two
/// relaxed atomic ops (cursor `fetch_add` + slot `store`) — no locks, no
/// allocation, so the zero-alloc control loop (rust/tests/hotloop_alloc.rs)
/// can record into it every iteration. Once the ring wraps, the oldest
/// samples are overwritten; percentile readers snapshot, sort, and
/// interpolate on their own (cold-path) heap.
#[derive(Debug)]
pub struct SampleRing {
    // lint: atomic(slots) plane # sample cells; a reader that races a wrap
    // sees either the old or the new sample, both of which were real.
    slots: Box<[AtomicU64]>,
    // lint: atomic(cursor) counter
    cursor: AtomicU64,
}

/// Default ring capacity — comfortably above the longest eval cell
/// (~thousands of decode iterations) so percentiles see the full run.
const SAMPLE_RING_CAP: usize = 8192;

impl Default for SampleRing {
    fn default() -> Self {
        SampleRing::with_capacity(SAMPLE_RING_CAP)
    }
}

impl SampleRing {
    /// `capacity` is rounded up to at least 1.
    pub fn with_capacity(capacity: usize) -> Self {
        let cap = capacity.max(1);
        SampleRing {
            slots: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            cursor: AtomicU64::new(0),
        }
    }

    /// Record one raw sample (alloc-free; hot-path safe). Samples are
    /// stored as `ns + 1` so an unwritten slot (0) is distinguishable.
    // lint: no_alloc no_panic
    pub fn record_ns(&self, ns: u64) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) as usize % self.slots.len();
        self.slots[i].store(ns.saturating_add(1), Ordering::Relaxed);
    }

    /// Samples recorded since construction (not capped at capacity).
    pub fn count(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Snapshot the retained samples in µs (unordered). Allocates —
    /// reader-side only, never called from the control loop.
    pub fn snapshot_us(&self) -> Vec<f64> {
        self.slots
            .iter()
            .map(|s| s.load(Ordering::Relaxed))
            .filter(|&v| v > 0)
            .map(|v| (v - 1) as f64 / 1000.0)
            .collect()
    }

    /// Exact `p`-th percentile in µs over the retained window (0.0 when
    /// empty), linearly interpolated between ranks.
    pub fn percentile_us(&self, p: f64) -> f64 {
        let mut v = self.snapshot_us();
        if v.is_empty() {
            return 0.0;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crate::util::stats::percentile_sorted(&v, p)
    }
}

/// All counter fields below share the `counter` contract: relaxed
/// increments on the device plane, relaxed reads from `/metrics` — the
/// observability path never needs to order against the data it describes.
#[derive(Debug, Default)]
pub struct SchedulerStats {
    // lint: atomic(decode_steps) counter
    pub decode_steps: AtomicU64,
    // lint: atomic(prefill_batches) counter
    pub prefill_batches: AtomicU64,
    // lint: atomic(prefilled_requests) counter
    pub prefilled_requests: AtomicU64,
    // lint: atomic(completed_requests) counter
    pub completed_requests: AtomicU64,
    // lint: atomic(failed_requests) counter
    pub failed_requests: AtomicU64,
    // lint: atomic(tokens_generated) counter
    pub tokens_generated: AtomicU64,
    /// Sum of live-lane counts over decode steps (occupancy = sum/steps).
    // lint: atomic(batch_occupancy_sum) counter
    pub batch_occupancy_sum: AtomicU64,
    /// Continuous-batching pauses taken for inline prefill.
    // lint: atomic(pauses) counter
    pub pauses: AtomicU64,
    /// Ring-scan latency accounting, nanoseconds.
    // lint: atomic(scan_count) counter
    pub scan_count: AtomicU64,
    // lint: atomic(scan_ns_sum) counter
    pub scan_ns_sum: AtomicU64,
    // lint: atomic(scan_ns_max) counter
    pub scan_ns_max: AtomicU64,
    /// Launch-window telemetry mirrored out of the scheduler loop.
    // lint: atomic(fnf_launches) counter
    pub fnf_launches: AtomicU64,
    // lint: atomic(tail_relaunches) counter
    pub tail_relaunches: AtomicU64,
    /// Admission backpressure events (no KV blocks / no batch slot).
    // lint: atomic(backpressure_events) counter
    pub backpressure_events: AtomicU64,
    /// Admissions whose ticket was lower than an earlier admission's —
    /// zero under FCFS, positive when a policy reorders the queue.
    // lint: atomic(admitted_out_of_order) counter
    pub admitted_out_of_order: AtomicU64,
    /// Requests whose first token was published after their TTFT
    /// deadline (only counted for requests that carry a deadline).
    // lint: atomic(ttft_deadline_misses) counter
    pub ttft_deadline_misses: AtomicU64,
    /// Prefix-reuse telemetry (mirrors `kvcache::KvStats`): admissions
    /// that reused at least one cached block, prompt tokens served from
    /// the prefix index, and parked blocks reclaimed under pool pressure.
    // lint: atomic(prefix_hits) counter
    pub prefix_hits: AtomicU64,
    // lint: atomic(prefix_hit_tokens) counter
    pub prefix_hit_tokens: AtomicU64,
    // lint: atomic(prefix_evicted_blocks) counter
    pub prefix_evicted_blocks: AtomicU64,
    /// Blocks currently shared or parked in the prefix index (gauge).
    // lint: atomic(prefix_indexed_blocks) counter
    pub prefix_indexed_blocks: AtomicU64,
    /// Offset-prefill graph launches (suffix-only prefills of live
    /// prefix-cache hits) — the counter `eval prefix-live` and
    /// `/metrics` report.
    // lint: atomic(prefill_offset_batches) counter
    pub prefill_offset_batches: AtomicU64,
    /// Prefix hits demoted to a full cold prefill because their suffix
    /// fit no offset graph (partial or absent offset grid).
    // lint: atomic(prefix_fallback_full) counter
    pub prefix_fallback_full: AtomicU64,
    /// Admissions carrying a session tag (multi-turn traffic) — read off
    /// the slot's RDMA-written `session_id` by the GPU plane, so
    /// `/metrics` distinguishes conversation turns from one-shot load.
    // lint: atomic(session_requests) counter
    pub session_requests: AtomicU64,
    /// Chunked-prefill telemetry (DESIGN.md §5): admissions whose
    /// uncached suffix exceeded the per-iteration budget and entered
    /// the chunked state machine, ...
    // lint: atomic(chunked_prefills) counter
    pub chunked_prefills: AtomicU64,
    /// ... individual chunk launches (one per lane per chunk, the final
    /// chunk included), ...
    // lint: atomic(chunk_launches) counter
    pub chunk_launches: AtomicU64,
    /// ... and the worst backlog a chunked lane saw: the maximum number
    /// of consecutive scheduler iterations a lane spent waiting for the
    /// per-iteration token budget to reach it.
    // lint: atomic(max_chunk_wait_iters) counter
    pub max_chunk_wait_iters: AtomicU64,
    /// Per-iteration control overhead (loop top → decode-launch enqueue,
    /// ns): ring scan, chunk servicing, policy work, arena staging and
    /// the launch call itself — everything the host-heap orchestration
    /// of a CPU-resident stack would inflate, measured instead of
    /// asserted. Iterations that never reach a decode launch (pure
    /// admission or idle spins) are not recorded; admission work that
    /// *precedes* a decode launch lands in that iteration's sample,
    /// which is what makes the p99 show control-path interference.
    pub loop_iter: LatencyHistogram,
    /// Full decode-iteration latency (loop top → tokens retired, ns) as
    /// raw samples: control overhead *plus* the executor step. Where
    /// `loop_iter` is a coarse log₂ histogram of control overhead alone,
    /// this ring keeps exact samples so the interference eval can assert
    /// tight inflation ratios (a host-driven loop under contention must
    /// inflate ≥3× while the device-plane loop holds <1.5× — bucket
    /// resolution can't express 1.5×).
    pub iter_full: SampleRing,
    /// Decode-batch membership changes (lane admitted, retired, or torn
    /// down on launch failure) — each one forces a full arena resync of
    /// the decode region instead of the in-place incremental update, so
    /// this counter is also "full block-table rewrites per run".
    // lint: atomic(batch_membership_changes) counter
    pub batch_membership_changes: AtomicU64,
    /// Which attention implementation the loaded artifacts were lowered
    /// against ("pallas" / "ref" / "mixed" / "modeled"), set once from
    /// the manifest when the scheduler starts. A label, not a counter —
    /// OnceLock keeps the struct lock-free for the hot-path writers.
    pub attention_backend: std::sync::OnceLock<String>,
    /// Ring-scan backlog observed at the top of the last admission pass
    /// (gauge): candidates waiting in submitted slots. One relaxed store
    /// per loop iteration — alloc-free, hot-path safe.
    // lint: atomic(queue_depth) counter
    pub queue_depth: AtomicU64,
    /// High-water mark of [`SchedulerStats::queue_depth`] over the run.
    // lint: atomic(queue_depth_peak) counter
    pub queue_depth_peak: AtomicU64,
    /// Overload-gate decisions (DESIGN.md §9), mirrored out of the DPU
    /// frontend via [`SchedulerStats::mirror_gate_decision`]: admissions
    /// that passed the gate, rejections by the global sliding window,
    /// rejections by a per-tenant token bucket, best-effort work shed by
    /// degradation (admitted with `max_new` capped), and best-effort
    /// work shed by dropping.
    // lint: atomic(overload_admitted) counter
    pub overload_admitted: AtomicU64,
    // lint: atomic(rate_limited) counter
    pub rate_limited: AtomicU64,
    // lint: atomic(tenant_limited) counter
    pub tenant_limited: AtomicU64,
    // lint: atomic(shed_degraded) counter
    pub shed_degraded: AtomicU64,
    // lint: atomic(shed_dropped) counter
    pub shed_dropped: AtomicU64,
    /// Speculative-decoding telemetry (DESIGN.md §11): draft tokens
    /// offered to `decode_verify` launches, ...
    // lint: atomic(spec_drafted) counter
    pub spec_drafted: AtomicU64,
    /// ... drafts accepted by the longest-prefix rule (the bonus token
    /// each verify emits is *not* counted here — `accepted / drafted`
    /// is the raw acceptance rate), ...
    // lint: atomic(spec_accepted) counter
    pub spec_accepted: AtomicU64,
    /// ... and per-verify accepted-draft counts as exact samples for
    /// the `accepted_per_verify` P50/P99 the eval table reports.
    /// Samples are stored ×1000 (a count recorded as "microseconds")
    /// so the ring's µs-scaled readers report whole accepted counts.
    pub accepted_per_verify: SampleRing,
}

impl SchedulerStats {
    /// Mirror one admission-gate decision (called by the DPU frontend on
    /// every gated submission) so overload counters surface next to the
    /// scheduler's own numbers in `summary()` and `/metrics`.
    // lint: no_alloc no_panic
    pub fn mirror_gate_decision(&self, d: &crate::frontend::overload::Decision) {
        use crate::frontend::overload::{Decision, RejectKind};
        match d {
            Decision::Admit => {
                self.overload_admitted.fetch_add(1, Ordering::Relaxed);
            }
            Decision::Degrade { .. } => {
                self.overload_admitted.fetch_add(1, Ordering::Relaxed);
                self.shed_degraded.fetch_add(1, Ordering::Relaxed);
            }
            Decision::Reject { kind, .. } => {
                match kind {
                    RejectKind::Window => &self.rate_limited,
                    RejectKind::Bucket => &self.tenant_limited,
                    RejectKind::Shed => &self.shed_dropped,
                }
                .fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Update the queue-depth gauge and its high-water mark (one relaxed
    /// store + fetch_max; hot-path safe).
    // lint: no_alloc no_panic
    pub fn record_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_depth_peak.fetch_max(depth, Ordering::Relaxed);
    }

    // lint: no_alloc no_panic
    pub fn record_scan(&self, ns: u64) {
        self.scan_count.fetch_add(1, Ordering::Relaxed);
        self.scan_ns_sum.fetch_add(ns, Ordering::Relaxed);
        self.scan_ns_max.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn mean_scan_us(&self) -> f64 {
        let n = self.scan_count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.scan_ns_sum.load(Ordering::Relaxed) as f64 / n as f64 / 1000.0
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        let n = self.decode_steps.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Control-overhead percentiles in µs (see [`SchedulerStats::loop_iter`]).
    pub fn loop_iter_p50_us(&self) -> f64 {
        self.loop_iter.percentile_us(50.0)
    }

    pub fn loop_iter_p99_us(&self) -> f64 {
        self.loop_iter.percentile_us(99.0)
    }

    /// Exact full-iteration percentiles in µs (see [`SchedulerStats::iter_full`]).
    pub fn iter_full_p50_us(&self) -> f64 {
        self.iter_full.percentile_us(50.0)
    }

    pub fn iter_full_p99_us(&self) -> f64 {
        self.iter_full.percentile_us(99.0)
    }

    /// Accepted-drafts-per-verify percentiles — *counts*, not times:
    /// samples go into the ring ×1000, so the µs conversion cancels and
    /// these read back as draft-token counts in `0.0..=k`.
    pub fn accepted_per_verify_p50(&self) -> f64 {
        self.accepted_per_verify.percentile_us(50.0)
    }

    pub fn accepted_per_verify_p99(&self) -> f64 {
        self.accepted_per_verify.percentile_us(99.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "decode_steps={} prefills={} offset_prefills={} completed={} failed={} tokens={} \
             occupancy={:.2} pauses={} scan_mean={:.2}µs scan_max={:.2}µs fnf={} tail={} \
             backpressure={} reordered={} ttft_misses={} prefix_hits={} prefix_hit_tokens={} \
             prefix_fallback_full={} prefix_evicted={} prefix_indexed={} session_requests={} \
             chunked_prefills={} chunk_launches={} max_chunk_wait_iters={} \
             loop_iter_p50_us={:.2} loop_iter_p99_us={:.2} iter_full_p50_us={:.2} \
             iter_full_p99_us={:.2} batch_membership_changes={} \
             heap_allocs={} attention_backend={} queue_depth={} queue_depth_peak={} \
             overload_admitted={} rate_limited={} tenant_limited={} shed_degraded={} \
             shed_dropped={} spec_drafted={} spec_accepted={} accepted_per_verify_p50={:.2} \
             accepted_per_verify_p99={:.2}",
            self.decode_steps.load(Ordering::Relaxed),
            self.prefill_batches.load(Ordering::Relaxed),
            self.prefill_offset_batches.load(Ordering::Relaxed),
            self.completed_requests.load(Ordering::Relaxed),
            self.failed_requests.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.pauses.load(Ordering::Relaxed),
            self.mean_scan_us(),
            self.scan_ns_max.load(Ordering::Relaxed) as f64 / 1000.0,
            self.fnf_launches.load(Ordering::Relaxed),
            self.tail_relaunches.load(Ordering::Relaxed),
            self.backpressure_events.load(Ordering::Relaxed),
            self.admitted_out_of_order.load(Ordering::Relaxed),
            self.ttft_deadline_misses.load(Ordering::Relaxed),
            self.prefix_hits.load(Ordering::Relaxed),
            self.prefix_hit_tokens.load(Ordering::Relaxed),
            self.prefix_fallback_full.load(Ordering::Relaxed),
            self.prefix_evicted_blocks.load(Ordering::Relaxed),
            self.prefix_indexed_blocks.load(Ordering::Relaxed),
            self.session_requests.load(Ordering::Relaxed),
            self.chunked_prefills.load(Ordering::Relaxed),
            self.chunk_launches.load(Ordering::Relaxed),
            self.max_chunk_wait_iters.load(Ordering::Relaxed),
            self.loop_iter_p50_us(),
            self.loop_iter_p99_us(),
            self.iter_full_p50_us(),
            self.iter_full_p99_us(),
            self.batch_membership_changes.load(Ordering::Relaxed),
            // 0 unless a test binary installed the counting allocator
            // (util::alloc) — surfaced so the zero-alloc property is a
            // number /metrics readers can watch, not just a test.
            crate::util::alloc::alloc_count(),
            self.attention_backend.get().map(|s| s.as_str()).unwrap_or("unspecified"),
            self.queue_depth.load(Ordering::Relaxed),
            self.queue_depth_peak.load(Ordering::Relaxed),
            self.overload_admitted.load(Ordering::Relaxed),
            self.rate_limited.load(Ordering::Relaxed),
            self.tenant_limited.load(Ordering::Relaxed),
            self.shed_degraded.load(Ordering::Relaxed),
            self.shed_dropped.load(Ordering::Relaxed),
            self.spec_drafted.load(Ordering::Relaxed),
            self.spec_accepted.load(Ordering::Relaxed),
            self.accepted_per_verify_p50(),
            self.accepted_per_verify_p99(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_accounting() {
        let s = SchedulerStats::default();
        s.record_scan(1000);
        s.record_scan(3000);
        assert!((s.mean_scan_us() - 2.0).abs() < 1e-9);
        assert_eq!(s.scan_ns_max.load(Ordering::Relaxed), 3000);
    }

    #[test]
    fn occupancy_mean() {
        let s = SchedulerStats::default();
        s.decode_steps.store(4, Ordering::Relaxed);
        s.batch_occupancy_sum.store(10, Ordering::Relaxed);
        assert!((s.mean_batch_occupancy() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles_hit_bucket_midpoints() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_us(50.0), 0.0, "empty histogram reads 0");
        // 99 samples at ~2 µs (bucket [2048, 4096) ns), 1 at ~1 ms.
        for _ in 0..99 {
            h.record_ns(3_000);
        }
        h.record_ns(1_000_000);
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_us(50.0);
        assert!((p50 - 3.072).abs() < 1e-9, "p50 = 1.5 * 2048 ns = {p50}");
        assert!((h.percentile_us(99.0) - 3.072).abs() < 1e-9, "p99 still in the 2 µs bucket");
        let p100 = h.percentile_us(100.0);
        assert!(p100 > 500.0, "the millisecond outlier owns the top rank: {p100}");
    }

    #[test]
    fn histogram_clamps_extremes() {
        let h = LatencyHistogram::default();
        h.record_ns(0); // clamps to bucket 0
        h.record_ns(u64::MAX); // clamps to the top bucket
        assert_eq!(h.count(), 2);
        assert!(h.percentile_us(100.0) > 0.0);
    }

    #[test]
    fn summary_carries_loop_iter_fields() {
        let s = SchedulerStats::default();
        s.loop_iter.record_ns(2_000);
        s.batch_membership_changes.store(3, Ordering::Relaxed);
        let sum = s.summary();
        assert!(sum.contains("loop_iter_p50_us="), "{sum}");
        assert!(sum.contains("batch_membership_changes=3"), "{sum}");
        assert!(sum.contains("heap_allocs="), "{sum}");
        assert!(sum.contains("attention_backend=unspecified"), "{sum}");
    }

    #[test]
    fn sample_ring_exact_percentiles() {
        let r = SampleRing::with_capacity(128);
        assert_eq!(r.percentile_us(99.0), 0.0, "empty ring reads 0");
        // 100 samples spanning 1..=100 µs: exact percentiles, not bucket
        // midpoints — p50 must land near 50 µs, not at a power of two.
        for us in 1..=100u64 {
            r.record_ns(us * 1000);
        }
        assert_eq!(r.count(), 100);
        let p50 = r.percentile_us(50.0);
        assert!((p50 - 50.5).abs() < 1.0, "p50 ≈ 50 µs, got {p50}");
        let p99 = r.percentile_us(99.0);
        assert!((p99 - 99.0).abs() < 1.5, "p99 ≈ 99 µs, got {p99}");
    }

    #[test]
    fn sample_ring_wraps_keeping_newest() {
        let r = SampleRing::with_capacity(4);
        for us in [1u64, 2, 3, 4, 100, 200, 300, 400] {
            r.record_ns(us * 1000);
        }
        assert_eq!(r.count(), 8);
        let snap = r.snapshot_us();
        assert_eq!(snap.len(), 4, "capacity bounds retention");
        assert!(snap.iter().all(|&v| v >= 100.0), "old samples overwritten: {snap:?}");
    }

    #[test]
    fn gate_decisions_mirror_into_overload_counters() {
        use crate::frontend::overload::{Decision, RejectKind};
        let s = SchedulerStats::default();
        s.mirror_gate_decision(&Decision::Admit);
        s.mirror_gate_decision(&Decision::Degrade { max_new_cap: 4 });
        for kind in [RejectKind::Window, RejectKind::Bucket, RejectKind::Shed] {
            s.mirror_gate_decision(&Decision::Reject {
                kind,
                reason: "x",
                retry_after_ms: 1,
            });
        }
        s.record_queue_depth(7);
        s.record_queue_depth(3);
        let sum = s.summary();
        assert!(sum.contains("overload_admitted=2"), "{sum}");
        assert!(sum.contains("rate_limited=1"), "{sum}");
        assert!(sum.contains("tenant_limited=1"), "{sum}");
        assert!(sum.contains("shed_degraded=1"), "{sum}");
        assert!(sum.contains("shed_dropped=1"), "{sum}");
        assert!(sum.contains("queue_depth=3"), "{sum}");
        assert!(sum.contains("queue_depth_peak=7"), "{sum}");
    }

    #[test]
    fn spec_counters_surface_as_counts_not_times() {
        let s = SchedulerStats::default();
        s.spec_drafted.store(40, Ordering::Relaxed);
        s.spec_accepted.store(30, Ordering::Relaxed);
        // Ten verifies accepting 3 drafts each, stored ×1000 so the
        // ring's µs readers return the raw count.
        for _ in 0..10 {
            s.accepted_per_verify.record_ns(3 * 1000);
        }
        assert!((s.accepted_per_verify_p50() - 3.0).abs() < 1e-9);
        assert!((s.accepted_per_verify_p99() - 3.0).abs() < 1e-9);
        let sum = s.summary();
        assert!(sum.contains("spec_drafted=40"), "{sum}");
        assert!(sum.contains("spec_accepted=30"), "{sum}");
        assert!(sum.contains("accepted_per_verify_p50=3.00"), "{sum}");
        assert!(sum.contains("accepted_per_verify_p99=3.00"), "{sum}");
    }

    #[test]
    fn summary_reports_attention_backend_once_set() {
        let s = SchedulerStats::default();
        s.attention_backend.set("pallas".to_string()).unwrap();
        assert!(s.summary().contains("attention_backend=pallas"));
        // Second set loses (OnceLock) — the label stays what the
        // scheduler stamped at startup.
        assert!(s.attention_backend.set("ref".to_string()).is_err());
        assert!(s.summary().contains("attention_backend=pallas"));
    }
}
