//! The graph cache (paper §4.2 "CUDA graph cache"): a dense grid of
//! pre-compiled (batch, sequence-length) executables with O(1)
//! tightest-fit selection via a precomputed lookup table, plus a
//! maximum-shape fallback for anything off-grid.
//!
//! This module is pure metadata — `GraphId`s index into the runtime's
//! compiled-executable arena (`crate::runtime`). Keeping selection
//! separate from execution lets the scheduler (and tests, and the DES)
//! reason about shape policy without touching PJRT.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GraphId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphKind {
    Prefill,
    /// Suffix prefill at a runtime offset (live prefix-cache hits): the
    /// graph's `seq` is the padded *suffix* length; per-lane
    /// block-aligned offsets are a runtime input.
    PrefillOffset,
    Decode,
    /// Draft-verify decode (speculative decoding): the graph's `seq`
    /// records **k**, the draft count — the token input is `[B, k+1]`
    /// (each lane's pending last token plus k self-drafted candidates)
    /// and every one of the k+1 query positions samples a successor.
    /// Selection requires an *exact* k match: a wider graph would score
    /// draft positions the lane never staged.
    DecodeVerify,
}

impl GraphKind {
    /// Manifest `graph` kind strings (see python/compile/aot.py).
    /// Unknown kinds are rejected by the manifest *parser* at load time
    /// (`runtime::manifest`), so by the time a kind string reaches this
    /// mapping it is one of the four known values.
    pub fn from_manifest(kind: &str) -> GraphKind {
        match kind {
            "decode" => GraphKind::Decode,
            "prefill_offset" => GraphKind::PrefillOffset,
            "decode_verify" => GraphKind::DecodeVerify,
            _ => GraphKind::Prefill,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub id: GraphId,
    pub name: String,
    pub kind: GraphKind,
    pub batch: usize,
    /// Padded sequence length (prefill), draft count k (decode verify),
    /// 0 for decode.
    pub seq: usize,
}

impl GraphSpec {
    /// Validate launch-input lengths against this graph's shape — the
    /// single check both the PJRT engine and the modeled executor
    /// apply, so the two backends can never drift: tokens are `[B]` for
    /// decode, `[B*S]` for (offset) prefill and `[B*(k+1)]` for decode
    /// verify, and `offsets` is `[B]` exactly for offset prefill
    /// graphs, empty otherwise.
    pub fn validate_launch_shapes(
        &self,
        max_blocks_per_seq: usize,
        block_tables_len: usize,
        seq_lens_len: usize,
        tokens_len: usize,
        offsets_len: usize,
    ) -> Result<(), String> {
        let b = self.batch;
        if block_tables_len != b * max_blocks_per_seq {
            return Err(format!(
                "{}: block_tables len {} != {}x{}",
                self.name, block_tables_len, b, max_blocks_per_seq
            ));
        }
        if seq_lens_len != b {
            return Err(format!("{}: seq_lens len {} != batch {}", self.name, seq_lens_len, b));
        }
        let expected_tok = match self.kind {
            GraphKind::Decode => b,
            GraphKind::Prefill | GraphKind::PrefillOffset => b * self.seq,
            GraphKind::DecodeVerify => b * (self.seq + 1),
        };
        if tokens_len != expected_tok {
            return Err(format!("{}: tokens len {} != {}", self.name, tokens_len, expected_tok));
        }
        let expected_off = if self.kind == GraphKind::PrefillOffset { b } else { 0 };
        if offsets_len != expected_off {
            return Err(format!(
                "{}: offsets len {} != {}",
                self.name, offsets_len, expected_off
            ));
        }
        Ok(())
    }
}

/// O(1) tightest-fit graph selection.
///
/// `prefill_lut[b-1][s-1]` and `decode_lut[b-1]` are fully materialized at
/// construction (≤ max_batch × max_seq entries), so runtime selection is
/// two array reads — the paper's "precomputed lookup table indexed by
/// (batch, sequence length)".
pub struct GraphCache {
    specs: Vec<GraphSpec>,
    max_batch: usize,
    max_seq: usize,
    /// Largest padded-suffix length in the offset-prefill grid (0 = the
    /// artifacts ship no offset graphs: live prefix reuse falls back to
    /// full prefill).
    max_offset_seq: usize,
    prefill_lut: Vec<Vec<Option<GraphId>>>,
    prefill_offset_lut: Vec<Vec<Option<GraphId>>>,
    decode_lut: Vec<Option<GraphId>>,
    /// Per-k decode-verify LUTs, sorted by k: `(k, [batch-1 -> id])`.
    /// k is an *exact*-match axis (a wider-k graph would score draft
    /// positions the lane never staged), batch rounds up to the
    /// tightest fit like decode. The k population is tiny (the aot
    /// k-grid), so the outer scan is effectively O(1).
    verify_luts: Vec<(usize, Vec<Option<GraphId>>)>,
    /// Fallback: the maximum-shape prefill graph.
    pub fallback_prefill: Option<GraphId>,
    pub fallback_decode: Option<GraphId>,
}

impl GraphCache {
    pub fn new(specs: Vec<GraphSpec>) -> GraphCache {
        let max_batch = specs.iter().map(|s| s.batch).max().unwrap_or(0);
        let max_seq =
            specs.iter().filter(|s| s.kind == GraphKind::Prefill).map(|s| s.seq).max().unwrap_or(0);
        let max_offset_seq = specs
            .iter()
            .filter(|s| s.kind == GraphKind::PrefillOffset)
            .map(|s| s.seq)
            .max()
            .unwrap_or(0);

        // Tightest fit = minimize (batch, then seq) among graphs that
        // fit. The offset LUT minimizes (seq, then batch) instead: its
        // seq axis is reservation-critical — a wider-than-reserved
        // suffix graph would write K/V past the admitted span — while a
        // wider batch only adds benign ghost lanes. The two orders agree
        // on rectangular grids (everything aot.py emits).
        let fit_lut = |kind: GraphKind, seq_cap: usize, seq_first: bool| {
            let mut lut: Vec<Vec<Option<GraphId>>> = vec![vec![None; seq_cap]; max_batch];
            for (bi, row) in lut.iter_mut().enumerate() {
                let b = bi + 1;
                for (si, cell) in row.iter_mut().enumerate() {
                    let s = si + 1;
                    *cell = specs
                        .iter()
                        .filter(|g| g.kind == kind && g.batch >= b && g.seq >= s)
                        .min_by_key(|g| if seq_first { (g.seq, g.batch) } else { (g.batch, g.seq) })
                        .map(|g| g.id);
                }
            }
            lut
        };
        let prefill_lut = fit_lut(GraphKind::Prefill, max_seq, false);
        let prefill_offset_lut = fit_lut(GraphKind::PrefillOffset, max_offset_seq, true);
        let mut decode_lut = vec![None; max_batch];
        for (bi, cell) in decode_lut.iter_mut().enumerate() {
            let b = bi + 1;
            *cell = specs
                .iter()
                .filter(|g| g.kind == GraphKind::Decode && g.batch >= b)
                .min_by_key(|g| g.batch)
                .map(|g| g.id);
        }
        let mut ks: Vec<usize> = specs
            .iter()
            .filter(|g| g.kind == GraphKind::DecodeVerify)
            .map(|g| g.seq)
            .collect();
        ks.sort_unstable();
        ks.dedup();
        let verify_luts = ks
            .into_iter()
            .map(|k| {
                let mut lut = vec![None; max_batch];
                for (bi, cell) in lut.iter_mut().enumerate() {
                    let b = bi + 1;
                    *cell = specs
                        .iter()
                        .filter(|g| {
                            g.kind == GraphKind::DecodeVerify && g.seq == k && g.batch >= b
                        })
                        .min_by_key(|g| g.batch)
                        .map(|g| g.id);
                }
                (k, lut)
            })
            .collect();
        let fallback_prefill = specs
            .iter()
            .filter(|g| g.kind == GraphKind::Prefill)
            .max_by_key(|g| (g.batch, g.seq))
            .map(|g| g.id);
        let fallback_decode = specs
            .iter()
            .filter(|g| g.kind == GraphKind::Decode)
            .max_by_key(|g| g.batch)
            .map(|g| g.id);
        GraphCache {
            specs,
            max_batch,
            max_seq,
            max_offset_seq,
            prefill_lut,
            prefill_offset_lut,
            decode_lut,
            verify_luts,
            fallback_prefill,
            fallback_decode,
        }
    }

    pub fn specs(&self) -> &[GraphSpec] {
        &self.specs
    }

    pub fn spec(&self, id: GraphId) -> &GraphSpec {
        &self.specs[id.0]
    }

    /// Largest decode batch available (the scheduler's batch capacity).
    pub fn max_decode_batch(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| s.kind == GraphKind::Decode)
            .map(|s| s.batch)
            .max()
            .unwrap_or(0)
    }

    pub fn max_prefill_seq(&self) -> usize {
        self.max_seq
    }

    pub fn max_prefill_batch(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| s.kind == GraphKind::Prefill)
            .map(|s| s.batch)
            .max()
            .unwrap_or(0)
    }

    /// Largest `batch × seq` token plane any (offset) prefill launch in
    /// the grid can carry — sizes the launch arena's prefill token
    /// plane. Decode launches carry `batch` tokens and verify launches
    /// `batch × (k+1)`; both ride the (widened) decode token plane, so
    /// neither participates here.
    pub fn max_launch_tokens(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| matches!(s.kind, GraphKind::Prefill | GraphKind::PrefillOffset))
            .map(|s| s.batch * s.seq)
            .max()
            .unwrap_or(0)
    }

    /// Largest `batch × (k+1)` token plane any decode-verify launch can
    /// carry (0 = no verify graphs) — sizes the decode region's widened
    /// token plane alongside the plain-decode `batch` width.
    pub fn max_verify_launch_tokens(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| s.kind == GraphKind::DecodeVerify)
            .map(|s| s.batch * (s.seq + 1))
            .max()
            .unwrap_or(0)
    }

    /// Do the artifacts provide offset prefill graphs? Gates default-on
    /// live prefix reuse (`PrefixReuse::Auto`).
    pub fn has_offset_graphs(&self) -> bool {
        self.max_offset_seq > 0
    }

    /// Largest padded-suffix length in the offset grid (0 = none).
    pub fn max_prefill_offset_seq(&self) -> usize {
        self.max_offset_seq
    }

    pub fn max_prefill_offset_batch(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| s.kind == GraphKind::PrefillOffset)
            .map(|s| s.batch)
            .max()
            .unwrap_or(0)
    }

    /// Tightest-fitting prefill graph for `batch` prompts padded to
    /// `seq` tokens; falls back to the maximum shape when off-grid.
    pub fn select_prefill(&self, batch: usize, seq: usize) -> Option<GraphId> {
        if batch == 0 || seq == 0 {
            return None;
        }
        if batch <= self.max_batch && seq <= self.max_seq {
            if let Some(id) = self.prefill_lut[batch - 1][seq - 1] {
                return Some(id);
            }
        }
        if batch <= self.max_prefill_batch() && seq <= self.max_seq {
            return self.fallback_prefill;
        }
        None
    }

    /// Tightest-fitting *offset* prefill graph for `batch` suffixes
    /// padded to `suffix` tokens. Deliberately no maximum-shape fallback:
    /// a suffix that fits no offset graph means the caller must fall back
    /// to a full prefill (and must not reserve any prefix reuse), so
    /// `None` here is the fallback signal, never a panic.
    pub fn select_prefill_offset(&self, batch: usize, suffix: usize) -> Option<GraphId> {
        if batch == 0 || suffix == 0 || batch > self.max_batch || suffix > self.max_offset_seq {
            return None;
        }
        self.prefill_offset_lut[batch - 1][suffix - 1]
    }

    /// Smallest offset-grid suffix length that fits `suffix` (the padding
    /// target for a prefix hit), or `None` when the suffix is off-grid.
    /// O(1): the (seq, batch)-first tie-break makes the batch-1 LUT
    /// entry's seq exactly the minimum covering grid length — this runs
    /// on the admission hot path (floor check + post-match padding).
    pub fn padded_offset_seq(&self, suffix: usize) -> Option<usize> {
        self.select_prefill_offset(1, suffix).map(|g| self.spec(g).seq)
    }

    /// Tightest-fitting decode graph for a live batch of `batch` lanes.
    pub fn select_decode(&self, batch: usize) -> Option<GraphId> {
        if batch == 0 {
            return None;
        }
        if batch <= self.max_batch {
            if let Some(id) = self.decode_lut[batch - 1] {
                return Some(id);
            }
        }
        None
    }

    /// Decode-verify graph for `batch` lanes drafting exactly `k`
    /// tokens: exact k match, tightest batch fit. `None` is the
    /// fall-back-to-plain-decode signal, never a panic — a wider-k
    /// graph would score draft positions the lane never staged, so no
    /// rounding on the k axis.
    pub fn select_decode_verify(&self, batch: usize, k: usize) -> Option<GraphId> {
        if batch == 0 || k == 0 || batch > self.max_batch {
            return None;
        }
        self.verify_luts
            .iter()
            .find(|(lk, _)| *lk == k)
            .and_then(|(_, lut)| lut[batch - 1])
    }

    /// Do the artifacts provide any decode-verify graphs? Gates
    /// `serve --spec-k` (requesting speculation without verify graphs
    /// is a plain-decode serve plus a warning, not an error).
    pub fn has_verify_graphs(&self) -> bool {
        !self.verify_luts.is_empty()
    }

    /// The distinct draft counts the manifest ships, ascending.
    pub fn verify_ks(&self) -> Vec<usize> {
        self.verify_luts.iter().map(|(k, _)| *k).collect()
    }

    /// Decode batch sizes (the plain-decode grid) that have NO
    /// decode-verify coverage at draft count `k` — the silent
    /// fallback-to-plain-decode case `blink info` warns about. Empty
    /// means full coverage: any batch a decode graph can serve, a
    /// k-verify graph can serve too.
    pub fn verify_uncovered_batches(&self, k: usize) -> Vec<usize> {
        self.specs
            .iter()
            .filter(|s| s.kind == GraphKind::Decode)
            .map(|s| s.batch)
            .filter(|&b| self.select_decode_verify(b, k).is_none())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> GraphCache {
        let mut specs = vec![];
        let mut id = 0;
        for b in [1usize, 2, 4] {
            for s in [16usize, 32, 64, 128] {
                specs.push(GraphSpec {
                    id: GraphId(id),
                    name: format!("prefill_b{b}_s{s}"),
                    kind: GraphKind::Prefill,
                    batch: b,
                    seq: s,
                });
                id += 1;
            }
        }
        // A *partial* offset grid (suffixes only up to 64): longer
        // suffixes must report None so the scheduler falls back to a
        // full prefill.
        for b in [1usize, 2] {
            for s in [16usize, 32, 64] {
                specs.push(GraphSpec {
                    id: GraphId(id),
                    name: format!("prefill_offset_b{b}_s{s}"),
                    kind: GraphKind::PrefillOffset,
                    batch: b,
                    seq: s,
                });
                id += 1;
            }
        }
        for b in [1usize, 2, 4, 8] {
            specs.push(GraphSpec {
                id: GraphId(id),
                name: format!("decode_b{b}"),
                kind: GraphKind::Decode,
                batch: b,
                seq: 0,
            });
            id += 1;
        }
        // A *partial* verify grid: k=2 covers every decode batch, k=4
        // only up to batch 4 — batch 8 at k=4 must fall back to plain
        // decode (and `verify_uncovered_batches` must report it).
        for (b, k) in [(1usize, 2usize), (2, 2), (4, 2), (8, 2), (1, 4), (2, 4), (4, 4)] {
            specs.push(GraphSpec {
                id: GraphId(id),
                name: format!("decode_verify_b{b}_k{k}"),
                kind: GraphKind::DecodeVerify,
                batch: b,
                seq: k,
            });
            id += 1;
        }
        GraphCache::new(specs)
    }

    #[test]
    fn tightest_fit_exact() {
        let c = cache();
        let g = c.select_prefill(2, 32).unwrap();
        assert_eq!(c.spec(g).name, "prefill_b2_s32");
    }

    #[test]
    fn tightest_fit_rounds_up() {
        let c = cache();
        let g = c.select_prefill(3, 33).unwrap();
        assert_eq!(c.spec(g).name, "prefill_b4_s64");
        let d = c.select_decode(5).unwrap();
        assert_eq!(c.spec(d).name, "decode_b8");
    }

    #[test]
    fn decode_exact_sizes() {
        let c = cache();
        for (b, want) in [(1, "decode_b1"), (2, "decode_b2"), (3, "decode_b4"), (8, "decode_b8")] {
            assert_eq!(c.spec(c.select_decode(b).unwrap()).name, want);
        }
    }

    #[test]
    fn off_grid_returns_none() {
        let c = cache();
        assert!(c.select_decode(9).is_none());
        assert!(c.select_prefill(5, 16).is_none());
        assert!(c.select_prefill(1, 1000).is_none());
        assert!(c.select_prefill(0, 16).is_none());
    }

    #[test]
    fn selection_is_consistent_with_linear_scan() {
        // The O(1) LUT must agree with a brute-force tightest-fit scan.
        let c = cache();
        for b in 1..=4usize {
            for s in 1..=128usize {
                let lin = c
                    .specs()
                    .iter()
                    .filter(|g| g.kind == GraphKind::Prefill && g.batch >= b && g.seq >= s)
                    .min_by_key(|g| (g.batch, g.seq))
                    .map(|g| g.id);
                assert_eq!(c.select_prefill(b, s), lin, "b={b} s={s}");
            }
        }
    }

    #[test]
    fn max_decode_batch_reported() {
        assert_eq!(cache().max_decode_batch(), 8);
    }

    #[test]
    fn max_launch_tokens_covers_widest_prefill_plane() {
        // Widest full-prefill plane: b4 × s128; the offset grid tops out
        // at b2 × s64, smaller.
        assert_eq!(cache().max_launch_tokens(), 4 * 128);
    }

    #[test]
    fn offset_selection_tightest_fit() {
        let c = cache();
        let g = c.select_prefill_offset(1, 16).unwrap();
        assert_eq!(c.spec(g).name, "prefill_offset_b1_s16");
        let g = c.select_prefill_offset(2, 17).unwrap();
        assert_eq!(c.spec(g).name, "prefill_offset_b2_s32", "rounds up both axes");
        let g = c.select_prefill_offset(1, 5).unwrap();
        assert_eq!(c.spec(g).name, "prefill_offset_b1_s16");
    }

    #[test]
    fn offset_selection_off_grid_is_fallback_signal_not_panic() {
        let c = cache();
        // Suffix longer than any offset graph: None (caller falls back
        // to full prefill), even though a *full* prefill graph covers it.
        assert!(c.select_prefill_offset(1, 65).is_none());
        assert!(c.select_prefill(1, 65).is_some());
        assert_eq!(c.padded_offset_seq(65), None);
        // Batch wider than the offset grid: same signal.
        assert!(c.select_prefill_offset(4, 16).is_none());
        // Degenerate inputs.
        assert!(c.select_prefill_offset(0, 16).is_none());
        assert!(c.select_prefill_offset(1, 0).is_none());
    }

    #[test]
    fn offset_selection_consistent_with_linear_scan() {
        // Offset fit minimizes (seq, batch): seq is reservation-critical.
        let c = cache();
        for b in 1..=4usize {
            for s in 1..=80usize {
                let lin = c
                    .specs()
                    .iter()
                    .filter(|g| g.kind == GraphKind::PrefillOffset && g.batch >= b && g.seq >= s)
                    .min_by_key(|g| (g.seq, g.batch))
                    .map(|g| g.id);
                assert_eq!(c.select_prefill_offset(b, s), lin, "b={b} s={s}");
            }
        }
    }

    #[test]
    fn offset_selection_never_over_provisions_seq() {
        // Non-rectangular grid: offset graphs b1_s64 and b2_s16. A
        // 16-token suffix at batch 1 must select (2, 16) — more batch is
        // benign ghost lanes — never (1, 64), whose K/V writes would
        // land past the admitted reservation.
        let mut specs = vec![];
        for (i, (b, s)) in [(1usize, 64usize), (2, 16)].iter().enumerate() {
            specs.push(GraphSpec {
                id: GraphId(i),
                name: format!("prefill_offset_b{b}_s{s}"),
                kind: GraphKind::PrefillOffset,
                batch: *b,
                seq: *s,
            });
        }
        let c = GraphCache::new(specs);
        let g = c.select_prefill_offset(1, 16).unwrap();
        assert_eq!(c.spec(g).name, "prefill_offset_b2_s16");
        // A 17-token suffix genuinely needs the s64 graph.
        let g = c.select_prefill_offset(1, 17).unwrap();
        assert_eq!(c.spec(g).name, "prefill_offset_b1_s64");
    }

    #[test]
    fn offset_grid_queries() {
        let c = cache();
        assert!(c.has_offset_graphs());
        assert_eq!(c.max_prefill_offset_seq(), 64);
        assert_eq!(c.max_prefill_offset_batch(), 2);
        assert_eq!(c.padded_offset_seq(20), Some(32));
        // A cache without offset graphs reports their absence.
        let plain = GraphCache::new(vec![GraphSpec {
            id: GraphId(0),
            name: "prefill_b1_s16".into(),
            kind: GraphKind::Prefill,
            batch: 1,
            seq: 16,
        }]);
        assert!(!plain.has_offset_graphs());
        assert!(plain.select_prefill_offset(1, 8).is_none());
    }

    #[test]
    fn manifest_kind_mapping() {
        assert_eq!(GraphKind::from_manifest("decode"), GraphKind::Decode);
        assert_eq!(GraphKind::from_manifest("prefill"), GraphKind::Prefill);
        assert_eq!(GraphKind::from_manifest("prefill_offset"), GraphKind::PrefillOffset);
        assert_eq!(GraphKind::from_manifest("decode_verify"), GraphKind::DecodeVerify);
    }

    #[test]
    fn verify_selection_exact_k_tightest_batch() {
        let c = cache();
        let g = c.select_decode_verify(2, 2).unwrap();
        assert_eq!(c.spec(g).name, "decode_verify_b2_k2");
        // Batch rounds up to the tightest fit, like decode.
        let g = c.select_decode_verify(3, 4).unwrap();
        assert_eq!(c.spec(g).name, "decode_verify_b4_k4");
        // k never rounds: k=3 has no graph even though k=4 would "fit".
        assert!(c.select_decode_verify(1, 3).is_none());
        // Off the batch grid at this k: fallback signal, not a panic.
        assert!(c.select_decode_verify(8, 4).is_none());
        assert!(c.select_decode_verify(0, 2).is_none());
        assert!(c.select_decode_verify(1, 0).is_none());
    }

    #[test]
    fn verify_coverage_queries() {
        let c = cache();
        assert!(c.has_verify_graphs());
        assert_eq!(c.verify_ks(), vec![2, 4]);
        assert_eq!(c.verify_uncovered_batches(2), Vec::<usize>::new());
        assert_eq!(c.verify_uncovered_batches(4), vec![8]);
        // Widest verify token plane: b8 × (2+1) = 24 > b4 × (4+1) = 20.
        assert_eq!(c.max_verify_launch_tokens(), 24);
        // Verify graphs never bleed into prefill-plane or decode-batch
        // sizing.
        assert_eq!(c.max_launch_tokens(), 4 * 128);
        assert_eq!(c.max_decode_batch(), 8);
        // A cache without verify graphs reports their absence.
        let plain = GraphCache::new(vec![GraphSpec {
            id: GraphId(0),
            name: "decode_b1".into(),
            kind: GraphKind::Decode,
            batch: 1,
            seq: 0,
        }]);
        assert!(!plain.has_verify_graphs());
        assert!(plain.select_decode_verify(1, 2).is_none());
        assert_eq!(plain.max_verify_launch_tokens(), 0);
        assert_eq!(plain.verify_uncovered_batches(2), vec![1]);
    }

    #[test]
    fn verify_launch_shape_validation() {
        let spec = GraphSpec {
            id: GraphId(0),
            name: "decode_verify_b2_k4".into(),
            kind: GraphKind::DecodeVerify,
            batch: 2,
            seq: 4,
        };
        // tokens = b*(k+1) = 10, offsets empty.
        assert!(spec.validate_launch_shapes(8, 16, 2, 10, 0).is_ok());
        assert!(spec.validate_launch_shapes(8, 16, 2, 2, 0).is_err());
        assert!(spec.validate_launch_shapes(8, 16, 2, 10, 2).is_err());
    }
}
