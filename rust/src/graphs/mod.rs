//! The graph cache (paper §4.2 "CUDA graph cache"): a dense grid of
//! pre-compiled (batch, sequence-length) executables with O(1)
//! tightest-fit selection via a precomputed lookup table, plus a
//! maximum-shape fallback for anything off-grid.
//!
//! This module is pure metadata — `GraphId`s index into the runtime's
//! compiled-executable arena (`crate::runtime`). Keeping selection
//! separate from execution lets the scheduler (and tests, and the DES)
//! reason about shape policy without touching PJRT.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GraphId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphKind {
    Prefill,
    Decode,
}

#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub id: GraphId,
    pub name: String,
    pub kind: GraphKind,
    pub batch: usize,
    /// Padded sequence length (prefill only; 0 for decode).
    pub seq: usize,
}

/// O(1) tightest-fit graph selection.
///
/// `prefill_lut[b-1][s-1]` and `decode_lut[b-1]` are fully materialized at
/// construction (≤ max_batch × max_seq entries), so runtime selection is
/// two array reads — the paper's "precomputed lookup table indexed by
/// (batch, sequence length)".
pub struct GraphCache {
    specs: Vec<GraphSpec>,
    max_batch: usize,
    max_seq: usize,
    prefill_lut: Vec<Vec<Option<GraphId>>>,
    decode_lut: Vec<Option<GraphId>>,
    /// Fallback: the maximum-shape prefill graph.
    pub fallback_prefill: Option<GraphId>,
    pub fallback_decode: Option<GraphId>,
}

impl GraphCache {
    pub fn new(specs: Vec<GraphSpec>) -> GraphCache {
        let max_batch = specs.iter().map(|s| s.batch).max().unwrap_or(0);
        let max_seq =
            specs.iter().filter(|s| s.kind == GraphKind::Prefill).map(|s| s.seq).max().unwrap_or(0);

        // Tightest fit = minimize (batch, then seq) among graphs that fit.
        let mut prefill_lut = vec![vec![None; max_seq]; max_batch];
        for (bi, row) in prefill_lut.iter_mut().enumerate() {
            let b = bi + 1;
            for (si, cell) in row.iter_mut().enumerate() {
                let s = si + 1;
                *cell = specs
                    .iter()
                    .filter(|g| g.kind == GraphKind::Prefill && g.batch >= b && g.seq >= s)
                    .min_by_key(|g| (g.batch, g.seq))
                    .map(|g| g.id);
            }
        }
        let mut decode_lut = vec![None; max_batch];
        for (bi, cell) in decode_lut.iter_mut().enumerate() {
            let b = bi + 1;
            *cell = specs
                .iter()
                .filter(|g| g.kind == GraphKind::Decode && g.batch >= b)
                .min_by_key(|g| g.batch)
                .map(|g| g.id);
        }
        let fallback_prefill = specs
            .iter()
            .filter(|g| g.kind == GraphKind::Prefill)
            .max_by_key(|g| (g.batch, g.seq))
            .map(|g| g.id);
        let fallback_decode = specs
            .iter()
            .filter(|g| g.kind == GraphKind::Decode)
            .max_by_key(|g| g.batch)
            .map(|g| g.id);
        GraphCache {
            specs,
            max_batch,
            max_seq,
            prefill_lut,
            decode_lut,
            fallback_prefill,
            fallback_decode,
        }
    }

    pub fn specs(&self) -> &[GraphSpec] {
        &self.specs
    }

    pub fn spec(&self, id: GraphId) -> &GraphSpec {
        &self.specs[id.0]
    }

    /// Largest decode batch available (the scheduler's batch capacity).
    pub fn max_decode_batch(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| s.kind == GraphKind::Decode)
            .map(|s| s.batch)
            .max()
            .unwrap_or(0)
    }

    pub fn max_prefill_seq(&self) -> usize {
        self.max_seq
    }

    pub fn max_prefill_batch(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| s.kind == GraphKind::Prefill)
            .map(|s| s.batch)
            .max()
            .unwrap_or(0)
    }

    /// Tightest-fitting prefill graph for `batch` prompts padded to
    /// `seq` tokens; falls back to the maximum shape when off-grid.
    pub fn select_prefill(&self, batch: usize, seq: usize) -> Option<GraphId> {
        if batch == 0 || seq == 0 {
            return None;
        }
        if batch <= self.max_batch && seq <= self.max_seq {
            if let Some(id) = self.prefill_lut[batch - 1][seq - 1] {
                return Some(id);
            }
        }
        if batch <= self.max_prefill_batch() && seq <= self.max_seq {
            return self.fallback_prefill;
        }
        None
    }

    /// Tightest-fitting decode graph for a live batch of `batch` lanes.
    pub fn select_decode(&self, batch: usize) -> Option<GraphId> {
        if batch == 0 {
            return None;
        }
        if batch <= self.max_batch {
            if let Some(id) = self.decode_lut[batch - 1] {
                return Some(id);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> GraphCache {
        let mut specs = vec![];
        let mut id = 0;
        for b in [1usize, 2, 4] {
            for s in [16usize, 32, 64, 128] {
                specs.push(GraphSpec {
                    id: GraphId(id),
                    name: format!("prefill_b{b}_s{s}"),
                    kind: GraphKind::Prefill,
                    batch: b,
                    seq: s,
                });
                id += 1;
            }
        }
        for b in [1usize, 2, 4, 8] {
            specs.push(GraphSpec {
                id: GraphId(id),
                name: format!("decode_b{b}"),
                kind: GraphKind::Decode,
                batch: b,
                seq: 0,
            });
            id += 1;
        }
        GraphCache::new(specs)
    }

    #[test]
    fn tightest_fit_exact() {
        let c = cache();
        let g = c.select_prefill(2, 32).unwrap();
        assert_eq!(c.spec(g).name, "prefill_b2_s32");
    }

    #[test]
    fn tightest_fit_rounds_up() {
        let c = cache();
        let g = c.select_prefill(3, 33).unwrap();
        assert_eq!(c.spec(g).name, "prefill_b4_s64");
        let d = c.select_decode(5).unwrap();
        assert_eq!(c.spec(d).name, "decode_b8");
    }

    #[test]
    fn decode_exact_sizes() {
        let c = cache();
        for (b, want) in [(1, "decode_b1"), (2, "decode_b2"), (3, "decode_b4"), (8, "decode_b8")] {
            assert_eq!(c.spec(c.select_decode(b).unwrap()).name, want);
        }
    }

    #[test]
    fn off_grid_returns_none() {
        let c = cache();
        assert!(c.select_decode(9).is_none());
        assert!(c.select_prefill(5, 16).is_none());
        assert!(c.select_prefill(1, 1000).is_none());
        assert!(c.select_prefill(0, 16).is_none());
    }

    #[test]
    fn selection_is_consistent_with_linear_scan() {
        // The O(1) LUT must agree with a brute-force tightest-fit scan.
        let c = cache();
        for b in 1..=4usize {
            for s in 1..=128usize {
                let lin = c
                    .specs()
                    .iter()
                    .filter(|g| g.kind == GraphKind::Prefill && g.batch >= b && g.seq >= s)
                    .min_by_key(|g| (g.batch, g.seq))
                    .map(|g| g.id);
                assert_eq!(c.select_prefill(b, s), lin, "b={b} s={s}");
            }
        }
    }

    #[test]
    fn max_decode_batch_reported() {
        assert_eq!(cache().max_decode_batch(), 8);
    }
}
