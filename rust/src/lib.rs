//! Blink: CPU-free LLM inference — rust coordinator (paper reproduction).
//!
//! See DESIGN.md for the system inventory and the paper→module map.

pub mod devsim;
pub mod eval;
pub mod frontend;
pub mod http;
pub mod server;
pub mod gpu;
pub mod hostsim;
pub mod runtime;
pub mod sim;
pub mod workload;
pub mod graphs;
pub mod kvcache;
pub mod rdma;
pub mod ringbuf;
pub mod tokenizer;
pub mod util;
